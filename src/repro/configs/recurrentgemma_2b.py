"""recurrentgemma-2b — Griffin-style hybrid: RG-LRU + local attention, 1:2.

[arXiv:2402.19427; hf] 26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000.
Pattern per Griffin: (recurrent, recurrent, local_attn) repeating; 26 = 8*3 + 2,
the final two layers are recurrent (pattern prefix). head_dim 256 per the paper.
"""
from repro.configs.base import ArchConfig, LOCAL_ATTN, RGLRU

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256_000,
    head_dim=256,
    block_pattern=(RGLRU, RGLRU, LOCAL_ATTN),
    window=2048,
    rope="standard",
    tie_embeddings=True,
    optimizer="adamw",
    source="arXiv:2402.19427; hf",
)
