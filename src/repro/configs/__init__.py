"""Config registry: ``get_config(arch_id)`` + the assigned shape table."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    ArchConfig,
    InputShape,
    MoEConfig,
    SHAPES,
    cell_status,
)

# arch-id -> module path (one module per assigned architecture).
_REGISTRY = {
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "chatglm3-6b": "repro.configs.chatglm3_6b",
    "command-r-35b": "repro.configs.command_r_35b",
    "gemma3-12b": "repro.configs.gemma3_12b",
    "llama3-8b": "repro.configs.llama3_8b",
    "llava-next-mistral-7b": "repro.configs.llava_next_mistral_7b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "xlstm-125m": "repro.configs.xlstm_125m",
}

ARCH_IDS = tuple(_REGISTRY)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return importlib.import_module(_REGISTRY[arch_id]).CONFIG


def smoke_config(arch_id: str) -> ArchConfig:
    """A reduced same-family config for CPU smoke tests.

    Keeps the layer pattern/family intact but shrinks width, depth, vocab and
    expert count so one train step runs on a single CPU device.
    """
    cfg = get_config(arch_id)
    pat = len(cfg.block_pattern)
    n_layers = max(pat, min(cfg.num_layers, pat * 2))
    moe = cfg.moe
    if moe is not None:
        import dataclasses

        # capacity_factor 4.0 => effectively dropless at smoke scale, so
        # prefill (per-row dispatch) and decode (flat dispatch) agree exactly
        moe = dataclasses.replace(
            moe, num_experts=4, top_k=min(moe.top_k, 2), d_ff_expert=64,
            capacity_factor=4.0,
        )
    return cfg.scaled(
        num_layers=n_layers,
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2),
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        window=min(cfg.window, 32) if cfg.window else 0,
        num_patch_tokens=8 if cfg.frontend == "vision" else 0,
        moe=moe,
        fsdp=False,
        attn_block_q=16,
        attn_block_kv=32,
        scan_chunk=16,
        max_seq_len=512,
    )
