"""command-r-35b — dense decoder, GQA kv=8, no biases.

[hf:CohereForAI/c4ai-command-r-v01; unverified] 40L d_model=8192 64H (GQA kv=8)
d_ff=22528 vocab=256000. Cohere ties embeddings and uses layernorm.
"""
from repro.configs.base import ArchConfig, ATTN

CONFIG = ArchConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22528,
    vocab_size=256_000,
    block_pattern=(ATTN,),
    rope="standard",
    norm="layernorm",
    tie_embeddings=True,
    fsdp=True,
    optimizer="adafactor",
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
)
