"""llava-next-mistral-7b — VLM: mistral-7b backbone + anyres vision frontend STUB.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified] 32L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=32000. The backbone is Mistral-7B-Instruct-v0.2, which dropped
the sliding window (32k full attention, rope theta 1e6). Per the system prompt,
the modality frontend is a stub: input_specs() provides precomputed patch
embeddings (anyres tiling yields up to 2880 patch tokens; we use a 576-token
base-resolution prefix) scattered at the start of the sequence.
"""
from repro.configs.base import ArchConfig, ATTN

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32_000,
    block_pattern=(ATTN,),
    rope="standard",
    rope_theta=1_000_000.0,
    frontend="vision",
    num_patch_tokens=576,
    fsdp=True,
    optimizer="adamw",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)
