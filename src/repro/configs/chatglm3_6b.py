"""chatglm3-6b — dense decoder, GQA kv=2, GLM "2d RoPE" (partial rotary).

[arXiv:2406.12793; hf] 28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
GLM applies rotary embedding to half of each head's dims (rotary_dim = head_dim/2);
we model this as rope="partial". QKV uses bias per the released checkpoint.
"""
from repro.configs.base import ArchConfig, ATTN

CONFIG = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    block_pattern=(ATTN,),
    rope="partial",
    use_bias=True,
    optimizer="adamw",
    source="arXiv:2406.12793; hf",
)
