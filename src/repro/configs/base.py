"""Architecture / run configuration dataclasses.

Every assigned architecture is expressed as an :class:`ArchConfig`. A config is a
pure description — no jax state is touched at import time. Model construction
(`repro.models.transformer`) consumes the config; the launcher
(`repro.launch.dryrun` / `train`) pairs it with an :class:`InputShape` and a mesh.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Sequence

# Layer kinds usable inside a block pattern.
ATTN = "attn"                # global causal self attention
LOCAL_ATTN = "local_attn"    # sliding-window causal self attention
ENC_ATTN = "enc_attn"        # bidirectional (encoder) self attention
RGLRU = "rglru"              # RG-LRU recurrent block (Griffin / RecurrentGemma)
MLSTM = "mlstm"              # xLSTM matrix-memory block
SLSTM = "slstm"              # xLSTM scalar-memory block

LAYER_KINDS = (ATTN, LOCAL_ATTN, ENC_ATTN, RGLRU, MLSTM, SLSTM)
_RECURRENT_KINDS = (RGLRU, MLSTM, SLSTM)


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings for FFN sublayers."""

    num_experts: int
    top_k: int
    d_ff_expert: int
    # Apply MoE FFN on every `interleave`-th layer (1 = every layer). Non-MoE
    # layers use a dense FFN of width `ArchConfig.d_ff`.
    interleave: int = 1
    shared_expert: bool = False
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class ArchConfig:
    """A complete architecture description (one per assigned arch)."""

    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None   # default: d_model // num_heads
    # The repeating unit of layer kinds. num_layers = k*len(pattern) + r; the
    # final r layers reuse the pattern prefix, applied unscanned.
    block_pattern: Sequence[str] = (ATTN,)
    window: int = 0                  # sliding window size for LOCAL_ATTN
    rope: str = "standard"           # standard | partial | none
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    use_bias: bool = False
    tie_embeddings: bool = False
    encoder_only: bool = False
    # Modality frontend stub: None | "audio" | "vision". For "audio" the model
    # input is precomputed frame embeddings (B, S, d_model); for "vision" the
    # input is tokens plus a prefix of precomputed patch embeddings.
    frontend: Optional[str] = None
    num_patch_tokens: int = 0        # vision frontend: patch-embedding prefix len
    moe: Optional[MoEConfig] = None
    max_seq_len: int = 131_072

    # Explicit long-context capability (long_500k decode): recurrent/SSM archs
    # and local-attention-dominant hybrids whose global-KV share stays linear.
    # None => derived from is_subquadratic.
    long_context: bool | None = None

    # --- distribution hints -------------------------------------------------
    fsdp: bool = False               # additionally shard weights over the data axis
    optimizer: str = "adamw"         # adamw | adafactor | sgdm
    remat: str = "full"              # full | dots | none
    # Query-block size for blocked (flash-style) attention at the jnp level.
    attn_block_q: int = 512
    attn_block_kv: int = 1024
    # "flash": custom-VJP recompute backward (O(S) residuals);
    # "naive": plain scan AD (O(S^2) bwd residual traffic) — the recorded
    # pre-hillclimb baseline in EXPERIMENTS.md §Perf.
    attn_impl: str = "flash"
    # Gradient-accumulation microbatches per optimizer step (1 = off).
    # Remat-saved activations shrink by this factor.
    accum_steps: int = 1
    scan_chunk: int = 256            # chunk size for recurrent chunkwise forms

    # --- bookkeeping ---------------------------------------------------------
    source: str = ""                 # provenance note ([arXiv/hf]; tier)

    def __post_init__(self):
        for k in self.block_pattern:
            if k not in LAYER_KINDS:
                raise ValueError(f"unknown layer kind {k!r}")
        if self.encoder_only and any(k != ENC_ATTN for k in self.block_pattern):
            raise ValueError("encoder_only configs must use enc_attn layers")
        if self.num_heads % self.num_kv_heads:
            raise ValueError("num_heads must be divisible by num_kv_heads")

    # -- derived -------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def is_subquadratic(self) -> bool:
        """True when per-token decode state does not grow O(seq) for the
        *dominant* layer kind (recurrent/hybrid/local archs)."""
        kinds = set(self.block_pattern)
        return bool(kinds & set(_RECURRENT_KINDS)) or (
            LOCAL_ATTN in kinds and ATTN not in kinds
        )

    @property
    def supports_decode(self) -> bool:
        return not self.encoder_only

    def layer_kinds(self) -> list[str]:
        """Kind of every layer, pattern repeated/truncated to num_layers."""
        pat = list(self.block_pattern)
        reps = -(-self.num_layers // len(pat))
        return (pat * reps)[: self.num_layers]

    def layer_is_moe(self, layer_idx: int) -> bool:
        if self.moe is None:
            return False
        return (layer_idx % self.moe.interleave) == (self.moe.interleave - 1)

    def scaled(self, **overrides) -> "ArchConfig":
        """A reduced copy for smoke tests (same family, small dims)."""
        return dataclasses.replace(self, **overrides)


@dataclass(frozen=True)
class InputShape:
    """One assigned (seq_len, global_batch) workload cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_serve(self) -> bool:
        return self.kind in ("prefill", "decode")


# The four assigned LM shapes.
SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def cell_status(cfg: ArchConfig, shape: InputShape) -> tuple[bool, str]:
    """Whether an (arch, shape) cell runs, and the reason when skipped.

    Skip rules follow DESIGN.md §4: decode shapes need an autoregressive step;
    long_500k needs a sub-quadratic arch.
    """
    if shape.kind == "decode" and not cfg.supports_decode:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k":
        capable = cfg.long_context if cfg.long_context is not None else cfg.is_subquadratic
        if not capable:
            return False, "pure full-attention arch; 500k decode KV skipped per DESIGN.md"
    return True, ""
