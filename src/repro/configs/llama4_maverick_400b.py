"""llama4-maverick-400b-a17b — interleaved MoE, 128 experts top-1 + shared expert.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified] 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1, early fusion. To reach ~400B total /
~17B active we follow the released Maverick layout: MoE FFN on every 2nd layer
(interleave=2) with a shared expert, dense layers use d_ff=16384 (inferred;
noted in DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, MoEConfig, ATTN

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=202_048,
    block_pattern=(ATTN,),
    rope="standard",
    rope_theta=500_000.0,
    moe=MoEConfig(
        num_experts=128,
        top_k=1,
        d_ff_expert=8192,
        interleave=2,
        shared_expert=True,
    ),
    fsdp=True,
    optimizer="adafactor",
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
