"""hubert-xlarge — encoder-only audio transformer (wav2vec2 arch).

[arXiv:2106.07447; unverified] 48L d_model=1280 16H d_ff=5120 vocab=504
(masked-unit prediction targets). kv=16 => MHA. head_dim = 1280/16 = 80,
kept faithful (not padded to 128; noted in DESIGN.md). The CNN waveform
frontend is a STUB: input_specs() provides precomputed frame embeddings
(B, S, d_model). LayerNorm + biases per fairseq.
"""
from repro.configs.base import ArchConfig, ENC_ATTN

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    block_pattern=(ENC_ATTN,),
    rope="none",
    norm="layernorm",
    use_bias=True,
    encoder_only=True,
    frontend="audio",
    max_seq_len=32_768,
    optimizer="adamw",
    source="arXiv:2106.07447; unverified",
)
