"""dbrx-132b — fine-grained MoE, 16 experts top-4 on every layer.

[hf:databricks/dbrx-base; unverified] 40L d_model=6144 48H (GQA kv=8)
d_ff=10752 vocab=100352, MoE 16e top-4.
"""
from repro.configs.base import ArchConfig, MoEConfig, ATTN

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100_352,
    block_pattern=(ATTN,),
    rope="standard",
    rope_theta=500_000.0,
    norm="layernorm",
    moe=MoEConfig(num_experts=16, top_k=4, d_ff_expert=10752, interleave=1),
    fsdp=True,
    optimizer="adafactor",
    source="hf:databricks/dbrx-base; unverified",
)
