"""gemma3-12b — dense decoder, 5 local : 1 global attention, 128k context.

[hf:google/gemma-3-1b-pt; unverified] 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144. Gemma3 uses explicit head_dim=256 (16*256=4096 != d_model) and a
1024-token sliding window on local layers; pattern (local x5, global) x 8.
"""
from repro.configs.base import ArchConfig, ATTN, LOCAL_ATTN

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    d_ff=15360,
    vocab_size=262_144,
    head_dim=256,
    block_pattern=(LOCAL_ATTN, LOCAL_ATTN, LOCAL_ATTN, LOCAL_ATTN, LOCAL_ATTN, ATTN),
    window=1024,
    rope="standard",
    long_context=True,  # 5:1 local:global — global-KV share stays linear
    tie_embeddings=True,
    fsdp=True,
    optimizer="adamw",
    source="hf:google/gemma-3-1b-pt; unverified",
)
