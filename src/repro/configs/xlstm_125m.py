"""xlstm-125m — sLSTM + mLSTM recurrent blocks.

[arXiv:2405.04517; unverified] 12L d_model=768 4H d_ff=0 vocab=50304. d_ff=0 =>
blocks carry their own up/down projections (xLSTM block style). We use a
(mlstm, mlstm, mlstm, slstm) repeating unit (3:1; the paper's xLSTM[7:1] uses a
similar sparse sLSTM placement — noted in DESIGN.md). head_dim 192.
"""
from repro.configs.base import ArchConfig, MLSTM, SLSTM

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    block_pattern=(MLSTM, MLSTM, MLSTM, SLSTM),
    rope="none",
    norm="layernorm",
    use_bias=True,
    tie_embeddings=True,
    optimizer="adamw",
    source="arXiv:2405.04517; unverified",
)
