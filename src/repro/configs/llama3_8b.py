"""llama3-8b — dense decoder, GQA kv=8, 128k vocab.

[arXiv:2407.21783; unverified] 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256. SwiGLU FFN, RMSNorm, rope theta 500000.
"""
from repro.configs.base import ArchConfig, ATTN

CONFIG = ArchConfig(
    name="llama3-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128_256,
    block_pattern=(ATTN,),
    rope="standard",
    rope_theta=500_000.0,
    fsdp=True,
    optimizer="adamw",
    source="arXiv:2407.21783; unverified",
)
