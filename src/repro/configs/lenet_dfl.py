"""The paper's own workload: LeNet-5 on MNIST-shaped inputs (28x28x1, 10 classes).

Used by the paper-faithful reproduction (Figs 10-17, Tables IV-VII). This is a
CNN, not an ArchConfig; see repro.models.lenet.
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class LeNetConfig:
    name: str = "lenet-dfl"
    image_size: int = 28
    in_channels: int = 1
    num_classes: int = 10
    conv_channels: tuple = (6, 16)
    fc_dims: tuple = (120, 84)
    # Caffe LeNet solver defaults (paper §VI-D): base_lr 0.01, momentum 0.9,
    # inv decay lr_t = base_lr * (1 + gamma*t)^-power
    base_lr: float = 0.01
    momentum: float = 0.9
    lr_gamma: float = 1e-4
    lr_power: float = 0.75


CONFIG = LeNetConfig()
