"""Digest-chained sharded checkpointing (checkpoint/restart fault tolerance).

Each checkpoint is a directory of per-host ``.npz`` shards plus a manifest:

    manifest.json: step, arch, per-array {path, shape, dtype, sha256},
                   prev_digest (previous checkpoint's manifest digest),
                   digest (sha256 of the above)

The prev_digest chain makes checkpoint history a DFL proof-of-contribution:
``verify_chain`` audits that no checkpoint was tampered with or dropped —
the blockchain idea (paper §III-F) applied to training artifacts. On restart
``latest``/``restore`` re-verify every array hash before handing state back.

Multi-host: each process saves only its addressable shards under
``shard-<process_index>``; this container is single-process, and the layout
is identical.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
from typing import Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(re.sub(r"[\[\]'\.]", "", str(p)) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _digest(arr: np.ndarray) -> str:
    return hashlib.sha256(arr.tobytes()).hexdigest()


def save(ckpt_dir: str, state, step: int, *, arch: str = "",
         extra: Optional[dict] = None) -> str:
    """Write checkpoint for `step`; returns the manifest digest."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    flat = _flatten(state)
    shard_file = os.path.join(path, f"shard-{jax.process_index()}.npz")
    np.savez(shard_file, **flat)

    prev = latest_manifest(ckpt_dir, before=step)
    manifest = {
        "step": step,
        "arch": arch,
        "extra": extra or {},
        "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                       "sha256": _digest(v)} for k, v in flat.items()},
        "prev_digest": prev["digest"] if prev else "0" * 64,
    }
    blob = json.dumps(manifest, sort_keys=True).encode()
    manifest["digest"] = hashlib.sha256(blob).hexdigest()
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest["digest"]


def _manifests(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in sorted(os.listdir(ckpt_dir)):
        mf = os.path.join(ckpt_dir, d, "manifest.json")
        if os.path.exists(mf):
            with open(mf) as f:
                out.append((d, json.load(f)))
    return out


def latest_manifest(ckpt_dir: str, before: Optional[int] = None):
    ms = [m for _, m in _manifests(ckpt_dir)
          if before is None or m["step"] < before]
    return max(ms, key=lambda m: m["step"]) if ms else None


def verify_chain(ckpt_dir: str) -> bool:
    """Audit the digest chain across all checkpoints (proof of contribution)."""
    prev = "0" * 64
    for _, m in sorted(_manifests(ckpt_dir), key=lambda x: x[1]["step"]):
        if m["prev_digest"] != prev:
            return False
        blob = dict(m)
        digest = blob.pop("digest")
        recomputed = hashlib.sha256(
            json.dumps(blob, sort_keys=True).encode()).hexdigest()
        if recomputed != digest:
            return False
        prev = digest
    return True


def restore(ckpt_dir: str, state_like, step: Optional[int] = None):
    """Load the latest (or given) checkpoint into the structure of
    ``state_like``. Verifies every array's sha256. Returns (state, step)."""
    m = (latest_manifest(ckpt_dir) if step is None
         else next(mm for _, mm in _manifests(ckpt_dir) if mm["step"] == step))
    if m is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{m['step']:08d}")
    shard_file = os.path.join(path, f"shard-{jax.process_index()}.npz")
    data = np.load(shard_file)
    for k, spec in m["arrays"].items():
        if _digest(data[k]) != spec["sha256"]:
            raise ValueError(f"checkpoint corruption detected in {k}")

    flat_like = _flatten(state_like)
    assert set(flat_like) == set(data.files), "state structure mismatch"
    leaves, treedef = jax.tree_util.tree_flatten(state_like)
    paths = [p for p, _ in jax.tree_util.tree_flatten_with_path(state_like)[0]]
    keys = ["/".join(re.sub(r"[\[\]'\.]", "", str(x)) for x in p) for p in paths]
    new_leaves = [jax.numpy.asarray(data[k]) for k in keys]
    return jax.tree_util.tree_unflatten(treedef, new_leaves), m["step"]


def prune(ckpt_dir: str, keep: int = 3):
    ms = sorted(_manifests(ckpt_dir), key=lambda x: x[1]["step"])
    for d, _ in ms[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
