"""Step builders: train_step / prefill / decode, plus abstract input specs
and sharding resolution for every (arch x shape) cell.

Everything here is mesh-agnostic until ``resolve_shardings`` pairs the logical
axes with a mesh; the dry-run lowers the same functions the real launcher runs.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import sharding as sh
from repro.configs.base import ArchConfig, InputShape
from repro.models import transformer
from repro.optim import optimizers as opt_lib
from repro.optim import schedules

_AXES_LEAF = lambda x: isinstance(x, tuple) and all(
    y is None or isinstance(y, str) for y in x)


# ------------------------------------------------------------ abstract structs
def abstract_params(cfg: ArchConfig):
    """(ShapeDtypeStruct params, logical axes) without allocating anything."""
    box = {}

    def f():
        p, a = transformer.init(jax.random.PRNGKey(0), cfg)
        box["axes"] = a
        return p

    shapes = jax.eval_shape(f)
    return shapes, box["axes"]


def abstract_cache(cfg: ArchConfig, batch: int, max_seq: int):
    box = {}

    def f():
        c, a = transformer.cache_init(cfg, batch, max_seq)
        box["axes"] = a
        return c

    shapes = jax.eval_shape(f)
    return shapes, box["axes"]


def opt_state_axes(opt_name: str, params_axes, params):
    """Logical axes for optimizer state, mirroring the param axes."""
    if opt_name in ("adamw",):
        return {"m": params_axes, "v": params_axes}
    if opt_name == "sgdm":
        return {"mu": params_axes}
    if opt_name == "adafactor":
        def leaf(a, p):
            if len(p.shape) >= 2:
                return {"vr": tuple(a[:-1]), "vc": tuple(a[:-2]) + (a[-1],)}
            return {"v": a}

        return {"v": jax.tree.map(leaf, params_axes, params, is_leaf=_AXES_LEAF)}
    raise KeyError(opt_name)


def input_specs(cfg: ArchConfig, shape: InputShape) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        if cfg.frontend == "audio":
            batch = {
                "frame_embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
                "loss_mask": jax.ShapeDtypeStruct((B, S), jnp.float32),
            }
        else:
            batch = {
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }
            if cfg.frontend == "vision":
                batch["patch_embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.num_patch_tokens, cfg.d_model), jnp.bfloat16)
        return batch
    if shape.kind == "prefill":
        if cfg.frontend == "audio":
            return {"frame_embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)}
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.frontend == "vision":
            batch["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_patch_tokens, cfg.d_model), jnp.bfloat16)
        return batch
    # decode: one new token against a seq_len KV cache
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}


def batch_axes(cfg: ArchConfig, shape: InputShape):
    specs = {
        "tokens": (sh.BATCH, sh.SEQ),
        "labels": (sh.BATCH, sh.SEQ),
        "loss_mask": (sh.BATCH, sh.SEQ),
        "frame_embeds": (sh.BATCH, sh.SEQ, None),
        "patch_embeds": (sh.BATCH, None, None),
    }
    return {k: specs[k] for k in input_specs(cfg, shape)}


# ---------------------------------------------------------------- step builders
def make_lr_fn(cfg: ArchConfig, total_steps: int = 100_000):
    peak = 3e-4 if cfg.optimizer != "adafactor" else 1e-3
    return schedules.warmup_cosine(peak, 2_000, total_steps)


def make_optimizer(cfg: ArchConfig, total_steps: int = 100_000):
    return opt_lib.make_optimizer(cfg.optimizer, make_lr_fn(cfg, total_steps))


def make_train_step(cfg: ArchConfig, opt: Optional[opt_lib.Optimizer] = None,
                    grad_clip: float = 1.0):
    opt = opt or make_optimizer(cfg)
    accum = max(1, cfg.accum_steps)

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: transformer.train_loss(p, cfg, batch),
            has_aux=True)(params)

    def train_step(state, batch):
        if accum == 1:
            (loss, metrics), grads = grads_of(state["params"], batch)
        else:
            # microbatch over the batch dim: live activations shrink accum x
            micro = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
                batch)

            def acc_body(carry, mb):
                g_acc, m_acc = carry
                (loss, metrics), g = grads_of(state["params"], mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                     g_acc, g)
                m_acc = jax.tree.map(lambda a, b: a + b / accum, m_acc, metrics)
                return (g_acc, m_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state["params"])
            m0 = {"loss": jnp.zeros(()), "accuracy": jnp.zeros(()),
                  "aux": jnp.zeros(())}
            (grads, metrics), _ = jax.lax.scan(acc_body, (g0, m0), micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
        grads, gnorm = opt_lib.clip_by_global_norm(grads, grad_clip)
        updates, opt_state = opt.update(grads, state["opt"], state["params"],
                                        state["step"])
        params = opt_lib.apply_updates(state["params"], updates)
        metrics = dict(metrics, grad_norm=gnorm)
        return ({"params": params, "opt": opt_state, "step": state["step"] + 1},
                metrics)

    return train_step


def make_prefill(cfg: ArchConfig):
    def prefill_step(params, batch, cache):
        return transformer.prefill(params, cfg, batch, cache)

    return prefill_step


def make_decode(cfg: ArchConfig):
    def decode_step(params, cache, tokens, position):
        return transformer.decode_step(params, cfg, tokens, cache, position)

    return decode_step


def init_train_state(cfg: ArchConfig, key, opt: Optional[opt_lib.Optimizer] = None):
    """Concrete state (smoke tests / real training on small configs)."""
    opt = opt or make_optimizer(cfg)
    params, axes = transformer.init(key, cfg)
    return {
        "params": params,
        "opt": opt.init(params),
        "step": jnp.zeros((), jnp.int32),
    }, axes


def abstract_train_state(cfg: ArchConfig):
    """(state ShapeDtypeStructs, state logical axes) for the dry-run."""
    params, p_axes = abstract_params(cfg)
    opt = make_optimizer(cfg)

    def f():
        zeros = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params)
        return opt.init(zeros)

    opt_shapes = jax.eval_shape(f)
    o_axes = opt_state_axes(cfg.optimizer, p_axes, params)
    state = {"params": params, "opt": opt_shapes,
             "step": jax.ShapeDtypeStruct((), jnp.int32)}
    axes = {"params": p_axes, "opt": o_axes, "step": ()}
    return state, axes


# ------------------------------------------------------------------- shardings
def rules_for(cfg: ArchConfig, shape: InputShape) -> dict:
    decode = shape.kind == "decode"
    rules = sh.make_rules(fsdp=cfg.fsdp)
    if decode:
        # KV cache sequence dim: prefer data (frees when batch < data axis),
        # else model (flash-decoding style sequence parallelism).
        rules[sh.KV_SEQ] = (("data",), ("model",))
    return rules


def state_shardings(state, axes, mesh, rules):
    return sh.tree_shardings(axes, mesh, rules, state)


def batch_shardings(cfg, shape, batch_struct, mesh, rules):
    return sh.tree_shardings(batch_axes(cfg, shape), mesh, rules, batch_struct)
