"""Fault tolerance & elasticity for the DFL federation (DESIGN.md §7).

DFL's partial consensus means node failure needs NO global recovery protocol:
a dead replica simply stops gossiping; its ring neighbors renumber. This
module provides the host-side control plane:

* ``HeartbeatMonitor`` — failure detection from per-replica step heartbeats.
* ``FedRing`` — live-membership ring; on change, gossip round functions are
  rebuilt (recompile) for the new fed size while surviving replicas keep
  their params/opt state untouched (bounded loss: at most H local steps of
  the dead node's contribution).
* ``StragglerPolicy`` — the paper's expire_time applied to gossip: a replica
  whose heartbeat lags more than `stale_after` rounds is treated as expired
  and skipped by the ring (bounded staleness), instead of stalling the world
  as a synchronous all-reduce would.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass
class HeartbeatMonitor:
    timeout_s: float = 300.0
    _last: Dict[int, float] = dataclasses.field(default_factory=dict)

    def beat(self, replica: int, now: Optional[float] = None):
        self._last[replica] = time.time() if now is None else now

    def dead(self, now: Optional[float] = None) -> List[int]:
        now = time.time() if now is None else now
        return [r for r, t in self._last.items() if now - t > self.timeout_s]

    def alive(self, now: Optional[float] = None) -> List[int]:
        now = time.time() if now is None else now
        return [r for r, t in self._last.items() if now - t <= self.timeout_s]


@dataclasses.dataclass
class StragglerPolicy:
    stale_after: int = 2  # rounds (the paper's expire_time, in gossip rounds)
    _round_of: Dict[int, int] = dataclasses.field(default_factory=dict)

    def report(self, replica: int, round_idx: int):
        self._round_of[replica] = round_idx

    def fresh(self, replica: int, current_round: int) -> bool:
        seen = self._round_of.get(replica)
        return seen is not None and current_round - seen <= self.stale_after


class FedRing:
    """Live federation membership; rebuilds ring permutations on change."""

    def __init__(self, replicas: List[int]):
        self.members = list(replicas)
        self.epoch = 0  # bumps on every membership change -> recompile key

    def fail(self, replica: int):
        if replica in self.members:
            self.members.remove(replica)
            self.epoch += 1

    def join(self, replica: int):
        if replica not in self.members:
            self.members.append(replica)
            self.epoch += 1

    @property
    def size(self) -> int:
        return len(self.members)

    def perms(self):
        """(fwd, bwd) ring permutations over CURRENT members, expressed in
        dense rank space 0..size-1 (callers re-map params to dense ranks)."""
        n = self.size
        fwd = [(i, (i + 1) % n) for i in range(n)]
        bwd = [(i, (i - 1) % n) for i in range(n)]
        return fwd, bwd

    def dense_rank(self, replica: int) -> int:
        return self.members.index(replica)


def elastic_gossip_builder(make_round_fn: Callable[[int], Callable]):
    """Memoize gossip-round builds per fed size: membership changes reuse
    compiled rounds for sizes seen before (recompile happens at most once
    per distinct live count)."""
    cache: Dict[int, Callable] = {}

    def get(fed_size: int) -> Callable:
        if fed_size not in cache:
            cache[fed_size] = make_round_fn(fed_size)
        return cache[fed_size]

    return get
