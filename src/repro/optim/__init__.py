from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adafactor,
    adamw,
    apply_updates,
    clip_by_global_norm,
    global_norm,
    make_optimizer,
    sgd_momentum,
)
from repro.optim.schedules import caffe_inv, constant, warmup_cosine  # noqa: F401
