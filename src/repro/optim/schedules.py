"""Learning-rate schedules (step -> lr, jnp-traceable)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak_lr, warmup_steps, total_steps, final_frac=0.1):
    def fn(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(1.0, (s + 1.0) / max(1, warmup_steps))
        frac = jnp.clip((s - warmup_steps) / max(1, total_steps - warmup_steps), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(s < warmup_steps, warm, cos)

    return fn


def caffe_inv(base_lr, gamma=1e-4, power=0.75):
    """Caffe 'inv' policy — the paper's LeNet solver (§VI-D)."""
    def fn(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.asarray(step, jnp.float32)
        return base_lr * (1.0 + gamma * s) ** (-power)

    return fn
