"""Optimizers in the optax style (init/update pairs), built from scratch
(optax is not available offline). State is a pytree compatible with pjit.

    opt = make_optimizer(name, lr_fn, **hp)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params, step)
    params = apply_updates(params, updates)
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable
    update: Callable  # (grads, state, params, step) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


# ----------------------------------------------------------------- sgd+momentum
def sgd_momentum(lr_fn, momentum=0.9, weight_decay=0.0):
    def init(params):
        return {"mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params, step):
        lr = lr_fn(step)
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                          state["mu"], grads)
        upd = jax.tree.map(
            lambda m, p: -lr * (m + weight_decay * p.astype(jnp.float32)),
            mu, params)
        return upd, {"mu": mu}

    return Optimizer("sgdm", init, update)


# ------------------------------------------------------------------------ adamw
def adamw(lr_fn, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1):
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(m, v, p):
            mhat = m / bc1
            vhat = v / bc2
            return -lr * (mhat / (jnp.sqrt(vhat) + eps)
                          + weight_decay * p.astype(jnp.float32))

        return jax.tree.map(upd, m, v, params), {"m": m, "v": v}

    return Optimizer("adamw", init, update)


# -------------------------------------------------------------------- adafactor
def adafactor(lr_fn, decay=0.8, eps=1e-30, clip_threshold=1.0):
    """Factored second moments for >=2D params (memory: O(n+m) vs O(n*m));
    used by the >=35B configs so optimizer state fits per-device HBM."""

    def _factored(shape):
        return len(shape) >= 2

    def init(params):
        def leaf(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(p, jnp.float32)}

        return {"v": jax.tree.map(leaf, params)}

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** -decay
        lr = lr_fn(step)

        def leaf(g, s, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(p.shape):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                v = (vr[..., None] * vc[..., None, :]) / denom[..., None]
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                new_s = {"v": v}
            u = g * jax.lax.rsqrt(v + eps)
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return -lr * u, new_s

        # two passes (XLA CSE merges the duplicate math); a single map with
        # tuple outputs would collide with tuple-valued param substructure.
        upd = jax.tree.map(lambda g, s, p: leaf(g, s, p)[0],
                           grads, state["v"], params)
        new_v = jax.tree.map(lambda g, s, p: leaf(g, s, p)[1],
                             grads, state["v"], params)
        return upd, {"v": new_v}

    return Optimizer("adafactor", init, update)


_FACTORIES = {"sgdm": sgd_momentum, "adamw": adamw, "adafactor": adafactor}


def make_optimizer(name, lr_fn, **hp) -> Optimizer:
    if name not in _FACTORIES:
        raise KeyError(f"unknown optimizer {name!r}")
    return _FACTORIES[name](lr_fn, **hp)
