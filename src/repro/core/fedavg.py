"""Reputation-weighted federated averaging — the paper's Eq. 2 and Eq. 3.

    weight_n     = reputation_n * accuracy_n                      (Eq. 2)
    model_out    = (sum_n weight_n / weight_T * model_n + model_prev) / 2   (Eq. 3)

Two equivalent forms:
* ``weighted_fedavg``      — stacked models (N, ...) pytree; used by the
  paper-scale simulator FedAvg buffer (and accelerated by the wfedavg Pallas
  kernel on flat param vectors).
* ``streaming_accumulator`` — running (sum_w_model, sum_w) pair; used inside
  the pod-scale gossip round so 2*ttl neighbor models never need to be
  stacked in memory at once.

If the total weight is ~0 (every sender's reputation crushed to 0), the
previous model is kept unchanged — the paper's buffer simply has nothing
trustworthy in it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-12


def model_weights(reputation, accuracy):
    """Eq. 2. Both in [0, 1]; elementwise product."""
    return reputation * accuracy


def weighted_fedavg(stacked_models, weights, prev_model):
    """Eq. 3 over stacked models (leading dim N). fp32 math."""
    w = weights.astype(jnp.float32)
    w_t = jnp.sum(w)
    safe = w_t > EPS
    wn = jnp.where(safe, w / jnp.maximum(w_t, EPS), 0.0)

    def leaf(ms, prev):
        mf = ms.astype(jnp.float32)
        avg = jnp.tensordot(wn, mf, axes=(0, 0))
        out = 0.5 * (avg + prev.astype(jnp.float32))
        return jnp.where(safe, out, prev.astype(jnp.float32)).astype(prev.dtype)

    return jax.tree.map(leaf, stacked_models, prev_model)


def streaming_init(model_like):
    acc = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), model_like)
    return acc, jnp.zeros((), jnp.float32)


def streaming_add(acc_state, model, weight):
    acc, w_t = acc_state
    w = weight.astype(jnp.float32)
    acc = jax.tree.map(lambda a, m: a + w * m.astype(jnp.float32), acc, model)
    return acc, w_t + w


def streaming_finish(acc_state, prev_model):
    """Eq. 3 from the running sums."""
    acc, w_t = acc_state
    safe = w_t > EPS

    def leaf(a, prev):
        avg = a / jnp.maximum(w_t, EPS)
        out = 0.5 * (avg + prev.astype(jnp.float32))
        return jnp.where(safe, out, prev.astype(jnp.float32)).astype(prev.dtype)

    return jax.tree.map(leaf, acc, prev_model)
