"""Partial consensus on a TPU mesh: ttl-bounded ring gossip (paper §III-B).

The paper broadcasts model transactions `ttl` hops into a p2p network; every
receiver measures the model's accuracy on its own data (the receipt) and
feeds reputation-weighted FedAvg. Here the "network" is the federation axis
of the mesh (pod axis multi-pod, or the data axis single-pod) and a broadcast
hop is one ``jax.lax.ppermute`` — the whole round is ONE jitted program:

    for hop in 1..ttl:   (static unroll)
        fwd <- ppermute(fwd, +1); bwd <- ppermute(bwd, -1)
        for each received model m from sender s:
            acc_s = eval(m, my validation microbatch)      # the receipt
            w_s   = reputation_row[s] * acc_s              # Eq. 2
            accumulate w_s * m                             # streaming Eq. 3
    new_model = (sum w m / sum w + my_model) / 2           # Eq. 3
    reputation_row <- punish lowest-accuracy sender        # impl1/impl2

No cross-fed collective other than the 2*ttl permutes: global consensus is
waived exactly as in the paper. shard_map is manual over the fed axis only;
data/model stay auto so the model itself keeps its pjit sharding.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import sharding as sh
from repro.core import compression, fedavg
from repro.core.reputation import ReputationImpl


def tree_ppermute(tree, axis_name: str, perm):
    return jax.tree.map(lambda x: jax.lax.ppermute(x, axis_name, perm), tree)


def ring_perms(n: int):
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]
    return fwd, bwd


def make_gossip_round(
    eval_fn: Callable,
    *,
    fed_axis: str,
    fed_size: int,
    ttl: int,
    rep_impl: ReputationImpl,
    compress: Optional[str] = None,
    mesh=None,
):
    """Build the jitted gossip round.

    eval_fn(params, val_batch) -> accuracy scalar in [0, 1]; evaluated by the
    RECEIVER on its own validation microbatch (the paper's receipt).

    Inputs of the returned fn (all leading-dim fed-sharded):
        fed_params: pytree, leaves (F, ...)
        rep_rows:   (F, F) — row i is node i's opinion of every sender
        val_batch:  pytree, leaves (F, ...) per-node validation data
    Returns (new_fed_params, new_rep_rows, metrics).
    """
    if not 1 <= ttl:
        raise ValueError("ttl must be >= 1")
    fwd_perm, bwd_perm = ring_perms(fed_size)

    def _send(tree):
        if compress == "int8":
            qt, spec = compression.quantize_tree(tree)
            # barrier: stop XLA from hoisting the receiver's dequant convert
            # BEFORE the ppermute (measured: it otherwise permutes fp32 and
            # defeats the compression entirely — §Perf iteration log)
            return jax.lax.optimization_barrier(qt), spec
        return tree, None

    def _recv(payload, spec):
        if compress == "int8":
            return compression.dequantize_tree(
                jax.lax.optimization_barrier(payload), spec)
        return payload

    def _node_fn(params, rep_row, val_batch):
        # leaves arrive with a leading fed dim of size 1 — strip it
        params = jax.tree.map(lambda x: x[0], params)
        rep_row = rep_row[0]                    # (F,)
        val_batch = jax.tree.map(lambda x: x[0], val_batch)
        me = jax.lax.axis_index(fed_axis)

        payload, spec = _send(params)
        fwd = bwd = payload
        acc_state = fedavg.streaming_init(params)
        senders, accs = [], []
        for hop in range(1, ttl + 1):
            fwd = tree_ppermute(fwd, fed_axis, fwd_perm)
            bwd = tree_ppermute(bwd, fed_axis, bwd_perm)
            for payload_h, off in ((fwd, -hop), (bwd, +hop)):
                sender = jnp.mod(me + off, fed_size)
                model = _recv(payload_h, spec)
                acc = eval_fn(model, val_batch)          # receipt accuracy
                rep = jnp.take(rep_row, sender, axis=0)
                w = fedavg.model_weights(rep, acc)       # Eq. 2
                acc_state = fedavg.streaming_add(acc_state, model, w)
                senders.append(sender)
                accs.append(acc)
        new_params = fedavg.streaming_finish(acc_state, params)  # Eq. 3
        sender_ids = jnp.stack(senders)
        acc_vec = jnp.stack(accs)
        new_rep = rep_impl.update_row(rep_row, sender_ids, acc_vec)
        metrics = {
            "mean_neighbor_acc": jnp.mean(acc_vec),
            "min_neighbor_acc": jnp.min(acc_vec),
            "rep_min": jnp.min(new_rep),
        }
        # restore the leading fed dim for out_specs
        return (
            jax.tree.map(lambda x: x[None], new_params),
            new_rep[None],
            jax.tree.map(lambda x: x[None], metrics),
        )

    def node_fn(params, rep_row, val_batch):
        # activation constraints cannot be applied on vma-typed arrays
        # inside the manual region — suppress them for the receipt evals
        with sh.no_activation_sharding():
            return _node_fn(params, rep_row, val_batch)

    def gossip_round(fed_params, rep_rows, val_batch):
        kwargs = dict(
            in_specs=(P(fed_axis), P(fed_axis), P(fed_axis)),
            out_specs=(P(fed_axis), P(fed_axis), P(fed_axis)),
            axis_names={fed_axis},
            check_vma=False,
        )
        if mesh is not None:
            kwargs["mesh"] = mesh
        return jax.shard_map(node_fn, **kwargs)(fed_params, rep_rows, val_batch)

    return gossip_round


def make_local_steps(train_step_fn, *, fed_axis: str, num_steps: int = 1,
                     mesh=None):
    """H local optimizer steps per federation node — no cross-fed collectives
    (the paper's asynchronous local training between broadcasts).

    fed_state: train-state pytree with leading fed dim; batches: leaves
    (F, H, ...) — H microbatches per node per round.
    """

    def node_fn(state, batches):
        state = jax.tree.map(lambda x: x[0], state)
        batches = jax.tree.map(lambda x: x[0], batches)

        def body(s, b):
            with sh.no_activation_sharding():
                s, metrics = train_step_fn(s, b)
            return s, metrics

        state, metrics = jax.lax.scan(body, state, batches)
        metrics = jax.tree.map(lambda m: m[-1], metrics)  # last step's metrics
        return (jax.tree.map(lambda x: x[None], state),
                jax.tree.map(lambda x: x[None], metrics))

    def local_steps(fed_state, fed_batches):
        kwargs = dict(
            in_specs=(P(fed_axis), P(fed_axis)),
            out_specs=(P(fed_axis), P(fed_axis)),
            axis_names={fed_axis},
            check_vma=False,
        )
        if mesh is not None:
            kwargs["mesh"] = mesh
        return jax.shard_map(node_fn, **kwargs)(fed_state, fed_batches)

    return local_steps
