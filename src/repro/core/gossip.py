"""Partial consensus on a TPU mesh: ttl-bounded gossip (paper §III-B) over an
arbitrary static topology.

The paper broadcasts model transactions `ttl` hops into a p2p network; every
receiver measures the model's accuracy on its own data (the receipt) and
feeds reputation-weighted FedAvg. Here the "network" is the federation axis
of the mesh (pod axis multi-pod, or the data axis single-pod) and the gossip
graph is a `repro.core.topology.Topology` baked into ONE jitted program: its
ttl-bounded flood compiles to a static schedule of permutation steps
(`topology.gossip_schedule` — the per-hop BFS-frontier lowering, EXACT for
every topology: each in-ball (receiver, sender) pair delivered exactly once,
at its BFS hop; the legacy under-covering chain lowering stays behind
``schedule="chain"`` as a regression oracle), one ``jax.lax.ppermute`` each:

    for each step (perm, parent):          (static unroll)
        payload <- ppermute(parent step's payload or my model, perm)
        s = senders[step, me]     # -1: broken chain or duplicate delivery
        acc_s = eval(payload, my validation microbatch)   # the receipt
        w_s   = reputation_row[s] * acc_s * (s >= 0)      # Eq. 2
        accumulate w_s * payload                          # streaming Eq. 3
    new_model = (sum w m / sum w + my_model) / 2              # Eq. 3
    reputation_row <- punish lowest-accuracy sender           # impl1/impl2

The default topology is the seed's bidirectional ring, which lowers to the
same 2*ttl collective-permutes as the original hard-wired ``ring_perms``
implementation. No cross-fed collective other than the schedule's permutes:
global consensus is waived exactly as in the paper. shard_map is manual over
the fed axis only; data/model stay auto so the model itself keeps its pjit
sharding.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro import sharding as sh
from repro.core import compression, fedavg
from repro.core import topology as topology_lib
from repro.core.reputation import ReputationImpl


def tree_ppermute(tree, axis_name: str, perm):
    return jax.tree.map(lambda x: jax.lax.ppermute(x, axis_name, perm), tree)


def ring_perms(n: int):
    """The seed's hard-wired bidirectional ring (kept as a reference point —
    `topology.ring(n).perm_schedule()` reproduces exactly these two perms)."""
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]
    return fwd, bwd


def make_gossip_round(
    eval_fn: Callable,
    *,
    fed_axis: str,
    fed_size: int,
    ttl: int,
    rep_impl: ReputationImpl,
    compress: Optional[str] = None,
    mesh=None,
    topology: Optional[topology_lib.Topology] = None,
    schedule: str = "frontier",
):
    """Build the jitted gossip round.

    eval_fn(params, val_batch) -> accuracy scalar in [0, 1]; evaluated by the
    RECEIVER on its own validation microbatch (the paper's receipt).

    ``topology`` is any `repro.core.topology.Topology` over ``fed_size`` nodes
    (default: the bidirectional ring, matching the seed lowering). The round
    costs ``gossip_schedule(topology, ttl).num_collectives`` permutes.
    ``schedule`` picks the lowering: ``"frontier"`` (default, exact ttl-ball
    on every topology) or ``"chain"`` (the legacy chain-walk oracle, which
    under-covers the ball on irregular graphs at ttl >= 2).

    Inputs of the returned fn (all leading-dim fed-sharded):
        fed_params: pytree, leaves (F, ...)
        rep_rows:   (F, F) — row i is node i's opinion of every sender
        val_batch:  pytree, leaves (F, ...) per-node validation data
    Returns (new_fed_params, new_rep_rows, metrics).
    """
    if not 1 <= ttl:
        raise ValueError("ttl must be >= 1")
    if topology is None:
        topology = topology_lib.ring(fed_size)
    if topology.num_nodes != fed_size:
        raise ValueError(
            f"topology has {topology.num_nodes} nodes, fed_size={fed_size}")
    schedule = topology_lib.gossip_schedule(topology, ttl,
                                            schedule=schedule)

    def _send(tree):
        if compress == "int8":
            qt, spec = compression.quantize_tree(tree)
            # barrier: stop XLA from hoisting the receiver's dequant convert
            # BEFORE the ppermute (measured: it otherwise permutes fp32 and
            # defeats the compression entirely — §Perf iteration log)
            return jax.lax.optimization_barrier(qt), spec
        return tree, None

    def _recv(payload, spec):
        if compress == "int8":
            return compression.dequantize_tree(
                jax.lax.optimization_barrier(payload), spec)
        return payload

    def _node_fn(params, rep_row, val_batch, me_arr):
        # leaves arrive with a leading fed dim of size 1 — strip it
        params = jax.tree.map(lambda x: x[0], params)
        rep_row = rep_row[0]                    # (F,)
        val_batch = jax.tree.map(lambda x: x[0], val_batch)
        # node id from a fed-sharded iota rather than jax.lax.axis_index:
        # axis_index lowers to a PartitionId instruction that the SPMD
        # partitioner rejects when the mesh has additional auto axes
        me = me_arr[0]

        payload0, spec = _send(params)
        acc_state = fedavg.streaming_init(params)
        senders, accs, valids = [], [], []
        payloads = []   # payload after each step, for forwarding chains
        for s, (perm, parent) in enumerate(schedule.steps):
            src = payload0 if parent < 0 else payloads[parent]
            payload = tree_ppermute(src, fed_axis, list(perm))
            payloads.append(payload)
            sender = jnp.take(jnp.asarray(schedule.senders[s]), me, axis=0)
            valid = (sender >= 0).astype(jnp.float32)
            sender = jnp.maximum(sender, 0)
            model = _recv(payload, spec)
            # masked steps (broken chain / duplicate delivery) carry zeros
            # or an already-counted model: mask the receipt so neither a
            # stray NaN nor a double-count can reach the weights
            acc = jnp.where(valid > 0, eval_fn(model, val_batch), 0.0)
            rep = jnp.take(rep_row, sender, axis=0)
            w = fedavg.model_weights(rep, acc) * valid        # Eq. 2
            acc_state = fedavg.streaming_add(acc_state, model, w)
            senders.append(sender)
            accs.append(acc)
            valids.append(valid)
        new_params = fedavg.streaming_finish(acc_state, params)  # Eq. 3
        sender_ids = jnp.stack(senders)
        acc_vec = jnp.stack(accs)
        valid_vec = jnp.stack(valids)
        # invalid receipts: acc pinned above 1.0 so they are never "worst",
        # and their (clamped-to-0) sender id is never punished
        updated_rep = rep_impl.update_row(
            rep_row, sender_ids, jnp.where(valid_vec > 0, acc_vec, 2.0))
        # punish-the-worst needs competition: a node with a single distinct
        # sender (degree-1 topologies) would otherwise zero its only
        # neighbor's reputation and freeze itself out of averaging. The
        # sender sets are static, so the guard is a baked per-device flag.
        distinct = jnp.asarray(  # host ints: schedule is static numpy
            [len({int(s) for s in schedule.senders[:, i] if s >= 0}) > 1  # jaxlint: ignore[host-coercion]
             for i in range(fed_size)])
        new_rep = jnp.where(jnp.take(distinct, me), updated_rep, rep_row)
        n_valid = jnp.maximum(jnp.sum(valid_vec), 1.0)
        metrics = {
            "mean_neighbor_acc": jnp.sum(acc_vec * valid_vec) / n_valid,
            "min_neighbor_acc": jnp.min(
                jnp.where(valid_vec > 0, acc_vec, jnp.inf)),
            "rep_min": jnp.min(new_rep),
            "models_received": jnp.sum(valid_vec),
        }
        # restore the leading fed dim for out_specs
        return (
            jax.tree.map(lambda x: x[None], new_params),
            new_rep[None],
            jax.tree.map(lambda x: x[None], metrics),
        )

    def node_fn(params, rep_row, val_batch, me_arr):
        # activation constraints cannot be applied on vma-typed arrays
        # inside the manual region — suppress them for the receipt evals
        with sh.no_activation_sharding():
            return _node_fn(params, rep_row, val_batch, me_arr)

    def gossip_round(fed_params, rep_rows, val_batch):
        ids = jnp.arange(fed_size, dtype=jnp.int32)
        return compat.shard_map(
            node_fn,
            mesh=mesh,
            in_specs=(P(fed_axis), P(fed_axis), P(fed_axis), P(fed_axis)),
            out_specs=(P(fed_axis), P(fed_axis), P(fed_axis)),
            axis_names={fed_axis},
            check_vma=False,
        )(fed_params, rep_rows, val_batch, ids)

    return gossip_round


def make_local_steps(train_step_fn, *, fed_axis: str, num_steps: int = 1,
                     mesh=None):
    """H local optimizer steps per federation node — no cross-fed collectives
    (the paper's asynchronous local training between broadcasts).

    fed_state: train-state pytree with leading fed dim; batches: leaves
    (F, H, ...) — H microbatches per node per round.
    """

    def node_fn(state, batches):
        state = jax.tree.map(lambda x: x[0], state)
        batches = jax.tree.map(lambda x: x[0], batches)

        def body(s, b):
            with sh.no_activation_sharding():
                s, metrics = train_step_fn(s, b)
            return s, metrics

        state, metrics = jax.lax.scan(body, state, batches)
        metrics = jax.tree.map(lambda m: m[-1], metrics)  # last step's metrics
        return (jax.tree.map(lambda x: x[None], state),
                jax.tree.map(lambda x: x[None], metrics))

    def local_steps(fed_state, fed_batches):
        return compat.shard_map(
            node_fn,
            mesh=mesh,
            in_specs=(P(fed_axis), P(fed_axis)),
            out_specs=(P(fed_axis), P(fed_axis)),
            axis_names={fed_axis},
            check_vma=False,
        )(fed_state, fed_batches)

    return local_steps
