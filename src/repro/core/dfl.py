"""DFL federation driver: state layout, eval (receipt) functions, and the
dry-run lowering of the gossip round.

Federation layout on a mesh (DESIGN.md §5):
* multi-pod (pod, data, model): fed axis = "pod" — each pod is one DFL node
  holding a full (internally sharded) replica; cross-pod traffic is ONLY the
  ttl-bounded gossip, every H local steps.
* single-pod (data, model): fed axis = "data" — 16 DFL nodes, each a 16-chip
  tensor-parallel replica. FSDP is disabled in this mode (the data axis now
  carries federation replicas, not ZeRO shards) and activation batch rules
  stop referencing the fed axis.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro import sharding as sh
from repro.configs.base import ArchConfig, InputShape
from repro.core import gossip as gossip_lib
from repro.core import reputation as rep_lib
from repro.core import topology as topology_lib
from repro.models import transformer
from repro.train import step as step_lib


@dataclasses.dataclass(frozen=True)
class DFLConfig:
    ttl: int = 1
    local_steps: int = 4          # H: optimizer steps between gossip rounds
    reputation: str = "impl2"
    compress: Optional[str] = None  # None | "int8"
    val_rows: int = 4             # validation microbatch rows per node
    val_seq: int = 1024           # validation sequence length (LM receipts)
    # gossip graph over the federation axis (repro.core.topology.make)
    topology: str = "ring"        # ring|kregular|erdos|smallworld|full
    topology_degree: int = 2      # kregular/smallworld neighbor offsets
    topology_p: float = 0.25      # erdos edge probability
    topology_beta: float = 0.2    # smallworld rewiring probability
    topology_seed: int = 0
    schedule: str = "frontier"    # gossip lowering: frontier|chain

    def make_topology(self, fed_size: int) -> topology_lib.Topology:
        return topology_lib.make(
            self.topology, fed_size, degree=self.topology_degree,
            p=self.topology_p, beta=self.topology_beta,
            seed=self.topology_seed)


def schedule_report(dfl: DFLConfig, fed_size: int, *, strict: bool = True,
                    topo: Optional[topology_lib.Topology] = None) -> dict:
    """Audit the gossip lowering this DFLConfig produces at ``fed_size``.

    Returns coverage / collective-count facts for logging and the dryrun
    record. With ``strict`` (the default for every --dfl lowering path), a
    schedule that under-covers the ttl-ball raises instead of letting the
    round silently run with partial delivery — only reachable via the
    ``schedule="chain"`` regression oracle on irregular graphs. ``topo``
    skips rebuilding an already-constructed topology.
    """
    if topo is None:
        topo = dfl.make_topology(fed_size)
    audit = topology_lib.audit_schedule(topo, dfl.ttl, schedule=dfl.schedule)
    report = {
        "topology": dfl.topology, "ttl": dfl.ttl, "schedule": dfl.schedule,
        "fed_size": fed_size,
        "coverage": round(audit.coverage, 4),
        "missing_pairs": len(audit.missing),
        "duplicate_pairs": len(audit.duplicates),
        "wasted_steps": len(audit.wasted_steps),
        "num_collectives": audit.num_collectives,
    }
    if strict and audit.missing:
        raise RuntimeError(
            f"gossip schedule under-covers the ttl-ball: "
            f"{len(audit.missing)} of the in-ball (receiver, sender) pairs "
            f"are never delivered (coverage {audit.coverage:.2f}) for "
            f"topology={dfl.topology} ttl={dfl.ttl} "
            f"schedule={dfl.schedule!r} at fed_size={fed_size}. Use the "
            f"default schedule='frontier' for exact ttl-ball flooding; "
            f"schedule='chain' is only a regression oracle.")
    return report


def fed_axis_for(mesh) -> str:
    return "pod" if "pod" in mesh.axis_names else (
        "fed" if "fed" in mesh.axis_names else "data")


def gossip_rules(cfg: ArchConfig, fed_axis: str) -> dict:
    """Sharding rules inside the gossip/eval region: never reference the fed
    axis (it is manual there), no FSDP when the data axis is the fed axis."""
    rules = sh.make_rules(fsdp=cfg.fsdp and fed_axis != "data")
    if fed_axis == "data":
        rules[sh.BATCH] = ()
    else:
        rules[sh.BATCH] = (("data",),)
    rules[sh.FED] = ((fed_axis,),)
    return rules


def make_lm_eval_fn(cfg: ArchConfig):
    """Receipt accuracy: token-level top-1 on the receiver's microbatch."""

    def eval_fn(params, val_batch):
        _, metrics = transformer.train_loss(params, cfg, val_batch)
        return metrics["accuracy"]

    return eval_fn


def val_batch_specs(cfg: ArchConfig, dfl: DFLConfig, fed_size: int):
    b, s = dfl.val_rows, dfl.val_seq
    if cfg.frontend == "audio":
        return {
            "frame_embeds": jax.ShapeDtypeStruct((fed_size, b, s, cfg.d_model), jnp.bfloat16),
            "labels": jax.ShapeDtypeStruct((fed_size, b, s), jnp.int32),
            "loss_mask": jax.ShapeDtypeStruct((fed_size, b, s), jnp.float32),
        }
    out = {
        "tokens": jax.ShapeDtypeStruct((fed_size, b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((fed_size, b, s), jnp.int32),
    }
    if cfg.frontend == "vision":
        out["patch_embeds"] = jax.ShapeDtypeStruct(
            (fed_size, b, cfg.num_patch_tokens, cfg.d_model), jnp.bfloat16)
    return out


def _prepend_fed(axes_tree):
    return jax.tree.map(
        lambda a: (sh.FED, *a), axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            y is None or isinstance(y, str) for y in x))


def abstract_fed_params(cfg: ArchConfig, fed_size: int):
    params, axes = step_lib.abstract_params(cfg)
    fed_params = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((fed_size, *s.shape), s.dtype), params)
    return fed_params, _prepend_fed(axes)


def init_federation(cfg: ArchConfig, fed_size: int, key, opt=None):
    """Concrete federation state (tests / paper-scale runs): per-node params
    (different init seeds), optimizer state, reputation rows, step counter."""
    opt = opt or step_lib.make_optimizer(cfg)
    keys = jax.random.split(key, fed_size)

    def one(k):
        params, _ = transformer.init(k, cfg)
        return {"params": params, "opt": opt.init(params),
                "step": jnp.zeros((), jnp.int32)}

    states = [one(k) for k in keys]
    fed_state = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
    rep_rows = jnp.ones((fed_size, fed_size), jnp.float32)
    return fed_state, rep_rows


def lower_gossip_round(cfg: ArchConfig, shape: InputShape, mesh, rules,
                       dfl: Optional[DFLConfig] = None,
                       schedule_checked: bool = False):
    """Dry-run entry: lower ONE gossip round (the paper's technique) for this
    arch on this mesh. Called by dryrun.py --dfl. ``schedule_checked``
    skips the under-coverage fail-fast when the caller already ran
    ``schedule_report`` (dryrun audits up front for its log/record)."""
    if shape.kind != "train":
        raise ValueError("the DFL gossip round applies to training shapes")
    dfl = dfl or DFLConfig()
    fed_axis = fed_axis_for(mesh)
    # old jaxlib aborts opaquely on partial-auto shard_map (e.g. the 16x16
    # production mesh, manual only over the fed axis) — fail fast instead
    compat.check_partial_auto_shard_map(mesh, {fed_axis})
    fed_size = mesh.shape[fed_axis]
    topo = dfl.make_topology(fed_size)
    if not schedule_checked:
        # fail fast on a schedule that under-covers the ttl-ball (only the
        # schedule="chain" oracle on irregular graphs can trip this)
        schedule_report(dfl, fed_size, strict=True, topo=topo)
    grules = gossip_rules(cfg, fed_axis)
    rep_impl = rep_lib.get(dfl.reputation)

    fed_params, fed_axes = abstract_fed_params(cfg, fed_size)
    rep_rows = jax.ShapeDtypeStruct((fed_size, fed_size), jnp.float32)
    vb = val_batch_specs(cfg, dfl, fed_size)

    p_sh = sh.tree_shardings(fed_axes, mesh, grules, fed_params)
    r_sh = NamedSharding(mesh, P(fed_axis))
    vb_axes = {k: (sh.FED, sh.BATCH, *([None] * (len(v.shape) - 2)))
               for k, v in vb.items()}
    vb_sh = sh.tree_shardings(vb_axes, mesh, grules, vb)

    round_fn = gossip_lib.make_gossip_round(
        make_lm_eval_fn(cfg), fed_axis=fed_axis, fed_size=fed_size,
        ttl=dfl.ttl, rep_impl=rep_impl, compress=dfl.compress, mesh=mesh,
        topology=topo, schedule=dfl.schedule)

    with sh.activation_sharding(mesh, grules):
        lowered = jax.jit(
            round_fn,
            in_shardings=(p_sh, r_sh, vb_sh),
            donate_argnums=(0,),
        ).lower(fed_params, rep_rows, vb)
    return lowered
