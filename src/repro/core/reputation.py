"""Reputation implementations (paper §IV-D1, §VI-E/F) — pluggable.

The paper's design: reputation only decreases, starts at 1.0, floors at 0.
Each FedAvg round the sender(s) of the lowest-accuracy model in the buffer
lose ``penalty`` (ties: all punished). Two concrete implementations are
evaluated in the paper:

    impl1 — penalty 0.01, FedAvg buffer 5   (fails under 1/5 malicious, Fig 14/15)
    impl2 — penalty 0.05, FedAvg buffer 10  (recovers, Fig 16/17)

Reputation is strictly local: node A's opinion of C is independent of B's
(§III-C). The in-graph form operates on a reputation *row* (my scores for all
senders); the host-side simulator keeps one row per node.

DFL treats this as a plug-in (§III-E): register custom implementations with
``register``; ``repro.core.dfl`` and the simulator look them up by name.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class ReputationImpl:
    name: str
    penalty: float
    buffer_size: int
    initial: float = 1.0
    floor: float = 0.0

    def update_row(self, rep_row, sender_ids, accuracies):
        """Punish the lowest-accuracy sender(s) in this round's buffer.

        rep_row: (N,) my reputation for every known node id.
        sender_ids: (K,) int32 ids of this buffer's model senders.
        accuracies: (K,) measured accuracy of each received model (my data).
        Returns the updated (N,) row. jnp-traceable. An empty buffer
        (K == 0 — a round that delivered nothing) is a no-op: nobody is
        punished, the row passes through unchanged.
        """
        accuracies = jnp.asarray(accuracies)
        if accuracies.shape[0] == 0:
            return jnp.asarray(rep_row)
        worst = jnp.min(accuracies)
        punished = (accuracies <= worst + _EPS).astype(jnp.float32)  # (K,)
        # scatter-add penalties onto the row (a sender may appear once)
        delta = jnp.zeros_like(rep_row).at[sender_ids].add(punished * self.penalty)
        return jnp.clip(rep_row - delta, self.floor, self.initial)


_REGISTRY: dict[str, ReputationImpl] = {}


def register(impl: ReputationImpl) -> ReputationImpl:
    _REGISTRY[impl.name] = impl
    return impl


def get(name: str) -> ReputationImpl:
    if name not in _REGISTRY:
        raise KeyError(f"unknown reputation impl {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


# The paper's two evaluated implementations.
IMPL1 = register(ReputationImpl("impl1", penalty=0.01, buffer_size=5))
IMPL2 = register(ReputationImpl("impl2", penalty=0.05, buffer_size=10))
