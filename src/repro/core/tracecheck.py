"""Trace-count guards: make "this function compiled once" an assertable fact.

jit hides a failure mode no numerical test catches: a function that quietly
RE-TRACES on every call (a python object in its closure changing identity, a
weak-typed scalar flipping dtype, a shape sneaking through as a python int
one call and an array the next) still returns bit-identical results — it
just pays trace+compile every time. At simulator scale that is the
difference between a sweep amortizing one compile across a grid and paying
seconds per cell (`repro.chain.sweeps` caches scenarios/topologies for
exactly this reason).

This module is the repo's chex-style ``assert_max_traces``: wrap the python
callable BEFORE handing it to ``jax.jit``. jit invokes the underlying
python function only when it actually traces, so the wrapper's call count
IS the trace count:

    counted = tracecheck.count_traces(fn, name="simlax._scan")
    jitted = jax.jit(counted)
    ...
    assert counted.counter.count == 1      # two same-shape calls, one trace

``count_traces`` only counts; ``assert_max_traces`` also raises on the
(n+1)-th trace, pointing at the retrace trigger instead of letting it hide
in wall-clock noise. Counters register by name so audits can read them
without holding the function (``tools/hlo_audit.py`` gates
``simlax`` on exactly one trace across two same-config simulators;
tests/test_tracecheck.py pins the retrace-on-shape-change contract).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional


@dataclasses.dataclass
class TraceCounter:
    """Mutable trace tally for one wrapped callable."""
    name: str
    count: int = 0
    max_traces: Optional[int] = None

    def bump(self) -> None:
        self.count += 1
        if self.max_traces is not None and self.count > self.max_traces:
            raise RuntimeError(
                f"{self.name!r} traced {self.count} times "
                f"(max_traces={self.max_traces}): a retrace means jit saw "
                "new static inputs — changed shapes/dtypes are legitimate, "
                "but same-shape retraces leak compile time on every call "
                "(unstable closure identity or a python-scalar argument?)")

    def reset(self) -> None:
        self.count = 0


_COUNTERS: Dict[str, TraceCounter] = {}


def get_counter(name: str) -> Optional[TraceCounter]:
    """The registered counter for ``name`` (None when nothing registered)."""
    return _COUNTERS.get(name)


def _register(counter: TraceCounter) -> TraceCounter:
    # last registration wins: re-wrapping under one name (e.g. a fresh
    # simulator cache entry) must not leave audits reading a dead counter
    _COUNTERS[counter.name] = counter
    return counter


def count_traces(fn: Callable, *, name: Optional[str] = None,
                 max_traces: Optional[int] = None) -> Callable:
    """Wrap ``fn`` so each python invocation bumps a ``TraceCounter``.

    Wrap BEFORE ``jax.jit``: under jit the python body only runs while
    tracing, so ``wrapped.counter.count`` is the trace count. The counter
    is exposed on the wrapper and registered under ``name`` (default: the
    function's qualname) for ``get_counter`` lookups.
    """
    counter = _register(TraceCounter(
        name=name or getattr(fn, "__qualname__", repr(fn)),
        max_traces=max_traces))

    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        counter.bump()
        return fn(*args, **kwargs)

    wrapped.counter = counter
    return wrapped


def assert_max_traces(fn: Optional[Callable] = None, *, n: int = 1,
                      name: Optional[str] = None) -> Callable:
    """chex-style decorator: the wrapped function may trace at most ``n``
    times; the (n+1)-th trace raises ``RuntimeError`` at the retrace site.

    Usable bare (``@assert_max_traces``) or parameterized
    (``@assert_max_traces(n=2)``); compose under jit as with
    ``count_traces``.
    """
    if fn is None:
        return functools.partial(assert_max_traces, n=n, name=name)
    return count_traces(fn, name=name, max_traces=n)
