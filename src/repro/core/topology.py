"""Static gossip topologies for the DFL federation (paper §VI-D scale-out).

The paper evaluates a fully-connected 5-node network and leaves "larger
networks and more complex situations" to its simulator. Related surveys
(arXiv:2401.17319) stress that the gossip graph shapes both convergence and
poisoning robustness, so this module makes the graph a first-class, swappable
object consumed by BOTH execution paths:

* the jitted pod-scale gossip round (`repro.core.gossip`) — the adjacency is
  decomposed into *permutation schedules*: a set of partial permutations
  (directed edge colouring) each of which lowers to one
  ``jax.lax.ppermute`` per hop;
* the tick simulators (`repro.chain.network` heap reference and the
  vectorized `repro.chain.simlax`) — as a dense adjacency matrix / name dict.

Supported families (``make(kind, n, ...)``):
    ring        1-regular ring (the seed's hard-wired graph)
    kregular    circulant ring with neighbours at offsets ±1..±k
    erdos       Erdős–Rényi G(n, p), resampled until connected
    smallworld  Watts–Strogatz: kregular ring with edges rewired w.p. beta
    full        fully connected (the paper's §VI topology)

Everything here is host-side numpy: graphs are built once, validated, and
baked into the jitted round as static constants.

Budget invariants (consumed by ``repro.chain.simlax``):

* ``delivery_budget(adj, ttl)`` — max ttl-ball size over receivers: the
  width of the sparse/compact engines' per-receiver arrival-slot buffers.
  A delivery can only come from the receiver's ball, so an ``(N, budget)``
  slot layout can never overflow.
* ``compaction_budget(adj, ttl, intervals)`` — exact bound on deliveries
  due on any ONE tick across the whole federation (per-sender max-weight
  ring-subset DP): the compact engine's flat work-buffer width ``W``.
* ``batch_budgets(adj, ttl, intervals, dead_sets)`` — the two bounds per
  federation of a batched (vmapped) run plus their max over the batch;
  stacked federations share one static slot width / work buffer, so the
  batch budget is the max over members (see docs/SWEEPS.md).

Both single-run bounds accept a dead-node-masked adjacency; masking only
shrinks balls/rings, so budgets computed on the masked graph stay safe.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

KINDS = ("ring", "kregular", "erdos", "smallworld", "full")

_UNREACH = np.iinfo(np.int32).max


@dataclasses.dataclass(frozen=True)
class Topology:
    """An undirected, connected, self-loop-free gossip graph."""

    kind: str
    adj: np.ndarray  # (N, N) bool, symmetric, zero diagonal

    def __post_init__(self):
        validate_adjacency(self.adj)

    # ------------------------------------------------------------- basic views
    @property
    def num_nodes(self) -> int:
        return self.adj.shape[0]

    @property
    def num_edges(self) -> int:
        return int(self.adj.sum()) // 2

    def degrees(self) -> np.ndarray:
        return self.adj.sum(axis=1).astype(np.int32)

    def neighbors(self, i: int) -> List[int]:
        return [int(j) for j in np.flatnonzero(self.adj[i])]

    def as_name_dict(self, names: Sequence[str]) -> Dict[str, List[str]]:
        """Adjacency in the heap `Simulator`'s {name: [peer, ...]} form."""
        if len(names) != self.num_nodes:
            raise ValueError(
                f"{len(names)} names for {self.num_nodes} nodes")
        return {names[i]: [names[j] for j in self.neighbors(i)]
                for i in range(self.num_nodes)}

    # ---------------------------------------------------------------- analysis
    def hop_distance(self) -> np.ndarray:
        """(N, N) int32 BFS hop counts; unreachable pairs get INT32_MAX."""
        return hop_distance_from_adj(self.adj)

    def is_connected(self) -> bool:
        return bool((self.hop_distance() < _UNREACH).all())

    # ------------------------------------------------------ permutation decomp
    def perm_schedule(self) -> List[List[tuple]]:
        """Decompose directed edges into partial permutations.

        Each returned colour class is a list of ``(src, dst)`` pairs in which
        every node appears at most once as a source and at most once as a
        destination — exactly the contract of ``jax.lax.ppermute``. Every
        directed edge (both orientations of each undirected edge) lands in
        exactly one class; König's bound guarantees max-degree classes exist,
        the greedy here may use a few more on irregular graphs (harmless: one
        extra ppermute per extra class).

        Circulant graphs (ring/kregular) are special-cased so the classes come
        out as the offset permutations [+1, -1, +2, -2, ...] — for ``ring``
        this reproduces the seed's ``ring_perms`` lowering verbatim.
        """
        n = self.num_nodes
        offsets = _circulant_offsets(self.adj)
        if offsets is not None:
            sched = []
            for k in offsets:
                sched.append([(i, (i + k) % n) for i in range(n)])
                if 2 * k != n:  # ±n/2 coincide on even n: one perm suffices
                    sched.append([(i, (i - k) % n) for i in range(n)])
            return sched
        edges = [(i, int(j)) for i in range(n)
                 for j in np.flatnonzero(self.adj[i])]
        sched = []
        while edges:
            srcs, dsts, cls, rest = set(), set(), [], []
            for (u, v) in edges:
                if u in srcs or v in dsts:
                    rest.append((u, v))
                else:
                    srcs.add(u)
                    dsts.add(v)
                    cls.append((u, v))
            sched.append(cls)
            edges = rest
        return sched


def hop_distance_from_adj(adj: np.ndarray, *,
                          max_hops: int | None = None) -> np.ndarray:
    """BFS hop counts over a raw (possibly partially-masked) adjacency;
    unreachable pairs get INT32_MAX. No validity requirements — usable on
    graphs with isolated nodes (e.g. dead-node-masked simulations).

    ``max_hops`` caps the search depth: pairs farther than ``max_hops``
    report INT32_MAX exactly as if unreachable. The tick simulators only
    consume distances within ``ttl`` (reach masks, delay tables, ring
    sizes), so capping at ``ttl`` is result-identical for them while
    turning the all-pairs cost from O(N * edges * diameter) into
    O(N^2 * max_hops / word-width) — the difference between minutes and
    sub-second at the sharded engine's N ~ 10^4 scale.

    All sources advance one synchronized frontier per step (a boolean
    product against the adjacency), so distances are the BFS hop counts
    bit-for-bit — there is no per-source ordering to diverge. Sparse
    graphs (max in-degree <= 64) expand frontiers by gathering padded
    in-neighbor lists, O(N^2 * degree) per hop; dense ones fall back to a
    float32 matmul (BLAS; exact for row sums <= 2^24)."""
    n = adj.shape[0]
    dist = np.full((n, n), _UNREACH, np.int32)
    np.fill_diagonal(dist, 0)
    frontier = np.eye(n, dtype=np.bool_)
    visited = frontier.copy()
    limit = n if max_hops is None else min(int(max_hops), n)
    deg_in = adj.sum(axis=0)
    k = int(deg_in.max()) if n else 0
    if k == 0 or limit < 1:
        return dist
    if k <= 64:
        # padded in-neighbor lists: nlist[u] = {v : edge v->u}, pad = n
        vs, us = np.nonzero(adj)
        order = np.argsort(us, kind="stable")
        us_s, vs_s = us[order], vs[order]
        starts = np.concatenate(
            ([0], np.cumsum(np.bincount(us_s, minlength=n))[:-1]))
        nlist = np.full((n, k), n, np.int64)
        nlist[us_s, np.arange(len(us_s)) - starts[us_s]] = vs_s
        fr_pad = np.zeros((n, n + 1), np.bool_)  # col n: always-False pad
        d = 0
        while frontier.any() and d < limit:
            d += 1
            fr_pad[:, :n] = frontier
            nxt = fr_pad[:, nlist[:, 0]]
            for j in range(1, k):                # per-column gathers avoid
                nxt |= fr_pad[:, nlist[:, j]]    # the (N, N, k) temp
            frontier = nxt & ~visited
            dist[frontier] = d
            visited |= frontier
        return dist
    adj_f = adj.astype(np.float32)
    d = 0
    while frontier.any() and d < limit:
        d += 1
        frontier = ((frontier.astype(np.float32) @ adj_f) > 0) & ~visited
        dist[frontier] = d
        visited |= frontier
    return dist


def ttl_ball_sizes(adj: np.ndarray, ttl: int, *,
                   dist: np.ndarray | None = None) -> np.ndarray:
    """(N,) int32: per node, how many OTHER nodes lie within ``ttl`` hops.

    This is the per-receiver in-flight bound of the tick simulators: a flood
    from ``src`` reaches ``dst`` iff ``1 <= dist(src, dst) <= ttl``, and each
    (dst, src) pair carries at most one in-flight model at a time, so no tick
    can deliver more than ``|ball(dst, ttl)|`` models to ``dst``. Works on
    raw (possibly dead-node-masked) adjacencies like
    ``hop_distance_from_adj``.
    """
    if ttl < 1:
        raise ValueError("ttl must be >= 1")
    if dist is None:
        dist = hop_distance_from_adj(adj)
    return ((dist >= 1) & (dist <= ttl)).sum(axis=1).astype(np.int32)


def delivery_budget(adj: np.ndarray, ttl: int, *,
                    dist: np.ndarray | None = None) -> int:
    """Static per-tick slot budget for the sparse delivery engine.

    ``max_dst |ball(dst, ttl)|`` — the exact worst case of simultaneous
    arrivals at one receiver (every in-ball sender timed so its model lands
    the same tick). The naive bound ``max_degree * ttl``-ish overcounts on
    dense graphs and undercounts on irregular ones; the BFS ball is both
    tight and safe, so the fixed-size slot buffer can never overflow.
    """
    return int(ttl_ball_sizes(adj, ttl, dist=dist).max())


def ring_sizes(adj: np.ndarray, ttl: int, *,
               dist: np.ndarray | None = None,
               receivers: np.ndarray | None = None) -> np.ndarray:
    """(N, ttl) int32: ``ring_sizes[s, d-1]`` = how many nodes lie at hop
    distance exactly ``d`` from ``s``. Rows sum to ``ttl_ball_sizes`` — the
    ball is the disjoint union of its rings. Works on raw (possibly
    dead-node-masked) adjacencies like ``hop_distance_from_adj``.

    ``receivers`` restricts the count to a subset of receiver columns: the
    sharded delivery engine budgets each shard by the deliveries landing on
    ITS nodes only, so each sender's ring is intersected with the shard's
    receiver block. Senders stay all-N — any node can send into the block.
    """
    if ttl < 1:
        raise ValueError("ttl must be >= 1")
    if dist is None:
        dist = hop_distance_from_adj(adj)
    if receivers is not None:
        dist = dist[:, np.asarray(receivers)]
    n = adj.shape[0]
    out = np.zeros((n, ttl), np.int32)
    for d in range(1, ttl + 1):
        out[:, d - 1] = (dist == d).sum(axis=1)
    return out


def compaction_budget(adj: np.ndarray, ttl: int, intervals, *,
                      latency: int = 1,
                      dist: np.ndarray | None = None,
                      receivers: np.ndarray | None = None) -> int:
    """Static bound on deliveries due on any ONE tick across the whole
    federation — the compact delivery engine's work-buffer width.

    A broadcast from ``src`` at tick ``t_b`` schedules its ttl-ball
    arrivals at ``t_b + d * latency``: one hop-distance *ring* of receivers
    per future tick. Two rings of the SAME sender can be due on the same
    tick only when they stem from two broadcasts spaced exactly
    ``(d2 - d1) * latency`` ticks apart, and a node trains at most once
    every ``lo = intervals[0]`` ticks — so co-due distances must be at
    least ``g = ceil(lo / latency)`` apart. Each sender therefore
    contributes at most its max-weight subset of ``{1..ttl}`` with pairwise
    gaps ``>= g``, weighted by its ring sizes, and the per-tick total is
    that summed over senders (exact: nothing stops every sender from timing
    its heaviest feasible ring combination onto one tick).

    In the recommended operating regime ``lo >= ttl * latency`` (outside
    it ``LaxSimulator`` warns: re-broadcast overwrites in-flight snapshots,
    which ALSO forbids multi-ring co-dueness, so the bound stays safe there
    too — just no longer tight) the gap exceeds ``ttl - 1``, feasible sets
    are singletons, and the bound collapses to
    ``sum_src max_d |ring(src, d)|``. Always ``<= N * delivery_budget``
    (the sparse engine's total slot count): the compact buffer is never
    larger than the sparse one.

    ``receivers`` restricts the bound to deliveries landing on that subset
    of nodes (see ``ring_sizes``): the sharded engine sizes each shard's
    work buffer by its own receiver block, so the per-shard budgets sum to
    at most the global one (rings partition over disjoint blocks).
    """
    lo = int(intervals[0]) if np.ndim(intervals) else int(intervals)
    if lo < 1:
        raise ValueError(f"min train interval must be >= 1, got {lo}")
    if latency < 1:
        raise ValueError(f"latency must be >= 1, got {latency}")
    rings = ring_sizes(adj, ttl, dist=dist, receivers=receivers)  # (N, ttl)
    g = max(1, -(-lo // latency))                    # ceil(lo / latency)
    # per-sender max-weight subset of distances with pairwise gaps >= g:
    # f[d] = ring[d] + best over earlier picks at distance <= d - g
    n = rings.shape[0]
    f = np.zeros((n, ttl + 1), np.int64)             # f[:, d], d = 1..ttl
    best_prefix = np.zeros((n, ttl + 1), np.int64)   # max f[:, 1..d]
    for d in range(1, ttl + 1):
        prev = best_prefix[:, d - g] if d - g >= 1 else 0
        f[:, d] = rings[:, d - 1] + prev
        best_prefix[:, d] = np.maximum(best_prefix[:, d - 1], f[:, d])
    return int(best_prefix[:, ttl].sum())


@dataclasses.dataclass(frozen=True)
class BatchBudgets:
    """Static delivery/compaction budgets for a batch of federations that
    share one topology (but may differ in dead-node sets): the per-member
    bounds plus their max over the batch. A vmapped multi-federation run
    carries ONE static ``(N, budget)`` slot layout and ONE ``(W,)`` work
    buffer for the whole batch, so the shared widths are the maxima; the
    per-federation columns record how much headroom each member has."""

    delivery: int                             # max over the batch, >= 1
    compaction: int                           # max over the batch, >= 1
    per_federation_delivery: tuple            # (B,) ints
    per_federation_compaction: tuple          # (B,) ints


def batch_budgets(adj: np.ndarray, ttl: int, intervals,
                  dead_sets: Sequence[Sequence[int]], *,
                  latency: int = 1,
                  dists: Optional[Sequence[np.ndarray]] = None
                  ) -> BatchBudgets:
    """``delivery_budget`` / ``compaction_budget`` over a batch of
    federations sharing one topology: member ``b`` routes on ``adj`` with
    ``dead_sets[b]`` masked out (rows AND columns — dead nodes neither
    send nor forward, exactly the mask ``LaxSimulator`` applies), and the
    batch budget is the max over members. ``dists`` optionally supplies
    precomputed ``hop_distance_from_adj`` results per member (the caller
    usually needs them anyway). Budgets are floored at 1 so downstream
    array shapes stay non-degenerate even for an all-dead member."""
    if not len(dead_sets):
        raise ValueError("batch_budgets needs >= 1 federation")
    if dists is not None and len(dists) != len(dead_sets):
        raise ValueError(
            f"{len(dists)} dists for {len(dead_sets)} federations")
    per_del, per_comp = [], []
    for b, dead in enumerate(dead_sets):
        alive = np.ones((adj.shape[0],), np.bool_)
        alive[list(dead)] = False
        masked = adj & alive[None, :] & alive[:, None]
        dist = dists[b] if dists is not None \
            else hop_distance_from_adj(masked)
        per_del.append(max(1, delivery_budget(masked, ttl, dist=dist)))
        per_comp.append(max(1, compaction_budget(
            masked, ttl, intervals, latency=latency, dist=dist)))
    return BatchBudgets(
        delivery=max(per_del), compaction=max(per_comp),
        per_federation_delivery=tuple(per_del),
        per_federation_compaction=tuple(per_comp))


def validate_adjacency(adj: np.ndarray) -> None:
    if adj.ndim != 2 or adj.shape[0] != adj.shape[1]:
        raise ValueError(f"adjacency must be square, got {adj.shape}")
    if adj.dtype != np.bool_:
        raise ValueError("adjacency must be boolean")
    if adj.shape[0] < 2:
        raise ValueError("a gossip graph needs at least 2 nodes")
    if np.diagonal(adj).any():
        raise ValueError("self-loops are not allowed")
    if not (adj == adj.T).all():
        raise ValueError("adjacency must be symmetric (undirected gossip)")
    if (adj.sum(axis=1) == 0).any():
        raise ValueError("isolated node: every node needs >= 1 neighbor")


def _circulant_offsets(adj: np.ndarray):
    """If adj is the circulant graph with neighbour offsets ±1..±k, return
    [1..k]; otherwise None."""
    n = adj.shape[0]
    row = adj[0]
    offs = sorted(int(o) for o in np.flatnonzero(row) if int(o) <= n // 2)
    ks = [o for o in offs if o <= (n - 1) // 2 or 2 * o == n]
    if ks != list(range(1, len(ks) + 1)):
        return None
    expect = np.zeros((n, n), np.bool_)
    for k in range(1, len(ks) + 1):
        for i in range(n):
            expect[i, (i + k) % n] = expect[i, (i - k) % n] = True
    return list(range(1, len(ks) + 1)) if (expect == adj).all() else None


# ------------------------------------------------------------------ generators
def ring(n: int) -> Topology:
    return kregular(n, 1)


def kregular(n: int, k: int = 1) -> Topology:
    """Circulant ring: node i adjacent to i±1..i±k (mod n)."""
    if k < 1 or (2 * k > n - 1 and not (n % 2 == 0 and k == n // 2)):
        raise ValueError(f"kregular needs 1 <= k <= (n-1)/2 (or k=n/2, even "
                         f"n); got n={n}, k={k}")
    adj = np.zeros((n, n), np.bool_)
    for d in range(1, k + 1):
        for i in range(n):
            adj[i, (i + d) % n] = adj[i, (i - d) % n] = True
    return Topology("kregular" if k > 1 else "ring", adj)


def full(n: int) -> Topology:
    adj = ~np.eye(n, dtype=np.bool_)
    return Topology("full", adj)


def erdos_renyi(n: int, p: float = 0.2, seed: int = 0,
                max_tries: int = 200) -> Topology:
    """G(n, p), resampled (fresh seed each try) until connected."""
    if not 0.0 < p <= 1.0:
        raise ValueError(f"erdos needs 0 < p <= 1, got {p}")
    rng = np.random.RandomState(seed)
    for _ in range(max_tries):
        upper = rng.rand(n, n) < p
        adj = np.triu(upper, 1)
        adj = adj | adj.T
        if (adj.sum(axis=1) > 0).all():
            topo = Topology("erdos", adj)
            if topo.is_connected():
                return topo
    raise ValueError(
        f"could not sample a connected G({n}, {p}) in {max_tries} tries; "
        "raise p")


def small_world(n: int, k: int = 2, beta: float = 0.2,
                seed: int = 0) -> Topology:
    """Watts–Strogatz: kregular ring, each +offset edge rewired w.p. beta."""
    if not 0.0 <= beta <= 1.0:
        raise ValueError(f"smallworld needs 0 <= beta <= 1, got {beta}")
    rng = np.random.RandomState(seed)
    adj = kregular(n, k).adj.copy()
    for d in range(1, k + 1):
        for i in range(n):
            j = (i + d) % n
            if not adj[i, j] or rng.rand() >= beta:
                continue
            candidates = np.flatnonzero(~adj[i])
            candidates = candidates[candidates != i]
            if candidates.size == 0:
                continue
            t = int(rng.choice(candidates))
            adj[i, j] = adj[j, i] = False
            adj[i, t] = adj[t, i] = True
    topo = Topology("smallworld", adj)
    if not topo.is_connected():  # rare at beta<1; rewire again deterministically
        return small_world(n, k, beta, seed + 1)
    return topo


def make(kind: str, n: int, *, degree: int = 2, p: float = 0.2,
         beta: float = 0.2, seed: int = 0) -> Topology:
    """CLI-facing factory: ``--topology ring|kregular|erdos|smallworld|full``."""
    if kind == "ring":
        return ring(n)
    if kind == "kregular":
        return kregular(n, degree)
    if kind == "erdos":
        return erdos_renyi(n, p, seed)
    if kind == "smallworld":
        return small_world(n, degree, beta, seed)
    if kind == "full":
        return full(n)
    raise ValueError(f"unknown topology {kind!r}; choose from {KINDS}")


# ------------------------------------------------------------ gossip schedules
SCHEDULES = ("frontier", "chain")


@dataclasses.dataclass(frozen=True)
class GossipSchedule:
    """Static lowering plan for one gossip round over a topology.

    ``steps``   sequence of (perm, parent) pairs. Each step permutes either
                the node's own payload (``parent == -1``) or the payload
                received at an earlier step (``parent`` = that step's index,
                forming a forwarding chain). One ppermute per step:
                ``num_collectives == len(steps)``.
    ``senders`` (num_steps, N) int32: senders[s, i] is the node whose model
                device i holds after step s, or -1 when nothing new arrives
                there — the receiver masks that contribution's weight to
                zero, so every (receiver, sender) pair is counted AT MOST
                ONCE per round.
    ``hops``    (num_steps,) int32: the flood hop each step belongs to. The
                default ``frontier`` lowering delivers every pair (r, s) at
                hop ``hop_distance(r, s)`` — the same timing the tick
                simulators use (``arrive = t + dist * latency``).

    Coverage: the default ``frontier`` lowering is EXACT for every topology —
    each pair within the ttl-ball is delivered exactly once, nothing outside
    it ever is (``audit_schedule`` verifies this). The legacy ``chain``
    lowering (kept as a pinned-regression oracle) floods irregular graphs
    along colour-class chain walks, which silently under-covers the ball at
    ttl >= 2; circulant graphs (ring/kregular/full) lower identically under
    both (one offset permutation per in-ball distance).
    """

    steps: tuple       # ((perm, parent), ...)
    senders: np.ndarray
    hops: Optional[np.ndarray] = None

    @property
    def num_collectives(self) -> int:
        return len(self.steps)

    def delivery_counts(self) -> np.ndarray:
        """(N, N) int: how many times the schedule delivers sender s's model
        to receiver r (an exact schedule is the 0/1 ttl-ball indicator)."""
        n = self.senders.shape[1]
        got = np.zeros((n, n), int)
        for row in self.senders:
            for i in np.flatnonzero(row >= 0):
                got[i, row[i]] += 1
        return got


def _circulant_ball_schedule(n: int, k: int, ttl: int):
    """One permutation per offset in the ttl-ball {1..k*ttl} (mod wrap).

    In a circulant graph the ball of radius ttl is exactly the offsets
    o <= k*ttl; delivering each by its own one-hop permutation keeps the
    collective count at 2*k*ttl (the chain lowering's count) while hitting
    every in-ball sender exactly once — for k=1 this is the seed ring
    lowering's 2*ttl permutes.
    """
    steps, senders, hops = [], [], []
    idx = np.arange(n)
    radius = min(k * ttl, (n - 1) // 2)
    for o in range(1, radius + 1):
        hop = -(-o // k)                     # circulant dist of offset o
        steps.append((tuple((i, (i + o) % n) for i in range(n)), -1))
        senders.append((idx - o) % n)
        hops.append(hop)
        steps.append((tuple((i, (i - o) % n) for i in range(n)), -1))
        senders.append((idx + o) % n)
        hops.append(hop)
    if n % 2 == 0 and k * ttl >= n // 2:
        o = n // 2
        steps.append((tuple((i, (i + o) % n) for i in range(n)), -1))
        senders.append((idx + o) % n)
        hops.append(-(-o // k))
    return steps, np.asarray(senders, np.int32), np.asarray(hops, np.int32)


def _frontier_schedule(topo: Topology, ttl: int):
    """Exact per-hop BFS-frontier lowering for arbitrary graphs.

    Hop 1 is the colour-class decomposition of the adjacency (every direct
    neighbour delivered once, own payloads, ``parent == -1``). Hop h >= 2
    delivers every pair at BFS distance exactly h by forwarding along fresh
    frontier edges: each pair (r, s) picks a parent p — a neighbour of r one
    hop closer to s — which received s's payload at a known hop-(h-1) step.
    A ppermute step forwards ONE earlier step's payload, so hop-h tasks are
    grouped by that parent step and each group is greedily edge-coloured
    into partial permutations. Every step delivers at least one new pair;
    every in-ball pair is delivered exactly once, at its BFS hop.
    """
    n = topo.num_nodes
    dist = topo.hop_distance()
    steps, senders, hops = [], [], []
    deliv_step = np.full((n, n), -1, np.int64)   # [receiver, sender] -> step

    for cls in topo.perm_schedule():             # hop 1: own payloads
        row = np.full((n,), -1, np.int32)
        for (u, v) in cls:
            row[v] = u
            deliv_step[v, u] = len(steps)
        steps.append((tuple(cls), -1))
        senders.append(row)
        hops.append(1)

    for h in range(2, ttl + 1):
        pairs = [(r, s) for r in range(n) for s in range(n)
                 if dist[r, s] == h]
        if not pairs:
            break                                # ball saturated early
        # parent choice balances per-(step, node) load so the greedy
        # colouring below needs fewer permutes; ties break deterministically
        groups: Dict[int, list] = {}             # parent step -> [(p, r, s)]
        load_src: Dict[tuple, int] = {}
        load_dst: Dict[tuple, int] = {}
        for r, s in pairs:
            best = None
            for p in np.flatnonzero(topo.adj[r]):
                p = int(p)
                if dist[p, s] != h - 1:
                    continue
                sigma = int(deliv_step[p, s])    # p got s here at hop h-1
                cost = max(load_src.get((sigma, p), 0),
                           load_dst.get((sigma, r), 0))
                if best is None or (cost, sigma, p) < best[0]:
                    best = ((cost, sigma, p), p, sigma)
            _, p, sigma = best                   # BFS guarantees a parent
            groups.setdefault(sigma, []).append((p, r, s))
            load_src[(sigma, p)] = load_src.get((sigma, p), 0) + 1
            load_dst[(sigma, r)] = load_dst.get((sigma, r), 0) + 1
        for sigma in sorted(groups):
            colours = []                         # [(srcs, dsts, perm, row)]
            for p, r, s in groups[sigma]:
                for c in colours:
                    if p not in c[0] and r not in c[1]:
                        break
                else:
                    c = (set(), set(), [], np.full((n,), -1, np.int32))
                    colours.append(c)
                c[0].add(p)
                c[1].add(r)
                c[2].append((p, r))
                c[3][r] = s
            for _, _, perm, row in colours:
                for i in np.flatnonzero(row >= 0):
                    deliv_step[i, row[i]] = len(steps)
                steps.append((tuple(perm), sigma))
                senders.append(row)
                hops.append(h)
    return steps, np.asarray(senders, np.int32), np.asarray(hops, np.int32)


def _chain_schedule(topo: Topology, ttl: int):
    """The legacy chain-walk lowering (pinned-regression oracle): forward
    along each colour-class chain for ttl hops, masking out pairs already
    delivered. At ttl >= 2 the chain walks cover only a SUBSET of the
    ttl-ball on irregular graphs — the exact-flooding bug the frontier
    scheduler fixes; kept behind ``schedule="chain"`` so the under-coverage
    stays measurable (audit_schedule, bench_gossip frontier_vs_chain)."""
    n = topo.num_nodes
    perms = topo.perm_schedule()
    steps, senders, hops = [], [], []
    delivered = np.zeros((n, n), bool)   # [receiver, sender]
    for perm in perms:
        recv_from = np.full((n,), -1, np.int64)
        for (src, dst) in perm:
            recv_from[dst] = src
        cur = recv_from.copy()  # after hop 1, device i holds cur[i]'s model
        parent = -1
        for h in range(ttl):
            row = np.full((n,), -1, np.int32)
            for i in range(n):
                s = cur[i]
                if s >= 0 and s != i and not delivered[i, s]:
                    row[i] = s
                    delivered[i, s] = True
            steps.append((tuple(perm), parent))
            senders.append(row)
            hops.append(h + 1)
            parent = len(steps) - 1
            ok = cur >= 0
            nxt = np.full((n,), -1, np.int64)
            nxt[ok] = recv_from[cur[ok]]  # extend the backward walk one link
            cur = nxt
    # prune steps that deliver nothing (e.g. 2-cycle colour classes bounce
    # every payload home at even hops) unless a later delivering step
    # forwards through them — each step costs a full-model ppermute
    keep = [bool((row >= 0).any()) for row in senders]
    for s in range(len(steps)):
        if keep[s]:
            p = steps[s][1]
            while p >= 0 and not keep[p]:
                keep[p] = True
                p = steps[p][1]
    remap, kept_steps, kept_senders, kept_hops = {}, [], [], []
    for s, (step, row) in enumerate(zip(steps, senders, strict=True)):
        if not keep[s]:
            continue
        perm, parent = step
        remap[s] = len(kept_steps)
        kept_steps.append((perm, remap[parent] if parent >= 0 else -1))
        kept_senders.append(row)
        kept_hops.append(hops[s])
    return (kept_steps, np.asarray(kept_senders, np.int32),
            np.asarray(kept_hops, np.int32))


def gossip_schedule(topo: Topology, ttl: int, *,
                    schedule: str = "frontier") -> GossipSchedule:
    """Lower one ttl-bounded gossip round to a static ppermute plan.

    ``schedule="frontier"`` (default) is exact on every topology; circulant
    graphs (ring/kregular/full) take the closed-form offset lowering either
    way, so their collective count is identical under both modes.
    ``schedule="chain"`` replays the legacy chain-walk lowering, which
    under-covers the ttl-ball on irregular graphs at ttl >= 2.
    """
    if ttl < 1:
        raise ValueError("ttl must be >= 1")
    if schedule not in SCHEDULES:
        raise ValueError(
            f"unknown schedule {schedule!r}; choose from {SCHEDULES}")
    n = topo.num_nodes
    offsets = _circulant_offsets(topo.adj)
    if offsets is not None:
        steps, senders, hops = _circulant_ball_schedule(n, len(offsets), ttl)
    elif schedule == "frontier":
        steps, senders, hops = _frontier_schedule(topo, ttl)
    else:
        steps, senders, hops = _chain_schedule(topo, ttl)
    return GossipSchedule(steps=tuple(steps), senders=senders, hops=hops)


@dataclasses.dataclass(frozen=True)
class ScheduleAudit:
    """``audit_schedule``'s verdict on one GossipSchedule vs the BFS ball.

    ``missing``      in-ball (receiver, sender) pairs the schedule never
                     delivers — the chain lowering's under-coverage bug
    ``duplicates``   pairs delivered more than once (double-counted weights)
    ``out_of_ball``  delivered pairs with hop distance > ttl (or self/
                     unreachable)
    ``mistimed``     pairs delivered at a step whose hop != their BFS
                     distance (breaks hop-distance delivery-timing parity
                     with the tick simulators)
    ``wasted_steps`` step indices that neither deliver a new pair nor feed
                     (transitively) a delivering step — pure collective cost
    ``coverage``     delivered_pairs / ball_pairs
    """
    ttl: int
    missing: tuple
    duplicates: tuple
    out_of_ball: tuple
    mistimed: tuple
    wasted_steps: tuple
    ball_pairs: int
    delivered_pairs: int
    coverage: float
    num_collectives: int

    @property
    def ok(self) -> bool:
        return not (self.missing or self.duplicates or self.out_of_ball
                    or self.mistimed or self.wasted_steps)


def audit_schedule(topo: Topology, ttl: int,
                   sched: Optional[GossipSchedule] = None, *,
                   schedule: str = "frontier") -> ScheduleAudit:
    """Check a GossipSchedule against the exact BFS ttl-ball: every in-ball
    (receiver, sender) pair delivered exactly once, nothing else delivered,
    no step wasted. ``sched`` defaults to ``gossip_schedule(topo, ttl,
    schedule=schedule)``."""
    if sched is None:
        sched = gossip_schedule(topo, ttl, schedule=schedule)
    n = topo.num_nodes
    dist = topo.hop_distance()
    ball = (dist >= 1) & (dist <= ttl)
    counts = sched.delivery_counts()
    missing = tuple(map(tuple, np.argwhere(ball & (counts == 0))))
    duplicates = tuple(map(tuple, np.argwhere(counts > 1)))
    out_of_ball = tuple(map(tuple, np.argwhere(~ball & (counts > 0))))
    mistimed = []
    if sched.hops is not None:
        for step, row in enumerate(sched.senders):
            for r in np.flatnonzero(row >= 0):
                if dist[r, row[r]] != sched.hops[step]:
                    mistimed.append((int(r), int(row[r])))
    # a step is useful iff it delivers, or a useful step forwards through it
    useful = [bool((row >= 0).any()) for row in sched.senders]
    for s in range(len(sched.steps)):
        if useful[s]:
            p = sched.steps[s][1]
            while p >= 0 and not useful[p]:
                useful[p] = True
                p = sched.steps[p][1]
    wasted = tuple(s for s, u in enumerate(useful) if not u)
    total = int(ball.sum())
    delivered = int((ball & (counts > 0)).sum())
    return ScheduleAudit(
        ttl=ttl, missing=missing, duplicates=duplicates,
        out_of_ball=out_of_ball, mistimed=tuple(mistimed),
        wasted_steps=wasted, ball_pairs=total, delivered_pairs=delivered,
        coverage=(delivered / total) if total else 1.0,
        num_collectives=sched.num_collectives)
