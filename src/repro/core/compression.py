"""Gossip payload compression (beyond-paper optimization, cf. ref [13]).

Block-wise symmetric int8 quantization for model tensors shipped over ICI
during the gossip round: 4x fewer link bytes than fp32 master weights
(2x vs bf16) at <0.4% relative error per tensor. The Pallas kernel pair in
repro.kernels.quantize implements the same math for the TPU deployment path;
this module is the jnp reference used inside traced gossip rounds.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256  # quantization block (elements)


def _pad_len(n: int, b: int = BLOCK) -> int:
    return (b - n % b) % b


def quantize_tensor(x, block: int = BLOCK):
    """x (any shape) -> (q int8 (nblocks, block), scales fp16 (nblocks,))."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = _pad_len(flat.size, block)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    blocks = flat.reshape(-1, block)
    absmax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0].astype(jnp.float16)


def dequantize_tensor(q, scales, shape, dtype):
    flat = (q.astype(jnp.float32) * scales.astype(jnp.float32)[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def quantize_last_axis(x, block: int = BLOCK):
    """Shape-preserving variant: blocks along the LAST axis only, so leading
    (often mesh-sharded) dims keep their sharding — a flat reshape would
    force an all-gather of every leaf before quantization (measured: it
    silently 12x'd the gossip permute bytes)."""
    lead = x.shape[:-1]
    last = x.shape[-1] if x.ndim else 1
    b = min(block, max(last, 1))
    pad = (-last) % b
    xf = x.astype(jnp.float32).reshape(*lead, last)
    if pad:
        xf = jnp.pad(xf, [(0, 0)] * len(lead) + [(0, pad)])
    blocks = xf.reshape(*lead, (last + pad) // b, b)
    absmax = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0].astype(jnp.float16)


def dequantize_last_axis(q, scales, shape, dtype):
    x = q.astype(jnp.float32) * scales.astype(jnp.float32)[..., None]
    last = shape[-1] if len(shape) else 1
    x = x.reshape(*shape[:-1], -1)[..., :last]
    return x.reshape(shape).astype(dtype)


def quantize_tree(tree, block: int = BLOCK):
    """Pytree -> (pytree of (q, scales), static (shape, dtype) spec tree)."""
    spec = jax.tree.map(lambda x: (x.shape, x.dtype), tree)
    qt = jax.tree.map(lambda x: quantize_last_axis(x, block), tree)
    return qt, spec


def dequantize_tree(qt, spec):
    return jax.tree.map(
        lambda qs, sp: dequantize_last_axis(qs[0], qs[1], sp[0], sp[1]),
        qt, spec,
        is_leaf=lambda x: (isinstance(x, tuple) and len(x) == 2
                           and hasattr(x[0], "dtype")),
    )
