"""Gossip payload compression (beyond-paper optimization, cf. ref [13]).

Block-wise symmetric int8 quantization for model tensors shipped over ICI
during the gossip round: 4x fewer link bytes than fp32 master weights
(2x vs bf16) at <0.4% relative error per tensor. The Pallas kernel pair in
repro.kernels.quantize implements the same math for the TPU deployment path;
this module is the jnp reference used inside traced gossip rounds.

Scale-dtype contract (shared with the kernel pair, pinned bitwise by
tests/test_compression.py): per-block scales ship as bfloat16 and are
rounded through bf16 BEFORE q is computed, so the exact scale the
receiver multiplies by is the one the sender divided by. bf16 keeps the
full f32 exponent range, so the SCALE_EPS clamp stays representable and
tiny-magnitude leaves keep their ~0.4% relative error; fp16 scales (the
original wire format) flushed any scale under ~6e-8 to zero — nonzero
int8 payloads that dequantized to zeros — and its subnormal granularity
made the rounded scale undershoot by up to 33%, silently clipping q. The
kernel stores scales as fp32 for lane alignment but the stored value is
bit-identical to this module's bf16 scale upcast.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256  # quantization block (elements)

# Zero-block guard. Comfortably inside bf16's normal range (min normal
# ~1.2e-38), so unlike the old fp16 wire format the clamp survives the
# cast and all-zero blocks dequantize to exact zeros via q == 0.
SCALE_EPS = 1e-12


def _pad_len(n: int, b: int = BLOCK) -> int:
    return (b - n % b) % b


def _block_scale(blocks):
    """absmax blocks (..., b) -> bf16 wire scale and its exact fp32 value.

    The bf16 round-through happens before quantization so sender (divide)
    and receiver (multiply) use the identical grid; without it, q computed
    against the unrounded fp32 scale dequantizes against a different
    number. Round-to-nearest bf16 undershoots by at most 2^-9 relative,
    so x/scale tops out at ~127.25 and the clip costs < scale/4.
    """
    absmax = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    scale16 = jnp.maximum(absmax / 127.0, SCALE_EPS).astype(jnp.bfloat16)
    return scale16, scale16.astype(jnp.float32)


def quantize_tensor(x, block: int = BLOCK):
    """x (any shape) -> (q int8 (nblocks, block), scales bf16 (nblocks,)).

    Size-0 inputs produce 0 blocks: q (0, block), scales (0,).
    """
    flat = x.astype(jnp.float32).reshape(-1)
    pad = _pad_len(flat.size, block)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    blocks = flat.reshape(-1, block)
    scale16, scale = _block_scale(blocks)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale16[:, 0]


def dequantize_tensor(q, scales, shape, dtype):
    flat = (q.astype(jnp.float32) * scales.astype(jnp.float32)[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def _last_axis_blocking(shape, block: int = BLOCK):
    """shape -> (lead, last, b, nblocks) for the last-axis scheme.

    0-d arrays quantize as one 1-element block; zero-size last axes carry
    zero blocks (empty in, empty out).
    """
    lead = tuple(shape[:-1])
    last = shape[-1] if len(shape) else 1
    b = min(block, max(last, 1))
    nblocks = -(-last // b)  # ceil; 0 when last == 0
    return lead, last, b, nblocks


def quantize_last_axis(x, block: int = BLOCK):
    """Shape-preserving variant: blocks along the LAST axis only, so leading
    (often mesh-sharded) dims keep their sharding — a flat reshape would
    force an all-gather of every leaf before quantization (measured: it
    silently 12x'd the gossip permute bytes).

    Edge cases are defined, not accidental: a 0-d leaf is one 1-element
    block (q (1, 1), scales (1,)); a zero-size last axis yields zero
    blocks (q (*lead, 0, 1), scales (*lead, 0)).
    """
    lead, last, b, nblocks = _last_axis_blocking(x.shape, block)
    xf = x.astype(jnp.float32).reshape(*lead, last)
    pad = nblocks * b - last
    if pad:
        xf = jnp.pad(xf, [(0, 0)] * len(lead) + [(0, pad)])
    blocks = xf.reshape(*lead, nblocks, b)
    scale16, scale = _block_scale(blocks)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale16[..., 0]


def dequantize_last_axis(q, scales, shape, dtype):
    lead, last, b, nblocks = _last_axis_blocking(shape, q.shape[-1])
    if last == 0:
        return jnp.zeros(shape, dtype)
    x = q.astype(jnp.float32) * scales.astype(jnp.float32)[..., None]
    x = x.reshape(*lead, nblocks * b)[..., :last]
    return x.reshape(shape).astype(dtype)


def quantize_tree(tree, block: int = BLOCK):
    """Pytree -> (pytree of (q, scales), static (shape, dtype) spec tree)."""
    spec = jax.tree.map(lambda x: (x.shape, x.dtype), tree)
    qt = jax.tree.map(lambda x: quantize_last_axis(x, block), tree)
    return qt, spec


def dequantize_tree(qt, spec):
    return jax.tree.map(
        lambda qs, sp: dequantize_last_axis(qs[0], qs[1], sp[0], sp[1]),
        qt, spec,
        is_leaf=lambda x: (isinstance(x, tuple) and len(x) == 2
                           and hasattr(x[0], "dtype")),
    )


def roundtrip_tree(tree, block: int = BLOCK):
    """Quantize + immediately dequantize every leaf back to its own dtype.

    This is the simulators' wire model: the sender quantizes its broadcast
    once, every receiver sees the identical reconstruction. Because
    quantize_last_axis blocks only the last axis, applying this to a
    stacked (N, ...) pytree is bitwise identical to applying it per node —
    which is what keeps heap and lax event streams comparable bit for bit.
    """
    qt, spec = quantize_tree(tree, block)
    return dequantize_tree(qt, spec)


def leaf_wire_bytes(shape, dtype, compress) -> int:
    """Bytes on the wire for one leaf under a compression mode.

    None ships the raw dtype; "int8" ships the padded int8 blocks plus one
    bf16 scale per block (the exact arrays quantize_last_axis emits).
    """
    size = 1
    for d in shape:
        size *= d
    if compress is None:
        return size * jnp.dtype(dtype).itemsize
    if compress == "int8":
        lead, _, b, nblocks = _last_axis_blocking(shape)
        nlead = 1
        for d in lead:
            nlead *= d
        return nlead * nblocks * (b + jnp.dtype(jnp.bfloat16).itemsize)
    raise ValueError(f"unknown compress mode: {compress!r}")


def payload_bytes(tree, compress) -> int:
    """Total wire bytes for a broadcast payload pytree (arrays or anything
    with .shape/.dtype, e.g. jax.ShapeDtypeStruct)."""
    return sum(
        leaf_wire_bytes(leaf.shape, leaf.dtype, compress)
        for leaf in jax.tree.leaves(tree)
    )
