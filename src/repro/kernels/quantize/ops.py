"""Public API: quantize/dequantize a flat payload with the Pallas kernels
(interpret mode on CPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.quantize.quantize import dequantize, quantize

BLOCK_COLS = 256


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


def quantize_flat(x_flat, block_cols: int = BLOCK_COLS):
    """x (D,) -> (q (R, C) int8, scales (R, 1), orig_len)."""
    d = x_flat.size
    pad = (-d) % block_cols
    if pad:
        x_flat = jnp.pad(x_flat.astype(jnp.float32), (0, pad))
    x2 = x_flat.reshape(-1, block_cols)
    rows = x2.shape[0]
    br = rows if rows < 256 else 256
    while rows % br:
        br //= 2
    q, s = quantize(x2, block_rows=max(br, 1), interpret=_is_cpu())
    return q, s, d


def dequantize_flat(q, scales, orig_len, dtype=jnp.float32):
    rows = q.shape[0]
    br = rows if rows < 256 else 256
    while rows % br:
        br //= 2
    x2 = dequantize(q, scales, dtype=dtype, block_rows=max(br, 1),
                    interpret=_is_cpu())
    return x2.reshape(-1)[:orig_len]
