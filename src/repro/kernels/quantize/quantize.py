"""Pallas TPU kernels: block-wise symmetric int8 quantize / dequantize.

Used to compress DFL gossip payloads before the cross-pod ppermute (4x fewer
ICI bytes than fp32). One VMEM pass per tile: rowwise absmax -> scale ->
round/clip. Rows are the quantization blocks; C is lane-aligned (x128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _q_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                   # (br, C)
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)  # (br, 1)
    # Same scale contract as repro.core.compression (pinned bitwise in
    # tests/test_compression.py): clamp, then round through bf16 before
    # quantizing, so q is computed against the exact scale the bf16 wire
    # format delivers to the receiver. Stored as fp32 for lane alignment;
    # the value is the bf16 grid point.
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    scale = scale.astype(jnp.bfloat16).astype(jnp.float32)
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[...] = scale.astype(s_ref.dtype)


def _dq_kernel(q_ref, s_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)
    out_ref[...] = (q * s).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def quantize(x, *, block_rows: int = 256, interpret: bool = False):
    """x (R, C) -> (q int8 (R, C), scales fp32 (R, 1)). R % block_rows == 0."""
    r, c = x.shape
    assert r % block_rows == 0, (r, block_rows)
    grid = (r // block_rows,)
    return pl.pallas_call(
        _q_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, c), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, c), jnp.int8),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret", "dtype"))
def dequantize(q, scales, *, dtype=jnp.float32, block_rows: int = 256,
               interpret: bool = False):
    r, c = q.shape
    assert r % block_rows == 0, (r, block_rows)
    grid = (r // block_rows,)
    return pl.pallas_call(
        _dq_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), dtype),
        interpret=interpret,
    )(q, scales)
