"""Pure-jnp oracle for the int8 gossip-payload quantizer (= the math in
repro.core.compression, restated on the kernel's (nblocks, block) layout)."""
from __future__ import annotations

import jax.numpy as jnp


def quantize_ref(x):
    """x (R, C) fp -> (q int8 (R, C), scales fp32 (R, 1)).

    Scales are clamped and rounded through bf16 before q is computed — the
    contract shared with repro.core.compression, whose wire format stores
    scales in bf16.
    """
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=1, keepdims=True)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    scale = scale.astype(jnp.bfloat16).astype(jnp.float32)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_ref(q, scales):
    return q.astype(jnp.float32) * scales.astype(jnp.float32)
