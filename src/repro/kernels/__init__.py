"""Pallas TPU kernels (interpret-mode validated on CPU, TPU-targeted):

    wfedavg/          fused reputation-weighted FedAvg (paper Eq. 3)
    quantize/         int8 block quantize/dequantize (gossip payloads)
    flash_attention/  online-softmax attention forward (causal + window)

Each kernel ships <name>.py (pl.pallas_call + BlockSpec tiling), ops.py
(jit'd public wrapper) and ref.py (pure-jnp oracle used by tests).
"""
