"""Public API: GQA-aware wrapper around the Pallas flash-attention kernel.

Folds (B, H) into the kernel's leading grid dim, expands GQA KV heads, pads
the head dim to the 128-lane multiple, and dispatches to interpret mode on
CPU. Layout matches repro.models.attention: q (B,S,H,Dh), k/v (B,S,KH,Dh).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_fwd

LANE = 128


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


def flash_attention(q, k, v, *, causal=True, window=0,
                    block_q=512, block_kv=512):
    B, Sq, H, Dh = q.shape
    KH = k.shape[2]
    G = H // KH
    pad = (-Dh) % LANE
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, pad)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, 0), (0, pad)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)))
    Dp = Dh + pad
    if G > 1:  # expand KV heads for the folded layout
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, Dp)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, -1, Dp)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, -1, Dp)
    of = flash_attention_fwd(qf, kf, vf, causal=causal, window=window,
                             block_q=block_q, block_kv=block_kv,
                             interpret=_is_cpu(), scale=Dh ** -0.5)
    o = of.reshape(B, H, Sq, Dp).transpose(0, 2, 1, 3)
    return o[..., :Dh]
