"""Oracle for the Pallas flash-attention kernel: plain masked softmax
attention in fp32 (small shapes only — tests sweep shapes/dtypes)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=0):
    """q (B,Sq,H,Dh); k/v (B,Skv,H,Dh) — heads already expanded (no GQA fold).
    Returns (B,Sq,H,Dh) in q.dtype."""
    B, Sq, H, Dh = q.shape
    Skv = k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (Dh ** -0.5)
    qp = jnp.arange(Sq)
    kp = jnp.arange(Skv)
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok &= kp[None, :] <= qp[:, None]
    if window:
        ok &= kp[None, :] > qp[:, None] - window
    s = jnp.where(ok[None, None], s, -2.0e38)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)
