"""Pallas TPU flash-attention forward kernel (causal / sliding-window).

Grid (batch*heads, nq, nk): the kv axis is the minor-most ("arbitrary")
dimension, so the fp32 (m, l, acc) VMEM scratch persists across kv steps of
one q block — the online-softmax accumulation never leaves VMEM, and HBM
traffic is O(S*Dh) per head (q/k/v tiles once, out once).

Block shapes: q (bq, Dh), k/v (bkv, Dh) — Dh padded to a lane multiple by
ops.py; bq/bkv default 512/512 (q tile + 2 kv tiles + acc in fp32 stay well
under a v5e core's VMEM). The backward pass uses the jnp custom-VJP in
repro.models.flash (recompute strategy); a fused bwd kernel is future work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1.0e38


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, causal, window, bq, bkv, nk, scale):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = kj * bkv
    # skip fully-masked tiles (causal: kv entirely above the diagonal;
    # window: kv entirely below the band)
    run = jnp.asarray(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + bq - 1)
    if window:
        run = jnp.logical_and(run, k_start + bkv - 1 > q_start - window)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)                 # (bq, Dh)
        k = k_ref[0].astype(jnp.float32)                 # (bkv, Dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        ok = jnp.ones((bq, bkv), jnp.bool_)
        if causal:
            ok &= k_pos <= q_pos
        if window:
            ok &= k_pos > q_pos - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]                              # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                           # (bq, bkv)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)                 # (bkv, Dh)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new

    @pl.when(kj == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-37)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_kv", "interpret", "scale"))
def flash_attention_fwd(q, k, v, *, causal=True, window=0,
                        block_q=512, block_kv=512, interpret=False,
                        scale=None):
    """q (BH, Sq, Dh); k/v (BH, Skv, Dh) — batch and heads pre-folded,
    GQA pre-expanded (ops.py handles layout). ``scale`` must be the
    UNPADDED 1/sqrt(head_dim) when Dh was lane-padded. Returns (BH, Sq, Dh)."""
    BH, Sq, Dh = q.shape
    Skv = k.shape[1]
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    assert Sq % bq == 0 and Skv % bkv == 0
    nq, nk = Sq // bq, Skv // bkv
    grid = (BH, nq, nk)
    kern = functools.partial(
        _kernel, causal=causal, window=window, bq=bq, bkv=bkv, nk=nk,
        scale=scale if scale is not None else Dh ** -0.5)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, Dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, Dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, Dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max m
            pltpu.VMEM((bq, 1), jnp.float32),    # running denom l
            pltpu.VMEM((bq, Dh), jnp.float32),   # output accumulator
        ],
        compiler_params=compat.pallas_tpu_compiler_params(
            pltpu,
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
