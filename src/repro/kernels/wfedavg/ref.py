"""Pure-jnp oracle for the wfedavg kernel: Eq. 3 on a flat parameter block.

    out = 0.5 * (sum_n wn[n] * models[n] + prev)

``wn`` are pre-normalized weights (w / w_T); the tree-level wrapper in ops.py
handles normalization and the zero-total-weight fallback.
"""
from __future__ import annotations

import jax.numpy as jnp


def wfedavg_ref(models, wn, prev):
    """models (N, R, C); wn (N,); prev (R, C) -> (R, C) in prev.dtype."""
    acc = jnp.tensordot(wn.astype(jnp.float32), models.astype(jnp.float32),
                        axes=(0, 0))
    return (0.5 * (acc + prev.astype(jnp.float32))).astype(prev.dtype)
