"""Pallas TPU kernel: fused reputation-weighted FedAvg (paper Eq. 3).

Bandwidth-bound stacked reduction: reads N model tiles + the previous model
tile once from HBM, writes one output tile — a single fused pass instead of
N separate axpy sweeps (the naive jnp lowering materializes the weighted sum
tree). VMEM tiling: a (N, bc) model block + (1, bc) prev/out blocks per grid
step; weights live in a tiny (N, 1) VMEM block.

Lane alignment: bc is a multiple of 128 (TPU lane width); callers pad the
flattened parameter vector (ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, models_ref, prev_ref, out_ref):
    # models_ref: (N, bc); prev_ref/out_ref: (1, bc); w_ref: (N, 1)
    m = models_ref[...].astype(jnp.float32)          # (N, bc)
    w = w_ref[...].astype(jnp.float32)               # (N, 1)
    acc = jnp.sum(m * w, axis=0, keepdims=True)      # (1, bc)
    prev = prev_ref[...].astype(jnp.float32)
    out_ref[...] = (0.5 * (acc + prev)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_cols", "interpret"))
def wfedavg_flat(models, wn, prev, *, block_cols: int = 2048,
                 interpret: bool = False):
    """models (N, D); wn (N,); prev (D,) -> (D,). D % block_cols == 0."""
    n, d = models.shape
    assert d % block_cols == 0, (d, block_cols)
    grid = (d // block_cols,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((n, block_cols), lambda i: (0, i)),
            pl.BlockSpec((1, block_cols), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block_cols), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), prev.dtype),
        interpret=interpret,
    )(wn.reshape(n, 1), models, prev.reshape(1, d))
    return out.reshape(d)
