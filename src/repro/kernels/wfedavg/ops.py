"""Public API for the wfedavg kernel: tree-level weighted FedAvg.

On CPU (tests, the paper-scale simulator) the kernel runs in interpret mode;
on TPU it compiles to a fused VMEM-tiled pass. Falls back to the jnp oracle
for tiny leaves where padding overhead dominates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import fedavg as fedavg_ref
from repro.kernels.wfedavg.wfedavg import wfedavg_flat

_BLOCK = 2048
_MIN_KERNEL_ELEMS = 4096


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


def weighted_fedavg_tree(stacked_models, weights, prev_model,
                         block_cols: int = _BLOCK):
    """Eq. 3 over a pytree with stacked leading dim N (kernel-accelerated)."""
    w = weights.astype(jnp.float32)
    w_t = jnp.sum(w)
    safe = w_t > fedavg_ref.EPS
    wn = jnp.where(safe, w / jnp.maximum(w_t, fedavg_ref.EPS), 0.0)
    interpret = _is_cpu()

    def leaf(ms, prev):
        if prev.size < _MIN_KERNEL_ELEMS or not jnp.issubdtype(prev.dtype, jnp.floating):
            mf = ms.astype(jnp.float32)
            avg = jnp.tensordot(wn, mf.reshape(mf.shape[0], -1), axes=(0, 0))
            out = 0.5 * (avg.reshape(prev.shape) + prev.astype(jnp.float32))
            return jnp.where(safe, out, prev.astype(jnp.float32)).astype(prev.dtype)
        n = ms.shape[0]
        d = prev.size
        pad = (-d) % block_cols
        flat_m = ms.reshape(n, d).astype(jnp.float32)
        flat_p = prev.reshape(d).astype(jnp.float32)
        if pad:
            flat_m = jnp.pad(flat_m, ((0, 0), (0, pad)))
            flat_p = jnp.pad(flat_p, (0, pad))
        out = wfedavg_flat(flat_m, wn, flat_p, block_cols=block_cols,
                           interpret=interpret)[:d].reshape(prev.shape)
        return jnp.where(safe, out, prev.astype(jnp.float32)).astype(prev.dtype)

    return jax.tree.map(leaf, stacked_models, prev_model)
