"""Logical-axis sharding rules (MaxText-style) + activation constraints.

Params and caches are annotated with *logical* axis names (repro.models.layers
vocabulary plus the activation/cache names below). ``logical_to_spec`` maps
them to mesh axes, dropping any assignment that does not divide the physical
dim (e.g. kv_heads=2 cannot shard over model=16 -> replicated).

Models call :func:`maybe_shard` on activations; it is a no-op unless the step
builder installed a mesh context (so unit tests on one CPU device never touch
device state).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import layers as L

# Activation / cache logical axes.
BATCH = "batch"
SEQ = "seq"
RES_SEQ = "res_seq"  # residual-stream seq dim at scan-unit boundaries only:
                     # mapping it to "model" gives Megatron-style sequence
                     # parallelism (remat saves shrink by the TP degree)
KV_SEQ = "kv_seq"
FED = "fed"  # federation replica dim (DFL mode)

# logical axis -> tuple of candidate mesh axes (first that exists+divides wins;
# multi-axis entries shard over several mesh axes at once).
DEFAULT_RULES: dict[str, tuple] = {
    L.VOCAB: (("model",),),
    L.HEADS: (("model",),),
    L.KV_HEADS: (("model",),),
    L.FFN: (("model",),),
    L.EXPERTS: (("model",),),
    L.EMBED: (),                   # replicated unless fsdp
    L.HEAD_DIM: (),
    L.RNN: (("model",),),
    L.STACK: (),
    L.CONV: (),
    BATCH: (("pod", "data"), ("data",)),
    SEQ: (),
    RES_SEQ: (),
    KV_SEQ: (),
    FED: (("fed",),),
}

FSDP_RULES = dict(DEFAULT_RULES)
FSDP_RULES[L.EMBED] = (("data",),)  # ZeRO-3: shard d_model over data

LONG_DECODE_RULES_EXTRA = {KV_SEQ: (("data",),)}  # sequence-parallel KV


def make_rules(*, fsdp: bool = False, shard_kv_seq: bool = False,
               extra: Optional[dict] = None) -> dict:
    rules = dict(FSDP_RULES if fsdp else DEFAULT_RULES)
    if shard_kv_seq:
        rules.update(LONG_DECODE_RULES_EXTRA)
    if extra:
        rules.update(extra)
    return rules


def logical_to_spec(axes: Sequence[Optional[str]], mesh: Mesh, rules: dict,
                    shape: Sequence[int]) -> P:
    """Resolve logical axis names to a PartitionSpec, checking divisibility."""
    used: set[str] = set()
    out = []
    for dim, name in zip(shape, axes, strict=False):
        assigned = None
        if name is not None:
            for cand in rules.get(name, ()):
                mesh_axes = tuple(a for a in cand if a in mesh.axis_names and a not in used)
                if not mesh_axes:
                    continue
                size = 1
                for a in mesh_axes:
                    size *= mesh.shape[a]
                if size and dim % size == 0 and dim >= size:
                    assigned = mesh_axes if len(mesh_axes) > 1 else mesh_axes[0]
                    used.update(mesh_axes)
                    break
        out.append(assigned)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_specs(axes_tree, mesh, rules, params_tree):
    """PartitionSpec pytree matching params, from the logical-axes pytree."""
    return jax.tree.map(
        lambda axes, p: logical_to_spec(axes, mesh, rules, p.shape),
        axes_tree, params_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x),
    )


def tree_shardings(axes_tree, mesh, rules, params_tree):
    specs = tree_specs(axes_tree, mesh, rules, params_tree)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


# ------------------------------------------------------- activation constraints
class _ShardCtx(threading.local):
    def __init__(self):
        self.mesh = None
        self.rules = None


_CTX = _ShardCtx()


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules: dict):
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


@contextlib.contextmanager
def no_activation_sharding():
    """Suppress activation constraints — required inside shard_map manual
    regions (e.g. the DFL gossip round), where with_sharding_constraint on
    vma-typed arrays rejects auto-axis NamedShardings."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = None, None
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def maybe_shard(x, axes: Sequence[Optional[str]]):
    """Apply a with_sharding_constraint if a mesh context is installed."""
    if _CTX.mesh is None:
        return x
    spec = logical_to_spec(axes, _CTX.mesh, _CTX.rules, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_CTX.mesh, spec))
