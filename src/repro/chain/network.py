"""Tick-based p2p network simulator (paper §VI-D: "we introduce the tick
time-keeping concept, a virtual time scale ... each node takes its actions in
a random number of ticks").

Simulates: topology (any adjacency; the paper uses fully-connected), per-edge
latency, ttl-bounded transaction forwarding, receipt backflow, block
generation with neighbor confirmations, malicious nodes, stragglers
(slow-train nodes), and node failure/join (elasticity tests). Messages ride a
heap-based event queue keyed by delivery tick.

Dynamic membership (``set_membership``): a ``repro.chain.attacks.
MembershipSchedule`` drives per-tick join/leave/rejoin events. Offline nodes
freeze their train countdowns, are skipped by recording, and never process a
transaction — but they still *relay*: routing is static, so a flood passes
through an offline node unchanged (ttl decremented via an unsigned relay
receipt, no evaluation, no buffering) exactly as the vectorized engines'
precomputed delivery schedules assume. A model in flight to an offline node
is lost for good (it is marked seen during the relay). Rejoining nodes resume
from their committed params; every peer's local reputation entry for the
rejoiner is decayed by ``rejoin_decay`` (clipped to [floor, initial]).
"""
from __future__ import annotations

import dataclasses
import heapq
import random
from typing import Callable, Dict, List, Optional, Sequence

from repro.chain.node import DFLNode
from repro.chain.types import Receipt


@dataclasses.dataclass
class SimConfig:
    ticks: int = 1000
    train_interval: tuple = (8, 16)     # uniform random ticks between trains
    latency: tuple = (1, 3)             # per-edge delivery delay (ticks)
    record_every: int = 10
    seed: int = 0


@dataclasses.dataclass(order=True)
class _Msg:
    tick: int
    seq: int
    kind: str = dataclasses.field(compare=False)    # "tx" | "receipt"
    dest: str = dataclasses.field(compare=False)
    src: str = dataclasses.field(compare=False)
    tx: object = dataclasses.field(compare=False)   # Transaction | Receipt
    params: object = dataclasses.field(compare=False)


class Simulator:
    """Drives DFLNodes over a virtual-time network."""

    def __init__(self, nodes: Sequence[DFLNode], topology: Dict[str, List[str]],
                 test_fn: Callable, cfg: SimConfig):
        self.nodes = {n.name: n for n in nodes}
        self.topology = topology
        self.test_fn = test_fn            # params -> accuracy on global test set
        self.cfg = cfg
        self.rand = random.Random(cfg.seed)
        self.queue: list[_Msg] = []
        self._seq = 0
        self.next_train = {
            n: self.rand.randint(*cfg.train_interval) for n in self.nodes}
        self.straggler_factor: Dict[str, int] = {}
        self.dead: set[str] = set()
        self.membership = None                  # MembershipSchedule | None
        self.offline: set[str] = set()          # churned-out (distinct from dead)
        self.stats = {"tx_sent": 0, "tx_delivered": 0, "tx_dropped_dup": 0,
                      "tx_dropped_expired": 0, "blocks": 0, "fedavg_rounds": 0}

    # --------------------------------------------------------------- plumbing
    def _push(self, tick: int, kind: str, dest: str, src: str, tx, params):
        self._seq += 1
        payload = tx.copy() if kind == "tx" else tx   # wire snapshot
        heapq.heappush(self.queue,
                       _Msg(tick, self._seq, kind, dest, src, payload, params))

    def _addr_to_name(self, address: str):
        for name, node in self.nodes.items():
            if node.info.address == address:
                return name
        return None

    def _latency(self) -> int:
        return self.rand.randint(*self.cfg.latency)

    def neighbors(self, name: str) -> List[str]:
        return [p for p in self.topology.get(name, []) if p not in self.dead]

    # ------------------------------------------------------------- lifecycle
    def kill_node(self, name: str):
        """Node failure: drops off the network; DFL needs no global action."""
        self.dead.add(name)

    def revive_node(self, name: str):
        self.dead.discard(name)

    def set_straggler(self, name: str, factor: int):
        self.straggler_factor[name] = factor

    def set_membership(self, schedule, *, names: Optional[Sequence[str]] = None):
        """Attach a ``MembershipSchedule``. ``names`` maps node index ->
        node name (defaults to insertion order, which matches the lax
        engines' index order when nodes were built in order)."""
        names = list(names) if names is not None else list(self.nodes)
        if len(names) != len(self.nodes):
            raise ValueError(
                f"names covers {len(names)} nodes, simulator has {len(self.nodes)}")
        dead_idx = [i for i, nm in enumerate(names) if nm in self.dead]
        schedule.validate(len(names), dead=dead_idx)
        self.membership = schedule
        self._member_names = names
        self._events_by_tick = {ev.tick: ev for ev in schedule.events}
        self._rejoin_decay = float(schedule.rejoin_decay)
        init_off = set(schedule.initial_offline)
        self.offline = {names[i] for i in init_off}
        # rejoin decay applies only to nodes that were online before — a
        # first join of an initially-offline node decays nothing
        self._ever_online = {nm for i, nm in enumerate(names) if i not in init_off}

    def _apply_membership_events(self, tick: int):
        ev = self._events_by_tick.get(tick)
        if ev is None:
            return
        for i in ev.leaves:
            self.offline.add(self._member_names[i])
        for i in ev.joins:
            nm = self._member_names[i]
            self.offline.discard(nm)
            if nm in self._ever_online:
                # rejoin: every peer decays its local view of the rejoiner
                addr = self.nodes[nm].info.address
                for nd in self.nodes.values():
                    impl = nd.rep_impl
                    cur = nd.reputation.get(addr, impl.initial)
                    nd.reputation[addr] = min(
                        impl.initial, max(impl.floor, self._rejoin_decay * cur))
            self._ever_online.add(nm)

    # ------------------------------------------------------------------ steps
    def _broadcast_tx(self, node: DFLNode, tick: int):
        params, _ = node.train_local(tick)
        tx = node.create_transaction(params, tick)
        node.stash_for_block(tx)
        self.stats["tx_sent"] += 1
        for peer in self.neighbors(node.name):
            self._push(tick + self._latency(), "tx", peer, node.name, tx, params)

    def _relay_tx(self, node: DFLNode, msg: _Msg, tick: int):
        """Offline pass-through: the node is churned out, so the model is
        lost to it (marked seen — a later rejoin never delivers it late) but
        the flood keeps moving. The ttl decrement rides an UNSIGNED relay
        receipt: Eq. (1) still counts the hop, and ``confirm_block`` only
        co-signs receipts it can ``verify()``, so the stub never becomes a
        confirmation."""
        if msg.tx.d in node.seen_tx:
            self.stats["tx_dropped_dup"] += 1
            return
        node.seen_tx.add(msg.tx.d)
        if not msg.tx.verify(now=tick):
            self.stats["tx_dropped_expired"] += 1
            return
        nxt = msg.tx.next_received_at_ttl()
        if nxt <= 0:
            return
        msg.tx.receipts.append(Receipt(
            creator=node.info, transaction_digest=msg.tx.d,
            received_at_ttl=nxt, accuracy=0.0, create_time=tick))
        for peer in self.neighbors(node.name):
            if peer != msg.src:
                self._push(tick + self._latency(), "tx", peer, node.name,
                           msg.tx, msg.params)

    def _deliver_tx(self, msg: _Msg, tick: int):
        node = self.nodes[msg.dest]
        if msg.dest in self.dead:
            return
        if msg.dest in self.offline:
            self._relay_tx(node, msg, tick)
            return
        receipt, forward = node.receive_transaction(msg.tx, msg.params, tick)
        if receipt is None:
            key = ("tx_dropped_expired" if not msg.tx.verify(now=tick)
                   else "tx_dropped_dup")
            self.stats[key] += 1
            return
        self.stats["tx_delivered"] += 1
        # receipt flows back to the generator (Fig 1) for block assembly
        gen_name = self._addr_to_name(msg.tx.generator.address)
        if gen_name and gen_name not in self.dead and gen_name not in self.offline:
            self._push(tick + self._latency(), "receipt", gen_name,
                       node.name, receipt, None)
        if node.maybe_update_model(tick):
            self.stats["fedavg_rounds"] += 1
        if forward:   # partial consensus: keep flooding while ttl remains
            for peer in self.neighbors(node.name):
                if peer != msg.src:
                    self._push(tick + self._latency(), "tx", peer, node.name,
                               msg.tx, msg.params)

    def _maybe_block(self, node: DFLNode, tick: int):
        if not node.ready_for_block():
            return
        draft = node.draft_block(tick)
        confirmations = []
        for peer in self.neighbors(node.name):
            if peer in self.offline:
                continue            # churned-out neighbors cannot witness
            confirmations.extend(self.nodes[peer].confirm_block(draft))
        if node.finalize_block(draft, confirmations):
            self.stats["blocks"] += 1

    # -------------------------------------------------------------------- run
    def run(self, progress: Optional[Callable] = None):
        for tick in range(self.cfg.ticks):
            if self.membership is not None:
                # top of tick, BEFORE delivery — same order as the lax
                # engines' membership step (leave/join gates this tick's
                # arrivals and this tick's countdown decrement)
                self._apply_membership_events(tick)
            while self.queue and self.queue[0].tick <= tick:
                msg = heapq.heappop(self.queue)
                if msg.kind == "tx":
                    self._deliver_tx(msg, tick)
                elif (msg.kind == "receipt" and msg.dest not in self.dead
                      and msg.dest not in self.offline):
                    self.nodes[msg.dest].attach_receipt(msg.tx)
            for name, node in self.nodes.items():
                if name in self.dead or name in self.offline:
                    continue
                self.next_train[name] -= 1
                if self.next_train[name] <= 0:
                    self._broadcast_tx(node, tick)
                    self._maybe_block(node, tick)
                    base = self.rand.randint(*self.cfg.train_interval)
                    self.next_train[name] = base * self.straggler_factor.get(name, 1)
            if tick % self.cfg.record_every == 0:
                for name, node in self.nodes.items():
                    if name not in self.dead and name not in self.offline:
                        node.record(tick, float(self.test_fn(node.params)))
                if progress:
                    progress(tick, self)
        return self


def fully_connected(names: Sequence[str]) -> Dict[str, List[str]]:
    return {a: [b for b in names if b != a] for a in names}


def ring(names: Sequence[str]) -> Dict[str, List[str]]:
    n = len(names)
    return {names[i]: [names[(i - 1) % n], names[(i + 1) % n]] for i in range(n)}


def mean_reputation(nodes: Sequence[DFLNode], target_address: str) -> float:
    """A node's reputation averaged over all other nodes' local views
    (paper Fig 15/17 metric)."""
    vals = [n.reputation.get(target_address) for n in nodes
            if n.reputation.get(target_address) is not None]
    return sum(vals) / len(vals) if vals else 1.0
