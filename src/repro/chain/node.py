"""A DFL node: the transaction/receipt/block/confirmation workflow of
Figs 1-4, plus the FedAvg buffer and local reputation table (§IV-D).

ML specifics are injected as callbacks so the same node drives LeNet (paper
reproduction) or any LM from the zoo:

    train_fn(params, rng)            -> (params, train_metrics)
    eval_fn(params)                  -> accuracy on THIS node's data (receipts)
    params are arbitrary pytrees; averaging uses repro.core.fedavg (Eq. 2/3,
    optionally the wfedavg Pallas kernel via use_kernel=True).

Adversaries are plug-ins (`repro.chain.attacks`): pass ``attack=`` (name or
instance) and the node broadcasts ``attack.apply(key, trained, committed,
tick)`` instead of its honest model — the SAME attack objects drive the
vectorized engine, so both simulators share one adversary definition. The
legacy ``malicious=True`` flag maps to the default ``gaussian`` attack (the
paper's §VI-E random-model poisoning).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.chain import attacks as attacks_lib
from repro.chain import crypto
from repro.chain.ledger import Ledger
from repro.chain.types import (Block, BlockConfirmation, NodeInformation,
                               Receipt, Transaction)
from repro.core import fedavg
from repro.core.reputation import ReputationImpl


@dataclasses.dataclass
class BufferedModel:
    sender: str
    params: object
    accuracy: float
    tx_digest: str


class DFLNode:
    def __init__(self, *, name: str, model_structure: str, params,
                 train_fn: Callable, eval_fn: Callable,
                 rep_impl: ReputationImpl, ttl: int = 2,
                 tx_per_block: int = 4, expire_after: float = 50.0,
                 malicious: bool = False, attack=None,
                 rng: Optional[jax.Array] = None,
                 attack_key_fn: Optional[Callable] = None,
                 use_kernel: bool = False,
                 compress: Optional[str] = None):
        self.name = name
        self.kp = crypto.generate_keypair()
        self.info = NodeInformation.from_keypair(self.kp)
        self.ledger = Ledger(model_structure, self.info, self.kp)
        self.params = params
        self.train_fn = train_fn
        self.eval_fn = eval_fn
        self.rep_impl = rep_impl
        self.ttl = ttl
        self.tx_per_block = tx_per_block
        self.expire_after = expire_after
        if isinstance(attack, str):
            attack = attacks_lib.get(attack)
        if malicious and attack is None:
            attack = attacks_lib.get("gaussian")   # legacy §VI-E poisoning
        self.attack = attack
        self.malicious = attack is not None
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        # tick -> attack key, the lax scan's fold_in(tick) stream
        # (attacks.attack_key_at via FederationSpec.attack_key_fns); None
        # falls back to the legacy per-node rng split
        self.attack_key_fn = attack_key_fn
        self.last_broadcast = None      # most recent train_local output
        self.use_kernel = use_kernel
        if compress not in (None, "int8"):
            raise ValueError(f"unknown compress mode {compress!r}")
        self.compress = compress
        # ^ "int8": broadcasts ship int8-quantized (repro.core.compression,
        #   the lax engine's exact calls — keeps heap<->lax event streams
        #   bitwise-comparable under compression). The round-trip happens
        #   ONCE here at the sender; the heap Simulator hands every
        #   receiver the same params object, so single-origin quantization
        #   holds structurally. Committed self.params stay full precision;
        #   attacks apply BEFORE quantization.

        self.reputation: Dict[str, float] = {}   # address -> [0,1], local only
        self.buffer: List[BufferedModel] = []
        self.pending_tx: List[Transaction] = []  # receipts gathered, await block
        self.seen_tx: set[str] = set()
        # histories for the paper's figures
        self.accuracy_history: List[tuple] = []
        self.reputation_history: List[tuple] = []

    # ------------------------------------------------------------ local train
    def _to_wire(self, params):
        """Apply the configured wire compression to an outgoing broadcast
        (post-attack, pre-send — the quantized payload is what every
        receiver evaluates and buffers)."""
        if self.compress == "int8":
            from repro.core import compression
            return compression.roundtrip_tree(params)
        return params

    def train_local(self, now: float):
        self.rng, sub = jax.random.split(self.rng)
        if self.attack is not None:
            # model poisoning: corrupt the honestly trained candidate at
            # broadcast time WITHOUT committing it (mirrors the vectorized
            # engine: attackers' persistent params never advance)
            if self.attack_key_fn is not None:
                # the lax scan's stream — bitwise-identical poison draws
                k_train, k_attack = sub, self.attack_key_fn(now)
            else:
                k_train, k_attack = jax.random.split(sub)
            trained, _ = self.train_fn(self.params, k_train)
            out = self._to_wire(
                self.attack.apply(k_attack, trained, self.params, now))
            self.last_broadcast = out
            return out, {}
        self.params, metrics = self.train_fn(self.params, sub)
        self.last_broadcast = self._to_wire(self.params)
        return self.last_broadcast, metrics

    # ---------------------------------------------------- transactions (Fig 1)
    def create_transaction(self, model_params, now: float) -> Transaction:
        tx = Transaction(
            generator=self.info,
            create_time=now,
            expire_time=now + self.expire_after,
            ml_model=crypto.fingerprint_tree(model_params),
            ttl=self.ttl,
        ).seal(self.kp)
        self.seen_tx.add(tx.d)
        return tx

    def receive_transaction(self, tx: Transaction, model_params, now: float):
        """Verify, measure accuracy (the receipt), buffer the model, decide
        forwarding. Returns (receipt | None, forward: bool)."""
        if tx.d in self.seen_tx:
            return None, False              # duplicate (§IV-A2)
        self.seen_tx.add(tx.d)
        if not tx.verify(now=now):
            return None, False              # invalid/expired
        acc = float(self.eval_fn(model_params))
        receipt = Receipt(
            creator=self.info,
            transaction_digest=tx.d,
            received_at_ttl=tx.next_received_at_ttl(),  # Eq. (1)
            accuracy=acc,
            create_time=now,
        ).seal(self.kp)
        tx.receipts.append(receipt)
        sender = tx.generator.address
        self.reputation.setdefault(sender, self.rep_impl.initial)
        self.buffer.append(BufferedModel(sender, model_params, acc, tx.d))
        forward = receipt.received_at_ttl > 0
        return receipt, forward

    # -------------------------------------------------- weighted FedAvg (Eq 3)
    def maybe_update_model(self, now: float) -> bool:
        if len(self.buffer) < self.rep_impl.buffer_size:
            return False
        buf = self.buffer[: self.rep_impl.buffer_size]
        self.buffer = self.buffer[self.rep_impl.buffer_size:]
        reps = jnp.asarray([self.reputation.get(b.sender, self.rep_impl.initial)
                            for b in buf], jnp.float32)
        accs = jnp.asarray([b.accuracy for b in buf], jnp.float32)
        weights = fedavg.model_weights(reps, accs)          # Eq. 2
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *[b.params for b in buf])
        if self.use_kernel:
            from repro.kernels.wfedavg import ops as wf_ops
            self.params = wf_ops.weighted_fedavg_tree(stacked, weights, self.params)
        else:
            self.params = fedavg.weighted_fedavg(stacked, weights, self.params)  # Eq. 3

        # reputation: punish the lowest-accuracy sender(s) (§IV-D1)
        worst = float(jnp.min(accs))
        for b in buf:
            if b.accuracy <= worst + 1e-9:
                cur = self.reputation.get(b.sender, self.rep_impl.initial)
                self.reputation[b.sender] = max(
                    self.rep_impl.floor, cur - self.rep_impl.penalty)
        return True

    def attach_receipt(self, receipt: Receipt) -> bool:
        """Generator side of Fig 1: collect receipts flowing back for my own
        pending transactions (used later for block confirmations)."""
        if not receipt.verify():
            return False
        for tx in self.pending_tx:
            if tx.d == receipt.transaction_digest:
                if all(r.d != receipt.d for r in tx.receipts):
                    tx.receipts.append(receipt)
                return True
        return False

    # ---------------------------------------------------------- blocks (Fig 2)
    def stash_for_block(self, tx: Transaction):
        self.pending_tx.append(tx)

    def ready_for_block(self) -> bool:
        # the paper: gather transactions AND their receipts before drafting —
        # receiptless transactions cannot be witnessed (confirmed) yet
        return sum(1 for t in self.pending_tx if t.receipts) >= self.tx_per_block

    def draft_block(self, now: float) -> Block:
        with_receipts = [t for t in self.pending_tx if t.receipts]
        txs = with_receipts[: self.tx_per_block]
        chosen = {t.d for t in txs}
        self.pending_tx = [t for t in self.pending_tx if t.d not in chosen]
        return self.ledger.new_draft([t.copy() for t in txs], now)

    def confirm_block(self, draft: Block) -> List[BlockConfirmation]:
        """Neighbor side of Fig 2: confirm every receipt I created."""
        out = []
        for t in draft.transactions:
            for r in t.receipts:
                if r.creator.address == self.info.address and r.verify():
                    out.append(BlockConfirmation(
                        creator=self.info,
                        transaction_digest=t.d,
                        receipt_digest=r.d,
                        block_digest=draft.d,
                    ).seal(self.kp))
        return out

    def finalize_block(self, draft: Block,
                       confirmations: List[BlockConfirmation],
                       min_confirmations_per_tx: int = 1) -> bool:
        draft.confirmations = confirmations
        draft.finalize()
        return self.ledger.append(draft, min_confirmations_per_tx)

    # ---------------------------------------------------------------- metrics
    def record(self, now: float, test_accuracy: float):
        self.accuracy_history.append((now, test_accuracy))
        if self.reputation:
            self.reputation_history.append((now, dict(self.reputation)))
