"""DFL blockchain data formats — the UML graphs of Figs 5-9 (paper §IV-B).

Signature-protected fields follow Table II exactly: a transaction's digest
covers (generator, create_time, expire_time, ml_model, ttl) — NOT receipts,
so appending receipts never changes the transaction digest (§IV-B3). A
receipt's received_at_ttl implements Eq. (1).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional

from repro.chain import crypto


@dataclass(frozen=True)
class NodeInformation:
    """Fig 5. address = hash(public_key)."""
    address: str
    public_key: str

    @classmethod
    def from_keypair(cls, kp: crypto.KeyPair) -> "NodeInformation":
        return cls(address=kp.address, public_key=kp.public_key)


@dataclass
class Receipt:
    """Fig 7. Created by each receiver of a transaction: accuracy measured on
    the receiver's OWN dataset; received_at_ttl per Eq. (1)."""
    creator: NodeInformation
    transaction_digest: str
    received_at_ttl: int
    accuracy: float
    create_time: float
    d: str = ""
    sig: str = ""

    def compute_digest(self) -> str:
        return crypto.hash_fields(
            self.creator.address, self.transaction_digest,
            self.received_at_ttl, f"{self.accuracy:.6f}", self.create_time)

    def seal(self, kp: crypto.KeyPair) -> "Receipt":
        self.d = self.compute_digest()
        self.sig = crypto.sign(kp, self.d)
        return self

    def verify(self) -> bool:
        return (self.d == self.compute_digest()
                and crypto.verify(self.creator.public_key, self.d, self.sig))


@dataclass
class Transaction:
    """Fig 6. ml_model is the signed model fingerprint (+ out-of-band payload
    reference); ttl bounds the partial-consensus broadcast range."""
    generator: NodeInformation
    create_time: float
    expire_time: float
    ml_model: str
    ttl: int
    d: str = ""
    sig: str = ""
    receipts: List[Receipt] = field(default_factory=list)

    def compute_digest(self) -> str:
        return crypto.hash_fields(
            self.generator.address, self.create_time, self.expire_time,
            self.ml_model, self.ttl)

    def seal(self, kp: crypto.KeyPair) -> "Transaction":
        self.d = self.compute_digest()
        self.sig = crypto.sign(kp, self.d)
        return self

    def verify(self, now: Optional[float] = None) -> bool:
        if self.d != self.compute_digest():
            return False
        if not crypto.verify(self.generator.public_key, self.d, self.sig):
            return False
        if now is not None and now > self.expire_time:
            return False  # late transaction: outdated model (§IV-B2)
        return True

    def next_received_at_ttl(self) -> int:
        """Eq. (1): min(trans.ttl, min receipts.received_at_ttl) - 1."""
        vals = [r.received_at_ttl for r in self.receipts]
        return min([self.ttl] + vals) - 1

    def copy(self) -> "Transaction":
        """Wire copy: a forwarded transaction is a serialized snapshot —
        receivers must never mutate the sender's receipt list."""
        return dataclasses.replace(self, receipts=list(self.receipts))


@dataclass
class BlockConfirmation:
    """Fig 9. A neighbor co-signs (transaction, receipt, block) it authored
    a receipt for — after this the generator cannot alter history."""
    creator: NodeInformation
    transaction_digest: str
    receipt_digest: str
    block_digest: str
    d: str = ""
    sig: str = ""

    def compute_digest(self) -> str:
        return crypto.hash_fields(
            self.creator.address, self.transaction_digest,
            self.receipt_digest, self.block_digest)

    def seal(self, kp: crypto.KeyPair) -> "BlockConfirmation":
        self.d = self.compute_digest()
        self.sig = crypto.sign(kp, self.d)
        return self

    def verify(self) -> bool:
        return (self.d == self.compute_digest()
                and crypto.verify(self.creator.public_key, self.d, self.sig))


@dataclass
class Block:
    """Fig 8. Two-phase: draft digest d covers content; final_digest also
    covers the gathered confirmations and chains into the next block."""
    generator: NodeInformation
    create_time: float
    previous_final_digest: str
    genesis_digest: str
    height: int
    transactions: List[Transaction] = field(default_factory=list)
    d: str = ""
    sig: str = ""
    confirmations: List[BlockConfirmation] = field(default_factory=list)
    final_digest: str = ""

    def compute_digest(self) -> str:
        return crypto.hash_fields(
            self.generator.address, self.create_time,
            self.previous_final_digest, self.genesis_digest, self.height,
            [t.d for t in self.transactions],
            [[r.d for r in t.receipts] for t in self.transactions])

    def seal_draft(self, kp: crypto.KeyPair) -> "Block":
        self.d = self.compute_digest()
        self.sig = crypto.sign(kp, self.d)
        return self

    def finalize(self) -> "Block":
        self.final_digest = crypto.hash_fields(
            self.d, [c.d for c in self.confirmations])
        return self

    def verify(self, min_confirmations_per_tx: int = 1) -> bool:
        if self.d != self.compute_digest():
            return False
        if not crypto.verify(self.generator.public_key, self.d, self.sig):
            return False
        if self.final_digest != crypto.hash_fields(
                self.d, [c.d for c in self.confirmations]):
            return False
        for t in self.transactions:
            if t.d != t.compute_digest():
                return False
            if not crypto.verify(t.generator.public_key, t.d, t.sig):
                return False
            for r in t.receipts:
                if not r.verify() or r.transaction_digest != t.d:
                    return False
        receipt_digests = {r.d for t in self.transactions for r in t.receipts}
        conf_by_tx: dict[str, int] = {}
        for c in self.confirmations:
            if not c.verify() or c.block_digest != self.d:
                return False
            if c.receipt_digest not in receipt_digests:
                return False
            conf_by_tx[c.transaction_digest] = conf_by_tx.get(c.transaction_digest, 0) + 1
        for t in self.transactions:
            if t.receipts and conf_by_tx.get(t.d, 0) < min_confirmations_per_tx:
                return False
        return True


def make_genesis(model_structure: str, creator: NodeInformation,
                 kp: crypto.KeyPair) -> Block:
    """The genesis block records the ML network structure so every node
    trains the same model (§IV-B4)."""
    g = Block(generator=creator, create_time=0.0, previous_final_digest="0" * 64,
              genesis_digest="", height=0)
    g.genesis_digest = crypto.hash_fields("genesis", model_structure)
    g.seal_draft(kp)
    return g.finalize()
