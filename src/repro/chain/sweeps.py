"""Batched federation sweeps: whole what-if grids per dispatch.

The paper's evaluation (§VI) is a grid — attack type × topology × network
size × seed — but a single ``LaxSimulator.run()`` answers ONE federation.
This module turns a grid into the fewest possible batched runs:

1. ``expand_grid`` enumerates the attack × topology-seed × size × rng-seed
   product into ``SweepCell``s (one cell = one federation).
2. ``plan_batches`` groups cells into *shape-compatible* batches: members
   of a batch must share everything vmap needs to be static — node count,
   topology (kind + generator seed) and scenario — while attacker sheets,
   dead sets and rng seeds are free to differ per member
   (``repro.chain.attacks.BatchedFederationSpec``).
3. ``run_sweep`` builds one ``BatchedFederationSpec`` per batch, runs it
   through the vectorized engine (budgets take the max over the batch —
   `repro.core.topology.batch_budgets`), round-robins batches across the
   available jax devices, and reduces each member's ``SimLaxResult`` to
   the frontier metrics: time-to-accuracy (first recorded tick where the
   honest-node mean clears a target) and accuracy/reputation under attack.
4. ``frontier_tables`` pivots the outcomes into the two JSON-ready tables
   benchmarks/bench_sweep.py persists and docs/SWEEPS.md explains.

Everything here is host-side orchestration; the per-batch heavy lifting is
one vmapped ``lax.scan`` dispatch (docs/SWEEPS.md has the shape rules and
the measured batched-vs-loop throughput).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.chain import scenarios as scenarios_lib
from repro.chain import simlax
from repro.chain.attacks import BatchedFederationSpec, FederationSpec
from repro.core import topology as topology_lib
from repro.core.reputation import get as get_rep


@dataclasses.dataclass(frozen=True)
class SweepCell:
    """One grid point = one federation: who attacks, on which sampled
    topology, at what size, under which engine seed."""

    size: int
    attack: Optional[str]        # attack registry name; None = honest run
    malicious_frac: float        # fraction of nodes assigned the attack
    topology_seed: int           # generator seed (erdos/smallworld sampling)
    seed: int                    # engine PRNG seed (SimLaxConfig.seed role)

    def num_malicious(self) -> int:
        if self.attack is None:
            return 0
        return max(1, int(self.malicious_frac * self.size))

    def spec(self) -> FederationSpec:
        """The cell's role sheet: the first ``num_malicious`` node ids run
        ``attack`` (the harness convention — deterministic and
        size-comparable across the grid)."""
        mal = tuple(range(self.num_malicious()))
        return FederationSpec.build(self.size, malicious=mal,
                                    attack=self.attack or None)

    def batch_key(self) -> tuple:
        """Cells sharing this key can ride in ONE batched run: vmap needs
        the node count and topology static; roles/seeds may differ."""
        return (self.size, self.topology_seed)


def expand_grid(*, sizes: Sequence[int],
                attacks: Sequence[Optional[str]] = (None,),
                topology_seeds: Sequence[int] = (0,),
                seeds: Sequence[int] = (0,),
                malicious_frac: float = 0.125) -> List[SweepCell]:
    """The full attack × topology-seed × size × rng-seed product, ordered
    so ``plan_batches`` finds maximal shape-compatible runs contiguously."""
    return [SweepCell(size=int(n), attack=a,
                      malicious_frac=float(malicious_frac),
                      topology_seed=int(ts), seed=int(s))
            for n in sizes for ts in topology_seeds
            for a in attacks for s in seeds]


def plan_batches(cells: Sequence[SweepCell], *,
                 max_batch: int = 0) -> List[List[SweepCell]]:
    """Group cells into shape-compatible batches (same ``batch_key``),
    preserving grid order; ``max_batch > 0`` additionally splits batches
    so no single dispatch exceeds that many federations (memory control:
    per-batch state is B× one federation's)."""
    by_key: Dict[tuple, List[SweepCell]] = {}
    order: List[tuple] = []
    for c in cells:
        k = c.batch_key()
        if k not in by_key:
            by_key[k] = []
            order.append(k)
        by_key[k].append(c)
    batches: List[List[SweepCell]] = []
    for k in order:
        group = by_key[k]
        step = max_batch if max_batch > 0 else len(group)
        for i in range(0, len(group), step):
            batches.append(group[i:i + step])
    return batches


@dataclasses.dataclass
class SweepOutcome:
    """One federation's reduced frontier metrics."""

    cell: SweepCell
    final_honest_acc: float      # honest-node mean test acc, last record
    time_to_acc: Optional[int]   # first recorded tick clearing target_acc
    attacker_reputation: float   # mean over attackers of mean_reputation
    honest_reputation: float
    stats: dict

    def row(self) -> dict:
        return {
            "size": self.cell.size, "attack": self.cell.attack or "none",
            "malicious_frac": (self.cell.malicious_frac
                               if self.cell.attack else 0.0),
            "topology_seed": self.cell.topology_seed, "seed": self.cell.seed,
            "final_honest_acc": round(self.final_honest_acc, 6),
            "time_to_acc": self.time_to_acc,
            "attacker_reputation": round(self.attacker_reputation, 6),
            "honest_reputation": round(self.honest_reputation, 6),
        }


def _reduce(cell: SweepCell, res: simlax.SimLaxResult,
            target_acc: float) -> SweepOutcome:
    mal = set(range(cell.num_malicious()))
    honest = [i for i in range(cell.size) if i not in mal]
    honest_curve = res.acc_history[:, honest].mean(axis=1)   # (records,)
    reached = np.flatnonzero(honest_curve >= target_acc)
    return SweepOutcome(
        cell=cell,
        final_honest_acc=float(honest_curve[-1]),
        time_to_acc=(int(res.record_ticks[reached[0]]) if len(reached)
                     else None),
        attacker_reputation=(float(np.mean(
            [res.mean_reputation(i) for i in sorted(mal)])) if mal
            else float("nan")),
        honest_reputation=float(np.mean(
            [res.mean_reputation(i) for i in honest])),
        stats=res.stats)


def run_sweep(cells: Sequence[SweepCell], *,
              cfg: simlax.SimLaxConfig,
              scenario: str = "toy",
              scenario_kw: Optional[dict] = None,
              topology_kind: str = "kregular",
              degree: int = 2, p: float = 0.3,
              rep_impl: str = "impl2",
              target_acc: float = 0.5,
              max_batch: int = 0,
              devices: Optional[Sequence] = None) -> List[SweepOutcome]:
    """Run a planned grid: one vectorized batched dispatch per
    shape-compatible batch, round-robined over ``devices`` (default: all
    jax devices — under ``launch.dryrun``'s forced host-device count a CPU
    machine exposes many). Scenario data is built once per size and shared
    by every batch member (vmap closes over it unbatched); each member
    runs at its OWN cell seed, so outcomes are bitwise reproducible as
    single runs of the same cells."""
    devices = list(devices if devices is not None else jax.devices())
    rep = get_rep(rep_impl)
    builder = scenarios_lib.get(scenario)
    sc_cache: Dict[int, object] = {}
    topo_cache: Dict[tuple, topology_lib.Topology] = {}
    outcomes: List[SweepOutcome] = []
    for i, batch in enumerate(plan_batches(cells, max_batch=max_batch)):
        n, topo_seed = batch[0].batch_key()
        if n not in sc_cache:
            sc_cache[n] = builder(n, **(scenario_kw or {}))
        if (n, topo_seed) not in topo_cache:
            topo_cache[(n, topo_seed)] = topology_lib.make(
                topology_kind, n, degree=degree, p=p, seed=topo_seed)
        bspec = BatchedFederationSpec.build(
            [c.spec() for c in batch], [c.seed for c in batch])
        with jax.default_device(devices[i % len(devices)]):
            sim = simlax.LaxSimulator(sc_cache[n], topo_cache[(n, topo_seed)],
                                      bspec, rep, cfg)
            results = sim.run()
        outcomes.extend(_reduce(c, r, target_acc)
                        for c, r in zip(batch, results, strict=True))
    return outcomes


def frontier_tables(outcomes: Sequence[SweepOutcome], *,
                    target_acc: float) -> dict:
    """Pivot outcomes into the two frontier tables (JSON-ready rows):

    ``time_to_accuracy`` — per (attack, size): how fast the honest mean
    clears ``target_acc`` across topology-seed × seed replicates (median
    over the replicates that reached it + the reached fraction); the
    speed-vs-robustness frontier axis.
    ``accuracy_under_attack`` — per (attack, size): final honest accuracy
    and the attacker/honest reputation split the defense achieved.
    """
    groups: Dict[Tuple[str, int], List[SweepOutcome]] = {}
    for o in outcomes:
        groups.setdefault((o.cell.attack or "none", o.cell.size),
                          []).append(o)
    tta, aua = [], []
    for (attack, size), grp in sorted(groups.items()):
        times = [o.time_to_acc for o in grp if o.time_to_acc is not None]
        tta.append({
            "attack": attack, "size": size, "replicates": len(grp),
            "target_acc": target_acc,
            "reached_frac": round(len(times) / len(grp), 4),
            "median_ticks_to_acc": (float(np.median(times)) if times
                                    else None),
        })
        att_reps = [o.attacker_reputation for o in grp
                    if not np.isnan(o.attacker_reputation)]
        aua.append({
            "attack": attack, "size": size, "replicates": len(grp),
            "mean_final_honest_acc": round(
                float(np.mean([o.final_honest_acc for o in grp])), 6),
            "mean_attacker_reputation": (round(float(np.mean(att_reps)), 6)
                                         if att_reps else None),
            "mean_honest_reputation": round(
                float(np.mean([o.honest_reputation for o in grp])), 6),
        })
    return {"time_to_accuracy": tta, "accuracy_under_attack": aua}
