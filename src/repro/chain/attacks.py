"""Pluggable model-poisoning attacks + the FederationSpec role sheet.

The paper evaluates its reputation defense against exactly one adversary —
a node that broadcasts an arbitrary random model (§VI-E) — and that attack
used to be a hard-coded ``malicious`` boolean inside both simulator engines.
Related work (Dong et al. 2023, Hallaji et al. 2024) shows reputation
schemes behave very differently under richer adversaries, so attacks are now
plug-ins, following the registry pattern of ``repro.core.reputation``:

    attacks.get("signflip")                  # default-parameterized instance
    attacks.make("gaussian", sigma=3.0)      # parameterized variant
    attacks.register(MyAttack())             # custom adversaries

An attack is a frozen dataclass with one jit-traceable method::

    apply(key, params, committed, tick) -> outgoing params (same pytree)

* ``params``    — the model the node WOULD honestly broadcast this action
                  (its honestly-trained candidate; attackers never commit it)
* ``committed`` — the node's persistent (pre-train) state; doubles as the
                  shape/dtype template for replacement attacks
* ``tick``      — the current simulator tick (traced int32 in the lax
                  engine, a plain int heap-side) for schedule-driven attacks

The same ``apply`` runs vmapped over the federation inside the
``LaxSimulator`` ``lax.scan`` and one-node-at-a-time inside the heap
``DFLNode``, so both engines share one adversary definition.

Shipped attacks (all §VI-E-style model poisoning at broadcast time):

``signflip``      broadcast the sign-flipped (optionally scaled) model
``gaussian``      replace the model with ``sigma * N(0, 1)`` noise — exactly
                  the paper's "arbitrary random model" attack at sigma=1
                  (the legacy ``malicious=`` flag maps here, bit-for-bit)
``scaled``        boosting: exaggerate the local update,
                  ``committed + factor * (trained - committed)``
``freerider``     stale-replay: re-broadcast the committed (never-trained)
                  model unchanged — contributes nothing, looks plausible
``intermittent``  tick-scheduled on/off wrapper: run ``inner`` during the
                  first ``duty`` ticks of every ``period``, act honest
                  otherwise (evades windowed detectors)

``FederationSpec`` is the single role sheet both simulator engines are
constructed from: per-node attacker assignment (name or instance), dead
nodes, straggler factors, and the initial train countdown. Building the heap
and lax simulators from ONE spec is what makes their parity tests a
single-source-of-truth comparison (tests/test_simlax.py).

``BatchedFederationSpec`` stacks several same-N role sheets (plus one PRNG
seed each) into a single batch the vectorized engine vmaps end-to-end: per-
spec role arrays gain a leading batch axis, and the distinct attack
instances across the whole batch form a union (``attack_union``) of
``(attack, (B, N) mask, (B,) fold)`` triples — each batch member keeps its
OWN per-spec fold constants (``attack_fold`` over its own group order), so a
batched run replays every member's single-run key stream bit-for-bit. See
docs/SWEEPS.md.

PRNG key-stream contract (shared by both engines; fold constants must stay
disjoint): with ``key_t = fold_in(PRNGKey(seed), tick)``, fold 0 keys the
tick's train steps, ``attack_fold(gi)`` keys attack group ``gi`` (1 for
group 0 — pinned to the legacy hard-coded poison stream — then 3, 4, ...),
fold 2 keys the train-interval redraw, and fold 12345 of the BASE key (not
``key_t``) draws the initial countdowns.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _map_floats(fn, tree):
    """Apply fn to floating leaves only (step counters etc. pass through)."""
    return jax.tree.map(
        lambda x: fn(x) if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)
        else x, tree)


@dataclasses.dataclass(frozen=True)
class SignFlip:
    """Constant sign-flip poisoning: broadcast ``-scale *`` the honestly
    trained model. scale>1 additionally boosts the magnitude."""
    scale: float = 1.0
    name: str = "signflip"

    def apply(self, key, params, committed, tick):
        del key, committed, tick
        return _map_floats(lambda x: (-self.scale) * x, params)


@dataclasses.dataclass(frozen=True)
class GaussianNoise:
    """Replace the model with ``sigma * N(0, 1)`` noise — the paper's §VI-E
    "broadcast an arbitrary random model" attack at sigma=1 (the legacy
    hard-coded behavior; non-float leaves pass through untouched)."""
    sigma: float = 1.0
    name: str = "gaussian"

    def apply(self, key, params, committed, tick):
        del params, tick
        leaves, treedef = jax.tree.flatten(committed)
        keys = jax.random.split(key, len(leaves))
        bad = [self.sigma * jax.random.normal(k, l.shape, l.dtype)
               if jnp.issubdtype(jnp.asarray(l).dtype, jnp.floating) else l
               for k, l in zip(keys, leaves, strict=True)]
        return jax.tree.unflatten(treedef, bad)


@dataclasses.dataclass(frozen=True)
class ScaledPoison:
    """Boosting / scaled poisoning: exaggerate the local update by
    ``factor`` — ``committed + factor * (trained - committed)`` — the
    classic attack against plain averaging (a boosted update dominates the
    buffer mean)."""
    factor: float = 10.0
    name: str = "scaled"

    def apply(self, key, params, committed, tick):
        del key, tick
        return jax.tree.map(
            lambda tr, cm: (cm + self.factor * (tr - cm)).astype(tr.dtype)
            if jnp.issubdtype(jnp.asarray(tr).dtype, jnp.floating) else tr,
            params, committed)


@dataclasses.dataclass(frozen=True)
class FreeRider:
    """Stale-replay free-riding: broadcast the committed model unchanged.
    Attackers never commit local training in either engine, so this
    re-broadcasts the initial (stale) model forever — plausible-looking
    receipts early, a drag on the federation later."""
    name: str = "freerider"

    def apply(self, key, params, committed, tick):
        del key, params, tick
        return committed


@dataclasses.dataclass(frozen=True)
class Intermittent:
    """Tick-scheduled on/off attacker: run the ``inner`` attack during the
    first ``duty`` ticks of every ``period``-tick window, broadcast the
    honest candidate otherwise. Evades detectors that only watch recent
    windows; ``tick`` is traced, so the schedule stays inside the scan."""
    period: int = 8
    duty: int = 4
    inner: str = "gaussian"
    name: str = "intermittent"

    def apply(self, key, params, committed, tick):
        bad = get(self.inner).apply(key, params, committed, tick)
        active = (tick % self.period) < self.duty
        return jax.tree.map(lambda b, p: jnp.where(active, b, p), bad, params)


_REGISTRY: Dict[str, object] = {}


def register(attack) -> object:
    """Register a default-parameterized attack instance under its name."""
    _REGISTRY[attack.name] = attack
    return attack


def get(name: str):
    if name not in _REGISTRY:
        raise KeyError(f"unknown attack {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def make(name: str, **params):
    """A parameterized variant of a registered attack:
    ``make("gaussian", sigma=3.0)``."""
    return dataclasses.replace(get(name), **params) if params else get(name)


def names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


SIGNFLIP = register(SignFlip())
GAUSSIAN = register(GaussianNoise())
SCALED = register(ScaledPoison())
FREERIDER = register(FreeRider())
INTERMITTENT = register(Intermittent())


# ======================================================== shared PRNG streams
def attack_fold(group_index: int) -> int:
    """The fold constant keying attack group ``group_index``'s PRNG stream.

    Single source for BOTH engines: the lax scan folds 0 for train keys,
    1 for attack group 0 (pinned so a single-gaussian spec replays the
    legacy hard-coded poison stream bit-for-bit) and 2 for the interval
    draw, so later groups start at 3 to keep every stream disjoint.
    """
    return 1 if group_index == 0 else group_index + 2


def attack_key_at(base_key, tick, fold: int, num_nodes: int, node: int):
    """Node ``node``'s attack key at ``tick`` — EXACTLY the key the lax
    scan hands that node's attack vmap (``split(fold_in(fold_in(key0, t),
    fold), n)[node]``). The heap ``DFLNode`` draws from this same stream
    (via ``FederationSpec.attack_key_fns``), which is what upgrades
    randomized-attack parity between the engines from event-stream to
    bitwise."""
    key_t = jax.random.fold_in(base_key, tick)
    return jax.random.split(jax.random.fold_in(key_t, fold), num_nodes)[node]


# ================================================================= role sheet
def _resolve(attack) -> object:
    return get(attack) if isinstance(attack, str) else attack


# ============================================================ churn schedule
@dataclasses.dataclass(frozen=True)
class MembershipEvent:
    """One tick's worth of churn: ``joins`` come online and ``leaves`` go
    offline at the TOP of ``tick``, before any queue drain or training —
    a node leaving at tick t neither receives nor trains on tick t, and a
    node joining at tick t participates from tick t onward."""
    tick: int
    joins: Tuple[int, ...] = ()
    leaves: Tuple[int, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "tick", int(self.tick))
        object.__setattr__(self, "joins",
                           tuple(int(i) for i in self.joins))
        object.__setattr__(self, "leaves",
                           tuple(int(i) for i in self.leaves))
        if self.tick < 0:
            raise ValueError(f"event tick must be >= 0, got {self.tick}")
        overlap = set(self.joins) & set(self.leaves)
        if overlap:
            raise ValueError(
                f"nodes {sorted(overlap)} both join and leave at tick "
                f"{self.tick}")


@dataclasses.dataclass(frozen=True)
class MembershipSchedule:
    """Dynamic membership for one federation run: which nodes are offline
    from tick 0 (``initial_offline``) and the per-tick join/leave/rejoin
    event stream. Both simulator engines consume the SAME schedule, so churn
    scenarios stay single-source like every other role in the spec.

    Semantics (the contract docs/SCALING.md pins):

    * Offline nodes keep their committed params and receive nothing; models
      in flight toward them when they drop are lost (both engines).
    * A REJOIN (a node that was online earlier — or started online — coming
      back) resumes from its committed params with every peer's reputation
      of it decayed: ``rep <- clip(rejoin_decay * rep, floor, initial)``.
      First-time joins of ``initial_offline`` nodes get no decay.
    * Routing/budgets stay the static all-alive worst case: an offline node
      can only SHRINK the set of deliveries due on a tick, never grow it.

    ``dead`` nodes (the spec's permanent failures) may not appear in any
    event or in ``initial_offline`` — they never participate.
    """
    events: Tuple[MembershipEvent, ...] = ()
    rejoin_decay: float = 0.5
    initial_offline: Tuple[int, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "initial_offline",
                           tuple(sorted(set(int(i)
                                            for i in self.initial_offline))))
        object.__setattr__(self, "rejoin_decay", float(self.rejoin_decay))
        if not 0.0 <= self.rejoin_decay <= 1.0:
            raise ValueError(
                f"rejoin_decay must be in [0, 1], got {self.rejoin_decay}")
        ticks = [e.tick for e in self.events]
        if ticks != sorted(ticks):
            raise ValueError("events must be sorted by tick")
        if len(set(ticks)) != len(ticks):
            raise ValueError("at most one MembershipEvent per tick "
                             "(merge joins/leaves into one event)")

    @classmethod
    def build(cls, events=(), *, rejoin_decay: float = 0.5,
              initial_offline: Sequence[int] = ()) -> "MembershipSchedule":
        """``events`` entries are ``MembershipEvent``s or
        ``(tick, joins, leaves)`` tuples; they are sorted by tick here."""
        evs = []
        for e in events:
            if not isinstance(e, MembershipEvent):
                t, joins, leaves = e
                e = MembershipEvent(tick=t, joins=tuple(joins),
                                    leaves=tuple(leaves))
            evs.append(e)
        evs.sort(key=lambda e: e.tick)
        return cls(events=tuple(evs), rejoin_decay=rejoin_decay,
                   initial_offline=tuple(initial_offline))

    def validate(self, num_nodes: int, dead: Sequence[int] = ()) -> None:
        """Replay the schedule against ``num_nodes``/``dead`` and reject
        impossible streams: out-of-range ids, events touching dead nodes,
        joining while online, leaving while offline."""
        horizon = (max(e.tick for e in self.events) + 1) if self.events \
            else 1
        self.timeline(num_nodes, horizon, dead=dead)

    def timeline(self, num_nodes: int, ticks: int,
                 dead: Sequence[int] = ()) -> Tuple[np.ndarray, np.ndarray]:
        """Expand to dense per-tick masks: ``(alive_t, rejoin_t)`` both
        ``(ticks, num_nodes)`` bool. ``alive_t[t, i]`` — node i participates
        on tick t (events applied at the top of their tick, dead nodes
        always False); ``rejoin_t[t, i]`` — node i REJOINS at the top of
        tick t (triggers the reputation decay; first-time joins of
        ``initial_offline`` nodes don't)."""
        dead_set = set(int(i) for i in dead)
        for i in self.initial_offline:
            if not 0 <= i < num_nodes:
                raise ValueError(
                    f"initial_offline id {i} outside [0, {num_nodes})")
            if i in dead_set:
                raise ValueError(f"node {i} is dead; it cannot churn")
        participating = np.ones((num_nodes,), np.bool_)
        participating[list(dead_set)] = False
        participating[list(self.initial_offline)] = False
        ever_online = participating.copy()
        alive_t = np.zeros((ticks, num_nodes), np.bool_)
        rejoin_t = np.zeros((ticks, num_nodes), np.bool_)
        by_tick = {e.tick: e for e in self.events}
        for t in range(ticks):
            ev = by_tick.get(t)
            if ev is not None:
                for i in ev.leaves:
                    if not 0 <= i < num_nodes:
                        raise ValueError(
                            f"leave id {i} outside [0, {num_nodes})")
                    if i in dead_set:
                        raise ValueError(
                            f"node {i} is dead; it cannot churn")
                    if not participating[i]:
                        raise ValueError(
                            f"node {i} leaves at tick {t} but is already "
                            "offline")
                    participating[i] = False
                for i in ev.joins:
                    if not 0 <= i < num_nodes:
                        raise ValueError(
                            f"join id {i} outside [0, {num_nodes})")
                    if i in dead_set:
                        raise ValueError(
                            f"node {i} is dead; it cannot churn")
                    if participating[i]:
                        raise ValueError(
                            f"node {i} joins at tick {t} but is already "
                            "online")
                    participating[i] = True
                    if ever_online[i]:
                        rejoin_t[t, i] = True
                    ever_online[i] = True
            alive_t[t] = participating
        return alive_t, rejoin_t


@dataclasses.dataclass(frozen=True)
class FederationSpec:
    """Per-node roles for one federation run — the single source both
    simulator engines are constructed from.

    attackers: ((node_id, attack_instance), ...) sorted by node id
    dead:      node ids that never act (failure/elasticity tests)
    stragglers: ((node_id, factor), ...) train-interval multipliers
    initial_countdown: per-node ticks until the first train action (length
        num_nodes), or None for the engine's seeded random draw
    membership: optional MembershipSchedule of join/leave/rejoin churn
        (None = everyone but ``dead`` participates for the whole run)
    """
    num_nodes: int
    attackers: Tuple[Tuple[int, object], ...] = ()
    dead: Tuple[int, ...] = ()
    stragglers: Tuple[Tuple[int, int], ...] = ()
    initial_countdown: Optional[Tuple[int, ...]] = None
    membership: Optional[MembershipSchedule] = None

    def __post_init__(self):
        for i, _ in self.attackers:
            if not 0 <= i < self.num_nodes:
                raise ValueError(f"attacker id {i} outside [0, {self.num_nodes})")
        for i in self.dead:
            if not 0 <= i < self.num_nodes:
                raise ValueError(f"dead id {i} outside [0, {self.num_nodes})")
        for i, f in self.stragglers:
            if not 0 <= i < self.num_nodes:
                raise ValueError(f"straggler id {i} outside [0, {self.num_nodes})")
            if f < 1:
                raise ValueError(f"straggler factor must be >= 1, got {f}")
        if (self.initial_countdown is not None
                and len(self.initial_countdown) != self.num_nodes):
            raise ValueError(
                f"initial_countdown has {len(self.initial_countdown)} entries "
                f"for {self.num_nodes} nodes")
        if self.membership is not None:
            self.membership.validate(self.num_nodes, dead=self.dead)

    @classmethod
    def build(cls, num_nodes: int, *, malicious=(), attack=None,
              dead: Sequence[int] = (), stragglers: Optional[dict] = None,
              initial_countdown=None,
              membership: Optional[MembershipSchedule] = None
              ) -> "FederationSpec":
        """The convenient constructor. ``malicious`` is either a sequence of
        node ids (all assigned ``attack``, name or instance; default
        ``gaussian``) or a dict ``{node_id: attack}`` for heterogeneous
        adversaries (in which case ``attack`` must be omitted)."""
        if isinstance(malicious, dict):
            if attack is not None:
                raise ValueError(
                    "malicious={node: attack} already assigns per-node "
                    "attacks; drop the separate attack= argument")
            attackers = tuple(sorted(
                (int(i), _resolve(a)) for i, a in malicious.items()))
        else:
            atk = _resolve(attack if attack is not None else "gaussian")
            attackers = tuple((int(i), atk) for i in sorted(set(malicious)))
        return cls(
            num_nodes=num_nodes, attackers=attackers,
            dead=tuple(sorted(set(int(i) for i in dead))),
            stragglers=tuple(sorted(
                (int(k), int(v)) for k, v in (stragglers or {}).items())),
            initial_countdown=(None if initial_countdown is None
                               else tuple(int(c) for c in initial_countdown)),
            membership=membership)

    @classmethod
    def honest(cls, num_nodes: int) -> "FederationSpec":
        return cls(num_nodes=num_nodes)

    # ------------------------------------------------------------- accessors
    @property
    def malicious(self) -> Tuple[int, ...]:
        return tuple(i for i, _ in self.attackers)

    def attack_for(self, node_id: int):
        for i, a in self.attackers:
            if i == node_id:
                return a
        return None

    def straggler_map(self) -> Dict[int, int]:
        return dict(self.stragglers)

    def attack_groups(self) -> List[Tuple[object, np.ndarray]]:
        """Attackers grouped by attack instance, as (attack, (N,) bool mask)
        in first-appearance order over ascending node ids — the vectorized
        engine runs one vmap per group over just that group's node ids, and
        the group order keys its PRNG folds (group 0 of a single-gaussian
        spec reproduces the legacy ``malicious=`` stream bit-for-bit)."""
        groups: List[Tuple[object, np.ndarray]] = []
        index: Dict[object, int] = {}
        for i, a in self.attackers:   # attackers are sorted by node id
            if a not in index:
                index[a] = len(groups)
                groups.append((a, np.zeros((self.num_nodes,), np.bool_)))
            groups[index[a]][1][i] = True
        return groups

    def attack_fold_of(self, attack) -> Optional[int]:
        """The fold constant THIS spec assigns ``attack`` (its position in
        ``attack_groups()`` order through ``attack_fold``), or None if the
        spec has no node running it. Batched runs use this to give every
        batch member its own single-run key stream."""
        for gi, (a, _) in enumerate(self.attack_groups()):
            if a == attack:
                return attack_fold(gi)
        return None

    def attack_key_fns(self, seed: int) -> Dict[int, Callable]:
        """Per-attacker ``tick -> key`` streams for the heap engine, drawn
        from the SAME fold_in(tick) scheme the lax scan uses (group order
        over ``attack_groups()``, fold constants from ``attack_fold``) —
        with matching broadcast ticks the two engines poison with
        bit-identical randomness."""
        base = jax.random.PRNGKey(seed)
        fns: Dict[int, Callable] = {}
        for gi, (_, mask) in enumerate(self.attack_groups()):
            fold_const = attack_fold(gi)
            for i in np.flatnonzero(mask):
                node = int(i)
                def key_at(tick, _fold=fold_const, _i=node):
                    return attack_key_at(base, tick, _fold,
                                         self.num_nodes, _i)
                fns[node] = key_at
        return fns


# ============================================================== batched sheet
@dataclasses.dataclass(frozen=True)
class BatchedFederationSpec:
    """A stack of same-N ``FederationSpec`` role sheets, one PRNG seed each
    — the unit the vectorized engine vmaps over (docs/SWEEPS.md).

    All members must agree on ``num_nodes`` (the static shape vmap
    requires); topology, scenario and ``SimLaxConfig`` are shared at the
    simulator level. Everything else — attacker sheets, dead sets,
    stragglers, countdowns, seeds — may differ per member and becomes a
    leading-axis array inside the scan.

    specs: (B,) FederationSpec members
    seeds: (B,) per-member engine seeds (member b's run is bitwise the
        single run of ``specs[b]`` under ``SimLaxConfig(seed=seeds[b])``),
        or None to run every member at the config's seed
    """
    specs: Tuple[FederationSpec, ...]
    seeds: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if not self.specs:
            raise ValueError("BatchedFederationSpec needs >= 1 spec")
        n = self.specs[0].num_nodes
        for b, s in enumerate(self.specs):
            if s.num_nodes != n:
                raise ValueError(
                    f"batch members must share num_nodes: member {b} has "
                    f"{s.num_nodes}, member 0 has {n}")
        if self.seeds is not None and len(self.seeds) != len(self.specs):
            raise ValueError(
                f"{len(self.seeds)} seeds for {len(self.specs)} specs")

    @classmethod
    def build(cls, specs: Sequence[FederationSpec],
              seeds: Optional[Sequence[int]] = None
              ) -> "BatchedFederationSpec":
        return cls(specs=tuple(specs),
                   seeds=None if seeds is None
                   else tuple(int(s) for s in seeds))

    # ------------------------------------------------------------- accessors
    @property
    def batch_size(self) -> int:
        return len(self.specs)

    @property
    def num_nodes(self) -> int:
        return self.specs[0].num_nodes

    def resolved_seeds(self, default_seed: int) -> Tuple[int, ...]:
        return (self.seeds if self.seeds is not None
                else (int(default_seed),) * len(self.specs))

    def dead_sets(self) -> Tuple[Tuple[int, ...], ...]:
        """(B,) dead-node tuples, the ``topology.batch_budgets`` input."""
        return tuple(s.dead for s in self.specs)

    def attack_union(self) -> List[Tuple[object, np.ndarray, np.ndarray]]:
        """Distinct attack instances across the batch, in first-appearance
        order (member-major), as ``(attack, (B, N) bool mask, (B,) int32
        folds)`` triples. ``mask[b]`` marks member b's nodes running the
        attack; ``folds[b]`` is the fold constant member b's OWN
        ``attack_groups()`` order assigns it (``attack_fold``), so the
        batched scan replays each member's single-run poison stream
        bit-for-bit. Members without the attack get an all-False mask (the
        fold entry is unused — the masked select discards the output)."""
        b_n = (len(self.specs), self.num_nodes)
        union: List[Tuple[object, np.ndarray, np.ndarray]] = []
        index: Dict[object, int] = {}
        for b, s in enumerate(self.specs):
            for gi, (a, mask) in enumerate(s.attack_groups()):
                if a not in index:
                    index[a] = len(union)
                    union.append((a, np.zeros(b_n, np.bool_),
                                  np.zeros((b_n[0],), np.int32)))
                _, masks, folds = union[index[a]]
                masks[b] = mask
                folds[b] = attack_fold(gi)
        return union
