"""Keys, addresses, digests, signatures (paper §IV-A, Table I).

The paper allows "either one of the mainstream asymmetric encryption methods,
such as ECDSA and RSA". No crypto package ships in this container, so we
implement textbook RSA signing over sha256 digests (Miller-Rabin keygen,
sig = H^d mod n). The interface (generate_keypair / sign / verify / address)
isolates the scheme so a hardened ECDSA can be dropped in.

Model payloads are identified by *fingerprints*: hashing 10^11-parameter
arrays on the host is impossible, so shards are reduced in-graph to a few u32
checksums (see ``fingerprint_tree``) and the sha256 of those is signed.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import secrets
from typing import Any

import numpy as np

_RSA_BITS = 1024
_E = 65537


def sha256_hex(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


def hash_fields(*fields: Any) -> str:
    """Canonical digest of heterogeneous fields (paper: hash(content))."""
    blob = json.dumps([str(f) for f in fields], separators=(",", ":")).encode()
    return sha256_hex(blob)


# ------------------------------------------------------------------ RSA keygen
def _is_probable_prime(n: int, rounds: int = 20) -> bool:
    if n < 2:
        return False
    for p in (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37):
        if n % p == 0:
            return n == p
    d, r = n - 1, 0
    while d % 2 == 0:
        d //= 2
        r += 1
    for _ in range(rounds):
        a = secrets.randbelow(n - 3) + 2
        x = pow(a, d, n)
        if x in (1, n - 1):
            continue
        for _ in range(r - 1):
            x = pow(x, 2, n)
            if x == n - 1:
                break
        else:
            return False
    return True


def _random_prime(bits: int) -> int:
    while True:
        n = secrets.randbits(bits) | (1 << (bits - 1)) | 1
        if _is_probable_prime(n):
            return n


@dataclasses.dataclass(frozen=True)
class KeyPair:
    n: int
    e: int
    d: int

    @property
    def public_key(self) -> str:
        return f"{self.n:x}:{self.e:x}"

    @property
    def address(self) -> str:
        """address_node = hash(pub_key_node) (paper §IV-A1)."""
        return sha256_hex(self.public_key.encode())


def generate_keypair(bits: int = _RSA_BITS) -> KeyPair:
    while True:
        p = _random_prime(bits // 2)
        q = _random_prime(bits // 2)
        if p == q:
            continue
        n = p * q
        phi = (p - 1) * (q - 1)
        if phi % _E == 0:
            continue
        d = pow(_E, -1, phi)
        return KeyPair(n=n, e=_E, d=d)


def sign(key: KeyPair, digest_hex: str) -> str:
    h = int(digest_hex, 16) % key.n
    return f"{pow(h, key.d, key.n):x}"


def verify(public_key: str, digest_hex: str, signature_hex: str) -> bool:
    try:
        n_hex, e_hex = public_key.split(":")
        n, e = int(n_hex, 16), int(e_hex, 16)
        h = int(digest_hex, 16) % n
        return pow(int(signature_hex, 16), e, n) == h
    except (ValueError, AttributeError):
        return False


# ------------------------------------------------------- model fingerprinting
def fingerprint_array(x) -> int:
    """Cheap order-sensitive u32 checksum of an array (computed on host for
    small models; the in-graph variant lives in repro.core for giants)."""
    a = np.asarray(x)
    b = a.astype(np.float32, copy=False).tobytes() if a.dtype.kind == "f" else a.tobytes()
    return int.from_bytes(hashlib.sha256(b).digest()[:4], "big")


def fingerprint_tree(tree) -> str:
    """sha256 over per-leaf checksums — the transaction's ml_model identity."""
    import jax

    sums = [fingerprint_array(x) for x in jax.tree.leaves(tree)]
    return sha256_hex(np.asarray(sums, np.uint64).tobytes())
