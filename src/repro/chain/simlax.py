"""Vectorized tick simulator: the paper's §VI-D network experiments at
thousand-node scale.

The heap `Simulator` (repro.chain.network) walks a Python event queue one
message at a time — faithful, but tens of nodes at most. This engine replays
the same tick process as ONE jitted ``lax.scan`` over ticks with every
per-node action vectorized:

* node train steps are ``vmap``'d over the federation (optionally over
  per-node training data too — real-model scenarios shard a dataset);
* message delivery is a masked gather/scatter over the topology's adjacency:
  ``arrive[dst, src]`` holds the delivery tick of the in-flight model from
  ``src`` (INT32_MAX when none), set at broadcast time to
  ``t + hop_distance * latency`` for every node within ``ttl`` hops — with
  deterministic per-hop latency this is exactly the heap simulator's
  first-arrival (duplicate-dropping) flood, and (since the frontier
  lowering) exactly the hop at which ``topology.gossip_schedule`` delivers
  that pair in the production gossip round, on EVERY topology kind;
* the FedAvg buffer is the streaming form of Eq. 3 (weighted sum + weight
  total + count) plus a running (min accuracy, argmin sender) pair for the
  reputation punishment, all (N,) / (N, N) arrays;
* latency, train countdowns and straggler factors are integer tick counters
  carried in arrays.

Receipt evaluation has two interchangeable engines (``SimLaxConfig.delivery``):

``sparse`` (default)
    Per tick the due ``(dst, src)`` pairs are compacted into a fixed-size
    slot buffer of width ``budget = max_dst |ball(dst, ttl)|``
    (`repro.core.topology.delivery_budget` — no receiver can have more
    in-flight models than its ttl-ball holds senders, so the buffer never
    overflows). ``eval_fn`` runs once per SLOT via one nested vmap and the
    weights / running-min are scattered back: per-tick receipt cost is
    O(N * budget * eval) ≈ O(deliveries * eval) instead of O(N² * eval).
    This is what makes real receipt models (LeNet, LMs) feasible: the model
    forward pass dominates and only actually-delivered pairs pay it.

``dense``
    The original oracle: ``eval_fn`` on all N² ``(dst, src)`` pairs every
    tick, masked by dueness. Kept as the behavioral reference — the two
    engines are parity-tested to produce identical event streams and
    matching state (tests/test_simlax.py).

Scope: train/broadcast/receipt/FedAvg/reputation dynamics — the metrics the
paper's figures plot. Block assembly, signatures and ledger bookkeeping stay
in the heap simulator, which remains the behavioral reference; `simlax` is
validated against it on shared scenarios (tests/test_simlax.py).

Deliberate approximations vs the heap reference (all vanish in aggregate,
see the parity test):
* a FedAvg round consumes the WHOLE pending buffer at end-of-tick, not
  exactly ``buffer_size`` entries mid-tick;
* exactly one worst sender is punished per round (ties are measure-zero for
  continuous accuracies);
* a node re-broadcasting before its previous model finished propagating
  overwrites the in-flight snapshot — ``__init__`` warns when
  ``min train interval < ttl * latency`` makes that reachable (the heap
  engine keeps every snapshot, so event streams diverge there; pinned in
  tests/test_simlax.py).
"""
from __future__ import annotations

import dataclasses
import inspect
import warnings
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.chain import attacks as attacks_lib
from repro.chain.attacks import FederationSpec
from repro.core import topology as topology_lib
from repro.core.reputation import ReputationImpl

_NEVER = np.iinfo(np.int32).max
_EPS = 1e-12

DELIVERY_ENGINES = ("sparse", "dense")


@dataclasses.dataclass(frozen=True)
class SimLaxConfig:
    ticks: int = 200
    train_interval: tuple = (8, 16)   # uniform random ticks between trains
    latency: int = 2                  # per-hop delivery delay (ticks)
    ttl: int = 2                      # flood radius (hops)
    record_every: int = 10
    seed: int = 0
    delivery: str = "sparse"          # receipt engine: "sparse" | "dense"


def _normalize_train_fn(train_fn: Callable, *, has_train_data: bool) -> Callable:
    """The engine calls ``train_fn(params, key, data)`` uniformly (the
    Scenario protocol); legacy two-arg ``train_fn(params, key)`` callables
    are wrapped to ignore the (absent) data operand. A two-arg train_fn
    combined with actual train_data is an error — silently training
    without the data would corrupt results."""
    try:
        n_params = len(inspect.signature(train_fn).parameters)
    except (TypeError, ValueError):     # builtins / partials without sigs
        return train_fn
    if n_params >= 3:
        return train_fn
    if has_train_data:
        raise TypeError(
            "train_fn takes (params, key) but train_data was provided; a "
            "data-consuming train step must accept (params, key, data)")
    return lambda params, key, data: train_fn(params, key)


@dataclasses.dataclass
class SimLaxResult:
    params: object                    # pytree, leaves (N, ...)
    reputation: np.ndarray            # (N, N) row i = node i's local view
    acc_history: np.ndarray           # (num_records, N) test accuracy
    record_ticks: np.ndarray          # (num_records,)
    stats: dict                       # broadcasts / deliveries / fedavg_rounds
    final_state: dict = dataclasses.field(default_factory=dict)
    # ^ raw end-of-run carry (arrive/w_sum/buf_cnt/min_acc/min_sender as
    #   numpy) — the engine-parity tests compare it across delivery engines
    sent: object = None               # pytree (N, ...): each node's LAST
    # broadcast payload (post-attack) — the heap `DFLNode.last_broadcast`
    # counterpart the bitwise attack-parity tests compare against

    def mean_reputation(self, target: int) -> float:
        """target's reputation averaged over other nodes' local views
        (paper Fig 15/17 metric)."""
        n = self.reputation.shape[0]
        others = [i for i in range(n) if i != target]
        return float(self.reputation[others, target].mean())


class LaxSimulator:
    """Drives a vectorized federation over a virtual-time network.

    The primary constructor takes the three first-class abstractions::

        LaxSimulator(scenario, topology, spec, rep_impl, cfg)

    * ``scenario`` — anything satisfying ``repro.chain.scenarios.Scenario``
      (uniform ``train_fn(params, key, data)`` / ``eval_fn`` / ``test_fn``
      plus stacked params/data properties);
    * ``spec`` — a ``repro.chain.attacks.FederationSpec`` role sheet
      (per-node attacker assignment, dead nodes, stragglers, initial
      countdowns); the heap ``Simulator`` is constructed from the SAME spec
      via ``scenarios.make_heap_simulator`` for the parity tests;
    * attacks run inside the jitted scan: one masked vmap per distinct
      attack instance, so heterogeneous adversary populations stay traced.

    The pre-spec keyword form (``train_fn=...``, ``malicious=...``,
    ``dead=...``, ...) remains as a thin deprecation shim that builds the
    same internals — ``malicious`` ids map to the default ``gaussian``
    attack, which reproduces the legacy hard-coded poisoning bit-for-bit.
    """

    def __init__(self, scenario=None, topology: topology_lib.Topology = None,
                 spec: Optional[FederationSpec] = None,
                 rep_impl: ReputationImpl = None,
                 cfg: SimLaxConfig = None, *,
                 train_fn: Callable = None, eval_fn: Callable = None,
                 test_fn: Callable = None, eval_data=None,
                 malicious: Sequence[int] = (),
                 stragglers: Optional[dict] = None,
                 dead: Sequence[int] = (),
                 initial_countdown: Optional[Sequence[int]] = None,
                 train_data=None):
        if topology is None:
            raise TypeError("LaxSimulator requires a topology")
        if rep_impl is None or cfg is None:
            raise TypeError("LaxSimulator requires rep_impl and cfg")
        n = topology.num_nodes

        if scenario is not None:
            if train_fn or eval_fn or test_fn or eval_data is not None:
                raise TypeError(
                    "pass EITHER a scenario OR the legacy "
                    "train_fn/eval_fn/test_fn/eval_data kwargs, not both")
            train_fn, eval_fn, test_fn = (scenario.train_fn,
                                          scenario.eval_fn, scenario.test_fn)
            eval_data = scenario.eval_data()
            if train_data is None:
                train_data = scenario.train_data()
        else:
            if train_fn is None or eval_fn is None or test_fn is None \
                    or eval_data is None:
                raise TypeError(
                    "LaxSimulator needs a scenario (preferred) or the "
                    "legacy train_fn/eval_fn/test_fn/eval_data kwargs")
            warnings.warn(
                "constructing LaxSimulator from loose train_fn/eval_fn/"
                "test_fn kwargs is deprecated; pass a Scenario "
                "(repro.chain.scenarios) instead",
                DeprecationWarning, stacklevel=2)

        legacy_roles = (tuple(malicious) != () or tuple(dead) != ()
                        or bool(stragglers) or initial_countdown is not None)
        if spec is None:
            spec = FederationSpec.build(
                n,
                malicious=(tuple(malicious)
                           or tuple(getattr(scenario, "malicious", ()) or ())),
                dead=tuple(dead), stragglers=stragglers,
                initial_countdown=initial_countdown)
        elif legacy_roles:
            raise TypeError(
                "pass node roles EITHER via FederationSpec OR via the "
                "legacy malicious/dead/stragglers/initial_countdown "
                "kwargs, not both")
        if spec.num_nodes != n:
            raise ValueError(
                f"spec is for {spec.num_nodes} nodes, topology has {n}")

        self.scenario = scenario
        self.spec = spec
        self.topology = topology
        self.cfg = cfg
        self.rep_impl = rep_impl

        if cfg.latency < 1:
            raise ValueError(
                "latency must be >= 1 tick (0 would schedule arrivals at "
                "the already-processed current tick and drop every message)")
        if cfg.delivery not in DELIVERY_ENGINES:
            raise ValueError(
                f"unknown delivery engine {cfg.delivery!r}; "
                f"choose from {DELIVERY_ENGINES}")
        # strict <: deliveries are processed before same-tick re-broadcast,
        # so interval == ttl*latency still delivers every hop-ttl arrival
        if cfg.train_interval[0] < cfg.ttl * cfg.latency:
            warnings.warn(
                f"min train interval ({cfg.train_interval[0]}) < ttl * "
                f"latency ({cfg.ttl * cfg.latency}): a node can re-broadcast "
                "before its previous model finished propagating, and this "
                "engine's single in-flight snapshot per (dst, src) pair "
                "overwrites the old delivery — event counts will fall below "
                "the heap reference's. Raise train_interval or lower "
                "ttl/latency for exact parity.",
                stacklevel=2)
        alive = np.ones((n,), np.bool_)
        alive[list(spec.dead)] = False
        self.alive = alive
        # flooding routes only through alive nodes
        adj = topology.adj & alive[None, :] & alive[:, None]
        dist = topology_lib.hop_distance_from_adj(adj)
        reach = (dist >= 1) & (dist <= cfg.ttl)
        self._reach = jnp.asarray(reach)
        delay = np.where(reach, dist * cfg.latency, 0).astype(np.int32)
        self._delay = jnp.asarray(delay)
        # sparse engine: fixed slot-buffer width = the exact worst case of
        # simultaneous arrivals at one receiver (its ttl-ball size). Slots
        # are STATIC: slot k of dst is its k-th in-ball sender (ascending
        # src index, so the masked argmin reproduces the dense engine's
        # lowest-src tie-break) — a delivery can only come from the ball,
        # so dueness is a cheap (N, budget) gather, no per-tick compaction.
        self.delivery_budget = max(
            1, topology_lib.delivery_budget(adj, cfg.ttl, dist=dist))
        slot_src = np.argsort(~reach, axis=1, kind="stable")
        self._slot_src = jnp.asarray(
            slot_src[:, :self.delivery_budget].astype(np.int32))

        # one gathered vmap per distinct attack instance over that group's
        # (static) node ids only; group order keys the per-group PRNG folds
        # (group 0 of a single-gaussian spec replays the legacy hard-coded
        # poison stream bit-for-bit)
        self._attack_groups = [(attack, np.flatnonzero(mask))
                               for attack, mask in spec.attack_groups()]
        mal = np.zeros((n,), np.bool_)
        mal[list(spec.malicious)] = True
        self._malicious = jnp.asarray(mal)
        strag = np.ones((n,), np.int32)
        for k, v in spec.straggler_map().items():
            strag[k] = v
        self._straggler = jnp.asarray(strag)
        self._alive_j = jnp.asarray(alive)

        self._train_fn = _normalize_train_fn(
            train_fn, has_train_data=train_data is not None)
        self._eval_fn = eval_fn
        self._test_fn = test_fn
        self._eval_data = eval_data
        self._train_data = train_data
        self._initial_countdown = (
            None if spec.initial_countdown is None
            else jnp.asarray(np.asarray(spec.initial_countdown, np.int32)))

    # ------------------------------------------------------------------ pieces
    def _interval(self, key):
        lo, hi = self.cfg.train_interval
        base = (jnp.full(key.shape[:-1] or (), lo, jnp.int32) if lo == hi
                else jax.random.randint(key, (), lo, hi + 1, jnp.int32))
        return base

    # ------------------------------------------------------------- delivery
    def _deliver_dense(self, state, due, eval_data):
        """Oracle: eval ALL N² (dst, src) pairs, mask by dueness."""
        # accs[dst, src] = eval of src's in-flight model on dst's data
        accs = jax.vmap(
            lambda d: jax.vmap(lambda s: self._eval_fn(s, d))(state["sent"])
        )(eval_data)                                     # (dst, src)
        accs = jnp.where(due, accs, 0.0)
        w = state["rep"] * accs * due                    # Eq. 2 per pair
        acc_sum = jax.tree.map(
            lambda a, s: a + jnp.einsum(
                "ds,s...->d...", w, s.astype(jnp.float32)),
            state["acc_sum"], state["sent"])
        w_sum = state["w_sum"] + w.sum(axis=1)
        buf_cnt = state["buf_cnt"] + due.sum(axis=1).astype(jnp.int32)
        # running (min acc, argmin sender) for the punishment
        masked = jnp.where(due, accs, jnp.inf)           # (dst, src)
        batch_min = masked.min(axis=1)
        batch_sender = masked.argmin(axis=1).astype(jnp.int32)
        return acc_sum, w_sum, buf_cnt, batch_min, batch_sender

    def _deliver_sparse(self, state, due, eval_data):
        """Budgeted: gather the (N, budget) static ball slots, eval only
        those via one nested vmap, scatter weights/min back."""
        slot_src = self._slot_src                        # (dst, slot)
        slot_ok = jnp.take_along_axis(due, slot_src, axis=1)
        # gather the in-ball models once: leaves (N, B, ...)
        gathered = jax.tree.map(lambda s: s[slot_src], state["sent"])
        accs = jax.vmap(
            lambda models, d: jax.vmap(
                lambda m: self._eval_fn(m, d))(models)
        )(gathered, eval_data)                           # (dst, slot)
        accs = jnp.where(slot_ok, accs, 0.0)
        rep_slot = jnp.take_along_axis(state["rep"], slot_src, axis=1)
        w = rep_slot * accs * slot_ok                    # Eq. 2 per slot
        acc_sum = jax.tree.map(
            lambda a, g: a + jnp.einsum(
                "nb,nb...->n...", w, g.astype(jnp.float32)),
            state["acc_sum"], gathered)
        w_sum = state["w_sum"] + w.sum(axis=1)
        buf_cnt = state["buf_cnt"] + slot_ok.sum(axis=1).astype(jnp.int32)
        masked = jnp.where(slot_ok, accs, jnp.inf)       # (dst, slot)
        batch_min = masked.min(axis=1)
        arg_slot = masked.argmin(axis=1)
        batch_sender = jnp.take_along_axis(
            slot_src, arg_slot[:, None], axis=1)[:, 0]
        return acc_sum, w_sum, buf_cnt, batch_min, batch_sender

    # --------------------------------------------------------------------- run
    def run(self, params0=None):
        """params0: pytree with leading N dim (defaults to the scenario's
        stacked init). Returns SimLaxResult."""
        if params0 is None:
            if self.scenario is None:
                raise TypeError(
                    "run() needs params0 when constructed without a scenario")
            params0 = self.scenario.init_params_stacked()
        cfg = self.cfg
        n = self.topology.num_nodes
        rep_impl = self.rep_impl
        alive = self._alive_j
        reach, delay = self._reach, self._delay
        malicious, straggler = self._malicious, self._straggler
        attack_groups = self._attack_groups
        eval_data = self._eval_data
        train_data = self._train_data
        train_v = jax.vmap(self._train_fn,
                           in_axes=(0, 0, None if train_data is None else 0))
        test_v = jax.vmap(self._test_fn)
        deliver = (self._deliver_sparse if cfg.delivery == "sparse"
                   else self._deliver_dense)

        key0 = jax.random.PRNGKey(cfg.seed)
        zeros_like_params = jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params0)

        init = dict(
            params=params0,
            sent=jax.tree.map(jnp.zeros_like, params0),
            arrive=jnp.full((n, n), _NEVER, jnp.int32),
            rep=jnp.full((n, n), rep_impl.initial, jnp.float32),
            acc_sum=zeros_like_params,
            w_sum=jnp.zeros((n,), jnp.float32),
            buf_cnt=jnp.zeros((n,), jnp.int32),
            min_acc=jnp.full((n,), jnp.inf, jnp.float32),
            min_sender=jnp.zeros((n,), jnp.int32),
            # heap parity: the FIRST countdown is not straggler-scaled
            next_train=(self._initial_countdown
                        if self._initial_countdown is not None
                        else jax.vmap(self._interval)(
                            jax.random.split(
                                jax.random.fold_in(key0, 12345), n))),
            broadcasts=jnp.zeros((n,), jnp.int32),
            deliveries=jnp.zeros((), jnp.int32),
            fedavg_rounds=jnp.zeros((), jnp.int32),
        )

        def body(state, t):
            key_t = jax.random.fold_in(key0, t)

            # ---- 1. deliveries: models whose tick counter hits t.
            # On a no-delivery tick every update below is a no-op, so the
            # (model-forward-pass-heavy) eval work is skipped entirely via
            # cond — most ticks between broadcast waves cost nothing.
            due = (state["arrive"] == t) & alive[:, None]    # (dst, src)
            acc_sum, w_sum, buf_cnt, batch_min, batch_sender = jax.lax.cond(
                due.any(),
                lambda s: deliver(s, due, eval_data),
                lambda s: (s["acc_sum"], s["w_sum"], s["buf_cnt"],
                           jnp.full((n,), jnp.inf, jnp.float32),
                           jnp.zeros((n,), jnp.int32)),
                state)
            better = batch_min < state["min_acc"]
            min_acc = jnp.where(better, batch_min, state["min_acc"])
            min_sender = jnp.where(better, batch_sender,
                                   state["min_sender"])
            arrive = jnp.where(due, _NEVER, state["arrive"])

            # ---- 2. weighted FedAvg (Eq. 3) where the buffer filled up
            fire = buf_cnt >= rep_impl.buffer_size           # (N,)
            safe = w_sum > _EPS
            apply = fire & safe

            def leaf(acc, p):
                avg = acc / jnp.maximum(w_sum, _EPS).reshape(
                    (-1,) + (1,) * (acc.ndim - 1))
                out = 0.5 * (avg + p.astype(jnp.float32))
                keep = apply.reshape((-1,) + (1,) * (acc.ndim - 1))
                return jnp.where(keep, out, p.astype(jnp.float32)).astype(
                    p.dtype)

            params = jax.tree.map(leaf, acc_sum, state["params"])
            # punish the worst sender of each fired buffer (§IV-D1)
            pen = jnp.zeros((n, n), jnp.float32).at[
                jnp.arange(n), min_sender].add(
                jnp.where(fire & (min_acc < jnp.inf), rep_impl.penalty, 0.0))
            rep = jnp.clip(state["rep"] - pen, rep_impl.floor,
                           rep_impl.initial)
            # reset consumed buffers
            keep1 = ~fire
            acc_sum = jax.tree.map(
                lambda a: a * keep1.reshape((-1,) + (1,) * (a.ndim - 1)),
                acc_sum)
            w_sum = w_sum * keep1
            buf_cnt = buf_cnt * keep1
            min_acc = jnp.where(fire, jnp.inf, min_acc)
            min_sender = jnp.where(fire, 0, min_sender)

            # ---- 3. train + broadcast where the countdown expired
            # (cond-gated like delivery: the vmapped train step + poison
            # sampling only run on ticks where some countdown expired)
            next_train = state["next_train"] - 1
            trains = (next_train <= 0) & alive                # (N,)

            def do_train(operand):
                committed, sent = operand
                tkeys = jax.random.split(jax.random.fold_in(key_t, 0), n)
                trained = train_v(committed, tkeys, train_data)
                # attackers never COMMIT local training; their honestly
                # trained candidate is still handed to the attack below
                params = jax.tree.map(
                    lambda new, old: jnp.where(
                        (trains & ~malicious).reshape(
                            (-1,) + (1,) * (new.ndim - 1)),
                        new, old),
                    trained, committed)
                outgoing = trained
                for gi, (attack, ids) in enumerate(attack_groups):
                    # fold constants: 0 = train keys, attacks.attack_fold(gi)
                    # per group, 2 = the interval draw below; the heap
                    # DFLNode draws from the SAME stream (FederationSpec
                    # .attack_key_fns), making randomized-attack parity
                    # bitwise
                    akeys = jax.random.split(
                        jax.random.fold_in(key_t, attacks_lib.attack_fold(gi)),
                        n)[ids]
                    bad = jax.vmap(
                        lambda k, tr, cm, a=attack: a.apply(k, tr, cm, t)
                    )(akeys, jax.tree.map(lambda x: x[ids], trained),
                      jax.tree.map(lambda x: x[ids], committed))
                    outgoing = jax.tree.map(
                        lambda o, b: o.at[ids].set(b.astype(o.dtype)),
                        outgoing, bad)
                sent = jax.tree.map(
                    lambda s, o: jnp.where(
                        trains.reshape((-1,) + (1,) * (s.ndim - 1)), o, s),
                    sent, outgoing)
                return params, sent

            params, sent = jax.lax.cond(
                trains.any(), do_train, lambda operand: operand,
                (params, state["sent"]))
            sched = trains[None, :] & reach                   # (dst, src)
            arrive = jnp.where(sched, t + delay, arrive)
            ikeys = jax.random.split(jax.random.fold_in(key_t, 2), n)
            fresh = jax.vmap(self._interval)(ikeys) * straggler
            next_train = jnp.where(trains, fresh, next_train)

            new_state = dict(
                params=params, sent=sent, arrive=arrive, rep=rep,
                acc_sum=acc_sum, w_sum=w_sum, buf_cnt=buf_cnt,
                min_acc=min_acc, min_sender=min_sender,
                next_train=next_train,
                broadcasts=state["broadcasts"] + trains.astype(jnp.int32),
                deliveries=state["deliveries"] + due.sum(),
                fedavg_rounds=state["fedavg_rounds"] + apply.sum(),
            )
            # the global test eval can dominate at scale: only run it on
            # record ticks (the non-record rows are dropped anyway)
            acc_row = jax.lax.cond(
                t % cfg.record_every == 0,
                lambda p: test_v(p).astype(jnp.float32),
                lambda p: jnp.zeros((n,), jnp.float32),
                params)
            return new_state, acc_row

        final, acc_by_tick = jax.lax.scan(
            body, init, jnp.arange(cfg.ticks, dtype=jnp.int32))
        rec = np.arange(0, cfg.ticks, cfg.record_every)
        return SimLaxResult(
            params=jax.tree.map(np.asarray, final["params"]),
            reputation=np.asarray(final["rep"]),
            acc_history=np.asarray(acc_by_tick)[rec],
            record_ticks=rec,
            stats={
                "broadcasts": int(final["broadcasts"].sum()),
                "broadcasts_per_node": np.asarray(final["broadcasts"]),
                "deliveries": int(final["deliveries"]),
                "fedavg_rounds": int(final["fedavg_rounds"]),
                "delivery": cfg.delivery,
                "delivery_budget": self.delivery_budget,
            },
            final_state={
                k: np.asarray(final[k])
                for k in ("arrive", "w_sum", "buf_cnt",
                          "min_acc", "min_sender", "next_train")
            },
            sent=jax.tree.map(np.asarray, final["sent"]),
        )
