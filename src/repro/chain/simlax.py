"""Vectorized tick simulator: the paper's §VI-D network experiments at
thousand-node scale.

The heap `Simulator` (repro.chain.network) walks a Python event queue one
message at a time — faithful, but tens of nodes at most. This engine replays
the same tick process as ONE jitted ``lax.scan`` over ticks with every
per-node action vectorized:

* node train steps are ``vmap``'d over the federation (optionally over
  per-node training data too — real-model scenarios shard a dataset);
* message delivery is a masked gather/scatter over the topology's adjacency:
  ``arrive[dst, src]`` holds the delivery tick of the in-flight model from
  ``src`` (INT32_MAX when none; the default ``compact`` engine carries the
  same information in ``(N, budget)`` receiver slots), set at broadcast
  time to
  ``t + hop_distance * latency`` for every node within ``ttl`` hops — with
  deterministic per-hop latency this is exactly the heap simulator's
  first-arrival (duplicate-dropping) flood, and (since the frontier
  lowering) exactly the hop at which ``topology.gossip_schedule`` delivers
  that pair in the production gossip round, on EVERY topology kind;
* the FedAvg buffer is the streaming form of Eq. 3 (weighted sum + weight
  total + count) plus a running (min accuracy, argmin sender) pair for the
  reputation punishment, all (N,) / (N, N) arrays;
* latency, train countdowns and straggler factors are integer tick counters
  carried in arrays.

Receipt evaluation has three interchangeable engines
(``SimLaxConfig.delivery``):

``compact`` (default)
    Segment compaction, two layers deep. (1) State layout: the in-flight
    arrival state is per-receiver SLOTS — ``arrive[dst, k]`` is the
    delivery tick of dst's k-th in-ball sender, an ``(N, budget)`` array —
    instead of the oracles' ``(N, N)`` matrix, and broadcasts scatter
    through a static inverse map (sender -> its (dst, slot) landing
    sites), so the per-tick arrival bookkeeping is O(N * budget), not
    O(N²). (2) Work compaction: the tick's due ``(receiver, slot)`` pairs
    are gathered into ONE static work buffer of width
    ``W = topology.compaction_budget(adj, ttl, train_interval)`` — the
    exact per-tick activity bound from broadcast intervals and ring sizes
    (each sender's in-flight broadcast lands at most one hop-distance ring
    per tick). ``eval_fn`` runs once per WORK ITEM via one flat vmap and
    the weights / running-min are segment-scattered back per receiver:
    per-tick receipt cost is O(W * eval), scaling with deliveries that can
    actually be due rather than ``N * budget``. ``SimLaxConfig
    .compact_budget`` overrides W (e.g. staggered broadcast phases make
    the worst-case bound pessimistic); an overflowing tick then fails fast
    (RuntimeError from ``run()``) instead of silently dropping receipts.

``sparse``
    The budgeted per-receiver slot buffer: ``eval_fn`` on all
    ``N * budget`` slots (``budget = max_dst |ball(dst, ttl)|``,
    `repro.core.topology.delivery_budget`) on any tick with >= 1 delivery,
    masked by dueness. O(N * budget * eval) per active tick — every
    mostly-idle receiver still pays its full ball. Kept as the first-level
    parity oracle for ``compact``.

``dense``
    The original all-pairs oracle: ``eval_fn`` on all N² ``(dst, src)``
    pairs every tick, masked by dueness. The behavioral reference — all
    three engines are parity-tested to produce identical event streams and
    matching state (tests/test_simlax.py).

``sharded``
    The compact engine's node axis partitioned over ``SimLaxConfig.shards``
    devices of a `repro.launch.mesh.make_fed_mesh` mesh via ``shard_map``:
    each shard carries its ``(N/S, budget)`` block of the slot state, the
    cross-shard receipt exchange is lowered through the SAME per-offset
    ppermute schedules the production gossip round uses, and the per-shard
    work-buffer budget comes from ``topology.compaction_budget`` on the
    LOCAL adjacency block (worst case over shards; ``compact_budget``
    overrides it per shard). Bitwise identical to ``compact`` — same
    scatter-add structure, pinned on a forced 8-host-device mesh in
    tests/test_sharded.py. Does not compose with ``BatchedFederationSpec``
    (docs/SCALING.md records why).

Dynamic membership: a `repro.chain.attacks.MembershipSchedule` on
``FederationSpec.membership`` threads per-tick join/leave/rejoin events
through this engine (alive/rejoin masks baked as scan consts) and the heap
engine alike. Offline nodes freeze their train countdowns, receive nothing
(models in flight toward them are lost), and keep committed params;
rejoining nodes resume from those params with every peer's reputation of
them decayed ``rep <- clip(rejoin_decay * rep, floor, initial)``. Budgets
stay the static all-alive worst case — churn can only shrink a tick's due
set, and frozen countdowns can re-ALIGN broadcast phases on rejoin, raising
the per-tick delivery peak above the no-churn run's (tests/test_membership
.py pins both).

Batched runs: constructing with a ``repro.chain.attacks
.BatchedFederationSpec`` (B same-N role sheets + per-member seeds; one
shared scenario/topology/config) vmaps the ENTIRE scan over the batch —
per-member role arrays, slot maps and attack masks gain a leading batch
axis, the slot width and compaction budget take the max over members
(`repro.core.topology.batch_budgets`), and ``run()`` returns a list of B
``SimLaxResult``s, each bitwise identical to that member's single run
(tests/test_batched.py pins this). One compiled dispatch amortizes the
per-op overhead that dominates small-N single runs — the whole-grid sweep
throughput lever (`repro.chain.sweeps`, docs/SWEEPS.md).

PRNG key-stream contract (single source: ``repro.chain.attacks``): with
``key_t = fold_in(PRNGKey(cfg.seed), t)``, fold 0 of ``key_t`` keys the
tick's train steps, ``attacks.attack_fold(gi)`` keys attack group ``gi``,
fold 2 keys the train-interval redraw, and fold 12345 of the BASE key
draws initial countdowns. The heap ``DFLNode`` draws attack keys from the
same stream (``FederationSpec.attack_key_fns``), which is what makes
randomized-attack parity between the engines bitwise.

Scope: train/broadcast/receipt/FedAvg/reputation dynamics — the metrics the
paper's figures plot. Block assembly, signatures and ledger bookkeeping stay
in the heap simulator, which remains the behavioral reference; `simlax` is
validated against it on shared scenarios (tests/test_simlax.py).

Deliberate approximations vs the heap reference (all vanish in aggregate,
see the parity test):
* a FedAvg round consumes the WHOLE pending buffer at end-of-tick, not
  exactly ``buffer_size`` entries mid-tick;
* exactly one worst sender is punished per round (ties are measure-zero for
  continuous accuracies);
* a node re-broadcasting before its previous model finished propagating
  overwrites the in-flight snapshot — ``__init__`` warns when
  ``min train interval < ttl * latency`` makes that reachable (the heap
  engine keeps every snapshot, so event streams diverge there; pinned in
  tests/test_simlax.py).
"""
from __future__ import annotations

import dataclasses
import inspect
import warnings
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.chain import attacks as attacks_lib
from repro.chain.attacks import BatchedFederationSpec, FederationSpec
from repro.core import compression
from repro.core import tracecheck
from repro.core import topology as topology_lib
from repro.core.gossip import tree_ppermute
from repro.core.reputation import ReputationImpl

_NEVER = np.iinfo(np.int32).max
_EPS = 1e-12

# One compiled scan per static configuration: simulators whose static
# signature matches share a single jitted dispatch (and its
# tracecheck.TraceCounter), so sweeps over many federations with one
# scenario/topology/config pay trace+compile ONCE instead of per instance.
# Everything dynamic (per-member consts, PRNG keys, eval/train data) flows
# through jit ARGUMENTS; everything the scan closes over statically is part
# of the key. Values hold strong refs to the keyed callables so the id()s
# in the key cannot be recycled while an entry is alive.
_SCAN_CACHE: dict = {}


def _fn_key(fn):
    """Identity key for a (possibly bound-method) callable: bound methods
    are fresh objects per attribute access, so key on the underlying
    function + instance instead of the wrapper."""
    if fn is None:
        return None
    func = getattr(fn, "__func__", None)
    if func is not None:
        return (id(func), id(fn.__self__))
    return (id(fn), None)


def clear_scan_cache():
    """Drop every cached compiled scan (tests / memory pressure)."""
    _SCAN_CACHE.clear()

DELIVERY_ENGINES = ("compact", "sparse", "dense", "sharded")
COMPRESS_MODES = (None, "int8")


@dataclasses.dataclass(frozen=True)
class SimLaxConfig:
    ticks: int = 200
    train_interval: tuple = (8, 16)   # uniform random ticks between trains
    latency: int = 2                  # per-hop delivery delay (ticks)
    ttl: int = 2                      # flood radius (hops)
    record_every: int = 10
    seed: int = 0
    delivery: str = "compact"         # receipt engine: see DELIVERY_ENGINES
    shards: Optional[int] = None      # sharded engine: device count to
    # partition the node axis over (None = all visible devices). Must
    # divide N; only meaningful with delivery="sharded" (docs/SCALING.md)
    compact_budget: Optional[int] = None
    # ^ overrides the compact engine's work-buffer width (default: the
    #   exact topology.compaction_budget bound). A smaller buffer cuts the
    #   per-tick eval bill when broadcasts are known to be staggered; a
    #   tick whose due deliveries exceed it makes run() raise.
    compress: Optional[str] = None    # None | "int8" wire quantization
    # ^ "int8": every broadcast payload is quantize->dequantize round-
    #   tripped ONCE at the sender before entering the in-flight state
    #   (repro.core.compression — the same math the production gossip
    #   round ships over ICI), so all receivers of that broadcast see the
    #   identical reconstruction, across all three delivery engines.
    #   Attacks apply BEFORE quantization: the attacker ships a quantized
    #   poisoned model, as on the real wire. Committed params stay full
    #   precision — only the wire payload is lossy.


def _normalize_train_fn(train_fn: Callable, *, has_train_data: bool) -> Callable:
    """The engine calls ``train_fn(params, key, data)`` uniformly (the
    Scenario protocol); legacy two-arg ``train_fn(params, key)`` callables
    are wrapped to ignore the (absent) data operand. A two-arg train_fn
    combined with actual train_data is an error — silently training
    without the data would corrupt results."""
    try:
        n_params = len(inspect.signature(train_fn).parameters)
    except (TypeError, ValueError):     # builtins / partials without sigs
        return train_fn
    if n_params >= 3:
        return train_fn
    if has_train_data:
        raise TypeError(
            "train_fn takes (params, key) but train_data was provided; a "
            "data-consuming train step must accept (params, key, data)")
    return lambda params, key, data: train_fn(params, key)


@dataclasses.dataclass
class SimLaxResult:
    params: object                    # pytree, leaves (N, ...)
    reputation: np.ndarray            # (N, N) row i = node i's local view
    acc_history: np.ndarray           # (num_records, N) test accuracy
    record_ticks: np.ndarray          # (num_records,)
    stats: dict                       # broadcasts / deliveries / fedavg_rounds
    final_state: dict = dataclasses.field(default_factory=dict)
    # ^ raw end-of-run carry (arrive/w_sum/buf_cnt/min_acc/min_sender as
    #   numpy) — the engine-parity tests compare it across delivery engines
    sent: object = None               # pytree (N, ...): each node's LAST
    # broadcast payload (post-attack) — the heap `DFLNode.last_broadcast`
    # counterpart the bitwise attack-parity tests compare against

    def mean_reputation(self, target: int) -> float:
        """target's reputation averaged over other nodes' local views
        (paper Fig 15/17 metric)."""
        n = self.reputation.shape[0]
        others = [i for i in range(n) if i != target]
        return float(self.reputation[others, target].mean())


class LaxSimulator:
    """Drives a vectorized federation over a virtual-time network.

    The primary constructor takes the three first-class abstractions::

        LaxSimulator(scenario, topology, spec, rep_impl, cfg)

    * ``scenario`` — anything satisfying ``repro.chain.scenarios.Scenario``
      (uniform ``train_fn(params, key, data)`` / ``eval_fn`` / ``test_fn``
      plus stacked params/data properties);
    * ``spec`` — a ``repro.chain.attacks.FederationSpec`` role sheet
      (per-node attacker assignment, dead nodes, stragglers, initial
      countdowns); the heap ``Simulator`` is constructed from the SAME spec
      via ``scenarios.make_heap_simulator`` for the parity tests;
    * attacks run inside the jitted scan: one masked vmap per distinct
      attack instance, so heterogeneous adversary populations stay traced.

    The pre-spec keyword form (``train_fn=...``, ``malicious=...``,
    ``dead=...``, ...) remains as a thin deprecation shim that builds the
    same internals — ``malicious`` ids map to the default ``gaussian``
    attack, which reproduces the legacy hard-coded poisoning bit-for-bit.
    """

    def __init__(self, scenario=None, topology: topology_lib.Topology = None,
                 spec: Optional[FederationSpec] = None,
                 rep_impl: ReputationImpl = None,
                 cfg: SimLaxConfig = None, *,
                 train_fn: Callable = None, eval_fn: Callable = None,
                 test_fn: Callable = None, eval_data=None,
                 malicious: Sequence[int] = (),
                 stragglers: Optional[dict] = None,
                 dead: Sequence[int] = (),
                 initial_countdown: Optional[Sequence[int]] = None,
                 train_data=None):
        if topology is None:
            raise TypeError("LaxSimulator requires a topology")
        if rep_impl is None or cfg is None:
            raise TypeError("LaxSimulator requires rep_impl and cfg")
        n = topology.num_nodes

        if scenario is not None:
            if train_fn or eval_fn or test_fn or eval_data is not None:
                raise TypeError(
                    "pass EITHER a scenario OR the legacy "
                    "train_fn/eval_fn/test_fn/eval_data kwargs, not both")
            train_fn, eval_fn, test_fn = (scenario.train_fn,
                                          scenario.eval_fn, scenario.test_fn)
            eval_data = scenario.eval_data()
            if train_data is None:
                train_data = scenario.train_data()
        else:
            if train_fn is None or eval_fn is None or test_fn is None \
                    or eval_data is None:
                raise TypeError(
                    "LaxSimulator needs a scenario (preferred) or the "
                    "legacy train_fn/eval_fn/test_fn/eval_data kwargs")
            warnings.warn(
                "constructing LaxSimulator from loose train_fn/eval_fn/"
                "test_fn kwargs is deprecated; pass a Scenario "
                "(repro.chain.scenarios) instead",
                DeprecationWarning, stacklevel=2)

        legacy_roles = (tuple(malicious) != () or tuple(dead) != ()
                        or bool(stragglers) or initial_countdown is not None)
        if spec is None:
            spec = FederationSpec.build(
                n,
                malicious=(tuple(malicious)
                           or tuple(getattr(scenario, "malicious", ()) or ())),
                dead=tuple(dead), stragglers=stragglers,
                initial_countdown=initial_countdown)
        elif legacy_roles:
            raise TypeError(
                "pass node roles EITHER via FederationSpec OR via the "
                "legacy malicious/dead/stragglers/initial_countdown "
                "kwargs, not both")
        batched = isinstance(spec, BatchedFederationSpec)
        specs = spec.specs if batched else (spec,)
        for b, s in enumerate(specs):
            if s.num_nodes != n:
                raise ValueError(
                    (f"batch member {b}'s spec" if batched else "spec")
                    + f" is for {s.num_nodes} nodes, topology has {n}")

        self.scenario = scenario
        self.spec = spec
        self.topology = topology
        self.cfg = cfg
        self.rep_impl = rep_impl
        self._batched = batched
        self.batch_size = spec.batch_size if batched else None

        if cfg.latency < 1:
            raise ValueError(
                "latency must be >= 1 tick (0 would schedule arrivals at "
                "the already-processed current tick and drop every message)")
        if cfg.delivery not in DELIVERY_ENGINES:
            raise ValueError(
                f"unknown delivery engine {cfg.delivery!r}; "
                f"choose from {DELIVERY_ENGINES}")
        if cfg.compress not in COMPRESS_MODES:
            raise ValueError(
                f"unknown compress mode {cfg.compress!r}; "
                f"choose from {COMPRESS_MODES}")
        if cfg.shards is not None and cfg.delivery != "sharded":
            raise ValueError(
                f"SimLaxConfig.shards only applies to delivery='sharded' "
                f"(got delivery={cfg.delivery!r})")
        if cfg.delivery == "sharded" and batched:
            raise ValueError(
                "delivery='sharded' does not compose with "
                "BatchedFederationSpec yet: the batch vmap and the fed-axis "
                "shard_map would compete for the same device mesh "
                "(docs/SCALING.md). Run sharded federations one at a time, "
                "or batch with the compact engine.")
        # strict <: deliveries are processed before same-tick re-broadcast,
        # so interval == ttl*latency still delivers every hop-ttl arrival
        if cfg.train_interval[0] < cfg.ttl * cfg.latency:
            warnings.warn(
                f"min train interval ({cfg.train_interval[0]}) < ttl * "
                f"latency ({cfg.ttl * cfg.latency}): a node can re-broadcast "
                "before its previous model finished propagating, and this "
                "engine's single in-flight snapshot per (dst, src) pair "
                "overwrites the old delivery — event counts will fall below "
                "the heap reference's. Raise train_interval or lower "
                "ttl/latency for exact parity.",
                stacklevel=2)
        # per-member role/topology constants: flooding routes only through
        # alive nodes, so each batch member gets its own masked reach/delay
        alives, dists, reaches, delays = [], [], [], []
        for s in specs:
            alive = np.ones((n,), np.bool_)
            alive[list(s.dead)] = False
            adj = topology.adj & alive[None, :] & alive[:, None]
            # the engine only consumes distances <= ttl (reach/delay masks,
            # ring sizes, budgets), so capping the BFS keeps setup O(N^2*ttl)
            dist = topology_lib.hop_distance_from_adj(adj, max_hops=cfg.ttl)
            reach = (dist >= 1) & (dist <= cfg.ttl)
            alives.append(alive)
            dists.append(dist)
            reaches.append(reach)
            delays.append(np.where(reach, dist * cfg.latency, 0)
                          .astype(np.int32))
        self.alive = np.stack(alives) if batched else alives[0]
        # sparse/compact slot width and the compact work-buffer bound both
        # take the MAX over the batch — one static layout serves every
        # member; batch_budgets also records the per-member exact bounds
        self.budgets = topology_lib.batch_budgets(
            topology.adj, cfg.ttl, cfg.train_interval,
            [s.dead for s in specs], latency=cfg.latency, dists=dists)
        # sparse engine: fixed slot-buffer width = the exact worst case of
        # simultaneous arrivals at one receiver (its ttl-ball size). Slots
        # are STATIC: slot k of dst is its k-th in-ball sender (ascending
        # src index, so the masked argmin reproduces the dense engine's
        # lowest-src tie-break) — a delivery can only come from the ball,
        # so dueness is a cheap (N, budget) gather, no per-tick compaction.
        self.delivery_budget = budget = self.budgets.delivery
        # compact engine: one flat work buffer over ALL receivers, sized by
        # the exact per-tick activity bound (every sender's heaviest
        # feasible ring combination landing on one tick) — never larger
        # than the sparse engine's n * budget slots, usually far smaller.
        # cfg.compact_budget overrides it; runtime overflow then fails fast.
        exact = self.budgets.compaction
        if cfg.compact_budget is not None and cfg.compact_budget < 1:
            raise ValueError(
                f"compact_budget must be >= 1, got {cfg.compact_budget}")
        self.compact_budget = min(
            exact if cfg.compact_budget is None else int(cfg.compact_budget),
            n * self.delivery_budget)
        # members whose own ttl-ball is smaller than the shared width get
        # padding slots mapped to non-reach senders: never due, weight 0
        slot_srcs = [np.argsort(~reach, axis=1, kind="stable")[:, :budget]
                     .astype(np.int32) for reach in reaches]
        self._slot_src_np = np.stack(slot_srcs) if batched else slot_srcs[0]
        # compact state layout: arrive is (N, budget) receiver slots, and
        # broadcasting scatters through the static INVERSE slot map — for
        # each sender, the (dst, slot, delay) triples it lands in (out-ball
        # == ball on a symmetric adjacency, so budget rows suffice; padding
        # rows point at the dropped index n). This keeps the per-tick
        # arrival bookkeeping O(N * budget); the oracles keep the (N, N)
        # matrix the parity tests compare against — and skip building the
        # map (an O(N^2) temp + a python loop over senders) entirely.
        inv_dsts, inv_slots, inv_delays = [], [], []
        if cfg.delivery == "compact":
            for reach, delay, slot_src in zip(reaches, delays, slot_srcs,
                                              strict=True):
                slot_of = np.full((n, n), -1, np.int64)
                rows = np.arange(n)[:, None]
                slot_of[rows, slot_src] = np.arange(budget)[None, :]
                slot_of[~reach] = -1  # padding cols map to non-reach senders
                inv_dst = np.full((n, budget), n, np.int32)
                inv_slot = np.zeros((n, budget), np.int32)
                inv_delay = np.zeros((n, budget), np.int32)
                for src in range(n):
                    dsts = np.flatnonzero(reach[:, src])
                    inv_dst[src, :len(dsts)] = dsts
                    inv_slot[src, :len(dsts)] = slot_of[dsts, src]
                    inv_delay[src, :len(dsts)] = delay[dsts, src]
                inv_dsts.append(inv_dst)
                inv_slots.append(inv_slot)
                inv_delays.append(inv_delay)

        # sharded engine: fed-axis partition layout — each device carries an
        # m = N/S receiver block of the scan state; broadcasts are exchanged
        # between blocks by the same ppermute collective the production
        # gossip round uses, one permute per occupied shard offset
        # (docs/SCALING.md)
        self.shards = None
        self._offsets = None
        self.shard_budget = None
        self._mesh = None
        src_to_buf = shard_index = slot_delay = slot_valid = None
        if cfg.delivery == "sharded":
            S = int(cfg.shards) if cfg.shards is not None \
                else jax.device_count()
            if S < 1:
                raise ValueError(f"shards must be >= 1, got {S}")
            if n % S != 0:
                raise ValueError(
                    f"delivery='sharded' needs num_nodes ({n}) divisible "
                    f"by shards ({S})")
            if S > jax.device_count():
                raise ValueError(
                    f"shards={S} but only {jax.device_count()} devices are "
                    "visible (on CPU, force host devices with "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=S "
                    "before the first jax import)")
            m = n // S
            reach, delay, dist = reaches[0], delays[0], dists[0]
            alive0 = alives[0]
            adj0 = topology.adj & alive0[None, :] & alive0[:, None]
            # per-shard work buffer: each shard compacts only deliveries
            # landing on ITS receiver block, so its width is the compaction
            # bound restricted to those receiver columns (shared width =
            # worst case over shards; cfg.compact_budget overrides it)
            per_shard = [
                topology_lib.compaction_budget(
                    adj0, cfg.ttl, cfg.train_interval, latency=cfg.latency,
                    dist=dist, receivers=np.arange(p * m, (p + 1) * m))
                for p in range(S)]
            want = (max(1, max(per_shard)) if cfg.compact_budget is None
                    else int(cfg.compact_budget))
            self.shard_budget = min(want, m * budget)
            # exchange schedule: shard p needs sent-models from shard q iff
            # some reach pair crosses q -> p; offset d = (p - q) mod S reads
            # "receive from the shard d behind me" — one ppermute per
            # occupied offset, every tick, unconditionally (collectives may
            # not sit under a data-dependent cond)
            rblk = np.arange(n) // m
            pairs = np.argwhere(reach)                      # (dst, src) rows
            doff = (rblk[pairs[:, 0]] - rblk[pairs[:, 1]]) % S
            self._offsets = tuple(int(d) for d in sorted(set(doff.tolist()))
                                  if d != 0)
            # src_to_buf[p, s]: row of shard p's concatenated exchange
            # buffer holding global sender s's model (own block first, then
            # one m-row block per offset). Senders in no exchanged block get
            # the sentinel last row — gathered only for invalid work items,
            # whose weight is zeroed.
            n_blocks = 1 + len(self._offsets)
            src_to_buf = np.full((S, n), n_blocks * m - 1, np.int32)
            for p in range(S):
                src_to_buf[p, p * m:(p + 1) * m] = np.arange(m)
                for j, d in enumerate(self._offsets):
                    q = (p - d) % S
                    src_to_buf[p, q * m:(q + 1) * m] = \
                        (1 + j) * m + np.arange(m)
            shard_index = np.arange(S, dtype=np.int32)
            # receiver-driven arrival scheduling: slot k of dst holds its
            # k-th in-ball sender, so arrivals are a pure gather over the
            # replicated trains vector — no cross-shard scatter needed
            slot_delay = np.take_along_axis(delay, slot_srcs[0], axis=1)
            slot_valid = np.take_along_axis(reach, slot_srcs[0], axis=1)
            self.shards = S
            from repro.launch import mesh as mesh_lib
            self._mesh = mesh_lib.make_fed_mesh(S, 1, 1)
            compat.check_partial_auto_shard_map(self._mesh, {"fed"})

        # distinct attack instances (union over the batch) each run one
        # masked vmap over ALL nodes; the per-member (G, N) masks select
        # which nodes actually broadcast the poisoned model, and the
        # per-member (G,) fold constants key each member's OWN single-run
        # PRNG stream (group 0 of a single-gaussian spec replays the
        # legacy hard-coded poison stream bit-for-bit)
        if batched:
            union = spec.attack_union()
            self._attack_instances = tuple(a for a, _, _ in union)
            amask = (np.stack([m for _, m, _ in union], axis=1) if union
                     else np.zeros((len(specs), 0, n), np.bool_))  # (B, G, N)
            afold = (np.stack([f for _, _, f in union], axis=1) if union
                     else np.zeros((len(specs), 0), np.int32))     # (B, G)
            gids = [np.flatnonzero(amask[:, g, :].any(axis=0))
                    for g in range(amask.shape[1])]
        else:
            groups = spec.attack_groups()
            self._attack_instances = tuple(a for a, _ in groups)
            amask = (np.stack([m for _, m in groups]) if groups
                     else np.zeros((0, n), np.bool_))              # (G, N)
            afold = np.asarray([attacks_lib.attack_fold(gi)
                                for gi in range(len(groups))], np.int32)
            gids = [np.flatnonzero(amask[g]) for g in range(amask.shape[0])]
        # static per-group attacker ids (union over the batch): poison
        # sampling + the attack vmap run over these ids only — at N=2048
        # with a few attackers, running them over all N nodes multiplies
        # the per-tick cost several-fold
        self._attack_ids = tuple(np.asarray(i, np.int32) for i in gids)
        # sharded: each shard runs the attack vmap over its LOCAL attacker
        # ids (global id - shard start), padded to the max count over shards
        # with the out-of-range sentinel m (scatters drop it, masks zero it)
        attack_lids = None
        if cfg.delivery == "sharded":
            S, m = self.shards, n // self.shards
            tables = []
            for ids in self._attack_ids:
                per = [ids[(ids >= p * m) & (ids < (p + 1) * m)] - p * m
                       for p in range(S)]
                amax = max(1, max(len(x) for x in per))
                tab = np.full((S, amax), m, np.int32)
                for p, x in enumerate(per):
                    tab[p, :len(x)] = x
                tables.append(tab)
            attack_lids = tuple(tables)

        mals, strags, countdowns, use_countdowns = [], [], [], []
        for s in specs:
            mal = np.zeros((n,), np.bool_)
            mal[list(s.malicious)] = True
            mals.append(mal)
            strag = np.ones((n,), np.int32)
            for k, v in s.straggler_map().items():
                strag[k] = v
            strags.append(strag)
            use_countdowns.append(s.initial_countdown is not None)
            countdowns.append(
                np.zeros((n,), np.int32) if s.initial_countdown is None
                else np.asarray(s.initial_countdown, np.int32))

        def _stack(arrs):
            return jnp.asarray(np.stack(arrs) if batched else arrs[0])

        # the per-member constants the scan closes over — leaves gain a
        # leading batch axis in batched mode and run() vmaps over them
        consts = {
            "alive": _stack(alives),
            "malicious": _stack(mals),
            "straggler": _stack(strags),
            "countdown": _stack(countdowns),
            "use_countdown": _stack([np.asarray(u) for u in use_countdowns]),
            "attack_mask": jnp.asarray(amask),
            "attack_fold": jnp.asarray(afold),
        }
        if cfg.delivery == "compact":
            consts["slot_src"] = _stack(slot_srcs)
            consts["inv_dst"] = _stack(inv_dsts)
            consts["inv_slot"] = _stack(inv_slots)
            consts["inv_delay"] = _stack(inv_delays)
        elif cfg.delivery == "sparse":
            consts["slot_src"] = _stack(slot_srcs)
            consts["reach"] = _stack(reaches)
            consts["delay"] = _stack(delays)
        elif cfg.delivery == "sharded":
            consts["slot_src"] = _stack(slot_srcs)
            consts["slot_delay"] = jnp.asarray(slot_delay)
            consts["slot_valid"] = jnp.asarray(slot_valid)
            consts["src_to_buf"] = jnp.asarray(src_to_buf)
            consts["shard_index"] = jnp.asarray(shard_index)
            consts["attack_lids"] = tuple(
                jnp.asarray(t) for t in attack_lids)
        else:
            consts["reach"] = _stack(reaches)
            consts["delay"] = _stack(delays)
        # dynamic membership: expand the schedule to dense per-tick masks
        # once, host-side; the scan indexes them by tick. The consts stay
        # ABSENT without membership so churn-free simulators keep their
        # argument pytrees (and their cached compiled scans) unchanged.
        self._has_membership = any(s.membership is not None for s in specs)
        if self._has_membership:
            alive_ts, rejoin_ts, decays = [], [], []
            for s, alv in zip(specs, alives, strict=True):
                if s.membership is None:
                    alive_ts.append(np.tile(alv, (cfg.ticks, 1)))
                    rejoin_ts.append(np.zeros((cfg.ticks, n), np.bool_))
                    decays.append(np.float32(1.0))
                else:
                    a_t, r_t = s.membership.timeline(n, cfg.ticks,
                                                     dead=s.dead)
                    alive_ts.append(a_t)
                    rejoin_ts.append(r_t)
                    decays.append(np.float32(s.membership.rejoin_decay))
            consts["alive_t"] = _stack(alive_ts)
            consts["rejoin_t"] = _stack(rejoin_ts)
            consts["rejoin_decay"] = _stack(decays)
        self._consts = consts

        self._train_fn = _normalize_train_fn(
            train_fn, has_train_data=train_data is not None)
        self._eval_fn = eval_fn
        self._test_fn = test_fn
        self._eval_data = eval_data
        self._train_data = train_data

        # key on the ORIGINAL train_fn: _normalize_train_fn may return a
        # fresh wrapper per construction, which would defeat sharing
        self._trace_key = (
            _fn_key(train_fn), _fn_key(eval_fn), _fn_key(test_fn),
            train_data is not None, cfg, rep_impl, n, batched,
            self._attack_instances,
            tuple(tuple(ids.tolist()) for ids in self._attack_ids),
            self.delivery_budget, self.compact_budget,
            self._has_membership, self.shards, self._offsets,
            self.shard_budget)
        cached = _SCAN_CACHE.get(self._trace_key)
        if cached is None:
            if batched:
                def dispatch(params0, keys, consts, eval_data, train_data):
                    return jax.vmap(
                        self._scan, in_axes=(None, 0, 0, None, None))(
                            params0, keys, consts, eval_data, train_data)
            elif cfg.delivery == "sharded":
                dispatch = self._scan_sharded
            else:
                dispatch = self._scan
            counted = tracecheck.count_traces(
                dispatch, name=f"simlax._scan#{len(_SCAN_CACHE)}")
            cached = (jax.jit(counted), counted.counter,
                      (train_fn, eval_fn, test_fn, self))
            _SCAN_CACHE[self._trace_key] = cached
        self._jit_scan = cached[0]
        #: tracecheck.TraceCounter for this config's compiled scan — two
        #: same-shape run() calls must leave it at 1 (tests/test_tracecheck
        #: and tools/hlo_audit.py gate on it)
        self.trace_counter = cached[1]

    # ------------------------------------------------------------------ pieces
    def _interval(self, key):
        lo, hi = self.cfg.train_interval
        base = (jnp.full(key.shape[:-1] or (), lo, jnp.int32) if lo == hi
                else jax.random.randint(key, (), lo, hi + 1, jnp.int32))
        return base

    # ------------------------------------------------------------- delivery
    def _deliver_dense(self, state, due, eval_data):
        """Oracle: eval ALL N² (dst, src) pairs, mask by dueness."""
        # accs[dst, src] = eval of src's in-flight model on dst's data
        accs = jax.vmap(
            lambda d: jax.vmap(lambda s: self._eval_fn(s, d))(state["sent"])
        )(eval_data)                                     # (dst, src)
        accs = jnp.where(due, accs, 0.0)
        w = state["rep"] * accs * due                    # Eq. 2 per pair
        acc_sum = jax.tree.map(
            lambda a, s: a + jnp.einsum(
                "ds,s...->d...", w, s.astype(jnp.float32)),
            state["acc_sum"], state["sent"])
        w_sum = state["w_sum"] + w.sum(axis=1)
        buf_cnt = state["buf_cnt"] + due.sum(axis=1).astype(jnp.int32)
        # running (min acc, argmin sender) for the punishment
        masked = jnp.where(due, accs, jnp.inf)           # (dst, src)
        batch_min = masked.min(axis=1)
        batch_sender = masked.argmin(axis=1).astype(jnp.int32)
        return acc_sum, w_sum, buf_cnt, batch_min, batch_sender

    def _deliver_sparse(self, state, due, eval_data, slot_src):
        """Budgeted: gather the (N, budget) static ball slots, eval only
        those via one nested vmap, scatter weights/min back."""
        # slot_src: this member's (dst, slot) static ball map
        slot_ok = jnp.take_along_axis(due, slot_src, axis=1)
        # gather the in-ball models once: leaves (N, B, ...)
        gathered = jax.tree.map(lambda s: s[slot_src], state["sent"])
        accs = jax.vmap(
            lambda models, d: jax.vmap(
                lambda m: self._eval_fn(m, d))(models)
        )(gathered, eval_data)                           # (dst, slot)
        accs = jnp.where(slot_ok, accs, 0.0)
        rep_slot = jnp.take_along_axis(state["rep"], slot_src, axis=1)
        w = rep_slot * accs * slot_ok                    # Eq. 2 per slot
        acc_sum = jax.tree.map(
            lambda a, g: a + jnp.einsum(
                "nb,nb...->n...", w, g.astype(jnp.float32)),
            state["acc_sum"], gathered)
        w_sum = state["w_sum"] + w.sum(axis=1)
        buf_cnt = state["buf_cnt"] + slot_ok.sum(axis=1).astype(jnp.int32)
        masked = jnp.where(slot_ok, accs, jnp.inf)       # (dst, slot)
        batch_min = masked.min(axis=1)
        arg_slot = masked.argmin(axis=1)
        batch_sender = jnp.take_along_axis(
            slot_src, arg_slot[:, None], axis=1)[:, 0]
        return acc_sum, w_sum, buf_cnt, batch_min, batch_sender

    def _deliver_compact(self, state, slot_ok, eval_data, slot_src):
        """Segment-compacted: gather the tick's due (receiver, slot) pairs
        into a static (W,) work buffer, eval only those items via ONE flat
        vmap, segment-scatter weights / running-min back per receiver.
        ``slot_ok`` is the (N, budget) slot-layout dueness (the compact
        arrive state IS slot-indexed, so no per-tick re-mapping);
        ``slot_src`` the member's (dst, slot) static ball map."""
        n, budget = slot_ok.shape[0], self.delivery_budget
        flat_ok = slot_ok.ravel()                        # (n * budget,)
        # due (receiver, slot) indices compacted to the buffer front; the
        # fill value marks unused items (gathers clamp, scatters drop).
        # Ascending index order keeps receivers' items grouped (segments)
        # and slots in ascending-src order inside each segment.
        flat_idx = jnp.nonzero(flat_ok, size=self.compact_budget,
                               fill_value=n * budget)[0]
        valid = flat_idx < n * budget
        rcv = jnp.minimum(flat_idx // budget, n - 1)     # clamped for gathers
        src = slot_src[rcv, flat_idx % budget]           # (W,)
        models = jax.tree.map(lambda s: s[src], state["sent"])   # (W, ...)
        ed = jax.tree.map(lambda e: e[rcv], eval_data)
        accs = jax.vmap(self._eval_fn)(models, ed)       # (W,)
        w_item = jnp.where(valid, state["rep"][rcv, src] * accs, 0.0)
        scat = jnp.where(valid, rcv, n)                  # n == dropped row
        acc_sum = jax.tree.map(
            lambda a, m: a.at[scat].add(
                w_item.reshape((-1,) + (1,) * (a.ndim - 1))
                * m.astype(jnp.float32), mode="drop"),
            state["acc_sum"], models)
        w_sum = state["w_sum"].at[scat].add(w_item, mode="drop")
        buf_cnt = state["buf_cnt"].at[scat].add(1, mode="drop")
        masked = jnp.where(valid, accs, jnp.inf)
        batch_min = jnp.full((n,), jnp.inf, jnp.float32).at[scat].min(
            masked, mode="drop")
        # lowest-src tie-break, matching the dense argmin: among the items
        # hitting the receiver's min, scatter-min the sender index
        tie = valid & (accs == batch_min[rcv])
        batch_sender = jnp.full((n,), n, jnp.int32).at[scat].min(
            jnp.where(tie, src, n), mode="drop")
        batch_sender = jnp.where(batch_sender == n, 0, batch_sender)
        return acc_sum, w_sum, buf_cnt, batch_min, batch_sender

    def _deliver_sharded(self, state, slot_ok, eval_data, slot_src, buf,
                         row_of_src):
        """The compact engine's flat work buffer, per shard: compact this
        shard's due (local-receiver, slot) pairs into a static
        (shard_budget,) buffer, eval via one flat vmap, segment-scatter
        back. Senders' models are gathered from ``buf``, the concatenated
        ppermute exchange blocks, through ``row_of_src`` (global sender id
        -> local buffer row). All shapes are shard-local (m receivers);
        sender ids stay GLOBAL (rep columns, min_sender)."""
        n = self.topology.num_nodes
        m, budget = slot_ok.shape[0], self.delivery_budget
        flat_ok = slot_ok.ravel()                        # (m * budget,)
        flat_idx = jnp.nonzero(flat_ok, size=self.shard_budget,
                               fill_value=m * budget)[0]
        valid = flat_idx < m * budget
        rcv = jnp.minimum(flat_idx // budget, m - 1)     # local receiver row
        src = slot_src[rcv, flat_idx % budget]           # (W,) global sender
        buf_rows = jax.tree.leaves(buf)[0].shape[0]
        row = jnp.minimum(row_of_src[src], buf_rows - 1)
        models = jax.tree.map(lambda b: b[row], buf)     # (W, ...)
        ed = jax.tree.map(lambda e: e[rcv], eval_data)
        accs = jax.vmap(self._eval_fn)(models, ed)       # (W,)
        w_item = jnp.where(valid, state["rep"][rcv, src] * accs, 0.0)
        scat = jnp.where(valid, rcv, m)                  # m == dropped row
        acc_sum = jax.tree.map(
            lambda a, mo: a.at[scat].add(
                w_item.reshape((-1,) + (1,) * (a.ndim - 1))
                * mo.astype(jnp.float32), mode="drop"),
            state["acc_sum"], models)
        w_sum = state["w_sum"].at[scat].add(w_item, mode="drop")
        buf_cnt = state["buf_cnt"].at[scat].add(1, mode="drop")
        masked = jnp.where(valid, accs, jnp.inf)
        batch_min = jnp.full((m,), jnp.inf, jnp.float32).at[scat].min(
            masked, mode="drop")
        tie = valid & (accs == batch_min[rcv])
        batch_sender = jnp.full((m,), n, jnp.int32).at[scat].min(
            jnp.where(tie, src, n), mode="drop")
        batch_sender = jnp.where(batch_sender == n, 0, batch_sender)
        return acc_sum, w_sum, buf_cnt, batch_min, batch_sender

    # -------------------------------------------------------------------- scan
    def _scan(self, params0, key0, consts, eval_data, train_data):
        """One member's full tick loop as a single ``lax.scan``. The
        per-member constants arrive via ``consts`` (leaves WITHOUT a batch
        axis); ``key0`` is the member's base PRNG key; ``eval_data`` /
        ``train_data`` are jit arguments rather than closure constants so
        the compiled scan is shared across simulators with identical
        static config (see ``_SCAN_CACHE``). Batched runs vmap this method
        over the stacked constants/keys, single runs call it directly —
        one body serves both, so the heap-parity pins validate the exact
        code the batch executes. Returns the raw scan output
        ``(final_state_dict, (ticks, N) per-tick accuracy rows)``."""
        cfg = self.cfg
        n = self.topology.num_nodes
        rep_impl = self.rep_impl
        alive = consts["alive"]
        malicious, straggler = consts["malicious"], consts["straggler"]
        attack_instances = self._attack_instances
        train_v = jax.vmap(self._train_fn,
                           in_axes=(0, 0, None if train_data is None else 0))
        test_v = jax.vmap(self._test_fn)
        compact = cfg.delivery == "compact"
        if compact:
            def deliver(s, due):
                return self._deliver_compact(s, due, eval_data,
                                             consts["slot_src"])
        elif cfg.delivery == "sparse":
            def deliver(s, due):
                return self._deliver_sparse(s, due, eval_data,
                                            consts["slot_src"])
        else:
            def deliver(s, due):
                return self._deliver_dense(s, due, eval_data)

        zeros_like_params = jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params0)
        # compact keeps the in-flight state in (N, budget) receiver slots
        # (broadcast scatters through the static inverse map); the oracles
        # carry the full (N, N) matrix
        arrive_shape = (n, self.delivery_budget) if compact else (n, n)
        # heap parity: the FIRST countdown is not straggler-scaled; members
        # with an explicit countdown sheet select it over the seeded draw
        drawn = jax.vmap(self._interval)(
            jax.random.split(jax.random.fold_in(key0, 12345), n))
        init = dict(
            params=params0,
            sent=jax.tree.map(jnp.zeros_like, params0),
            arrive=jnp.full(arrive_shape, _NEVER, jnp.int32),
            rep=jnp.full((n, n), rep_impl.initial, jnp.float32),
            acc_sum=zeros_like_params,
            w_sum=jnp.zeros((n,), jnp.float32),
            buf_cnt=jnp.zeros((n,), jnp.int32),
            min_acc=jnp.full((n,), jnp.inf, jnp.float32),
            min_sender=jnp.zeros((n,), jnp.int32),
            next_train=jnp.where(consts["use_countdown"],
                                 consts["countdown"], drawn),
            broadcasts=jnp.zeros((n,), jnp.int32),
            deliveries=jnp.zeros((), jnp.int32),
            max_due=jnp.zeros((), jnp.int32),
            fedavg_rounds=jnp.zeros((), jnp.int32),
        )

        has_membership = self._has_membership

        def body(state, t):
            key_t = jax.random.fold_in(key0, t)

            # ---- 0. membership: events apply at the TOP of the tick.
            # a_t masks this tick's participants; rejoiners get every
            # peer's reputation COLUMN decayed before any delivery uses it
            # (attacks.MembershipSchedule — without churn a_t is the static
            # alive mask and the branch is compiled out).
            if has_membership:
                a_t = consts["alive_t"][t]
                rej = consts["rejoin_t"][t]
                decayed = jnp.clip(
                    state["rep"] * consts["rejoin_decay"],
                    rep_impl.floor, rep_impl.initial)
                state = dict(state,
                             rep=jnp.where(rej[None, :], decayed,
                                           state["rep"]))
            else:
                a_t = alive

            # ---- 1. deliveries: models whose tick counter hits t.
            # On a no-delivery tick every update below is a no-op, so the
            # (model-forward-pass-heavy) eval work is skipped entirely via
            # cond — most ticks between broadcast waves cost nothing. (In a
            # vmapped batch the cond becomes a select over per-member
            # predicates: every member pays the eval on ticks where ANY
            # member delivers — the batch amortizes dispatch, not work.)
            # due is (dst, src) for the oracles, (dst, slot) for compact.
            # An arrival at an offline receiver EXPIRES without delivering
            # (the model in flight is lost, matching the heap engine's
            # duplicate-dropping first-arrival flood).
            expired = state["arrive"] == t
            due = expired & a_t[:, None]
            acc_sum, w_sum, buf_cnt, batch_min, batch_sender = jax.lax.cond(
                due.any(),
                lambda s: deliver(s, due),
                lambda s: (s["acc_sum"], s["w_sum"], s["buf_cnt"],
                           jnp.full((n,), jnp.inf, jnp.float32),
                           jnp.zeros((n,), jnp.int32)),
                state)
            better = batch_min < state["min_acc"]
            min_acc = jnp.where(better, batch_min, state["min_acc"])
            min_sender = jnp.where(better, batch_sender,
                                   state["min_sender"])
            arrive = jnp.where(expired, _NEVER, state["arrive"])

            # ---- 2. weighted FedAvg (Eq. 3) where the buffer filled up
            fire = buf_cnt >= rep_impl.buffer_size           # (N,)
            safe = w_sum > _EPS
            apply = fire & safe

            def leaf(acc, p):
                avg = acc / jnp.maximum(w_sum, _EPS).reshape(
                    (-1,) + (1,) * (acc.ndim - 1))
                out = 0.5 * (avg + p.astype(jnp.float32))
                keep = apply.reshape((-1,) + (1,) * (acc.ndim - 1))
                return jnp.where(keep, out, p.astype(jnp.float32)).astype(
                    p.dtype)

            params = jax.tree.map(leaf, acc_sum, state["params"])
            # punish the worst sender of each fired buffer (§IV-D1): only
            # the (receiver, worst-sender) entries can move — all others
            # already sit inside [floor, initial] — so update those O(N)
            # entries in place instead of building an (N, N) penalty
            # matrix and re-clipping the whole reputation state every tick
            rows_n = jnp.arange(n)
            hit = fire & (min_acc < jnp.inf)
            cur = state["rep"][rows_n, min_sender]
            rep = state["rep"].at[rows_n, min_sender].set(
                jnp.where(hit,
                          jnp.clip(cur - rep_impl.penalty, rep_impl.floor,
                                   rep_impl.initial),
                          cur))
            # reset consumed buffers
            keep1 = ~fire
            acc_sum = jax.tree.map(
                lambda a: a * keep1.reshape((-1,) + (1,) * (a.ndim - 1)),
                acc_sum)
            w_sum = w_sum * keep1
            buf_cnt = buf_cnt * keep1
            min_acc = jnp.where(fire, jnp.inf, min_acc)
            min_sender = jnp.where(fire, 0, min_sender)

            # ---- 3. train + broadcast where the countdown expired
            # (cond-gated like delivery: the vmapped train step + poison
            # sampling only run on ticks where some countdown expired).
            # Offline nodes' countdowns FREEZE (they resume where they left
            # off, matching the heap engine's skip); without membership the
            # decrement stays the unconditional -1 of the static mask.
            next_train = state["next_train"] - (
                a_t.astype(jnp.int32) if has_membership else 1)
            trains = (next_train <= 0) & a_t                  # (N,)

            def do_train(operand):
                committed, sent = operand
                tkeys = jax.random.split(jax.random.fold_in(key_t, 0), n)
                trained = train_v(committed, tkeys, train_data)
                # attackers never COMMIT local training; their honestly
                # trained candidate is still handed to the attack below
                params = jax.tree.map(
                    lambda new, old: jnp.where(
                        (trains & ~malicious).reshape(
                            (-1,) + (1,) * (new.ndim - 1)),
                        new, old),
                    trained, committed)
                outgoing = trained
                for g, attack in enumerate(attack_instances):
                    # fold constants: 0 = train keys, the member's
                    # consts["attack_fold"][g] per attack, 2 = the interval
                    # draw below; the heap DFLNode draws from the SAME
                    # stream (FederationSpec.attack_key_fns), making
                    # randomized-attack parity bitwise. The attack runs
                    # over the group's STATIC attacker ids (union over the
                    # batch) and the member's mask selects within — keys
                    # are gathered from the same n-way split, so per-node
                    # keys/inputs match the legacy gathered form
                    # bit-for-bit, while the mask/fold arrays let one
                    # traced body serve a whole batch of heterogeneous
                    # adversary sheets.
                    ids = self._attack_ids[g]
                    akeys = jax.random.split(
                        jax.random.fold_in(key_t,
                                           consts["attack_fold"][g]),
                        n)[ids]
                    bad = jax.vmap(
                        lambda k, tr, cm, a=attack: a.apply(k, tr, cm, t)
                    )(akeys,
                      jax.tree.map(lambda x: x[ids], trained),
                      jax.tree.map(lambda x: x[ids], committed))
                    mask = consts["attack_mask"][g][ids]
                    outgoing = jax.tree.map(
                        lambda o, b, m=mask: o.at[ids].set(
                            jnp.where(
                                m.reshape((-1,) + (1,) * (o.ndim - 1)),
                                b.astype(o.dtype), o[ids])),
                        outgoing, bad)
                if cfg.compress == "int8":
                    # wire model: the sender quantizes its (post-attack)
                    # broadcast ONCE; every receiver sees the identical
                    # reconstruction. quantize_last_axis blocks only the
                    # last axis, so this stacked round-trip is bitwise the
                    # per-node one — the heap DFLNode applies the same
                    # calls per node and stays event-stream comparable.
                    outgoing = compression.roundtrip_tree(outgoing)
                sent = jax.tree.map(
                    lambda s, o: jnp.where(
                        trains.reshape((-1,) + (1,) * (s.ndim - 1)), o, s),
                    sent, outgoing)
                return params, sent

            params, sent = jax.lax.cond(
                trains.any(), do_train, lambda operand: operand,
                (params, state["sent"]))
            if compact:
                # scatter each training sender's (dst, slot) landing sites;
                # non-training senders target the dropped row n
                tgt = jnp.where(trains[:, None], consts["inv_dst"], n)
                arrive = arrive.at[tgt.ravel(),
                                   consts["inv_slot"].ravel()].set(
                    (t + consts["inv_delay"]).ravel(), mode="drop")
            else:
                sched = trains[None, :] & consts["reach"]     # (dst, src)
                arrive = jnp.where(sched, t + consts["delay"], arrive)
            ikeys = jax.random.split(jax.random.fold_in(key_t, 2), n)
            fresh = jax.vmap(self._interval)(ikeys) * straggler
            next_train = jnp.where(trains, fresh, next_train)

            new_state = dict(
                params=params, sent=sent, arrive=arrive, rep=rep,
                acc_sum=acc_sum, w_sum=w_sum, buf_cnt=buf_cnt,
                min_acc=min_acc, min_sender=min_sender,
                next_train=next_train,
                broadcasts=state["broadcasts"] + trains.astype(jnp.int32),
                deliveries=state["deliveries"] + due.sum(),
                max_due=jnp.maximum(state["max_due"], due.sum()),
                fedavg_rounds=state["fedavg_rounds"] + apply.sum(),
            )
            # the global test eval can dominate at scale: only run it on
            # record ticks (the non-record rows are dropped anyway)
            acc_row = jax.lax.cond(
                t % cfg.record_every == 0,
                lambda p: test_v(p).astype(jnp.float32),
                lambda p: jnp.zeros((n,), jnp.float32),
                params)
            return new_state, acc_row

        return jax.lax.scan(
            body, init, jnp.arange(cfg.ticks, dtype=jnp.int32))

    # ------------------------------------------------------------ sharded scan
    def _scan_sharded(self, params0, key0, consts, eval_data, train_data):
        """The compact tick loop partitioned over the ``fed`` mesh axis via
        shard_map: each of S devices scans an m = N/S receiver block of the
        state (params/sent/arrive/rep rows, eval/train data), and every tick
        opens with one ``lax.ppermute`` per occupied shard offset moving the
        ``sent`` blocks neighbors need — the identical collective schedule
        shape the production gossip round lowers to. Cross-shard coupling is
        ONLY that exchange plus the replicated train-countdown vector: the
        countdown/interval PRNG draws are recomputed identically on every
        shard (``jax.random.split(key, n)`` row i depends only on i and the
        key), so broadcast schedules agree without any collective. On one
        device (S=1) the offsets are empty and this degrades to exactly the
        compact engine minus its inverse-map scatter. Parity with compact is
        bitwise (tests/test_sharded.py); docs/SCALING.md has the design."""
        cfg = self.cfg
        n = self.topology.num_nodes
        S = self.shards
        m = n // S
        offsets = self._offsets
        rep_impl = self.rep_impl
        has_membership = self._has_membership
        attack_instances = self._attack_instances
        train_v = jax.vmap(self._train_fn,
                           in_axes=(0, 0, None if train_data is None else 0))
        test_v = jax.vmap(self._test_fn)
        fed = P("fed")
        # replicated consts (full-N role vectors + attack tables) vs
        # fed-sharded layout tables (leading axis N or S)
        sharded_keys = {"slot_src", "slot_delay", "slot_valid",
                        "src_to_buf", "shard_index", "attack_lids"}
        const_specs = {k: (fed if k in sharded_keys else P())
                       for k in consts}

        def inner(params0, key0, consts, eval_data, train_data):
            start = consts["shard_index"][0] * m       # this shard's row 0
            row_of_src = consts["src_to_buf"][0]       # (n,) global -> buf

            def loc(x):
                return jax.lax.dynamic_slice_in_dim(x, start, m, axis=0)

            zeros_like_params = jax.tree.map(
                lambda x: jnp.zeros(x.shape, jnp.float32), params0)
            # next_train stays FULL-N and replicated: arrival scheduling
            # gathers trains at global sender ids, and every shard's
            # identical PRNG recomputation keeps it consistent for free
            drawn = jax.vmap(self._interval)(
                jax.random.split(jax.random.fold_in(key0, 12345), n))
            init = dict(
                params=params0,
                sent=jax.tree.map(jnp.zeros_like, params0),
                arrive=jnp.full((m, self.delivery_budget), _NEVER,
                                jnp.int32),
                rep=jnp.full((m, n), rep_impl.initial, jnp.float32),
                acc_sum=zeros_like_params,
                w_sum=jnp.zeros((m,), jnp.float32),
                buf_cnt=jnp.zeros((m,), jnp.int32),
                min_acc=jnp.full((m,), jnp.inf, jnp.float32),
                min_sender=jnp.zeros((m,), jnp.int32),
                next_train=jnp.where(consts["use_countdown"],
                                     consts["countdown"], drawn),
                broadcasts=jnp.zeros((m,), jnp.int32),
                deliveries=jnp.zeros((), jnp.int32),
                max_due=jnp.zeros((), jnp.int32),
                fedavg_rounds=jnp.zeros((), jnp.int32),
            )

            def body(state, t):
                key_t = jax.random.fold_in(key0, t)

                # ---- 0. membership (rep columns are global-N)
                if has_membership:
                    a_t_full = consts["alive_t"][t]
                    rej = consts["rejoin_t"][t]
                    decayed = jnp.clip(
                        state["rep"] * consts["rejoin_decay"],
                        rep_impl.floor, rep_impl.initial)
                    state = dict(state,
                                 rep=jnp.where(rej[None, :], decayed,
                                               state["rep"]))
                else:
                    a_t_full = consts["alive"]
                a_loc = loc(a_t_full)

                # ---- neighbor exchange: collectives run UNCONDITIONALLY
                # (outside the delivery cond) so every shard issues the
                # same static ppermute schedule every tick
                blocks = [state["sent"]]
                for d in offsets:
                    perm = [(q, (q + d) % S) for q in range(S)]
                    blocks.append(tree_ppermute(state["sent"], "fed", perm))
                buf = jax.tree.map(
                    lambda *bs: jnp.concatenate(bs, axis=0), *blocks)

                # ---- 1. deliveries (local receiver rows)
                expired = state["arrive"] == t
                due = expired & a_loc[:, None]
                acc_sum, w_sum, buf_cnt, batch_min, batch_sender = \
                    jax.lax.cond(
                        due.any(),
                        lambda s: self._deliver_sharded(
                            s, due, eval_data, consts["slot_src"], buf,
                            row_of_src),
                        lambda s: (s["acc_sum"], s["w_sum"], s["buf_cnt"],
                                   jnp.full((m,), jnp.inf, jnp.float32),
                                   jnp.zeros((m,), jnp.int32)),
                        state)
                better = batch_min < state["min_acc"]
                min_acc = jnp.where(better, batch_min, state["min_acc"])
                min_sender = jnp.where(better, batch_sender,
                                       state["min_sender"])
                arrive = jnp.where(expired, _NEVER, state["arrive"])

                # ---- 2. weighted FedAvg + punishment (local rows)
                fire = buf_cnt >= rep_impl.buffer_size       # (m,)
                safe = w_sum > _EPS
                apply = fire & safe

                def leaf(acc, p):
                    avg = acc / jnp.maximum(w_sum, _EPS).reshape(
                        (-1,) + (1,) * (acc.ndim - 1))
                    out = 0.5 * (avg + p.astype(jnp.float32))
                    keep = apply.reshape((-1,) + (1,) * (acc.ndim - 1))
                    return jnp.where(keep, out,
                                     p.astype(jnp.float32)).astype(p.dtype)

                params = jax.tree.map(leaf, acc_sum, state["params"])
                rows_m = jnp.arange(m)
                hit = fire & (min_acc < jnp.inf)
                cur = state["rep"][rows_m, min_sender]
                rep = state["rep"].at[rows_m, min_sender].set(
                    jnp.where(hit,
                              jnp.clip(cur - rep_impl.penalty,
                                       rep_impl.floor, rep_impl.initial),
                              cur))
                keep1 = ~fire
                acc_sum = jax.tree.map(
                    lambda a: a * keep1.reshape((-1,) + (1,) * (a.ndim - 1)),
                    acc_sum)
                w_sum = w_sum * keep1
                buf_cnt = buf_cnt * keep1
                min_acc = jnp.where(fire, jnp.inf, min_acc)
                min_sender = jnp.where(fire, 0, min_sender)

                # ---- 3. train + broadcast; trains is replicated full-N
                # (so the predicate agrees across shards), the train step
                # runs on local rows only
                next_train = state["next_train"] - (
                    a_t_full.astype(jnp.int32) if has_membership else 1)
                trains = (next_train <= 0) & a_t_full        # (n,)
                trains_loc = loc(trains)

                def do_train(operand):
                    committed, sent = operand
                    tkeys = loc(jax.random.split(
                        jax.random.fold_in(key_t, 0), n))
                    trained = train_v(committed, tkeys, train_data)
                    mal_loc = loc(consts["malicious"])
                    params = jax.tree.map(
                        lambda new, old: jnp.where(
                            (trains_loc & ~mal_loc).reshape(
                                (-1,) + (1,) * (new.ndim - 1)),
                            new, old),
                        trained, committed)
                    outgoing = trained
                    for g, attack in enumerate(attack_instances):
                        # local attacker ids; keys/masks are gathered at
                        # the GLOBAL ids from the same full-n split the
                        # compact engine uses, so poison streams match
                        # bit-for-bit. Sentinel m rows: mask False +
                        # dropped scatter.
                        lids = consts["attack_lids"][g][0]
                        lclamp = jnp.minimum(lids, m - 1)
                        gids = jnp.minimum(start + lids, n - 1)
                        akeys = jax.random.split(
                            jax.random.fold_in(
                                key_t, consts["attack_fold"][g]),
                            n)[gids]
                        bad = jax.vmap(
                            lambda k, tr, cm, a=attack: a.apply(k, tr, cm, t)
                        )(akeys,
                          jax.tree.map(lambda x: x[lclamp], trained),
                          jax.tree.map(lambda x: x[lclamp], committed))
                        mask = (consts["attack_mask"][g][gids]
                                & (lids < m))
                        outgoing = jax.tree.map(
                            lambda o, b, msk=mask, li=lids, lc=lclamp:
                            o.at[li].set(
                                jnp.where(
                                    msk.reshape((-1,) + (1,) * (o.ndim - 1)),
                                    b.astype(o.dtype), o[lc]),
                                mode="drop"),
                            outgoing, bad)
                    if cfg.compress == "int8":
                        outgoing = compression.roundtrip_tree(outgoing)
                    sent = jax.tree.map(
                        lambda s, o: jnp.where(
                            trains_loc.reshape((-1,) + (1,) * (s.ndim - 1)),
                            o, s),
                        sent, outgoing)
                    return params, sent

                params, sent = jax.lax.cond(
                    trains.any(), do_train, lambda operand: operand,
                    (params, state["sent"]))
                # receiver-driven arrivals: slot k of local dst is due
                # t + delay ticks after its (global) sender trains —
                # identical values to the compact inverse-map scatter
                sched = trains[consts["slot_src"]] & consts["slot_valid"]
                arrive = jnp.where(sched, t + consts["slot_delay"], arrive)
                ikeys = jax.random.split(jax.random.fold_in(key_t, 2), n)
                fresh = jax.vmap(self._interval)(ikeys) \
                    * consts["straggler"]
                next_train = jnp.where(trains, fresh, next_train)

                new_state = dict(
                    params=params, sent=sent, arrive=arrive, rep=rep,
                    acc_sum=acc_sum, w_sum=w_sum, buf_cnt=buf_cnt,
                    min_acc=min_acc, min_sender=min_sender,
                    next_train=next_train,
                    broadcasts=state["broadcasts"]
                    + trains_loc.astype(jnp.int32),
                    deliveries=state["deliveries"] + due.sum(),
                    max_due=jnp.maximum(state["max_due"], due.sum()),
                    fedavg_rounds=state["fedavg_rounds"] + apply.sum(),
                )
                acc_row = jax.lax.cond(
                    t % cfg.record_every == 0,
                    lambda p: test_v(p).astype(jnp.float32),
                    lambda p: jnp.zeros((m,), jnp.float32),
                    params)
                return new_state, (acc_row, due.sum().astype(jnp.int32)
                                   .reshape((1,)))

            final, (acc_rows, due_rows) = jax.lax.scan(
                body, init, jnp.arange(cfg.ticks, dtype=jnp.int32))
            out_final = dict(final)
            # every output leaf leaves the shard on axis 0: slice the
            # replicated countdown to local rows, lift the per-shard scalar
            # counters to (1,) so they concatenate to (S,) globally
            out_final["next_train"] = loc(final["next_train"])
            for k in ("deliveries", "max_due", "fedavg_rounds"):
                out_final[k] = final[k][None]
            return {"final": out_final, "acc": acc_rows, "due": due_rows}

        shmapped = compat.shard_map(
            inner, mesh=self._mesh,
            in_specs=(fed, P(), const_specs, fed, fed),
            out_specs={"final": fed, "acc": P(None, "fed"),
                       "due": P(None, "fed")},
            axis_names={"fed"}, check_vma=False)
        return shmapped(params0, key0, consts, eval_data, train_data)

    # --------------------------------------------------------------------- run
    def run(self, params0=None):
        """params0: pytree with leading N dim (defaults to the scenario's
        stacked init; batched runs share it across members). Returns a
        SimLaxResult — or, when constructed from a BatchedFederationSpec,
        a list of B per-member SimLaxResults, member ``b`` bitwise
        identical to the single run of ``specs[b]`` at ``seeds[b]``."""
        if params0 is None:
            if self.scenario is None:
                raise TypeError(
                    "run() needs params0 when constructed without a scenario")
            params0 = self.scenario.init_params_stacked()
        cfg = self.cfg

        if cfg.delivery == "sharded":
            out = self._jit_scan(
                params0, jax.random.PRNGKey(cfg.seed), self._consts,
                self._eval_data, self._train_data)
            final = jax.tree.map(np.asarray, out["final"])
            due_rows = np.asarray(out["due"])            # (ticks, S)
            max_shard_due = final["max_due"]             # (S,) per-shard
            if (max_shard_due > self.shard_budget).any():
                offenders = np.flatnonzero(max_shard_due > self.shard_budget)
                raise RuntimeError(
                    f"sharded delivery overflow: shard "
                    f"{[int(p) for p in offenders]} had "
                    f"{[int(d) for d in max_shard_due[offenders]]} due "
                    f"deliveries on one tick but the per-shard work buffer "
                    f"holds {self.shard_budget} (SimLaxConfig.compact_budget "
                    "override; the exact per-shard "
                    "topology.compaction_budget bound cannot overflow)")
            # global counters from the per-shard columns
            merged = dict(final)
            merged["deliveries"] = final["deliveries"].sum()
            merged["fedavg_rounds"] = final["fedavg_rounds"].sum()
            merged["max_due"] = (due_rows.sum(axis=1).max()
                                 if due_rows.size else 0)
            return self._package(
                merged, np.asarray(out["acc"]), self._slot_src_np,
                {"shards": self.shards, "shard_budget": self.shard_budget,
                 "max_shard_deliveries": int(max_shard_due.max())})

        if not self._batched:
            final, acc_by_tick = self._jit_scan(
                params0, jax.random.PRNGKey(cfg.seed), self._consts,
                self._eval_data, self._train_data)
            final = jax.tree.map(np.asarray, final)
            max_due = int(final["max_due"])
            if cfg.delivery == "compact" and max_due > self.compact_budget:
                # only reachable with a cfg.compact_budget override below
                # the exact topology.compaction_budget bound: fail fast
                # rather than return results whose overflowing ticks
                # dropped receipts
                raise RuntimeError(
                    f"compact delivery overflow: a tick had {max_due} due "
                    f"deliveries but the work buffer holds "
                    f"{self.compact_budget} (SimLaxConfig.compact_budget "
                    f"override; the exact topology.compaction_budget bound "
                    "for this topology/ttl/interval cannot overflow)")
            return self._package(final, np.asarray(acc_by_tick),
                                 self._slot_src_np, {})

        seeds = self.spec.resolved_seeds(cfg.seed)
        keys = jnp.stack([jax.random.PRNGKey(s) for s in seeds])
        final, acc_by_tick = self._jit_scan(
            params0, keys, self._consts, self._eval_data, self._train_data)
        final = jax.tree.map(np.asarray, final)
        acc_np = np.asarray(acc_by_tick)
        max_due = final["max_due"]                           # (B,)
        if cfg.delivery == "compact" \
                and (max_due > self.compact_budget).any():
            offenders = np.flatnonzero(max_due > self.compact_budget)
            raise RuntimeError(
                "compact delivery overflow in batched run: federation "
                f"{[int(b) for b in offenders]} of the batch (size "
                f"{self.batch_size}) had "
                f"{[int(m) for m in max_due[offenders]]} due deliveries on "
                f"one tick but the shared work buffer holds "
                f"{self.compact_budget} (SimLaxConfig.compact_budget "
                "override below the batch's max exact "
                "topology.compaction_budget bound)")
        out = []
        for b in range(self.batch_size):
            out.append(self._package(
                jax.tree.map(lambda x, _b=b: x[_b], final), acc_np[b],
                self._slot_src_np[b],
                {"federation_index": b, "batch_size": self.batch_size,
                 "seed": int(seeds[b])}))
        return out

    def lower_scan(self, params0=None):
        """Lower (never execute) this simulator's cached jitted scan and
        return the ``jax.stages.Lowered`` object. ``tools/hlo_audit.py``
        compiles it to assert structural invariants of the tick loop (no
        f64, quantization confined to the scan body, while trip count ==
        cfg.ticks). NOTE: lowering traces, so it bumps ``trace_counter``."""
        if params0 is None:
            if self.scenario is None:
                raise TypeError(
                    "lower_scan() needs params0 when constructed without "
                    "a scenario")
            params0 = self.scenario.init_params_stacked()
        if self._batched:
            keys = jnp.stack([
                jax.random.PRNGKey(s)
                for s in self.spec.resolved_seeds(self.cfg.seed)])
        else:
            keys = jax.random.PRNGKey(self.cfg.seed)
        return self._jit_scan.lower(
            params0, keys, self._consts, self._eval_data, self._train_data)

    def _package(self, final, acc_by_tick, slot_src, extra_stats):
        """Numpy-side result assembly for one member: expand the compact
        slot state back to the (N, N) oracle layout, slice the recorded
        accuracy rows, fold the scan counters into the stats dict."""
        cfg = self.cfg
        n = self.topology.num_nodes
        rec = np.arange(0, cfg.ticks, cfg.record_every)
        final_arrive = np.asarray(final["arrive"])
        if cfg.delivery in ("compact", "sharded"):
            # expand the (N, budget) slot state back to the (N, N) matrix
            # the oracles carry, so final-state parity is one comparison
            dense_arrive = np.full((n, n), _NEVER, np.int32)
            dense_arrive[np.arange(n)[:, None],
                         np.asarray(slot_src)] = final_arrive
            final_arrive = dense_arrive
        # dtype-derived wire model: one broadcast's bytes under the
        # configured compression (per-node payload = the (N, ...) sent tree
        # minus its leading axis); each delivery moves one copy
        broadcast_bytes = compression.payload_bytes(
            jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                final["sent"]),
            cfg.compress)
        return SimLaxResult(
            params=jax.tree.map(np.asarray, final["params"]),
            reputation=np.asarray(final["rep"]),
            acc_history=np.asarray(acc_by_tick)[rec],
            record_ticks=rec,
            stats={
                "broadcasts": int(final["broadcasts"].sum()),
                "broadcasts_per_node": np.asarray(final["broadcasts"]),
                "deliveries": int(final["deliveries"]),
                "fedavg_rounds": int(final["fedavg_rounds"]),
                "delivery": cfg.delivery,
                "delivery_budget": self.delivery_budget,
                "compact_budget": self.compact_budget,
                "max_tick_deliveries": int(final["max_due"]),
                "compress": cfg.compress,
                "broadcast_bytes": broadcast_bytes,
                "wire_bytes": broadcast_bytes * int(final["deliveries"]),
                **extra_stats,
            },
            final_state={
                "arrive": final_arrive,
                **{k: np.asarray(final[k])
                   for k in ("w_sum", "buf_cnt",
                             "min_acc", "min_sender", "next_train")},
            },
            sent=jax.tree.map(np.asarray, final["sent"]),
        )
