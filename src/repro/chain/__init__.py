"""Blockchain substrate: proof-of-contribution chain + p2p simulator."""
from repro.chain import crypto, ledger, network, node, types  # noqa: F401
