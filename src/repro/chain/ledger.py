"""Per-node blockchain database: a proof of contribution, not a ledger
(paper §III-F). No global chain exists — partial consensus means each node
keeps its own digest-chained history of the blocks it generated, witnessed by
neighbor confirmations.
"""
from __future__ import annotations

from typing import List, Optional

from repro.chain import crypto
from repro.chain.types import Block, NodeInformation, Transaction, make_genesis


class Ledger:
    def __init__(self, model_structure: str, owner: NodeInformation,
                 kp: crypto.KeyPair):
        self.owner = owner
        self._kp = kp
        self.blocks: List[Block] = [make_genesis(model_structure, owner, kp)]

    @property
    def genesis_digest(self) -> str:
        return self.blocks[0].genesis_digest

    @property
    def head(self) -> Block:
        return self.blocks[-1]

    def new_draft(self, transactions: List[Transaction], now: float) -> Block:
        b = Block(
            generator=self.owner,
            create_time=now,
            previous_final_digest=self.head.final_digest,
            genesis_digest=self.genesis_digest,
            height=len(self.blocks),
            transactions=list(transactions),
        )
        return b.seal_draft(self._kp)

    def append(self, block: Block, min_confirmations_per_tx: int = 1) -> bool:
        if block.previous_final_digest != self.head.final_digest:
            return False
        if block.genesis_digest != self.genesis_digest:
            return False
        if not block.verify(min_confirmations_per_tx):
            return False
        self.blocks.append(block)
        return True

    def verify_chain(self, min_confirmations_per_tx: int = 1) -> bool:
        """Full immutability audit: digests chain, every block verifies."""
        for i, b in enumerate(self.blocks[1:], start=1):
            prev = self.blocks[i - 1]
            if b.previous_final_digest != prev.final_digest:
                return False
            if b.genesis_digest != self.genesis_digest:
                return False
            if not b.verify(min_confirmations_per_tx):
                return False
        return True

    def contribution_count(self, address: Optional[str] = None) -> int:
        """Transactions recorded for an address (proof of contribution)."""
        addr = address or self.owner.address
        return sum(1 for b in self.blocks for t in b.transactions
                   if t.generator.address == addr)
