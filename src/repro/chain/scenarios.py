"""Shared toy federation scenarios runnable on BOTH simulator engines.

The heap `Simulator` (behavioral reference) and the vectorized `LaxSimulator`
must agree on the paper's headline metrics; to compare them we need one
scenario expressible as heap-side Python callbacks AND as vmappable jax
functions over stacked arrays. The toy model here is a D-dim vector pulled
toward a target by each local train step:

    train:   w <- w + LR * (target - w)          (deterministic — no RNG, so
                                                  both engines walk identical
                                                  parameter trajectories)
    receipt: acc(w) = clip(1 - mean|w - target|) (receiver-side measurement;
                                                  poisoned N(0,1) models land
                                                  far from target -> acc ~ 0)
    test:    same closeness metric (the global "accuracy" curve)

Used by tests/test_simlax.py (heap-vs-lax parity) and
benchmarks/bench_gossip.py (wall-clock speedup at scale).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.chain.node import DFLNode
from repro.core.reputation import ReputationImpl

LR = 0.1


@dataclasses.dataclass
class ToyScenario:
    dim: int
    target: jnp.ndarray          # (dim,)
    init_w: np.ndarray           # (n, dim) per-node initial params
    malicious: tuple

    # ------------------------------------------------------------- jax (lax) side
    def init_params_stacked(self):
        return {"w": jnp.asarray(self.init_w)}

    def eval_data(self):
        n = self.init_w.shape[0]
        return jnp.broadcast_to(self.target, (n, self.dim))

    def train_fn(self, params, _key):
        return {"w": params["w"] + LR * (self.target - params["w"])}

    def eval_fn(self, params, ref):
        return jnp.clip(1.0 - jnp.mean(jnp.abs(params["w"] - ref)), 0.0, 1.0)

    def test_fn(self, params):
        return self.eval_fn(params, self.target)

    # ------------------------------------------------------------------ heap side
    def make_heap_nodes(self, *, rep_impl: ReputationImpl, ttl: int,
                        seed: int = 0) -> List[DFLNode]:
        target = np.asarray(self.target)
        nodes = []
        for i in range(self.init_w.shape[0]):
            params = {"w": jnp.asarray(self.init_w[i])}

            def train_fn(p, _k):
                return {"w": p["w"] + LR * (jnp.asarray(target) - p["w"])}, {}

            def eval_fn(p):
                return float(np.clip(
                    1.0 - np.mean(np.abs(np.asarray(p["w"]) - target)),
                    0.0, 1.0))

            nodes.append(DFLNode(
                name=f"n{i}", model_structure="toy", params=params,
                train_fn=train_fn, eval_fn=eval_fn, rep_impl=rep_impl,
                ttl=ttl, malicious=(i in self.malicious),
                rng=jax.random.PRNGKey(seed * 1000 + i)))
        return nodes

    def heap_test_fn(self):
        target = np.asarray(self.target)

        def test_fn(p):
            return float(np.clip(
                1.0 - np.mean(np.abs(np.asarray(p["w"]) - target)), 0.0, 1.0))

        return test_fn


def toy_scenario(n: int, dim: int = 16, malicious: Sequence[int] = (),
                 seed: int = 0) -> ToyScenario:
    rng = np.random.RandomState(seed)
    target = jnp.asarray(np.full((dim,), 0.8, np.float32))
    # nodes start spread BELOW the target so the acc curve visibly climbs
    init_w = (0.1 + 0.05 * rng.rand(n, 1) + 0.01 * rng.rand(n, dim)) \
        .astype(np.float32)
    return ToyScenario(dim=dim, target=target, init_w=init_w,
                       malicious=tuple(malicious))
