"""Shared federation scenarios runnable on BOTH simulator engines.

The heap `Simulator` (behavioral reference) and the vectorized `LaxSimulator`
must agree on the paper's headline metrics; to compare them we need scenarios
expressible as heap-side Python callbacks AND as vmappable jax functions over
stacked arrays. A scenario is anything satisfying the formal ``Scenario``
protocol — ONE uniform signature set for every workload:

    num_nodes                     -> int
    init_params_stacked()         -> pytree, leaves (N, ...)
    train_data()                  -> pytree leaves (N, ...) or None
    eval_data()                   -> pytree leaves (N, ...) per-receiver
    train_fn(params, key, data)   -> params        (one node, vmappable)
    eval_fn(params, eval_data_i)  -> accuracy      (receipt measurement)
    test_fn(params)               -> accuracy      (global test metric)

Scenarios register by name (`scenarios.get("toy")(n, ...)`), mirroring
``repro.core.reputation`` / ``repro.chain.attacks``, and ONE generic heap
binder (`make_heap_nodes` / `make_heap_simulator`) turns any scenario plus a
``FederationSpec`` into heap-`Simulator` nodes — there are no per-scenario
heap bridges anymore.

``ToyScenario`` — a D-dim vector pulled toward a target by each local train
step (deterministic, so both engines walk identical parameter trajectories):

    train:   w <- w + LR * (target - w)
    receipt: acc(w) = clip(1 - mean|w - target|) (receiver-side measurement;
                                                  poisoned models land far
                                                  from target -> acc ~ 0)
    test:    same closeness metric (the global "accuracy" curve)

``LeNetScenario`` — the paper's REAL §VI-D workload: LeNet-5 on synthetic
MNIST, non-I.I.D. Dirichlet label shards (`repro.data.partition`), SGD local
training, receipt accuracy measured on the receiver's own held-out shard
(§IV-B3). Feasible in `simlax` only with the sparse delivery engine
(receipt evals cost a real forward pass).

State layout / batching contract: every stacked property carries node id
as the LEADING axis (leaves ``(N, ...)``), which is what lets the engine
vmap ``train_fn`` over nodes — and, one level up, vmap whole federations
(`repro.chain.attacks.BatchedFederationSpec`): a batched run closes over
ONE scenario instance shared by all members (same data, same
``init_params_stacked()``), so per-federation divergence comes only from
roles and seeds. Scenarios hold no PRNG state of their own: ``train_fn``
receives its key from the engine's per-tick ``fold_in`` stream (the
key-stream contract in `repro.chain.simlax`), which is why two engines —
or a batched member and its single-run twin — walk identical
trajectories.

Used by tests/test_simlax.py (heap-vs-lax and sparse-vs-dense parity),
tests/test_batched.py, benchmarks/bench_gossip.py / bench_malicious.py /
bench_sweep.py, `repro.chain.sweeps`, and
`repro.launch.dryrun --engine lax`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Protocol, Sequence, \
    runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.chain.attacks import FederationSpec
from repro.chain.node import DFLNode
from repro.configs.lenet_dfl import CONFIG as LENET_CFG
from repro.core.reputation import ReputationImpl
from repro.data.partition import dirichlet_class_probs, iid_class_probs
from repro.data.synthetic import SyntheticMnist
from repro.models import lenet

LR = 0.1


@runtime_checkable
class Scenario(Protocol):
    """The formal contract both simulator engines program against."""

    @property
    def num_nodes(self) -> int: ...

    def init_params_stacked(self): ...

    def train_data(self): ...          # pytree leaves (N, ...) or None

    def eval_data(self): ...           # pytree leaves (N, ...)

    def train_fn(self, params, key, data): ...

    def eval_fn(self, params, eval_data_i): ...

    def test_fn(self, params): ...


# ================================================================== registry
_REGISTRY: Dict[str, Callable] = {}


def register(name: str, builder: Callable) -> Callable:
    """Register a scenario builder (n, **kwargs) -> Scenario under a name."""
    _REGISTRY[name] = builder
    return builder


def get(name: str) -> Callable:
    """The registered builder: ``scenarios.get("toy")(n, malicious=(0,))``."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown scenario {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def names() -> tuple:
    return tuple(sorted(_REGISTRY))


# ===================================================== generic heap binding
def make_heap_nodes(scenario: Scenario, *, rep_impl: ReputationImpl,
                    ttl: int, seed: int = 0,
                    spec: Optional[FederationSpec] = None,
                    sim_seed: Optional[int] = None,
                    compress: Optional[str] = None) -> List[DFLNode]:
    """Bind ANY Scenario to heap-`Simulator` nodes: slice the stacked
    params/data per node and wrap the uniform jax callbacks into the node's
    (params, key) -> (params, metrics) / params -> float conventions.
    ``spec`` assigns attacker roles (falls back to the scenario's legacy
    ``malicious`` ids with the default gaussian attack). ``sim_seed`` (the
    lax engine's ``SimLaxConfig.seed``) wires each attacker to the scan's
    fold_in(tick) poison stream so randomized attacks draw bit-identical
    keys on both engines; None keeps the legacy per-node rng split.
    ``compress`` is the wire quantization mode (``SimLaxConfig.compress``);
    nodes then broadcast int8 round-tripped payloads via the same
    ``repro.core.compression`` calls as the lax scan."""
    n = scenario.num_nodes
    if spec is None:
        spec = FederationSpec.build(
            n, malicious=tuple(getattr(scenario, "malicious", ()) or ()))
    if spec.num_nodes != n:
        raise ValueError(f"spec is for {spec.num_nodes} nodes, scenario has {n}")
    key_fns = {} if sim_seed is None else spec.attack_key_fns(sim_seed)
    stacked = scenario.init_params_stacked()
    tdata = scenario.train_data()
    edata = scenario.eval_data()
    train_jit = jax.jit(scenario.train_fn)
    eval_jit = jax.jit(scenario.eval_fn)
    nodes = []
    for i in range(n):
        params_i = jax.tree.map(lambda x, _i=i: jnp.asarray(x[_i]), stacked)
        data_i = (None if tdata is None
                  else jax.tree.map(lambda x, _i=i: jnp.asarray(x[_i]), tdata))
        ed_i = jax.tree.map(lambda x, _i=i: jnp.asarray(x[_i]), edata)

        def train_fn(p, k, data=data_i):
            return train_jit(p, k, data), {}

        def eval_fn(p, ed=ed_i):
            return float(eval_jit(p, ed))

        nodes.append(DFLNode(
            name=f"n{i}", model_structure=type(scenario).__name__.lower(),
            params=params_i, train_fn=train_fn, eval_fn=eval_fn,
            rep_impl=rep_impl, ttl=ttl, attack=spec.attack_for(i),
            attack_key_fn=key_fns.get(i), compress=compress,
            rng=jax.random.PRNGKey(seed * 1000 + i)))
    return nodes


def heap_test_fn(scenario: Scenario) -> Callable:
    """The scenario's global test metric as the heap simulator's
    params -> float callback."""
    test_jit = jax.jit(scenario.test_fn)

    def test_fn(p):
        return float(test_jit(p))

    return test_fn


def make_heap_simulator(scenario: Scenario, topology, spec: FederationSpec,
                        rep_impl: ReputationImpl, cfg, *, seed: int = 0):
    """Construct the heap `Simulator` from the SAME (scenario, topology,
    spec, rep_impl, SimLaxConfig) tuple that constructs ``LaxSimulator`` —
    the single source of truth the engine-parity tests are built from.
    The scalar per-hop latency becomes the heap's (lo, hi) = (l, l)."""
    from repro.chain.network import SimConfig, Simulator
    nodes = make_heap_nodes(scenario, rep_impl=rep_impl, ttl=cfg.ttl,
                            seed=seed, spec=spec, sim_seed=cfg.seed,
                            compress=getattr(cfg, "compress", None))
    names_ = [nd.name for nd in nodes]
    sim = Simulator(
        nodes, topology.as_name_dict(names_), heap_test_fn(scenario),
        SimConfig(ticks=cfg.ticks, train_interval=cfg.train_interval,
                  latency=(cfg.latency, cfg.latency),
                  record_every=cfg.record_every, seed=cfg.seed))
    if spec.initial_countdown is not None:
        sim.next_train = {names_[i]: spec.initial_countdown[i]
                          for i in range(len(names_))}
    for i, factor in spec.stragglers:
        sim.set_straggler(names_[i], factor)
    for i in spec.dead:
        sim.kill_node(names_[i])
    if spec.membership is not None:
        # same join/leave/rejoin event stream the lax engines scan over
        sim.set_membership(spec.membership, names=names_)
    return sim


# ======================================================================= toy
@dataclasses.dataclass
class ToyScenario:
    dim: int
    target: jnp.ndarray          # (dim,)
    init_w: np.ndarray           # (n, dim) per-node initial params
    malicious: tuple

    @property
    def num_nodes(self) -> int:
        return self.init_w.shape[0]

    # ------------------------------------------------------------- jax (lax) side
    def init_params_stacked(self):
        return {"w": jnp.asarray(self.init_w)}

    def train_data(self):
        return None              # the toy train step needs no local data

    def eval_data(self):
        n = self.init_w.shape[0]
        return jnp.broadcast_to(self.target, (n, self.dim))

    def train_fn(self, params, key, data=None):
        del key, data
        return {"w": params["w"] + LR * (self.target - params["w"])}

    def eval_fn(self, params, ref):
        return jnp.clip(1.0 - jnp.mean(jnp.abs(params["w"] - ref)), 0.0, 1.0)

    def test_fn(self, params):
        return self.eval_fn(params, self.target)

    # ----------------------------------------- heap side (deprecation shims)
    def make_heap_nodes(self, *, rep_impl: ReputationImpl, ttl: int,
                        seed: int = 0) -> List[DFLNode]:
        """Deprecated: use the module-level generic ``make_heap_nodes``."""
        return make_heap_nodes(self, rep_impl=rep_impl, ttl=ttl, seed=seed)

    def heap_test_fn(self):
        """Deprecated: use the module-level generic ``heap_test_fn``."""
        return heap_test_fn(self)


def toy_scenario(n: int, dim: int = 16, malicious: Sequence[int] = (),
                 seed: int = 0) -> ToyScenario:
    rng = np.random.RandomState(seed)
    target = jnp.asarray(np.full((dim,), 0.8, np.float32))
    # nodes start spread BELOW the target so the acc curve visibly climbs
    init_w = (0.1 + 0.05 * rng.rand(n, 1) + 0.01 * rng.rand(n, dim)) \
        .astype(np.float32)
    return ToyScenario(dim=dim, target=target, init_w=init_w,
                       malicious=tuple(malicious))


# =========================================================== real-model (LeNet)
@dataclasses.dataclass
class LeNetScenario:
    """Paper §VI-D at federation scale: LeNet-5, non-I.I.D. Dirichlet shards,
    receipt accuracy on the receiver's own held-out data. ``malicious`` names
    the default attacker set (legacy: gaussian random-model poisoning, the
    paper's §VI-E attack); richer adversaries come from a ``FederationSpec``
    built over ``repro.chain.attacks``."""

    class_probs: np.ndarray      # (n, classes) per-node label distribution
    train_images: np.ndarray     # (n, P, 28, 28, 1) local training pools
    train_labels: np.ndarray     # (n, P)
    eval_images: np.ndarray      # (n, E, 28, 28, 1) receipt-eval held-out sets
    eval_labels: np.ndarray     # (n, E)
    test_images: np.ndarray      # (T, 28, 28, 1) global I.I.D. test set
    test_labels: np.ndarray      # (T,)
    malicious: tuple
    train_steps: int             # SGD steps per training action
    batch: int
    lr: float
    seed: int

    @property
    def num_nodes(self) -> int:
        return self.train_images.shape[0]

    # ------------------------------------------------------------- jax (lax) side
    def init_params_stacked(self):
        keys = jax.random.split(jax.random.PRNGKey(self.seed),
                                self.num_nodes)
        return jax.vmap(lambda k: lenet.init(k, LENET_CFG))(keys)

    def train_data(self):
        return {"images": jnp.asarray(self.train_images),
                "labels": jnp.asarray(self.train_labels)}

    def eval_data(self):
        return {"images": jnp.asarray(self.eval_images),
                "labels": jnp.asarray(self.eval_labels)}

    def train_fn(self, params, key, data):
        """`train_steps` plain-SGD steps on batches resampled from this
        node's pool (vmapped over the federation by the engine)."""
        pool = data["labels"].shape[0]
        idx = jax.random.randint(key, (self.train_steps, self.batch), 0, pool)

        def step(p, ix):
            b = {"images": data["images"][ix], "labels": data["labels"][ix]}
            (_, _), g = jax.value_and_grad(
                lenet.loss_and_acc, has_aux=True)(p, b)
            return jax.tree.map(lambda a, gg: a - self.lr * gg, p, g), None

        params, _ = jax.lax.scan(step, params, idx)
        return params

    def eval_fn(self, params, ed):
        return lenet.accuracy(params, ed["images"], ed["labels"])

    def test_fn(self, params):
        return lenet.accuracy(params, jnp.asarray(self.test_images),
                              jnp.asarray(self.test_labels))

    # ----------------------------------------- heap side (deprecation shims)
    def make_heap_nodes(self, *, rep_impl: ReputationImpl, ttl: int,
                        seed: int = 0) -> List[DFLNode]:
        """Deprecated: use the module-level generic ``make_heap_nodes``."""
        return make_heap_nodes(self, rep_impl=rep_impl, ttl=ttl, seed=seed)

    def heap_test_fn(self):
        """Deprecated: use the module-level generic ``heap_test_fn``."""
        return heap_test_fn(self)


def lenet_scenario(n: int, *, alpha: float = 1.0,
                   malicious: Sequence[int] = (), seed: int = 0,
                   pool: int = 256, eval_size: int = 64,
                   test_size: int = 512, train_steps: int = 2,
                   batch: int = 32, noise: float = 1.5,
                   lr: float = 0.1) -> LeNetScenario:
    """Build the §VI-D federation data: Dirichlet(alpha) label shards
    (``alpha=None`` -> I.I.D.), per-node train pools and held-out receipt
    sets drawn from the node's OWN distribution, one global I.I.D. test set.
    noise=1.5 calibrates SyntheticMnist so single-node LeNet saturates in
    the mid-90s like the paper's MNIST setup (see benchmarks/harness.py)."""
    ds = SyntheticMnist(seed=seed, noise=noise)
    if alpha is None:
        probs = iid_class_probs(n, ds.num_classes)
    else:
        probs = dirichlet_class_probs(n, ds.num_classes, alpha, seed=seed)
    tr_i = np.empty((n, pool, ds.image_size, ds.image_size, 1), np.float32)
    tr_l = np.empty((n, pool), np.int32)
    ev_i = np.empty((n, eval_size, ds.image_size, ds.image_size, 1),
                    np.float32)
    ev_l = np.empty((n, eval_size), np.int32)
    for i in range(n):
        rng = np.random.RandomState(seed * 100 + i)
        tr_i[i], tr_l[i] = ds.batch(rng, pool, class_probs=probs[i])
        ev_i[i], ev_l[i] = ds.batch(
            np.random.RandomState(seed * 100 + i + 5000), eval_size,
            class_probs=probs[i])
    te_i, te_l = ds.batch(np.random.RandomState(9999), test_size)
    return LeNetScenario(
        class_probs=probs, train_images=tr_i, train_labels=tr_l,
        eval_images=ev_i, eval_labels=ev_l,
        test_images=te_i.astype(np.float32), test_labels=te_l.astype(np.int32),
        malicious=tuple(malicious), train_steps=train_steps, batch=batch,
        lr=lr, seed=seed)


register("toy", toy_scenario)
register("lenet", lenet_scenario)


# the calibrated §VI-D data/optimizer recipe — single source for the
# acceptance test, bench_malicious, and the dryrun CLI sanity pass
LENET_PAPER_HP = dict(alpha=1.0, pool=384, eval_size=16, test_size=256,
                      batch=16, lr=0.12)


def lenet_paper_setup(n: int = 10, *, ticks: int = 108, train_steps: int = 8,
                      seed: int = 0, delivery: str = "compact",
                      compress: Optional[str] = None):
    """The calibrated §VI-D acceptance recipe, shared by
    tests/test_simlax.py::test_lenet_poisoned_federation_reaches_paper_accuracy
    and benchmarks/bench_malicious.py so they cannot drift apart: 20%
    poisoned senders, Dirichlet(1) shards, kregular(n, 2) ttl=2, SGD
    hyperparameters tuned so honest nodes clear 90% mean test accuracy
    within the default 108 ticks on 2 CPU cores.

    Returns (scenario, spec, topology, SimLaxConfig).
    """
    from repro.chain import simlax          # one-way dep: simlax <- scenarios
    from repro.core import topology as topology_lib
    mal = tuple(range(max(1, n // 5)))      # 20% poisoned senders
    sc = lenet_scenario(n, malicious=mal, seed=seed,
                        train_steps=train_steps, **LENET_PAPER_HP)
    topo = topology_lib.kregular(n, 2)
    cfg = simlax.SimLaxConfig(ticks=ticks, train_interval=(6, 6), latency=1,
                              ttl=2, record_every=12, seed=seed,
                              delivery=delivery, compress=compress)
    countdown = [3 + (5 * i) % 6 for i in range(n)]
    spec = FederationSpec.build(n, malicious=mal,
                                initial_countdown=countdown)
    return sc, spec, topo, cfg
