"""Version shims for the jax APIs this repo uses across jax releases.

The codebase targets the modern spellings (``jax.shard_map`` with
``axis_names``/``check_vma``, ``jax.make_mesh`` with ``axis_types``); CI and
dev containers may carry an older jax (0.4.x) where the same features live
under ``jax.experimental.shard_map`` with ``auto``/``check_rep``. Everything
routes through here so call sites stay on one spelling.
"""
from __future__ import annotations

import jax

_HAS_NEW_SHARD_MAP = hasattr(jax, "shard_map")


def supports_partial_auto_shard_map() -> bool:
    """Whether this jax can lower a shard_map that is manual over SOME mesh
    axes while other, non-trivial (size > 1) axes stay auto. Old jaxlib's
    SPMD partitioner aborts on that case deep inside compilation with an
    opaque error; jax >= 0.6 (the ``jax.shard_map`` era) handles it."""
    return _HAS_NEW_SHARD_MAP


def check_partial_auto_shard_map(mesh, manual_axes) -> None:
    """Fail fast — with an actionable message — where old jaxlib's SPMD
    partitioner would abort opaquely: a partial-auto shard_map (manual over
    ``manual_axes``) on a mesh whose remaining axes are non-trivial."""
    if supports_partial_auto_shard_map():
        return
    auto = [a for a in mesh.axis_names
            if a not in set(manual_axes) and mesh.shape[a] > 1]
    if auto:
        raise RuntimeError(
            f"partial-auto shard_map is unsupported on jax {jax.__version__}: "
            f"manual axes {sorted(manual_axes)} with non-trivial auto axes "
            f"{auto} (mesh "
            f"{'x'.join(str(mesh.shape[a]) for a in mesh.axis_names)}) abort "
            "inside the old SPMD partitioner. Upgrade to jax >= 0.6, or use "
            "a federation mesh whose non-federation axes are size 1 "
            "(repro.launch.mesh.make_fed_mesh(F, 1, 1)).")


def shard_map(fn, *, mesh=None, in_specs, out_specs, axis_names=None,
              check_vma=False):
    """``jax.shard_map`` on new jax; ``jax.experimental.shard_map`` fallback.

    ``axis_names`` is the set of MANUAL axes (new-jax convention). On old jax
    the complement of ``axis_names`` is passed as ``auto`` and ``check_vma``
    maps to ``check_rep``.
    """
    if _HAS_NEW_SHARD_MAP:
        kwargs = dict(in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        if mesh is not None:
            kwargs["mesh"] = mesh
        return jax.shard_map(fn, **kwargs)

    from jax.experimental.shard_map import shard_map as _sm
    if mesh is None:
        raise ValueError(
            "mesh is required with jax<0.6 (no ambient-mesh shard_map)")
    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check_vma)
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        if auto:
            kwargs["auto"] = auto
    return _sm(fn, **kwargs)


def pallas_tpu_compiler_params(pltpu, **kwargs):
    """Pallas-TPU compiler params across the TPUCompilerParams ->
    CompilerParams rename."""
    cls = getattr(pltpu, "CompilerParams",
                  getattr(pltpu, "TPUCompilerParams", None))
    if cls is None:
        raise ImportError(
            "this jax.experimental.pallas.tpu exposes neither CompilerParams "
            "nor TPUCompilerParams; unsupported pallas version")
    return cls(**kwargs)


def make_mesh(shape, axes):
    """``jax.make_mesh`` with all-Auto axis types where supported."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    except (ImportError, TypeError):
        return jax.make_mesh(shape, axes)
