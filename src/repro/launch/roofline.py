"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Terms (per chip, TPU v5e constants):
    compute    = HLO_FLOPs / peak_FLOPs            [cost_analysis]
    memory     = HLO_bytes / HBM_bw                [cost_analysis]
    collective = collective_operand_bytes / ICI_bw [parsed from optimized HLO]

``cost_analysis()`` on an SPMD-partitioned module reports *per-device*
numbers, so no further division by chip count is applied. Collective bytes
sum the operand sizes of all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute ops in the post-optimization HLO (falling
back to the output size when an operand's shape is not resolvable).
ICI is modeled as one 50 GB/s link per hop (v5e has 4 links/chip — we report
the conservative single-link figure and note it).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

V5E = {
    "peak_flops": 197e12,   # bf16 FLOP/s per chip
    "hbm_bw": 819e9,        # B/s per chip
    "ici_bw": 50e9,         # B/s per link
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f32": 4, "s32": 4, "u32": 4,
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
}

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"        # result name
    r"((?:\([^=]*?\))|(?:[a-z0-9]+\[[^\]]*\]\S*))\s+"  # result type
    r"([\w\-]+)\(([^)]*)\)",                        # opcode + operands
)


def shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: dict = field(default_factory=dict)
    count_by_op: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of every collective in post-optimization HLO."""
    shapes: dict[str, str] = {}
    instrs = []
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rtype, opcode, args = m.groups()
        shapes[name] = rtype
        instrs.append((name, rtype, opcode, args))

    stats = CollectiveStats()
    for _name, rtype, opcode, args in instrs:
        base = opcode[:-6] if opcode.endswith("-start") else opcode
        if base not in COLLECTIVE_OPS or opcode.endswith("-done"):
            continue
        operand_bytes = 0
        for arg in args.split(","):
            arg = arg.strip().lstrip("%")
            # operands may carry inline types: "bf16[8,128]{1,0} %name"
            parts = arg.split()
            ref = parts[-1].lstrip("%") if parts else ""
            if len(parts) > 1:
                operand_bytes += shape_bytes(" ".join(parts[:-1]))
            elif ref in shapes:
                operand_bytes += shape_bytes(shapes[ref])
        if operand_bytes == 0:
            operand_bytes = shape_bytes(rtype)
        stats.bytes_by_op[base] = stats.bytes_by_op.get(base, 0) + operand_bytes
        stats.count_by_op[base] = stats.count_by_op.get(base, 0) + 1
    return stats


def terms_from_walker(walk, raw_cost: dict, hw: dict = V5E) -> dict:
    """Roofline terms from the trip-count-aware HLO walker (repro.launch.
    hlo_cost); raw ``cost_analysis()`` numbers kept for cross-reference
    (XLA's builtin counts while bodies once — see hlo_cost docstring)."""
    flops = float(walk.flops)
    byts = float(walk.bytes)
    t = {
        "compute_s": flops / hw["peak_flops"],
        "memory_s": byts / hw["hbm_bw"],
        "collective_s": walk.total_collective_bytes / hw["ici_bw"],
        "hlo_flops": flops,
        "hlo_bytes": byts,
        "collective_bytes": walk.total_collective_bytes,
        "collectives": {k: int(v) for k, v in walk.collective_count.items()},
        "collective_bytes_by_op": dict(walk.collective_bytes),
        "raw_cost_flops": float(raw_cost.get("flops", 0.0)),
        "raw_cost_bytes": float(raw_cost.get("bytes accessed", 0.0)),
        "scan_trip_counts": walk.while_trips,
    }
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: t[k])
    t["dominant"] = dom.replace("_s", "")
    bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
    t["roofline_fraction"] = (t["compute_s"] / bound) if bound > 0 else 0.0
    return t


# ------------------------------------------------------------- model FLOPs (6ND)
def effective_param_count(cfg, total_params: int, embed_params: int,
                          active: bool) -> int:
    """N for the 6*N*D model-FLOPs estimate.

    Excludes the input embedding table when untied (lookup, not matmul);
    for MoE archs `active=True` keeps only top_k (+ shared) experts' FFN
    params per MoE layer.
    """
    n = total_params
    if not cfg.tie_embeddings:
        n -= embed_params  # input table: gather only
    if active and cfg.moe is not None:
        m = cfg.moe
        n_moe_layers = sum(1 for i in range(cfg.num_layers) if cfg.layer_is_moe(i))
        expert_params = 3 * cfg.d_model * m.d_ff_expert * m.num_experts
        inactive_frac = (m.num_experts - m.top_k) / m.num_experts
        n -= int(n_moe_layers * expert_params * inactive_frac)
    return n


def model_flops(cfg, total_params: int, embed_params: int, shape) -> float:
    n = effective_param_count(cfg, total_params, embed_params, active=True)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens
