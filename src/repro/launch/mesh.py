"""Mesh construction. Functions only — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import)."""
from __future__ import annotations

from repro import compat


def _mk(shape, axes):
    return compat.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model). Multi-pod: 2 pods = 512
    chips (pod, data, model); the pod axis doubles as the DFL federation axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_fed_mesh(num_fed: int, data: int = 1, model: int = 1):
    """DFL federation mesh: fed axis carries one model replica per slice
    (paper-scale runs: num_fed nodes x 1 device; pod-scale: fed=pods)."""
    return _mk((num_fed, data, model), ("fed", "data", "model"))


def make_test_mesh(data: int = 2, model: int = 2):
    return _mk((data, model), ("data", "model"))


def fed_axis_name(mesh) -> str:
    if "fed" in mesh.axis_names:
        return "fed"
    if "pod" in mesh.axis_names:
        return "pod"
    return "data"
