"""End-to-end training driver (example application + production launcher).

Two modes:
  * plain data-parallel training of any zoo arch on the synthetic pipeline;
  * ``--dfl``: DFL federated training — F replicas, H local steps per round,
    ttl-bounded reputation-weighted gossip, elastic ring on simulated node
    failure, digest-chained checkpoints.

CPU-friendly: ``--smoke`` uses the reduced config; ``--host-devices N`` backs
the federation mesh with N host devices (set before jax imports). The
production path is the same code lowered on the real mesh (see dryrun.py).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke --steps 20
  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --smoke --dfl \
      --host-devices 4 --fed 4 --rounds 10 --local-steps 2 --ttl 1 \
      --fail-node 2@5 --ckpt-dir /tmp/dflckpt
"""
import argparse
import os
import sys


def _early_env():
    if "--host-devices" in sys.argv:
        n = sys.argv[sys.argv.index("--host-devices") + 1]
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={n} "
            + os.environ.get("XLA_FLAGS", ""))


_early_env()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config, smoke_config  # noqa: E402
from repro.core import dfl as dfl_lib  # noqa: E402
from repro.core import gossip as gossip_lib  # noqa: E402
from repro.core import reputation as rep_lib  # noqa: E402
from repro.data.pipeline import TokenPipeline  # noqa: E402
from repro.launch.mesh import make_fed_mesh  # noqa: E402
from repro.train import checkpoint as ckpt_lib  # noqa: E402
from repro.train import step as step_lib  # noqa: E402
from repro.train.fault import FedRing, elastic_gossip_builder  # noqa: E402


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--host-devices", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    # DFL federation
    ap.add_argument("--dfl", action="store_true")
    ap.add_argument("--fed", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--ttl", type=int, default=1)
    ap.add_argument("--reputation", default="impl2")
    ap.add_argument("--compress", default=None, choices=(None, "int8"))
    ap.add_argument("--fail-node", default=None,
                    help="simulate failure: '<replica>@<round>'")
    return ap.parse_args(argv)


def run_plain(args, cfg):
    pipe = TokenPipeline(cfg.vocab_size, args.batch, args.seq)
    state, _ = step_lib.init_train_state(cfg, jax.random.PRNGKey(0))
    start = 0
    if args.resume and args.ckpt_dir:
        state, start = ckpt_lib.restore(args.ckpt_dir, state)
        print(f"[train] resumed from step {start} "
              f"(chain ok: {ckpt_lib.verify_chain(args.ckpt_dir)})")
    ts = jax.jit(step_lib.make_train_step(cfg), donate_argnums=(0,))
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
        state, metrics = ts(state, batch)
        if step % 5 == 0 or step == args.steps - 1:
            print(f"[train] step {step} loss {float(metrics['loss']):.4f} "
                  f"acc {float(metrics['accuracy']):.3f}")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt_lib.save(args.ckpt_dir, state, step + 1, arch=cfg.name)
    return state


def _pack_live(fed_state, rep_rows, live, new_mesh):
    """Drop the dead replica's slice and re-place survivors on the smaller
    federation mesh (their params/opt state are untouched)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    idx = jnp.asarray(live)
    fs = jax.tree.map(lambda x: np.asarray(x[idx]), fed_state)
    rr = np.asarray(rep_rows[idx][:, idx])
    sh = NamedSharding(new_mesh, P("fed"))
    fs = jax.tree.map(lambda x: jax.device_put(x, sh), fs)
    return fs, jax.device_put(rr, sh)


def run_dfl(args, cfg):
    fed = args.fed
    mesh = make_fed_mesh(fed, data=1, model=1)
    if mesh.size > jax.device_count():
        raise SystemExit(f"need {mesh.size} devices; pass --host-devices")
    rep_impl = rep_lib.get(args.reputation)
    pipe = TokenPipeline(cfg.vocab_size, args.batch, args.seq, fed_nodes=fed)
    fed_state, rep_rows = dfl_lib.init_federation(cfg, fed, jax.random.PRNGKey(0))
    ring = FedRing(list(range(fed)))
    fail_at = None
    if args.fail_node:
        rep, rnd = args.fail_node.split("@")
        fail_at = (int(rep), int(rnd))

    ts = step_lib.make_train_step(cfg)

    def build_round(f):
        m = make_fed_mesh(f, data=1, model=1)
        local = jax.jit(gossip_lib.make_local_steps(ts, fed_axis="fed", mesh=m))
        gr = jax.jit(gossip_lib.make_gossip_round(
            dfl_lib.make_lm_eval_fn(cfg), fed_axis="fed", fed_size=f,
            ttl=min(args.ttl, max(1, (f - 1) // 2)), rep_impl=rep_impl,
            compress=args.compress, mesh=m))
        return local, gr

    get_round = elastic_gossip_builder(build_round)

    for rnd in range(args.rounds):
        if fail_at and rnd == fail_at[1] and fail_at[0] in ring.members:
            print(f"[dfl] replica {fail_at[0]} FAILED at round {rnd}; "
                  f"ring renumbers {ring.size} -> {ring.size - 1}")
            ring.fail(fail_at[0])
            new_mesh = make_fed_mesh(ring.size, data=1, model=1)
            fed_state, rep_rows = _pack_live(fed_state, rep_rows,
                                             ring.members, new_mesh)
            ring.members = list(range(ring.size))  # dense ranks after pack
        f = ring.size
        local, gossip_round = get_round(f)
        batches = pipe.fed_batches(rnd, args.local_steps)
        batches = {k: jnp.asarray(v[:f]) for k, v in batches.items()}
        fed_state, metrics = local(fed_state, batches)
        val = pipe.fed_batches(10_000 + rnd, 1)
        vb = {k: jnp.asarray(v[:f, 0, : max(2, args.batch // 2)])
              for k, v in val.items()}
        new_params, rep_rows, gm = gossip_round(fed_state["params"], rep_rows, vb)
        fed_state = dict(fed_state, params=new_params)
        print(f"[dfl] round {rnd} F={f} "
              f"loss={np.asarray(metrics['loss']).mean():.4f} "
              f"neighbor_acc={np.asarray(gm['mean_neighbor_acc']).mean():.3f} "
              f"rep_min={np.asarray(gm['rep_min']).min():.2f}")
        if args.ckpt_dir and (rnd + 1) % args.ckpt_every == 0:
            ckpt_lib.save(args.ckpt_dir, fed_state, rnd + 1, arch=cfg.name,
                          extra={"mode": "dfl", "fed": f})
    return fed_state


def main(argv=None):
    args = parse_args(argv)
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    print(f"[train] arch={cfg.name} smoke={args.smoke} dfl={args.dfl} "
          f"devices={jax.device_count()}")
    if args.dfl:
        run_dfl(args, cfg)
    else:
        run_plain(args, cfg)
    if args.ckpt_dir:
        print(f"[train] checkpoint chain ok: {ckpt_lib.verify_chain(args.ckpt_dir)}")


if __name__ == "__main__":
    main()
