"""Trip-count-aware cost analysis over optimized HLO text.

XLA's built-in ``compiled.cost_analysis()`` visits a while-loop body ONCE —
for scan-over-layers models that undercounts FLOPs, bytes and (critically)
per-layer collectives by the layer count. This walker parses the optimized
HLO, resolves the static trip count of each while loop from its condition
computation, and accumulates:

* flops            — dot (2 * result * contraction), conv, reduce ops
* bytes            — operand+result bytes of *top-level* instructions in
                     control-flow computations (fusion internals excluded:
                     they live in registers/VMEM, not HBM)
* collective bytes — operand bytes per collective op kind

each multiplied by the product of enclosing loop trip counts.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f32": 4, "s32": 4, "u32": 4,
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "token": 0, "s64v": 8,
}

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_TRIPCOUNT_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_INSTR_PREFIX = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")
_PARAM_RE = re.compile(r"([\w.\-]+):\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\]))")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TOAPPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_WINDOW_RE = re.compile(r"window=\{size=([0-9x]+)")


def shape_elems_bytes(type_str):
    elems, byts = 0, 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dtype]
    return elems, byts


def shape_dims(type_str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    name: str
    rtype: str
    opcode: str
    line: str
    operands: list = field(default_factory=list)


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)  # value name -> type str
    params: list = field(default_factory=list)  # param names, in order


def _split_operands(argstr: str) -> list[str]:
    """Split the top-level comma-separated operand list."""
    out, depth, cur = [], 0, []
    for ch in argstr:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    return [o for o in out if o]


def _balanced_span(s: str, start: int) -> int:
    """Index just past the paren group opening at s[start] (== '(')."""
    depth = 0
    for j in range(start, len(s)):
        if s[j] == "(":
            depth += 1
        elif s[j] == ")":
            depth -= 1
            if depth == 0:
                return j + 1
    return len(s)


def parse_instr(line: str):
    """-> Instr | None. Handles tuple result types with nested parens and
    /*index=N*/ comments (which defeat naive regexes)."""
    m = _INSTR_PREFIX.match(line)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    if i >= len(line):
        return None
    if line[i] == "(":  # tuple result type
        j = _balanced_span(line, i)
        rtype = line[i:j]
    else:
        j = line.find(" ", i)
        if j < 0:
            return None
        rtype = line[i:j]
    mo = _OPCODE_RE.match(line, j)
    if not mo:
        return None
    opcode = mo.group(1)
    oi = mo.end() - 1  # position of '('
    oj = _balanced_span(line, oi)
    args = line[oi + 1: oj - 1]
    return Instr(name, rtype, opcode, line.strip(), _split_operands(args))


def parse_module(text: str) -> tuple[dict, str]:
    """-> ({name: Computation}, entry_name)."""
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            if line.lstrip().startswith(("%", "ENTRY")) and line.endswith("{"):
                stripped = line.strip()
                m = _COMP_HDR.match(stripped)
                if m:
                    cur = Computation(m.group(1))
                    if stripped.startswith("ENTRY"):
                        entry = cur.name
                    # balanced-paren param span (types may nest tuples)
                    i = stripped.find("(")
                    depth, j = 0, i
                    for j in range(i, len(stripped)):
                        if stripped[j] == "(":
                            depth += 1
                        elif stripped[j] == ")":
                            depth -= 1
                            if depth == 0:
                                break
                    for pname, ptype in _PARAM_RE.findall(stripped[i: j + 1]):
                        cur.shapes[pname] = ptype
                        cur.params.append(pname)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        ins = parse_instr(line)
        if ins is not None:
            cur.instrs.append(ins)
            cur.shapes[ins.name] = ins.rtype
    return comps, entry


def _operand_type(comp: Computation, operand: str):
    parts = operand.split()
    if len(parts) > 1 and "[" in parts[0]:
        return " ".join(parts[:-1])
    ref = parts[-1].lstrip("%") if parts else ""
    return comp.shapes.get(ref)


def _trip_count(comps: dict, cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = None
    for ins in cond.instrs:
        for mm in _CONST_RE.finditer(ins.line):
            v = int(mm.group(1))
            best = v if best is None else max(best, v)
    return best if best else 1


@dataclass
class CostResult:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: dict = field(default_factory=dict)
    collective_count: dict = field(default_factory=dict)
    while_trips: list = field(default_factory=list)
    contributors: list = field(default_factory=list)  # (bytes, flops, instr) when debug

    @property
    def total_collective_bytes(self):
        return sum(self.collective_bytes.values())


_SKIP_BYTES_OPS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id",
}


def _instr_flops(comp: Computation, ins: Instr) -> float:
    if ins.opcode == "dot":
        res_elems, _ = shape_elems_bytes(ins.rtype)
        lhs_t = _operand_type(comp, ins.operands[0]) if ins.operands else None
        m = _CONTRACT_RE.search(ins.line)
        contract = 1
        if lhs_t and m:
            dims = shape_dims(lhs_t)
            for idx in (int(x) for x in m.group(1).split(",") if x):
                if idx < len(dims):
                    contract *= dims[idx]
        return 2.0 * res_elems * contract
    if ins.opcode == "convolution":
        res_elems, _ = shape_elems_bytes(ins.rtype)
        win = _WINDOW_RE.search(ins.line)
        wsize = 1
        if win:
            for d in win.group(1).split("x"):
                wsize *= int(d)
        in_t = _operand_type(comp, ins.operands[0]) if ins.operands else None
        in_ch = shape_dims(in_t)[-1] if in_t else 1
        return 2.0 * res_elems * wsize * in_ch
    if ins.opcode in ("reduce", "reduce-window"):
        elems = 0
        for op in ins.operands[: max(1, len(ins.operands) // 2)]:
            t = _operand_type(comp, op)
            if t:
                e, _ = shape_elems_bytes(t)
                elems += e
        return float(elems)
    return 0.0


_SLICE_OPS = {"dynamic-slice", "gather", "slice"}


def _ref_name(operand: str) -> str:
    parts = operand.split()
    return parts[-1].lstrip("%") if parts else ""


def _result_bytes(ins: Instr) -> float:
    _, b = shape_elems_bytes(ins.rtype)
    return float(b)


def _instr_bytes(comp: Computation, ins: Instr) -> float:
    """HBM traffic estimate for a top-level instruction.

    Slicing ops read only their result-sized window, not the whole operand;
    dynamic-update-slice writes only the update region. Counting full operand
    bytes there overstates KV-cache updates and scan xs slicing by O(S).
    """
    if ins.opcode in _SKIP_BYTES_OPS:
        return 0.0
    if ins.opcode in _SLICE_OPS:
        return 2.0 * _result_bytes(ins)  # read window + write result
    if ins.opcode == "dynamic-update-slice":
        upd_t = _operand_type(comp, ins.operands[1]) if len(ins.operands) > 1 else None
        if upd_t:
            _, ub = shape_elems_bytes(upd_t)
            return 2.0 * ub
        return _result_bytes(ins)
    if ins.opcode == "scatter":
        upd_t = _operand_type(comp, ins.operands[-1]) if ins.operands else None
        if upd_t:
            _, ub = shape_elems_bytes(upd_t)
            return 2.0 * ub
        return _result_bytes(ins)
    total = _result_bytes(ins)
    for op in ins.operands:
        t = _operand_type(comp, op)
        if t:
            _, ob = shape_elems_bytes(t)
            total += ob
    return total


def _fusion_bytes(comp: Computation, ins: Instr, comps: dict) -> float:
    """Traffic of a fusion call: result + effective reads per operand.

    A fusion parameter consumed *only* by slice/gather ops reads just the
    windows (e.g. scan xs slicing, embedding lookup, KV band extraction);
    any other use reads the full operand.
    """
    m = _CALLS_RE.search(ins.line)
    fcomp = comps.get(m.group(1)) if m else None
    total = _result_bytes(ins)
    if fcomp is None:
        for op in ins.operands:
            t = _operand_type(comp, op)
            if t:
                _, ob = shape_elems_bytes(t)
                total += ob
        return total
    # alias sets: bitcast/reshape/transpose/copy/convert of a param is still
    # "the param" for window-read detection. XLA routes DUS bases through
    # convert dances (bf16->f32->DUS->bf16); a real TPU pipeline simplifies
    # those away, so we account the optimistic window-only traffic.
    _TRANSPARENT = ("bitcast", "reshape", "transpose", "copy", "convert")
    alias: dict[str, str] = {p: p for p in fcomp.params}
    for fin in fcomp.instrs:
        if fin.opcode in _TRANSPARENT and fin.operands:
            src = _ref_name(fin.operands[0])
            if src in alias:
                alias[fin.name] = alias[src]

    # In-place DUS at the fusion root: the write is the update window, not the
    # whole base buffer (XLA buffer assignment shares base/result).
    for fin in fcomp.instrs:
        if (fin.opcode == "dynamic-update-slice"
                and _result_bytes(fin) >= _result_bytes(ins) * 0.99
                and len(fin.operands) > 1):
            upd_t = _operand_type(fcomp, fin.operands[1])
            if upd_t:
                _, ub = shape_elems_bytes(upd_t)
                total = total - _result_bytes(ins) + float(ub)
            break

    for idx, op in enumerate(ins.operands):
        t = _operand_type(comp, op)
        if not t:
            continue
        _, full = shape_elems_bytes(t)
        pname = fcomp.params[idx] if idx < len(fcomp.params) else None
        est, sliced_only = 0.0, pname is not None
        if pname is not None:
            for fin in fcomp.instrs:
                if fin.opcode in _TRANSPARENT:
                    continue  # aliases handled above
                refs = [_ref_name(o) for o in fin.operands]
                if not any(alias.get(r) == pname for r in refs):
                    continue
                if fin.opcode in _SLICE_OPS:
                    est += _result_bytes(fin)
                elif (fin.opcode == "dynamic-update-slice"
                      and alias.get(refs[0]) == pname):
                    upd_t = _operand_type(fcomp, fin.operands[1])
                    if upd_t:
                        _, ub = shape_elems_bytes(upd_t)
                        est += ub
                else:
                    sliced_only = False
                    break
        total += min(full, est) if (sliced_only and est > 0) else full
    return total


def analyze(text: str, debug: bool = False) -> CostResult:
    comps, entry = parse_module(text)
    res = CostResult()
    flops_memo: dict[str, float] = {}

    def note(b, f, ins, mult):
        if debug and (b > 0 or f > 0):
            res.contributors.append((b, f, mult, ins.line[:180]))

    def fusion_flops(name: str) -> float:
        """Total dot/conv/reduce flops inside a fusion-called computation."""
        if name in flops_memo:
            return flops_memo[name]
        comp = comps.get(name)
        if comp is None:
            return 0.0
        total = 0.0
        for ins in comp.instrs:
            total += _instr_flops(comp, ins)
            m = _CALLS_RE.search(ins.line)
            if m and ins.opcode in ("fusion", "call", "map"):
                total += fusion_flops(m.group(1))
        flops_memo[name] = total
        return total

    def walk(name: str, mult: float):
        comp = comps.get(name)
        if comp is None:
            return
        for ins in comp.instrs:
            if ins.opcode == "while":
                m = _WHILE_RE.search(ins.line)
                if m:
                    cond, body = m.groups()
                    tc = _TRIPCOUNT_RE.search(ins.line)
                    trips = int(tc.group(1)) if tc else _trip_count(comps, cond)
                    res.while_trips.append(trips)
                    walk(body, mult * trips)
                    walk(cond, mult * trips)
                continue
            if ins.opcode == "conditional":
                m = _BRANCH_RE.search(ins.line)
                if m:
                    branches = [b.strip().lstrip("%") for b in m.group(1).split(",")]
                    for b in branches:  # conservative: all branches
                        walk(b, mult)
                continue
            if ins.opcode in ("call", "async-start"):
                m = _CALLS_RE.search(ins.line)
                if m:
                    walk(m.group(1), mult)
                res.bytes += mult * _instr_bytes(comp, ins)
                continue
            base = ins.opcode[:-6] if ins.opcode.endswith("-start") else ins.opcode
            if base in COLLECTIVE_OPS and not ins.opcode.endswith("-done"):
                ob = 0.0
                for op in ins.operands:
                    t = _operand_type(comp, op)
                    if t:
                        _, b = shape_elems_bytes(t)
                        ob += b
                if ob == 0:
                    _, ob = shape_elems_bytes(ins.rtype)
                res.collective_bytes[base] = res.collective_bytes.get(base, 0.0) + mult * ob
                res.collective_count[base] = res.collective_count.get(base, 0.0) + mult
                res.bytes += mult * _instr_bytes(comp, ins)
                continue
            if ins.opcode == "fusion":
                m = _CALLS_RE.search(ins.line)
                ff = fusion_flops(m.group(1)) if m else 0.0
                fb = _fusion_bytes(comp, ins, comps)
                res.flops += mult * ff
                res.bytes += mult * fb
                note(mult * fb, mult * ff, ins, mult)
                continue
            f = _instr_flops(comp, ins)
            b = _instr_bytes(comp, ins)
            res.flops += mult * f
            res.bytes += mult * b
            note(mult * b, mult * f, ins, mult)

    if entry:
        walk(entry, 1.0)
    return res
