import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST run before any jax-importing module: jax locks the
# device count at first init. 512 host devices back the production meshes
# (16x16 single pod, 2x16x16 multi-pod) without hardware.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each runnable cell this builds the real step function (train_step /
prefill / decode_step — the same code the launcher runs), pairs it with
ShapeDtypeStruct inputs and NamedShardings from the logical-axis rules, then:

    lowered  = jax.jit(step, in_shardings=...).lower(**specs)
    compiled = lowered.compile()
    print(compiled.memory_analysis())   # proves it fits
    print(compiled.cost_analysis())     # FLOPs/bytes for the roofline

Results (roofline terms, collective schedule, bytes/device) are appended to a
JSON file consumed by EXPERIMENTS.md.

Usage:
    python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--dfl]
    python -m repro.launch.dryrun --engine lax --nodes 64 \
        --delivery sharded --mesh 8 \
        --churn 10:leave:3+5 --churn 20:join:3
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import sharding as sh
from repro.configs import ARCH_IDS, SHAPES, cell_status, get_config
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh
from repro.train import step as step_lib


def _tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               dfl: bool = False, extra_rules=None, cfg_overrides=None,
               mesh=None, dfl_cfg=None):
    """Returns (record dict, lowered, compiled). ``mesh`` overrides the
    production mesh (hillclimb experiments re-viewing the same chips)."""
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.scaled(**cfg_overrides)
    shape = SHAPES[shape_name]
    ok, why = cell_status(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skip",
                "reason": why}, None, None

    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    rules = step_lib.rules_for(cfg, shape)
    if extra_rules:
        rules.update(extra_rules)
    t0 = time.time()

    sched_info = None
    if dfl:
        # audit the gossip lowering up front: log ttl-ball coverage and the
        # collective count, and fail fast on an under-covering schedule
        # rather than silently lowering a round with partial delivery
        from repro.core import dfl as dfl_lib
        dfl_cfg = dfl_cfg or dfl_lib.DFLConfig()
        fed_size = mesh.shape[dfl_lib.fed_axis_for(mesh)]
        sched_info = dfl_lib.schedule_report(dfl_cfg, fed_size, strict=True)
        print(f"[dryrun] gossip schedule: topology={sched_info['topology']} "
              f"ttl={sched_info['ttl']} schedule={sched_info['schedule']} "
              f"coverage={sched_info['coverage']:.3f} "
              f"num_collectives={sched_info['num_collectives']}")

    with mesh, sh.activation_sharding(mesh, rules):
        if dfl:
            lowered = dfl_lib.lower_gossip_round(cfg, shape, mesh, rules,
                                                 dfl=dfl_cfg,
                                                 schedule_checked=True)
        elif shape.kind == "train":
            state, axes = step_lib.abstract_train_state(cfg)
            batch = step_lib.input_specs(cfg, shape)
            s_sh = step_lib.state_shardings(state, axes, mesh, rules)
            b_sh = step_lib.batch_shardings(cfg, shape, batch, mesh, rules)
            fn = step_lib.make_train_step(cfg)
            lowered = jax.jit(
                fn, in_shardings=(s_sh, b_sh), donate_argnums=(0,),
            ).lower(state, batch)
        elif shape.kind == "prefill":
            params, p_axes = step_lib.abstract_params(cfg)
            cache, c_axes = step_lib.abstract_cache(
                cfg, shape.global_batch, shape.seq_len)
            batch = step_lib.input_specs(cfg, shape)
            p_sh = sh.tree_shardings(p_axes, mesh, rules, params)
            c_sh = sh.tree_shardings(c_axes, mesh, rules, cache)
            b_sh = step_lib.batch_shardings(cfg, shape, batch, mesh, rules)
            fn = step_lib.make_prefill(cfg)
            lowered = jax.jit(
                fn, in_shardings=(p_sh, b_sh, c_sh), donate_argnums=(2,),
            ).lower(params, batch, cache)
        else:  # decode
            params, p_axes = step_lib.abstract_params(cfg)
            cache, c_axes = step_lib.abstract_cache(
                cfg, shape.global_batch, shape.seq_len)
            batch = step_lib.input_specs(cfg, shape)
            p_sh = sh.tree_shardings(p_axes, mesh, rules, params)
            c_sh = sh.tree_shardings(c_axes, mesh, rules, cache)
            b_sh = step_lib.batch_shardings(cfg, shape, batch, mesh, rules)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            fn = step_lib.make_decode(cfg)
            lowered = jax.jit(
                fn, in_shardings=(p_sh, c_sh, b_sh["tokens"], None),
                donate_argnums=(1,),
            ).lower(params, cache, batch["tokens"],
                    jax.ShapeDtypeStruct((), jnp.int32))
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    from repro.launch import hlo_cost
    walk = hlo_cost.analyze(compiled.as_text())
    terms = roofline.terms_from_walker(walk, cost)

    # model-FLOPs ratio
    params_struct, _ = step_lib.abstract_params(cfg)
    total_params = sum(x.size for x in jax.tree.leaves(params_struct))
    embed_params = params_struct["embed"]["table"].size
    mf = roofline.model_flops(cfg, total_params, embed_params, shape)
    chips = mesh.size
    hlo_flops_global = terms["hlo_flops"] * chips

    record = {
        "arch": arch, "shape": shape_name, "status": "ok",
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "mesh_axes": list(mesh.axis_names),
        "chips": chips,
        "dfl": dfl,
        "topology": (dfl_cfg.topology if (dfl and dfl_cfg is not None)
                     else ("ring" if dfl else None)),
        "gossip_schedule": sched_info,
        "step_kind": "gossip" if dfl else shape.kind,
        "params": int(total_params),
        "bytes_per_device": {
            "argument": getattr(mem, "argument_size_in_bytes", None),
            "output": getattr(mem, "output_size_in_bytes", None),
            "temp": getattr(mem, "temp_size_in_bytes", None),
            "peak": getattr(mem, "peak_memory_in_bytes", None),
        },
        "compress": (dfl_cfg.compress if (dfl and dfl_cfg is not None)
                     else None),
        "permute_bytes": (terms["collective_bytes_by_op"].get(
            "collective-permute", 0.0) if dfl else None),
        "roofline": terms,
        "model_flops_global": mf,
        "useful_flops_ratio": (mf / hlo_flops_global) if hlo_flops_global else None,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
    }
    return record, lowered, compiled


def _parse_attack_args(pairs):
    """--attack-arg k=v pairs -> {k: int|float|str} for attacks.make."""
    out = {}
    for pair in pairs or ():
        if "=" not in pair:
            raise SystemExit(f"--attack-arg expects k=v, got {pair!r}")
        k, v = pair.split("=", 1)
        try:
            out[k] = int(v)
        except ValueError:
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = v
    return out


def _parse_churn(args):
    """--churn TICK:OP:IDS entries (+ --churn-offline / --churn-decay) ->
    MembershipSchedule, or None when no churn was requested. Entries
    sharing a tick are merged into one event (the schedule's invariant)."""
    if not args.churn and not args.churn_offline:
        return None
    from repro.chain.attacks import MembershipSchedule

    by_tick = {}
    for raw in args.churn:
        parts = raw.split(":")
        if len(parts) != 3 or parts[1] not in ("join", "leave"):
            raise SystemExit(
                f"--churn expects TICK:join|leave:ID+ID..., got {raw!r}")
        try:
            tick = int(parts[0])
            ids = tuple(int(i) for i in parts[2].split("+") if i)
        except ValueError:
            raise SystemExit(
                f"--churn expects integer tick/ids, got {raw!r}")
        joins, leaves = by_tick.setdefault(tick, (set(), set()))
        (joins if parts[1] == "join" else leaves).update(ids)
    offline = tuple(int(i) for i in args.churn_offline.split("+") if i)
    return MembershipSchedule.build(
        [(t, tuple(sorted(j)), tuple(sorted(lv)))
         for t, (j, lv) in sorted(by_tick.items())],
        rejoin_decay=args.churn_decay, initial_offline=offline)


def run_lax_federation(args):
    """--engine lax: drive the vectorized tick simulator end-to-end
    (registered scenario x registered attack) instead of lowering a mesh
    step — the quick sanity pass for the §VI-D federation dynamics at a
    chosen scale/topology/adversary."""
    from repro.chain import attacks, scenarios, simlax
    from repro.core import topology as topology_lib
    from repro.core.reputation import get as get_rep

    n, ticks = args.nodes, args.ticks
    ttl = max(1, args.ttl)
    scenario_name = args.scenario or args.model
    mal = tuple(range(max(1, n // 10)))   # 10% attackers
    builder = scenarios.get(scenario_name)
    if scenario_name == "lenet":
        # the paper recipe's data/optimizer constants (single source in
        # scenarios.py), at a CLI-friendly 4 steps per training action
        sc = builder(n, malicious=mal, train_steps=4,
                     **scenarios.LENET_PAPER_HP)
        interval = (6, 6)
    else:
        sc = builder(n, dim=16, malicious=mal)
        interval = (8, 16)
    attack = attacks.make(args.attack, **_parse_attack_args(args.attack_arg))
    membership = _parse_churn(args)
    spec = attacks.FederationSpec.build(
        n, malicious=mal, attack=attack,
        initial_countdown=[1 + (5 * i) % interval[0] for i in range(n)],
        membership=membership)
    topo = topology_lib.make(args.topology, n, degree=args.topology_degree,
                             seed=1)
    shards = None
    if args.delivery == "sharded":
        # default: as many shards as devices help, capped so the node axis
        # still divides (validation in SimLaxConfig fails fast otherwise)
        shards = args.mesh or min(jax.device_count(), n)
    elif args.mesh:
        raise SystemExit("--mesh only applies to --delivery sharded")
    cfg = simlax.SimLaxConfig(
        ticks=ticks, train_interval=interval, latency=1,
        ttl=ttl, record_every=max(1, ticks // 8), seed=0,
        delivery=args.delivery, compress=args.compress, shards=shards)
    sim = simlax.LaxSimulator(sc, topo, spec, get_rep("impl2"), cfg)
    t0 = time.time()
    res = sim.run()
    wall = time.time() - t0
    honest = [i for i in range(n) if i not in mal]
    record = {
        "engine": "lax", "scenario": scenario_name, "model": scenario_name,
        "status": "ok", "attack": attack.name,
        "attack_params": _parse_attack_args(args.attack_arg),
        "delivery": args.delivery, "topology": args.topology,
        "shards": res.stats.get("shards"),
        "churn_events": len(membership.events) if membership else 0,
        "ttl": ttl, "nodes": n, "ticks": ticks,
        "compress": res.stats["compress"],
        "broadcast_bytes": res.stats["broadcast_bytes"],
        "wire_bytes": res.stats["wire_bytes"],
        "delivery_budget": res.stats["delivery_budget"],
        "compact_budget": res.stats["compact_budget"],
        "max_tick_deliveries": res.stats["max_tick_deliveries"],
        "broadcasts": res.stats["broadcasts"],
        "deliveries": res.stats["deliveries"],
        "fedavg_rounds": res.stats["fedavg_rounds"],
        "honest_acc": float(res.acc_history[-1][honest].mean()),
        "malicious_reputation": float(
            sum(res.mean_reputation(i) for i in mal) / len(mal)),
        "wall_s": round(wall, 1),
    }
    print(f"[dryrun] lax {scenario_name} attack={attack.name} n={n} "
          f"ticks={ticks} delivery={args.delivery} "
          f"compress={record['compress']} "
          f"budget={record['delivery_budget']} "
          f"deliveries={record['deliveries']} "
          f"wire_bytes={record['wire_bytes']:.3e} "
          f"honest_acc={record['honest_acc']:.3f} "
          f"rep_attacker={record['malicious_reputation']:.2f} "
          f"wall={wall:.1f}s")
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    results.append(record)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1, default=str)
    return 0


def run_sweep_cli(args):
    """--sweep: expand an attack x topology-seed x size x rng-seed grid,
    batch shape-compatible cells into single vmapped runs sharded over the
    forced host devices (see repro.chain.sweeps), and append the frontier
    tables (time-to-accuracy, accuracy-under-attack) to the JSON log."""
    from repro.chain import simlax, sweeps

    sizes = [int(s) for s in args.sweep_sizes.split(",")]
    attack_list = [None if a in ("none", "") else a
                   for a in args.sweep_attacks.split(",")]
    topo_seeds = [int(s) for s in args.sweep_topology_seeds.split(",")]
    seeds = [int(s) for s in args.sweep_seeds.split(",")]
    cells = sweeps.expand_grid(sizes=sizes, attacks=attack_list,
                               topology_seeds=topo_seeds, seeds=seeds)
    ticks = args.ticks
    cfg = simlax.SimLaxConfig(ticks=ticks, train_interval=(8, 16),
                              ttl=max(1, args.ttl),
                              record_every=max(1, ticks // 8),
                              delivery=args.delivery)
    scenario_name = args.scenario or "toy"
    n_batches = len(sweeps.plan_batches(cells, max_batch=args.max_batch))
    print(f"[dryrun] sweep: {len(cells)} federations in {n_batches} "
          f"batched dispatches over {jax.device_count()} devices")
    t0 = time.time()
    outcomes = sweeps.run_sweep(
        cells, cfg=cfg, scenario=scenario_name,
        topology_kind=args.topology, degree=args.topology_degree,
        target_acc=args.target_acc, max_batch=args.max_batch)
    wall = time.time() - t0
    tables = sweeps.frontier_tables(outcomes, target_acc=args.target_acc)
    for row in tables["accuracy_under_attack"]:
        print(f"[dryrun] sweep frontier: attack={row['attack']:<10} "
              f"n={row['size']:<5} acc={row['mean_final_honest_acc']:.3f} "
              f"rep_attacker={row['mean_attacker_reputation']}")
    print(f"[dryrun] sweep done: {len(cells)} federations in {wall:.1f}s "
          f"({len(cells) / wall:.2f} federations/s)")
    record = {
        "engine": "sweep", "status": "ok", "scenario": scenario_name,
        "topology": args.topology, "ttl": max(1, args.ttl), "ticks": ticks,
        "delivery": args.delivery, "sizes": sizes,
        "attacks": [a or "none" for a in attack_list],
        "cells": len(cells), "batches": n_batches,
        "devices": jax.device_count(),
        "wall_s": round(wall, 1),
        "federations_per_s": round(len(cells) / wall, 2),
        "outcomes": [o.row() for o in outcomes],
        "frontier": tables,
    }
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    results.append(record)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1, default=str)
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--dfl", action="store_true",
                    help="lower the DFL gossip round instead of the plain step")
    ap.add_argument("--engine", default="mesh", choices=("mesh", "lax"),
                    help="mesh: lower+compile step cells (default); "
                    "lax: run the vectorized tick simulator end-to-end")
    from repro.chain.attacks import names as attack_names
    from repro.chain.scenarios import names as scenario_names
    ap.add_argument("--scenario", default=None, choices=scenario_names(),
                    help="registered federation scenario for --engine lax")
    ap.add_argument("--model", default="toy", choices=scenario_names(),
                    help="deprecated alias for --scenario")
    ap.add_argument("--attack", default="gaussian", choices=attack_names(),
                    help="registered attack for the poisoned senders "
                    "(--engine lax)")
    ap.add_argument("--attack-arg", action="append", default=[],
                    metavar="K=V",
                    help="attack parameter override, repeatable "
                    "(e.g. --attack gaussian --attack-arg sigma=3.0)")
    ap.add_argument("--nodes", type=int, default=64,
                    help="federation size for --engine lax")
    ap.add_argument("--ticks", type=int, default=48,
                    help="simulated ticks for --engine lax")
    ap.add_argument("--delivery", default="compact",
                    choices=("compact", "sparse", "dense", "sharded"),
                    help="receipt engine for --engine lax: compact "
                    "(segment-compacted work buffer, default), sparse "
                    "(per-receiver slot buffer), dense (N^2 oracle), "
                    "sharded (node axis shard_map-partitioned over the "
                    "forced host devices — docs/SCALING.md)")
    ap.add_argument("--mesh", type=int, default=0, metavar="SHARDS",
                    help="--delivery sharded: partition the node axis over "
                    "this many of the forced host devices (0 = one shard "
                    "per device; num nodes must divide evenly)")
    ap.add_argument("--churn", action="append", default=[],
                    metavar="TICK:OP:IDS",
                    help="membership event for --engine lax, repeatable: "
                    "OP is join|leave, IDS is '+'-separated node ids "
                    "(e.g. --churn 10:leave:3+5 --churn 20:join:3); "
                    "entries sharing a tick merge into one event. Rejoins "
                    "resume from committed params with reputation decayed "
                    "(docs/SCALING.md)")
    ap.add_argument("--churn-offline", default="", metavar="ID+ID...",
                    help="node ids offline from tick 0 (their first join "
                    "is not a rejoin: no reputation decay)")
    ap.add_argument("--churn-decay", type=float, default=0.5,
                    help="rejoin reputation decay factor in [0, 1] "
                    "(rep <- clip(decay * rep, floor, initial))")
    ap.add_argument("--compress", default=None,
                    type=lambda s: None if s in ("none", "") else s,
                    choices=(None, "int8"), metavar="{none,int8}",
                    help="wire payload quantization for broadcasts "
                    "(--dfl lowering and --engine lax): int8 ships "
                    "block-quantized models (repro.core.compression), "
                    "none ships fp32 (default)")
    from repro.core.topology import KINDS  # numpy-only module: safe pre-mesh
    ap.add_argument("--topology", default="ring", choices=KINDS,
                    help="gossip graph over the federation axis "
                    "(--dfl and --engine lax)")
    ap.add_argument("--topology-degree", type=int, default=2,
                    help="kregular/smallworld neighbor offsets per side")
    ap.add_argument("--ttl", type=int, default=1,
                    help="gossip flood radius in hops (--dfl and "
                    "--engine lax)")
    from repro.core.topology import SCHEDULES
    ap.add_argument("--gossip-schedule", default="frontier",
                    choices=SCHEDULES,
                    help="--dfl lowering: frontier (exact ttl-ball, default)"
                    " or chain (legacy under-covering oracle; fails fast on"
                    " irregular graphs at ttl >= 2)")
    ap.add_argument("--sweep", action="store_true",
                    help="run a batched federation sweep (repro.chain.sweeps)"
                    " instead of a single lax run / mesh lowering")
    ap.add_argument("--sweep-sizes", default="16,64", metavar="N,N,...",
                    help="--sweep: comma-separated federation sizes")
    ap.add_argument("--sweep-attacks", default="none,gaussian,signflip",
                    metavar="A,A,...",
                    help="--sweep: comma-separated attack registry names "
                    "('none' = honest baseline)")
    ap.add_argument("--sweep-topology-seeds", default="0", metavar="S,S,...",
                    help="--sweep: topology generator seeds (erdos/smallworld"
                    " resampling; kregular/ring ignore the seed)")
    ap.add_argument("--sweep-seeds", default="0,1", metavar="S,S,...",
                    help="--sweep: engine PRNG seeds per cell")
    ap.add_argument("--target-acc", type=float, default=0.5,
                    help="--sweep: accuracy target for time-to-accuracy")
    ap.add_argument("--max-batch", type=int, default=0,
                    help="--sweep: cap federations per batched dispatch "
                    "(0 = unlimited)")
    ap.add_argument("--out", default="experiments/dryrun.json")
    ap.add_argument("--print-hlo", action="store_true")
    args = ap.parse_args()

    if args.sweep:
        return run_sweep_cli(args)
    if args.engine == "lax":
        return run_lax_federation(args)

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch/--shape or --all required")
        cells = [(args.arch, args.shape)]

    dfl_cfg = None
    if args.dfl:
        from repro.core.dfl import DFLConfig
        dfl_cfg = DFLConfig(ttl=args.ttl, topology=args.topology,
                            topology_degree=args.topology_degree,
                            schedule=args.gossip_schedule,
                            compress=args.compress)

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    topo_tag = args.topology if args.dfl else None
    done = {(r["arch"], r["shape"], r.get("mesh"), r.get("dfl", False),
             # records predating the topology field were all ring gossip
             r.get("topology", "ring" if r.get("dfl") else None))
            for r in results if r.get("status") in ("ok", "skip")
            and "arch" in r}   # --engine lax records share the same file

    mesh_tag = "2x16x16" if args.multi_pod else "16x16"
    for arch, shape in cells:
        key = (arch, shape, mesh_tag, args.dfl, topo_tag)
        skip_key = (arch, shape, None, args.dfl, topo_tag)
        if key in done or skip_key in done:
            print(f"[dryrun] {arch} x {shape} ({mesh_tag}) cached, skipping")
            continue
        print(f"[dryrun] {arch} x {shape} mesh={mesh_tag} dfl={args.dfl} "
              f"topology={topo_tag} ...", flush=True)
        try:
            rec, lowered, compiled = lower_cell(
                arch, shape, multi_pod=args.multi_pod, dfl=args.dfl,
                dfl_cfg=dfl_cfg)
            if rec["status"] == "ok":
                print(f"  compiled in {rec['compile_s']}s; "
                      f"flops/dev={rec['roofline']['hlo_flops']:.3e} "
                      f"coll_bytes/dev={rec['roofline']['collective_bytes']:.3e} "
                      f"dominant={rec['roofline']['dominant']}")
                print(f"  memory/device: {rec['bytes_per_device']}")
                print(f"  collectives: {rec['roofline']['collectives']}")
                if args.print_hlo:
                    print(compiled.as_text()[:20000])
            else:
                print(f"  SKIP: {rec['reason']}")
        except Exception as e:
            rec = {"arch": arch, "shape": shape, "mesh": mesh_tag,
                   "dfl": args.dfl, "status": "error",
                   "error": f"{type(e).__name__}: {e}"}
            print(f"  ERROR: {type(e).__name__}: {e}")
            traceback.print_exc(limit=4)
        results.append(rec)
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, default=str)
    n_ok = sum(1 for r in results if r.get("status") == "ok")
    n_skip = sum(1 for r in results if r.get("status") == "skip")
    n_err = sum(1 for r in results if r.get("status") == "error")
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skip, {n_err} error -> {args.out}")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
