"""Render EXPERIMENTS.md tables from the dry-run JSONs.

    PYTHONPATH=src python -m repro.launch.report > experiments/tables.md
"""
from __future__ import annotations

import argparse
import json
import os

HBM_PER_CHIP = 16e9  # v5e

_NOTE = {
    "compute": ("compute-bound: raise MXU utilization (larger per-device "
                "batch or fused kernels); already near the best case"),
    "memory": ("memory-bound: cut activation traffic (flash bwd recompute, "
               "grad accumulation, bf16 residuals) or increase arithmetic "
               "intensity per HBM byte"),
    "collective": ("collective-bound: shrink cross-device bytes (DFL gossip "
                   "instead of sync all-reduce, int8 payloads, kv-head-"
                   "aligned TP degree)"),
}


def _fits(rec) -> str:
    b = rec.get("bytes_per_device", {})
    tot = (b.get("argument") or 0) + (b.get("temp") or 0)
    return f"{tot/1e9:.1f}" + ("" if tot < HBM_PER_CHIP else " **(>16G)**")


def dryrun_table(records) -> str:
    rows = ["| arch | shape | mesh | status | args+temp GB/dev | peak GB/dev "
            "| HLO GFLOP/dev | collectives (count) |",
            "|---|---|---|---|---|---|---|---|"]
    for r in records:
        if r.get("status") == "skip":
            rows.append(f"| {r['arch']} | {r['shape']} | — | SKIP: "
                        f"{r['reason']} | — | — | — | — |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r.get('mesh')} | "
                        f"ERROR {r.get('error','')[:60]} | — | — | — | — |")
            continue
        rf = r["roofline"]
        colls = ", ".join(f"{k}:{v}" for k, v in sorted(rf["collectives"].items()))
        peak = (r["bytes_per_device"].get("peak") or 0) / 1e9
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | {_fits(r)} | "
            f"{peak:.1f} | {rf['hlo_flops']/1e9:.0f} | {colls} |")
    return "\n".join(rows)


def roofline_table(records) -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | dominant "
            "| roofline frac | model GFLOP (6ND) | useful ratio | bottleneck note |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for r in records:
        if r.get("status") != "ok" or r.get("dfl"):
            continue
        rf = r["roofline"]
        ratio = r.get("useful_flops_ratio")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3f} | "
            f"{rf['memory_s']:.3f} | {rf['collective_s']:.3f} | "
            f"{rf['dominant']} | {rf['roofline_fraction']:.3f} | "
            f"{r['model_flops_global']/1e9:.0f} | "
            f"{ratio:.3f} | {_NOTE[rf['dominant']]} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments")
    args = ap.parse_args()

    def load(name):
        p = os.path.join(args.dir, name)
        return json.load(open(p)) if os.path.exists(p) else []

    single = load("dryrun_single_pod.json")
    multi = load("dryrun_multi_pod.json")
    dfl_s = load("dryrun_dfl_single_pod.json")
    dfl_m = load("dryrun_dfl_multi_pod.json")

    print("## Dry-run — single pod (16x16 = 256 chips)\n")
    print(dryrun_table(single))
    print("\n## Dry-run — multi-pod (2x16x16 = 512 chips)\n")
    print(dryrun_table(multi))
    print("\n## Dry-run — DFL gossip round (the paper's technique)\n")
    print("### single pod (fed axis = data: 16 replicas x TP-16)\n")
    print(dryrun_table(dfl_s))
    print("\n### multi-pod (fed axis = pod: 2 replicas x 16x16)\n")
    print(dryrun_table(dfl_m))
    print("\n## Roofline — single pod, per cell (v5e: 197 TF/s bf16, "
          "819 GB/s HBM, 50 GB/s/link)\n")
    print(roofline_table(single))


if __name__ == "__main__":
    main()
