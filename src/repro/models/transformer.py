"""The multi-architecture transformer: scan-over-units assembly.

A config's layer stack is grouped into identical repeating *units*
(``unit_len = lcm(len(block_pattern), moe.interleave)``). Units are scanned
with stacked params (one trace of the unit body regardless of depth — the
only way 40 dry-run cells compile in reasonable time) and optionally
rematerialized. ``num_layers % unit_len`` trailing layers run unscanned.

Entry points:
    init(key, cfg)                      -> (params, logical_axes)
    train_loss(params, cfg, batch)      -> (loss, metrics)
    prefill(params, cfg, batch, cache)  -> (last_logits, cache)
    decode_step(params, cfg, tokens, cache, position) -> (logits, cache)
    cache_init / cache_axes             -> KV/recurrent cache pytrees
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro import sharding as sh
from repro.configs.base import (ATTN, ENC_ATTN, LOCAL_ATTN, MLSTM, RGLRU, SLSTM)
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import xlstm as xlstm_lib

_ATTN_KINDS = (ATTN, LOCAL_ATTN, ENC_ATTN)
LOSS_CHUNK = 2048  # vocab-projection chunk (tokens) to bound logits memory


def unit_len(cfg) -> int:
    base = len(cfg.block_pattern)
    if cfg.moe is not None:
        base = math.lcm(base, cfg.moe.interleave)
    return base


def unit_layout(cfg) -> tuple[int, int, list[tuple[str, bool]]]:
    """(n_units, n_rest, unit_entries) where entries = (kind, is_moe)."""
    ul = unit_len(cfg)
    kinds = cfg.layer_kinds()
    entries = [(kinds[i], cfg.layer_is_moe(i)) for i in range(min(ul, cfg.num_layers))]
    return cfg.num_layers // ul, cfg.num_layers % ul, entries


# ---------------------------------------------------------------------- init
def _layer_init(key, cfg, kind, is_moe):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p, a = {}, {}
    p["norm1"], a["norm1"] = L.norm_init(cfg.d_model, cfg.norm, cfg.use_bias)
    if kind in _ATTN_KINDS:
        p["mix"], a["mix"] = attn.attn_init(k1, cfg)
    elif kind == RGLRU:
        p["mix"], a["mix"] = rglru_lib.rglru_init(k1, cfg)
    elif kind == MLSTM:
        p["mix"], a["mix"] = xlstm_lib.mlstm_init(k1, cfg)
    elif kind == SLSTM:
        p["mix"], a["mix"] = xlstm_lib.slstm_init(k1, cfg)
    else:
        raise ValueError(kind)
    if kind in (MLSTM, SLSTM):
        return p, a  # xLSTM blocks carry their own FFN/gating
    p["norm2"], a["norm2"] = L.norm_init(cfg.d_model, cfg.norm, cfg.use_bias)
    if is_moe:
        p["ffn"], a["ffn"] = moe_lib.moe_init(k2, cfg)
    else:
        p["ffn"], a["ffn"] = L.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.use_bias)
    return p, a


def _unit_init(key, cfg, entries):
    ps, as_ = [], []
    for i, (kind, is_moe) in enumerate(entries):
        p, a = _layer_init(jax.random.fold_in(key, i), cfg, kind, is_moe)
        ps.append(p)
        as_.append(a)
    return tuple(ps), tuple(as_)


def init(key, cfg):
    n_units, n_rest, entries = unit_layout(cfg)
    keys = jax.random.split(key, 4)
    params: dict[str, Any] = {}
    axes: dict[str, Any] = {}

    params["embed"], axes["embed"] = L.embed_init(keys[0], cfg.vocab_size, cfg.d_model)
    if not cfg.tie_embeddings:
        params["unembed"], axes["unembed"] = L.embed_init(keys[1], cfg.vocab_size, cfg.d_model)
    if cfg.encoder_only:  # learned absolute positions (conv-pos stub)
        params["pos"] = L.truncated_normal(keys[2], (cfg.max_seq_len, cfg.d_model), 1.0)
        axes["pos"] = (sh.SEQ, L.EMBED)
    params["final_norm"], axes["final_norm"] = L.norm_init(cfg.d_model, cfg.norm, cfg.use_bias)

    if n_units:
        unit_keys = jax.random.split(keys[3], n_units)
        stacked = [ _unit_init(k, cfg, entries) for k in unit_keys ]
        params["units"] = jax.tree.map(lambda *xs: jnp.stack(xs), *[s[0] for s in stacked])
        # stacked axes: prepend STACK to every leaf's axes
        unit_axes = stacked[0][1]
        params_like = stacked[0][0]
        axes["units"] = jax.tree.map(
            lambda a, _: (L.STACK, *a), unit_axes, params_like,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                y is None or isinstance(y, str) for y in x))
    if n_rest:
        rest_entries = entries[:n_rest]
        params["rest"], axes["rest"] = _unit_init(
            jax.random.fold_in(keys[3], 10_000), cfg, rest_entries)
    return params, axes


# --------------------------------------------------------------------- layers
def _layer_apply(p, cfg, kind, is_moe, h, positions, cache_entry):
    """One layer, full-sequence mode. Returns (h, new_cache_entry, aux)."""
    aux = jnp.zeros((), jnp.float32)
    hn = L.norm_apply(p["norm1"], h, cfg.norm)
    if kind in _ATTN_KINDS:
        y, new_cache = attn.attn_apply(p["mix"], cfg, hn, positions, kind=kind,
                                       cache=cache_entry)
    elif kind == RGLRU:
        y, new_cache = rglru_lib.rglru_apply(p["mix"], cfg, hn, cache=cache_entry)
    elif kind == MLSTM:
        y, new_cache = xlstm_lib.mlstm_apply(p["mix"], cfg, hn, cache=cache_entry)
    elif kind == SLSTM:
        y, new_cache = xlstm_lib.slstm_apply(p["mix"], cfg, hn, cache=cache_entry)
    h = h + y
    h = sh.maybe_shard(h, (sh.BATCH, sh.SEQ, None))
    if kind not in (MLSTM, SLSTM):
        hn = L.norm_apply(p["norm2"], h, cfg.norm)
        if is_moe:
            y, aux = moe_lib.moe_apply(p["ffn"], cfg, hn)
        else:
            y = L.mlp_apply(p["ffn"], hn)
        h = h + y
        h = sh.maybe_shard(h, (sh.BATCH, sh.SEQ, None))
    return h, new_cache, aux


def _layer_decode(p, cfg, kind, is_moe, h, position, cache_entry):
    aux = jnp.zeros((), jnp.float32)
    hn = L.norm_apply(p["norm1"], h, cfg.norm)
    if kind in _ATTN_KINDS:
        y, new_cache = attn.attn_decode(p["mix"], cfg, hn, position, cache_entry,
                                        kind=kind)
    elif kind == RGLRU:
        y, new_cache = rglru_lib.rglru_decode(p["mix"], cfg, hn, cache_entry)
    elif kind == MLSTM:
        y, new_cache = xlstm_lib.mlstm_decode(p["mix"], cfg, hn, cache_entry)
    elif kind == SLSTM:
        y, new_cache = xlstm_lib.slstm_decode(p["mix"], cfg, hn, cache_entry)
    h = h + y
    if kind not in (MLSTM, SLSTM):
        hn = L.norm_apply(p["norm2"], h, cfg.norm)
        if is_moe:
            y, aux = moe_lib.moe_apply(p["ffn"], cfg, hn)
        else:
            y = L.mlp_apply(p["ffn"], hn)
        h = h + y
    return h, new_cache, aux


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    policy = None
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return jax.checkpoint(fn, policy=policy)


def _stack_forward(params, cfg, h, positions, cache, decode_position=None):
    """Run all layers. cache may be None (training). Returns (h, cache, aux)."""
    n_units, n_rest, entries = unit_layout(cfg)
    decode = decode_position is not None
    aux_total = jnp.zeros((), jnp.float32)

    def unit_apply(h, unit_params, unit_cache):
        if not decode:
            # unit-boundary residual: under sequence parallelism this is the
            # (sharded) tensor remat saves per unit
            h = sh.maybe_shard(h, (sh.BATCH, sh.RES_SEQ, None))
        new_caches = []
        aux_sum = jnp.zeros((), jnp.float32)
        for i, (kind, is_moe) in enumerate(entries):
            ce = None if unit_cache is None else unit_cache[i]
            if decode:
                h, nc, aux = _layer_decode(unit_params[i], cfg, kind, is_moe, h,
                                           decode_position, ce)
            else:
                h, nc, aux = _layer_apply(unit_params[i], cfg, kind, is_moe, h,
                                          positions, ce)
            new_caches.append(nc)
            aux_sum = aux_sum + aux
        return h, tuple(new_caches), aux_sum

    if n_units:
        unit_fn = _remat(unit_apply, cfg) if not decode else unit_apply

        def scan_body(carry, xs):
            h, aux = carry
            unit_params, unit_cache = xs
            h, new_cache, aux_u = unit_fn(h, unit_params, unit_cache)
            return (h, aux + aux_u), new_cache

        ucache = cache["units"] if cache is not None else None
        if ucache is None:
            n = n_units
            ucache_xs = tuple(None for _ in entries)
            # scan requires a pytree with a leading axis; pass params only
            (h, aux_total), _ = jax.lax.scan(
                lambda c, up: (scan_body(c, (up, None))[0], None),
                (h, aux_total), params["units"])
            new_ucache = None
        else:
            (h, aux_total), new_ucache = jax.lax.scan(
                scan_body, (h, aux_total), (params["units"], ucache))
    else:
        new_ucache = None

    new_rest = None
    if n_rest:
        rc = cache["rest"] if cache is not None else None
        h, new_rest, aux_r = unit_apply_rest(params["rest"], cfg,
                                             entries[:n_rest], h, positions,
                                             rc, decode_position)
        aux_total = aux_total + aux_r

    new_cache = None
    if cache is not None:
        new_cache = {"units": new_ucache, "rest": new_rest}
    return h, new_cache, aux_total


def unit_apply_rest(rest_params, cfg, rest_entries, h, positions, rest_cache,
                    decode_position):
    new_caches = []
    aux_sum = jnp.zeros((), jnp.float32)
    for i, (kind, is_moe) in enumerate(rest_entries):
        ce = None if rest_cache is None else rest_cache[i]
        if decode_position is not None:
            h, nc, aux = _layer_decode(rest_params[i], cfg, kind, is_moe, h,
                                       decode_position, ce)
        else:
            h, nc, aux = _layer_apply(rest_params[i], cfg, kind, is_moe, h,
                                      positions, ce)
        new_caches.append(nc)
        aux_sum = aux_sum + aux
    return h, tuple(new_caches), aux_sum


# -------------------------------------------------------------------- embedding
def _embed_inputs(params, cfg, batch, dtype=jnp.bfloat16):
    """Token / frontend-stub embedding. Returns (h, positions)."""
    if cfg.frontend == "audio":
        h = batch["frame_embeds"].astype(dtype)
        B, S = h.shape[:2]
    else:
        tokens = batch["tokens"]
        B, S = tokens.shape
        h = L.embed_apply(params["embed"], tokens, dtype)
        if cfg.frontend == "vision" and "patch_embeds" in batch:
            pe = batch["patch_embeds"].astype(dtype)
            h = jax.lax.dynamic_update_slice(h, pe, (0, 0, 0))
    if cfg.encoder_only:
        h = h + params["pos"][:S].astype(dtype)
    h = sh.maybe_shard(h, (sh.BATCH, sh.SEQ, None))
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    return h, positions


def _unembed(params, cfg, h):
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return L.unembed_apply(table, h)


# ------------------------------------------------------------------ entrypoints
def train_loss(params, cfg, batch):
    """Next-token (or masked-unit) xent. batch: tokens/frame_embeds, labels,
    optional loss_mask, patch_embeds."""
    h, positions = _embed_inputs(params, cfg, batch)
    h, _, aux = _stack_forward(params, cfg, h, positions, cache=None)
    h = L.norm_apply(params["final_norm"], h, cfg.norm)

    labels = batch["labels"]
    mask = batch.get("loss_mask")
    B, S, D = h.shape
    chunk = min(LOSS_CHUNK, S)
    assert S % chunk == 0
    nchunks = S // chunk

    def chunk_loss(carry, idx):
        tot, totacc, totw = carry
        hs = jax.lax.dynamic_slice_in_dim(h, idx * chunk, chunk, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(labels, idx * chunk, chunk, axis=1)
        logits = _unembed(params, cfg, hs)
        if mask is not None:
            ms = jax.lax.dynamic_slice_in_dim(mask, idx * chunk, chunk, axis=1)
        else:
            ms = jnp.ones_like(ls, jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ls[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * ms
        acc = (jnp.argmax(logits, -1) == ls) * ms
        return (tot + nll.sum(), totacc + acc.sum(), totw + ms.sum()), None

    (tot, totacc, totw), _ = jax.lax.scan(
        chunk_loss, (jnp.zeros(()), jnp.zeros(()), jnp.zeros(())),
        jnp.arange(nchunks))
    totw = jnp.maximum(totw, 1.0)
    loss = tot / totw + 0.01 * aux
    return loss, {"loss": tot / totw, "accuracy": totacc / totw, "aux": aux}


def prefill(params, cfg, batch, cache, dtype=jnp.bfloat16):
    """Process the prompt, fill the cache, return last-token logits.
    ``dtype`` is the activation/residual dtype (blocks compute in fp32
    internally and cast back to it; fp32 here keeps the whole stack fp32 —
    the numerics oracle for prefill-vs-decode consistency checks)."""
    h, positions = _embed_inputs(params, cfg, batch, dtype=dtype)
    h, cache, _ = _stack_forward(params, cfg, h, positions, cache=cache)
    h_last = h[:, -1:]
    h_last = L.norm_apply(params["final_norm"], h_last, cfg.norm)
    logits = _unembed(params, cfg, h_last)[:, 0]
    return logits, cache


def decode_step(params, cfg, tokens, cache, position, dtype=jnp.bfloat16):
    """One decode step. tokens (B,1); position scalar int32."""
    if cfg.frontend == "audio":
        raise ValueError("encoder-only arch has no decode step")
    h = L.embed_apply(params["embed"], tokens, dtype)
    h = sh.maybe_shard(h, (sh.BATCH, sh.SEQ, None))
    h, cache, _ = _stack_forward(params, cfg, h, None, cache=cache,
                                 decode_position=position)
    h = L.norm_apply(params["final_norm"], h, cfg.norm)
    logits = _unembed(params, cfg, h)[:, 0]
    return logits, cache


# ------------------------------------------------------------------- KV caches
def _entry_cache(cfg, kind, batch, max_seq, stack: int | None):
    def maybe_stack(tree):
        if stack is None:
            return tree
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (stack, *x.shape)), tree)

    if kind in _ATTN_KINDS:
        c = attn.attn_cache_init(cfg, kind, batch, max_seq)
        seq_name = sh.KV_SEQ if kind != LOCAL_ATTN else None
        a = {k: (sh.BATCH, seq_name, L.KV_HEADS, L.HEAD_DIM) for k in ("k", "v")}
    elif kind == RGLRU:
        c = rglru_lib.rglru_cache_init(cfg, batch)
        a = {"h": (sh.BATCH, L.RNN), "conv": (sh.BATCH, None, L.RNN)}
    elif kind == MLSTM:
        c = xlstm_lib.mlstm_state_init(cfg, batch)
        a = {"C": (sh.BATCH, L.HEADS, None, None), "n": (sh.BATCH, L.HEADS, None),
             "m": (sh.BATCH, L.HEADS)}
    elif kind == SLSTM:
        c = xlstm_lib.slstm_state_init(cfg, batch)
        a = {k: (sh.BATCH, None) for k in ("c", "n", "h", "m")}
    else:
        raise ValueError(kind)
    c = maybe_stack(c)
    if stack is not None:
        a = jax.tree.map(lambda ax: (L.STACK, *ax), a,
                         is_leaf=lambda x: isinstance(x, tuple) and all(
                             y is None or isinstance(y, str) for y in x))
    return c, a


def cache_init(cfg, batch, max_seq):
    """Cache pytree + logical axes, mirroring the scan/rest layout."""
    n_units, n_rest, entries = unit_layout(cfg)
    cache: dict[str, Any] = {}
    axes: dict[str, Any] = {}
    if n_units:
        cs, as_ = zip(*[
            _entry_cache(cfg, kind, batch, max_seq, stack=n_units)
            for kind, _ in entries], strict=True)
        cache["units"] = tuple(cs)
        axes["units"] = tuple(as_)
    else:
        cache["units"] = None
        axes["units"] = None
    if n_rest:
        cs, as_ = zip(*[
            _entry_cache(cfg, kind, batch, max_seq, stack=None)
            for kind, _ in entries[:n_rest]], strict=True)
        cache["rest"] = tuple(cs)
        axes["rest"] = tuple(as_)
    else:
        cache["rest"] = None
        axes["rest"] = None
    return cache, axes


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
