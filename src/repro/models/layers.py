"""Shared neural-net building blocks (pure jnp, param pytrees as dicts).

Every ``init_*`` returns ``(params, axes)`` where ``axes`` mirrors the params
pytree with a tuple of *logical axis names* per array dim. The sharding rules
engine (repro.train.sharding) maps logical axes -> mesh axes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# Logical axis vocabulary.
EMBED = "embed"        # d_model
FFN = "ffn"            # feed-forward hidden
VOCAB = "vocab"
HEADS = "heads"        # query heads
KV_HEADS = "kv_heads"
HEAD_DIM = "head_dim"
EXPERTS = "experts"
STACK = "stack"        # scanned-layer leading dim
RNN = "rnn"            # recurrent hidden width
CONV = "conv"          # conv kernel taps

_pt = jnp.float32  # params kept fp32 (master weights); compute casts to bf16


def truncated_normal(key, shape, scale, dtype=_pt):
    stddev = scale / max(1.0, np.sqrt(shape[-1] if len(shape) else 1))
    return stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def dense_init(key, in_dim, out_dims, *, in_axis, out_axes, use_bias, scale=1.0):
    """Weight (in_dim, *out_dims) with fan-in scaled init."""
    shape = (in_dim, *out_dims)
    stddev = scale / np.sqrt(in_dim)
    w = stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape, _pt)
    p = {"w": w}
    a = {"w": (in_axis, *out_axes)}
    if use_bias:
        p["b"] = jnp.zeros(out_dims, _pt)
        a["b"] = tuple(out_axes)
    return p, a


def dense_apply(p, x, *, contract_dims=1):
    """x @ w (+ b). Contracts the last `contract_dims` dims of x with the
    first `contract_dims` dims of w."""
    w = p["w"].astype(x.dtype)
    xd = tuple(range(x.ndim - contract_dims, x.ndim))
    wd = tuple(range(contract_dims))
    y = jax.lax.dot_general(x, w, ((xd, wd), ((), ())))
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# --------------------------------------------------------------------------- norm
def norm_init(d, kind, use_bias):
    p = {"scale": jnp.ones((d,), _pt)}
    a = {"scale": (EMBED,)}
    if kind == "layernorm" and use_bias:
        p["bias"] = jnp.zeros((d,), _pt)
        a["bias"] = (EMBED,)
    return p, a


def norm_apply(p, x, kind, eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
    else:  # layernorm
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------- mlp
def mlp_init(key, d_model, d_ff, use_bias):
    """SwiGLU MLP: gate/up (d, ff) x2, down (ff, d)."""
    k1, k2, k3 = jax.random.split(key, 3)
    gate, a_gate = dense_init(k1, d_model, (d_ff,), in_axis=EMBED, out_axes=(FFN,), use_bias=use_bias)
    up, a_up = dense_init(k2, d_model, (d_ff,), in_axis=EMBED, out_axes=(FFN,), use_bias=use_bias)
    down, a_down = dense_init(k3, d_ff, (d_model,), in_axis=FFN, out_axes=(EMBED,), use_bias=use_bias)
    return (
        {"gate": gate, "up": up, "down": down},
        {"gate": a_gate, "up": a_up, "down": a_down},
    )


def mlp_apply(p, x):
    g = dense_apply(p["gate"], x)
    u = dense_apply(p["up"], x)
    h = jax.nn.silu(g) * u
    return dense_apply(p["down"], h)


# ---------------------------------------------------------------------- embedding
def embed_init(key, vocab, d_model):
    w = truncated_normal(key, (vocab, d_model), scale=1.0)
    return {"table": w}, {"table": (VOCAB, EMBED)}


def embed_apply(p, tokens, dtype=jnp.bfloat16):
    return p["table"].astype(dtype)[tokens]


def unembed_apply(p, x):
    """Project to vocab logits in fp32 for a stable softmax/xent."""
    w = p["table"].astype(x.dtype)
    logits = jax.lax.dot_general(x, w, (((x.ndim - 1,), (1,)), ((), ())))
    return logits.astype(jnp.float32)


# --------------------------------------------------------------------------- rope
def rope_freqs(head_dim, rotary_dim, theta):
    exponents = np.arange(0, rotary_dim, 2, dtype=np.float32) / rotary_dim
    return 1.0 / (theta ** exponents)  # (rotary_dim/2,)


def apply_rope(x, positions, *, rotary_dim, theta):
    """x: (..., S, H, D); positions: (..., S). Rotates the first rotary_dim dims."""
    if rotary_dim == 0:
        return x
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, rotary_dim, theta))  # (rotary_dim/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, r/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, r/2)
    sin = jnp.sin(angles)[..., None, :]
    rot, rest = x[..., :rotary_dim], x[..., rotary_dim:]
    x1, x2 = rot[..., : rotary_dim // 2], rot[..., rotary_dim // 2:]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    out = jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)
    if rotary_dim < d:
        out = jnp.concatenate([out, rest], axis=-1)
    return out


# --------------------------------------------------------------------------- loss
def softmax_xent(logits, labels, mask=None):
    """Token-level cross entropy; logits fp32 (..., V), labels int (...)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll), jnp.mean((jnp.argmax(logits, -1) == labels))
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / denom
    acc = jnp.sum((jnp.argmax(logits, -1) == labels) * mask) / denom
    return loss, acc
