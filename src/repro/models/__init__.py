"""Model zoo: multi-architecture transformer + the paper's LeNet."""
from repro.models import transformer, lenet  # noqa: F401
