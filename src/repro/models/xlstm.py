"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, chunkwise-parallel)
and sLSTM (scalar memory, sequential scan).

mLSTM recurrence per head (stabilized, exponential gating):

    m_t  = max(logsig(f̃_t) + m_{t-1}, ĩ_t)
    C_t  = f'_t C_{t-1} + i'_t v_t k_tᵀ      f' = exp(logf + m_{t-1} - m_t)
    n_t  = f'_t n_{t-1} + i'_t k_t           i' = exp(ĩ - m_t)
    h_t  = C_tᵀ q_t / max(|n_tᵀ q_t|, exp(-m_t))

Training/prefill runs the *chunkwise* form: a lax.scan over chunks carrying the
stabilized (C, n, m); within a chunk the quadratic decay-matrix form is used
(TPU-native: two MXU matmuls per chunk instead of a length-S scan). The pure
sequential recurrence lives in tests as the oracle. Decode is one recurrence
step carried in the cache.

sLSTM uses a genuine sequential lax.scan (its block-diagonal recurrent weights
make the step cheap); xlstm-125m places one sLSTM per 4 blocks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L

_PF = 2          # mLSTM up-projection factor
_FFN_PF = 4 / 3  # sLSTM trailing-FFN factor


def _proj(key, din, dout, scale=1.0):
    return scale / jnp.sqrt(din).astype(jnp.float32) * jax.random.truncated_normal(
        key, -2.0, 2.0, (din, dout), jnp.float32)


# ================================================================= mLSTM block
def mlstm_init(key, cfg):
    d = cfg.d_model
    di = _PF * d                      # inner width
    H = cfg.num_heads
    Dh = di // H
    ks = jax.random.split(key, 8)
    p = {
        "up": _proj(ks[0], d, 2 * di),
        "down": _proj(ks[1], di, d),
        "wq": _proj(ks[2], di, di),
        "wk": _proj(ks[3], di, di),
        "wv": _proj(ks[4], di, di),
        # scalar-per-head gates from the inner activations
        "wi": _proj(ks[5], di, H, scale=0.1),
        "wf": _proj(ks[6], di, H, scale=0.1),
        "bi": jnp.zeros((H,), jnp.float32),
        "bf": 3.0 + jnp.arange(H, dtype=jnp.float32) * 0.5,  # forget-bias init
        "ogate_skip": jnp.ones((di,), jnp.float32),
    }
    a = {
        "up": (L.EMBED, L.FFN), "down": (L.FFN, L.EMBED),
        "wq": (L.FFN, L.FFN), "wk": (L.FFN, L.FFN), "wv": (L.FFN, L.FFN),
        "wi": (L.FFN, L.HEADS), "wf": (L.FFN, L.HEADS),
        "bi": (L.HEADS,), "bf": (L.HEADS,), "ogate_skip": (L.FFN,),
    }
    return p, a


def _mlstm_qkv_gates(p, cfg, u):
    """u (B,S,di) -> q,k,v (B,S,H,Dh) fp32; logf, logi (B,S,H) fp32."""
    B, S, di = u.shape
    H = cfg.num_heads
    Dh = di // H
    uf = u.astype(jnp.float32)
    q = (uf @ p["wq"]).reshape(B, S, H, Dh)
    k = (uf @ p["wk"]).reshape(B, S, H, Dh) * (Dh ** -0.5)
    v = (uf @ p["wv"]).reshape(B, S, H, Dh)
    logi = uf @ p["wi"] + p["bi"]                       # ĩ
    logf = jax.nn.log_sigmoid(uf @ p["wf"] + p["bf"])   # log f
    return q, k, v, logf, logi


def _mlstm_chunk(carry, inp):
    """One chunk of the chunkwise form. carry: (C (B,H,Dh,Dh), n (B,H,Dh),
    m (B,H)); inp: q,k,v (B,Lc,H,Dh), logf, logi (B,Lc,H)."""
    C, n, m = carry
    q, k, v, logf, logi = inp
    B, Lc, H, Dh = q.shape
    F = jnp.cumsum(logf, axis=1)                        # (B,Lc,H)
    # running stabilizer: M_i = max(m_prev, max_{j<=i}(ĩ_j - F_j))
    g = jax.lax.cummax(logi - F, axis=1)
    M = jnp.maximum(m[:, None], g)                      # (B,Lc,H)
    m_new = F[:, -1] + M[:, -1]

    # intra-chunk: S_ij = (q_i k_j) exp(F_i - F_j + ĩ_j - m_i), j <= i
    logD = (F[:, :, None] - F[:, None, :] + logi[:, None, :]
            - M[:, :, None])                            # (B,i,j,H)
    mask = jnp.tril(jnp.ones((Lc, Lc), bool))
    logD = jnp.where(mask[None, :, :, None], logD, -jnp.inf)
    qk = jnp.einsum("bihd,bjhd->bijh", q, k)
    S = qk * jnp.exp(logD)
    num_intra = jnp.einsum("bijh,bjhd->bihd", S, v)
    den_intra = jnp.sum(S, axis=2)                      # Σ_j S_ij -> (B,i,H)

    # inter-chunk: weight exp(F_i + m_prev - m_i) = exp(m_prev - M_i)
    w_inter = jnp.exp(m[:, None] - M)                   # (B,Lc,H)
    num_inter = jnp.einsum("bihd,bhde->bihe", q, C) * w_inter[..., None]
    den_inter = jnp.einsum("bihd,bhd->bih", q, n) * w_inter

    m_i = F + M                                         # absolute stabilizer
    denom = jnp.maximum(jnp.abs(den_intra + den_inter), jnp.exp(-m_i))
    h = (num_intra + num_inter) / denom[..., None]      # (B,Lc,H,Dh)

    # carry update to end of chunk
    w_c = jnp.exp(m - m_new)                            # (B,H)
    w_kv = jnp.exp(F[:, -1][:, None] - F + logi - m_new[:, None])  # (B,Lc,H)
    C_new = C * w_c[..., None, None] + jnp.einsum(
        "bjh,bjhd,bjhe->bhde", w_kv, k, v)
    n_new = n * w_c[..., None] + jnp.einsum("bjh,bjhd->bhd", w_kv, k)
    return (C_new, n_new, m_new), h


def mlstm_state_init(cfg, batch):
    di = _PF * cfg.d_model
    H = cfg.num_heads
    Dh = di // H
    return {
        "C": jnp.zeros((batch, H, Dh, Dh), jnp.float32),
        "n": jnp.zeros((batch, H, Dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def mlstm_apply(p, cfg, x, *, cache=None):
    """x (B,S,d). Chunkwise over cfg.scan_chunk. Returns (y, new_cache).

    Ragged S is padded to a chunk multiple with gate-neutral positions
    (i' = 0, f' = 1): the carry is exact, padded outputs are sliced off."""
    B, S, d = x.shape
    up = x.astype(jnp.float32) @ p["up"]
    u, gate = jnp.split(up, 2, axis=-1)                 # (B,S,di) each
    q, k, v, logf, logi = _mlstm_qkv_gates(p, cfg, u)
    Lc = min(cfg.scan_chunk, S)
    pad = (-S) % Lc
    if pad:
        zpad = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        q, k, v = zpad(q), zpad(k), zpad(v)
        logf = jnp.pad(logf, ((0, 0), (0, pad), (0, 0)))          # log f = 0
        logi = jnp.pad(logi, ((0, 0), (0, pad), (0, 0)),
                       constant_values=-1e30)                      # i' = 0
    S_p = S + pad
    nc = S_p // Lc

    def to_chunks(t):
        return jnp.moveaxis(t.reshape(B, nc, Lc, *t.shape[2:]), 1, 0)

    st = cache if cache is not None else mlstm_state_init(cfg, B)
    carry = (st["C"], st["n"], st["m"])
    carry, hs = jax.lax.scan(
        _mlstm_chunk, carry,
        tuple(to_chunks(t) for t in (q, k, v, logf, logi)))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S_p, -1)[:, :S]  # (B,S,di)
    h = h + p["ogate_skip"] * u                         # learnable skip
    y = h * jax.nn.silu(gate)
    y = (y @ p["down"]).astype(x.dtype)
    new_cache = None
    if cache is not None:
        C, n, m = carry
        new_cache = {"C": C, "n": n, "m": m}
    return y, new_cache


def mlstm_decode(p, cfg, x, cache):
    """Single-token recurrence step. x (B,1,d)."""
    up = x.astype(jnp.float32) @ p["up"]
    u, gate = jnp.split(up, 2, axis=-1)
    q, k, v, logf, logi = _mlstm_qkv_gates(p, cfg, u)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                 # (B,H,Dh)
    logf, logi = logf[:, 0], logi[:, 0]                 # (B,H)
    C, n, m = cache["C"], cache["n"], cache["m"]
    m_new = jnp.maximum(logf + m, logi)
    fp = jnp.exp(logf + m - m_new)
    ip = jnp.exp(logi - m_new)
    C_new = C * fp[..., None, None] + ip[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n_new = n * fp[..., None] + ip[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n_new)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(x.shape[0], 1, -1)
    h = h + p["ogate_skip"] * u
    y = h * jax.nn.silu(gate)
    return (y @ p["down"]).astype(x.dtype), {"C": C_new, "n": n_new, "m": m_new}


# ================================================================= sLSTM block
def slstm_init(key, cfg):
    d = cfg.d_model
    H = cfg.num_heads
    Dh = d // H
    dff = int(d * _FFN_PF)
    ks = jax.random.split(key, 7)
    p = {
        # input weights for z,i,f,o stacked: (d, 4d)
        "w": _proj(ks[0], d, 4 * d),
        "b": jnp.concatenate([
            jnp.zeros((2 * d,), jnp.float32),
            jnp.ones((d,), jnp.float32),       # forget bias +1
            jnp.zeros((d,), jnp.float32)]),
        # block-diagonal recurrent weights per head: (4, H, Dh, Dh)
        "r": 0.4 * jax.random.normal(ks[1], (4, H, Dh, Dh), jnp.float32) / Dh ** 0.5,
        "ffn_up": _proj(ks[2], d, dff),
        "ffn_down": _proj(ks[3], dff, d),
    }
    a = {
        "w": (L.EMBED, L.FFN), "b": (L.FFN,),
        "r": (L.CONV, L.HEADS, L.HEAD_DIM, L.HEAD_DIM),
        "ffn_up": (L.EMBED, L.FFN), "ffn_down": (L.FFN, L.EMBED),
    }
    return p, a


def slstm_state_init(cfg, batch):
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.ones((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.zeros((batch, d), jnp.float32),
    }


def _slstm_step(p, cfg, state, wx_t):
    """One timestep. wx_t (B, 4d) precomputed input contribution."""
    H = cfg.num_heads
    B = wx_t.shape[0]
    d = wx_t.shape[1] // 4
    Dh = d // H
    c, n, h, m = state["c"], state["n"], state["h"], state["m"]
    hh = h.reshape(B, H, Dh)
    rec = jnp.stack([
        jnp.einsum("bhd,hde->bhe", hh, p["r"][g]).reshape(B, d)
        for g in range(4)], axis=-1)                    # (B,d,4)
    pre = wx_t.reshape(B, d, 4) + rec + p["b"].reshape(4, d).T
    z = jnp.tanh(pre[..., 0])
    itil = pre[..., 1]
    ftil = jax.nn.log_sigmoid(pre[..., 2])
    o = jax.nn.sigmoid(pre[..., 3])
    m_new = jnp.maximum(ftil + m, itil)
    ip = jnp.exp(itil - m_new)
    fp = jnp.exp(ftil + m - m_new)
    c_new = fp * c + ip * z
    n_new = jnp.maximum(fp * n + ip, 1e-6)
    h_new = o * (c_new / n_new)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_apply(p, cfg, x, *, cache=None):
    """x (B,S,d) -> (B,S,d). Sequential scan over time."""
    B, S, d = x.shape
    wx = x.astype(jnp.float32) @ p["w"]                 # (B,S,4d)
    st = cache if cache is not None else slstm_state_init(cfg, B)

    def step(state, xs):
        wx_t, valid = xs
        new = _slstm_step(p, cfg, state, wx_t)
        # ragged-S padding: invalid steps pass state through untouched
        new = jax.tree.map(lambda a, b: jnp.where(valid, a, b), new, state)
        return new, new["h"]

    valid = jnp.ones((S,), bool)
    st_new, hs = jax.lax.scan(step, st,
                              (jnp.moveaxis(wx, 1, 0), valid))
    h = jnp.moveaxis(hs, 0, 1)                          # (B,S,d)
    y = jax.nn.gelu(h @ p["ffn_up"]) @ p["ffn_down"]
    y = y.astype(x.dtype)
    return y, (st_new if cache is not None else None)


def slstm_decode(p, cfg, x, cache):
    wx = (x.astype(jnp.float32) @ p["w"])[:, 0]
    st = _slstm_step(p, cfg, cache, wx)
    y = jax.nn.gelu(st["h"] @ p["ffn_up"]) @ p["ffn_down"]
    return y[:, None].astype(x.dtype), st
