"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block structure (one "recurrent" temporal-mixing sublayer):

    x -> [W_gate -> GeLU] ---------------------------\
    x -> [W_x] -> causal conv1d(width 4) -> RG-LRU ->  * -> W_out

RG-LRU recurrence (elementwise, diagonal):

    r_t = sigmoid(W_r x_t + b_r)              recurrence gate
    i_t = sigmoid(W_i x_t + b_i)              input gate
    log a_t = -c * softplus(Lambda) * r_t     (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses ``jax.lax.associative_scan`` (log-depth, parallel over
time — the TPU-native adaptation of the paper's CUDA linear-recurrence scan);
decode is a single-step update carried in the cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L

_C = 8.0
_CONV_WIDTH = 4


def rglru_init(key, cfg):
    d = cfg.d_model
    d_rnn = cfg.d_model  # RecurrentGemma: RNN width == d_model
    ks = jax.random.split(key, 7)
    gate, a_gate = L.dense_init(ks[0], d, (d_rnn,), in_axis=L.EMBED, out_axes=(L.RNN,), use_bias=False)
    xproj, a_x = L.dense_init(ks[1], d, (d_rnn,), in_axis=L.EMBED, out_axes=(L.RNN,), use_bias=False)
    out, a_out = L.dense_init(ks[2], d_rnn, (d,), in_axis=L.RNN, out_axes=(L.EMBED,), use_bias=False)
    p = {
        "gate": gate,
        "x": xproj,
        "out": out,
        "conv_w": 0.01 * jax.random.normal(ks[3], (_CONV_WIDTH, d_rnn), jnp.float32),
        "conv_b": jnp.zeros((d_rnn,), jnp.float32),
        "w_r": 0.01 * jax.random.normal(ks[4], (d_rnn, d_rnn), jnp.float32),
        "b_r": jnp.zeros((d_rnn,), jnp.float32),
        "w_i": 0.01 * jax.random.normal(ks[5], (d_rnn, d_rnn), jnp.float32),
        "b_i": jnp.zeros((d_rnn,), jnp.float32),
        # Lambda init so that a^c = sigmoid(Lambda)^c spans ~[0.9, 0.999]
        "lam": jax.random.uniform(ks[6], (d_rnn,), jnp.float32, 2.0, 6.0),
    }
    a = {
        "gate": a_gate, "x": a_x, "out": a_out,
        "conv_w": (L.CONV, L.RNN), "conv_b": (L.RNN,),
        "w_r": (L.RNN, L.RNN), "b_r": (L.RNN,),
        "w_i": (L.RNN, L.RNN), "b_i": (L.RNN,),
        "lam": (L.RNN,),
    }
    return p, a


def _causal_conv(p, u, state=None):
    """Depthwise causal conv width 4. u (B,S,D). state (B, 3, D) prior inputs."""
    B, S, D = u.shape
    if state is None:
        pad = jnp.zeros((B, _CONV_WIDTH - 1, D), u.dtype)
    else:
        pad = state.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)  # (B, S+3, D)
    w = p["conv_w"].astype(u.dtype)
    y = sum(full[:, i: i + S] * w[i] for i in range(_CONV_WIDTH))
    y = y + p["conv_b"].astype(u.dtype)
    new_state = full[:, -(_CONV_WIDTH - 1):]
    return y, new_state


def _gates(p, u):
    """r/i gates and log decay. u (..., D) -> (log_a, beta*i*u) in fp32."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["w_r"] + p["b_r"])
    i = jax.nn.sigmoid(uf @ p["w_i"] + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r          # (..., D), <= 0
    a2 = jnp.exp(2.0 * log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12))
    return log_a, beta * i * uf


def rglru_cache_init(cfg, batch, dtype=jnp.bfloat16):
    d_rnn = cfg.d_model
    return {
        "h": jnp.zeros((batch, d_rnn), jnp.float32),
        "conv": jnp.zeros((batch, _CONV_WIDTH - 1, d_rnn), dtype),
    }


def rglru_apply(p, cfg, x, *, cache=None):
    """Full-sequence apply. x (B,S,d) -> (B,S,d); returns (y, new_cache)."""
    gate = jax.nn.gelu(L.dense_apply(p["gate"], x))
    u = L.dense_apply(p["x"], x)
    u, conv_state = _causal_conv(p, u, None if cache is None else cache["conv"])
    log_a, b = _gates(p, u)                               # (B,S,D) fp32
    h0 = None if cache is None else cache["h"]
    if h0 is not None:
        # fold carried state into step 0: b_0 += a_0 * h0
        b = b.at[:, 0].add(jnp.exp(log_a[:, 0]) * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 + a2, jnp.exp(a2) * b1 + b2

    log_acc, h = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    del log_acc
    y = (gate.astype(jnp.float32) * h).astype(x.dtype)
    y = L.dense_apply(p["out"], y)
    new_cache = None
    if cache is not None:
        new_cache = {"h": h[:, -1], "conv": conv_state.astype(cache["conv"].dtype)}
    return y, new_cache


def rglru_decode(p, cfg, x, cache):
    """Single-token step. x (B,1,d)."""
    gate = jax.nn.gelu(L.dense_apply(p["gate"], x))[:, 0]
    u = L.dense_apply(p["x"], x)
    u, conv_state = _causal_conv(p, u, cache["conv"])
    log_a, b = _gates(p, u[:, 0])
    h = jnp.exp(log_a) * cache["h"] + b
    y = (gate.astype(jnp.float32) * h).astype(x.dtype)[:, None]
    y = L.dense_apply(p["out"], y)
    return y, {"h": h, "conv": conv_state.astype(cache["conv"].dtype)}
