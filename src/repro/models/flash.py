"""Memory-efficient blocked attention with a custom VJP (flash-attention
recompute backward), pure jnp/lax.

Differentiating the naive blocked-scan attention makes JAX save per-KV-block
probabilities — O(S^2) residual traffic that dominates the training memory
roofline term (measured: ~60% of HBM bytes for llama3-8b train_4k). This
implementation saves only (out, logsumexp) per row and *recomputes*
probabilities blockwise in the backward pass: residuals drop to O(S), at the
cost of one extra QK^T matmul per block in bwd (the classic flash trade).

Supports: causal, bidirectional (encoder), and banded sliding-window causal
attention (exact O(S*W) FLOPs via dynamic KV band slicing). GQA layout:
q (B, Sq, KH, G, Dh); k/v (B, Skv, KH, Dh).

The Pallas TPU kernel in repro.kernels.flash_attention implements the same
forward; this function is both its oracle and the lowering used by dry-runs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def _bias(q_pos, k_pos, causal, window, kv_len=0):
    ok = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    if kv_len:
        ok &= k_pos[None, :] < kv_len
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def flash_attention(q, k, v, causal=True, window=0, q_offset=0,
                    block_q=512, block_kv=1024, kv_len=0, tri=True):
    """kv_len: static real KV length when k/v are block-padded (masks padded
    keys — required for non-causal attention; causal masks them for free).
    tri: use the triangle-packed causal path (best for training, where it
    halves bwd FLOPs/traffic; fwd-only callers pass False — the packed
    output-buffer writes cost more than the masked-block waste they save)."""
    out, _ = _fwd_impl(q, k, v, causal, window, q_offset, block_q, block_kv,
                       kv_len, tri)
    return out


def flash_attention_padded(q, k, v, causal=True, window=0, q_offset=0,
                           block_q=512, block_kv=1024, tri=True):
    """Pads Sq/Skv up to block multiples, runs flash, slices the result.
    Gradients flow through pad/slice; padded KV is masked via kv_len."""
    B, Sq, KH, G, Dh = q.shape
    Skv = k.shape[1]
    bq = min(block_q, max(Sq, 1))
    bkv = min(block_kv, max(Skv, 1))
    pq = (-Sq) % bq
    pkv = (-Skv) % bkv
    if not pq and not pkv:
        return flash_attention(q, k, v, causal, window, q_offset,
                               block_q, block_kv, 0, tri)
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pkv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0)))
    out = flash_attention(qp, kp, vp, causal, window, q_offset,
                          block_q, block_kv, Skv if pkv else 0, tri)
    return out[:, :Sq]


def _tri_pairs(nq: int, nkq: int):
    """Static (i, j) kv<=q block-pair enumeration for causal attention —
    the scan runs over exactly the nq*(nq+1)/2 unmasked pairs instead of the
    nq*nk rectangle (strictly-masked blocks cost zero FLOPs). nkq = block
    ratio bq // bkv >= 1 maps q-block i to kv blocks [0, (i+1)*nkq)."""
    import numpy as np
    i_idx, j_idx, first, last = [], [], [], []
    for i in range(nq):
        hi = (i + 1) * nkq
        for j in range(hi):
            i_idx.append(i)
            j_idx.append(j)
            first.append(j == 0)
            last.append(j == hi - 1)
    return (jnp.asarray(np.array(i_idx), jnp.int32),
            jnp.asarray(np.array(j_idx), jnp.int32),
            jnp.asarray(np.array(first)),
            jnp.asarray(np.array(last)))


def _fwd_tri(q, k, v, q_offset, block_q, block_kv, kv_len):
    """Triangle-packed causal forward (Sq == Skv, no window)."""
    B, Sq, KH, G, Dh = q.shape
    Skv = k.shape[1]
    bq = min(block_q, Sq)
    bkv = min(block_kv, bq)  # kv block never larger than q block
    nq, nk = Sq // bq, Skv // bkv
    nkq = bq // bkv
    scale = Dh ** -0.5
    qb = jnp.moveaxis(q.reshape(B, nq, bq, KH, G, Dh), 1, 0)
    kb = jnp.moveaxis(k.reshape(B, nk, bkv, KH, Dh), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, bkv, KH, Dh), 1, 0)
    i_idx, j_idx, first, last = _tri_pairs(nq, nkq)

    out0 = jnp.zeros((nq, B, bq, KH, G, Dh), q.dtype)
    lse0 = jnp.zeros((nq, B, KH, G, bq), jnp.float32)
    m0 = jnp.full((B, KH, G, bq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KH, G, bq), jnp.float32)
    a0 = jnp.zeros((B, KH, G, bq, Dh), jnp.float32)

    def step(carry, xs):
        m_r, l_r, acc, outb, lseb = carry
        i, j, is_first, is_last = xs
        m_r = jnp.where(is_first, m0, m_r)
        l_r = jnp.where(is_first, l0, l_r)
        acc = jnp.where(is_first, a0, acc)
        q_blk = jax.lax.dynamic_index_in_dim(qb, i, 0, keepdims=False)
        k_blk = jax.lax.dynamic_index_in_dim(kb, j, 0, keepdims=False)
        v_blk = jax.lax.dynamic_index_in_dim(vb, j, 0, keepdims=False)
        q_pos = q_offset + i * bq + jnp.arange(bq)
        k_pos = j * bkv + jnp.arange(bkv)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk,
                       preferred_element_type=jnp.float32) * scale
        ok = k_pos[None, :] <= q_pos[:, None]
        if kv_len:
            ok &= (k_pos < kv_len)[None, :]
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m_r, s.max(-1))
        alpha = jnp.exp(m_r - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_r * alpha + p.sum(-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype), v_blk,
                        preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + pv
        # flush on the diagonal block
        o = acc / jnp.maximum(l_new, 1e-37)[..., None]
        o = jnp.moveaxis(o, 3, 1).astype(q.dtype)
        lse = m_new + jnp.log(jnp.maximum(l_new, 1e-37))
        cur_o = jax.lax.dynamic_index_in_dim(outb, i, 0, keepdims=False)
        cur_l = jax.lax.dynamic_index_in_dim(lseb, i, 0, keepdims=False)
        outb = jax.lax.dynamic_update_index_in_dim(
            outb, jnp.where(is_last, o, cur_o), i, 0)
        lseb = jax.lax.dynamic_update_index_in_dim(
            lseb, jnp.where(is_last, lse, cur_l), i, 0)
        return (m_new, l_new, acc, outb, lseb), None

    (_, _, _, outb, lseb), _ = jax.lax.scan(
        step, (m0, l0, a0, out0, lse0), (i_idx, j_idx, first, last))
    out = jnp.moveaxis(outb, 0, 1).reshape(B, Sq, KH, G, Dh)
    lse = jnp.moveaxis(lseb, 0, 3).reshape(B, KH, G, Sq)
    return out, lse


def _bwd_tri(q, k, v, out, lse, dout, q_offset, block_q, block_kv, kv_len):
    """Triangle-packed causal backward (recompute p per pair, bf16 grads)."""
    B, Sq, KH, G, Dh = q.shape
    Skv = k.shape[1]
    bq = min(block_q, Sq)
    bkv = min(block_kv, bq)
    nq, nk = Sq // bq, Skv // bkv
    nkq = bq // bkv
    scale = Dh ** -0.5
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), -1)
    delta = jnp.moveaxis(delta.reshape(B, Sq, KH, G), 1, 3)

    qb = jnp.moveaxis(q.reshape(B, nq, bq, KH, G, Dh), 1, 0)
    dob = jnp.moveaxis(dout.reshape(B, nq, bq, KH, G, Dh), 1, 0)
    kb = jnp.moveaxis(k.reshape(B, nk, bkv, KH, Dh), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, bkv, KH, Dh), 1, 0)
    lseb = jnp.moveaxis(lse.reshape(B, KH, G, nq, bq), 3, 0)
    deltab = jnp.moveaxis(delta.reshape(B, KH, G, nq, bq), 3, 0)
    i_idx, j_idx, first, last = _tri_pairs(nq, nkq)

    dq0 = jnp.zeros((nq, B, bq, KH, G, Dh), jnp.float32)
    dk0 = jnp.zeros((nk, B, bkv, KH, Dh), jnp.float32)
    dv0 = jnp.zeros((nk, B, bkv, KH, Dh), jnp.float32)
    dqa0 = jnp.zeros((B, bq, KH, G, Dh), jnp.float32)

    def step(carry, xs):
        dq_acc, dqb, dkb, dvb = carry
        i, j, is_first, is_last = xs
        dq_acc = jnp.where(is_first, dqa0, dq_acc)
        q_blk = jax.lax.dynamic_index_in_dim(qb, i, 0, keepdims=False)
        do_blk = jax.lax.dynamic_index_in_dim(dob, i, 0, keepdims=False)
        lse_blk = jax.lax.dynamic_index_in_dim(lseb, i, 0, keepdims=False)
        delta_blk = jax.lax.dynamic_index_in_dim(deltab, i, 0, keepdims=False)
        k_blk = jax.lax.dynamic_index_in_dim(kb, j, 0, keepdims=False)
        v_blk = jax.lax.dynamic_index_in_dim(vb, j, 0, keepdims=False)
        q_pos = q_offset + i * bq + jnp.arange(bq)
        k_pos = j * bkv + jnp.arange(bkv)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk,
                       preferred_element_type=jnp.float32) * scale
        ok = k_pos[None, :] <= q_pos[:, None]
        if kv_len:
            ok &= (k_pos < kv_len)[None, :]
        s = jnp.where(ok, s, NEG_INF)
        p = jnp.exp(s - lse_blk[..., None])
        p16 = p.astype(jnp.bfloat16)
        do16 = do_blk.astype(jnp.bfloat16)
        dv = jnp.einsum("bhgqk,bqhgd->bkhd", p16, do16,
                        preferred_element_type=jnp.float32)
        dp = jnp.einsum("bqhgd,bkhd->bhgqk", do16, v_blk.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
        ds = (p * (dp - delta_blk[..., None]) * scale).astype(jnp.bfloat16)
        dq = jnp.einsum("bhgqk,bkhd->bqhgd", ds, k_blk.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
        dk = jnp.einsum("bhgqk,bqhgd->bkhd", ds, q_blk.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
        dq_acc = dq_acc + dq
        dkb = jax.lax.dynamic_update_index_in_dim(
            dkb, jax.lax.dynamic_index_in_dim(dkb, j, 0, keepdims=False) + dk,
            j, 0)
        dvb = jax.lax.dynamic_update_index_in_dim(
            dvb, jax.lax.dynamic_index_in_dim(dvb, j, 0, keepdims=False) + dv,
            j, 0)
        cur = jax.lax.dynamic_index_in_dim(dqb, i, 0, keepdims=False)
        dqb = jax.lax.dynamic_update_index_in_dim(
            dqb, jnp.where(is_last, dq_acc, cur), i, 0)
        return (dq_acc, dqb, dkb, dvb), None

    (_, dqb, dkb, dvb), _ = jax.lax.scan(
        step, (dqa0, dq0, dk0, dv0), (i_idx, j_idx, first, last))
    dq = jnp.moveaxis(dqb, 0, 1).reshape(B, Sq, KH, G, Dh).astype(q.dtype)
    dk = jnp.moveaxis(dkb, 0, 1).reshape(B, Skv, KH, Dh).astype(k.dtype)
    dv = jnp.moveaxis(dvb, 0, 1).reshape(B, Skv, KH, Dh).astype(v.dtype)
    return dq, dk, dv


def _use_tri(q, k, causal, window, q_offset, tri=True):
    return (tri and causal and not window and q.shape[1] == k.shape[1]
            and q_offset == 0)


# ------------------------------------------------------------------- forward
def _fwd_impl(q, k, v, causal, window, q_offset, block_q, block_kv,
              kv_len=0, tri=True):
    if _use_tri(q, k, causal, window, q_offset, tri):
        return _fwd_tri(q, k, v, q_offset, block_q, block_kv, kv_len)
    B, Sq, KH, G, Dh = q.shape
    Skv = k.shape[1]
    bq = min(block_q, Sq)
    assert Sq % bq == 0
    nq = Sq // bq
    scale = Dh ** -0.5
    qb = jnp.moveaxis(q.reshape(B, nq, bq, KH, G, Dh), 1, 0)

    use_band = bool(window) and causal
    band = min(Skv, window + bq) if use_band else None
    bkv = min(block_kv, Skv)
    assert Skv % bkv == 0
    nk = Skv // bkv

    def q_step(_, qi):
        i, q_blk = qi
        q_pos = q_offset + i * bq + jnp.arange(bq)
        if use_band:
            start = jnp.clip(q_offset + i * bq + bq - band, 0, Skv - band)
            k_s = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            v_s = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            k_pos = start + jnp.arange(band)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_s,
                           preferred_element_type=jnp.float32) * scale
            s = s + _bias(q_pos, k_pos, True, window, kv_len)
            m = s.max(-1)
            p = jnp.exp(s - m[..., None])
            l = p.sum(-1)                                  # (B,KH,G,bq)
            o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v_s)
            inv_l = 1.0 / jnp.maximum(l, 1e-37)
            o = o * jnp.moveaxis(inv_l, 3, 1)[..., None]   # (B,bq,KH,G,1)
            lse = m + jnp.log(jnp.maximum(l, 1e-37))
            return None, (o.astype(q.dtype), lse)

        def kv_step(carry, kj):
            m_r, l_r, acc = carry
            j, k_blk, v_blk = kj
            k_pos = j * bkv + jnp.arange(bkv)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            s = s + _bias(q_pos, k_pos, causal, 0, kv_len)
            m_new = jnp.maximum(m_r, s.max(-1))
            alpha = jnp.exp(m_r - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_r * alpha + p.sum(-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype), v_blk)
            return (m_new, l_new, acc * alpha[..., None] + pv.astype(jnp.float32)), None

        m0 = jnp.full((B, KH, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KH, G, bq), jnp.float32)
        a0 = jnp.zeros((B, KH, G, bq, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(k.reshape(B, nk, bkv, KH, Dh), 1, 0),
             jnp.moveaxis(v.reshape(B, nk, bkv, KH, Dh), 1, 0)))
        o = acc / jnp.maximum(l, 1e-37)[..., None]
        o = jnp.moveaxis(o, 3, 1).astype(q.dtype)         # (B,bq,KH,G,Dh)
        lse = m + jnp.log(jnp.maximum(l, 1e-37))          # (B,KH,G,bq)
        return None, (o, lse)

    _, (outs, lses) = jax.lax.scan(q_step, None, (jnp.arange(nq), qb))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, KH, G, Dh)
    # lses (nq, B, KH, G, bq) -> (B, KH, G, Sq)
    lse = jnp.moveaxis(lses, 0, 3).reshape(B, KH, G, Sq)
    return out, lse


def _fwd(q, k, v, causal, window, q_offset, block_q, block_kv, kv_len=0,
         tri=True):
    out, lse = _fwd_impl(q, k, v, causal, window, q_offset, block_q, block_kv,
                         kv_len, tri)
    return out, (q, k, v, out, lse)


# ------------------------------------------------------------------ backward
def _bwd(causal, window, q_offset, block_q, block_kv, kv_len, tri, res, dout):
    q, k, v, out, lse = res
    if _use_tri(q, k, causal, window, q_offset, tri):
        return _bwd_tri(q, k, v, out, lse, dout, q_offset, block_q, block_kv,
                        kv_len)
    B, Sq, KH, G, Dh = q.shape
    Skv = k.shape[1]
    bq = min(block_q, Sq)
    nq = Sq // bq
    scale = Dh ** -0.5
    use_band = bool(window) and causal
    band = min(Skv, window + bq) if use_band else None
    bkv = min(block_kv, Skv)
    nk = Skv // bkv

    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), -1)
    delta = jnp.moveaxis(delta.reshape(B, Sq, KH, G), 1, 3)   # (B,KH,G,Sq)

    qb = jnp.moveaxis(q.reshape(B, nq, bq, KH, G, Dh), 1, 0)
    dob = jnp.moveaxis(dout.reshape(B, nq, bq, KH, G, Dh), 1, 0)
    lseb = jnp.moveaxis(lse.reshape(B, KH, G, nq, bq), 3, 0)  # (nq,B,KH,G,bq)
    deltab = jnp.moveaxis(delta.reshape(B, KH, G, nq, bq), 3, 0)

    def _block_grads(q_blk, do_blk, lse_blk, delta_blk, k_s, v_s, q_pos, k_pos):
        """Recompute p for one (q block, kv span) pair and form grads.

        p/ds are cast to bf16 for the grad matmuls (fp32 accumulation via
        preferred_element_type): halves the dominant HBM traffic of the
        backward pass at no observed loss-curve difference (§Perf iter)."""
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_s,
                       preferred_element_type=jnp.float32) * scale
        s = s + _bias(q_pos, k_pos, causal, window if use_band else 0, kv_len)
        p = jnp.exp(s - lse_blk[..., None])               # (B,KH,G,bq,bkv)
        p16 = p.astype(jnp.bfloat16)
        do16 = do_blk.astype(jnp.bfloat16)
        dv = jnp.einsum("bhgqk,bqhgd->bkhd", p16, do16,
                        preferred_element_type=jnp.float32)
        dp = jnp.einsum("bqhgd,bkhd->bhgqk", do16, v_s.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
        ds = (p * (dp - delta_blk[..., None]) * scale).astype(jnp.bfloat16)
        dq = jnp.einsum("bhgqk,bkhd->bqhgd", ds, k_s.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
        dk = jnp.einsum("bhgqk,bqhgd->bkhd", ds, q_blk.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
        return dq, dk, dv

    if use_band:
        def q_step(carry, xs):
            dk_acc, dv_acc = carry
            i, q_blk, do_blk, lse_blk, delta_blk = xs
            start = jnp.clip(q_offset + i * bq + bq - band, 0, Skv - band)
            k_s = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            v_s = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            q_pos = q_offset + i * bq + jnp.arange(bq)
            k_pos = start + jnp.arange(band)
            dq, dk, dv = _block_grads(q_blk, do_blk, lse_blk, delta_blk,
                                      k_s, v_s, q_pos, k_pos)
            upd_k = jax.lax.dynamic_slice_in_dim(dk_acc, start, band, 1) + dk
            upd_v = jax.lax.dynamic_slice_in_dim(dv_acc, start, band, 1) + dv
            dk_acc = jax.lax.dynamic_update_slice_in_dim(dk_acc, upd_k, start, 1)
            dv_acc = jax.lax.dynamic_update_slice_in_dim(dv_acc, upd_v, start, 1)
            return (dk_acc, dv_acc), dq
    else:
        kb = jnp.moveaxis(k.reshape(B, nk, bkv, KH, Dh), 1, 0)
        vb = jnp.moveaxis(v.reshape(B, nk, bkv, KH, Dh), 1, 0)

        def q_step(carry, xs):
            dk_acc, dv_acc = carry
            i, q_blk, do_blk, lse_blk, delta_blk = xs
            q_pos = q_offset + i * bq + jnp.arange(bq)

            def kv_step(_, kj):
                j, k_blk, v_blk = kj
                k_pos = j * bkv + jnp.arange(bkv)
                return None, _block_grads(q_blk, do_blk, lse_blk, delta_blk,
                                          k_blk, v_blk, q_pos, k_pos)

            _, (dqs, dks, dvs) = jax.lax.scan(
                kv_step, None, (jnp.arange(nk), kb, vb))
            dq = jnp.sum(dqs, axis=0)
            dk_acc = dk_acc + jnp.moveaxis(dks, 0, 1).reshape(B, Skv, KH, Dh)
            dv_acc = dv_acc + jnp.moveaxis(dvs, 0, 1).reshape(B, Skv, KH, Dh)
            return (dk_acc, dv_acc), dq

    dk0 = jnp.zeros((B, Skv, KH, Dh), jnp.float32)
    dv0 = jnp.zeros((B, Skv, KH, Dh), jnp.float32)
    (dk, dv), dqs = jax.lax.scan(
        q_step, (dk0, dv0), (jnp.arange(nq), qb, dob, lseb, deltab))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(B, Sq, KH, G, Dh).astype(q.dtype)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_fwd, _bwd)
