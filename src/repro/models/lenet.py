"""LeNet-5 (paper §VI: MNIST experiments). Pure jnp, NHWC.

conv5x5(6) -> maxpool2 -> conv5x5(16) -> maxpool2 -> fc120 -> fc84 -> fc10,
tanh activations per the Caffe LeNet used by the paper's solver settings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init(key, cfg):
    ks = jax.random.split(key, 5)
    c1, c2 = cfg.conv_channels
    f1, f2 = cfg.fc_dims
    spatial = cfg.image_size // 4  # two 2x2 pools
    flat = spatial * spatial * c2

    def conv_w(k, kh, kw, cin, cout):
        scale = 1.0 / jnp.sqrt(jnp.asarray(kh * kw * cin, jnp.float32))
        return scale * jax.random.truncated_normal(k, -2, 2, (kh, kw, cin, cout), jnp.float32)

    def fc_w(k, din, dout):
        scale = 1.0 / jnp.sqrt(jnp.asarray(din, jnp.float32))
        return scale * jax.random.truncated_normal(k, -2, 2, (din, dout), jnp.float32)

    return {
        "c1": {"w": conv_w(ks[0], 5, 5, cfg.in_channels, c1), "b": jnp.zeros((c1,))},
        "c2": {"w": conv_w(ks[1], 5, 5, c1, c2), "b": jnp.zeros((c2,))},
        "f1": {"w": fc_w(ks[2], flat, f1), "b": jnp.zeros((f1,))},
        "f2": {"w": fc_w(ks[3], f1, f2), "b": jnp.zeros((f2,))},
        "out": {"w": fc_w(ks[4], f2, cfg.num_classes), "b": jnp.zeros((cfg.num_classes,))},
    }


def _conv(p, x):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def _pool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def forward(params, x):
    """x (B, H, W, C) float in [0,1] -> logits (B, classes)."""
    h = jnp.tanh(_conv(params["c1"], x))
    h = _pool(h)
    h = jnp.tanh(_conv(params["c2"], h))
    h = _pool(h)
    h = h.reshape(h.shape[0], -1)
    h = jnp.tanh(h @ params["f1"]["w"] + params["f1"]["b"])
    h = jnp.tanh(h @ params["f2"]["w"] + params["f2"]["b"])
    return h @ params["out"]["w"] + params["out"]["b"]


def loss_and_acc(params, batch):
    logits = forward(params, batch["images"])
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(logz - gold)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, acc


def accuracy(params, images, labels):
    logits = forward(params, images)
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
