"""Attention: GQA projections + memory-efficient blocked attention.

Three execution paths, all pure jnp/lax (the Pallas flash kernel in
repro.kernels.flash_attention shares the same math; this module is its oracle
and the dry-run lowering path):

* global causal / bidirectional: scan over query blocks, inner scan over KV
  blocks with an online softmax (fp32 running max / denom). Causal masking is
  applied per block — masked blocks still cost FLOPs (~2x waste on the strict
  upper triangle; recorded in the roofline notes and a hillclimb lever).
* sliding-window (local) attention: per query block, an exact KV *band* of
  width ``window + block_q`` is dynamically sliced, so FLOPs are O(S * W) with
  no masked-block waste.
* decode: one query token against a KV cache (full or ring-buffered window).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.flash import flash_attention_padded

NEG_INF = -2.0e38


def attn_init(key, cfg):
    d, H, KH, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    q, aq = L.dense_init(k1, d, (H, Dh), in_axis=L.EMBED, out_axes=(L.HEADS, L.HEAD_DIM), use_bias=cfg.use_bias)
    k, ak = L.dense_init(k2, d, (KH, Dh), in_axis=L.EMBED, out_axes=(L.KV_HEADS, L.HEAD_DIM), use_bias=cfg.use_bias)
    v, av = L.dense_init(k3, d, (KH, Dh), in_axis=L.EMBED, out_axes=(L.KV_HEADS, L.HEAD_DIM), use_bias=cfg.use_bias)
    o, ao = L.dense_init(k4, H * Dh, (d,), in_axis=L.HEADS, out_axes=(L.EMBED,), use_bias=cfg.use_bias)
    # reshape o to (H, Dh, d) for a 2-dim contraction
    o = dict(o)
    o["w"] = o["w"].reshape(H, Dh, d)
    ao = dict(ao)
    ao["w"] = (L.HEADS, L.HEAD_DIM, L.EMBED)
    return ({"q": q, "k": k, "v": v, "o": o}, {"q": aq, "k": ak, "v": av, "o": ao})


def _rotary_dim(cfg):
    if cfg.rope == "none":
        return 0
    if cfg.rope == "partial":  # GLM-style 2d rope: rotate half the head dims
        return cfg.resolved_head_dim // 2
    return cfg.resolved_head_dim


def _project_qkv(p, cfg, x, positions):
    B, S, _ = x.shape
    H, KH, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = L.dense_apply(p["q"], x)          # (B,S,H,Dh)
    k = L.dense_apply(p["k"], x)          # (B,S,KH,Dh)
    v = L.dense_apply(p["v"], x)
    rd = _rotary_dim(cfg)
    if rd:
        q = L.apply_rope(q, positions, rotary_dim=rd, theta=cfg.rope_theta)
        k = L.apply_rope(k, positions, rotary_dim=rd, theta=cfg.rope_theta)
    return q, k, v


def _mask_bias(q_pos, k_pos, *, causal, window, kv_valid=None):
    """Additive fp32 bias (…, bq, bkv) from absolute positions."""
    ok = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    if kv_valid is not None:
        ok &= kv_valid[None, :]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa(q, k, v, bias):
    """One-shot attention on a (small) KV span. q (B,bq,KH,G,Dh), k/v (B,bkv,KH,Dh)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32)
    s = s * scale + bias
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v)


def _blocked_global(q, k, v, *, causal, q_offset, block_q, block_kv):
    """Scan-over-blocks attention with online softmax. q (B,Sq,KH,G,Dh)."""
    B, Sq, KH, G, Dh = q.shape
    Skv = k.shape[1]
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    assert Sq % bq == 0 and Skv % bkv == 0, (Sq, bq, Skv, bkv)
    nq, nk = Sq // bq, Skv // bkv
    scale = Dh ** -0.5
    qb = q.reshape(B, nq, bq, KH, G, Dh)
    kb = k.reshape(B, nk, bkv, KH, Dh)
    vb = v.reshape(B, nk, bkv, KH, Dh)

    def q_step(_, qi):
        i, q_blk = qi  # q_blk (B,bq,KH,G,Dh)
        q_pos = q_offset + i * bq + jnp.arange(bq)

        def kv_step(carry, kj):
            m, l, acc = carry
            j, k_blk, v_blk = kj
            k_pos = j * bkv + jnp.arange(bkv)
            bias = _mask_bias(q_pos, k_pos, causal=causal, window=0)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            s = s + bias  # (B,KH,G,bq,bkv)
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype), v_blk)
            acc_new = acc * alpha[..., None] + pv.astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KH, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KH, G, bq), jnp.float32)
        a0 = jnp.zeros((B, KH, G, bq, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)))
        out = acc / jnp.maximum(l[..., None], 1e-37)
        return None, jnp.moveaxis(out, 3, 1).astype(q.dtype)  # (B,bq,KH,G,Dh)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), jnp.moveaxis(qb, 1, 0)))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, KH, G, Dh)


def _blocked_local(q, k, v, *, window, q_offset, block_q):
    """Exact banded attention: per q block slice KV[band]; O(S*(W+bq)) FLOPs."""
    B, Sq, KH, G, Dh = q.shape
    Skv = k.shape[1]
    bq = min(block_q, Sq)
    assert Sq % bq == 0
    nq = Sq // bq
    band = min(Skv, window + bq)
    qb = q.reshape(B, nq, bq, KH, G, Dh)

    def q_step(_, qi):
        i, q_blk = qi
        q_start = q_offset + i * bq
        start = jnp.clip(q_start + bq - band, 0, Skv - band)
        k_band = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
        v_band = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
        q_pos = q_start + jnp.arange(bq)
        k_pos = start + jnp.arange(band)
        bias = _mask_bias(q_pos, k_pos, causal=True, window=window)
        return None, _sdpa(q_blk, k_band, v_band, bias)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), jnp.moveaxis(qb, 1, 0)))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, KH, G, Dh)


def attn_apply(p, cfg, x, positions, *, kind, cache=None):
    """Full-sequence attention (train / prefill). Returns (y, new_cache)."""
    B, S, _ = x.shape
    H, KH, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    G = H // KH
    q, k, v = _project_qkv(p, cfg, x, positions)
    qg = q.reshape(B, S, KH, G, Dh)
    if cfg.attn_impl == "flash":
        causal = kind != "enc_attn"
        window = cfg.window if kind == "local_attn" else 0
        # triangle packing pays off when a backward pass follows (training);
        # fwd-only prefill (cache is not None) uses the rectangular scan
        ctx = flash_attention_padded(qg, k, v, causal, window, 0,
                                     cfg.attn_block_q, cfg.attn_block_kv,
                                     tri=cache is None)
    elif kind == "local_attn":
        ctx = _blocked_local(qg, k, v, window=cfg.window, q_offset=0,
                             block_q=cfg.attn_block_q)
    elif kind == "enc_attn":
        ctx = _blocked_global(qg, k, v, causal=False, q_offset=0,
                              block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv)
    else:
        ctx = _blocked_global(qg, k, v, causal=True, q_offset=0,
                              block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv)
    ctx = ctx.reshape(B, S, H, Dh)
    y = jax.lax.dot_general(ctx, p["o"]["w"].astype(x.dtype),
                            (((2, 3), (0, 1)), ((), ())))
    if "b" in p["o"]:
        y = y + p["o"]["b"].astype(x.dtype)
    new_cache = None
    if cache is not None:
        new_cache = _prefill_cache(cache, cfg, k, v, kind, seq_len=S)
    return y, new_cache


# ------------------------------------------------------------------- KV caching
def attn_cache_init(cfg, kind, batch, max_seq, dtype=jnp.bfloat16):
    KH, Dh = cfg.num_kv_heads, cfg.resolved_head_dim
    length = min(max_seq, cfg.window) if kind == "local_attn" else max_seq
    return {
        "k": jnp.zeros((batch, length, KH, Dh), dtype),
        "v": jnp.zeros((batch, length, KH, Dh), dtype),
    }


def _prefill_cache(cache, cfg, k, v, kind, seq_len):
    """Write prefill K/V into the cache. Ring layout: slot = pos % length."""
    length = cache["k"].shape[1]
    if kind == "local_attn" and seq_len > length:
        # keep the trailing `length` positions, placed at their ring slots
        tail_k, tail_v = k[:, -length:], v[:, -length:]
        pos = jnp.arange(seq_len - length, seq_len)
        slots = pos % length
        k_new = cache["k"].at[:, slots].set(tail_k.astype(cache["k"].dtype))
        v_new = cache["v"].at[:, slots].set(tail_v.astype(cache["v"].dtype))
    else:
        k_new = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k[:, :length].astype(cache["k"].dtype), 0, axis=1)
        v_new = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v[:, :length].astype(cache["v"].dtype), 0, axis=1)
    return {"k": k_new, "v": v_new}


def attn_decode(p, cfg, x, position, cache, *, kind):
    """One-token decode. x (B,1,d); position scalar int32 (same for all rows —
    batched serving with ragged positions would pass a (B,) vector; we keep the
    benchmark-shape semantics of one shared decode index)."""
    B = x.shape[0]
    H, KH, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    G = H // KH
    pos = jnp.full((B, 1), position, jnp.int32)
    q, k, v = _project_qkv(p, cfg, x, pos)  # q (B,1,H,Dh); k/v (B,1,KH,Dh)
    length = cache["k"].shape[1]
    slot = position % length if kind == "local_attn" else position
    k_new = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    v_new = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), slot, axis=1)

    qg = q.reshape(B, 1, KH, G, Dh)
    if kind == "local_attn":
        # ring buffer: slot s holds absolute position p where p % length == s
        # and p <= position; reconstruct absolute positions for masking.
        s_idx = jnp.arange(length)
        cycle = (position - s_idx) // length
        k_pos = s_idx + cycle * length  # largest pos <= position at this slot
        kv_valid = (k_pos >= 0) & (k_pos > position - cfg.window)
        bias = _mask_bias(jnp.full((1,), position), k_pos, causal=False,
                          window=0, kv_valid=kv_valid)
    else:
        k_pos = jnp.arange(length)
        bias = _mask_bias(jnp.full((1,), position), k_pos, causal=True, window=0)
    ctx = _sdpa(qg, k_new, v_new, bias).reshape(B, 1, H, Dh)
    y = jax.lax.dot_general(ctx, p["o"]["w"].astype(x.dtype),
                            (((2, 3), (0, 1)), ((), ())))
    if "b" in p["o"]:
        y = y + p["o"]["b"].astype(x.dtype)
    return y, {"k": k_new, "v": v_new}
