"""Mixture-of-experts FFN with capacity-based dispatch.

Two dispatch strategies, both expressible in plain pjit (no shard_map):

* ``per_row`` (train / prefill): router positions are computed *within each
  batch row*, so the position cumsum is local to the row — no global cumsum,
  and the dispatch buffer (B, E, C_row, d) shards batch over data and experts
  over model (expert parallelism). C_row = ceil(S * top_k / E * capacity).
* ``flat`` (decode, S == 1): tokens across the batch are dispatched together
  with a tiny (B, E) cumsum so expert FLOPs stay proportional to *active*
  params rather than computing all experts per token.

Over-capacity tokens are dropped (their combine weight is zero), the standard
Switch/GShard policy; gates are renormalized over the chosen top_k.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro import sharding as sh
from repro.models import layers as L


def moe_init(key, cfg):
    m = cfg.moe
    d, E, ff = cfg.d_model, m.num_experts, m.d_ff_expert
    keys = jax.random.split(key, 5)
    router, a_router = L.dense_init(
        keys[0], d, (E,), in_axis=L.EMBED, out_axes=(L.EXPERTS,), use_bias=False)
    scale = 1.0 / math.sqrt(d)

    def ew(key, shape):
        return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)

    p = {
        "router": router,
        "gate": ew(keys[1], (E, d, ff)),
        "up": ew(keys[2], (E, d, ff)),
        "down": (1.0 / math.sqrt(ff)) * jax.random.truncated_normal(
            keys[3], -2.0, 2.0, (E, ff, d), jnp.float32),
    }
    a = {
        "router": a_router,
        "gate": (L.EXPERTS, L.EMBED, L.FFN),
        "up": (L.EXPERTS, L.EMBED, L.FFN),
        "down": (L.EXPERTS, L.FFN, L.EMBED),
    }
    if m.shared_expert:
        sp, sa = L.mlp_init(keys[4], d, ff, use_bias=False)
        p["shared"] = sp
        a["shared"] = sa
    return p, a


def _route(p, cfg, x):
    """Router top-k. x (..., d) -> gates (..., k) fp32, experts (..., k) int32."""
    m = cfg.moe
    logits = L.dense_apply(p["router"], x).astype(jnp.float32)  # (..., E)
    gates, experts = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), m.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # load-balancing auxiliary loss (Switch): E * sum(frac_tokens * frac_prob)
    probs_mean = jnp.mean(jax.nn.softmax(logits, axis=-1).reshape(-1, m.num_experts), axis=0)
    onehot = jax.nn.one_hot(experts.reshape(-1, m.top_k)[..., 0], m.num_experts)
    frac_tokens = jnp.mean(onehot, axis=0)
    aux = m.num_experts * jnp.sum(frac_tokens * probs_mean)
    return gates, experts, aux


def gather_expert_weights(p, dtype):
    """FSDP all-gather of expert weights at use (ZeRO-3 pattern).

    Expert weights are FSDP-sharded on a contracting dim; without guidance
    XLA's SPMD partial-sums the (B,E,C,ff) activations and all-reduces them
    in fp32 (measured 4e12 B/dev on dbrx-132b). Constraining the weights to
    (experts->model, replicated, replicated) BEFORE the vmapped dispatch
    forces the cheap strategy: gather each expert's weight shards once per
    layer, keep activations batch-sharded, no giant all-reduce."""
    out = dict(p)
    for k in ("gate", "up", "down"):
        out[k] = sh.maybe_shard(p[k].astype(dtype),
                                (L.EXPERTS, None, None))
    return out


def _expert_ffn(p, xe):
    """xe (..., E, C, d) -> (..., E, C, d), batched over experts."""
    g = jnp.einsum("...ecd,edf->...ecf", xe, p["gate"].astype(xe.dtype))
    u = jnp.einsum("...ecd,edf->...ecf", xe, p["up"].astype(xe.dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("...ecf,efd->...ecd", h, p["down"].astype(xe.dtype))


def _dispatch_combine(p, cfg, x3d, capacity):
    """Capacity dispatch for x3d (R, N, d): R independent rows (sequences),
    N tokens each. Positions come from a per-row cumsum, so dispatch is local
    to the row — no global collective. Fully batched (no vmap): the dispatch
    buffer keeps its (rows->data, experts->model) sharding, which vmapped
    scatters lose (measured 16x expert-FLOP replication on dbrx-132b).
    """
    m = cfg.moe
    R, N, d = x3d.shape
    E, k = m.num_experts, m.top_k
    gates, experts, aux = _route(p, cfg, x3d)           # (R,N,k)
    flat_e = experts.reshape(R, N * k)                  # (R, N*k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (R, N*k, E)
    pos = jnp.cumsum(onehot, axis=1) - 1                # position within expert
    flat_pos = jnp.take_along_axis(
        pos, flat_e[..., None], axis=2)[..., 0]         # (R, N*k)
    keep = flat_pos < capacity
    safe_pos = jnp.where(keep, flat_pos, 0)

    # scatter tokens into (R, E, C, d) — LOCALLY (E unsharded): each data
    # shard fills its own rows' expert slots with no cross-device scatter...
    xk = jnp.repeat(x3d, k, axis=1)                     # (R, N*k, d)
    ridx = jnp.broadcast_to(jnp.arange(R)[:, None], (R, N * k))
    buf = jnp.zeros((R, E, capacity, d), x3d.dtype)
    buf = sh.maybe_shard(buf, (sh.BATCH, None, None, None))
    buf = buf.at[ridx, flat_e, safe_pos].add(
        jnp.where(keep[..., None], xk, 0))
    buf = sh.maybe_shard(buf, (sh.BATCH, None, None, None))
    # ...then reshard rows->data, experts->model (one all-to-all: the GShard
    # dispatch pattern) for the expert-parallel einsum
    buf = sh.maybe_shard(buf, (sh.BATCH, L.EXPERTS, None, None))
    ye = _expert_ffn(p, buf)                            # (R, E, C, d)
    # reshard back for the (row-local) combine gather
    ye = sh.maybe_shard(ye, (sh.BATCH, None, None, None))
    yk = ye[ridx, flat_e, safe_pos]                     # (R, N*k, d)
    w = (gates.reshape(R, N * k) * keep).astype(x3d.dtype)
    y = jnp.sum((yk * w[..., None]).reshape(R, N, k, d), axis=2)
    return y, jnp.mean(aux)


def moe_apply(p, cfg, x):
    """x (B, S, d) -> (B, S, d). Decode (S == 1) flattens the batch into a
    single dispatch row so expert FLOPs stay proportional to active params."""
    m = cfg.moe
    B, S, d = x.shape
    pg = dict(p, **gather_expert_weights(p, jnp.bfloat16))
    if S == 1:
        cap = max(1, math.ceil(B * m.top_k / m.num_experts * m.capacity_factor))
        y, aux = _dispatch_combine(pg, cfg, x.reshape(1, B, d), cap)
        y = y.reshape(B, 1, d)
    else:
        cap = max(1, math.ceil(S * m.top_k / m.num_experts * m.capacity_factor))
        y, aux = _dispatch_combine(pg, cfg, x, cap)
    if "shared" in p:
        y = y + L.mlp_apply(p["shared"], x)
    return y, aux
