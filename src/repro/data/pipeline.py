"""Sharded host data pipeline for LM training.

Produces per-step batches already laid out for the mesh: the global batch is
generated deterministically from (seed, step) so every restart resumes the
exact stream (checkpoint stores only the step counter), and each host
generates only its addressable shard — no central data server, matching DFL's
no-single-point-of-failure design.
"""
from __future__ import annotations

import numpy as np

from repro.data.synthetic import SyntheticTokens


class TokenPipeline:
    def __init__(self, vocab_size: int, global_batch: int, seq_len: int,
                 seed: int = 0, fed_nodes: int = 1):
        self.gen = SyntheticTokens(vocab_size, seed=seed)
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.seed = seed
        self.fed_nodes = fed_nodes

    def batch_at(self, step: int, node: int = 0):
        """Deterministic batch for (step, federation node)."""
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + step * 131 + node) % (2 ** 31 - 1))
        return self.gen.batch(rng, self.global_batch, self.seq_len)

    def fed_batches(self, step: int, local_steps: int = 1):
        """(F, H, B, S) token/label arrays for one DFL round."""
        toks, labs = [], []
        for f in range(self.fed_nodes):
            bt, bl = [], []
            for h in range(local_steps):
                b = self.batch_at(step * local_steps + h, node=f)
                bt.append(b["tokens"])
                bl.append(b["labels"])
            toks.append(np.stack(bt))
            labs.append(np.stack(bl))
        return {"tokens": np.stack(toks), "labels": np.stack(labs)}
