"""Non-IID dataset partitioning — Distribution-based label imbalance
(paper §VI-D, implementation of ref [23]): node k samples class c with
probability p_k[c] where p[:, c] ~ Dir_K(alpha). Smaller alpha => more
imbalanced. The paper evaluates Dir_5(1) and Dir_5(0.1).
"""
from __future__ import annotations

import numpy as np


def dirichlet_class_probs(num_nodes: int, num_classes: int, alpha: float,
                          seed: int = 0) -> np.ndarray:
    """(num_nodes, num_classes) row-normalized class sampling probabilities."""
    rng = np.random.RandomState(seed)
    # Dir over nodes per class, then normalize per node (Li et al. 2021)
    mat = rng.dirichlet([alpha] * num_nodes, size=num_classes).T  # (nodes, classes)
    mat = mat / np.maximum(mat.sum(axis=1, keepdims=True), 1e-9)
    return mat


def iid_class_probs(num_nodes: int, num_classes: int) -> np.ndarray:
    return np.full((num_nodes, num_classes), 1.0 / num_classes)
