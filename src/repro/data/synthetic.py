"""Synthetic datasets.

MNIST is not available offline, so the paper-reproduction experiments use a
*synthetic 10-class digit-like dataset*: each class is a fixed random 28x28
template; samples are the template plus Gaussian noise and a random +-2 pixel
shift. LeNet reaches >95% on it within a few hundred steps, preserving the
convergence / non-IID / poisoning dynamics the paper measures (EXPERIMENTS.md
notes this substitution).

LM training streams use a mixture-of-ngrams token generator so losses fall
below uniform (learnable structure), again with no external data.
"""
from __future__ import annotations

import numpy as np


class SyntheticMnist:
    def __init__(self, num_classes: int = 10, image_size: int = 28,
                 noise: float = 0.35, seed: int = 0):
        rng = np.random.RandomState(seed)
        self.num_classes = num_classes
        self.image_size = image_size
        self.noise = noise
        # smooth class templates (low-frequency random fields)
        base = rng.randn(num_classes, image_size // 4, image_size // 4)
        self.templates = np.stack([
            np.kron(b, np.ones((4, 4))) for b in base]).astype(np.float32)
        self.templates = np.clip(self.templates, -2, 2) * 0.5 + 0.5

    def sample(self, rng: np.random.RandomState, labels: np.ndarray):
        n = len(labels)
        imgs = self.templates[labels].copy()
        # random +-2 px shift
        for i in range(n):
            dx, dy = rng.randint(-2, 3, size=2)
            imgs[i] = np.roll(np.roll(imgs[i], dx, axis=0), dy, axis=1)
        imgs += rng.randn(n, self.image_size, self.image_size).astype(np.float32) * self.noise
        return imgs[..., None], labels

    def batch(self, rng: np.random.RandomState, batch_size: int,
              class_probs=None):
        labels = rng.choice(self.num_classes, size=batch_size, p=class_probs)
        return self.sample(rng, labels)


class SyntheticTokens:
    """Mixture-of-bigram LM stream: next-token depends on previous token via
    a sparse random transition table — learnable, non-trivial."""

    def __init__(self, vocab_size: int, seed: int = 0, branch: int = 4):
        rng = np.random.RandomState(seed)
        self.vocab = vocab_size
        self.next_tokens = rng.randint(0, vocab_size, size=(vocab_size, branch))

    def batch(self, rng: np.random.RandomState, batch_size: int, seq_len: int):
        toks = np.empty((batch_size, seq_len + 1), np.int32)
        toks[:, 0] = rng.randint(0, self.vocab, size=batch_size)
        for t in range(seq_len):
            choice = rng.randint(0, self.next_tokens.shape[1], size=batch_size)
            toks[:, t + 1] = self.next_tokens[toks[:, t], choice]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
