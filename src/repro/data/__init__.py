from repro.data.partition import dirichlet_class_probs, iid_class_probs  # noqa: F401
from repro.data.pipeline import TokenPipeline  # noqa: F401
from repro.data.synthetic import SyntheticMnist, SyntheticTokens  # noqa: F401
