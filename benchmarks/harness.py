"""Shared harness: LeNet DFL federation on synthetic MNIST (the paper's §VI
experimental setup) with timing instrumentation for the overhead tables.

MNIST itself is unavailable offline; SyntheticMnist (noise=1.5) is calibrated
so single-node LeNet saturates in the mid-90s like the paper's MNIST setup —
convergence/poisoning dynamics are preserved (see EXPERIMENTS.md §Setup).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.chain.network import SimConfig, Simulator, fully_connected
from repro.chain.node import DFLNode
from repro.configs.lenet_dfl import CONFIG as LCFG
from repro.core.reputation import ReputationImpl
from repro.data.partition import iid_class_probs
from repro.data.synthetic import SyntheticMnist
from repro.models import lenet
from repro.optim import caffe_inv, sgd_momentum

NOISE = 1.5


@dataclass
class Timers:
    acc: dict = field(default_factory=dict)

    def add(self, key: str, dt: float):
        tot, n = self.acc.get(key, (0.0, 0))
        self.acc[key] = (tot + dt, n + 1)

    def total(self, key: str) -> float:
        return self.acc.get(key, (0.0, 0))[0]

    def summary(self) -> dict:
        return {k: {"total_s": round(t, 4), "calls": n,
                    "per_call_us": round(1e6 * t / max(n, 1), 1)}
                for k, (t, n) in sorted(self.acc.items())}


class TimedNode(DFLNode):
    """DFLNode with per-sub-process wall timing (paper Tables IV/V)."""

    def __init__(self, *a, timers: Timers, **kw):
        super().__init__(*a, **kw)
        self.timers = timers

    def train_local(self, now):
        t0 = time.perf_counter()
        out = super().train_local(now)
        self.timers.add("ml/train", time.perf_counter() - t0)
        return out

    def create_transaction(self, model_params, now):
        t0 = time.perf_counter()
        tx = super().create_transaction(model_params, now)
        self.timers.add("chain/create_tx", time.perf_counter() - t0)
        return tx

    def receive_transaction(self, tx, model_params, now):
        t0 = time.perf_counter()
        if tx.d in self.seen_tx or not tx.verify(now=now):
            out = super().receive_transaction(tx, model_params, now)
            self.timers.add("chain/verify_tx", time.perf_counter() - t0)
            return out
        t1 = time.perf_counter()
        self.timers.add("chain/verify_tx", t1 - t0)
        out = super().receive_transaction(tx, model_params, now)
        # super() measures accuracy inside; split it out
        self.timers.add("ml/measure_accuracy", time.perf_counter() - t1)
        return out

    def maybe_update_model(self, now):
        t0 = time.perf_counter()
        updated = super().maybe_update_model(now)
        if updated:
            self.timers.add("ml/fedavg_update", time.perf_counter() - t0)
        return updated

    def draft_block(self, now):
        t0 = time.perf_counter()
        b = super().draft_block(now)
        self.timers.add("chain/draft_block", time.perf_counter() - t0)
        return b

    def confirm_block(self, draft):
        t0 = time.perf_counter()
        c = super().confirm_block(draft)
        self.timers.add("chain/confirm_block", time.perf_counter() - t0)
        return c

    def finalize_block(self, draft, confirmations, min_confirmations_per_tx=1):
        t0 = time.perf_counter()
        ok = super().finalize_block(draft, confirmations, min_confirmations_per_tx)
        self.timers.add("chain/finalize_block", time.perf_counter() - t0)
        return ok


def build_federation(*, num_nodes: int, rep_impl: ReputationImpl,
                     class_probs=None, malicious=(), ttl: int = 2,
                     samples_per_train: int = 16, train_steps: int = 2,
                     seed: int = 0, timers: Timers | None = None,
                     use_kernel: bool = False):
    """Returns (nodes, test_fn, dataset). class_probs (nodes, classes) rows
    are each node's label distribution (the Dirichlet partition)."""
    ds = SyntheticMnist(seed=seed, noise=NOISE)
    if class_probs is None:
        class_probs = iid_class_probs(num_nodes, ds.num_classes)
    ti, tl = ds.batch(np.random.RandomState(9999), 1024)
    ti, tl = jnp.asarray(ti), jnp.asarray(tl)
    test_fn = jax.jit(lambda p: lenet.accuracy(p, ti, tl))
    eval_acc = jax.jit(lenet.accuracy)
    opt = sgd_momentum(caffe_inv(LCFG.base_lr, LCFG.lr_gamma, LCFG.lr_power),
                       momentum=LCFG.momentum)

    @jax.jit
    def train_k(params, mu, step, imgs, labels):
        def body(carry, b):
            p, mu, s = carry
            (loss, _), g = jax.value_and_grad(lenet.loss_and_acc, has_aux=True)(
                p, {"images": b[0], "labels": b[1]})
            upd, st = opt.update(g, {"mu": mu}, p, s)
            return (jax.tree.map(lambda a, u: a + u, p, upd), st["mu"], s + 1), loss
        (p, mu, s), losses = jax.lax.scan(body, (params, mu, step), (imgs, labels))
        return p, mu, s, losses[-1]

    nodes = []
    cls = TimedNode if timers is not None else DFLNode
    for i in range(num_nodes):
        params = lenet.init(jax.random.PRNGKey(seed * 100 + i), LCFG)
        opt_state = {"mu": jax.tree.map(jnp.zeros_like, params),
                     "step": jnp.zeros((), jnp.int32)}
        rng = np.random.RandomState(seed * 100 + i)
        probs = class_probs[i]
        # local held-out set drawn from the node's OWN distribution (receipts
        # are measured on the receiver's data — §IV-B3)
        ei, el = ds.batch(np.random.RandomState(seed * 100 + i + 5000), 256,
                          class_probs=probs)
        ei, el = jnp.asarray(ei), jnp.asarray(el)

        # the paper's nodes COLLECT data over time and train on everything
        # collected so far (16 samples/s system-wide); we keep a growing
        # replay buffer per node and resample it each training action
        # bounded collection window (keeps per-action cost constant)
        CAP = 4096
        store = {"imgs": np.zeros((CAP, 28, 28, 1), np.float32),
                 "labels": np.zeros((CAP,), np.int32), "n": 0}

        def train_fn(p, _k, st=opt_state, rng=rng, probs=probs, store=store):
            im, lb = ds.batch(rng, samples_per_train, class_probs=probs)
            n = store["n"]
            sl = np.arange(n, n + len(lb)) % CAP
            store["imgs"][sl] = im
            store["labels"][sl] = lb
            store["n"] = n + len(lb)
            limit = min(store["n"], CAP)
            K, B = train_steps, 32
            idx = rng.randint(0, limit, size=(K, B))
            p, st["mu"], st["step"], loss = train_k(
                p, st["mu"], st["step"], jnp.asarray(store["imgs"][idx]),
                jnp.asarray(store["labels"][idx]))
            return p, {"loss": float(loss)}

        def eval_fn(p, ei=ei, el=el):
            return float(eval_acc(p, ei, el))

        kw = dict(name=f"node-{i}", model_structure="lenet5", params=params,
                  train_fn=train_fn, eval_fn=eval_fn, rep_impl=rep_impl,
                  ttl=ttl, malicious=(i in malicious),
                  rng=jax.random.PRNGKey(seed * 100 + i),
                  use_kernel=use_kernel)
        if timers is not None:
            kw["timers"] = timers
        nodes.append(cls(**kw))
    return nodes, test_fn, ds


def engine_pertick_speedup(n: int = 512, dim: int = 128, *,
                           quick: bool = False, ttl: int = 2,
                           degree: int = 2,
                           engines: tuple = ("sparse", "dense"),
                           train_interval: tuple = (12, 12),
                           countdown_mod: int = 12,
                           compact_budget: int | None = None,
                           ticks_pair: tuple | None = None,
                           reps: int = 2):
    """Receipt-delivery engines head-to-head on one toy scenario:
    steady-state seconds/tick each and the ratio slower/faster —
    ``engines[0]`` is the engine under test, ``engines[-1]`` the baseline
    (``("sparse", "dense")`` -> the >=3x-at-N=512 sparse acceptance line;
    ``("compact", "sparse")`` -> the >=2x-at-N=2048 compact line, run with
    a mostly-idle ``train_interval`` so receivers sit idle between
    broadcast waves). Per-tick is measured as (wall(T2)-wall(T1))/(T2-T1),
    min of 2 runs each, cancelling trace+compile; dim makes the receipt
    eval visible against the O(N^2) int bookkeeping all engines share (a
    real receipt model is far heavier still — see the LeNet scenario).
    ``compact_budget`` forwards the SimLaxConfig override (overflow still
    fails fast, so an overly tight bench budget crashes rather than
    under-measures)."""
    import time as _time

    from repro.chain import attacks, scenarios, simlax
    from repro.core import topology as topology_lib
    from repro.core.reputation import get as get_rep

    topo = topology_lib.kregular(n, degree)
    mal = tuple(range(max(1, n // 32)))
    sc = scenarios.toy_scenario(n, dim=dim, malicious=mal)
    spec = attacks.FederationSpec.build(
        n, malicious=mal,
        initial_countdown=[1 + (7 * i) % countdown_mod for i in range(n)])
    if ticks_pair is None:
        ticks_pair = (12, 96) if quick else (24, 192)
    t1, t2 = ticks_pair
    out = {"nodes": n, "dim": dim, "topology": f"kregular{degree}",
           "ttl": ttl, "train_interval": list(train_interval),
           "ticks_pair": list(ticks_pair)}
    for eng in engines:
        walls = {}
        for ticks in (t1, t2):
            cfg = simlax.SimLaxConfig(
                ticks=ticks, train_interval=train_interval, latency=1,
                ttl=ttl, record_every=10 ** 9, seed=0, delivery=eng,
                compact_budget=(compact_budget if eng == "compact"
                                else None))
            sim = simlax.LaxSimulator(sc, topo, spec, get_rep("impl2"), cfg)
            best = float("inf")
            for _ in range(reps):
                t0 = _time.perf_counter()
                sim.run()
                best = min(best, _time.perf_counter() - t0)
            walls[ticks] = best
        # floor at 0.1ms/tick: compile-time variance between the two runs
        # can otherwise swallow the whole fast-engine measurement
        out[f"{eng}_s_per_tick"] = round(
            max((walls[t2] - walls[t1]) / (t2 - t1), 1e-4), 6)
        out["delivery_budget"] = sim.delivery_budget
        if eng == "compact":
            out["compact_budget"] = sim.compact_budget
    out["speedup"] = round(
        out[f"{engines[-1]}_s_per_tick"] / out[f"{engines[0]}_s_per_tick"],
        2)
    return out


def attack_sweep(*, attack_names=None, n: int = 24, ticks: int = 300,
                 seed: int = 0, degree: int = 2, ttl: int = 2):
    """One toy-scenario run per registered attack on a FIXED topology
    (kregular(n, degree)): honest-accuracy and attacker/honest-reputation
    columns for the `malicious,attack_sweep` bench line. Returns JSON-ready
    row dicts (benchmarks/bench_malicious.py prints + persists them)."""
    from repro.chain import attacks, scenarios, simlax
    from repro.core import topology as topology_lib
    from repro.core.reputation import get as get_rep

    topo = topology_lib.kregular(n, degree)
    mal = tuple(range(max(1, n // 8)))
    honest = [i for i in range(n) if i not in mal]
    rows = []
    for name in (attack_names or attacks.names()):
        sc = scenarios.get("toy")(n, dim=8, malicious=mal, seed=seed)
        spec = attacks.FederationSpec.build(
            n, malicious=mal, attack=name,
            initial_countdown=[1 + (7 * i) % 10 for i in range(n)])
        cfg = simlax.SimLaxConfig(
            ticks=ticks, train_interval=(10, 10), latency=1, ttl=ttl,
            record_every=max(10, ticks // 10), seed=seed)
        sim = simlax.LaxSimulator(sc, topo, spec, get_rep("impl2"), cfg)
        res = sim.run()
        rows.append({
            "attack": name, "nodes": n, "ticks": ticks,
            "topology": f"kregular{degree}", "ttl": ttl,
            "malicious_frac": len(mal) / n,
            "honest_acc": float(res.acc_history[-1][honest].mean()),
            "attacker_reputation": float(np.mean(
                [res.mean_reputation(i) for i in mal])),
            "honest_reputation": float(np.mean(
                [res.mean_reputation(i) for i in honest])),
            "deliveries": res.stats["deliveries"],
        })
    return rows


def run_sim(nodes, test_fn, *, ticks: int, seed: int = 0,
            train_interval=(8, 16), record_every: int = 10,
            topology: str = "full", **topology_kw):
    """topology: any repro.core.topology kind ("full" = the paper's §VI)."""
    names = [n.name for n in nodes]
    if topology == "full":
        adj = fully_connected(names)
    else:
        from repro.core import topology as topology_lib
        adj = topology_lib.make(topology, len(names),
                                **topology_kw).as_name_dict(names)
    sim = Simulator(nodes, adj, test_fn,
                    SimConfig(ticks=ticks, seed=seed,
                              train_interval=train_interval,
                              record_every=record_every))
    sim.run()
    return sim


def curves(nodes):
    return {n.name: {"tick": [t for t, _ in n.accuracy_history],
                     "acc": [a for _, a in n.accuracy_history]}
            for n in nodes}
