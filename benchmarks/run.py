"""Benchmark aggregator — one bench per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Prints ``name,metric,...`` CSV lines; each bench also writes its JSON under
experiments/. Mapping to the paper:
    overhead     -> Tables IV, V, VII (+ the <5% claim)
    convergence  -> Figs 10, 11
    noniid       -> Figs 12, 13
    malicious    -> Figs 14, 15, 16, 17
    gossip       -> §III-B partial consensus at pod scale (link-byte roofline)
    kernels      -> Pallas kernel microbenches vs oracles
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _summarize(name, data):
    """Re-print the headline CSV lines from a cached bench JSON."""
    try:
        if name == "overhead":
            for r in data:
                print(f"overhead,{r['nodes']}-node,"
                      f"{r['blockchain_overhead_pct']}%_chain,"
                      f"under5pct={r['claim_under_5pct']}")
        elif name == "convergence":
            for r in data:
                print(f"convergence,{r['nodes']}-node,"
                      f"final_acc={r['mean_final']:.3f},auc={r['mean_auc']:.3f}")
        elif name == "noniid":
            for r in data:
                print(f"noniid,Dir5({r['alpha']}),final_acc={r['mean_final']:.3f}")
        elif name == "malicious":
            rows = data["paper"] if isinstance(data, dict) else data
            for r in rows:
                print(f"malicious,{r['impl']},"
                      f"honest_acc={r['mean_final_honest']:.3f},"
                      f"rep_malicious={r['malicious_reputation']:.2f}")
            if isinstance(data, dict):
                for r in data.get("topology_scale", []):
                    print(f"malicious,scale,{r['nodes']}nodes,{r['topology']},"
                          f"honest_acc={r['honest_acc']:.3f},"
                          f"rep_malicious={r['malicious_reputation']:.2f}")
        elif name == "gossip":
            for row in data.get("rows", []):
                print(f"gossip,ttl={row['ttl']},compress={row['compress']},"
                      f"permute_bytes={row['permute_bytes_per_round']:.3e}")
            for row in data.get("topology_rows", []):
                print(f"gossip,topology={row['topology']},"
                      f"permute_bytes={row['permute_bytes_per_round']:.3e}")
            if "reduction_fp32" in data:
                print(f"gossip,dfl_vs_syncdp_fp32,{data['reduction_fp32']}x")
                print(f"gossip,dfl_vs_syncdp_int8,{data['reduction_int8']}x")
            if data.get("simulator"):
                s = data["simulator"]
                print(f"gossip,simlax_speedup,{s['nodes']}nodes,"
                      f"{s['speedup']}x")
        elif name == "kernels":
            for r in data:
                print(f"kernels,{r['kernel']},{r['s_per_call']*1e6:.0f}us_per_call")
    except Exception as e:  # malformed cache: force a rerun instead
        raise KeyError(str(e)) from e


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="short runs (CI); full runs feed EXPERIMENTS.md")
    ap.add_argument("--only", default=None)
    ap.add_argument("--force", action="store_true",
                    help="ignore cached experiments/bench_<name>.json")
    args = ap.parse_args(argv)

    from benchmarks import (bench_convergence, bench_gossip, bench_kernels,
                            bench_malicious, bench_noniid, bench_overhead)
    benches = {
        "kernels": bench_kernels.main,
        "gossip": bench_gossip.main,
        "overhead": bench_overhead.main,
        "convergence": bench_convergence.main,
        "noniid": bench_noniid.main,
        "malicious": bench_malicious.main,
    }
    os.makedirs("experiments", exist_ok=True)
    results = {}
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        print(f"=== bench:{name} ===", flush=True)
        cache = os.path.join("experiments", f"bench_{name}.json")
        if not args.force and not args.quick and os.path.exists(cache):
            # full sim runs take ~minutes each; reuse the recorded full run
            # (delete experiments/bench_<name>.json or pass --force to redo)
            try:
                data = json.load(open(cache))
                _summarize(name, data)
                results[name] = data
                print(f"bench,{name},cached({cache})", flush=True)
                continue
            except Exception:
                pass
        try:
            results[name] = fn(quick=args.quick)
            with open(cache, "w") as f:
                json.dump(results[name], f, indent=1, default=str)
            print(f"bench,{name},ok,{time.time()-t0:.1f}s", flush=True)
        except Exception as e:  # pragma: no cover
            import traceback
            traceback.print_exc(limit=4)
            print(f"bench,{name},ERROR,{type(e).__name__}: {e}", flush=True)
            results[name] = {"error": str(e)}
    with open("experiments/bench_all.json", "w") as f:
        json.dump(results, f, indent=1, default=str)
    return 0


if __name__ == "__main__":
    sys.exit(main())
