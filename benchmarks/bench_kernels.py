"""Kernel microbenchmarks: wfedavg / quantize / flash forward.

On this CPU container Pallas runs in interpret mode, so wall numbers are
indicative only; the meaningful output is bytes-moved per call (the roofline
quantity) and the allclose check against each oracle.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.quantize.ops import dequantize_flat, quantize_flat
from repro.kernels.wfedavg import ops as wf_ops


def _time(fn, reps=3):
    fn()  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps


def main(quick: bool = False):
    out = []
    key = jax.random.PRNGKey(0)

    # wfedavg: N=10 models x 1M params (buffer size of reputation impl2)
    n, d = 10, 1 << 18 if quick else 1 << 20
    ms = jax.random.normal(key, (n, d))
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (n,)))
    prev = jnp.zeros((d,))
    t = _time(lambda: wf_ops.weighted_fedavg_tree({"p": ms}, w, {"p": prev})["p"])
    bytes_moved = (n + 2) * d * 4
    out.append({"kernel": "wfedavg", "n": n, "d": d, "s_per_call": t,
                "bytes_per_call": bytes_moved,
                "note": "interpret-mode on CPU; TPU path identical"})
    print(f"kernels,wfedavg,{t*1e6:.0f}us_per_call,bytes={bytes_moved:.2e}")

    # quantize round-trip on a gossip payload
    x = jax.random.normal(key, (d,))
    q, s, L = quantize_flat(x)
    t = _time(lambda: dequantize_flat(*quantize_flat(x)))
    rel = float(jnp.max(jnp.abs(dequantize_flat(q, s, L) - x))
                / jnp.max(jnp.abs(x)))
    out.append({"kernel": "quantize+dequantize", "d": d, "s_per_call": t,
                "payload_ratio": 0.2502, "max_rel_err": rel})
    print(f"kernels,quantize,{t*1e6:.0f}us_per_call,rel_err={rel:.4f}")

    # flash fwd vs ref
    B, S, H, KH, Dh = 1, 256, 4, 2, 64
    q = jax.random.normal(key, (B, S, H, Dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KH, Dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KH, Dh))
    t = _time(lambda: flash_attention(q, k, v, causal=True,
                                      block_q=64, block_kv=64), reps=1)
    ref = attention_ref(q, jnp.repeat(k, 2, 2), jnp.repeat(v, 2, 2), causal=True)
    got = flash_attention(q, k, v, causal=True, block_q=64, block_kv=64)
    err = float(jnp.max(jnp.abs(got - ref)))
    out.append({"kernel": "flash_attention_fwd", "S": S, "s_per_call": t,
                "max_err_vs_ref": err})
    print(f"kernels,flash,{t*1e6:.0f}us_per_call,err={err:.2e}")
    return out


if __name__ == "__main__":
    json.dump(main(), open("experiments/bench_kernels.json", "w"), indent=1)
