"""Paper Tables IV / V / VII: blockchain overhead vs ML time.

Runs the timed 2-node and 4-node federations and reports, per category,
total seconds + the overhead percentage P_oh = T_oh / T_subprocess (Eq. 4).
The paper's claim: blockchain consumes <5% of hardware resources overall.
"""
from __future__ import annotations

import json

from benchmarks.harness import Timers, build_federation, run_sim
from repro.core.reputation import get as get_rep


def run(num_nodes: int, ticks: int = 250, seed: int = 0):
    timers = Timers()
    nodes, test_fn, _ = build_federation(
        num_nodes=num_nodes, rep_impl=get_rep("impl1"),
        samples_per_train=16 // num_nodes * 2, timers=timers, seed=seed)
    sim = run_sim(nodes, test_fn, ticks=ticks, seed=seed, record_every=50)
    s = timers.summary()
    chain_s = sum(v["total_s"] for k, v in s.items() if k.startswith("chain/"))
    ml_s = sum(v["total_s"] for k, v in s.items() if k.startswith("ml/"))
    total = chain_s + ml_s
    return {
        "nodes": num_nodes,
        "by_subprocess": s,
        "chain_total_s": round(chain_s, 3),
        "ml_total_s": round(ml_s, 3),
        "blockchain_overhead_pct": round(100 * chain_s / max(total, 1e-9), 2),
        "blocks": sim.stats["blocks"],
        "tx_per_block": {n.name: (n.ledger.blocks[-1].transactions and
                                  len(n.ledger.blocks[-1].transactions))
                         for n in nodes},
        "claim_under_5pct": bool(chain_s / max(total, 1e-9) < 0.05),
    }


def main(quick: bool = False):
    ticks = 120 if quick else 300
    rows = []
    for n in (2, 4):
        r = run(n, ticks=ticks)
        rows.append(r)
        print(f"overhead,{n}-node,{r['blockchain_overhead_pct']}%_chain,"
              f"ml={r['ml_total_s']}s,chain={r['chain_total_s']}s,"
              f"under5pct={r['claim_under_5pct']}")
        for k, v in r["by_subprocess"].items():
            print(f"  {k},{v['per_call_us']}us_per_call,calls={v['calls']}")
    return rows


if __name__ == "__main__":
    json.dump(main(), open("experiments/bench_overhead.json", "w"), indent=1)
