"""Paper Figs 12/13: non-IID performance — Dirichlet label partitions.

5 nodes, Dir_5(1) and Dir_5(0.1). Paper: Dir(1) reaches >=90%; Dir(0.1)
still converges to ~70% on the global test set.
"""
from __future__ import annotations

import json

from benchmarks.harness import build_federation, curves, run_sim
from repro.core.reputation import get as get_rep
from repro.data.partition import dirichlet_class_probs


def run(alpha: float, ticks: int, seed: int = 0, nodes_n: int = 5):
    probs = dirichlet_class_probs(nodes_n, 10, alpha, seed=seed)
    nodes, test_fn, _ = build_federation(
        num_nodes=nodes_n, rep_impl=get_rep("impl1"), class_probs=probs,
        samples_per_train=12, train_steps=8, seed=seed)
    run_sim(nodes, test_fn, ticks=ticks, seed=seed)
    cs = curves(nodes)
    final = {k: v["acc"][-1] for k, v in cs.items()}
    return {"alpha": alpha, "curves": cs, "final": final,
            "mean_final": sum(final.values()) / len(final)}


def main(quick: bool = False):
    ticks = 150 if quick else 600
    out = []
    for alpha in (1.0, 0.1):
        r = run(alpha, ticks)
        out.append(r)
        print(f"noniid,Dir5({alpha}),final_acc={r['mean_final']:.3f}")
    if len(out) == 2:
        print(f"noniid,dir0.1_degrades_vs_dir1,"
              f"{out[1]['mean_final'] < out[0]['mean_final']}")
    return out


if __name__ == "__main__":
    json.dump(main(), open("experiments/bench_noniid.json", "w"), indent=1)
