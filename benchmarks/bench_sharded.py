"""Sharded-engine benchmark: the `gossip,sharded_vs_single` acceptance line
plus the carried `gossip,cond_vs_select` batching-delta row.

Runs on 8 forced host devices (re-execs itself with XLA_FLAGS when the
parent interpreter initialized jax with fewer — same pattern as
bench_gossip): the node axis of the compact scan state is partitioned over
a `make_fed_mesh(8,1,1)` mesh via shard_map (`delivery="sharded"`), and
each row times it against the single-device compact engine on the SAME toy
scenario with activity-matched work-buffer budgets.

* `gossip,sharded_vs_single` — seconds/tick each way at N up to 8192,
  kregular degree 2, staggered broadcast phases. On a CPU host mesh the
  shards share the same physical cores, so the "speedup" ratio
  (single/sharded, higher is better) is an OVERHEAD bound, not a win: the
  acceptance floor in check_regress (`ACCEPTANCE_FLOORS`) pins the
  partition + ppermute halo tax, and a drop means the sharded lowering
  regressed (e.g. an accidental all-gather of the (N, budget) state — the
  structural twin of this gate lives in tools/hlo_audit.py). The per-N rows
  double as the nodes-vs-ticks/sec table in docs/SCALING.md.
* `gossip,cond_vs_select` — the measured cost of the PR 6 deferral: under
  `BatchedFederationSpec` the scan's `lax.cond`s (train / deliver / eval)
  lower to `select`, so every federation pays every branch every tick even
  when its phase is idle. Phase-ALIGNED federations make the delta visible
  (a single run skips the train branch on 31/32 ticks; the batched run
  cannot): the row records batched-per-federation vs single seconds/tick.
  Phase-sorted batching stays deferred — rationale in docs/SWEEPS.md.

Quick mode keeps shards=8 but drops the big-N rows; the JSON is merged into
experiments/bench_gossip.json by bench_gossip.main() for check_regress.
"""
from __future__ import annotations

import json
import time

import jax

from repro.chain import attacks, scenarios, simlax
from repro.core import topology as topology_lib
from repro.core.reputation import get as get_rep

SHARDS = 8


def _pertick(sc, topo, spec, *, delivery, ticks_pair, interval, budget,
             shards=None, dim_note=None, reps=2, seed=0):
    """Steady-state seconds/tick of one engine via two-window differencing
    ((wall(T2)-wall(T1))/(T2-T1), min of `reps` runs each) — cancels
    trace+compile like benchmarks.harness.engine_pertick_speedup."""
    t1, t2 = ticks_pair
    walls, last = {}, None
    for ticks in (t1, t2):
        # free the previous window's result before timing this one: at
        # N=8192 the final slot + reputation state is >1GB, and holding it
        # across windows adds enough allocator noise to invert the
        # differencing (observed: wall(T2) <= wall(T1), clamped to floor)
        last = None
        cfg = simlax.SimLaxConfig(
            ticks=ticks, train_interval=(interval, interval), latency=1,
            ttl=2, record_every=10 ** 9, seed=seed, delivery=delivery,
            shards=shards, compact_budget=budget)
        sim = simlax.LaxSimulator(sc, topo, spec, get_rep("impl2"), cfg)
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            last = sim.run()
            best = min(best, time.perf_counter() - t0)
        walls[ticks] = best
    # same 0.1ms/tick floor as the harness: compile-variance guard
    return max((walls[t2] - walls[t1]) / (t2 - t1), 1e-4), last


def sharded_vs_single(quick: bool = False):
    """Per-tick cost of the shard_map-partitioned engine vs the
    single-device compact engine, one row per N (shards fixed at 8)."""
    interval, degree, dim = 64, 2, 16
    # the N=8192 headline runs even under --quick (CI's mode): like
    # compact_vs_sparse, the acceptance number must be in the CI JSON —
    # quick only drops the mid-scale row and shortens the windows
    sizes = (1024, 8192) if quick else (1024, 2048, 8192)
    ticks_pair = (16, 80) if quick else (24, 120)
    rows = []
    for n in sizes:
        topo = topology_lib.kregular(n, degree)
        sc = scenarios.toy_scenario(n, dim=dim, malicious=(0,))
        spec = attacks.FederationSpec.build(
            n, malicious=(0,),
            initial_countdown=[1 + (7 * i) % interval for i in range(n)])
        # activity-matched work buffers (overflow fails fast, so a tight
        # bench budget crashes rather than under-measures): staggered
        # phases land ~n*ball/interval due deliveries per tick (ball = 8 at
        # degree 2 / ttl 2), 2x headroom; the sharded budget is per-shard
        global_budget = 2 * n * 8 // interval
        single_s, res_c = _pertick(
            sc, topo, spec, delivery="compact", ticks_pair=ticks_pair,
            interval=interval, budget=global_budget, reps=3)
        # keep only the scalar before timing the other engine — the full
        # result pins >1GB of final state at N=8192 (see _pertick)
        deliveries_c, res_c = res_c.stats["deliveries"], None
        shard_s, res_s = _pertick(
            sc, topo, spec, delivery="sharded", ticks_pair=ticks_pair,
            interval=interval, budget=max(1, global_budget // SHARDS),
            shards=SHARDS, reps=3)
        deliveries_s, res_s = res_s.stats["deliveries"], None
        # cheap honesty check (the bitwise pin lives in tests/test_sharded.py)
        if deliveries_s != deliveries_c:
            raise AssertionError(
                f"sharded_vs_single N={n}: deliveries diverged "
                f"{deliveries_s} != {deliveries_c}")
        row = {
            "nodes": n, "shards": SHARDS, "dim": dim,
            "topology": f"kregular{degree}", "train_interval": interval,
            "ticks_pair": list(ticks_pair),
            "single_s_per_tick": round(single_s, 6),
            "sharded_s_per_tick": round(shard_s, 6),
            "single_ticks_per_s": round(1.0 / single_s, 2),
            "sharded_ticks_per_s": round(1.0 / shard_s, 2),
            "speedup": round(single_s / shard_s, 2),
        }
        rows.append(row)
        print(f"gossip,sharded_vs_single,{n}nodes,shards={SHARDS},"
              f"{row['speedup']}x,single={single_s:.4f}s/tick,"
              f"sharded={shard_s:.4f}s/tick")
    out = dict(rows[-1])  # the largest-N row is the gated headline
    out["scale_rows"] = rows
    return out


def cond_vs_select(quick: bool = False):
    """Phase-aligned federations through one vmapped dispatch vs one single
    run: the per-federation per-tick inflation from `lax.cond` lowering to
    `select` under vmap (the train/deliver branches run on idle ticks)."""
    n, batch, interval, dim = 256, 8, 32, 16
    # wide windows: the single run costs ~0.2ms/tick, so short windows put
    # the whole wall inside timing noise and the ratio swings 2x run-to-run
    ticks_pair = (64, 256) if quick else (128, 768)
    topo = topology_lib.kregular(n, 2)
    sc = scenarios.toy_scenario(n, dim=dim, malicious=(0,))
    # ALL nodes inside a federation share one phase (the single run's cond
    # skips the train branch on interval-1 of every interval ticks);
    # federations are offset from each other so the batch has no globally
    # idle tick to hide behind
    mk_spec = lambda b: attacks.FederationSpec.build(
        n, malicious=(0,),
        initial_countdown=[1 + (4 * b) % interval] * n)
    # aligned phases deliver in bursts (every node's flood lands the same
    # tick), so the staggered-activity budget would overflow: use the exact
    # topology.compaction_budget bound (budget=None, cannot overflow)
    # the single run's per-tick cost is ~0.2ms at this N (the cond skips
    # the train branch), so reps=5 to keep the tiny denominator stable
    single_s, _ = _pertick(
        sc, topo, mk_spec(0), delivery="compact", ticks_pair=ticks_pair,
        interval=interval, budget=None, reps=5)
    bspec = attacks.BatchedFederationSpec.build(
        [mk_spec(b) for b in range(batch)], list(range(batch)))
    batched_s, _ = _pertick(
        sc, topo, bspec, delivery="compact", ticks_pair=ticks_pair,
        interval=interval, budget=None, reps=5)
    out = {
        "nodes": n, "batch": batch, "dim": dim, "train_interval": interval,
        "ticks_pair": list(ticks_pair),
        "single_s_per_tick": round(single_s, 6),
        "batched_s_per_fed_per_tick": round(batched_s / batch, 6),
        "select_overhead": round(batched_s / batch / single_s, 2),
        "deferred": "phase-sorted batching (docs/SWEEPS.md)",
    }
    print(f"gossip,cond_vs_select,{n}nodes,batch={batch},"
          f"overhead={out['select_overhead']}x,single={single_s:.4f}s/tick,"
          f"batched_per_fed={batched_s / batch:.4f}s/tick")
    return out


def main(quick: bool = False) -> dict:
    if jax.device_count() < SHARDS:
        # re-exec in a fresh interpreter with 8 host devices (the flag must
        # be set before jax first init, which already happened here)
        import os
        import subprocess
        import sys
        env = dict(os.environ)
        env["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={SHARDS}"
        env.setdefault("PYTHONPATH", "src")
        res = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_sharded"]
            + (["--quick"] if quick else []),
            env=env, capture_output=True, text=True, timeout=2400)
        print(res.stdout, end="")
        if res.returncode != 0:
            raise RuntimeError(
                f"bench_sharded child exited {res.returncode}: "
                + res.stderr[-500:])
        return json.load(open("experiments/bench_sharded.json"))
    return {
        "sharded_vs_single": sharded_vs_single(quick=quick),
        "cond_vs_select": cond_vs_select(quick=quick),
    }


if __name__ == "__main__":
    import os
    import sys
    os.makedirs("experiments", exist_ok=True)
    json.dump(main(quick="--quick" in sys.argv),
              open("experiments/bench_sharded.json", "w"), indent=1)
