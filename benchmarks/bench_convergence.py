"""Paper Figs 10/11: 2-node and 4-node test-accuracy convergence.

The paper collects 16 samples/sec system-wide (8/node at 2 nodes, 4/node at
4 nodes) and reports: both reach ~90%+, 4-node converges slower (less data
per node per unit time).
"""
from __future__ import annotations

import json

from benchmarks.harness import build_federation, curves, run_sim
from repro.core.reputation import get as get_rep


def run(num_nodes: int, ticks: int, seed: int = 0):
    nodes, test_fn, _ = build_federation(
        num_nodes=num_nodes, rep_impl=get_rep("impl1"),
        samples_per_train=16 // num_nodes * 2,  # paper: constant global rate
        train_steps=8,
        seed=seed)
    run_sim(nodes, test_fn, ticks=ticks, seed=seed)
    cs = curves(nodes)
    final = {k: v["acc"][-1] for k, v in cs.items()}
    # area under curve as a convergence-speed proxy
    auc = {k: sum(v["acc"]) / max(len(v["acc"]), 1) for k, v in cs.items()}
    return {"nodes": num_nodes, "curves": cs, "final": final,
            "mean_final": sum(final.values()) / len(final),
            "mean_auc": sum(auc.values()) / len(auc)}


def main(quick: bool = False):
    ticks = 150 if quick else 500
    out = []
    for n in (2, 4):
        r = run(n, ticks)
        out.append(r)
        print(f"convergence,{n}-node,final_acc={r['mean_final']:.3f},"
              f"auc={r['mean_auc']:.3f}")
    if len(out) == 2:
        print(f"convergence,4node_slower_than_2node,"
              f"{out[1]['mean_auc'] < out[0]['mean_auc']}")
    return out


if __name__ == "__main__":
    json.dump(main(), open("experiments/bench_convergence.json", "w"), indent=1)
