"""Batched-sweep benchmark: the multi-federation dispatch acceptance lines.

Two sections, persisted to experiments/bench_sweep.json and merged into the
CI perf-regression gate (benchmarks/check_regress.py) alongside the gossip
bench:

* `sweep,batched_vs_loop` — ONE vmapped `LaxSimulator.run()` over a
  32-federation `BatchedFederationSpec` (heterogeneous attacker sheets +
  per-federation seeds, toy scenario, N=256) vs a Python loop of the same
  32 single runs. The acceptance contract is >=5x aggregate
  federations/sec at batch >= 8, AND bitwise-identical results member by
  member — the loop's outputs double as the oracle, so the throughput
  number can never come from a simulation that diverged.
* `sweep,smoke` — a 2x2 grid (attack x seed) at N=16 through the full
  `repro.chain.sweeps` orchestrator (grid -> batch planning -> frontier
  tables), so CI exercises and archives the sweep artifact end-to-end.

The batch scale stays at B=32/N=256 even under --quick (quick is already
CI's mode; the acceptance number must be in the CI JSON), mirroring
bench_gossip.compact_vs_sparse.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.chain import attacks, scenarios, simlax, sweeps
from repro.core import topology as topology_lib
from repro.core.reputation import get as get_rep


def _assert_bitwise(batched_res, single_res, b: int):
    """The batched member must equal its single-run twin bit for bit."""
    import jax

    for name, a, c in (("reputation", batched_res.reputation,
                        single_res.reputation),
                       ("acc_history", batched_res.acc_history,
                        single_res.acc_history)):
        if not np.array_equal(a, c):
            raise AssertionError(
                f"sweep,batched_vs_loop: federation {b} diverged in {name}")
    for a, c in zip(jax.tree.leaves(batched_res.params),
                    jax.tree.leaves(single_res.params), strict=True):
        if not np.array_equal(a, c):
            raise AssertionError(
                f"sweep,batched_vs_loop: federation {b} diverged in params")


def batched_vs_loop(n: int = 256, batch: int = 32, ticks: int = 120,
                    quick: bool = False):
    """One batched dispatch vs a sequential loop of identical single runs:
    wall clock each way, aggregate federations/sec, the speedup ratio, and
    a member-by-member bitwise equality check against the loop's results."""
    topo = topology_lib.kregular(n, 2)
    sc = scenarios.toy_scenario(n, dim=16)
    specs = [attacks.FederationSpec.build(
        n, malicious=tuple(range(b % 4)),
        initial_countdown=[1 + (i + b) % 12 for i in range(n)])
        for b in range(batch)]
    seeds = list(range(batch))
    mk_cfg = lambda seed: simlax.SimLaxConfig(
        ticks=ticks, train_interval=(12, 12), latency=1, ttl=2,
        record_every=20, seed=seed)
    bsim = simlax.LaxSimulator(
        sc, topo, attacks.BatchedFederationSpec.build(specs, seeds),
        get_rep("impl2"), mk_cfg(0))
    ssims = [simlax.LaxSimulator(sc, topo, s, get_rep("impl2"), mk_cfg(sd))
             for s, sd in zip(specs, seeds, strict=True)]
    # warm both paths (trace+compile) so the timed pass is steady-state
    bsim.run()
    ssims[0].run()
    t0 = time.perf_counter()
    singles = [s.run() for s in ssims]
    loop_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    batched = bsim.run()
    batched_wall = time.perf_counter() - t0
    for b, (br, sr) in enumerate(zip(batched, singles, strict=True)):
        _assert_bitwise(br, sr, b)
    out = {
        "nodes": n, "batch": batch, "ticks": ticks,
        "loop_wall_s": round(loop_wall, 3),
        "batched_wall_s": round(batched_wall, 3),
        "loop_feds_per_s": round(batch / loop_wall, 3),
        "batched_feds_per_s": round(batch / batched_wall, 3),
        "batched_s_per_fed": round(batched_wall / batch, 5),
        "speedup": round(loop_wall / batched_wall, 2),
        "bitwise_equal": True,
    }
    print(f"sweep,batched_vs_loop,{n}nodes,batch={batch},{out['speedup']}x,"
          f"loop={out['loop_feds_per_s']}feds/s,"
          f"batched={out['batched_feds_per_s']}feds/s,bitwise=ok")
    return out


def smoke_frontier(quick: bool = False):
    """2x2 grid (honest/gaussian x 2 seeds) at N=16 through the sweep
    orchestrator — the CI artifact proving grid -> batches -> frontier
    tables stays wired end to end."""
    cells = sweeps.expand_grid(sizes=[16], attacks=[None, "gaussian"],
                               seeds=[0, 1])
    cfg = simlax.SimLaxConfig(ticks=40, train_interval=(6, 10), ttl=2,
                              record_every=8)
    t0 = time.perf_counter()
    outcomes = sweeps.run_sweep(cells, cfg=cfg, target_acc=0.5)
    wall = time.perf_counter() - t0
    tables = sweeps.frontier_tables(outcomes, target_acc=0.5)
    out = {"cells": len(cells), "nodes": 16, "wall_s": round(wall, 2),
           "outcomes": [o.row() for o in outcomes], "frontier": tables}
    for row in tables["accuracy_under_attack"]:
        print(f"sweep,smoke,attack={row['attack']},n={row['size']},"
              f"acc={row['mean_final_honest_acc']},"
              f"rep_attacker={row['mean_attacker_reputation']}")
    return out


def main(quick: bool = False) -> dict:
    return {
        "sweep_batched_vs_loop": batched_vs_loop(quick=quick),
        "smoke": smoke_frontier(quick=quick),
    }


if __name__ == "__main__":
    import os
    import sys
    os.makedirs("experiments", exist_ok=True)
    json.dump(main(quick="--quick" in sys.argv),
              open("experiments/bench_sweep.json", "w"), indent=1)
