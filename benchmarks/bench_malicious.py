"""Paper Figs 14-17: model poisoning — one malicious node, two reputation
implementations.

5 nodes, node-0 broadcasts random models. impl1 (penalty .01 / buffer 5):
training degrades; impl2 (penalty .05 / buffer 10): reputation of the
malicious node hits 0 and the federation converges anyway. Also reproduces
the reputation-history curves (mean of other nodes' local views).
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.harness import build_federation, curves, run_sim
from repro.chain.network import mean_reputation
from repro.core.reputation import get as get_rep


def run(impl_name: str, ticks: int, seed: int = 0, nodes_n: int = 5):
    nodes, test_fn, _ = build_federation(
        num_nodes=nodes_n, rep_impl=get_rep(impl_name), malicious=(0,),
        samples_per_train=12, train_steps=8, seed=seed)
    mal_addr = nodes[0].info.address
    rep_hist = []

    sim = run_sim(nodes, test_fn, ticks=ticks, seed=seed)
    # reputation history recorded post-hoc per node record() snapshots
    for n in nodes[1:]:
        pass
    honest = nodes[1:]
    cs = curves(honest)
    final = {k: v["acc"][-1] for k, v in cs.items()}
    rep_mal = mean_reputation(honest, mal_addr)
    rep_honest = float(np.mean([
        mean_reputation([m for m in honest if m is not n], n.info.address)
        for n in honest]))
    return {
        "impl": impl_name, "curves": cs, "final": final,
        "mean_final_honest": sum(final.values()) / len(final),
        "malicious_reputation": rep_mal,
        "honest_reputation": rep_honest,
    }


def main(quick: bool = False):
    ticks = 150 if quick else 600
    out = []
    for impl in ("impl1", "impl2"):
        r = run(impl, ticks)
        out.append(r)
        print(f"malicious,{impl},honest_acc={r['mean_final_honest']:.3f},"
              f"rep_malicious={r['malicious_reputation']:.2f},"
              f"rep_honest={r['honest_reputation']:.2f}")
    if len(out) == 2:
        print(f"malicious,impl2_better_than_impl1,"
              f"{out[1]['mean_final_honest'] >= out[0]['mean_final_honest']}")
        print(f"malicious,reputation_detects_attacker,"
              f"{all(r['malicious_reputation'] < r['honest_reputation'] for r in out)}")
    return out


if __name__ == "__main__":
    json.dump(main(), open("experiments/bench_malicious.json", "w"), indent=1)
