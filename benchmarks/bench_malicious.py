"""Paper Figs 14-17: model poisoning — one malicious node, two reputation
implementations.

5 nodes, node-0 broadcasts random models. impl1 (penalty .01 / buffer 5):
training degrades; impl2 (penalty .05 / buffer 10): reputation of the
malicious node hits 0 and the federation converges anyway. Also reproduces
the reputation-history curves (mean of other nodes' local views).
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.harness import build_federation, curves, run_sim
from repro.chain.network import mean_reputation
from repro.core.reputation import get as get_rep


def run(impl_name: str, ticks: int, seed: int = 0, nodes_n: int = 5,
        topology: str = "full"):
    nodes, test_fn, _ = build_federation(
        num_nodes=nodes_n, rep_impl=get_rep(impl_name), malicious=(0,),
        samples_per_train=12, train_steps=8, seed=seed)
    mal_addr = nodes[0].info.address

    sim = run_sim(nodes, test_fn, ticks=ticks, seed=seed, topology=topology)
    honest = nodes[1:]
    cs = curves(honest)
    final = {k: v["acc"][-1] for k, v in cs.items()}
    rep_mal = mean_reputation(honest, mal_addr)
    rep_honest = float(np.mean([
        mean_reputation([m for m in honest if m is not n], n.info.address)
        for n in honest]))
    return {
        "impl": impl_name, "topology": topology, "curves": cs, "final": final,
        "mean_final_honest": sum(final.values()) / len(final),
        "malicious_reputation": rep_mal,
        "honest_reputation": rep_honest,
    }


def topology_scale_sweep(quick: bool = False):
    """Poisoning robustness across gossip topologies and network sizes
    (paper §VI swept with the vectorized engine — heap can't reach these N)."""
    from repro.chain import attacks, scenarios, simlax
    from repro.core import topology as topology_lib

    ticks = 120 if quick else 400
    sizes = (64,) if quick else (64, 256)
    out = []
    for n in sizes:
        mal = tuple(range(max(1, n // 20)))   # 5% poisoners
        sc = scenarios.toy_scenario(n, dim=8, malicious=mal)
        spec = attacks.FederationSpec.build(
            n, malicious=mal,
            initial_countdown=[1 + (7 * i) % 10 for i in range(n)])
        for kind, kw in (("full", {}), ("kregular", {"degree": 3}),
                         ("smallworld", {"degree": 3, "beta": 0.2}),
                         ("erdos", {"p": min(0.5, 8.0 / n)})):
            topo = topology_lib.make(kind, n, seed=1, **kw)
            cfg = simlax.SimLaxConfig(
                ticks=ticks, train_interval=(10, 10), latency=1, ttl=2,
                record_every=max(10, ticks // 10), seed=0)
            sim = simlax.LaxSimulator(sc, topo, spec, get_rep("impl2"), cfg)
            res = sim.run()
            honest = [i for i in range(n) if i not in mal]
            rec = {
                "nodes": n, "topology": kind,
                "malicious_frac": len(mal) / n,
                "honest_acc": float(res.acc_history[-1][honest].mean()),
                "malicious_reputation": float(np.mean(
                    [res.mean_reputation(i) for i in mal])),
                "honest_reputation": float(np.mean(
                    [res.mean_reputation(i) for i in honest[:64]])),
                "deliveries": res.stats["deliveries"],
            }
            out.append(rec)
            print(f"malicious,scale,{n}nodes,{kind},"
                  f"honest_acc={rec['honest_acc']:.3f},"
                  f"rep_malicious={rec['malicious_reputation']:.2f},"
                  f"rep_honest={rec['honest_reputation']:.2f}")
    return out


def lenet_poisoning(quick: bool = False):
    """§VI-D at federation scale with the REAL model: LeNet receipt evals
    through the sparse delivery engine (the dense oracle would pay an N^2
    forward-pass bill per tick), 20% poisoned senders, non-I.I.D.
    Dirichlet(1) shards."""
    from repro.chain import scenarios, simlax

    n = 8 if quick else 10
    ticks = 36 if quick else 108
    sc, spec, topo, cfg = scenarios.lenet_paper_setup(
        n, ticks=ticks, train_steps=4 if quick else 8)
    mal = spec.malicious
    sim = simlax.LaxSimulator(sc, topo, spec, get_rep("impl2"), cfg)
    res = sim.run()
    honest = [i for i in range(n) if i not in mal]
    rec = {
        "nodes": n, "ticks": ticks, "malicious_frac": len(mal) / n,
        "delivery_budget": res.stats["delivery_budget"],
        "honest_acc_curve": [round(float(a), 4)
                             for a in res.acc_history[:, honest].mean(axis=1)],
        "honest_acc": float(res.acc_history[-1][honest].mean()),
        "malicious_reputation": float(np.mean(
            [res.mean_reputation(i) for i in mal])),
        "honest_reputation": float(np.mean(
            [res.mean_reputation(i) for i in honest])),
        "deliveries": res.stats["deliveries"],
    }
    print(f"malicious,lenet,{n}nodes,{len(mal)}poisoned,"
          f"honest_acc={rec['honest_acc']:.3f},"
          f"rep_malicious={rec['malicious_reputation']:.2f},"
          f"rep_honest={rec['honest_reputation']:.2f}")
    return rec


def attack_sweep(quick: bool = False, attack_names=None, *, n=None,
                 ticks=None):
    """One run per registered attack on a fixed kregular topology — the
    reputation scheme's behaviour under adversaries beyond the paper's
    single random-model poisoner (rows built by benchmarks/harness.py)."""
    from benchmarks.harness import attack_sweep as sweep_rows
    rows = sweep_rows(attack_names=attack_names,
                      n=n or (16 if quick else 24),
                      ticks=ticks or (120 if quick else 300))
    for r in rows:
        print(f"malicious,attack_sweep,{r['attack']},"
              f"honest_acc={r['honest_acc']:.3f},"
              f"rep_attacker={r['attacker_reputation']:.2f},"
              f"rep_honest={r['honest_reputation']:.2f}")
    return rows


def main(quick: bool = False):
    ticks = 150 if quick else 600
    out = []
    for impl in ("impl1", "impl2"):
        r = run(impl, ticks)
        out.append(r)
        print(f"malicious,{impl},honest_acc={r['mean_final_honest']:.3f},"
              f"rep_malicious={r['malicious_reputation']:.2f},"
              f"rep_honest={r['honest_reputation']:.2f}")
    if len(out) == 2:
        print(f"malicious,impl2_better_than_impl1,"
              f"{out[1]['mean_final_honest'] >= out[0]['mean_final_honest']}")
        print(f"malicious,reputation_detects_attacker,"
              f"{all(r['malicious_reputation'] < r['honest_reputation'] for r in out)}")
    # short measurement windows even in full mode: bench_gossip owns the
    # high-precision N=512 sweep; this line just independently shows the
    # ratio without paying the long dense run twice per suite pass
    from benchmarks.harness import engine_pertick_speedup
    engine = engine_pertick_speedup(n=256 if quick else 512, quick=True)
    print(f"malicious,sparse_vs_dense,{engine['nodes']}nodes,"
          f"{engine['speedup']}x")
    return {"paper": out, "topology_scale": topology_scale_sweep(quick),
            "attack_sweep": attack_sweep(quick),
            "lenet": lenet_poisoning(quick), "engine": engine}


if __name__ == "__main__":
    import argparse
    import os

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--attack-sweep", nargs="*", default=None,
                    metavar="ATTACK",
                    help="run ONLY the attack sweep (optionally restricted "
                    "to the named attacks) — the CI registry smoke")
    ap.add_argument("--sweep-nodes", type=int, default=None)
    ap.add_argument("--sweep-ticks", type=int, default=None)
    args = ap.parse_args()
    os.makedirs("experiments", exist_ok=True)
    if args.attack_sweep is not None:
        rows = attack_sweep(quick=True, attack_names=args.attack_sweep or None,
                            n=args.sweep_nodes, ticks=args.sweep_ticks)
        json.dump(rows, open("experiments/bench_attack_sweep.json", "w"),
                  indent=1)
    else:
        json.dump(main(args.quick),
                  open("experiments/bench_malicious.json", "w"), indent=1)
