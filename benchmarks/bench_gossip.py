"""DFL-at-pod-scale benchmark (beyond the paper's tables): collective bytes
of the DFL gossip round vs synchronous data-parallel all-reduce, the
int8-compression saving, a gossip-topology sweep, the frontier-vs-chain
schedule coverage/collective-count table (`gossip,frontier_vs_chain`), the
receipt-engine head-to-heads (`gossip,sparse_vs_dense`,
`gossip,compact_vs_sparse`), the vectorized simulator's wall-clock
speedup over the heap reference at large N, and the sharded-engine
sections (`gossip,sharded_vs_single`, `gossip,cond_vs_select`) delegated
to benchmarks/bench_sharded.py on 8 forced host devices. The JSON is the
input to the
CI perf-regression gate (benchmarks/check_regress.py vs
benchmarks/baselines/).

Derived from lowered HLO (no hardware): per-round cross-fed link bytes for
  * sync DP: grad all-reduce every step  (H steps per round)
  * DFL:     schedule-permute model gossip every H steps (fp32 / int8)
plus wall-clock microbenches of the jitted gossip round on host devices and
a heap-vs-`simlax` wall-clock comparison (paper §VI-D "larger networks").
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.chain import scenarios, simlax
from repro.core import compression
from repro.configs import smoke_config
from repro.core import dfl as dfl_lib
from repro.core import gossip as gossip_lib
from repro.core import topology as topology_lib
from repro.core.reputation import get as get_rep
from repro.launch import hlo_cost
from repro.launch.mesh import make_fed_mesh
from repro.train import step as step_lib


def collective_bytes_of(fn, *args):
    lowered = jax.jit(fn).lower(*args)
    txt = lowered.compile().as_text()
    return hlo_cost.analyze(txt)


def simulator_speedup(n: int = 256, quick: bool = False):
    """Heap `Simulator` vs vectorized `LaxSimulator` on one shared toy
    scenario, BOTH built from the same FederationSpec: seconds/tick each,
    and the speedup ratio (acceptance: >=10x at >= 256 nodes)."""
    from repro.chain.attacks import FederationSpec

    topo = topology_lib.kregular(n, 2)
    sc = scenarios.toy_scenario(n, dim=8, malicious=(0,))
    interval, latency, ttl = 12, 1, 2
    spec = FederationSpec.build(
        n, malicious=(0,),
        initial_countdown=[1 + i % interval for i in range(n)])

    # --- heap reference: a short measured window (it is the slow one)
    heap_ticks = 4 if quick else 12
    heap_cfg = simlax.SimLaxConfig(ticks=heap_ticks, seed=0,
                                   train_interval=(interval, interval),
                                   latency=latency, ttl=ttl,
                                   record_every=10 ** 9)
    heap = scenarios.make_heap_simulator(sc, topo, spec, get_rep("impl2"),
                                         heap_cfg)
    t0 = time.perf_counter()
    heap.run()
    heap_wall = time.perf_counter() - t0
    heap_s_per_tick = heap_wall / heap_ticks

    # --- vectorized engine: full 200-tick run, wall includes trace+compile
    lax_ticks = 50 if quick else 200
    cfg = simlax.SimLaxConfig(ticks=lax_ticks,
                              train_interval=(interval, interval),
                              latency=latency, ttl=ttl, record_every=20,
                              seed=0)
    sim = simlax.LaxSimulator(sc, topo, spec, get_rep("impl2"), cfg)
    t0 = time.perf_counter()
    res = sim.run()
    lax_wall = time.perf_counter() - t0
    lax_s_per_tick = lax_wall / lax_ticks

    out = {
        "nodes": n, "topology": "kregular2",
        "heap_ticks": heap_ticks, "heap_wall_s": round(heap_wall, 3),
        "heap_s_per_tick": round(heap_s_per_tick, 5),
        "lax_ticks": lax_ticks, "lax_wall_s": round(lax_wall, 3),
        "lax_s_per_tick": round(lax_s_per_tick, 5),
        "lax_deliveries": res.stats["deliveries"],
        "speedup": round(heap_s_per_tick / max(lax_s_per_tick, 1e-9), 1),
    }
    print(f"gossip,simlax_speedup,{n}nodes,{out['speedup']}x"
          f",heap={heap_s_per_tick:.3f}s/tick,lax={lax_s_per_tick:.4f}s/tick")
    return out


def frontier_vs_chain(quick: bool = False):
    """Schedule-cost-and-coverage table of the exact frontier lowering vs
    the legacy chain-walk oracle, per topology kind (host-side, no mesh):
    ttl-ball coverage, collective count, and permutes per delivered pair.
    On circulant graphs (ring/kregular/full) the two are identical — the
    acceptance pin that exactness cost nothing where we already had it;
    on irregular graphs the chain rows record the under-coverage bug."""
    n = 12 if quick else 16
    rows = []
    for kind in topology_lib.KINDS:
        topo = topology_lib.make(kind, n, degree=2, p=0.3, seed=1)
        for ttl in (2, 3):
            for mode in ("frontier", "chain"):
                audit = topology_lib.audit_schedule(topo, ttl, schedule=mode)
                row = {
                    "kind": kind, "nodes": n, "ttl": ttl, "schedule": mode,
                    "coverage": round(audit.coverage, 4),
                    "missing_pairs": len(audit.missing),
                    "num_collectives": audit.num_collectives,
                    "collectives_per_delivered_pair": round(
                        audit.num_collectives
                        / max(audit.delivered_pairs, 1), 4),
                }
                rows.append(row)
                print(f"gossip,frontier_vs_chain,{kind},ttl={ttl},{mode},"
                      f"coverage={row['coverage']},"
                      f"collectives={row['num_collectives']},"
                      f"missing={row['missing_pairs']}")
    # the circulant no-cost-regression pin itself lives in test_topology.py
    # (hardcoded expected counts); this table is the per-PR visibility
    return rows


def sparse_vs_dense(quick: bool = False):
    """Per-tick cost of the sparse (budgeted slot) receipt engine vs the
    dense N^2 oracle at paper-beyond scale (acceptance: >=3x at N=512).
    Runs the full N=512 even under --quick (quick only shortens the
    measurement windows): the old N=256 quick runs left the sparse side at
    the harness's 0.1 ms/tick floor, where check_regress has to skip the
    row as signal-free."""
    from benchmarks.harness import engine_pertick_speedup
    out = engine_pertick_speedup(n=512, quick=quick)
    print(f"gossip,sparse_vs_dense,{out['nodes']}nodes,"
          f"budget={out['delivery_budget']},{out['speedup']}x,"
          f"dense={out['dense_s_per_tick']:.4f}s/tick,"
          f"sparse={out['sparse_s_per_tick']:.4f}s/tick")
    return out


def compact_vs_sparse(quick: bool = False):
    """Per-tick cost of the segment-compacted receipt engine vs the sparse
    per-receiver slot buffer at N=2048 with mostly-idle receivers
    (acceptance: >=2x). Broadcast phases are staggered over a long train
    interval — the realistic regime where most receivers are idle on any
    tick, so the sparse engine's N*budget slot evals are almost all wasted;
    the compact work buffer is set to a small multiple of the actual
    per-tick activity (`SimLaxConfig.compact_budget`; the overflow
    fail-fast guards the measurement's honesty). Runs at the full N=2048
    even under --quick so the CI JSON carries the acceptance number."""
    from benchmarks.harness import engine_pertick_speedup
    interval = 64
    out = engine_pertick_speedup(
        n=2048, dim=256, ttl=2, degree=2,
        engines=("compact", "sparse"),
        train_interval=(interval, interval), countdown_mod=interval,
        # staggered phases: ~n/interval senders per tick, each landing one
        # ring of 2*degree receivers per in-flight hop -> ~n*ball/interval
        # due deliveries; 2x headroom, still ~32x under the sparse slots
        compact_budget=2 * 2048 * 8 // interval,
        # long measurement windows: at N=2048 the (T2-T1) differencing has
        # to cancel seconds of per-run trace+compile, so short windows are
        # all noise
        quick=quick, ticks_pair=(24, 240) if quick else (48, 480), reps=3)
    print(f"gossip,compact_vs_sparse,{out['nodes']}nodes,"
          f"W={out['compact_budget']},budget={out['delivery_budget']},"
          f"{out['speedup']}x,"
          f"sparse={out['sparse_s_per_tick']:.4f}s/tick,"
          f"compact={out['compact_s_per_tick']:.4f}s/tick")
    return out


def int8_vs_fp32(*, quick: bool, hlo_fp32: int, hlo_int8: int,
                 model_ratio: float):
    """The accuracy/robustness/bandwidth trade-off of int8 wire payloads,
    per attack x topology (`gossip,int8_vs_fp32`): does quantization noise
    mask small-sigma gaussian poisoning? does reputation still isolate
    signflip? Bytes come from two independent derivations that must agree:
    the HLO of the production gossip round (collective-permute bytes, the
    gated pair) and the dtype-derived payload model
    (`repro.core.compression.payload_bytes`, what the simulators record) —
    if XLA ever hoists the dequant convert above the ppermute, the HLO
    ratio snaps back to ~1.0 while the model ratio stays ~0.26, and the
    check_regress bytes gate fails the build."""
    from repro.chain.attacks import FederationSpec
    from repro.core.reputation import get as get_rep

    ratio = round(hlo_int8 / max(hlo_fp32, 1), 4)
    out = {
        "permute_bytes_fp32": hlo_fp32,
        "permute_bytes_int8": hlo_int8,
        "permute_bytes_ratio": ratio,
        "model_bytes_ratio": round(model_ratio, 4),
        "sim_rows": [],
    }
    print(f"gossip,int8_vs_fp32,permute_bytes,fp32={hlo_fp32:.3e},"
          f"int8={hlo_int8:.3e},ratio={ratio},model_ratio={model_ratio:.4f}")

    n, ticks, interval = 10, 80 if quick else 160, 8
    mal = (0,)
    for attack in ("gaussian", "signflip"):
        for topo_name in ("kregular", "full"):
            topo = (topology_lib.kregular(n, 2) if topo_name == "kregular"
                    else topology_lib.full(n))
            sc = scenarios.toy_scenario(n, malicious=mal)
            spec = FederationSpec.build(
                n, malicious=mal, attack=attack,
                initial_countdown=[1 + (3 * i) % interval for i in range(n)])
            for compress in (None, "int8"):
                cfg = simlax.SimLaxConfig(
                    ticks=ticks, train_interval=(interval, interval),
                    latency=1, ttl=2, record_every=max(1, ticks // 8),
                    seed=0, compress=compress)
                res = simlax.LaxSimulator(sc, topo, spec, get_rep("impl2"),
                                          cfg).run()
                honest = [i for i in range(n) if i not in mal]
                row = {
                    "attack": attack, "topology": topo_name, "nodes": n,
                    "ticks": ticks, "ttl": cfg.ttl, "compress": compress,
                    "honest_acc": round(
                        float(res.acc_history[-1][honest].mean()), 4),
                    "rep_attacker": round(res.mean_reputation(0), 4),
                    "rep_honest": round(float(np.mean(
                        [res.mean_reputation(i) for i in honest])), 4),
                    "broadcast_bytes": res.stats["broadcast_bytes"],
                    "wire_bytes": res.stats["wire_bytes"],
                }
                out["sim_rows"].append(row)
                print(f"gossip,int8_vs_fp32,{attack},{topo_name},"
                      f"compress={compress},acc={row['honest_acc']},"
                      f"rep_mal={row['rep_attacker']},"
                      f"rep_hon={row['rep_honest']},"
                      f"wire_bytes={row['wire_bytes']:.3e}")
    return out


def main(quick: bool = False):
    out = {}
    F = min(4, jax.device_count())
    if F < 2:
        # re-exec in a fresh interpreter with 4 host devices (the flag must
        # be set before jax first init, which already happened here)
        import os
        import subprocess
        import sys
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        env.setdefault("PYTHONPATH", "src")
        res = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_gossip"]
            + (["--quick"] if quick else []),
            env=env, capture_output=True, text=True, timeout=2400)
        print(res.stdout, end="")
        if res.returncode != 0:
            # propagate: the CI smoke job must go red when the bench crashes
            raise RuntimeError(
                f"bench_gossip child exited {res.returncode}: "
                + res.stderr[-500:])
        return json.load(open("experiments/bench_gossip.json"))
    cfg = smoke_config("llama3-8b")
    mesh = make_fed_mesh(F, 1, 1)
    params_n = sum(x.size for x in jax.tree.leaves(
        step_lib.abstract_params(cfg)[0]))
    fed_state, rep_rows = dfl_lib.init_federation(cfg, F, jax.random.PRNGKey(0))
    vb = {"tokens": jnp.ones((F, 2, 64), jnp.int32),
          "labels": jnp.ones((F, 2, 64), jnp.int32)}

    def bench_round(*, compress, ttl, topology=None, topo_name="ring"):
        fn = gossip_lib.make_gossip_round(
            dfl_lib.make_lm_eval_fn(cfg), fed_axis="fed", fed_size=F,
            ttl=ttl, rep_impl=get_rep("impl2"), compress=compress, mesh=mesh,
            topology=topology)
        with mesh:
            res = collective_bytes_of(fn, fed_state["params"], rep_rows, vb)
            jfn = jax.jit(fn)
            o = jfn(fed_state["params"], rep_rows, vb)
            jax.block_until_ready(o)
            t0 = time.perf_counter()
            reps = 2 if quick else 5
            for _ in range(reps):
                o = jfn(fed_state["params"], rep_rows, vb)
                jax.block_until_ready(o)
            dt = (time.perf_counter() - t0) / reps
        cp_bytes = res.collective_bytes.get("collective-permute", 0)
        return {"compress": compress, "ttl": ttl, "topology": topo_name,
                "permute_bytes_per_round": cp_bytes,
                "permute_count": res.collective_count.get(
                    "collective-permute", 0),
                "all_collective_bytes": res.total_collective_bytes,
                "wall_s_per_round_cpu": round(dt, 4)}

    rows = []
    for compress, ttl in ((None, 1), ("int8", 1), (None, 2)):
        row = bench_round(compress=compress, ttl=ttl)
        rows.append(row)
        print(f"gossip,ttl={ttl},compress={compress},"
              f"permute_bytes={row['permute_bytes_per_round']:.3e},"
              f"wall={row['wall_s_per_round_cpu']*1e6:.0f}us")

    # topology sweep: link bytes scale with the permute-schedule size
    topo_rows = []
    for topo_name, topo in (("ring", topology_lib.ring(F)),
                            ("full", topology_lib.full(F)),
                            ("erdos", topology_lib.erdos_renyi(F, 0.7, 1))):
        row = bench_round(compress=None, ttl=1, topology=topo,
                          topo_name=topo_name)
        topo_rows.append(row)
        print(f"gossip,topology={topo_name},"
              f"permutes={row['permute_count']:.0f},"
              f"permute_bytes={row['permute_bytes_per_round']:.3e},"
              f"wall={row['wall_s_per_round_cpu']*1e6:.0f}us")

    # sync-DP comparison: grads all-reduced across fed every step, H steps/round
    H = 4
    fp32_grad_bytes = params_n * 4
    dfl_fp32 = rows[0]["permute_bytes_per_round"]
    dfl_int8 = rows[1]["permute_bytes_per_round"]
    # dtype-derived payload model: the predicted int8/fp32 wire ratio from
    # shapes alone — the independent cross-check on the HLO-measured pair
    model_ratio = (compression.payload_bytes(fed_state["params"], "int8")
                   / max(compression.payload_bytes(fed_state["params"], None),
                         1))
    out = {
        "params": int(params_n),
        "rows": rows,
        "topology_rows": topo_rows,
        "sync_dp_bytes_per_round_H4": fp32_grad_bytes * H,
        "reduction_fp32": round(fp32_grad_bytes * H / max(dfl_fp32, 1), 2),
        "reduction_int8": round(fp32_grad_bytes * H / max(dfl_int8, 1), 2),
        "int8_vs_fp32": int8_vs_fp32(quick=quick, hlo_fp32=dfl_fp32,
                                     hlo_int8=dfl_int8,
                                     model_ratio=model_ratio),
        "simulator": simulator_speedup(quick=quick),
        "sparse_vs_dense": sparse_vs_dense(quick=quick),
        "compact_vs_sparse": compact_vs_sparse(quick=quick),
        "frontier_vs_chain": frontier_vs_chain(quick=quick),
    }
    # the sharded engine needs 8 host devices (this interpreter forced 4):
    # bench_sharded re-execs itself and persists its own artifact; merging
    # its sections here puts them under the same check_regress gate
    from benchmarks import bench_sharded
    out.update(bench_sharded.main(quick=quick))
    print(f"gossip,dfl_vs_syncdp_fp32,{out['reduction_fp32']}x_fewer_link_bytes")
    print(f"gossip,dfl_vs_syncdp_int8,{out['reduction_int8']}x_fewer_link_bytes")
    return out


if __name__ == "__main__":
    import os
    import sys
    os.makedirs("experiments", exist_ok=True)
    json.dump(main(quick="--quick" in sys.argv),
              open("experiments/bench_gossip.json", "w"), indent=1)
