"""DFL-at-pod-scale benchmark (beyond the paper's tables): collective bytes
of the DFL gossip round vs synchronous data-parallel all-reduce, and the
int8-compression saving — the paper's "waive global consensus" claim mapped
onto the TPU collective roofline term.

Derived from lowered HLO (no hardware): per-round cross-fed link bytes for
  * sync DP: grad all-reduce every step  (H steps per round)
  * DFL:     2*ttl model ppermutes every H steps (fp32 / int8)
plus wall-clock microbenches of the jitted gossip round on host devices.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.core import dfl as dfl_lib
from repro.core import gossip as gossip_lib
from repro.core.reputation import get as get_rep
from repro.launch import hlo_cost
from repro.launch.mesh import make_fed_mesh
from repro.train import step as step_lib


def collective_bytes_of(fn, *args):
    lowered = jax.jit(fn).lower(*args)
    txt = lowered.compile().as_text()
    return hlo_cost.analyze(txt)


def main(quick: bool = False):
    out = {}
    F = min(4, jax.device_count())
    if F < 2:
        # re-exec in a fresh interpreter with 4 host devices (the flag must
        # be set before jax first init, which already happened here)
        import os
        import subprocess
        import sys
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        env.setdefault("PYTHONPATH", "src")
        res = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_gossip"]
            + (["--quick"] if quick else []),
            env=env, capture_output=True, text=True, timeout=1200)
        print(res.stdout, end="")
        if res.returncode != 0:
            print("gossip,ERROR,", res.stderr[-500:])
            return {}
        try:
            return json.load(open("experiments/bench_gossip.json"))
        except Exception:
            return {}
    cfg = smoke_config("llama3-8b")
    mesh = make_fed_mesh(F, 1, 1)
    params_n = sum(x.size for x in jax.tree.leaves(
        step_lib.abstract_params(cfg)[0]))
    fed_state, rep_rows = dfl_lib.init_federation(cfg, F, jax.random.PRNGKey(0))
    vb = {"tokens": jnp.ones((F, 2, 64), jnp.int32),
          "labels": jnp.ones((F, 2, 64), jnp.int32)}

    rows = []
    for compress, ttl in ((None, 1), ("int8", 1), (None, 2)):
        fn = gossip_lib.make_gossip_round(
            dfl_lib.make_lm_eval_fn(cfg), fed_axis="fed", fed_size=F,
            ttl=ttl, rep_impl=get_rep("impl2"), compress=compress, mesh=mesh)
        with mesh:
            res = collective_bytes_of(fn, fed_state["params"], rep_rows, vb)
            jfn = jax.jit(fn)
            o = jfn(fed_state["params"], rep_rows, vb)
            jax.block_until_ready(o)
            t0 = time.perf_counter()
            reps = 2 if quick else 5
            for _ in range(reps):
                o = jfn(fed_state["params"], rep_rows, vb)
                jax.block_until_ready(o)
            dt = (time.perf_counter() - t0) / reps
        cp_bytes = res.collective_bytes.get("collective-permute", 0)
        rows.append({"compress": compress, "ttl": ttl,
                     "permute_bytes_per_round": cp_bytes,
                     "all_collective_bytes": res.total_collective_bytes,
                     "wall_s_per_round_cpu": round(dt, 4)})
        print(f"gossip,ttl={ttl},compress={compress},"
              f"permute_bytes={cp_bytes:.3e},wall={dt*1e6:.0f}us")

    # sync-DP comparison: grads all-reduced across fed every step, H steps/round
    H = 4
    fp32_grad_bytes = params_n * 4
    dfl_fp32 = rows[0]["permute_bytes_per_round"]
    dfl_int8 = rows[1]["permute_bytes_per_round"]
    out = {
        "params": int(params_n),
        "rows": rows,
        "sync_dp_bytes_per_round_H4": fp32_grad_bytes * H,
        "reduction_fp32": round(fp32_grad_bytes * H / max(dfl_fp32, 1), 2),
        "reduction_int8": round(fp32_grad_bytes * H / max(dfl_int8, 1), 2),
    }
    print(f"gossip,dfl_vs_syncdp_fp32,{out['reduction_fp32']}x_fewer_link_bytes")
    print(f"gossip,dfl_vs_syncdp_int8,{out['reduction_int8']}x_fewer_link_bytes")
    return out


if __name__ == "__main__":
    import sys
    json.dump(main(quick="--quick" in sys.argv),
              open("experiments/bench_gossip.json", "w"), indent=1)
