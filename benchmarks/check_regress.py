"""CI perf-regression gate: the bench-smoke JSON vs committed baselines.

Compares the `gossip` bench output (experiments/bench_gossip.json, uploaded
per PR by the bench-smoke job) merged with the batched-sweep bench output
(experiments/bench_sweep.json, same job — the `sweep,batched_vs_loop`
acceptance line) against the committed snapshot under
benchmarks/baselines/ and FAILS the build on:

* any `gossip,frontier_vs_chain` collective-count growth (schedule cost is
  deterministic, so ANY growth is a lowering regression — likewise coverage
  drops and new missing pairs);
* an engine speedup ratio (`simulator`, `sparse_vs_dense`,
  `compact_vs_sparse`, `sweep_batched_vs_loop`, `sharded_vs_single`)
  falling more than --tolerance (default 30%) below its baseline;
* a per-tick wall time rising more than --tolerance above its baseline;
* the int8 gossip row's permute bytes exceeding BYTES_RATIO_MAX (0.3x) of
  the fp32 row — HLO-derived and deterministic, so no tolerance band: the
  known failure mode is XLA hoisting the dequant convert above the
  ppermute, which silently restores fp32 traffic (ratio ~1.0) while every
  numerical test keeps passing;
* any `tools/hlo_audit.py` cell (experiments/hlo_audit.json, produced by
  the same job) reporting ok=false, vanishing relative to the committed
  baseline, or growing its collective-permute count — the audit rows are
  deterministic structural facts about the compiled modules (quantize
  placement, scan trip counts, retrace counts), so like the schedule rows
  they gate with no tolerance band.

Baseline-refresh workflow (a legitimate perf change or a runner-class
change makes wall baselines stale):

    PYTHONPATH=src python -m benchmarks.bench_gossip --quick
    PYTHONPATH=src python -m benchmarks.bench_sweep --quick
    python tools/hlo_audit.py
    PYTHONPATH=src python -m benchmarks.check_regress --update
    git add benchmarks/baselines/ && git commit

— i.e. regenerate the bench JSON in the SAME mode CI runs it (--quick),
rewrite the trimmed baseline from it, and commit the diff so the refresh is
reviewable (wall baselines are hardware-relative: refresh from the CI
artifact — uploaded even on gate failure — when the runner class changes).
Rows whose scale knobs (nodes / measurement tick windows) differ from the
baseline's are skipped with a `regress,...,skip` line rather than
mis-compared; rows that VANISH from the current run fail, so a deleted
bench line cannot silently un-gate itself. Speedup bands are capped below
by the documented acceptance floors (`ACCEPTANCE_FLOORS`): wall-clock
ratios are noisy run-to-run, so the gate never demands more than the
contract the bench exists to enforce.

`--self-test` proves the gate actually bites: it seeds a slowdown (2x
per-tick times, +1 collective, halved speedups) into a synthetic current
run and asserts every category is flagged — CI runs it before the real
gate so a silently-toothless checker fails the build too.
"""
from __future__ import annotations

import argparse
import copy
import json
import os
import sys

BASELINE_DIR = os.path.join(os.path.dirname(__file__), "baselines")
BASELINE_PATH = os.path.join(BASELINE_DIR, "bench_gossip.json")
CURRENT_PATH = os.path.join("experiments", "bench_gossip.json")
SWEEP_CURRENT_PATH = os.path.join("experiments", "bench_sweep.json")
HLO_CURRENT_PATH = os.path.join("experiments", "hlo_audit.json")

# (section, key) pairs gated as wall-clock per-tick times (lower is better)
TIME_KEYS = (
    ("simulator", "lax_s_per_tick"),
    ("sparse_vs_dense", "sparse_s_per_tick"),
    ("sparse_vs_dense", "dense_s_per_tick"),
    ("compact_vs_sparse", "compact_s_per_tick"),
    ("compact_vs_sparse", "sparse_s_per_tick"),
    ("sweep_batched_vs_loop", "batched_s_per_fed"),
    ("sharded_vs_single", "sharded_s_per_tick"),
    ("sharded_vs_single", "single_s_per_tick"),
)
# sections gated as speedup ratios (higher is better). The documented
# acceptance contracts CAP the relative band from below: wall-clock ratios
# are noisy run-to-run, so the gate never demands more than the contract —
# falling below `baseline * (1 - tol)` AND the contract is what fails.
SPEEDUP_KEYS = ("simulator", "sparse_vs_dense", "compact_vs_sparse",
                "sweep_batched_vs_loop", "sharded_vs_single")
ACCEPTANCE_FLOORS = {"simulator": 10.0,       # >=10x heap at >=256 nodes
                     "sparse_vs_dense": 3.0,  # >=3x dense at N=512 toy
                     "compact_vs_sparse": 2.0,  # >=2x sparse at N=2048
                     # >=5x federations/sec, one vmapped dispatch vs a
                     # Python loop of single runs (batch=32, N=256 toy)
                     "sweep_batched_vs_loop": 5.0,
                     # 8-way shard_map partition vs the single-device
                     # compact engine on a HOST mesh: the shards share the
                     # physical cores, so this ratio bounds the partition +
                     # ppermute halo tax rather than claiming a win — below
                     # 0.5x (sharded >2x slower) means the sharded lowering
                     # regressed (docs/SCALING.md)
                     "sharded_vs_single": 0.5}
# int8 wire payloads must move <= this fraction of the fp32 row's permute
# bytes (int8 elements + bf16 block scales land near 0.26x; ~1.0 means the
# dequant was hoisted above the ppermute and fp32 went back on the wire)
BYTES_RATIO_MAX = 0.3


def _scale_key(row: dict):
    """The knobs that make two runs comparable: same N and the same
    measurement windows (quick vs full runs differ in one or both; the
    sweep line's window is its batch size x tick count)."""
    if "batch" in row:
        return [row.get("nodes"), [row.get("batch"), row.get("ticks")]]
    return [row.get("nodes"),
            row.get("ticks_pair") or [row.get("heap_ticks"),
                                      row.get("lax_ticks")]]


def extract(data: dict) -> dict:
    """Trim a bench_gossip JSON down to the gated metrics — the committed
    baseline stays small, deterministic-first, and reviewable."""
    out = {"schedule": {}, "speedups": {}, "times": {}, "scale": {},
           "bytes": {}, "hlo": {}}
    for key, row in data.get("hlo_audit", {}).items():
        # structural facts only — wall-independent, so gate-able exactly
        out["hlo"][key] = {
            "ok": bool(row.get("ok")),
            "collectives": row.get("collectives", 0),
            "problems": row.get("problems", []),
        }
    row = data.get("int8_vs_fp32")
    if row:
        out["bytes"]["int8_vs_fp32"] = {
            "permute_bytes_fp32": row["permute_bytes_fp32"],
            "permute_bytes_int8": row["permute_bytes_int8"],
            "ratio": row["permute_bytes_ratio"],
        }
    for row in data.get("frontier_vs_chain", []):
        key = f"{row['kind']},n={row['nodes']},ttl={row['ttl']}," \
              f"{row['schedule']}"
        out["schedule"][key] = {
            "num_collectives": row["num_collectives"],
            "coverage": row["coverage"],
            "missing_pairs": row["missing_pairs"],
        }
    for sec in SPEEDUP_KEYS:
        row = data.get(sec)
        if row:
            out["speedups"][sec] = row["speedup"]
            out["scale"][sec] = _scale_key(row)
    for sec, key in TIME_KEYS:
        row = data.get(sec)
        if row and key in row:
            out["times"][f"{sec}.{key}"] = row[key]
    return out


def compare(current: dict, baseline: dict, tolerance: float) -> list:
    """Returns a list of failure strings; prints one `regress,...` CSV line
    per gated metric (ok / FAIL / skip)."""
    fails = []

    def line(check, status, detail):
        print(f"regress,{check},{status},{detail}")
        if status == "FAIL":
            fails.append(f"{check}: {detail}")

    for key, base in baseline.get("schedule", {}).items():
        cur = current.get("schedule", {}).get(key)
        if cur is None:
            # a vanished row means the gate silently lost coverage of the
            # exact metric it protects: fail until the baseline is
            # refreshed (--update) to make the removal deliberate
            line(f"schedule({key})", "FAIL",
                 "baseline row missing from current run — removed a bench "
                 "line? refresh baselines (--update) if intentional")
            continue
        if cur["num_collectives"] > base["num_collectives"]:
            line(f"schedule({key})", "FAIL",
                 f"collectives {base['num_collectives']}"
                 f"->{cur['num_collectives']}")
        elif cur["coverage"] < base["coverage"]:
            line(f"schedule({key})", "FAIL",
                 f"coverage {base['coverage']}->{cur['coverage']}")
        elif cur["missing_pairs"] > base["missing_pairs"]:
            line(f"schedule({key})", "FAIL",
                 f"missing_pairs {base['missing_pairs']}"
                 f"->{cur['missing_pairs']}")
        else:
            line(f"schedule({key})", "ok",
                 f"collectives={cur['num_collectives']}")

    for key in baseline.get("bytes", {}):
        cur = current.get("bytes", {}).get(key)
        if cur is None:
            line(f"bytes({key})", "FAIL",
                 "baseline row missing from current run — removed a bench "
                 "line? refresh baselines (--update) if intentional")
            continue
        # deterministic (HLO-derived): the contract IS the bound, no
        # tolerance band — a ratio drifting toward 1.0 means the dequant
        # convert was hoisted above the ppermute and fp32 traffic is back
        if cur["ratio"] > BYTES_RATIO_MAX:
            line(f"bytes({key})", "FAIL",
                 f"int8/fp32 permute-bytes ratio {cur['ratio']} > "
                 f"{BYTES_RATIO_MAX} — dequant hoisted above the ppermute? "
                 "(fp32 traffic restored on the wire)")
        else:
            line(f"bytes({key})", "ok",
                 f"ratio={cur['ratio']} (max {BYTES_RATIO_MAX})")

    for key, base in baseline.get("hlo", {}).items():
        cur = current.get("hlo", {}).get(key)
        if cur is None:
            line(f"hlo({key})", "FAIL",
                 "baseline row missing from current run — removed an audit "
                 "cell? refresh baselines (--update) if intentional")
            continue
        # structural, HLO-derived, deterministic: no tolerance band
        if not cur["ok"]:
            detail = "; ".join(cur.get("problems") or []) \
                or "audit cell reported ok=false"
            line(f"hlo({key})", "FAIL", f"audit cell failed: {detail}")
        elif cur["collectives"] > base["collectives"]:
            line(f"hlo({key})", "FAIL",
                 f"collective-permute count {base['collectives']}"
                 f"->{cur['collectives']} (lowering regression)")
        else:
            line(f"hlo({key})", "ok",
                 f"collectives={cur['collectives']}")

    def scale_mismatch(sec):
        return current.get("scale", {}).get(sec) != \
            baseline.get("scale", {}).get(sec)

    for sec, base in baseline.get("speedups", {}).items():
        cur = current.get("speedups", {}).get(sec)
        if cur is None:
            line(f"speedup({sec})", "FAIL",
                 "baseline row missing from current run — removed a bench "
                 "line? refresh baselines (--update) if intentional")
            continue
        if scale_mismatch(sec):
            line(f"speedup({sec})", "skip",
                 f"scale {baseline.get('scale', {}).get(sec)}"
                 f"->{current.get('scale', {}).get(sec)} (mode mismatch; "
                 "refresh the baseline in the mode CI runs)")
            continue
        floor = base * (1.0 - tolerance)
        if sec in ACCEPTANCE_FLOORS:
            floor = min(floor, ACCEPTANCE_FLOORS[sec])
        status = "FAIL" if cur < floor else "ok"
        line(f"speedup({sec})", status,
             f"{cur}x vs baseline {base}x (floor {floor:.2f}x)")

    for key, base in baseline.get("times", {}).items():
        cur = current.get("times", {}).get(key)
        if cur is None:
            line(f"per_tick({key})", "FAIL",
                 "baseline row missing from current run — removed a bench "
                 "line? refresh baselines (--update) if intentional")
            continue
        sec = key.split(".", 1)[0]
        if scale_mismatch(sec):
            line(f"per_tick({key})", "skip",
                 "scale mismatch (mode mismatch; refresh the baseline in "
                 "the mode CI runs)")
            continue
        if base <= 1e-4:
            # the harness floors per-tick at 0.1ms (compile-variance
            # guard): a floored baseline carries no slowdown signal and a
            # 30% band around it is pure flake
            line(f"per_tick({key})", "skip",
                 f"baseline {base}s at the measurement floor")
            continue
        ceil = base * (1.0 + tolerance)
        status = "FAIL" if cur > ceil else "ok"
        line(f"per_tick({key})", status,
             f"{cur}s vs baseline {base}s (ceiling {ceil:.4f}s)")
    return fails


def self_test(tolerance: float) -> int:
    """Seed a slowdown into a synthetic run and assert the gate flags every
    category (and passes the clean run)."""
    baseline = {
        "schedule": {"erdos,n=12,ttl=2,frontier": {
            "num_collectives": 20, "coverage": 1.0, "missing_pairs": 0}},
        "speedups": {"compact_vs_sparse": 3.0,
                     "sweep_batched_vs_loop": 7.0},
        "scale": {"compact_vs_sparse": [2048, [24, 240]],
                  "sweep_batched_vs_loop": [256, [32, 120]]},
        "times": {"compact_vs_sparse.compact_s_per_tick": 0.01},
        "bytes": {"int8_vs_fp32": {"permute_bytes_fp32": 4.0e9,
                                   "permute_bytes_int8": 1.04e9,
                                   "ratio": 0.26}},
        "hlo": {"round/ring/ttl1/int8": {"ok": True, "collectives": 8,
                                         "problems": []},
                "retrace/single": {"ok": True, "collectives": 0,
                                   "problems": []}},
    }
    clean = copy.deepcopy(baseline)
    assert compare(clean, baseline, tolerance) == [], \
        "self-test: clean run must pass"
    seeded = copy.deepcopy(baseline)
    seeded["schedule"]["erdos,n=12,ttl=2,frontier"]["num_collectives"] += 1
    seeded["speedups"]["compact_vs_sparse"] = \
        baseline["speedups"]["compact_vs_sparse"] * 0.5
    # 3.5x sits below both the relative band and the 5x acceptance
    # contract — the sweep throughput line must be flagged by name
    seeded["speedups"]["sweep_batched_vs_loop"] = 3.5
    seeded["times"]["compact_vs_sparse.compact_s_per_tick"] = \
        baseline["times"]["compact_vs_sparse.compact_s_per_tick"] * 2.0
    # the known bytes regression: XLA hoists the dequant convert above the
    # ppermute and fp32 goes back on the wire — ratio snaps to ~1.0
    seeded["bytes"]["int8_vs_fp32"]["permute_bytes_int8"] = \
        seeded["bytes"]["int8_vs_fp32"]["permute_bytes_fp32"]
    seeded["bytes"]["int8_vs_fp32"]["ratio"] = 1.0
    # the HLO-audit regressions: an extra permute per step in the round
    # (lowering regression) and a retrace cell flipping to failed
    seeded["hlo"]["round/ring/ttl1/int8"]["collectives"] += 4
    seeded["hlo"]["retrace/single"] = {
        "ok": False, "collectives": 0,
        "problems": ["two same-shape runs traced 2x (expected 1)"]}
    fails = compare(seeded, baseline, tolerance)
    missing = [cat for cat in ("schedule", "speedup", "per_tick", "bytes",
                               "hlo")
               if not any(f.startswith(cat) for f in fails)]
    if not any(f.startswith("speedup(sweep_batched_vs_loop)")
               for f in fails):
        missing.append("speedup(sweep_batched_vs_loop)")
    if not any(f.startswith("hlo(retrace/single)") for f in fails):
        missing.append("hlo(retrace/single)")
    if missing:
        print(f"regress,self_test,FAIL,undetected categories: {missing}")
        return 1
    print(f"regress,self_test,ok,seeded slowdown flagged "
          f"{len(fails)} failures across all categories")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", default=CURRENT_PATH,
                    help="bench_gossip JSON from the run under test")
    ap.add_argument("--current-sweep", default=SWEEP_CURRENT_PATH,
                    help="bench_sweep JSON from the run under test (merged "
                    "into the same gate; absent file = no sweep rows, which "
                    "FAILS once the baseline carries them)")
    ap.add_argument("--current-hlo", default=HLO_CURRENT_PATH,
                    help="hlo_audit JSON from the run under test (merged "
                    "like --current-sweep; absent file = no hlo rows, which "
                    "FAILS once the baseline carries them)")
    ap.add_argument("--baseline", default=BASELINE_PATH,
                    help="committed baseline (benchmarks/baselines/)")
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("CHECK_REGRESS_TOL", 0.30)),
                    help="allowed wall-clock/speedup drift fraction "
                    "(default 0.30; env CHECK_REGRESS_TOL)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from --current "
                    "(the documented refresh workflow) instead of gating")
    ap.add_argument("--self-test", action="store_true",
                    help="verify the gate detects a seeded slowdown")
    args = ap.parse_args(argv)

    if args.self_test:
        return self_test(args.tolerance)

    try:
        with open(args.current) as f:
            data = json.load(f)
    except FileNotFoundError:
        print(f"regress,setup,FAIL,no bench JSON at {args.current} — run "
              "`python -m benchmarks.bench_gossip --quick` first")
        return 2
    # the sweep bench persists separately; merge its top-level sections so
    # one gate (and one committed baseline) covers both JSONs. A missing
    # sweep file just contributes no rows — the vanished-row check then
    # fails against a baseline that has them, so the sweep bench cannot be
    # silently dropped from CI.
    if os.path.exists(args.current_sweep):
        with open(args.current_sweep) as f:
            data.update(json.load(f))
    if os.path.exists(args.current_hlo):
        with open(args.current_hlo) as f:
            data.update(json.load(f))
    current = extract(data)

    if args.update:
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        with open(args.baseline, "w") as f:
            json.dump(current, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"regress,update,ok,baseline rewritten -> {args.baseline}")
        return 0

    try:
        with open(args.baseline) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        print(f"regress,setup,FAIL,no baseline at {args.baseline} — "
              "bootstrap with --update and commit benchmarks/baselines/")
        return 2

    fails = compare(current, baseline, args.tolerance)
    if fails:
        print(f"regress,SUMMARY,FAIL,{len(fails)} regression(s): "
              + "; ".join(fails))
        return 1
    print("regress,SUMMARY,ok,all gated metrics within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
