"""Batched serving example: prefill a prompt batch, then autoregressively
decode with the per-layer-kind KV/recurrent caches (ring buffers for local
attention, RG-LRU/xLSTM states for recurrent archs).

    PYTHONPATH=src python examples/serve.py --arch recurrentgemma-2b
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, smoke_config
from repro.models import transformer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-2b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch)
    if not cfg.supports_decode:
        raise SystemExit(f"{args.arch} is encoder-only; no decode")
    params, _ = transformer.init(jax.random.PRNGKey(0), cfg)
    B, P = args.batch, args.prompt_len
    max_seq = P + args.gen
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                 cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = 0.01 * jnp.ones(
            (B, cfg.num_patch_tokens, cfg.d_model), jnp.bfloat16)

    cache, _ = transformer.cache_init(cfg, B, max_seq)
    prefill = jax.jit(lambda p, b, c: transformer.prefill(p, cfg, b, c))
    decode = jax.jit(lambda p, c, t, pos: transformer.decode_step(p, cfg, t, c, pos))

    logits, cache = prefill(params, batch, cache)
    tok = jnp.argmax(logits, -1)[:, None]
    generated = [tok]
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok, jnp.asarray(P + i, jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None]
        generated.append(tok)
    out = jnp.concatenate(generated, axis=1)
    print(f"[serve] arch={cfg.name} batch={B} prompt={P} generated={out.shape[1]}")
    print("[serve] first row token ids:", np.asarray(out[0])[:16], "...")
    print("[serve] all finite logits:", bool(jnp.isfinite(logits).all()))


if __name__ == "__main__":
    main()
