"""Model-poisoning attack & reputation defense (paper §VI-E/F, Figs 14-17):
runs the 5-node federation with one malicious node under both reputation
implementations and prints the accuracy + reputation outcome.

    PYTHONPATH=src python examples/attack_defense.py [--ticks 400]
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from harness import build_federation, curves, run_sim  # noqa: E402
from repro.chain.network import mean_reputation  # noqa: E402
from repro.core.reputation import get as get_rep  # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=400)
    args = ap.parse_args(argv)
    for impl in ("impl1", "impl2"):
        nodes, test_fn, _ = build_federation(
            num_nodes=5, rep_impl=get_rep(impl), malicious=(0,),
            samples_per_train=12, train_steps=8)
        run_sim(nodes, test_fn, ticks=args.ticks)
        honest = nodes[1:]
        accs = [n.accuracy_history[-1][1] for n in honest]
        rep_bad = mean_reputation(honest, nodes[0].info.address)
        print(f"[{impl}] honest accuracy={np.mean(accs):.3f}  "
              f"malicious reputation={rep_bad:.2f}  "
              f"(penalty={get_rep(impl).penalty}, "
              f"buffer={get_rep(impl).buffer_size})")


if __name__ == "__main__":
    main()
