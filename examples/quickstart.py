"""Quickstart: a 4-node DFL federation training LeNet on synthetic MNIST —
the paper's §VI experiment in ~40 lines against the public API.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from harness import build_federation, curves, run_sim  # noqa: E402
from repro.chain.network import mean_reputation  # noqa: E402
from repro.core.reputation import get as get_rep  # noqa: E402


def main():
    # 4 honest nodes, fully connected, reputation impl1 (paper defaults);
    # 8 optimizer steps per training action over the collected-data window
    nodes, test_fn, _ = build_federation(
        num_nodes=4, rep_impl=get_rep("impl1"), samples_per_train=8,
        train_steps=8)
    sim = run_sim(nodes, test_fn, ticks=400, record_every=50)

    print("\n== DFL quickstart ==")
    print(f"transactions sent={sim.stats['tx_sent']} "
          f"delivered={sim.stats['tx_delivered']} "
          f"blocks={sim.stats['blocks']} "
          f"fedavg_rounds={sim.stats['fedavg_rounds']}")
    for name, c in curves(nodes).items():
        print(f"{name}: accuracy {c['acc'][0]:.2f} -> {c['acc'][-1]:.2f}")
    for n in nodes:
        ok = n.ledger.verify_chain(1)
        print(f"{n.name}: chain verified={ok} "
              f"blocks={len(n.ledger.blocks)} "
              f"contributions={n.ledger.contribution_count()}")


if __name__ == "__main__":
    main()
