"""DFL at LM scale: a 4-replica federation fine-tuning a reduced llama3
on synthetic token streams — H local steps + reputation-weighted gossip,
int8-compressed payloads, one simulated node failure.

This is the pod-scale path (shard_map over the fed axis) run on host
devices; the identical code lowers on the production meshes (see
repro/launch/dryrun.py --dfl).

    PYTHONPATH=src python examples/federated_lm.py
"""
from repro.launch import train as train_mod


def main():
    train_mod.main([
        "--arch", "llama3-8b", "--smoke", "--dfl",
        "--host-devices", "4", "--fed", "4",
        "--rounds", "8", "--local-steps", "2", "--ttl", "1",
        "--compress", "int8",
        "--fail-node", "3@5",
        "--batch", "4", "--seq", "128",
    ])


if __name__ == "__main__":
    main()
