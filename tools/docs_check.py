"""docs-check: every code reference in the docs must resolve against the
source tree.

Scans `docs/*.md` and `README.md` for inline-backtick references and
verifies each against the repo, so the docs pages cannot silently rot as
code moves:

* dotted names rooted at a known top-level package
  (`` `repro.chain.simlax.LaxSimulator` ``, `` `benchmarks.bench_sweep` ``)
  -> the module file must exist and the trailing symbol(s) must be found
  in its AST (top-level def/class/assignment, or a method/field one level
  into a class). Resolution is purely static — no imports, so the linter
  needs neither jax nor a configured PYTHONPATH.
* path-like references (`` `src/repro/core/` ``,
  `` `benchmarks/check_regress.py` ``) -> the file or directory must
  exist (also tried under `src/`). Generated artifacts (`experiments/...`)
  and glob patterns are skipped.
* relative markdown link targets (`[x](SWEEPS.md#anchor)`) -> the linked
  file must exist next to the referencing page.

Anything else in backticks (CLI flags, shell lines, config values, bare
symbol names without a package root) is out of scope — the linter checks
references it can resolve *unambiguously*, and stays quiet about prose.

Usage: python tools/docs_check.py  (exit 1 on any broken reference; CI
runs it as the `docs-check` job).
"""
from __future__ import annotations

import ast
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# top-level package/dir roots a dotted reference may start from, and where
# their source lives relative to the repo root
ROOTS = {"repro": "src", "benchmarks": "", "tools": "", "tests": ""}

DOTTED = re.compile(r"^[A-Za-z_][\w]*(\.[A-Za-z_][\w]*)+$")
PATHLIKE = re.compile(r"^[\w.\-/]+$")
INLINE_CODE = re.compile(r"`([^`\n]+)`")
MD_LINK = re.compile(r"\]\(([^)\s]+)\)")


def _module_file(parts):
    """Longest prefix of `parts` that is a module file/package; returns
    (path, remainder) or (None, parts)."""
    root = ROOTS.get(parts[0])
    if root is None:
        return None, parts
    for k in range(len(parts), 0, -1):
        base = os.path.join(REPO, root, *parts[:k])
        for cand in (base + ".py", os.path.join(base, "__init__.py")):
            if os.path.isfile(cand):
                return cand, parts[k:]
        if k > 1 and not os.path.isdir(os.path.join(REPO, root, *parts[:k - 1])):
            continue
    return None, parts


def _top_level_names(tree):
    names = set()
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            names.add(node.name)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    names.add(t.id)
        elif isinstance(node, ast.AnnAssign) and isinstance(node.target,
                                                            ast.Name):
            names.add(node.target.id)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for a in node.names:
                names.add(a.asname or a.name.split(".")[0])
    return names


def _class_members(tree, cls):
    for node in tree.body:
        if isinstance(node, ast.ClassDef) and node.name == cls:
            return _top_level_names(ast.Module(body=node.body,
                                               type_ignores=[]))
    return None


def check_dotted(ref: str):
    """None if ok, else a failure message."""
    parts = ref.split(".")
    mod_file, rest = _module_file(parts)
    if mod_file is None:
        return f"no module file for {ref!r}"
    if not rest:
        return None
    tree = ast.parse(open(mod_file).read())
    top = _top_level_names(tree)
    if rest[0] not in top:
        return f"{rest[0]!r} not found at top level of {mod_file}"
    if len(rest) >= 2:
        members = _class_members(tree, rest[0])
        if members is not None and rest[1] not in members:
            return f"{rest[1]!r} not a member of class {rest[0]} " \
                   f"in {mod_file}"
        # rest[0] is a function/value: deeper attrs are runtime objects
        # (e.g. dataclass instance fields) — out of static scope
    return None


def check_path(ref: str):
    if "*" in ref or ref.startswith("experiments/"):
        return None
    clean = ref.rstrip("/")
    for cand in (os.path.join(REPO, clean), os.path.join(REPO, "src", clean)):
        if os.path.exists(cand):
            return None
    return f"path {ref!r} does not exist (also tried under src/)"


def _strip_fences(text: str) -> str:
    out, fenced = [], False
    for line in text.splitlines():
        if line.lstrip().startswith("```"):
            fenced = not fenced
            continue
        if not fenced:
            out.append(line)
    return "\n".join(out)


def check_file(path: str):
    fails = []
    raw = open(path).read()
    text = _strip_fences(raw)
    for ref in INLINE_CODE.findall(text):
        ref = ref.strip()
        if DOTTED.match(ref) and ref.split(".")[0] in ROOTS:
            err = check_dotted(ref)
        elif PATHLIKE.match(ref) and ("/" in ref or ref.endswith(
                (".py", ".md", ".yml", ".json", ".txt"))):
            err = check_path(ref)
        else:
            continue
        if err:
            fails.append((ref, err))
    for target in MD_LINK.findall(text):
        if target.startswith(("http://", "https://", "#", "../")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not os.path.exists(os.path.join(os.path.dirname(path), rel)):
            fails.append((target, f"linked file {rel!r} missing"))
    return fails


def main() -> int:
    pages = [os.path.join(REPO, "README.md")]
    docs_dir = os.path.join(REPO, "docs")
    if os.path.isdir(docs_dir):
        pages += sorted(os.path.join(docs_dir, f)
                        for f in os.listdir(docs_dir) if f.endswith(".md"))
    n_checked, bad = 0, 0
    for page in pages:
        fails = check_file(page)
        rel = os.path.relpath(page, REPO)
        n_checked += 1
        if fails:
            bad += 1
            for ref, err in fails:
                print(f"docs-check,FAIL,{rel},{ref},{err}")
        else:
            print(f"docs-check,ok,{rel}")
    if bad:
        print(f"docs-check,SUMMARY,FAIL,{bad}/{n_checked} pages with "
              "broken references")
        return 1
    print(f"docs-check,SUMMARY,ok,{n_checked} pages clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
