#!/usr/bin/env python3
"""hlo_audit — compiled-HLO structural invariants of the gossip fabric.

Generalizes PR 7's hand-rolled HLO walk (the "dequant hoisted above the
ppermute" failure mode) into a gate: lower the production gossip round and
the vectorized simulator's tick scan, then assert properties of the
OPTIMIZED HLO that no numerical test can see:

production gossip round (per topology x ttl x compress):
  * collective-permute instructions lower as one per PERMUTED BUFFER per
    schedule step (fp32: one per param leaf; int8: payload + scales per
    leaf), so the audit asserts count is a whole multiple of
    ``GossipSchedule.num_collectives`` and that the schedule's
    ``delivery_counts()`` exactly covers the BFS ttl-ball
    (``topology.audit_schedule``)
  * quantize placement: with compress="int8" the permuted bytes are
    s8-dominated — quantization happens once on the send side and
    dequantization on the receive side of the wire. Scales legitimately
    ride along (bf16 in source; XLA:CPU promotes them to f32), but they
    are ~1/64 the payload bytes; a dequant hoisted above the ppermute
    puts FULL-SIZE f32 back on the wire, which the byte-weighted check
    catches even though a dtype set check would not
  * compiled permute bytes: int8/fp32 ratio <= the check_regress gate's
    BYTES_RATIO_MAX
  * no f64 anywhere in the module

lax engine (per delivery engine x compress):
  * the tick loop compiles to while loops whose static trip count includes
    cfg.ticks (the scan was not unrolled or split)
  * s8 appears iff compress="int8", and NEVER in the while-loop carry —
    the wire roundtrip is confined to the tick body, committed params stay
    full precision
  * no collectives, no f64

sharded engine (per compress):
  * the shard_map tick scan's only collectives are the neighbor-exchange
    ppermutes (one per occupied shard offset per sent leaf — the engine's
    static schedule), no all-gathers of per-shard state, while trips ==
    cfg.ticks, s8 out of the carry (docs/SCALING.md)

batched engine (per delivery engine x compress):
  * the same invariants over the VMAPPED B=2 heterogeneous-federation
    scan: vmap must add a batch axis, not collectives, not an unrolled
    tick loop, and not s8 leaking into the while carry

retrace guard:
  * two same-config ``LaxSimulator``s share one compiled scan: the
    ``core/tracecheck.py`` counter reads exactly 1 after both runs

Writes ``experiments/hlo_audit.json``; ``benchmarks/check_regress.py``
joins these rows into the CI perf gate (collective-count growth or any
ok=false fails the PR). Run via ``python tools/hlo_audit.py`` — forces 8
host devices, so it must set XLA_FLAGS before the first jax import.
"""
from __future__ import annotations

import os
import sys

# must precede the first jax import: device count is locked at backend init
_flags = os.environ.get("XLA_FLAGS", "")
if "host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _REPO)                       # benchmarks.check_regress
sys.path.insert(0, os.path.join(_REPO, "src"))  # repro.*

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.chain import scenarios, simlax  # noqa: E402
from repro.chain.attacks import (  # noqa: E402
    BatchedFederationSpec,
    FederationSpec,
)
from repro.core import gossip as gossip_lib  # noqa: E402
from repro.core import topology as topology_lib  # noqa: E402
from repro.core.reputation import get as get_rep  # noqa: E402
from repro.launch import hlo_cost  # noqa: E402
from repro.launch.mesh import make_fed_mesh  # noqa: E402

# one source of truth for the wire-compression acceptance ratio
from benchmarks.check_regress import BYTES_RATIO_MAX  # noqa: E402

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}
# result type of a collective-permute instruction: `= f32[8,1]{1,0} collective-permute(`
_PERMUTE_RESULT = re.compile(
    r"=\s*([a-z]+[0-9]+)\[([0-9,]*)\][^=]*collective-permute\(")


def permute_payloads(text: str):
    """[(dtype, bytes)] for each collective-permute instruction in an HLO
    module — a permute's result type equals its operand type, so this is
    exactly what crosses the wire, per shard."""
    out = []
    for line in text.splitlines():
        m = _PERMUTE_RESULT.search(line)
        if not m:
            continue
        dtype, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append((dtype, n * _DTYPE_BYTES.get(dtype, 4)))
    return out


def permute_dtypes(text: str):
    """Set of dtypes moved by collective-permute instructions."""
    return {dt for dt, _ in permute_payloads(text)}


def permute_count(res: hlo_cost.CostResult) -> int:
    return int(sum(v for k, v in res.collective_count.items()
                   if k.startswith("collective-permute")))


def total_collectives(res: hlo_cost.CostResult) -> int:
    return int(sum(res.collective_count.values()))


def while_carry_has(text: str, token: str) -> bool:
    """Does any while-loop carry (its result tuple type) contain `token`?"""
    for line in text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\(.*\))\s*while\(", stripped)
        if m and token in m.group(1):
            return True
    return False


# --------------------------------------------------------------- gossip round
def _toy_round_inputs(F: int):
    """Synthetic fed-sharded inputs: leaves sized in multiples of the
    compression block (256) so the int8/fp32 byte ratio is padding-free."""
    key = jax.random.PRNGKey(0)
    params = {
        "w": jax.random.normal(key, (F, 8, 256), jnp.float32),
        "b": jax.random.normal(jax.random.fold_in(key, 1), (F, 256),
                               jnp.float32),
    }
    rep_rows = jnp.ones((F, F), jnp.float32)
    vb = jnp.ones((F, 16), jnp.float32)
    return params, rep_rows, vb


def _toy_eval_fn(params, val):
    # any [0, 1] receipt works — the audit is structural, not numerical
    return jax.nn.sigmoid(jnp.vdot(params["b"][:16], val) / 16.0)


def audit_gossip_round(F: int, cells, out: dict) -> None:
    mesh = make_fed_mesh(F, 1, 1)
    params, rep_rows, vb = _toy_round_inputs(F)
    fp32_bytes: dict = {}
    for topo_name, ttl, compress in cells:
        topo = (topology_lib.ring(F) if topo_name == "ring"
                else topology_lib.erdos_renyi(F, 0.4, seed=1))
        sched = topology_lib.gossip_schedule(topo, ttl)
        sched_audit = topology_lib.audit_schedule(topo, ttl, sched)
        fn = gossip_lib.make_gossip_round(
            _toy_eval_fn, fed_axis="fed", fed_size=F, ttl=ttl,
            rep_impl=get_rep("impl2"), compress=compress, mesh=mesh,
            topology=topo)
        with mesh:
            text = jax.jit(fn).lower(params, rep_rows, vb).compile().as_text()
        res = hlo_cost.analyze(text)
        count = permute_count(res)
        payloads = permute_payloads(text)
        dtypes = {dt for dt, _ in payloads}
        wire_bytes = sum(v for k, v in res.collective_bytes.items()
                        if k.startswith("collective-permute"))
        problems = []
        if not sched_audit.ok:
            problems.append(f"schedule audit failed: coverage="
                            f"{sched_audit.coverage:.3f}")
        # XLA lowers one permute per buffer per schedule step (fp32: one
        # per leaf; int8: quantized payload + scales per leaf), so the
        # instruction count must be a whole multiple of the schedule's
        # step count — anything else means steps were fused, duplicated,
        # or dropped relative to GossipSchedule.
        if count < sched.num_collectives or count % sched.num_collectives:
            problems.append(
                f"permute count {count} is not a whole multiple of "
                f"schedule num_collectives {sched.num_collectives}")
        if "f64[" in text:
            problems.append("f64 present in compiled module")
        if compress == "int8":
            s8_bytes = sum(b for dt, b in payloads if dt == "s8")
            other_bytes = sum(b for dt, b in payloads if dt != "s8")
            if s8_bytes == 0:
                problems.append("int8 round ships no s8 payload "
                                "(quantization compiled away?)")
            # scales + routing metadata are ~1/64 the payload; a dequant
            # hoisted above the ppermute would ship full-size f32 (4x the
            # s8 bytes) and blow this budget immediately
            elif other_bytes > s8_bytes // 8 + 256:
                problems.append(
                    f"int8 wire is not s8-dominated ({other_bytes}B "
                    f"non-s8 vs {s8_bytes}B s8): dequantize ran on the "
                    "SEND side of a ppermute")
            base = fp32_bytes.get((topo_name, ttl))
            if base:
                ratio = wire_bytes / base
                if ratio > BYTES_RATIO_MAX:
                    problems.append(f"compiled permute-bytes ratio "
                                    f"{ratio:.3f} > {BYTES_RATIO_MAX}")
        else:
            fp32_bytes[(topo_name, ttl)] = wire_bytes
            if "s8" in dtypes:
                problems.append("fp32 wire unexpectedly carries s8")
        key = f"round/{topo_name}/ttl{ttl}/{compress or 'fp32'}"
        out[key] = {
            "ok": not problems,
            "collectives": count,
            "schedule_collectives": sched.num_collectives,
            "buffers_per_step": (count // sched.num_collectives
                                 if sched.num_collectives else 0),
            "permute_dtypes": sorted(dtypes),
            "permute_bytes": wire_bytes,
            "problems": problems,
        }
        print(f"hlo-audit,{'ok' if not problems else 'FAIL'},{key},"
              f"collectives={count}/{sched.num_collectives},"
              f"dtypes={'/'.join(sorted(dtypes))}"
              + ("," + ";".join(problems) if problems else ""))


# ----------------------------------------------------------------- lax engine
def _make_sim(delivery: str, compress, n: int = 10, ticks: int = 12):
    topo = topology_lib.kregular(n, 2)
    sc = scenarios.toy_scenario(n, dim=8, malicious=(0,))
    spec = FederationSpec.build(
        n, malicious=(0,),
        initial_countdown=[1 + (3 * i) % 4 for i in range(n)])
    cfg = simlax.SimLaxConfig(ticks=ticks, seed=0, train_interval=(4, 4),
                              latency=1, ttl=2, delivery=delivery,
                              compress=compress)
    return simlax.LaxSimulator(sc, topo, spec, get_rep("impl2"), cfg)


def _scan_cell(sim, compress, key: str, out: dict) -> None:
    """Shared tick-scan invariants: the single-federation and the vmapped
    batched engine compile to the same structural shape (one while loop at
    cfg.ticks trips, no collectives, quantization confined to the body)."""
    text = sim.lower_scan().compile().as_text()
    res = hlo_cost.analyze(text)
    problems = []
    if "f64[" in text:
        problems.append("f64 present in compiled scan")
    if total_collectives(res) != 0:
        problems.append(
            f"single-device scan lowered {total_collectives(res)} "
            "collectives")
    ticks = sim.cfg.ticks
    if ticks not in res.while_trips:
        problems.append(
            f"no while loop with static trip count {ticks}: the "
            f"tick scan was unrolled or split (trips="
            f"{res.while_trips})")
    has_s8 = "s8[" in text
    if compress == "int8" and not has_s8:
        problems.append("int8 engine compiled without any s8 op")
    if compress is None and has_s8:
        problems.append("fp32 engine unexpectedly contains s8")
    if while_carry_has(text, "s8["):
        problems.append(
            "s8 in a while-loop carry: the wire roundtrip must be "
            "confined to the tick body (committed params stay f32)")
    out[key] = {
        "ok": not problems,
        "collectives": total_collectives(res),
        "while_trips": sorted(res.while_trips),
        "has_s8": has_s8,
        "problems": problems,
    }
    print(f"hlo-audit,{'ok' if not problems else 'FAIL'},{key},"
          f"trips={sorted(res.while_trips)},s8={has_s8}"
          + ("," + ";".join(problems) if problems else ""))


def audit_lax_engine(engines, out: dict) -> None:
    for delivery in engines:
        for compress in (None, "int8"):
            sim = _make_sim(delivery, compress)
            _scan_cell(sim, compress, f"lax/{delivery}/{compress or 'fp32'}",
                       out)


# -------------------------------------------------------------- sharded engine
def _make_sharded_sim(compress, n: int = 16, shards: int = 8,
                      ticks: int = 12):
    topo = topology_lib.kregular(n, 2)
    sc = scenarios.toy_scenario(n, dim=8, malicious=(0,))
    spec = FederationSpec.build(
        n, malicious=(0,),
        initial_countdown=[1 + (3 * i) % 4 for i in range(n)])
    cfg = simlax.SimLaxConfig(ticks=ticks, seed=0, train_interval=(4, 4),
                              latency=1, ttl=2, delivery="sharded",
                              shards=shards, compress=compress)
    return simlax.LaxSimulator(sc, topo, spec, get_rep("impl2"), cfg)


def audit_sharded_engine(out: dict, compresses=(None, "int8")) -> None:
    """delivery="sharded" (docs/SCALING.md): the shard_map tick scan's ONLY
    collectives are the neighbor-exchange ppermutes — one per occupied
    shard offset per `sent` leaf, matching the engine's static schedule —
    and in particular NO all-gather of the (m, budget) slot state or the
    (m, N) reputation rows; the tick loop stays one while loop at
    cfg.ticks static trips; int8 stays confined to the body."""
    for compress in compresses:
        sim = _make_sharded_sim(compress)
        text = sim.lower_scan().compile().as_text()
        res = hlo_cost.analyze(text)
        sent_leaves = len(jax.tree.leaves(
            sim.scenario.init_params_stacked()))
        # hlo_cost trip-weights collectives: one ppermute per occupied
        # shard offset per sent leaf in the tick body, x cfg.ticks trips
        expected = len(sim._offsets) * sent_leaves * sim.cfg.ticks
        count = permute_count(res)
        total = total_collectives(res)
        problems = []
        if count != expected:
            problems.append(
                f"permute count {count} != offsets x sent-leaves x ticks "
                f"{len(sim._offsets)}x{sent_leaves}x{sim.cfg.ticks}="
                f"{expected}: the neighbor exchange was fused, duplicated, "
                "or dropped relative to the engine's offset schedule")
        if total != count:
            problems.append(
                f"{total - count} non-permute collectives (all-gather/"
                "all-reduce) lowered: per-shard state leaked onto the wire")
        if sim.cfg.ticks not in res.while_trips:
            problems.append(
                f"no while loop with static trip count {sim.cfg.ticks}: "
                f"the sharded tick scan was unrolled or split "
                f"(trips={res.while_trips})")
        if "f64[" in text:
            problems.append("f64 present in compiled module")
        has_s8 = "s8[" in text
        if compress == "int8" and not has_s8:
            problems.append("int8 engine compiled without any s8 op")
        if compress is None and has_s8:
            problems.append("fp32 engine unexpectedly contains s8")
        if while_carry_has(text, "s8["):
            problems.append(
                "s8 in a while-loop carry: the wire roundtrip must be "
                "confined to the tick body (committed params stay f32)")
        key = f"sharded/{sim.topology.num_nodes}x{sim.shards}/" \
              f"{compress or 'fp32'}"
        out[key] = {
            "ok": not problems,
            "collectives": total,
            "permutes": count,
            "schedule_permutes": expected,
            "while_trips": sorted(res.while_trips),
            "has_s8": has_s8,
            "problems": problems,
        }
        print(f"hlo-audit,{'ok' if not problems else 'FAIL'},{key},"
              f"permutes={count}/{expected},trips={sorted(res.while_trips)},"
              f"s8={has_s8}"
              + ("," + ";".join(problems) if problems else ""))


# -------------------------------------------------------------- batched engine
def _make_batched_sim(delivery: str, compress, n: int = 10, ticks: int = 12):
    """B=2 heterogeneous federations (different attacks, a straggler,
    distinct seeds) — the smallest batch that exercises the vmapped engine's
    mask/fold plumbing rather than collapsing to a broadcast."""
    topo = topology_lib.kregular(n, 2)
    sc = scenarios.toy_scenario(n, dim=8, malicious=(0,))
    specs = [
        FederationSpec.build(
            n, malicious=(0,), attack="gaussian",
            initial_countdown=[1 + (3 * i) % 4 for i in range(n)]),
        FederationSpec.build(n, malicious={2: "signflip"},
                             stragglers={7: 2}),
    ]
    bspec = BatchedFederationSpec.build(specs, seeds=(0, 7))
    cfg = simlax.SimLaxConfig(ticks=ticks, seed=0, train_interval=(4, 4),
                              latency=1, ttl=2, delivery=delivery,
                              compress=compress)
    return simlax.LaxSimulator(sc, topo, bspec, get_rep("impl2"), cfg)


def audit_batched_engine(engines, out: dict) -> None:
    """The vmapped multi-federation scan must keep every single-federation
    invariant: vmap adds a batch axis, not collectives; the tick loop stays
    ONE while loop with cfg.ticks static trips (vmap must not force an
    unroll); int8 stays confined to the body of that loop."""
    for delivery in engines:
        for compress in (None, "int8"):
            sim = _make_batched_sim(delivery, compress)
            _scan_cell(sim, compress,
                       f"batched/{delivery}/{compress or 'fp32'}", out)


# -------------------------------------------------------------- retrace guard
def audit_retrace(out: dict) -> None:
    """Two simulators over the SAME scenario/topology/spec objects and an
    equal config must share ONE compiled scan: run both, read the shared
    tracecheck counter. (The cache keys bound train/eval fns by identity,
    so the scenario object must be shared — a fresh scenario is a
    legitimately different federation. lower_scan also traces, so this
    uses a config distinct from the lax-engine cells.)"""
    simlax.clear_scan_cache()
    n = 8
    topo = topology_lib.kregular(n, 2)
    sc = scenarios.toy_scenario(n, dim=8, malicious=(0,))
    spec = FederationSpec.build(
        n, malicious=(0,),
        initial_countdown=[1 + (3 * i) % 4 for i in range(n)])
    cfg = simlax.SimLaxConfig(ticks=10, seed=0, train_interval=(4, 4),
                              latency=1, ttl=2, delivery="compact",
                              compress=None)
    sim_a = simlax.LaxSimulator(sc, topo, spec, get_rep("impl2"), cfg)
    sim_b = simlax.LaxSimulator(sc, topo, spec, get_rep("impl2"), cfg)
    sim_a.run()
    sim_b.run()
    traces = sim_a.trace_counter.count
    shared = sim_a.trace_counter is sim_b.trace_counter
    problems = []
    if not shared:
        problems.append("same-config simulators did not share a compiled "
                        "scan (cache key drift)")
    if traces != 1:
        problems.append(
            f"two same-shape runs traced {traces}x (expected 1): a retrace "
            "means jit saw unstable static inputs")
    out["retrace/single"] = {"ok": not problems, "collectives": 0,
                             "traces": traces, "problems": problems}
    print(f"hlo-audit,{'ok' if not problems else 'FAIL'},retrace/single,"
          f"traces={traces}"
          + ("," + ";".join(problems) if problems else ""))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", metavar="OUT",
                    default="experiments/hlo_audit.json",
                    help="output path (joined into check_regress)")
    ap.add_argument("--quick", action="store_true",
                    help="one topology / one engine (test smoke)")
    args = ap.parse_args(argv)

    F = min(8, jax.device_count())
    if F < 2:
        print("hlo-audit,FAIL,setup,need >=2 devices — run via "
              "`python tools/hlo_audit.py` so XLA_FLAGS is set before jax "
              "imports")
        return 1

    rows: dict = {}
    if args.quick:
        round_cells = [("ring", 1, None), ("ring", 1, "int8")]
        engines = ("compact",)
    else:
        round_cells = [("ring", 1, None), ("ring", 1, "int8"),
                       ("ring", 2, None), ("ring", 2, "int8"),
                       ("erdos", 2, None), ("erdos", 2, "int8")]
        engines = ("compact", "sparse", "dense")
    audit_gossip_round(F, round_cells, rows)
    audit_lax_engine(engines, rows)
    audit_sharded_engine(rows, compresses=((None,) if args.quick
                                           else (None, "int8")))
    audit_batched_engine(engines, rows)
    audit_retrace(rows)

    payload = {"hlo_audit": rows}
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w", encoding="utf-8") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")
    bad = [k for k, r in rows.items() if not r["ok"]]
    print(f"hlo-audit,summary,cells={len(rows)},failed={len(bad)}"
          + ("," + ";".join(bad) if bad else ""))
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
