"""Embedded self-test fixtures: every rule must fire on its bad fixture
and stay silent on the good one.

A fixture source is either a plain string (single module, analyzed under
the given dotted module name) or a ``{repo-relative-path: source}`` dict
— the cross-module form, analyzed as a real multi-file project so the
taint rules prove their cross-module propagation end-to-end.
"""
from __future__ import annotations

from typing import Dict, List, Set, Tuple, Union

from jaxlintlib.engine import lint_project, lint_source
from jaxlintlib.model import Model
from jaxlintlib.project import Project

Source = Union[str, Dict[str, str]]

FIXTURES: List[Tuple[str, str, Source, Source]] = [
    ("nonzero-size", "repro.chain.simlax",
     """
import jax
import jax.numpy as jnp

def body(state, t):
    idx = jnp.nonzero(state > 0)
    return state, idx

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
""",
     """
import jax
import jax.numpy as jnp

def body(state, t):
    idx = jnp.nonzero(state > 0, size=8, fill_value=0)
    return state, idx

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
"""),
    ("nonzero-size", "repro.chain.simlax",
     """
import jax
import jax.numpy as jnp

def picker(mask):
    return jnp.where(mask)

def go(mask):
    return jax.jit(picker)(mask)
""",
     """
import jax
import jax.numpy as jnp

def picker(mask):
    return jnp.where(mask, 1.0, 0.0)

def go(mask):
    return jax.jit(picker)(mask)
"""),
    # cross-module: the traced scan body lives in simlax, the unpinned
    # nonzero in a helper module outside JITTED_MODULES — only the
    # foreign-taint edge can see it
    ("nonzero-size", "",
     {"src/repro/chain/simlax.py": """
import jax
import jax.numpy as jnp
from repro.models.helper import active_set

def body(state, t):
    return state, active_set(state)

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
""",
      "src/repro/models/helper.py": """
import jax.numpy as jnp

def active_set(x):
    return jnp.nonzero(x > 0)
"""},
     {"src/repro/chain/simlax.py": """
import jax
import jax.numpy as jnp
from repro.models.helper import active_set

def body(state, t):
    return state, active_set(state)

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
""",
      "src/repro/models/helper.py": """
import jax.numpy as jnp

def active_set(x):
    return jnp.nonzero(x > 0, size=8, fill_value=0)
"""}),
    ("host-coercion", "repro.chain.simlax",
     """
import jax
import jax.numpy as jnp

def body(state, t):
    lr = float(state[0])
    return state * lr, state.item()

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
""",
     """
import jax
import jax.numpy as jnp

def body(state, t):
    lr = state[0]
    return state * lr, state[0]

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
"""),
    # cross-module: the coercion hides in a helper file; the helper's
    # *static* param stays legal (good fixture coerces untainted config)
    ("host-coercion", "",
     {"src/repro/chain/simlax.py": """
import jax
import jax.numpy as jnp
from repro.train.sched import step_size

def body(state, t):
    return state * step_size(state, 10), t

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
""",
      "src/repro/train/sched.py": """
def step_size(x, horizon):
    return float(x[0]) / horizon
"""},
     {"src/repro/chain/simlax.py": """
import jax
import jax.numpy as jnp
from repro.train.sched import step_size

def body(state, t):
    return state * step_size(state, 10), t

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
""",
      "src/repro/train/sched.py": """
def step_size(x, horizon):
    return x[0] / float(horizon)
"""}),
    ("np-in-traced", "repro.chain.simlax",
     """
import jax
import numpy as np
import jax.numpy as jnp

def body(state, t):
    noise = np.random.normal(size=3)
    return state + noise, t

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
""",
     """
import jax
import jax.numpy as jnp

def body(state, t):
    noise = jnp.ones((3,))
    return state + noise, t

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
"""),
    # cross-module: np.cumsum over a traced value in a helper module the
    # old module-local engine could not see
    ("np-in-traced", "",
     {"src/repro/chain/simlax.py": """
import jax
import jax.numpy as jnp
from repro.models.helper import smooth

def body(state, t):
    return smooth(state), t

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
""",
      "src/repro/models/helper.py": """
import numpy as np

def smooth(x):
    return np.cumsum(x)
"""},
     {"src/repro/chain/simlax.py": """
import jax
import jax.numpy as jnp
from repro.models.helper import smooth

def body(state, t):
    return smooth(state), t

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
""",
      "src/repro/models/helper.py": """
import jax.numpy as jnp

def smooth(x):
    return jnp.cumsum(x)
"""}),
    ("traced-control-flow", "repro.chain.simlax",
     """
import jax
import jax.numpy as jnp

def body(state, t):
    if t == 0:
        state = state * 0
    return state, t

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
""",
     """
import jax
import jax.numpy as jnp

def body(state, t):
    state = jnp.where(t == 0, state * 0, state)
    return state, t

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
"""),
    # cross-module: the helper branches on its (foreign-tainted) param;
    # branching on a static attribute of it stays legal
    ("traced-control-flow", "",
     {"src/repro/chain/simlax.py": """
import jax
import jax.numpy as jnp
from repro.models.helper import clamp

def body(state, t):
    return clamp(state), t

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
""",
      "src/repro/models/helper.py": """
import jax.numpy as jnp

def clamp(x):
    if x > 0:
        return x
    return -x
"""},
     {"src/repro/chain/simlax.py": """
import jax
import jax.numpy as jnp
from repro.models.helper import clamp

def body(state, t):
    return clamp(state), t

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
""",
      "src/repro/models/helper.py": """
import jax.numpy as jnp

def clamp(x):
    if x.ndim == 2:
        return jnp.abs(x)
    return jnp.abs(x)
"""}),
    ("prngkey-in-scan", "repro.chain.simlax",
     """
import jax
import jax.numpy as jnp

def body(state, t):
    key = jax.random.PRNGKey(0)
    return state + jax.random.normal(key, state.shape), t

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
""",
     """
import jax
import jax.numpy as jnp

def body(state, t):
    key = jax.random.fold_in(state_key, t)
    return state + jax.random.normal(key, state.shape), t

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
"""),
    ("fp16-wire", "repro.core.compression",
     """
import jax.numpy as jnp

def pack(scales):
    return scales.astype(jnp.float16)
""",
     """
import jax.numpy as jnp

def pack(scales):
    return scales.astype(jnp.bfloat16)
"""),
    ("fp16-wire", "repro.core.compression",
     """
import jax.numpy as jnp

def pack(scales):
    return scales.astype("float16")
""",
     """
import jax.numpy as jnp

def pack(scales):
    return scales.astype("bfloat16")
"""),
    # cross-module: the fp16 cast lives OUTSIDE the wire modules, but the
    # function's call graph reaches the codec — the payload is corrupted
    # all the same
    ("fp16-wire", "",
     {"src/repro/chain/node.py": """
import jax.numpy as jnp
from repro.core.compression import roundtrip

def send(tree):
    tree = jnp.asarray(tree).astype(jnp.float16)
    return roundtrip(tree)
""",
      "src/repro/core/compression.py": """
def roundtrip(tree):
    return tree
"""},
     {"src/repro/chain/node.py": """
import jax.numpy as jnp
from repro.core.compression import roundtrip

def send(tree):
    tree = jnp.asarray(tree).astype(jnp.bfloat16)
    return roundtrip(tree)
""",
      "src/repro/core/compression.py": """
def roundtrip(tree):
    return tree
"""}),
    ("f64-root", "repro.chain.simlax",
     """
import jax
import jax.numpy as jnp

def body(state, t):
    acc = state.astype(jnp.float64)
    return acc, t

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
""",
     """
import jax
import jax.numpy as jnp

def body(state, t):
    acc = state.astype(jnp.float32)
    return acc, t

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
"""),
    # cross-module dtype contract: the f64 promotion root sits in a helper
    # module but reaches jitted code through the traced chain
    ("f64-root", "",
     {"src/repro/chain/simlax.py": """
import jax
import jax.numpy as jnp
from repro.models.helper import accumulate

def body(state, t):
    return accumulate(state), t

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
""",
      "src/repro/models/helper.py": """
import jax.numpy as jnp

def accumulate(x):
    return jnp.asarray(x, dtype="float64")
"""},
     {"src/repro/chain/simlax.py": """
import jax
import jax.numpy as jnp
from repro.models.helper import accumulate

def body(state, t):
    return accumulate(state), t

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
""",
      "src/repro/models/helper.py": """
import jax.numpy as jnp

def accumulate(x):
    return jnp.asarray(x, dtype="float32")
"""}),
    ("prng-reuse", "repro.chain.simlax",
     """
import jax
import jax.numpy as jnp

def body(state, t):
    key = jax.random.fold_in(state[1], t)
    a = jax.random.normal(key, (3,))
    b = jax.random.normal(key, (3,))
    return state, (a, b)

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
""",
     """
import jax
import jax.numpy as jnp

def body(state, t):
    key = jax.random.fold_in(state[1], t)
    ka, kb = jax.random.split(key)
    a = jax.random.normal(ka, (3,))
    b = jax.random.normal(kb, (3,))
    return state, (a, b)

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
"""),
    # fold_in over distinct constants is the repo's stream-derivation
    # idiom and must NOT count as reuse
    ("prng-reuse", "repro.chain.simlax",
     """
import jax
import jax.numpy as jnp

def body(state, t):
    noise = jax.random.normal(state[1], (3,))
    more = jax.random.uniform(state[1], (3,))
    return state, (noise, more)

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
""",
     """
import jax
import jax.numpy as jnp

def body(state, t):
    noise = jax.random.normal(jax.random.fold_in(state[1], 0), (3,))
    more = jax.random.uniform(jax.random.fold_in(state[1], 1), (3,))
    return state, (noise, more)

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
"""),
    ("cached-closure-capture", "repro.chain.simlax",
     """
import jax

_SCAN_CACHE = {}

def make_sim(train_data, cfg):
    def dispatch(params, keys):
        return params, train_data
    _SCAN_CACHE[cfg] = jax.jit(dispatch)
    return _SCAN_CACHE[cfg]
""",
     """
import jax

_SCAN_CACHE = {}

def make_sim(train_data, cfg):
    def dispatch(params, keys, train_data):
        return params, train_data
    _SCAN_CACHE[cfg] = jax.jit(dispatch)
    return _SCAN_CACHE[cfg]
"""),
    # cross-module: the cache-fed function captures self._train_data
    ("cached-closure-capture", "repro.chain.simlax",
     """
import jax

_SCAN_CACHE = {}

class Sim:
    def __init__(self, cfg):
        _SCAN_CACHE[cfg] = jax.jit(self._scan)

    def _scan(self, params, keys):
        return params, self._train_data
""",
     """
import jax

_SCAN_CACHE = {}

class Sim:
    def __init__(self, cfg):
        _SCAN_CACHE[cfg] = jax.jit(self._scan)

    def _scan(self, params, keys, train_data):
        return params, train_data
"""),
    ("bare-ignore", "repro.chain.simlax",
     """
import jax
import jax.numpy as jnp

def body(state, t):
    idx = jnp.nonzero(state > 0)  # jaxlint: ignore
    return state, idx

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
""",
     """
import jax
import jax.numpy as jnp

def body(state, t):
    idx = jnp.nonzero(state > 0)  # jaxlint: ignore[nonzero-size]
    return state, idx

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
"""),
]

SUPPRESSION_FIXTURE = (
    "repro.chain.simlax",
    """
import jax
import jax.numpy as jnp

def body(state, t):
    idx = jnp.nonzero(state > 0)  # jaxlint: ignore[nonzero-size]
    return state, idx

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
""")

SELF_TEST_RULES = {
    "nonzero-size", "host-coercion", "np-in-traced", "traced-control-flow",
    "prngkey-in-scan", "fp16-wire", "f64-root", "prng-reuse",
    "cached-closure-capture", "bare-ignore",
}


def _lint_fixture(src: Source, module: str, tag: str):
    if isinstance(src, dict):
        return lint_project(Project.from_sources(src))
    return lint_source(src, f"<{tag}>", module)


def self_test() -> int:
    """Every rule must fire on its bad fixture and stay silent on the good
    one; suppression comments must mark findings suppressed; --explain must
    resolve a traced chain across a module boundary."""
    failures = []
    fired: Set[str] = set()
    for i, (rule, module, bad, good) in enumerate(FIXTURES):
        bad_hits = [f for f in _lint_fixture(bad, module, f"bad:{rule}:{i}")
                    if f.rule == rule and not f.suppressed]
        good_hits = [f for f in _lint_fixture(good, module,
                                              f"good:{rule}:{i}")
                     if not f.suppressed]
        if not bad_hits:
            failures.append(f"{rule}: bad fixture #{i} produced no finding")
        else:
            fired.add(rule)
        if good_hits:
            failures.append(
                f"{rule}: good fixture #{i} produced findings: "
                + "; ".join(f"{f.rule}@{f.path}:{f.line}"
                            for f in good_hits))
    module, src = SUPPRESSION_FIXTURE
    sup_hits = lint_source(src, "<suppressed>", module)
    if not sup_hits or not all(f.suppressed for f in sup_hits):
        failures.append("suppression: ignore[...] comment did not suppress")
    for missing in sorted(SELF_TEST_RULES - fired):
        failures.append(f"{missing}: no bad fixture fired this rule")
    # the acceptance contract for --explain: a derived traced chain that
    # crosses a module boundary must resolve through the cross-module call
    xmod = next(bad for rule, _m, bad, _g in FIXTURES
                if rule == "np-in-traced" and isinstance(bad, dict))
    model = Model(Project.from_sources(xmod))
    explain = "\n".join(model.explain("smooth"))
    if "TRACED" not in explain or "repro.chain.simlax" not in explain:
        failures.append(
            "explain: cross-module chain did not resolve: " + explain)
    for msg in failures:
        print(f"jaxlint,SELF-TEST-FAIL,{msg}")
    status = "FAIL" if failures else "OK"
    print(f"jaxlint,self-test,{status},rules={len(SELF_TEST_RULES)},"
          f"fixtures={len(FIXTURES) + 1}")
    return 1 if failures else 0
