"""Rule passes over a (:class:`Project`, :class:`Model`) pair.

Scoping model (documented per rule in docs/STATIC_ANALYSIS.md):

* *Blanket* scopes are unchanged from the module-local engine: the
  np/coercion/nonzero rules fire throughout traced functions of
  ``JITTED_MODULES``, and in direct scan bodies anywhere.
* *Value-sensitive* (cross-module) firing is new: when a parameter's
  taint arrived over a **cross-module call edge** (``foreign_taint``),
  the np/coercion/nonzero/control-flow rules fire on expressions that
  actually touch a tainted value — wherever the helper is defined. A
  helper's static config params stay untainted, so trace-time work on
  them stays legal.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from jaxlintlib import config
from jaxlintlib.model import Model
from jaxlintlib.project import Finding, FuncInfo, ModuleInfo, Project


class RuleRunner:
    def __init__(self, project: Project, model: Model):
        self.project = project
        self.model = model
        self.findings: List[Finding] = []

    # -- driver ------------------------------------------------------------
    def run(self) -> List[Finding]:
        for mod in self.project.modules.values():
            if mod.parse_error is not None:
                e = mod.parse_error
                self.findings.append(Finding(
                    "parse-error", mod.path, e.lineno or 0, 0, str(e)))
                continue
            self._run_module(mod)
        # one finding per (rule, site): blanket and value-sensitive scopes
        # can both match the same expression
        seen = set()
        out = []
        for f in sorted(self.findings,
                        key=lambda f: (f.path, f.line, f.col, f.rule)):
            key = (f.rule, f.path, f.line, f.col)
            if key not in seen:
                seen.add(key)
                out.append(f)
        return out

    def _run_module(self, mod: ModuleInfo):
        model = self.model
        jitted = mod.name in model.jitted_modules
        for line, col in mod.bare_ignores:
            self._emit_at("bare-ignore", mod, line, col,
                          "bare `# jaxlint: ignore` would waive every rule "
                          "on the line — name the rules: "
                          "`# jaxlint: ignore[rule-a, rule-b]`")
        for info in mod.funcs.values():
            host_entry = model.host_entry(mod, info)
            foreign = bool(info.foreign_taint)
            # nonzero-size: traced code in jitted modules must pin shapes;
            # cross-module, a helper whose tainted arg feeds the query
            if (jitted and info.traced) or foreign:
                self._rule_nonzero(mod, info,
                                   blanket=jitted and info.traced)
            # host-coercion / np-in-traced: blanket in jitted modules (plus
            # direct scan bodies anywhere) — traced helpers elsewhere may
            # legally compute on *static* args at trace time, so outside
            # the jitted set they fire only on foreign-tainted values
            if (jitted and info.traced) or info.scan_body or foreign:
                self._rule_coercion(mod, info,
                                    blanket=(jitted and info.traced)
                                    or info.scan_body)
            if ((jitted and (info.traced or host_entry is None)
                 and mod.np_aliases) or info.scan_body or foreign):
                self._rule_np(mod, info,
                              detected_traced=info.traced,
                              blanket=(jitted and host_entry is None)
                              or (jitted and info.traced)
                              or info.scan_body)
            if info.traced:
                self._rule_prngkey(mod, info)
                self._rule_f64(mod, info)
            if info.traced or info.scan_body:
                self._rule_prng_reuse(mod, info)
            if info.scan_body or foreign:
                self._rule_control_flow(mod, info)
            if info.wire_path and mod.name not in model.wire_modules:
                self._rule_fp16(mod, info=info)
            if info.cache_fed:
                self._rule_cache_capture(mod, info)
        if mod.name in model.wire_modules:
            self._rule_fp16(mod, info=None)

    # -- emit helpers -------------------------------------------------------
    def _emit(self, rule: str, mod: ModuleInfo, node: ast.AST, message: str):
        self.findings.append(Finding(
            rule=rule, path=mod.path, line=node.lineno,
            col=getattr(node, "col_offset", 0), message=message))

    def _emit_at(self, rule: str, mod: ModuleInfo, line: int, col: int,
                 message: str):
        self.findings.append(Finding(rule, mod.path, line, col, message))

    @staticmethod
    def _origin(info: FuncInfo) -> str:
        """' (taint entered via ...)' suffix for cross-module messages."""
        if not info.foreign_taint:
            return ""
        p, origin = sorted(info.foreign_taint.items())[0]
        return f" — param {p!r} tainted via {origin}"

    def _touches_taint(self, info: FuncInfo, call: ast.Call) -> bool:
        ta = info.taint
        if ta is None:
            return False
        return any(ta.expr_taints(a) for a in call.args) or any(
            ta.expr_taints(k.value) for k in call.keywords)

    # -- rules --------------------------------------------------------------
    def _rule_nonzero(self, mod: ModuleInfo, info: FuncInfo, blanket: bool):
        for n in mod.walk_fn_body(info):
            if not isinstance(n, ast.Call):
                continue
            f = n.func
            if not (isinstance(f, ast.Attribute)
                    and isinstance(f.value, ast.Name)
                    and f.value.id in mod.jnp_aliases):
                continue
            if not blanket and not self._touches_taint(info, n):
                continue
            kwnames = {k.arg for k in n.keywords}
            if f.attr in config.SIZE_WANTING and "size" not in kwnames:
                self._emit("nonzero-size", mod, n,
                           f"jnp.{f.attr} without size= in traced code "
                           f"({info.qualname}): result shape is data-"
                           "dependent and cannot be jitted — pin it with a "
                           "static budget (size=..., fill_value=...)"
                           + ("" if blanket else self._origin(info)))
            elif (f.attr == "where" and len(n.args) == 1
                  and "size" not in kwnames):
                self._emit("nonzero-size", mod, n,
                           f"single-arg jnp.where without size= in traced "
                           f"code ({info.qualname}): use the 3-arg form or "
                           "jnp.nonzero(size=...)"
                           + ("" if blanket else self._origin(info)))

    def _rule_coercion(self, mod: ModuleInfo, info: FuncInfo, blanket: bool):
        ta = info.taint
        for n in mod.walk_fn_body(info):
            if not isinstance(n, ast.Call):
                continue
            f = n.func
            if (isinstance(f, ast.Name) and f.id in config.COERCION_BUILTINS
                    and len(n.args) == 1 and not n.keywords
                    and not isinstance(n.args[0], ast.Constant)):
                if not blanket and not (ta and ta.expr_taints(n.args[0])):
                    continue
                self._emit("host-coercion", mod, n,
                           f"{f.id}() coercion in traced code "
                           f"({info.qualname}): forces a concrete value "
                           "mid-trace (ConcretizationTypeError on a tracer, "
                           "silently baked constant on host data)"
                           + ("" if blanket else self._origin(info)))
            elif (isinstance(f, ast.Attribute)
                  and f.attr in config.COERCION_METHODS
                  and not isinstance(f.value, ast.Constant)):
                if not blanket and not (ta and ta.expr_taints(f.value)):
                    continue
                self._emit("host-coercion", mod, n,
                           f".{f.attr}() in traced code ({info.qualname}): "
                           "pulls the value to host mid-trace"
                           + ("" if blanket else self._origin(info)))

    def _rule_np(self, mod: ModuleInfo, info: FuncInfo,
                 detected_traced: bool, blanket: bool):
        ta = info.taint
        for n in mod.walk_fn_body(info):
            if not isinstance(n, ast.Call):
                continue
            f = n.func
            root = f
            while isinstance(root, ast.Attribute):
                root = root.value
            if not (isinstance(root, ast.Name)
                    and root.id in mod.np_aliases):
                continue
            if not blanket:
                if not ta:
                    continue
                touches = any(ta.expr_taints(a) for a in n.args) or any(
                    ta.expr_taints(k.value) for k in n.keywords)
                if not touches:
                    continue
            where = ("traced code" if detected_traced
                     else "a jitted module without a host-side allowlist "
                          "entry")
            self._emit("np-in-traced", mod, n,
                       f"numpy call in {where} ({info.qualname}): numpy "
                       "ops bake host constants / break tracing — use jnp, "
                       "or move to the static-build phase and allowlist "
                       "the function in tools/jaxlintlib/config.py with a "
                       "rationale" + ("" if blanket else self._origin(info)))

    def _rule_prngkey(self, mod: ModuleInfo, info: FuncInfo):
        for n in mod.walk_fn_body(info):
            if not isinstance(n, ast.Call):
                continue
            f = n.func
            if isinstance(f, ast.Attribute) and f.attr in ("PRNGKey", "key"):
                v = f.value
                is_random = ((isinstance(v, ast.Name)
                              and (v.id == "random"
                                   or v.id in mod.random_aliases))
                             or (isinstance(v, ast.Attribute)
                                 and v.attr == "random"))
                if is_random:
                    self._emit("prngkey-in-scan", mod, n,
                               f"PRNGKey constructed inside a scan body "
                               f"({info.qualname}): keys must flow from the "
                               "fold_in(tick) stream (attacks.attack_fold) "
                               "or heap/lax parity silently diverges")

    def _rule_control_flow(self, mod: ModuleInfo, info: FuncInfo):
        ta = info.taint
        if ta is None:
            return
        origin = "" if info.scan_body else self._origin(info)
        for n in mod.walk_fn_body(info):
            if isinstance(n, ast.If) and ta.expr_taints(n.test):
                self._emit("traced-control-flow", mod, n,
                           f"python `if` over a traced value in "
                           f"{info.qualname}: branch on tracers with "
                           "lax.cond/jnp.where, not python control flow"
                           + origin)
            elif isinstance(n, ast.While) and ta.expr_taints(n.test):
                self._emit("traced-control-flow", mod, n,
                           f"python `while` over a traced value in "
                           f"{info.qualname}: use lax.while_loop" + origin)
            elif isinstance(n, ast.For) and ta.expr_taints(n.iter):
                self._emit("traced-control-flow", mod, n,
                           f"python `for` over a traced value in "
                           f"{info.qualname}: traced arrays cannot drive "
                           "python iteration — use lax.scan/vmap" + origin)

    def _rule_f64(self, mod: ModuleInfo, info: FuncInfo):
        dtype_roots = mod.np_aliases | mod.jnp_aliases
        for n in mod.walk_fn_body(info):
            if (isinstance(n, ast.Attribute)
                    and n.attr in config.F64_ATTRS
                    and isinstance(n.value, ast.Name)
                    and n.value.id in dtype_roots):
                self._emit("f64-root", mod, n,
                           f"float64 dtype in traced code "
                           f"({info.qualname}): an f64 promotion root "
                           "either upcasts the downstream computation "
                           "(x64 on) or silently truncates (x64 off) — "
                           "both break the bitwise heap<->lax parity pin; "
                           "use float32/bfloat16")
            elif isinstance(n, ast.Call):
                f = n.func
                # .astype(float) / dtype=float: weak f64 root under x64
                if (isinstance(f, ast.Attribute) and f.attr == "astype"
                        and n.args
                        and isinstance(n.args[0], ast.Name)
                        and n.args[0].id == "float"):
                    self._emit("f64-root", mod, n,
                               f".astype(float) in traced code "
                               f"({info.qualname}): python float means "
                               "float64 under x64 — name the dtype "
                               "(jnp.float32)")
                    continue
                for kw in n.keywords:
                    if (kw.arg == "dtype" and isinstance(kw.value, ast.Name)
                            and kw.value.id == "float"):
                        self._emit("f64-root", mod, kw.value,
                                   f"dtype=float in traced code "
                                   f"({info.qualname}): python float means "
                                   "float64 under x64 — name the dtype")
                for sub in list(n.args) + [k.value for k in n.keywords]:
                    if (isinstance(sub, ast.Constant)
                            and isinstance(sub.value, str)
                            and sub.value.lower() in config.F64_STRINGS):
                        self._emit("f64-root", mod, sub,
                                   f"'{sub.value}' dtype literal in traced "
                                   f"code ({info.qualname}): f64 roots "
                                   "break the parity pin — use float32")

    def _rule_prng_reuse(self, mod: ModuleInfo, info: FuncInfo):
        """Same key expression consumed by two jax.random primitives with
        no intervening split/rebind. fold_in is exempt: deriving streams
        via fold_in(key, i) over distinct constants is the repo idiom."""

        def consumer(call: ast.Call) -> Optional[ast.AST]:
            f = call.func
            if isinstance(f, ast.Attribute) and \
                    f.attr in config.PRNG_CONSUMERS:
                v = f.value
                is_random = ((isinstance(v, ast.Name)
                              and (v.id == "random"
                                   or v.id in mod.random_aliases))
                             or (isinstance(v, ast.Attribute)
                                 and v.attr == "random"))
                if is_random and call.args:
                    return call.args[0]
            return None

        def names_assigned(t: ast.AST, acc: set):
            if isinstance(t, ast.Name):
                acc.add(t.id)
            elif isinstance(t, (ast.Tuple, ast.List, ast.Starred)):
                for x in getattr(t, "elts", [getattr(t, "value", None)]):
                    if x is not None:
                        names_assigned(x, acc)

        nested = {id(i.node) for i in mod.funcs.values()
                  if i.parent == info.qualname}

        def stmt_calls(stmt: ast.AST):
            """Calls directly under a statement (nested blocks and nested
            function bodies excluded)."""
            block_fields = {"body", "orelse", "finalbody", "handlers"}
            out = []
            stack = [(stmt, True)]
            while stack:
                n, is_root = stack.pop()
                if id(n) in nested:
                    continue
                if isinstance(n, ast.Call):
                    out.append(n)
                for fname, value in ast.iter_fields(n):
                    if is_root and isinstance(
                            n, (ast.If, ast.While, ast.For, ast.With,
                                ast.Try)) and fname in block_fields:
                        continue
                    for child in (value if isinstance(value, list)
                                  else [value]):
                        if isinstance(child, ast.AST):
                            stack.append((child, False))
            return sorted(out, key=lambda c: (c.lineno, c.col_offset))

        def scan_block(stmts, counts):
            for stmt in stmts:
                for call in stmt_calls(stmt):
                    key = consumer(call)
                    if key is None:
                        continue
                    try:
                        rep = ast.unparse(key)
                    except Exception:
                        continue
                    counts[rep] = counts.get(rep, 0) + 1
                    if counts[rep] == 2:
                        self._emit("prng-reuse", mod, call,
                                   f"key `{rep}` consumed twice in "
                                   f"{info.qualname} without an "
                                   "intervening split/fold_in/rebind: "
                                   "reused keys correlate streams and "
                                   "silently break the bitwise heap<->lax "
                                   "parity contract")
                # rebinding a name retires every key expression built on it
                assigned: set = set()
                if isinstance(stmt, ast.Assign):
                    for t in stmt.targets:
                        names_assigned(t, assigned)
                elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
                    names_assigned(stmt.target, assigned)
                if assigned:
                    for rep in list(counts):
                        toks = set(
                            rep.replace("(", " ").replace(")", " ")
                            .replace("[", " ").replace("]", " ")
                            .replace(",", " ").replace(".", " ").split())
                        if toks & assigned:
                            del counts[rep]
                # branches see the prefix counts but not each other's
                if isinstance(stmt, (ast.If,)):
                    scan_block(stmt.body, dict(counts))
                    scan_block(stmt.orelse, dict(counts))
                elif isinstance(stmt, (ast.For, ast.While, ast.With,
                                       ast.Try)):
                    for block in ("body", "orelse", "finalbody"):
                        scan_block(getattr(stmt, block, []) or [],
                                   dict(counts))

        body = (info.node.body if isinstance(info.node.body, list)
                else [info.node.body])
        scan_block(body, {})

    def _rule_fp16(self, mod: ModuleInfo, info: Optional[FuncInfo]):
        dtype_roots = mod.np_aliases | mod.jnp_aliases
        where = ("a wire module" if info is None else
                 f"{info.qualname}, which is on a call path into a wire "
                 "module")
        nodes = (ast.walk(mod.tree) if info is None
                 else mod.walk_fn_body(info))
        for node in nodes:
            if (isinstance(node, ast.Attribute) and node.attr == "float16"
                    and isinstance(node.value, ast.Name)
                    and node.value.id in dtype_roots):
                self._emit("fp16-wire", mod, node,
                           f"float16 dtype in {where}: the scale "
                           "contract is bf16 (fp16 subnormal scales zero "
                           "small leaves — see core/compression.py)")
            elif isinstance(node, ast.Call):
                for sub in list(node.args) + [k.value for k in
                                              node.keywords]:
                    if (isinstance(sub, ast.Constant)
                            and isinstance(sub.value, str)
                            and sub.value.lower() in config.FP16_STRINGS):
                        self._emit("fp16-wire", mod, sub,
                                   f"float16 dtype literal in {where}: "
                                   "wire scales are bf16 by contract")

    def _rule_cache_capture(self, mod: ModuleInfo, info: FuncInfo):
        """Data-dependent closure captures in a function that feeds a scan
        cache: the capture outlives the call that created it, so a cached
        compile silently reuses stale data (the PR 8 bug class — train/
        eval data must be jit *arguments*)."""
        params = set(info.params)
        parent = mod.funcs.get(info.parent) if info.parent else None
        parent_taint = parent.taint.tainted if parent and parent.taint \
            else set()
        local: set = set()
        for n in mod.walk_fn_body(info):
            if isinstance(n, ast.Assign):
                for t in n.targets:
                    for sub in ast.walk(t):
                        if isinstance(sub, ast.Name):
                            local.add(sub.id)
            elif isinstance(n, (ast.For, ast.AsyncFor)):
                for sub in ast.walk(n.target):
                    if isinstance(sub, ast.Name):
                        local.add(sub.id)
        module_level = (set(mod.classes) | set(mod.sym_imports)
                        | set(mod.mod_imports)
                        | {i.name for i in mod.funcs.values()
                           if i.parent is None})
        reported: set = set()
        for n in mod.walk_fn_body(info):
            if (isinstance(n, ast.Attribute)
                    and isinstance(n.value, ast.Name)
                    and n.value.id in ("self", "cls")
                    and config.DATA_CAPTURE_RE.match(n.attr)
                    and n.attr not in reported):
                reported.add(n.attr)
                self._emit("cached-closure-capture", mod, n,
                           f"self.{n.attr} captured by {info.qualname}, "
                           f"which feeds a scan cache (stored at "
                           f"{info.cache_fed}): data captured by a cached "
                           "jitted callable is baked into the compile and "
                           "goes stale — pass it as a jit argument "
                           "instead")
            elif (isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)
                  and n.id not in params and n.id not in local
                  and n.id not in module_level and n.id not in reported):
                is_data = bool(config.DATA_CAPTURE_RE.match(n.id))
                is_traced_capture = n.id in parent_taint
                if is_data or is_traced_capture:
                    reported.add(n.id)
                    why = ("matches a federation-data name"
                           if is_data else
                           "carries a traced value in the enclosing scope")
                    self._emit("cached-closure-capture", mod, n,
                               f"free variable `{n.id}` ({why}) captured "
                               f"by {info.qualname}, which feeds a scan "
                               f"cache (stored at {info.cache_fed}): "
                               "captures outlive the call that created "
                               "them — pass the value as a jit argument")
