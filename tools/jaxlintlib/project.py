"""Parse a file set into a cross-module project graph.

Pure ``ast`` + ``tokenize`` — no jax import, no PYTHONPATH (same
dependency discipline as ``tools/docs_check.py``). A :class:`Project`
holds every module's alias tables, import tables, function table and
call sites; its resolvers turn call/function-reference expressions into
:class:`FuncInfo` targets across module boundaries (plain names,
``from``-imports, module-alias attributes, ``self.`` methods,
``ClassName.method``, lambdas, and ``partial``/wrapper chains followed
through local assignments).
"""
from __future__ import annotations

import ast
import io
import os
import tokenize
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Set, Tuple

from jaxlintlib import config

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


@dataclass
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str
    suppressed: bool = False

    def as_dict(self):
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message,
                "suppressed": self.suppressed}


@dataclass
class CallSite:
    call: ast.Call
    is_with: bool = False      # a `with ctx():` context manager call — the
                               # callee runs host-side at trace time, so it
                               # does not propagate tracedness
    is_entry: bool = False     # a tracing entry (jit/scan/...): tracedness
                               # flows to its function ARGUMENTS, not callee


@dataclass
class FuncInfo:
    node: ast.AST                      # FunctionDef / AsyncFunctionDef / Lambda
    qualname: str
    module: str                        # dotted module name
    parent: Optional[str]              # lexically enclosing function qualname
    cls: Optional[str]                 # enclosing class name, if a method
    params: Tuple[str, ...] = ()
    traced: bool = False
    scan_body: bool = False            # passed DIRECTLY to scan/while/cond/...
    calls: List[CallSite] = field(default_factory=list)
    # --- filled by jaxlintlib.model ---
    reasons: list = field(default_factory=list)          # List[TraceReason]
    tainted_params: Set[str] = field(default_factory=set)
    foreign_taint: Dict[str, str] = field(default_factory=dict)
    # param -> "module.qual:line" of the cross-module caller that tainted it
    closure_taint: Set[str] = field(default_factory=set)
    taint: Optional[object] = None                        # TaintInfo
    wire_path: bool = False
    cache_fed: Optional[str] = None    # "path:line" of the cache store

    @property
    def name(self) -> str:
        return self.qualname.rsplit(".", 1)[-1]

    def add_reason(self, reason) -> bool:
        """Record a trace reason once per (kind, via); returns True if new."""
        key = (reason.kind, reason.via.qualname if reason.via else None)
        for r in self.reasons:
            if (r.kind, r.via.qualname if r.via else None) == key:
                return False
        self.reasons.append(reason)
        return True


def module_name(path: str, root: str = REPO) -> str:
    """Dotted module name for a repo file (src-rooted for src/)."""
    rel = os.path.relpath(os.path.abspath(path), root)
    parts = rel.replace(os.sep, "/").split("/")
    if parts and parts[0] == "src":
        parts = parts[1:]
    if parts and parts[-1].endswith(".py"):
        parts[-1] = parts[-1][:-3]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def scan_suppressions(source: str):
    """Returns (line -> suppressed rule ids, [(line, col) of bare ignores]).

    Only the rule-scoped form ``# jaxlint: ignore[rule-a, rule-b]`` (or
    ``ignore[*]``) suppresses. A bare ``# jaxlint: ignore`` — which would
    silently waive *every* rule on the line — is rejected and reported as
    a ``bare-ignore`` finding instead.
    """
    out: Dict[int, Set[str]] = {}
    bare: List[Tuple[int, int]] = []
    try:
        toks = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in toks:
            if tok.type != tokenize.COMMENT:
                continue
            text = tok.string
            marker = "jaxlint:"
            if marker not in text:
                continue
            rest = text.split(marker, 1)[1].strip()
            if not rest.startswith("ignore"):
                continue
            ids: Set[str] = set()
            if rest.startswith("ignore[") and "]" in rest:
                rules = rest[len("ignore["):rest.index("]")]
                ids = {r.strip() for r in rules.split(",") if r.strip()}
            if ids:
                out.setdefault(tok.start[0], set()).update(ids)
            else:
                bare.append(tok.start)
    except tokenize.TokenError:
        pass
    return out, bare


class ModuleInfo:
    """One parsed file: aliases, imports, functions, classes, call sites."""

    def __init__(self, name: str, path: str, source: str, tree_kind: str):
        self.name = name
        self.path = path
        self.source = source
        self.tree_kind = tree_kind
        self.parse_error: Optional[SyntaxError] = None
        self.tree: Optional[ast.Module] = None
        self.np_aliases: Set[str] = set()
        self.jnp_aliases: Set[str] = set()
        self.lax_aliases: Set[str] = set()
        self.jax_aliases: Set[str] = set()
        self.random_aliases: Set[str] = set()   # `from jax import random [as r]`
        self.mod_imports: Dict[str, str] = {}   # local alias -> dotted module
        self.sym_imports: Dict[str, Tuple[str, str]] = {}  # name -> (module, symbol)
        self.classes: Set[str] = set()          # top-level class names
        self.funcs: Dict[str, FuncInfo] = {}
        self.suppressions: Dict[int, Set[str]] = {}
        self.bare_ignores: List[Tuple[int, int]] = []
        try:
            self.tree = ast.parse(source)
        except SyntaxError as e:
            self.parse_error = e
            return
        self.suppressions, self.bare_ignores = scan_suppressions(source)
        self._collect_imports()
        self._collect_funcs()
        self._collect_calls()

    # -- setup ------------------------------------------------------------
    def _pkg(self) -> str:
        """Package prefix for resolving relative imports."""
        if self.path.replace(os.sep, "/").endswith("/__init__.py"):
            return self.name
        return self.name.rsplit(".", 1)[0] if "." in self.name else ""

    def _collect_imports(self):
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    name = a.asname or a.name.split(".")[0]
                    if a.name == "numpy":
                        self.np_aliases.add(a.asname or a.name)
                    elif a.name == "jax.numpy":
                        self.jnp_aliases.add(a.asname or a.name)
                    elif a.name == "jax":
                        self.jax_aliases.add(name)
                    if a.asname:
                        self.mod_imports[a.asname] = a.name
                    else:
                        self.mod_imports[a.name.split(".")[0]] = \
                            a.name.split(".")[0]
            elif isinstance(node, ast.ImportFrom):
                base = node.module or ""
                if node.level:
                    pkg_parts = self._pkg().split(".") if self._pkg() else []
                    up = node.level - 1
                    pkg_parts = pkg_parts[:len(pkg_parts) - up] if up else \
                        pkg_parts
                    base = ".".join(pkg_parts + ([node.module]
                                                 if node.module else []))
                if base == "jax":
                    for a in node.names:
                        name = a.asname or a.name
                        if a.name == "numpy":
                            self.jnp_aliases.add(name)
                        elif a.name == "lax":
                            self.lax_aliases.add(name)
                        elif a.name == "random":
                            self.random_aliases.add(name)
                for a in node.names:
                    name = a.asname or a.name
                    self.sym_imports[name] = (base, a.name)

    def _collect_funcs(self):
        mod = self

        class V(ast.NodeVisitor):
            def __init__(self):
                self.stack: List[str] = []
                self.fn_stack: List[str] = []
                self.cls_stack: List[str] = []

            def _add(self, node, name):
                qual = ".".join(self.stack + [name])
                a = node.args
                params = [arg.arg for arg in
                          (list(a.posonlyargs) + list(a.args)
                           + list(a.kwonlyargs)
                           + ([a.vararg] if a.vararg else [])
                           + ([a.kwarg] if a.kwarg else []))]
                mod.funcs[qual] = FuncInfo(
                    node=node, qualname=qual, module=mod.name,
                    parent=self.fn_stack[-1] if self.fn_stack else None,
                    cls=self.cls_stack[-1] if self.cls_stack else None,
                    params=tuple(params))
                return qual

            def visit_ClassDef(self, node):
                if not self.stack:
                    mod.classes.add(node.name)
                self.stack.append(node.name)
                self.cls_stack.append(node.name)
                self.generic_visit(node)
                self.cls_stack.pop()
                self.stack.pop()

            def _visit_fn(self, node, name):
                qual = self._add(node, name)
                self.stack.append(name)
                self.fn_stack.append(qual)
                self.generic_visit(node)
                self.fn_stack.pop()
                self.stack.pop()

            def visit_FunctionDef(self, node):
                self._visit_fn(node, node.name)

            def visit_AsyncFunctionDef(self, node):
                self._visit_fn(node, node.name)

            def visit_Lambda(self, node):
                self._visit_fn(node, f"<lambda@{node.lineno}>")

        V().visit(self.tree)

    def _collect_calls(self):
        for info in self.funcs.values():
            with_calls = set()
            for n in self.walk_fn_body(info):
                if isinstance(n, (ast.With, ast.AsyncWith)):
                    for item in n.items:
                        if isinstance(item.context_expr, ast.Call):
                            with_calls.add(id(item.context_expr))
            for n in self.walk_fn_body(info):
                if isinstance(n, ast.Call):
                    info.calls.append(CallSite(
                        call=n, is_with=id(n) in with_calls,
                        is_entry=self.tracing_entry(n.func) is not None))

    # -- structural helpers -----------------------------------------------
    def walk_fn_body(self, info: FuncInfo) -> Iterable[ast.AST]:
        """Nodes belonging to this function but not to a nested function."""
        nested = {id(i.node) for i in self.funcs.values()
                  if i.parent == info.qualname}
        body = (info.node.body if isinstance(info.node.body, list)
                else [info.node.body])
        stack = list(body)
        while stack:
            n = stack.pop()
            if not isinstance(n, ast.AST) or id(n) in nested:
                continue
            yield n
            stack.extend(ast.iter_child_nodes(n))

    def enclosing(self, node: ast.AST) -> Optional[FuncInfo]:
        """Innermost function containing a node (by line span)."""
        lineno = getattr(node, "lineno", None)
        if lineno is None:
            return None
        best, best_span = None, None
        for info in self.funcs.values():
            n = info.node
            end = getattr(n, "end_lineno", n.lineno)
            if n.lineno <= lineno <= end:
                span = end - n.lineno
                if best_span is None or span < best_span:
                    best, best_span = info, span
        return best

    def scope_body(self, scope: Optional[FuncInfo]) -> List[ast.AST]:
        """Statement list for local-assignment chasing: the function's own
        body (nested functions excluded) or the module's top level."""
        if scope is not None:
            return list(self.walk_fn_body(scope))
        out = []
        in_fn = {id(n) for i in self.funcs.values()
                 for n in ast.walk(i.node)}
        for n in ast.walk(self.tree):
            if id(n) not in in_fn:
                out.append(n)
        return out

    def tracing_entry(self, func: ast.AST) -> Optional[str]:
        """If `func` is jit/vmap/scan/... return its short name, else None."""
        if isinstance(func, ast.Name) and func.id in config.TRACING_NAME_FUNCS:
            return func.id
        if isinstance(func, ast.Attribute):
            attr = func.attr
            if attr == "map":
                # only lax.map / jax.lax.map (python's map is not a tracer)
                v = func.value
                if isinstance(v, ast.Name) and v.id in self.lax_aliases:
                    return attr
                if isinstance(v, ast.Attribute) and v.attr == "lax":
                    return attr
                return None
            if attr in config.TRACING_ATTR_FUNCS:
                return attr
        return None


class Project:
    """All modules of a lint run, with cross-module resolution."""

    def __init__(self, modules: List[ModuleInfo]):
        self.modules: Dict[str, ModuleInfo] = {}
        for m in modules:
            self.modules[m.name] = m

    # -- construction -----------------------------------------------------
    @classmethod
    def from_paths(cls, paths: List[str], root: str = REPO) -> "Project":
        files: List[str] = []
        for p in paths:
            if os.path.isfile(p):
                files.append(p)
            else:
                for dirpath, dirnames, filenames in os.walk(p):
                    dirnames[:] = [d for d in dirnames
                                   if d not in ("__pycache__", ".git")]
                    files.extend(os.path.join(dirpath, f)
                                 for f in sorted(filenames)
                                 if f.endswith(".py"))
        mods = []
        for fp in sorted(files):
            with open(fp, "r", encoding="utf-8") as fh:
                src = fh.read()
            rel = os.path.relpath(os.path.abspath(fp), root)
            mods.append(ModuleInfo(module_name(fp, root), rel, src,
                                   rel.replace(os.sep, "/").split("/")[0]))
        return cls(mods)

    @classmethod
    def from_sources(cls, sources: Dict[str, str]) -> "Project":
        """In-memory project from {repo-relative-path: source} — the
        multi-file fixture/test entry point."""
        mods = []
        for rel, src in sorted(sources.items()):
            rel = rel.replace(os.sep, "/")
            name = rel
            parts = rel.split("/")
            if parts and parts[0] == "src":
                parts = parts[1:]
            if parts and parts[-1].endswith(".py"):
                parts[-1] = parts[-1][:-3]
            if parts and parts[-1] == "__init__":
                parts = parts[:-1]
            name = ".".join(parts)
            mods.append(ModuleInfo(name, rel, src, rel.split("/")[0]))
        return cls(mods)

    @classmethod
    def single(cls, source: str, path: str, module: str) -> "Project":
        """One in-memory module under an explicit dotted name — the
        lint_source() back-compat path."""
        return cls([ModuleInfo(module, path, source, "src")])

    # -- iteration --------------------------------------------------------
    def iter_funcs(self) -> Iterable[FuncInfo]:
        for m in self.modules.values():
            yield from m.funcs.values()

    def mod_of(self, info: FuncInfo) -> ModuleInfo:
        return self.modules[info.module]

    # -- resolution -------------------------------------------------------
    def _local_by_name(self, mod: ModuleInfo, short: str,
                       cls_name: Optional[str] = None) -> List[FuncInfo]:
        hits = [i for i in mod.funcs.values() if i.name == short]
        if cls_name is not None:
            scoped = [i for i in hits if i.cls == cls_name]
            if scoped:
                return scoped
        return hits

    def _toplevel_func(self, modname: str, short: str) -> List[FuncInfo]:
        m = self.modules.get(modname)
        if m is None:
            return []
        info = m.funcs.get(short)
        return [info] if info is not None else []

    def resolve_funcref(self, mod: ModuleInfo, scope: Optional[FuncInfo],
                        expr: ast.AST, _depth: int = 0,
                        _seen: Optional[Set[Tuple[str, str]]] = None,
                        ) -> List[FuncInfo]:
        """FuncInfos an expression may refer to (best-effort, cross-module).

        Handles: bare names (local defs, from-imports, local assignments
        chased through partial/jit/count_traces wrappers and tuples),
        ``self.method`` / ``cls.method``, ``alias.func`` module attributes,
        ``ClassName.method``, and lambdas.
        """
        if _depth > 6:
            return []
        _seen = _seen or set()
        if isinstance(expr, ast.Lambda):
            key = f"<lambda@{expr.lineno}>"
            return [i for i in mod.funcs.values()
                    if i.name == key and i.node is expr] or \
                   [i for i in mod.funcs.values() if i.name == key]
        if isinstance(expr, ast.Name):
            nm = expr.id
            if (mod.name, nm) in _seen:
                return []
            _seen = _seen | {(mod.name, nm)}
            # 1. module-local function definitions
            hits = self._local_by_name(mod, nm,
                                       scope.cls if scope else None)
            hits = [h for h in hits if h.cls is None or
                    (scope is not None and h.cls == scope.cls)]
            if hits:
                return hits
            # 2. from-imports: plain function in the source module
            if nm in mod.sym_imports:
                src_mod, sym = mod.sym_imports[nm]
                got = self._toplevel_func(src_mod, sym)
                if got:
                    return got
            # 3. local assignment dataflow (train_v = jax.vmap(...), etc.)
            out: List[FuncInfo] = []
            for n in mod.scope_body(scope):
                if isinstance(n, ast.Assign):
                    for t in n.targets:
                        if isinstance(t, ast.Name) and t.id == nm:
                            out.extend(self.resolve_funcref(
                                mod, scope, n.value, _depth + 1, _seen))
            return out
        if isinstance(expr, ast.Attribute):
            v = expr.value
            attr = expr.attr
            if isinstance(v, ast.Name):
                if v.id in ("self", "cls"):
                    return self._local_by_name(
                        mod, attr, scope.cls if scope else None)
                # module alias: `compression.quantize_tensor`
                target = None
                if v.id in mod.sym_imports:
                    base, sym = mod.sym_imports[v.id]
                    dotted = f"{base}.{sym}" if base else sym
                    if dotted in self.modules:
                        target = dotted
                    elif base in self.modules and sym in \
                            self.modules[base].classes:
                        # imported class: ClassName.method
                        return [i for i in
                                self.modules[base].funcs.values()
                                if i.qualname == f"{sym}.{attr}"]
                if target is None and v.id in mod.mod_imports:
                    dotted = mod.mod_imports[v.id]
                    if dotted in self.modules:
                        target = dotted
                if target is not None:
                    return self._toplevel_func(target, attr)
                # local class: ClassName.method
                if v.id in mod.classes:
                    return [i for i in mod.funcs.values()
                            if i.qualname == f"{v.id}.{attr}"]
                return []
            # dotted module path: repro.core.fedavg.tree_mean
            parts = []
            cur = expr
            while isinstance(cur, ast.Attribute):
                parts.append(cur.attr)
                cur = cur.value
            if isinstance(cur, ast.Name):
                parts.append(cur.id)
                dotted = ".".join(reversed(parts[1:]))
                if dotted in self.modules:
                    return self._toplevel_func(dotted, attr)
            return []
        if isinstance(expr, (ast.Tuple, ast.List)):
            out = []
            for e in expr.elts:
                out.extend(self.resolve_funcref(mod, scope, e,
                                                _depth + 1, _seen))
            return out
        if isinstance(expr, ast.Call):
            f = expr.func
            short = f.id if isinstance(f, ast.Name) else (
                f.attr if isinstance(f, ast.Attribute) else None)
            is_wrap = short in config.WRAPPER_FUNCS
            is_entry = mod.tracing_entry(f) is not None
            if (is_wrap or is_entry) and expr.args:
                return self.resolve_funcref(mod, scope, expr.args[0],
                                            _depth + 1, _seen)
        return []

    def resolve_call(self, mod: ModuleInfo, scope: Optional[FuncInfo],
                     call: ast.Call) -> List[FuncInfo]:
        return self.resolve_funcref(mod, scope, call.func)

    def find_funcs(self, query: str) -> List[FuncInfo]:
        """Match '--explain' queries: 'module.Qual.name', 'Qual.name' or a
        bare function name."""
        out = []
        for m in self.modules.values():
            for q, info in m.funcs.items():
                full = f"{m.name}.{q}"
                if query in (full, q, info.name) or full.endswith(
                        "." + query):
                    out.append(info)
        return out
