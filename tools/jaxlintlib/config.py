"""Repo contract tables and shared syntax sets.

Since the cross-module engine landed, the tables below are **asserted
overrides**, not the model: ``jaxlintlib.model`` derives the jit boundary
from actual ``jax.jit`` / ``lax.scan`` / ``vmap`` / ``pallas_call`` call
sites and decorators, and ``python tools/jaxlint.py --check-model`` fails
CI when a table entry stops being confirmed by the derivation (stale
module, dead seed pattern, vanished allowlist qualname) or when a traced
chain rooted in a jitted module escapes into a module the table does not
list.
"""
from __future__ import annotations

import re

# Modules whose bodies are (transitively) jitted: the tick-loop fabric, the
# gossip round, and the kernels it lowers to. Trace-hygiene rules treat any
# traced context in these modules as load-bearing, and the *blanket* rules
# (np-in-traced outside traced functions, host-coercion in any traced
# function) stay scoped here. --check-model asserts each entry is confirmed
# by at least one derived tracing site reaching it.
JITTED_MODULES = {
    "repro.chain.simlax",
    "repro.chain.attacks",
    "repro.core.gossip",
    "repro.core.fedavg",
    "repro.core.compression",
    "repro.core.reputation",
    "repro.core.dfl",
    "repro.kernels.quantize.ref",
    "repro.kernels.quantize.ops",
    "repro.kernels.quantize.quantize",
    "repro.kernels.wfedavg.ref",
    "repro.kernels.wfedavg.ops",
    "repro.kernels.wfedavg.wfedavg",
}

# Functions in jitted modules that are host-side BY DESIGN (static build /
# result unpacking). numpy is legal here; the rationale records why. A
# function both allowlisted and *detected* as traced is still flagged —
# the allowlist cannot mask a real leak into the scan.
HOST_SIDE_FUNCS = {
    "repro.chain.simlax": {
        "LaxSimulator.__init__":
            "static-build phase: schedules, budgets, slot tables are "
            "computed once on host and baked as consts",
        "LaxSimulator.run":
            "entry point: seeds PRNG, dispatches the jitted scan, "
            "post-checks overflow on materialized numpy outputs",
        "LaxSimulator._package":
            "unpacks device outputs to numpy history records",
        "LaxSimulator.lower_scan":
            "audit surface: lowers (never executes) the cached scan",
        "SimLaxResult.mean_reputation":
            "result accessor over materialized numpy history",
    },
    "repro.chain.attacks": {
        "FederationSpec.build":
            "host-side role-sheet expansion (static per federation)",
        "FederationSpec.attack_groups":
            "host-side group extraction from the static role sheet",
        "FederationSpec.attack_key_fns":
            "host-side construction of the per-group fold_in streams",
        "BatchedFederationSpec.build":
            "host-side stacking of member role sheets",
        "BatchedFederationSpec.attack_union":
            "host-side union over member role sheets",
        "MembershipSchedule.timeline":
            "host-side expansion of churn events to dense per-tick "
            "alive/rejoin masks, baked as scan consts at build time",
    },
}

# JITTED_MODULES entries the derivation cannot confirm from the analysis
# surface (src/benchmarks/tools), each with the reason the AST resolver
# cannot see the edge. --check-model is bidirectional about these: an
# unasserted unconfirmed entry is stale, and an asserted entry that BECOMES
# derivable must drop its assertion (the rationale has gone stale instead).
ASSERTED_JITTED = {
    "repro.chain.attacks":
        "Attack.apply dispatches through attack-registry instances "
        "(`for g, attack in enumerate(attack_instances)` in the simlax "
        "scan body) — instance dispatch is invisible to the resolver",
    "repro.core.reputation":
        "ReputationImpl methods run in-scan via the rep_impl instance "
        "attribute; only data attrs (.penalty/.floor) appear as names",
    "repro.kernels.quantize.ops":
        "jitted from the tests' kernel-parity harness; src callers reach "
        "the pallas kernels in .quantize directly",
    "repro.kernels.quantize.ref":
        "pure jnp oracle, jitted only from tests/ comparisons",
    "repro.kernels.wfedavg.ops":
        "called from the host-side heap engine (node.py) and benchmarks; "
        "the jit entry lives in .wfedavg",
    "repro.kernels.wfedavg.ref":
        "pure jnp oracle, jitted only from tests/ comparisons",
}

# Extra traced seeds the detector cannot see statically (methods handed to
# jit/vmap via instance attributes, or called from the other engine).
# --check-model asserts every pattern still matches at least one function.
TRACED_SEEDS = {
    "repro.chain.simlax": {"LaxSimulator._scan",
                           "LaxSimulator._scan_sharded"},
    "repro.chain.attacks": {"*.apply"},       # every Attack.apply runs in-scan
    "repro.core.compression": {"*"},          # fully traced wire codec
    "repro.core.fedavg": {"*"},               # fully traced aggregation
    "repro.core.reputation": {"ReputationImpl.*"},
}

# Modules that put bytes on the wire: float16 literals here bypass the bf16
# scale contract (PR 7: fp16 subnormal scales silently zeroed tiny leaves).
# The fp16-wire rule also fires in any *function* (any module) whose call
# graph reaches one of these modules — wire corruption does not care which
# file the cast lives in.
WIRE_MODULES = {
    "repro.core.compression",
    "repro.core.gossip",
    "repro.chain.simlax",
    "repro.kernels.quantize.ref",
    "repro.kernels.quantize.ops",
    "repro.kernels.quantize.quantize",
}

# Call-sites that hand a function to the tracer. Name-style entries apply to
# bare names (``from jax import vmap``); attr-style to ``<root>.<attr>``.
TRACING_NAME_FUNCS = {"jit", "vmap", "pmap", "shard_map", "pallas_call",
                      "scan", "cond", "while_loop", "fori_loop", "switch",
                      "grad", "value_and_grad", "checkpoint", "remat"}
TRACING_ATTR_FUNCS = TRACING_NAME_FUNCS | {"custom_vjp", "custom_jvp"}
# tracing entries whose callee's parameters are ALL traced by construction
# (scan carry/xs, while/fori carry, cond/switch operands) — the only scope
# where "python control flow over a parameter-derived name" is a sound rule
SCAN_BODY_FUNCS = {"scan", "while_loop", "fori_loop", "cond", "switch"}
# tracing entries whose callee parameters are traced *under jit semantics*:
# every non-static arg is a tracer once the wrapper is jitted. Used for
# cross-module param taint (with static_argnums/static_argnames honored),
# NOT for the scan-body blanket rules.
JIT_PARAM_FUNCS = {"jit", "pallas_call", "shard_map", "grad",
                   "value_and_grad", "vmap", "pmap", "checkpoint", "remat"}

# Wrapper callables whose first positional argument is the real function:
# `jax.jit(count_traces(dispatch))` must derive `dispatch` as traced. The
# local-dataflow resolver chases through these.
WRAPPER_FUNCS = {"partial", "count_traces", "assert_max_traces", "wraps"}

COERCION_BUILTINS = {"float", "int", "bool"}
COERCION_METHODS = {"item", "tolist"}
SIZE_WANTING = {"nonzero", "flatnonzero", "argwhere"}
# attributes of a traced value that are static python objects (no taint)
STATIC_ATTRS = {"shape", "dtype", "ndim", "size", "sharding"}

# --- dtype-contract rule (f64-root) ---------------------------------------
# f64 promotion roots: an explicit float64 dtype in traced code either
# upcasts the whole downstream computation (x64 enabled) or silently
# truncates (x64 disabled) — both break the heap<->lax bitwise-parity pin.
F64_ATTRS = {"float64", "double", "longdouble"}
F64_STRINGS = {"float64", "f64", "double"}
FP16_STRINGS = {"float16", "f16", "fp16"}

# --- prng-reuse rule ------------------------------------------------------
# jax.random callables that CONSUME a key (same key to two of these =
# correlated streams). fold_in is deliberately absent: deriving many
# streams from one key via fold_in(key, i) over distinct constants is the
# repo's documented idiom (attacks.attack_fold).
PRNG_CONSUMERS = {
    "split", "normal", "uniform", "randint", "bernoulli", "permutation",
    "choice", "categorical", "gumbel", "bits", "truncated_normal",
    "dirichlet", "beta", "gamma", "poisson", "exponential", "laplace",
    "shuffle",
}

# --- cached-closure-capture rule ------------------------------------------
# names of module-level dicts that cache jitted callables keyed on static
# config (simlax._SCAN_CACHE). Functions whose references flow into a store
# on one of these are "cache-fed": any data-dependent closure capture in
# them outlives the call that created it (the exact bug class PR 8 fixed by
# moving train/eval data to jit arguments).
SCAN_CACHE_NAMES = {"_SCAN_CACHE"}
# free-variable / self-attribute names that look like federation data; a
# cache-fed function may only receive these as *parameters*
DATA_CAPTURE_RE = re.compile(
    r"^_?((train|eval|test)_(data|batches?|set)|(datasets?|batches))$")

# --- per-tree rule profiles (CI repo pass over src benchmarks tools) ------
# keyed on the first path component of the file's repo-relative path; the
# value is the set of rule ids DISABLED for that tree. benchmarks' timing
# harnesses legitimately pull scalars to host between measured sections.
TREE_PROFILES = {
    "src": frozenset(),
    "benchmarks": frozenset({"host-coercion"}),
    "tools": frozenset(),
    "tests": frozenset({"host-coercion", "np-in-traced"}),
}

ALL_RULES = {
    "nonzero-size", "host-coercion", "np-in-traced", "traced-control-flow",
    "prngkey-in-scan", "fp16-wire", "f64-root", "prng-reuse",
    "cached-closure-capture", "bare-ignore", "parse-error",
}
