"""Lint driver: build Project -> Model -> rules -> suppressed findings.

Suppressions are file-local by construction: each module's
``# jaxlint: ignore[rule]`` table only applies to findings whose path is
that module's path — a suppression in module A never silences a
cross-module finding reported in module B.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional

from jaxlintlib import config
from jaxlintlib.model import Model
from jaxlintlib.project import REPO, Finding, Project


def _apply_suppressions(project: Project,
                        findings: List[Finding]) -> List[Finding]:
    sup_by_path: Dict[str, Dict[int, set]] = {
        m.path: m.suppressions for m in project.modules.values()}
    for f in findings:
        rules = sup_by_path.get(f.path, {}).get(f.line, set())
        if f.rule != "bare-ignore" and ("*" in rules or f.rule in rules):
            f.suppressed = True
    return findings


def _apply_profiles(project: Project,
                    findings: List[Finding]) -> List[Finding]:
    """Per-tree rule profiles (config.TREE_PROFILES): drop findings whose
    rule is disabled for the tree the file lives in."""
    tree_by_path = {m.path: m.tree_kind for m in project.modules.values()}
    out = []
    for f in findings:
        disabled = config.TREE_PROFILES.get(tree_by_path.get(f.path, ""),
                                            frozenset())
        if f.rule in disabled:
            continue
        out.append(f)
    return out


def lint_project(project: Project,
                 model: Optional[Model] = None) -> List[Finding]:
    from jaxlintlib.rules import RuleRunner
    if model is None:
        model = Model(project)
    findings = RuleRunner(project, model).run()
    findings = _apply_profiles(project, findings)
    return _apply_suppressions(project, findings)


def lint_source(source: str, path: str, module: Optional[str] = None,
                ) -> List[Finding]:
    """Analyze one source blob (back-compat single-file entry point)."""
    from jaxlintlib.project import module_name
    module = module if module is not None else module_name(path)
    project = Project.single(source, path, module)
    return lint_project(project)


def lint_paths(paths: List[str], root: str = REPO) -> List[Finding]:
    project = Project.from_paths([os.path.abspath(p) for p in paths], root)
    return lint_project(project)
