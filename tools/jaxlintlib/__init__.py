"""jaxlintlib — the repo-wide trace-hygiene analysis engine behind
``tools/jaxlint.py``.

Layout (each module documented in docs/STATIC_ANALYSIS.md):

    config    the repo contract tables (JITTED_MODULES, TRACED_SEEDS,
              HOST_SIDE_FUNCS, WIRE_MODULES) — now asserted-consistent
              overrides over the DERIVED model, not the model itself —
              plus the syntax sets every pass shares
    project   parse a file set into modules / functions / import tables /
              resolvable cross-module call edges (pure ast + tokenize,
              no jax import)
    model     the derived jit-boundary model: tracing-entry detection,
              traced/param-taint propagation across modules, wire-path
              reverse reachability, scan-cache-fed function derivation,
              --explain chains, table consistency checks
    rules     the rule passes over (project, model)
    fixtures  embedded bad/good sources for --self-test
    cli       argument parsing, per-tree rule profiles, entry point
"""
from jaxlintlib.cli import main  # noqa: F401
from jaxlintlib.engine import (  # noqa: F401
    lint_paths,
    lint_project,
    lint_source,
)
from jaxlintlib.fixtures import self_test  # noqa: F401
from jaxlintlib.model import Model  # noqa: F401
from jaxlintlib.project import Finding, Project  # noqa: F401
