"""The derived jit-boundary model.

Instead of trusting the hand-maintained tables, the model *derives*
traced contexts from the source:

1. **Tracing entries.** Every ``jit`` / ``vmap`` / ``scan`` /
   ``pallas_call`` / ``shard_map`` / ... call site and decorator marks its
   function arguments traced — following local dataflow, so
   ``counted = count_traces(dispatch); jax.jit(counted)`` derives
   ``dispatch``, and ``train_v = jax.vmap(self._train_fn, ...)`` chases
   through the assignment.
2. **Propagation.** Tracedness spreads through lexical nesting and
   *resolvable* call edges — now cross-module — with every hop recorded
   as a :class:`TraceReason` so ``--explain`` can print the chain.
   Context-manager calls (``with sharding_ctx():``) and the tracing
   entries themselves do not propagate (their bodies are host-side
   trace-time plumbing).
3. **Param taint.** Scan bodies taint every parameter; jit-like entries
   taint every non-static parameter; taint then flows argument-by-
   argument through resolvable call sites. Taint that crosses a module
   boundary is recorded as *foreign* — the license for ``np-in-traced``
   / ``host-coercion`` / ``traced-control-flow`` to fire on helpers
   defined in other files.
4. **Wire reachability.** Any function whose call graph reaches a
   ``WIRE_MODULES`` module is on the wire path (``fp16-wire`` fires on
   its body wherever it lives).
5. **Cache-fed functions.** Functions whose references flow into a
   ``simlax._SCAN_CACHE`` store outlive the call that created them —
   the ``cached-closure-capture`` rule's scope.

The checked-in tables (``config.JITTED_MODULES`` etc.) are applied *after*
derivation as asserted overrides; :meth:`Model.check` reports every
disagreement between them and the derived model.
"""
from __future__ import annotations

import ast
import fnmatch
from dataclasses import dataclass
from typing import Dict, List, Optional, Set

from jaxlintlib import config
from jaxlintlib.project import FuncInfo, ModuleInfo, Project


@dataclass
class TraceReason:
    kind: str            # "entry" | "decorator" | "seed-table" | "nesting" | "call"
    detail: str          # human-readable evidence
    site_module: str     # module the evidence lives in
    line: int
    via: Optional[FuncInfo] = None   # previous hop for chain reasons


class TaintInfo:
    """Intra-function taint: which local names carry traced values, and an
    ``expr_taints`` oracle the rules reuse."""

    def __init__(self, mod: ModuleInfo, info: FuncInfo, seeds: Set[str]):
        self.mod = mod
        self.info = info
        self.tainted: Set[str] = set(seeds)
        self._body = list(mod.walk_fn_body(info))
        self._fixpoint()

    def expr_taints(self, e: ast.AST) -> bool:
        """Does this expression carry a traced value?"""
        if isinstance(e, ast.Name):
            return e.id in self.tainted
        if isinstance(e, (ast.Tuple, ast.List, ast.Set)):
            return any(self.expr_taints(x) for x in e.elts)
        if isinstance(e, ast.Dict):
            return any(v is not None and self.expr_taints(v)
                       for v in e.values)
        if isinstance(e, ast.Starred):
            return self.expr_taints(e.value)
        if isinstance(e, ast.Subscript):
            return self.expr_taints(e.value)
        if isinstance(e, ast.Attribute):
            if e.attr in config.STATIC_ATTRS:
                return False
            return self.expr_taints(e.value)
        if isinstance(e, ast.BinOp):
            return self.expr_taints(e.left) or self.expr_taints(e.right)
        if isinstance(e, ast.UnaryOp):
            return self.expr_taints(e.operand)
        if isinstance(e, ast.IfExp):
            return self.expr_taints(e.body) or self.expr_taints(e.orelse)
        if isinstance(e, ast.NamedExpr):
            return self.expr_taints(e.value)
        if isinstance(e, ast.Compare):
            # `x is None` / `x is not None` is trace-time-static structure,
            # and so is `"bias" in params`: pytree/dict key membership is
            # python-level structure, fixed at trace time
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in e.ops):
                return False
            if (all(isinstance(op, (ast.In, ast.NotIn)) for op in e.ops)
                    and isinstance(e.left, ast.Constant)
                    and isinstance(e.left.value, str)):
                return False
            return (self.expr_taints(e.left)
                    or any(self.expr_taints(c) for c in e.comparators))
        if isinstance(e, ast.BoolOp):
            return any(self.expr_taints(v) for v in e.values)
        if isinstance(e, ast.Call):
            # jnp/lax/jax results stay traced; python calls (len, range,
            # int(...)) launder the taint for *control flow* purposes —
            # the coercion rule catches the coercions themselves
            f = e.func
            root = f
            while isinstance(root, ast.Attribute):
                root = root.value
            if isinstance(root, ast.Name) and root.id in (
                    self.mod.jnp_aliases | self.mod.lax_aliases
                    | self.mod.jax_aliases | self.mod.random_aliases):
                return any(self.expr_taints(x) for x in e.args) or any(
                    self.expr_taints(k.value) for k in e.keywords)
            return False
        return False

    def _assign_targets(self, t: ast.AST, taint: bool):
        if isinstance(t, ast.Name):
            (self.tainted.add if taint else self.tainted.discard)(t.id)
        elif isinstance(t, (ast.Tuple, ast.List)):
            for x in t.elts:
                self._assign_targets(x, taint)
        elif isinstance(t, ast.Starred):
            self._assign_targets(t.value, taint)

    def _fixpoint(self):
        for _ in range(10):
            before = len(self.tainted)
            for n in self._body:
                if isinstance(n, ast.Assign):
                    if self.expr_taints(n.value):
                        for t in n.targets:
                            self._assign_targets(t, True)
                elif isinstance(n, ast.AugAssign):
                    if self.expr_taints(n.value) or self.expr_taints(n.target):
                        self._assign_targets(n.target, True)
                elif isinstance(n, ast.AnnAssign) and n.value is not None:
                    if self.expr_taints(n.value):
                        self._assign_targets(n.target, True)
                elif isinstance(n, ast.NamedExpr):
                    if self.expr_taints(n.value):
                        self._assign_targets(n.target, True)
                elif isinstance(n, (ast.For, ast.AsyncFor)):
                    if self.expr_taints(n.iter):
                        self._assign_targets(n.target, True)
            if len(self.tainted) == before:
                break


class Model:
    """Derived jit-boundary model over a :class:`Project`."""

    def __init__(self, project: Project, *,
                 jitted_modules: Optional[Set[str]] = None,
                 traced_seeds: Optional[Dict[str, Set[str]]] = None,
                 host_side: Optional[Dict[str, Dict[str, str]]] = None,
                 wire_modules: Optional[Set[str]] = None):
        self.project = project
        self.jitted_modules = (config.JITTED_MODULES if jitted_modules is None
                               else jitted_modules)
        self.traced_seeds = (config.TRACED_SEEDS if traced_seeds is None
                             else traced_seeds)
        self.host_side = (config.HOST_SIDE_FUNCS if host_side is None
                          else host_side)
        self.wire_modules = (config.WIRE_MODULES if wire_modules is None
                             else wire_modules)
        # per (module, pattern): number of functions the seed matched
        self.seed_matches: Dict[tuple, int] = {}
        # modules containing at least one *derived* tracing site
        self.entry_modules: Set[str] = set()
        self._build()

    # -- construction -----------------------------------------------------
    def _build(self):
        for mod in self.project.modules.values():
            if mod.tree is not None:
                self._scan_entries(mod)
                self._scan_cache_stores(mod)
        # derivation first, seed tables second: a table entry never masks a
        # derived chain (--explain shows real evidence when it exists, and
        # check() can tell "confirmed by derivation" from "asserted only")
        self._propagate_traced()
        self.derived_traced = {(i.module, i.qualname)
                               for i in self.project.iter_funcs()
                               if i.traced}
        seeded = self._apply_seed_tables()
        self._propagate_traced(roots=seeded)
        self._propagate_param_taint()
        self._wire_reachability()

    def _mark_entry(self, targets: List[FuncInfo], entry: str,
                    mod: ModuleInfo, line: int, *, scan_body: bool,
                    tainted: Optional[List[Optional[Set[str]]]] = None,
                    kind: str = "entry"):
        self.entry_modules.add(mod.name)
        for i, info in enumerate(targets):
            info.traced = True
            info.scan_body = info.scan_body or scan_body
            info.add_reason(TraceReason(
                kind=kind, detail=f"passed to {entry}",
                site_module=mod.name, line=line))
            if tainted is not None and tainted[i] is not None:
                info.tainted_params |= tainted[i]

    @staticmethod
    def _static_params(info: FuncInfo, call: Optional[ast.Call]) -> Set[str]:
        """Params excluded from jit taint via literal static_argnums /
        static_argnames."""
        out: Set[str] = set()
        params = [p for p in info.params if p not in ("self", "cls")]
        if call is None:
            return out

        def ints(node):
            if isinstance(node, ast.Constant) and isinstance(node.value, int):
                return [node.value]
            if isinstance(node, (ast.Tuple, ast.List)):
                return [v for e in node.elts for v in ints(e)]
            return []

        def strs(node):
            if isinstance(node, ast.Constant) and isinstance(node.value, str):
                return [node.value]
            if isinstance(node, (ast.Tuple, ast.List)):
                return [v for e in node.elts for v in strs(e)]
            return []

        for kw in call.keywords:
            if kw.arg == "static_argnums":
                for i in ints(kw.value):
                    if 0 <= i < len(params):
                        out.add(params[i])
            elif kw.arg == "static_argnames":
                out.update(strs(kw.value))
        return out

    def _entry_taint(self, entry: str, info: FuncInfo,
                     call: Optional[ast.Call]) -> Optional[Set[str]]:
        nonself = {p for p in info.params if p not in ("self", "cls")}
        if entry in config.SCAN_BODY_FUNCS:
            return nonself
        if entry in config.JIT_PARAM_FUNCS or entry == "map":
            return nonself - self._static_params(info, call)
        return None

    def _scan_entries(self, mod: ModuleInfo):
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                entry = mod.tracing_entry(node.func)
                if not entry:
                    continue
                scope = mod.enclosing(node)
                scan_body = entry in config.SCAN_BODY_FUNCS
                for arg in node.args:
                    targets = self.project.resolve_funcref(mod, scope, arg)
                    self._mark_entry(
                        targets, entry, mod, node.lineno,
                        scan_body=scan_body,
                        tainted=[self._entry_taint(entry, t, node)
                                 for t in targets])
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    # @jax.jit / @jit(...) / @partial(jax.jit, ...)
                    target = dec.func if isinstance(dec, ast.Call) else dec
                    entry = mod.tracing_entry(target)
                    call = dec if isinstance(dec, ast.Call) else None
                    if (entry is None and isinstance(dec, ast.Call)
                            and dec.args
                            and isinstance(target, (ast.Name, ast.Attribute))
                            and (getattr(target, "id", None) == "partial"
                                 or getattr(target, "attr", None)
                                 == "partial")):
                        entry = mod.tracing_entry(dec.args[0])
                    if entry is None:
                        continue
                    scope = mod.enclosing(node)
                    # the decorated function itself
                    targets = [i for i in mod.funcs.values()
                               if i.node is node]
                    self._mark_entry(
                        targets, f"@{entry}", mod, node.lineno,
                        scan_body=entry in config.SCAN_BODY_FUNCS,
                        tainted=[self._entry_taint(entry, t, call)
                                 for t in targets],
                        kind="decorator")

    def _scan_cache_stores(self, mod: ModuleInfo):
        def is_cache(base: ast.AST) -> bool:
            return ((isinstance(base, ast.Name)
                     and base.id in config.SCAN_CACHE_NAMES)
                    or (isinstance(base, ast.Attribute)
                        and base.attr in config.SCAN_CACHE_NAMES))

        for node in ast.walk(mod.tree):
            value = None
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Subscript) and is_cache(t.value):
                        value = node.value
            elif (isinstance(node, ast.Call)
                  and isinstance(node.func, ast.Attribute)
                  and node.func.attr == "setdefault"
                  and is_cache(node.func.value) and len(node.args) >= 2):
                value = node.args[1]
            if value is None:
                continue
            scope = mod.enclosing(node)
            for info in self.project.resolve_funcref(mod, scope, value):
                if info.cache_fed is None:
                    info.cache_fed = f"{mod.path}:{node.lineno}"

    def _apply_seed_tables(self) -> List[FuncInfo]:
        newly: List[FuncInfo] = []
        for modname, patterns in self.traced_seeds.items():
            mod = self.project.modules.get(modname)
            for pattern in sorted(patterns):
                count = 0
                if mod is not None:
                    for qual, info in mod.funcs.items():
                        if fnmatch.fnmatch(qual, pattern):
                            count += 1
                            if not info.traced:
                                info.traced = True
                                info.add_reason(TraceReason(
                                    kind="seed-table",
                                    detail=f"TRACED_SEEDS[{modname!r}] "
                                           f"pattern {pattern!r}",
                                    site_module=modname,
                                    line=info.node.lineno))
                                newly.append(info)
                self.seed_matches[(modname, pattern)] = count
        return newly

    def _propagate_traced(self, roots: Optional[List[FuncInfo]] = None):
        """Fixpoint: lexical nesting + resolvable (cross-module) call edges
        spread `traced`, each hop recorded for --explain. `scan_body` does
        NOT propagate: only a function handed straight to scan/while/cond
        has all-traced parameters."""
        work = (list(roots) if roots is not None
                else [i for i in self.project.iter_funcs() if i.traced])
        children: Dict[tuple, List[FuncInfo]] = {}
        for i in self.project.iter_funcs():
            if i.parent:
                children.setdefault((i.module, i.parent), []).append(i)
        while work:
            src = work.pop()
            mod = self.project.mod_of(src)
            for child in children.get((src.module, src.qualname), ()):
                if not child.traced:
                    child.traced = True
                    child.add_reason(TraceReason(
                        kind="nesting",
                        detail=f"nested in {src.qualname}",
                        site_module=src.module,
                        line=child.node.lineno, via=src))
                    work.append(child)
            for site in src.calls:
                if site.is_with or site.is_entry:
                    continue
                for target in self.project.resolve_call(mod, src, site.call):
                    if not target.traced:
                        target.traced = True
                        target.add_reason(TraceReason(
                            kind="call",
                            detail=f"called from {src.module}."
                                   f"{src.qualname}",
                            site_module=src.module,
                            line=site.call.lineno, via=src))
                        work.append(target)

    def _propagate_param_taint(self):
        """Worklist: run the intra-function taint fixpoint, push taint
        argument-by-argument through resolvable call sites (foreign when
        the edge crosses a module boundary) and into nested closures."""
        work = [i for i in self.project.iter_funcs()
                if i.tainted_params or i.scan_body]
        for info in self.project.iter_funcs():
            if info.scan_body:
                info.tainted_params |= {p for p in info.params
                                        if p not in ("self", "cls")}
        seen_state: Dict[int, tuple] = {}
        guard = 0
        while work and guard < 10000:
            guard += 1
            info = work.pop()
            state = (frozenset(info.tainted_params),
                     frozenset(info.closure_taint))
            if seen_state.get(id(info)) == state:
                continue
            seen_state[id(info)] = state
            mod = self.project.mod_of(info)
            info.taint = TaintInfo(mod, info,
                                   info.tainted_params | info.closure_taint)
            ta = info.taint
            # closures: nested functions inherit tainted free names
            for child in (i for i in mod.funcs.values()
                          if i.parent == info.qualname):
                free = {n.id for n in ast.walk(child.node)
                        if isinstance(n, ast.Name)
                        and isinstance(n.ctx, ast.Load)}
                inherited = (free & ta.tainted) - set(child.params)
                if not inherited <= child.closure_taint:
                    child.closure_taint |= inherited
                    work.append(child)
            # call sites: map tainted arguments onto callee params
            for site in info.calls:
                if site.is_entry:
                    continue
                call = site.call
                # explicit unbound `ClassName.method(obj, ...)` passes self
                # positionally; every other route to a method (self.m(...),
                # vmap(self.m, ...)(...)) binds it
                f = call.func
                unbound_cls = (
                    isinstance(f, ast.Attribute)
                    and isinstance(f.value, ast.Name)
                    and (f.value.id in mod.classes
                         or (f.value.id in mod.sym_imports
                             and self._is_class_import(mod, f.value.id))))
                for target in self.project.resolve_call(mod, info, call):
                    params = list(target.params)
                    offset = 1 if (params and params[0] in ("self", "cls")
                                   and not unbound_cls) else 0
                    newly: Set[str] = set()
                    for i, arg in enumerate(call.args):
                        if isinstance(arg, ast.Starred):
                            continue
                        pi = i + offset
                        if pi < len(params) and ta.expr_taints(arg):
                            newly.add(params[pi])
                    for kw in call.keywords:
                        if kw.arg and kw.arg in params and \
                                ta.expr_taints(kw.value):
                            newly.add(kw.arg)
                    newly -= target.tainted_params
                    if not newly:
                        continue
                    target.tainted_params |= newly
                    if target.module != info.module or info.foreign_taint:
                        origin = (f"{info.module}.{info.qualname}:"
                                  f"{call.lineno}")
                        for p in newly:
                            target.foreign_taint.setdefault(p, origin)
                    work.append(target)
        # make sure every func with taint has its TaintInfo computed
        for info in self.project.iter_funcs():
            if (info.tainted_params or info.closure_taint) and \
                    info.taint is None:
                info.taint = TaintInfo(self.project.mod_of(info), info,
                                       info.tainted_params
                                       | info.closure_taint)

    def _is_class_import(self, mod: ModuleInfo, name: str) -> bool:
        base, sym = mod.sym_imports[name]
        src = self.project.modules.get(base)
        return src is not None and sym in src.classes

    def _wire_reachability(self):
        """Reverse reachability: F.wire_path iff F's resolvable call graph
        reaches a WIRE_MODULES module."""
        for info in self.project.iter_funcs():
            if info.module in self.wire_modules:
                info.wire_path = True
        changed = True
        while changed:
            changed = False
            for info in self.project.iter_funcs():
                if info.wire_path:
                    continue
                mod = self.project.mod_of(info)
                for site in info.calls:
                    if site.is_with:
                        continue
                    hit = any(
                        t.wire_path or t.module in self.wire_modules
                        for t in self.project.resolve_call(mod, info,
                                                           site.call))
                    if hit:
                        info.wire_path = True
                        changed = True
                        break

    # -- host allowlist ----------------------------------------------------
    def host_entry(self, mod: ModuleInfo, info: FuncInfo) -> Optional[str]:
        table = self.host_side.get(mod.name, {})
        cur: Optional[FuncInfo] = info
        while cur is not None:
            if cur.qualname in table:
                return cur.qualname
            cur = mod.funcs.get(cur.parent) if cur.parent else None
        return None

    # -- explain ------------------------------------------------------------
    def explain(self, query: str) -> List[str]:
        """Human-readable derived-traced-context chains for a function."""
        lines: List[str] = []
        matches = self.project.find_funcs(query)
        if not matches:
            return [f"jaxlint,explain,NO-MATCH,{query}"]
        for info in matches:
            head = f"{info.module}.{info.qualname}"
            if not info.traced:
                lines.append(f"{head}: not traced")
            else:
                lines.append(f"{head}: TRACED"
                             + (" (scan body: every param is a tracer)"
                                if info.scan_body else ""))
                chain, cur, depth = [], info, 0
                while cur is not None and depth < 20:
                    r = cur.reasons[0] if cur.reasons else None
                    if r is None:
                        break
                    chain.append(f"  {'  ' * depth}<- {r.kind}: {r.detail} "
                                 f"[{r.site_module}:{r.line}]")
                    cur = r.via
                    depth += 1
                lines.extend(chain)
            if info.tainted_params:
                pts = ", ".join(sorted(info.tainted_params))
                lines.append(f"  tainted params: {pts}")
                for p, origin in sorted(info.foreign_taint.items()):
                    lines.append(f"    {p}: foreign taint via {origin}")
            if info.wire_path and info.module not in self.wire_modules:
                lines.append("  on a call path into WIRE_MODULES "
                             "(fp16-wire applies)")
            if info.cache_fed:
                lines.append(f"  feeds a scan cache (stored at "
                             f"{info.cache_fed})")
        return lines

    # -- table consistency --------------------------------------------------
    def check(self) -> List[str]:
        """Disagreements between the checked-in tables and the derived
        model. Empty list == consistent."""
        problems: List[str] = []
        mods = self.project.modules

        def derived_root(info: FuncInfo) -> Optional[TraceReason]:
            cur, depth = info, 0
            while cur is not None and depth < 50:
                r = cur.reasons[0] if cur.reasons else None
                if r is None:
                    return None
                if r.via is None:
                    return r
                cur = r.via
                depth += 1
            return None

        asserted = config.ASSERTED_JITTED
        for m in sorted(asserted):
            if m not in self.jitted_modules:
                problems.append(
                    f"ASSERTED_JITTED entry {m!r} is not in JITTED_MODULES "
                    "(assertions annotate the operative table, they do not "
                    "extend it)")
        for m in sorted(self.jitted_modules):
            if m not in mods:
                problems.append(f"JITTED_MODULES entry {m!r} does not exist")
                continue
            confirmed = m in self.entry_modules or any(
                (i.module, i.qualname) in self.derived_traced
                for i in mods[m].funcs.values())
            if not confirmed and m not in asserted:
                problems.append(
                    f"JITTED_MODULES entry {m!r} is stale: no tracing "
                    "entry in the module, no derived traced chain reaches "
                    "it, and no ASSERTED_JITTED rationale covers it")
            elif confirmed and m in asserted:
                problems.append(
                    f"ASSERTED_JITTED entry {m!r} is now confirmed by the "
                    "derived model — drop the assertion (rationale was: "
                    f"{asserted[m]})")
        for (modname, pattern), count in sorted(self.seed_matches.items()):
            if modname not in mods:
                problems.append(
                    f"TRACED_SEEDS module {modname!r} does not exist")
            elif count == 0:
                problems.append(
                    f"TRACED_SEEDS[{modname!r}] pattern {pattern!r} "
                    "matches no function")
        for modname, table in sorted(self.host_side.items()):
            if modname not in mods:
                problems.append(
                    f"HOST_SIDE_FUNCS module {modname!r} does not exist")
                continue
            for qual in sorted(table):
                if qual not in mods[modname].funcs:
                    problems.append(
                        f"HOST_SIDE_FUNCS entry {modname}:{qual} does "
                        "not exist")
        for m in sorted(self.wire_modules):
            if m not in mods:
                problems.append(f"WIRE_MODULES entry {m!r} does not exist")
        # closure: a traced chain rooted in a jitted module must not escape
        # into an unlisted src module (benchmarks/tools callers are fine —
        # the jitted-module blanket rules do not apply there)
        for info in self.project.iter_funcs():
            if not info.traced or info.module in self.jitted_modules:
                continue
            mod = self.project.mod_of(info)
            if mod.tree_kind != "src":
                continue
            root = derived_root(info)
            if root is not None and root.site_module in \
                    self.jitted_modules and root.site_module != info.module:
                problems.append(
                    f"traced chain rooted in jitted module "
                    f"{root.site_module} reaches {info.module}."
                    f"{info.qualname}, but {info.module!r} is not in "
                    "JITTED_MODULES")
        return problems
