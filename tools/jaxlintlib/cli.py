"""Command-line entry point for jaxlint (invoked via tools/jaxlint.py)."""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from jaxlintlib.engine import lint_project
from jaxlintlib.model import Model
from jaxlintlib.project import REPO, Project

DESCRIPTION = ("jaxlint — repo-wide trace-hygiene linter "
               "(pure AST, no jax import)")

# the full analysis surface for --explain / --check-model when no paths
# are given: the derived model is only meaningful over every tree that
# can hold a tracing site or a cross-module call edge
DEFAULT_MODEL_PATHS = ("src", "benchmarks", "tools")


def _build_project(paths: Optional[List[str]]) -> Project:
    paths = paths or [os.path.join(REPO, p) for p in DEFAULT_MODEL_PATHS]
    return Project.from_paths([os.path.abspath(p) for p in paths], REPO)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=DESCRIPTION)
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/directories to lint (default: src)")
    ap.add_argument("--json", metavar="OUT", default=None,
                    help="also write findings as JSON (- for stdout)")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="list suppressed findings too")
    ap.add_argument("--self-test", action="store_true",
                    help="run every rule against its embedded fixtures")
    ap.add_argument("--explain", metavar="FUNC", default=None,
                    help="print the derived traced-context chain for a "
                         "function (name, Class.method, or module.qualname);"
                         " analyzes src benchmarks tools unless paths given")
    ap.add_argument("--check-model", action="store_true",
                    help="verify the checked-in override tables "
                         "(JITTED_MODULES/TRACED_SEEDS/HOST_SIDE_FUNCS/"
                         "WIRE_MODULES) agree with the derived jit-boundary "
                         "model; exit 1 on any disagreement")
    args = ap.parse_args(argv)

    if args.self_test:
        from jaxlintlib.fixtures import self_test
        return self_test()

    if args.explain is not None:
        project = _build_project(args.paths)
        model = Model(project)
        for line in model.explain(args.explain):
            print(line)
        return 0 if project.find_funcs(args.explain) else 1

    if args.check_model:
        project = _build_project(args.paths)
        model = Model(project)
        problems = model.check()
        for p in problems:
            print(f"jaxlint,MODEL-MISMATCH,{p}")
        print(f"jaxlint,check-model,{'FAIL' if problems else 'OK'},"
              f"problems={len(problems)},modules={len(project.modules)}")
        return 1 if problems else 0

    paths = args.paths or [os.path.join(REPO, "src")]
    project = Project.from_paths([os.path.abspath(p) for p in paths], REPO)
    findings = lint_project(project)
    active = [f for f in findings if not f.suppressed]
    suppressed = [f for f in findings if f.suppressed]

    for f in active:
        print(f"jaxlint,FAIL,{f.rule},{f.path}:{f.line}:{f.col},{f.message}")
    if args.show_suppressed:
        for f in suppressed:
            print(f"jaxlint,suppressed,{f.rule},{f.path}:{f.line}")

    if args.json:
        payload = json.dumps([f.as_dict() for f in findings], indent=2)
        if args.json == "-":
            print(payload)
        else:
            with open(args.json, "w", encoding="utf-8") as fh:
                fh.write(payload + "\n")

    print(f"jaxlint,summary,findings={len(active)},"
          f"suppressed={len(suppressed)}")
    return 1 if active else 0


if __name__ == "__main__":
    sys.exit(main())
