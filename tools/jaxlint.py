#!/usr/bin/env python3
"""jaxlint — repo-specific trace-hygiene linter (pure AST, no jax import).

The repo's correctness rests on contracts no general-purpose linter checks:
everything inside the ``lax.scan`` tick loop must stay jit-traceable, PRNG
keys must flow from the shared ``fold_in(tick)`` stream that keeps heap↔lax
parity bitwise, result shapes must be pinned by static python-int budgets
(``jnp.nonzero(size=...)``), and every wire payload must honor
``core/compression.py``'s bf16-scale contract. This tool makes those
contracts machine-checked: it walks the source tree with ``ast`` only
(same dependency discipline as ``tools/docs_check.py`` — runs on a bare
python, no jax, no PYTHONPATH) and reports findings per rule.

Usage:
    python tools/jaxlint.py [paths...]      # default: src
    python tools/jaxlint.py --json out.json src
    python tools/jaxlint.py --self-test     # every rule vs embedded fixtures

Suppression: append ``# jaxlint: ignore[rule-id]`` (comma-separate several
ids, or ``ignore[*]``) on the offending line. Suppressions are deliberate,
reviewed escapes — each should carry a rationale comment.

Rules (documented in docs/STATIC_ANALYSIS.md):
    nonzero-size         jnp.nonzero/flatnonzero/argwhere/where(1-arg)
                         without size= in traced code of jitted modules
    host-coercion        float()/int()/bool()/.item()/.tolist() in traced code
    np-in-traced         numpy calls reachable from jitted code paths
                         (host-side setup allowlisted per function below)
    traced-control-flow  python if/while/for over scan-carried values
    prngkey-in-scan      jax.random.PRNGKey built inside a scan body
                         (keys must flow from attacks.attack_fold streams)
    fp16-wire            float16 dtype literals in wire modules (the scale
                         contract is bf16: fp16 subnormals zero tiny leaves)

Exit status: 0 iff zero unsuppressed findings (and fixtures pass under
--self-test).
"""
from __future__ import annotations

import argparse
import ast
import fnmatch
import io
import json
import os
import sys
import tokenize
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# --------------------------------------------------------------------------
# repo contract configuration
# --------------------------------------------------------------------------

# Modules whose bodies are (transitively) jitted: the tick-loop fabric, the
# gossip round, and the kernels it lowers to. Trace-hygiene rules treat any
# traced context in these modules as load-bearing.
JITTED_MODULES = {
    "repro.chain.simlax",
    "repro.chain.attacks",
    "repro.core.gossip",
    "repro.core.fedavg",
    "repro.core.compression",
    "repro.core.reputation",
    "repro.core.dfl",
    "repro.kernels.quantize.ref",
    "repro.kernels.quantize.ops",
    "repro.kernels.quantize.quantize",
    "repro.kernels.wfedavg.ref",
    "repro.kernels.wfedavg.ops",
    "repro.kernels.wfedavg.wfedavg",
}

# Functions in jitted modules that are host-side BY DESIGN (static build /
# result unpacking). numpy is legal here; the rationale records why. A
# function both allowlisted and *detected* as traced is still flagged —
# the allowlist cannot mask a real leak into the scan.
HOST_SIDE_FUNCS = {
    "repro.chain.simlax": {
        "LaxSimulator.__init__":
            "static-build phase: schedules, budgets, slot tables are "
            "computed once on host and baked as consts",
        "LaxSimulator.run":
            "entry point: seeds PRNG, dispatches the jitted scan, "
            "post-checks overflow on materialized numpy outputs",
        "LaxSimulator._package":
            "unpacks device outputs to numpy history records",
        "LaxSimulator.lower_scan":
            "audit surface: lowers (never executes) the cached scan",
        "SimLaxResult.mean_reputation":
            "result accessor over materialized numpy history",
    },
    "repro.chain.attacks": {
        "FederationSpec.build":
            "host-side role-sheet expansion (static per federation)",
        "FederationSpec.attack_groups":
            "host-side group extraction from the static role sheet",
        "FederationSpec.attack_union":
            "host-side registry lookup over the static role sheet",
        "FederationSpec.attack_key_fns":
            "host-side construction of the per-group fold_in streams",
        "BatchedFederationSpec.build":
            "host-side stacking of member role sheets",
        "BatchedFederationSpec.attack_union":
            "host-side union over member role sheets",
        "BatchedFederationSpec.attack_masks":
            "host-side (B, G, N) mask table from static role sheets",
    },
}

# Extra traced seeds the detector cannot see statically (methods handed to
# jit/vmap via instance attributes, or called from the other engine).
TRACED_SEEDS = {
    "repro.chain.simlax": {"LaxSimulator._scan"},
    "repro.chain.attacks": {"*.apply"},       # every Attack.apply runs in-scan
    "repro.core.compression": {"*"},          # fully traced wire codec
    "repro.core.fedavg": {"*"},               # fully traced aggregation
    "repro.core.reputation": {"ReputationImpl.*"},
}

# Modules that put bytes on the wire: float16 literals here bypass the bf16
# scale contract (PR 7: fp16 subnormal scales silently zeroed tiny leaves).
WIRE_MODULES = {
    "repro.core.compression",
    "repro.core.gossip",
    "repro.chain.simlax",
    "repro.kernels.quantize.ref",
    "repro.kernels.quantize.ops",
    "repro.kernels.quantize.quantize",
}

# Call-sites that hand a function to the tracer. Name-style entries apply to
# bare names (``from jax import vmap``); attr-style to ``<root>.<attr>``.
TRACING_NAME_FUNCS = {"jit", "vmap", "pmap", "shard_map", "pallas_call",
                      "scan", "cond", "while_loop", "fori_loop", "switch",
                      "grad", "value_and_grad", "checkpoint", "remat"}
TRACING_ATTR_FUNCS = TRACING_NAME_FUNCS | {"custom_vjp", "custom_jvp"}
# tracing entries whose callee's parameters are ALL traced by construction
# (scan carry/xs, while/fori carry, cond/switch operands) — the only scope
# where "python control flow over a parameter-derived name" is a sound rule
SCAN_BODY_FUNCS = {"scan", "while_loop", "fori_loop", "cond", "switch"}

COERCION_BUILTINS = {"float", "int", "bool"}
COERCION_METHODS = {"item", "tolist"}
SIZE_WANTING = {"nonzero", "flatnonzero", "argwhere"}
# attributes of a traced value that are static python objects (no taint)
STATIC_ATTRS = {"shape", "dtype", "ndim", "size", "sharding"}


@dataclass
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str
    suppressed: bool = False

    def as_dict(self):
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message,
                "suppressed": self.suppressed}


@dataclass
class FuncInfo:
    node: ast.AST                      # FunctionDef / AsyncFunctionDef / Lambda
    qualname: str
    parent: Optional[str]              # lexically enclosing function qualname
    cls: Optional[str]                 # enclosing class name, if a method
    traced: bool = False
    scan_body: bool = False        # passed DIRECTLY to scan/while/cond/...
    calls: Set[str] = field(default_factory=set)   # resolvable callee names


def _module_name(path: str) -> str:
    """Dotted module name for a repo file (src-rooted for src/)."""
    rel = os.path.relpath(os.path.abspath(path), REPO)
    parts = rel.replace(os.sep, "/").split("/")
    if parts and parts[0] == "src":
        parts = parts[1:]
    if parts and parts[-1].endswith(".py"):
        parts[-1] = parts[-1][:-3]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _suppressions(source: str) -> Dict[int, Set[str]]:
    """line -> set of suppressed rule ids (or {'*'}) from jaxlint comments."""
    out: Dict[int, Set[str]] = {}
    try:
        toks = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in toks:
            if tok.type != tokenize.COMMENT:
                continue
            text = tok.string
            marker = "jaxlint:"
            if marker not in text:
                continue
            rest = text.split(marker, 1)[1].strip()
            if not rest.startswith("ignore[") or "]" not in rest:
                continue
            rules = rest[len("ignore["):rest.index("]")]
            ids = {r.strip() for r in rules.split(",") if r.strip()}
            if ids:
                out.setdefault(tok.start[0], set()).update(ids)
    except tokenize.TokenError:
        pass
    return out


class Analyzer:
    """Single-file analysis: alias tables, function table, traced-context
    propagation, then the rule passes."""

    def __init__(self, source: str, path: str, module: str):
        self.source = source
        self.path = path
        self.module = module
        self.tree = ast.parse(source)
        self.findings: List[Finding] = []
        self.np_aliases: Set[str] = set()
        self.jnp_aliases: Set[str] = set()
        self.lax_aliases: Set[str] = set()
        self.jax_aliases: Set[str] = set()
        self.funcs: Dict[str, FuncInfo] = {}
        self._collect_aliases()
        self._collect_funcs()
        self._seed_traced()
        self._propagate()

    # -- setup ------------------------------------------------------------
    def _collect_aliases(self):
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    name = a.asname or a.name
                    if a.name == "numpy":
                        self.np_aliases.add(name)
                    elif a.name in ("jax.numpy",):
                        self.jnp_aliases.add(name)
                    elif a.name == "jax":
                        self.jax_aliases.add(name)
            elif isinstance(node, ast.ImportFrom):
                if node.module == "jax":
                    for a in node.names:
                        name = a.asname or a.name
                        if a.name == "numpy":
                            self.jnp_aliases.add(name)
                        elif a.name == "lax":
                            self.lax_aliases.add(name)
                elif node.module == "numpy":
                    # `from numpy import ...` — treat the imported names as
                    # numpy calls when they collide with rule targets; rare
                    # in this repo, so only record the module-as-a-whole case
                    pass

    def _collect_funcs(self):
        analyzer = self

        class V(ast.NodeVisitor):
            def __init__(self):
                self.stack: List[str] = []     # qualname parts
                self.fn_stack: List[str] = []  # enclosing function qualnames
                self.cls_stack: List[str] = []

            def _add(self, node, name):
                qual = ".".join(self.stack + [name])
                analyzer.funcs[qual] = FuncInfo(
                    node=node, qualname=qual,
                    parent=self.fn_stack[-1] if self.fn_stack else None,
                    cls=self.cls_stack[-1] if self.cls_stack else None)
                return qual

            def visit_ClassDef(self, node):
                self.stack.append(node.name)
                self.cls_stack.append(node.name)
                self.generic_visit(node)
                self.cls_stack.pop()
                self.stack.pop()

            def _visit_fn(self, node, name):
                qual = self._add(node, name)
                self.stack.append(name)
                self.fn_stack.append(qual)
                self.generic_visit(node)
                self.fn_stack.pop()
                self.stack.pop()

            def visit_FunctionDef(self, node):
                self._visit_fn(node, node.name)

            def visit_AsyncFunctionDef(self, node):
                self._visit_fn(node, node.name)

            def visit_Lambda(self, node):
                self._visit_fn(node, f"<lambda@{node.lineno}>")

        V().visit(self.tree)
        # call edges: resolvable module-local calls per function
        for info in self.funcs.values():
            body = (info.node.body if isinstance(info.node.body, list)
                    else [info.node.body])
            for stmt in body:
                for sub in ast.walk(stmt if isinstance(stmt, ast.AST) else stmt):
                    if not isinstance(sub, ast.Call):
                        continue
                    f = sub.func
                    if isinstance(f, ast.Name):
                        info.calls.add(f.id)
                    elif (isinstance(f, ast.Attribute)
                          and isinstance(f.value, ast.Name)
                          and f.value.id in ("self", "cls")):
                        info.calls.add(f.attr)

    def _is_tracing_entry(self, func: ast.AST) -> Optional[str]:
        """If `func` is jit/vmap/scan/... return its short name, else None."""
        if isinstance(func, ast.Name) and func.id in TRACING_NAME_FUNCS:
            return func.id
        if isinstance(func, ast.Attribute):
            attr = func.attr
            if attr == "map":
                # only lax.map / jax.lax.map (python's map is not a tracer)
                v = func.value
                if isinstance(v, ast.Name) and v.id in self.lax_aliases:
                    return attr
                if (isinstance(v, ast.Attribute) and v.attr == "lax"):
                    return attr
                return None
            if attr in TRACING_ATTR_FUNCS:
                return attr
        return None

    def _callee_names(self, arg: ast.AST) -> List[str]:
        """Module-local function names a call argument might refer to."""
        if isinstance(arg, ast.Name):
            return [arg.id]
        if (isinstance(arg, ast.Attribute) and isinstance(arg.value, ast.Name)
                and arg.value.id in ("self", "cls")):
            return [arg.attr]
        if isinstance(arg, ast.Lambda):
            return [f"<lambda@{arg.lineno}>"]
        if isinstance(arg, ast.Call):
            f = arg.func
            is_partial = ((isinstance(f, ast.Name) and f.id == "partial") or
                          (isinstance(f, ast.Attribute) and f.attr == "partial"))
            if is_partial and arg.args:
                return self._callee_names(arg.args[0])
        return []

    def _mark_by_short_name(self, short: str, scan_body: bool):
        for qual, info in self.funcs.items():
            last = qual.rsplit(".", 1)[-1]
            if last == short:
                info.traced = True
                info.scan_body = info.scan_body or scan_body

    def _seed_traced(self):
        # (a) config seeds
        for pattern in TRACED_SEEDS.get(self.module, ()):  # patterns
            for qual, info in self.funcs.items():
                if fnmatch.fnmatch(qual, pattern):
                    info.traced = True
        # (b) detected: args of tracing calls + jit-ish decorators
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call):
                entry = self._is_tracing_entry(node.func)
                if not entry:
                    continue
                scan_body = entry in SCAN_BODY_FUNCS
                for arg in node.args:
                    for short in self._callee_names(arg):
                        self._mark_by_short_name(short, scan_body)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    # @jax.jit / @jit(...) / @partial(jax.jit, ...)
                    target = dec.func if isinstance(dec, ast.Call) else dec
                    hit = self._is_tracing_entry(target) is not None
                    if (not hit and isinstance(dec, ast.Call) and dec.args
                            and isinstance(target, (ast.Name, ast.Attribute))
                            and (getattr(target, "id", None) == "partial"
                                 or getattr(target, "attr", None) == "partial")):
                        hit = self._is_tracing_entry(dec.args[0]) is not None
                    if hit:
                        self._mark_by_short_name(node.name, False)

    def _propagate(self):
        """Fixpoint: lexical nesting + module-local call graph spread the
        `traced` flag. `scan_body` deliberately does NOT propagate: only a
        function handed straight to scan/while/cond has all-traced
        parameters; a helper it calls may take static config args."""
        changed = True
        while changed:
            changed = False
            for info in self.funcs.values():
                if not info.traced and info.parent:
                    p = self.funcs.get(info.parent)
                    if p and p.traced:
                        info.traced = True
                        changed = True
                if info.traced:
                    for callee in info.calls:
                        for q2, i2 in self.funcs.items():
                            if q2.rsplit(".", 1)[-1] != callee:
                                continue
                            if not i2.traced:
                                i2.traced = True
                                changed = True

    # -- helpers ----------------------------------------------------------
    def _enclosing(self, lineno) -> Optional[FuncInfo]:
        """Innermost function containing a line (by node span)."""
        best = None
        best_span = None
        for info in self.funcs.values():
            n = info.node
            end = getattr(n, "end_lineno", n.lineno)
            if n.lineno <= lineno <= end:
                span = end - n.lineno
                if best_span is None or span < best_span:
                    best, best_span = info, span
        return best

    def _in_host_allowlist(self, info: FuncInfo) -> Optional[str]:
        table = HOST_SIDE_FUNCS.get(self.module, {})
        # a nested helper inherits its outermost allowlisted ancestor
        cur: Optional[FuncInfo] = info
        while cur is not None:
            if cur.qualname in table:
                return cur.qualname
            cur = self.funcs.get(cur.parent) if cur.parent else None
        return None

    def _emit(self, rule, node, message):
        self.findings.append(Finding(
            rule=rule, path=self.path, line=node.lineno,
            col=getattr(node, "col_offset", 0), message=message))

    def _walk_fn_body(self, info: FuncInfo):
        """Nodes belonging to this function but not to a nested function."""
        nested = [i.node for i in self.funcs.values() if i.parent == info.qualname]
        body = (info.node.body if isinstance(info.node.body, list)
                else [info.node.body])
        stack = list(body)
        while stack:
            n = stack.pop()
            if not isinstance(n, ast.AST) or n in nested:
                continue
            yield n
            stack.extend(ast.iter_child_nodes(n))

    # -- rules ------------------------------------------------------------
    def run_rules(self):
        jitted = self.module in JITTED_MODULES
        for info in self.funcs.values():
            host_entry = self._in_host_allowlist(info)
            # nonzero-size: traced code in jitted modules must pin shapes
            if jitted and info.traced:
                self._rule_nonzero(info)
            # host-coercion / np-in-traced: scoped to jitted modules (plus
            # direct scan bodies anywhere) — traced helpers elsewhere may
            # legally compute on *static* args at trace time (e.g. models'
            # block-index tables), which pure AST cannot distinguish
            if (jitted and info.traced) or info.scan_body:
                self._rule_coercion(info)
            if ((jitted and (info.traced or host_entry is None)
                 and self.np_aliases) or info.scan_body):
                self._rule_np(info, detected_traced=info.traced)
            if info.traced:
                self._rule_prngkey(info)
            if info.scan_body:
                self._rule_control_flow(info)
        if self.module in WIRE_MODULES:
            self._rule_fp16()

    def _rule_nonzero(self, info: FuncInfo):
        for n in self._walk_fn_body(info):
            if not isinstance(n, ast.Call):
                continue
            f = n.func
            if not (isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name)
                    and f.value.id in self.jnp_aliases):
                continue
            kwnames = {k.arg for k in n.keywords}
            if f.attr in SIZE_WANTING and "size" not in kwnames:
                self._emit("nonzero-size", n,
                           f"jnp.{f.attr} without size= in traced code "
                           f"({info.qualname}): result shape is data-"
                           "dependent and cannot be jitted — pin it with a "
                           "static budget (size=..., fill_value=...)")
            elif (f.attr == "where" and len(n.args) == 1
                  and "size" not in kwnames):
                self._emit("nonzero-size", n,
                           f"single-arg jnp.where without size= in traced "
                           f"code ({info.qualname}): use the 3-arg form or "
                           "jnp.nonzero(size=...)")

    def _rule_coercion(self, info: FuncInfo):
        for n in self._walk_fn_body(info):
            if not isinstance(n, ast.Call):
                continue
            f = n.func
            if (isinstance(f, ast.Name) and f.id in COERCION_BUILTINS
                    and len(n.args) == 1 and not n.keywords
                    and not isinstance(n.args[0], (ast.Constant,))):
                self._emit("host-coercion", n,
                           f"{f.id}() coercion in traced code "
                           f"({info.qualname}): forces a concrete value "
                           "mid-trace (ConcretizationTypeError on a tracer, "
                           "silently baked constant on host data)")
            elif (isinstance(f, ast.Attribute) and f.attr in COERCION_METHODS
                  and not isinstance(f.value, ast.Constant)):
                self._emit("host-coercion", n,
                           f".{f.attr}() in traced code ({info.qualname}): "
                           "pulls the value to host mid-trace")

    def _rule_np(self, info: FuncInfo, detected_traced: bool):
        for n in self._walk_fn_body(info):
            if not isinstance(n, ast.Call):
                continue
            f = n.func
            root = f
            while isinstance(root, ast.Attribute):
                root = root.value
            if not (isinstance(root, ast.Name) and root.id in self.np_aliases):
                continue
            where = ("traced code" if detected_traced
                     else "a jitted module without a host-side allowlist "
                          "entry")
            self._emit("np-in-traced", n,
                       f"numpy call in {where} ({info.qualname}): numpy "
                       "ops bake host constants / break tracing — use jnp, "
                       "or move to the static-build phase and allowlist "
                       "the function in tools/jaxlint.py with a rationale")

    def _rule_prngkey(self, info: FuncInfo):
        for n in self._walk_fn_body(info):
            if not isinstance(n, ast.Call):
                continue
            f = n.func
            if isinstance(f, ast.Attribute) and f.attr in ("PRNGKey", "key"):
                v = f.value
                is_random = ((isinstance(v, ast.Name) and v.id == "random") or
                             (isinstance(v, ast.Attribute)
                              and v.attr == "random"))
                if is_random:
                    self._emit("prngkey-in-scan", n,
                               f"PRNGKey constructed inside a scan body "
                               f"({info.qualname}): keys must flow from the "
                               "fold_in(tick) stream (attacks.attack_fold) "
                               "or heap/lax parity silently diverges")

    def _rule_control_flow(self, info: FuncInfo):
        node = info.node
        params: Set[str] = set()
        a = node.args
        for arg in (list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)
                    + ([a.vararg] if a.vararg else [])
                    + ([a.kwarg] if a.kwarg else [])):
            params.add(arg.arg)
        tainted = set(params)

        def expr_taints(e: ast.AST) -> bool:
            """Does this expression carry a loop-carried (traced) value?"""
            if isinstance(e, ast.Name):
                return e.id in tainted
            if isinstance(e, ast.Tuple) or isinstance(e, ast.List):
                return any(expr_taints(x) for x in e.elts)
            if isinstance(e, ast.Starred):
                return expr_taints(e.value)
            if isinstance(e, ast.Subscript):
                return expr_taints(e.value)
            if isinstance(e, ast.Attribute):
                if e.attr in STATIC_ATTRS:
                    return False
                return expr_taints(e.value)
            if isinstance(e, ast.BinOp):
                return expr_taints(e.left) or expr_taints(e.right)
            if isinstance(e, ast.UnaryOp):
                return expr_taints(e.operand)
            if isinstance(e, ast.Compare):
                return (expr_taints(e.left)
                        or any(expr_taints(c) for c in e.comparators))
            if isinstance(e, ast.BoolOp):
                return any(expr_taints(v) for v in e.values)
            if isinstance(e, ast.Call):
                # only jnp/lax results stay traced; python calls (len, range,
                # jax.tree.leaves -> list) launder the taint for *control
                # flow* purposes (other rules catch the coercions)
                f = e.func
                if (isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name)
                        and f.value.id in (self.jnp_aliases | self.lax_aliases)):
                    return any(expr_taints(x) for x in e.args)
                return False
            return False

        def assign_targets(t: ast.AST, taint: bool):
            if isinstance(t, ast.Name):
                (tainted.add if taint else tainted.discard)(t.id)
            elif isinstance(t, (ast.Tuple, ast.List)):
                for x in t.elts:
                    assign_targets(x, taint)
            elif isinstance(t, ast.Starred):
                assign_targets(t.value, taint)

        # taint fixpoint over straight-line assignments
        body_nodes = list(self._walk_fn_body(info))
        for _ in range(10):
            before = len(tainted)
            for n in body_nodes:
                if isinstance(n, ast.Assign):
                    taint = expr_taints(n.value)
                    if taint:
                        for t in n.targets:
                            assign_targets(t, True)
                elif isinstance(n, ast.AugAssign):
                    if expr_taints(n.value) or expr_taints(n.target):
                        assign_targets(n.target, True)
                elif isinstance(n, ast.AnnAssign) and n.value is not None:
                    if expr_taints(n.value):
                        assign_targets(n.target, True)
            if len(tainted) == before:
                break

        for n in body_nodes:
            if isinstance(n, ast.If) and expr_taints(n.test):
                self._emit("traced-control-flow", n,
                           f"python `if` over a loop-carried value in scan "
                           f"body {info.qualname}: branch on tracers with "
                           "lax.cond/jnp.where, not python control flow")
            elif isinstance(n, ast.While) and expr_taints(n.test):
                self._emit("traced-control-flow", n,
                           f"python `while` over a loop-carried value in "
                           f"scan body {info.qualname}: use lax.while_loop")
            elif isinstance(n, ast.For) and expr_taints(n.iter):
                self._emit("traced-control-flow", n,
                           f"python `for` over a loop-carried value in scan "
                           f"body {info.qualname}: traced arrays cannot "
                           "drive python iteration — use lax.scan/vmap")

    def _rule_fp16(self):
        dtype_roots = self.np_aliases | self.jnp_aliases
        for node in ast.walk(self.tree):
            if (isinstance(node, ast.Attribute) and node.attr == "float16"
                    and isinstance(node.value, ast.Name)
                    and node.value.id in dtype_roots):
                self._emit("fp16-wire", node,
                           "float16 dtype in a wire module: the scale "
                           "contract is bf16 (fp16 subnormal scales zero "
                           "small leaves — see core/compression.py)")
            elif isinstance(node, ast.Call):
                for sub in list(node.args) + [k.value for k in node.keywords]:
                    if (isinstance(sub, ast.Constant)
                            and isinstance(sub.value, str)
                            and sub.value.lower() in ("float16", "f16", "fp16")):
                        self._emit("fp16-wire", sub,
                                   "float16 dtype literal in a wire module: "
                                   "wire scales are bf16 by contract")


def lint_source(source: str, path: str, module: Optional[str] = None,
                ) -> List[Finding]:
    """Analyze one source blob; returns findings with suppressions marked."""
    module = module if module is not None else _module_name(path)
    try:
        an = Analyzer(source, path, module)
    except SyntaxError as e:
        return [Finding("parse-error", path, e.lineno or 0, 0, str(e))]
    an.run_rules()
    sup = _suppressions(source)
    for f in an.findings:
        rules = sup.get(f.line, set())
        if "*" in rules or f.rule in rules:
            f.suppressed = True
    return an.findings


def lint_paths(paths: List[str]) -> List[Finding]:
    findings: List[Finding] = []
    files: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
        else:
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = [d for d in dirnames
                               if d not in ("__pycache__", ".git")]
                files.extend(os.path.join(dirpath, f)
                             for f in sorted(filenames) if f.endswith(".py"))
    for fp in sorted(files):
        with open(fp, "r", encoding="utf-8") as fh:
            src = fh.read()
        findings.extend(lint_source(src, os.path.relpath(fp, REPO)))
    return findings


# --------------------------------------------------------------------------
# self-test fixtures: (rule, module-to-analyze-as, bad source, good source)
# --------------------------------------------------------------------------

FIXTURES: List[Tuple[str, str, str, str]] = [
    ("nonzero-size", "repro.chain.simlax",
     """
import jax
import jax.numpy as jnp

def body(state, t):
    idx = jnp.nonzero(state > 0)
    return state, idx

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
""",
     """
import jax
import jax.numpy as jnp

def body(state, t):
    idx = jnp.nonzero(state > 0, size=8, fill_value=0)
    return state, idx

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
"""),
    ("nonzero-size", "repro.chain.simlax",
     """
import jax
import jax.numpy as jnp

def picker(mask):
    return jnp.where(mask)

def go(mask):
    return jax.jit(picker)(mask)
""",
     """
import jax
import jax.numpy as jnp

def picker(mask):
    return jnp.where(mask, 1.0, 0.0)

def go(mask):
    return jax.jit(picker)(mask)
"""),
    ("host-coercion", "repro.chain.simlax",
     """
import jax
import jax.numpy as jnp

def body(state, t):
    lr = float(state[0])
    return state * lr, state.item()

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
""",
     """
import jax
import jax.numpy as jnp

def body(state, t):
    lr = state[0]
    return state * lr, state[0]

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
"""),
    ("np-in-traced", "repro.chain.simlax",
     """
import jax
import numpy as np
import jax.numpy as jnp

def body(state, t):
    noise = np.random.normal(size=3)
    return state + noise, t

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
""",
     """
import jax
import jax.numpy as jnp

def body(state, t):
    noise = jnp.ones((3,))
    return state + noise, t

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
"""),
    ("traced-control-flow", "repro.chain.simlax",
     """
import jax
import jax.numpy as jnp

def body(state, t):
    if t == 0:
        state = state * 0
    return state, t

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
""",
     """
import jax
import jax.numpy as jnp

def body(state, t):
    state = jnp.where(t == 0, state * 0, state)
    return state, t

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
"""),
    ("prngkey-in-scan", "repro.chain.simlax",
     """
import jax
import jax.numpy as jnp

def body(state, t):
    key = jax.random.PRNGKey(0)
    return state + jax.random.normal(key, state.shape), t

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
""",
     """
import jax
import jax.numpy as jnp

def body(state, t):
    key = jax.random.fold_in(state_key, t)
    return state + jax.random.normal(key, state.shape), t

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
"""),
    ("fp16-wire", "repro.core.compression",
     """
import jax.numpy as jnp

def pack(scales):
    return scales.astype(jnp.float16)
""",
     """
import jax.numpy as jnp

def pack(scales):
    return scales.astype(jnp.bfloat16)
"""),
    ("fp16-wire", "repro.core.compression",
     """
import jax.numpy as jnp

def pack(scales):
    return scales.astype("float16")
""",
     """
import jax.numpy as jnp

def pack(scales):
    return scales.astype("bfloat16")
"""),
]

SUPPRESSION_FIXTURE = (
    "repro.chain.simlax",
    """
import jax
import jax.numpy as jnp

def body(state, t):
    idx = jnp.nonzero(state > 0)  # jaxlint: ignore[nonzero-size]
    return state, idx

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
""")


def self_test() -> int:
    """Every rule must fire on its bad fixture and stay silent on the good
    one; suppression comments must mark findings suppressed."""
    failures = []
    fired: Set[str] = set()
    for i, (rule, module, bad, good) in enumerate(FIXTURES):
        bad_hits = [f for f in lint_source(bad, f"<bad:{rule}:{i}>", module)
                    if f.rule == rule and not f.suppressed]
        good_hits = [f for f in lint_source(good, f"<good:{rule}:{i}>", module)
                     if not f.suppressed]
        if not bad_hits:
            failures.append(f"{rule}: bad fixture #{i} produced no finding")
        else:
            fired.add(rule)
        if good_hits:
            failures.append(
                f"{rule}: good fixture #{i} produced findings: "
                + "; ".join(f"{f.rule}@{f.line}" for f in good_hits))
    module, src = SUPPRESSION_FIXTURE
    sup_hits = lint_source(src, "<suppressed>", module)
    if not sup_hits or not all(f.suppressed for f in sup_hits):
        failures.append("suppression: ignore[...] comment did not suppress")
    all_rules = {"nonzero-size", "host-coercion", "np-in-traced",
                 "traced-control-flow", "prngkey-in-scan", "fp16-wire"}
    for missing in sorted(all_rules - fired):
        failures.append(f"{missing}: no bad fixture fired this rule")
    for msg in failures:
        print(f"jaxlint,SELF-TEST-FAIL,{msg}")
    status = "FAIL" if failures else "OK"
    print(f"jaxlint,self-test,{status},rules={len(all_rules)},"
          f"fixtures={len(FIXTURES) + 1}")
    return 1 if failures else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/directories to lint (default: src)")
    ap.add_argument("--json", metavar="OUT", default=None,
                    help="also write findings as JSON (- for stdout)")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="list suppressed findings too")
    ap.add_argument("--self-test", action="store_true",
                    help="run every rule against its embedded fixtures")
    args = ap.parse_args(argv)

    if args.self_test:
        return self_test()

    paths = args.paths or [os.path.join(REPO, "src")]
    findings = lint_paths(paths)
    active = [f for f in findings if not f.suppressed]
    suppressed = [f for f in findings if f.suppressed]

    for f in active:
        print(f"jaxlint,FAIL,{f.rule},{f.path}:{f.line}:{f.col},{f.message}")
    if args.show_suppressed:
        for f in suppressed:
            print(f"jaxlint,suppressed,{f.rule},{f.path}:{f.line}")

    if args.json:
        payload = json.dumps([f.as_dict() for f in findings], indent=2)
        if args.json == "-":
            print(payload)
        else:
            with open(args.json, "w", encoding="utf-8") as fh:
                fh.write(payload + "\n")

    print(f"jaxlint,summary,findings={len(active)},"
          f"suppressed={len(suppressed)}")
    return 1 if active else 0


if __name__ == "__main__":
    sys.exit(main())
