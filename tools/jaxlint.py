#!/usr/bin/env python3
"""jaxlint — repo-wide trace-hygiene linter (pure AST, no jax import).

The repo's correctness rests on contracts no general-purpose linter checks:
everything inside the ``lax.scan`` tick loop must stay jit-traceable, PRNG
keys must flow from the shared ``fold_in(tick)`` stream that keeps heap↔lax
parity bitwise, result shapes must be pinned by static python-int budgets
(``jnp.nonzero(size=...)``), and every wire payload must honor
``core/compression.py``'s bf16-scale contract. This tool makes those
contracts machine-checked: it parses the source trees with ``ast`` only
(same dependency discipline as ``tools/docs_check.py`` — runs on a bare
python, no jax, no PYTHONPATH), builds a repo-wide import + call graph,
*derives* the jit boundary from actual jit/scan/vmap/pallas_call sites,
and propagates traced-param taint across module boundaries.

The implementation lives in the ``tools/jaxlintlib/`` package (graph
build, derived model, taint engine, rules, fixtures, CLI); this file is
the stable entry point and import surface (``import jaxlint``).

Usage:
    python tools/jaxlint.py [paths...]        # default: src
    python tools/jaxlint.py src benchmarks tools   # the CI repo pass
    python tools/jaxlint.py --json out.json src
    python tools/jaxlint.py --self-test       # every rule vs fixtures
    python tools/jaxlint.py --explain LaxSimulator._scan
    python tools/jaxlint.py --check-model     # tables vs derived model

Suppression: append ``# jaxlint: ignore[rule-id]`` (comma-separate several
ids, or ``ignore[*]``) on the offending line. Suppressions are deliberate,
reviewed escapes — each should carry a rationale comment. A bare
``# jaxlint: ignore`` (no rule list) is itself a ``bare-ignore`` finding.

Rules (documented in docs/STATIC_ANALYSIS.md):
    nonzero-size         jnp.nonzero/flatnonzero/argwhere/where(1-arg)
                         without size= on traced paths
    host-coercion        float()/int()/bool()/.item()/.tolist() in traced code
    np-in-traced         numpy calls reachable from jitted code paths
                         (host-side setup allowlisted per function)
    traced-control-flow  python if/while/for over traced values
    prngkey-in-scan      jax.random.PRNGKey built inside a scan body
    prng-reuse           the same key consumed by two jax.random primitives
                         without an intervening split/fold_in/rebind
    f64-root             float64 promotion roots in traced code
    fp16-wire            float16 literals in wire modules OR in any function
                         on a call path into them
    cached-closure-capture  data/traced captures in functions feeding
                         simlax._SCAN_CACHE (must be jit arguments)
    bare-ignore          `# jaxlint: ignore` without a rule list

Exit status: 0 iff zero unsuppressed findings (and fixtures pass under
--self-test / tables agree under --check-model).
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from jaxlintlib import (  # noqa: E402,F401  (re-exported public API)
    Finding,
    Model,
    Project,
    lint_paths,
    lint_project,
    lint_source,
    main,
    self_test,
)
from jaxlintlib.config import (  # noqa: E402,F401  (contract tables)
    HOST_SIDE_FUNCS,
    JITTED_MODULES,
    TRACED_SEEDS,
    WIRE_MODULES,
)
from jaxlintlib.fixtures import FIXTURES, SUPPRESSION_FIXTURE  # noqa: E402,F401
from jaxlintlib.project import REPO, module_name as _module_name  # noqa: E402,F401

if __name__ == "__main__":
    sys.exit(main())
