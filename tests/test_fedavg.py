"""Property tests (hypothesis) for the paper's Eq. 2/3 weighted FedAvg."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # minimal installs still collect the suite
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import fedavg

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _models(n, d, seed):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(n, d).astype(np.float32))


@given(n=st.integers(2, 8), d=st.integers(1, 64), seed=st.integers(0, 999))
def test_equal_weights_is_plain_average(n, d, seed):
    ms = _models(n, d, seed)
    prev = jnp.zeros((d,))
    w = jnp.ones((n,))
    out = fedavg.weighted_fedavg(ms, w, prev)
    expected = 0.5 * ms.mean(0)
    np.testing.assert_allclose(out, expected, rtol=1e-5, atol=1e-6)


@given(n=st.integers(2, 6), seed=st.integers(0, 999))
def test_output_in_convex_hull_midpoint(n, seed):
    """Eq. 3: out = (convex combo + prev)/2 => bounded by extremes."""
    d = 16
    ms = _models(n, d, seed)
    prev = _models(1, d, seed + 1)[0]
    w = jnp.asarray(np.random.RandomState(seed).rand(n).astype(np.float32) + 0.01)
    out = fedavg.weighted_fedavg(ms, w, prev)
    lo = 0.5 * (ms.min(0) + prev)
    hi = 0.5 * (ms.max(0) + prev)
    assert bool(jnp.all(out >= lo - 1e-5) and jnp.all(out <= hi + 1e-5))


@given(seed=st.integers(0, 999))
def test_zero_total_weight_keeps_previous_model(seed):
    ms = _models(4, 8, seed)
    prev = _models(1, 8, seed + 1)[0]
    out = fedavg.weighted_fedavg(ms, jnp.zeros((4,)), prev)
    np.testing.assert_allclose(out, prev, rtol=1e-6)


@given(n=st.integers(2, 6), seed=st.integers(0, 999))
def test_weight_scale_invariance(n, seed):
    """Eq. 3 normalizes by w_T: scaling all weights changes nothing."""
    ms = _models(n, 8, seed)
    prev = jnp.ones((8,))
    w = jnp.asarray(np.random.RandomState(seed).rand(n).astype(np.float32) + 0.1)
    a = fedavg.weighted_fedavg(ms, w, prev)
    b = fedavg.weighted_fedavg(ms, 7.3 * w, prev)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


@given(n=st.integers(2, 6), seed=st.integers(0, 999))
def test_streaming_matches_stacked(n, seed):
    ms = _models(n, 12, seed)
    prev = _models(1, 12, seed + 1)[0]
    w = jnp.asarray(np.random.RandomState(seed).rand(n).astype(np.float32))
    stacked = fedavg.weighted_fedavg(ms, w, prev)
    acc = fedavg.streaming_init(prev)
    for i in range(n):
        acc = fedavg.streaming_add(acc, ms[i], w[i])
    stream = fedavg.streaming_finish(acc, prev)
    np.testing.assert_allclose(stream, stacked, rtol=1e-4, atol=1e-5)


def test_zero_weight_member_excluded():
    ms = jnp.stack([jnp.ones((4,)), 100.0 * jnp.ones((4,))])
    prev = jnp.ones((4,))
    out = fedavg.weighted_fedavg(ms, jnp.asarray([1.0, 0.0]), prev)
    np.testing.assert_allclose(out, jnp.ones((4,)), rtol=1e-6)


def test_pytree_structure_preserved():
    tree = {"a": jnp.ones((3, 4, 5)), "b": (jnp.zeros((3, 2)),)}
    prev = {"a": jnp.zeros((4, 5)), "b": (jnp.ones((2,)),)}
    w = jnp.asarray([0.5, 0.2, 0.3])
    out = fedavg.weighted_fedavg(tree, w, prev)
    assert out["a"].shape == (4, 5) and out["b"][0].shape == (2,)
