"""The attack registry (repro.chain.attacks): each shipped adversary's
corruption semantics, jit/vmap traceability, parameterization via ``make``,
and the FederationSpec role sheet both simulator engines are built from."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.chain import attacks
from repro.chain.attacks import FederationSpec
from repro.chain.node import DFLNode
from repro.core.reputation import IMPL2

P = {"a": jnp.arange(4, dtype=jnp.float32),
     "b": {"w": jnp.ones((2, 3), jnp.float32),
           "step": jnp.asarray(7, jnp.int32)}}
COMMITTED = jax.tree.map(lambda x: x * 0 + 2 if x.dtype == jnp.float32 else x, P)
KEY = jax.random.PRNGKey(0)


def test_registry_get_make_names():
    assert set(attacks.names()) == {"signflip", "gaussian", "scaled",
                                    "freerider", "intermittent"}
    assert attacks.get("signflip") is attacks.SIGNFLIP
    strong = attacks.make("signflip", scale=3.0)
    assert strong.scale == pytest.approx(3.0) and strong.name == "signflip"
    assert attacks.make("gaussian") is attacks.GAUSSIAN   # no params: shared
    with pytest.raises(KeyError, match="unknown attack"):
        attacks.get("nope")
    with pytest.raises(TypeError):
        attacks.make("freerider", scale=2.0)   # unknown field


def test_signflip_flips_float_leaves_only():
    out = attacks.get("signflip").apply(KEY, P, COMMITTED, 0)
    np.testing.assert_allclose(out["a"], -np.arange(4, dtype=np.float32))
    np.testing.assert_allclose(out["b"]["w"], -np.ones((2, 3)))
    assert int(out["b"]["step"]) == 7                  # int leaf untouched
    boosted = attacks.make("signflip", scale=4.0).apply(KEY, P, COMMITTED, 0)
    np.testing.assert_allclose(boosted["b"]["w"], -4.0 * np.ones((2, 3)))


def test_gaussian_replaces_with_scaled_noise():
    g1 = attacks.get("gaussian").apply(KEY, P, COMMITTED, 0)
    g3 = attacks.make("gaussian", sigma=3.0).apply(KEY, P, COMMITTED, 0)
    # noise ignores the honest candidate entirely, scales with sigma
    np.testing.assert_allclose(np.asarray(g3["a"]), 3.0 * np.asarray(g1["a"]),
                               rtol=1e-6)
    assert not np.allclose(np.asarray(g1["b"]["w"]), np.asarray(P["b"]["w"]))
    assert int(g1["b"]["step"]) == 7
    # same key -> same noise (deterministic inside the scan)
    g1b = attacks.get("gaussian").apply(KEY, P, COMMITTED, 0)
    np.testing.assert_array_equal(np.asarray(g1["a"]), np.asarray(g1b["a"]))


def test_scaled_boosts_the_local_update():
    out = attacks.make("scaled", factor=10.0).apply(KEY, P, COMMITTED, 0)
    want = np.asarray(COMMITTED["a"]) + 10.0 * (
        np.asarray(P["a"]) - np.asarray(COMMITTED["a"]))
    np.testing.assert_allclose(np.asarray(out["a"]), want, rtol=1e-6)
    assert int(out["b"]["step"]) == 7


def test_freerider_replays_committed_state():
    out = attacks.get("freerider").apply(KEY, P, COMMITTED, 0)
    jax.tree.map(lambda o, c: np.testing.assert_array_equal(
        np.asarray(o), np.asarray(c)), out, COMMITTED)


def test_intermittent_toggles_by_tick():
    atk = attacks.make("intermittent", period=6, duty=2, inner="signflip")
    on = atk.apply(KEY, P, COMMITTED, 1)       # 1 % 6 < 2 -> attacking
    off = atk.apply(KEY, P, COMMITTED, 3)      # 3 % 6 >= 2 -> honest
    np.testing.assert_allclose(np.asarray(on["a"]),
                               -np.arange(4, dtype=np.float32))
    np.testing.assert_array_equal(np.asarray(off["a"]), np.asarray(P["a"]))
    # next window attacks again
    np.testing.assert_allclose(np.asarray(atk.apply(KEY, P, COMMITTED, 6)["a"]),
                               -np.arange(4, dtype=np.float32))


@pytest.mark.parametrize("name", sorted(attacks.names()))
def test_every_attack_is_jit_and_vmap_traceable(name):
    """The contract the lax engine relies on: apply() vmaps over the
    federation inside a jitted scan with a traced tick."""
    atk = attacks.get(name)
    n = 5
    stacked = jax.tree.map(lambda x: jnp.stack([x] * n), P)
    committed = jax.tree.map(lambda x: jnp.stack([x] * n), COMMITTED)
    keys = jax.random.split(KEY, n)

    @jax.jit
    def go(keys, stacked, committed, tick):
        return jax.vmap(lambda k, p, c: atk.apply(k, p, c, tick))(
            keys, stacked, committed)

    out = go(keys, stacked, committed, jnp.asarray(3, jnp.int32))
    assert jax.tree.map(lambda a, b: a.shape == b.shape, out, stacked)
    assert all(jax.tree.leaves(
        jax.tree.map(lambda a, b: a.dtype == b.dtype, out, stacked)))


def test_attacks_are_hashable_and_replaceable():
    # frozen dataclasses: FederationSpec groups by instance equality
    assert attacks.make("gaussian", sigma=2.0) == attacks.make(
        "gaussian", sigma=2.0)
    assert hash(attacks.get("signflip")) == hash(attacks.SignFlip())
    assert dataclasses.replace(attacks.get("scaled"), factor=2.0).factor == 2.0


# ================================================================ role sheet
def test_federation_spec_build_and_accessors():
    spec = FederationSpec.build(
        8, malicious=(3, 1), attack="signflip", dead=(5,),
        stragglers={2: 4}, initial_countdown=range(8))
    assert spec.malicious == (1, 3)                  # sorted, deduped
    assert spec.attack_for(1).name == "signflip"
    assert spec.attack_for(0) is None
    assert spec.straggler_map() == {2: 4}
    assert spec.initial_countdown == tuple(range(8))
    groups = spec.attack_groups()
    assert len(groups) == 1
    np.testing.assert_array_equal(
        groups[0][1], [False, True, False, True] + [False] * 4)


def test_federation_spec_heterogeneous_attackers_group_by_instance():
    spec = FederationSpec.build(
        6, malicious={0: "gaussian", 2: attacks.make("gaussian", sigma=2.0),
                      4: "gaussian", 5: "signflip"})
    groups = spec.attack_groups()
    # three distinct instances: default gaussian {0,4}, sigma=2 {2}, signflip
    assert len(groups) == 3
    by_mask = {tuple(np.flatnonzero(m)): a.name for a, m in groups}
    assert by_mask == {(0, 4): "gaussian", (2,): "gaussian", (5,): "signflip"}
    # group order follows first appearance over ascending node ids
    assert [tuple(np.flatnonzero(m)) for _, m in groups] \
        == [(0, 4), (2,), (5,)]


def test_federation_spec_dict_malicious_rejects_separate_attack():
    # a heterogeneous dict already assigns attacks; a second attack=
    # argument would be silently ignored otherwise
    with pytest.raises(ValueError, match="drop the separate attack"):
        FederationSpec.build(4, malicious={0: "signflip"}, attack="gaussian")


def test_federation_spec_validation():
    with pytest.raises(ValueError, match="attacker id"):
        FederationSpec.build(4, malicious=(4,))
    with pytest.raises(ValueError, match="dead id"):
        FederationSpec.build(4, dead=(-1,))
    with pytest.raises(ValueError, match="factor"):
        FederationSpec.build(4, stragglers={0: 0})
    with pytest.raises(ValueError, match="initial_countdown"):
        FederationSpec.build(4, initial_countdown=(1, 2))
    assert FederationSpec.honest(3).attackers == ()


# ============================================================ heap-side node
def _toy_node(attack=None, malicious=False):
    params = {"w": jnp.full((4,), 2.0, jnp.float32)}
    return DFLNode(
        name="x", model_structure="toy", params=params,
        train_fn=lambda p, k: (jax.tree.map(lambda x: x + 1.0, p), {}),
        eval_fn=lambda p: 0.5, rep_impl=IMPL2, attack=attack,
        malicious=malicious, rng=jax.random.PRNGKey(0))


def test_node_attack_corrupts_broadcast_without_committing():
    nd = _toy_node(attack="signflip")
    out, _ = nd.train_local(0)
    # broadcast = sign-flipped honestly-trained candidate (2 + 1 = 3)
    np.testing.assert_allclose(np.asarray(out["w"]), -3.0 * np.ones(4))
    # the node's persistent state never advanced
    np.testing.assert_allclose(np.asarray(nd.params["w"]), 2.0 * np.ones(4))
    assert nd.malicious


def test_node_legacy_malicious_flag_maps_to_gaussian():
    nd = _toy_node(malicious=True)
    assert nd.attack is attacks.get("gaussian")
    out, _ = nd.train_local(0)
    assert not np.allclose(np.asarray(out["w"]), np.asarray(nd.params["w"]))


def test_node_honest_by_default():
    nd = _toy_node()
    assert nd.attack is None and not nd.malicious
    out, _ = nd.train_local(0)
    np.testing.assert_allclose(np.asarray(out["w"]), 3.0 * np.ones(4))
    np.testing.assert_allclose(np.asarray(nd.params["w"]), 3.0 * np.ones(4))
