"""``tools/hlo_audit.py`` — the compiled-HLO audit gate: the pure HLO-text
helpers on canned module text, the multi-device setup guard, and (in a
subprocess — the script must set XLA_FLAGS before jax init) the real
``--quick`` audit pass."""
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CANNED = """\
HloModule canned
%collective-permute.1 = f32[8,1]{1,0} collective-permute(f32[8,1]{1,0} %a), channel_id=1
%collective-permute.2 = s8[8,1,256]{2,1,0} collective-permute(s8[8,1,256]{2,1,0} %b), channel_id=2
%collective-permute.3 = f32[]{} collective-permute(f32[]{} %c), channel_id=3
%not-a-permute = f32[4]{0} add(f32[4]{0} %a, f32[4]{0} %a)
%loop = (f32[4]{0}, s32[]) while((f32[4]{0}, s32[]) %init), condition=%cond, body=%body
"""


def _import_hlo_audit():
    """Import the module without triggering its jax device setup twice —
    tools/ is not a package, so path-import it like the CI job runs it."""
    sys.path.insert(0, os.path.join(REPO, "tools"))
    import hlo_audit
    return hlo_audit


def test_permute_payloads_parse_result_types():
    ha = _import_hlo_audit()
    payloads = ha.permute_payloads(CANNED)
    # result type == operand type == what crosses the wire; scalars count
    assert payloads == [("f32", 32), ("s8", 2048), ("f32", 4)]
    assert ha.permute_dtypes(CANNED) == {"f32", "s8"}


def test_while_carry_token_matching():
    ha = _import_hlo_audit()
    assert ha.while_carry_has(CANNED, "f32[")
    assert ha.while_carry_has(CANNED, "s32[")
    # s8 appears in the module (a permute) but NOT in the while carry —
    # exactly the lax-engine invariant the audit gates
    assert not ha.while_carry_has(CANNED, "s8[")


def test_setup_guard_fails_fast_on_one_device():
    """Run under an XLA_FLAGS that pins one host device: the audit must
    refuse with an actionable message instead of lowering no-op cells."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=1"
    res = subprocess.run(
        [sys.executable, os.path.join("tools", "hlo_audit.py"),
         "--json", ""],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=300)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "need >=2 devices" in res.stdout


def test_quick_audit_passes(tmp_path):
    """The real contract CI enforces (bench job): lower the production
    gossip round + the compact lax engine and land every cell green."""
    out = tmp_path / "hlo_audit.json"
    res = subprocess.run(
        [sys.executable, os.path.join("tools", "hlo_audit.py"),
         "--quick", "--json", str(out)],
        cwd=REPO, capture_output=True, text=True, timeout=560)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "hlo-audit,summary" in res.stdout and "failed=0" in res.stdout
    rows = json.loads(out.read_text())["hlo_audit"]
    assert rows["round/ring/ttl1/int8"]["ok"]
    assert rows["retrace/single"]["traces"] == 1
    # int8 ships strictly fewer permute bytes than fp32 on the same cell
    assert (rows["round/ring/ttl1/int8"]["permute_bytes"]
            < 0.3 * rows["round/ring/ttl1/fp32"]["permute_bytes"])
    # vmapped B=2 engine: batch axis, not collectives, not an unrolled loop
    for compress in ("fp32", "int8"):
        row = rows[f"batched/compact/{compress}"]
        assert row["ok"], row["problems"]
        assert row["collectives"] == 0
        assert 12 in row["while_trips"]  # the tick loop survived vmap
        assert row["has_s8"] == (compress == "int8")
