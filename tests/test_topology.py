"""Gossip topology generators, validation, and permutation schedules."""
import numpy as np
import pytest

from repro.core import topology as T
from repro.core.gossip import ring_perms


ALL_KINDS = [
    ("ring", lambda n: T.ring(n)),
    ("kregular", lambda n: T.kregular(n, 2)),
    ("erdos", lambda n: T.erdos_renyi(n, 0.35, seed=1)),
    ("smallworld", lambda n: T.small_world(n, 2, 0.3, seed=0)),
    ("full", lambda n: T.full(n)),
]


@pytest.mark.parametrize("kind,mk", ALL_KINDS)
@pytest.mark.parametrize("n", [6, 9, 16])
def test_generators_valid_and_connected(kind, mk, n):
    topo = mk(n)
    T.validate_adjacency(topo.adj)  # symmetric, boolean, no self-loops
    assert topo.num_nodes == n
    assert topo.is_connected()


def test_degrees():
    assert (T.kregular(10, 3).degrees() == 6).all()
    assert (T.full(7).degrees() == 6).all()
    assert (T.ring(5).degrees() == 2).all()
    # smallworld rewiring preserves the edge count
    assert T.small_world(20, 2, 0.5, seed=3).num_edges == T.kregular(20, 2).num_edges


@pytest.mark.parametrize("kind,mk", ALL_KINDS)
def test_perm_schedule_partitions_directed_edges(kind, mk):
    topo = mk(12)
    n = topo.num_nodes
    cover = np.zeros((n, n), int)
    for cls in topo.perm_schedule():
        srcs = [s for s, _ in cls]
        dsts = [d for _, d in cls]
        # a ppermute-able partial permutation: each node sends/receives <= once
        assert len(set(srcs)) == len(srcs)
        assert len(set(dsts)) == len(dsts)
        for s, d in cls:
            cover[s, d] += 1
    np.testing.assert_array_equal(cover, topo.adj.astype(int))


def test_ring_schedule_reproduces_seed_ring_perms():
    for n in (4, 6, 11):
        fwd, bwd = ring_perms(n)
        sched = [list(c) for c in T.ring(n).perm_schedule()]
        assert sched == [fwd, bwd]


def _delivery_counts(gs, n):
    got = np.zeros((n, n), int)
    for row in gs.senders:
        for i, s in enumerate(row):
            if s >= 0:
                got[i, s] += 1
    return got


def test_gossip_schedule_ring_senders_closed_form():
    n, ttl = 8, 3
    gs = T.gossip_schedule(T.ring(n), ttl)
    assert gs.num_collectives == 2 * ttl  # the seed lowering's permute count
    idx = np.arange(n)
    # one ±offset step per in-ball distance: senders at ∓1, ±1, ∓2, ...
    for h in range(ttl):
        np.testing.assert_array_equal(gs.senders[2 * h], (idx - (h + 1)) % n)
        np.testing.assert_array_equal(gs.senders[2 * h + 1],
                                      (idx + (h + 1)) % n)


def test_gossip_schedule_hop1_covers_every_neighbor_once():
    topo = T.erdos_renyi(14, 0.3, seed=2)
    gs = T.gossip_schedule(topo, 1)
    np.testing.assert_array_equal(_delivery_counts(gs, 14),
                                  topo.adj.astype(int))


@pytest.mark.parametrize("n,k,ttl", [(8, 2, 2), (10, 2, 3), (9, 3, 2)])
def test_circulant_ttl_ball_exact_no_duplicates(n, k, ttl):
    """kregular at ttl>=2: every node in the ttl-ball delivered EXACTLY once
    (the chain lowering double-delivered overlap offsets and missed the
    ball's edge)."""
    topo = T.kregular(n, k)
    gs = T.gossip_schedule(topo, ttl)
    dist = topo.hop_distance()
    ball = ((dist >= 1) & (dist <= ttl)).astype(int)
    np.testing.assert_array_equal(_delivery_counts(gs, n), ball)


def test_irregular_schedule_prunes_useless_steps():
    """Steps that deliver to nobody (2-cycle colour classes bounce payloads
    home at even hops) cost a full-model ppermute each — they must be pruned
    unless a delivering step forwards through them."""
    for seed in range(5):
        topo = T.erdos_renyi(12, 0.3, seed=seed)
        for ttl in (2, 3):
            gs = T.gossip_schedule(topo, ttl)
            parents = {p for (_, p) in gs.steps if p >= 0}
            for s, (_, _p) in enumerate(gs.steps):
                delivers = bool((gs.senders[s] >= 0).any())
                assert delivers or s in parents, (seed, ttl, s)


def test_irregular_multittl_never_double_delivers():
    topo = T.erdos_renyi(12, 0.35, seed=1)
    gs = T.gossip_schedule(topo, 2)
    counts = _delivery_counts(gs, 12)
    assert counts.max() <= 1
    # hop-1 coverage (direct neighbours) is always complete
    assert ((counts - topo.adj.astype(int)) >= 0)[topo.adj].all()
    # chains only walk within the ttl-ball
    dist = topo.hop_distance()
    assert (counts[dist > 2] == 0).all()
    assert np.diagonal(counts).sum() == 0


def test_hop_distance_ring():
    n = 10
    dist = T.ring(n).hop_distance()
    for j in range(n):
        assert dist[0, j] == min(j, n - j)


def test_as_name_dict_matches_heap_helpers():
    from repro.chain import network
    names = [f"n{i}" for i in range(6)]
    assert T.full(6).as_name_dict(names) == network.fully_connected(names)
    got = T.ring(6).as_name_dict(names)
    want = network.ring(names)
    assert {k: set(v) for k, v in got.items()} == \
        {k: set(v) for k, v in want.items()}


def test_make_dispatch_and_validation():
    assert T.make("ring", 8).kind == "ring"
    assert T.make("kregular", 8, degree=3).degrees()[0] == 6
    assert T.make("erdos", 8, p=0.5, seed=0).kind == "erdos"
    assert T.make("smallworld", 8, degree=2, beta=0.1).kind == "smallworld"
    assert T.make("full", 8).num_edges == 28
    with pytest.raises(ValueError):
        T.make("torus", 8)
    with pytest.raises(ValueError):
        T.kregular(6, 5)
    with pytest.raises(ValueError):
        T.erdos_renyi(6, 0.0)
    bad = np.ones((4, 4), dtype=bool)  # self-loops
    with pytest.raises(ValueError):
        T.validate_adjacency(bad)
    asym = np.zeros((4, 4), dtype=bool)
    asym[0, 1] = True
    with pytest.raises(ValueError):
        T.validate_adjacency(asym)


def test_even_n_full_graph_half_offset_not_double_covered():
    # the ±n/2 offset is a single permutation on even n; cover must be exact
    topo = T.full(6)
    cover = np.zeros((6, 6), int)
    for cls in topo.perm_schedule():
        for s, d in cls:
            cover[s, d] += 1
    np.testing.assert_array_equal(cover, topo.adj.astype(int))
