"""Gossip topology generators, validation, and permutation schedules."""
import numpy as np
import pytest

from repro.core import topology as T
from repro.core.gossip import ring_perms


ALL_KINDS = [
    ("ring", lambda n: T.ring(n)),
    ("kregular", lambda n: T.kregular(n, 2)),
    ("erdos", lambda n: T.erdos_renyi(n, 0.35, seed=1)),
    ("smallworld", lambda n: T.small_world(n, 2, 0.3, seed=0)),
    ("full", lambda n: T.full(n)),
]


@pytest.mark.parametrize("kind,mk", ALL_KINDS)
@pytest.mark.parametrize("n", [6, 9, 16])
def test_generators_valid_and_connected(kind, mk, n):
    topo = mk(n)
    T.validate_adjacency(topo.adj)  # symmetric, boolean, no self-loops
    assert topo.num_nodes == n
    assert topo.is_connected()


def test_degrees():
    assert (T.kregular(10, 3).degrees() == 6).all()
    assert (T.full(7).degrees() == 6).all()
    assert (T.ring(5).degrees() == 2).all()
    # smallworld rewiring preserves the edge count
    assert T.small_world(20, 2, 0.5, seed=3).num_edges == T.kregular(20, 2).num_edges


@pytest.mark.parametrize("kind,mk", ALL_KINDS)
def test_perm_schedule_partitions_directed_edges(kind, mk):
    topo = mk(12)
    n = topo.num_nodes
    cover = np.zeros((n, n), int)
    for cls in topo.perm_schedule():
        srcs = [s for s, _ in cls]
        dsts = [d for _, d in cls]
        # a ppermute-able partial permutation: each node sends/receives <= once
        assert len(set(srcs)) == len(srcs)
        assert len(set(dsts)) == len(dsts)
        for s, d in cls:
            cover[s, d] += 1
    np.testing.assert_array_equal(cover, topo.adj.astype(int))


def test_ring_schedule_reproduces_seed_ring_perms():
    for n in (4, 6, 11):
        fwd, bwd = ring_perms(n)
        sched = [list(c) for c in T.ring(n).perm_schedule()]
        assert sched == [fwd, bwd]


def _delivery_counts(gs, n):
    got = np.zeros((n, n), int)
    for row in gs.senders:
        for i, s in enumerate(row):
            if s >= 0:
                got[i, s] += 1
    return got


def test_gossip_schedule_ring_senders_closed_form():
    n, ttl = 8, 3
    gs = T.gossip_schedule(T.ring(n), ttl)
    assert gs.num_collectives == 2 * ttl  # the seed lowering's permute count
    idx = np.arange(n)
    # one ±offset step per in-ball distance: senders at ∓1, ±1, ∓2, ...
    for h in range(ttl):
        np.testing.assert_array_equal(gs.senders[2 * h], (idx - (h + 1)) % n)
        np.testing.assert_array_equal(gs.senders[2 * h + 1],
                                      (idx + (h + 1)) % n)


def test_gossip_schedule_hop1_covers_every_neighbor_once():
    topo = T.erdos_renyi(14, 0.3, seed=2)
    gs = T.gossip_schedule(topo, 1)
    np.testing.assert_array_equal(_delivery_counts(gs, 14),
                                  topo.adj.astype(int))


@pytest.mark.parametrize("n,k,ttl", [(8, 2, 2), (10, 2, 3), (9, 3, 2)])
def test_circulant_ttl_ball_exact_no_duplicates(n, k, ttl):
    """kregular at ttl>=2: every node in the ttl-ball delivered EXACTLY once
    (the chain lowering double-delivered overlap offsets and missed the
    ball's edge)."""
    topo = T.kregular(n, k)
    gs = T.gossip_schedule(topo, ttl)
    dist = topo.hop_distance()
    ball = ((dist >= 1) & (dist <= ttl)).astype(int)
    np.testing.assert_array_equal(_delivery_counts(gs, n), ball)


def test_chain_schedule_prunes_useless_steps():
    """Legacy chain oracle: steps that deliver to nobody (2-cycle colour
    classes bounce payloads home at even hops) cost a full-model ppermute
    each — they must be pruned unless a delivering step forwards through
    them. The frontier lowering never emits a non-delivering step at all."""
    for seed in range(5):
        topo = T.erdos_renyi(12, 0.3, seed=seed)
        for ttl in (2, 3):
            gs = T.gossip_schedule(topo, ttl, schedule="chain")
            parents = {p for (_, p) in gs.steps if p >= 0}
            for s, (_, _p) in enumerate(gs.steps):
                delivers = bool((gs.senders[s] >= 0).any())
                assert delivers or s in parents, (seed, ttl, s)
            fs = T.gossip_schedule(topo, ttl)
            assert all((row >= 0).any() for row in fs.senders), (seed, ttl)


def test_irregular_frontier_delivers_exact_ball():
    """The frontier lowering on an irregular graph: the FULL ttl-ball,
    every pair exactly once, nothing outside it (the chain walk used to
    silently miss a large subset of the ball)."""
    topo = T.erdos_renyi(12, 0.35, seed=1)
    dist = topo.hop_distance()
    for ttl in (2, 3):
        gs = T.gossip_schedule(topo, ttl)
        counts = _delivery_counts(gs, 12)
        ball = ((dist >= 1) & (dist <= ttl)).astype(int)
        np.testing.assert_array_equal(counts, ball)


def test_hop_distance_ring():
    n = 10
    dist = T.ring(n).hop_distance()
    for j in range(n):
        assert dist[0, j] == min(j, n - j)


# ------------------------------------------------- compaction budget math
def test_ring_sizes_partition_the_ball():
    for kind, kw in (("ring", {}), ("kregular", {"degree": 2}),
                     ("erdos", {"p": 0.3}), ("full", {})):
        topo = T.make(kind, 14, seed=5, **kw)
        for ttl in (1, 2, 3):
            rings = T.ring_sizes(topo.adj, ttl)
            assert rings.shape == (14, ttl)
            np.testing.assert_array_equal(rings.sum(axis=1),
                                          T.ttl_ball_sizes(topo.adj, ttl))
    with pytest.raises(ValueError, match="ttl"):
        T.ring_sizes(T.ring(6).adj, 0)


def test_compaction_budget_closed_forms():
    """Circulant graphs have every ring of size 2k (until wrap), so each
    regime of the interval-gap DP has a hand-computable answer."""
    n, k = 16, 1
    adj = T.kregular(n, k).adj
    # recommended regime (lo >= ttl * latency): one ring per sender
    assert T.compaction_budget(adj, 3, (3, 3), latency=1) == n * 2
    assert T.compaction_budget(adj, 3, (9, 12), latency=3) == n * 2
    # overwrite regime: gap g = ceil(lo/latency) admits multi-ring sets
    assert T.compaction_budget(adj, 3, (1, 1), latency=1) == n * 6  # all
    assert T.compaction_budget(adj, 3, (2, 2), latency=1) == n * 4  # {1,3}
    # full graph, ttl >= 1: everyone's ring-1 is everyone else
    assert T.compaction_budget(T.full(8).adj, 2, (8, 8)) == 8 * 7
    # scalar interval accepted (treated as lo)
    assert T.compaction_budget(adj, 2, 4) == n * 2


def test_compaction_budget_never_exceeds_sparse_slots():
    for kind, kw in (("kregular", {"degree": 3}), ("erdos", {"p": 0.35}),
                     ("smallworld", {"degree": 2, "beta": 0.3})):
        topo = T.make(kind, 12, seed=7, **kw)
        for ttl in (1, 2, 3):
            for lo in (1, 2, ttl, 4 * ttl):
                bound = T.compaction_budget(topo.adj, ttl, (lo, lo + 4))
                assert bound <= 12 * T.delivery_budget(topo.adj, ttl), \
                    (kind, ttl, lo)
                # a bound below the max ball would drop same-tick arrivals
                assert bound >= T.ttl_ball_sizes(topo.adj, ttl).max()


def test_compaction_budget_dead_masked_and_validation():
    n = 12
    topo = T.make("erdos", n, p=0.35, seed=3)
    alive = np.ones((n,), bool)
    alive[[2, 9]] = False
    masked = topo.adj & alive[None, :] & alive[:, None]
    assert T.compaction_budget(masked, 2, (4, 8)) <= \
        T.compaction_budget(topo.adj, 2, (4, 8))
    # fully-dead adjacency: no rings, zero bound (callers floor at 1)
    assert T.compaction_budget(np.zeros((4, 4), bool), 2, (4, 8)) == 0
    with pytest.raises(ValueError, match="interval"):
        T.compaction_budget(topo.adj, 2, (0, 4))
    with pytest.raises(ValueError, match="latency"):
        T.compaction_budget(topo.adj, 2, (4, 8), latency=0)


def test_as_name_dict_matches_heap_helpers():
    from repro.chain import network
    names = [f"n{i}" for i in range(6)]
    assert T.full(6).as_name_dict(names) == network.fully_connected(names)
    got = T.ring(6).as_name_dict(names)
    want = network.ring(names)
    assert {k: set(v) for k, v in got.items()} == \
        {k: set(v) for k, v in want.items()}


def test_make_dispatch_and_validation():
    assert T.make("ring", 8).kind == "ring"
    assert T.make("kregular", 8, degree=3).degrees()[0] == 6
    assert T.make("erdos", 8, p=0.5, seed=0).kind == "erdos"
    assert T.make("smallworld", 8, degree=2, beta=0.1).kind == "smallworld"
    assert T.make("full", 8).num_edges == 28
    with pytest.raises(ValueError):
        T.make("torus", 8)
    with pytest.raises(ValueError):
        T.kregular(6, 5)
    with pytest.raises(ValueError):
        T.erdos_renyi(6, 0.0)
    bad = np.ones((4, 4), dtype=bool)  # self-loops
    with pytest.raises(ValueError):
        T.validate_adjacency(bad)
    asym = np.zeros((4, 4), dtype=bool)
    asym[0, 1] = True
    with pytest.raises(ValueError):
        T.validate_adjacency(asym)


def test_even_n_full_graph_half_offset_not_double_covered():
    # the ±n/2 offset is a single permutation on even n; cover must be exact
    topo = T.full(6)
    cover = np.zeros((6, 6), int)
    for cls in topo.perm_schedule():
        for s, d in cls:
            cover[s, d] += 1
    np.testing.assert_array_equal(cover, topo.adj.astype(int))


# ============================================= schedule audit (frontier/chain)
@pytest.mark.parametrize("kind,mk", ALL_KINDS)
@pytest.mark.parametrize("ttl", [1, 2, 3])
def test_audit_schedule_frontier_clean_all_kinds(kind, mk, ttl):
    """The acceptance bar of the frontier lowering: for EVERY topology kind
    and ttl, the schedule delivers the exact BFS ttl-ball — no missing
    pairs, no duplicates, nothing out of ball, no wasted collectives, and
    every delivery lands at its BFS hop (the tick simulators' timing)."""
    topo = mk(13)
    audit = T.audit_schedule(topo, ttl)
    assert audit.ok, (kind, ttl, audit)
    assert audit.missing == ()
    assert audit.duplicates == ()
    assert audit.out_of_ball == ()
    assert audit.wasted_steps == ()
    assert audit.mistimed == ()
    assert audit.coverage == 1.0


@pytest.mark.parametrize("kind,mk", ALL_KINDS)
@pytest.mark.parametrize("ttl", [1, 2, 3])
def test_audit_chain_oracle_regression_record(kind, mk, ttl):
    """Pinned-regression record of the OLD chain lowering: exact at ttl=1
    everywhere and at any ttl on circulant graphs, but silently
    under-covering the ttl-ball on irregular graphs at ttl >= 2 (never
    duplicating or leaving the ball, though). If this 'xfail' half ever
    starts passing, the oracle stopped reproducing the historical bug."""
    topo = mk(13)
    audit = T.audit_schedule(topo, ttl, schedule="chain")
    assert audit.duplicates == ()
    assert audit.out_of_ball == ()
    if ttl == 1 or kind in ("ring", "kregular", "full"):
        assert audit.ok and audit.coverage == 1.0, (kind, ttl, audit)
    else:
        # the bug this PR fixed, preserved behind schedule="chain"
        assert audit.missing, (kind, ttl)
        assert audit.coverage < 1.0, (kind, ttl, audit.coverage)


# the circulant lowering's known collective counts at n=12 (2*radius
# one-hop offset permutes, +1 for the even-n half offset when in ball) —
# hardcoded so a cost regression in EITHER mode fails, not just a
# frontier/chain divergence (both modes share the circulant code path)
_CIRCULANT_COLLECTIVES_N12 = {
    ("ring", 1): 2, ("ring", 2): 4, ("ring", 3): 6,
    ("kregular", 1): 4, ("kregular", 2): 8, ("kregular", 3): 11,
    ("full", 1): 11, ("full", 2): 11, ("full", 3): 11,
}


@pytest.mark.parametrize("kind", ["ring", "kregular", "full"])
@pytest.mark.parametrize("ttl", [1, 2, 3])
def test_circulant_collective_count_unchanged_by_frontier(kind, ttl):
    """No cost regression where the old lowering was already exact: on
    circulant graphs both modes emit the identical closed-form offset
    schedule (same permutes, same senders), at the pre-frontier pinned
    collective count."""
    topo = {"ring": T.ring(12), "kregular": T.kregular(12, 2),
            "full": T.full(12)}[kind]
    fr = T.gossip_schedule(topo, ttl)
    ch = T.gossip_schedule(topo, ttl, schedule="chain")
    assert fr.num_collectives == _CIRCULANT_COLLECTIVES_N12[(kind, ttl)]
    assert ch.num_collectives == fr.num_collectives
    assert fr.steps == ch.steps
    np.testing.assert_array_equal(fr.senders, ch.senders)


def test_gossip_schedule_rejects_unknown_mode():
    with pytest.raises(ValueError, match="schedule"):
        T.gossip_schedule(T.ring(6), 1, schedule="bogus")


def test_dfl_schedule_report_fails_fast_on_under_coverage():
    """The --dfl path's guard: an under-covering schedule (only reachable
    via the schedule='chain' oracle on an irregular graph) raises instead
    of silently lowering a round with partial delivery; the default
    frontier lowering reports full coverage."""
    from repro.core.dfl import DFLConfig, schedule_report
    ok = schedule_report(DFLConfig(ttl=2, topology="erdos"), 12)
    assert ok["coverage"] == 1.0 and ok["missing_pairs"] == 0
    assert ok["num_collectives"] > 0
    bad = schedule_report(
        DFLConfig(ttl=2, topology="erdos", schedule="chain"), 12,
        strict=False)
    assert bad["coverage"] < 1.0 and bad["missing_pairs"] > 0
    with pytest.raises(RuntimeError, match="under-covers"):
        schedule_report(
            DFLConfig(ttl=2, topology="erdos", schedule="chain"), 12)


def test_frontier_parent_steps_hold_the_forwarded_payload():
    """Structural invariant the jitted round relies on: a step with parent
    sigma forwards payloads received at step sigma — so each of its (src ->
    dst) pairs must have src RECEIVING some payload at step sigma, and the
    delivered sender must be that very payload's origin."""
    for kind, mk in ALL_KINDS:
        topo = mk(11)
        for ttl in (2, 3):
            gs = T.gossip_schedule(topo, ttl)
            for s, (perm, parent) in enumerate(gs.steps):
                row = gs.senders[s]
                if parent < 0:
                    for (src, dst) in perm:
                        if row[dst] >= 0:
                            assert row[dst] == src, (kind, ttl, s)
                    continue
                prow = gs.senders[parent]
                for (src, dst) in perm:
                    if row[dst] >= 0:
                        assert prow[src] == row[dst], (kind, ttl, s)
