"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fedavg
from repro.kernels.flash_attention.ops import flash_attention as pallas_flash
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.quantize.quantize import dequantize, quantize
from repro.kernels.quantize.ref import dequantize_ref, quantize_ref
from repro.kernels.wfedavg import ops as wf_ops
from repro.kernels.wfedavg.ref import wfedavg_ref
from repro.kernels.wfedavg.wfedavg import wfedavg_flat


# ------------------------------------------------------------------- wfedavg
@pytest.mark.parametrize("n", [2, 5, 10])
@pytest.mark.parametrize("d,block", [(2048, 2048), (8192, 2048), (4096, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wfedavg_kernel_matches_ref(n, d, block, dtype):
    key = jax.random.PRNGKey(n * d)
    ms = jax.random.normal(key, (n, d), jnp.float32)
    prev = jax.random.normal(jax.random.fold_in(key, 1), (d,), jnp.float32).astype(dtype)
    wn = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 2), (n,)))
    out = wfedavg_flat(ms, wn, prev.astype(jnp.float32), block_cols=block,
                       interpret=True)
    ref = wfedavg_ref(ms[:, None, :], wn, prev.astype(jnp.float32)[None, :])[0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_wfedavg_tree_matches_core_fedavg():
    key = jax.random.PRNGKey(0)
    tree_m = {"w": jax.random.normal(key, (4, 128, 64)),
              "b": jax.random.normal(jax.random.fold_in(key, 1), (4, 16))}
    tree_p = {"w": jnp.zeros((128, 64)), "b": jnp.ones((16,))}
    w = jnp.asarray([0.1, 0.4, 0.0, 0.5])
    a = wf_ops.weighted_fedavg_tree(tree_m, w, tree_p)
    b = fedavg.weighted_fedavg(tree_m, w, tree_p)
    for k in ("w", "b"):
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   rtol=1e-5, atol=1e-6)


def test_wfedavg_tree_zero_weight_keeps_prev():
    tree_m = {"w": jnp.ones((3, 64, 64))}
    tree_p = {"w": 5.0 * jnp.ones((64, 64))}
    out = wf_ops.weighted_fedavg_tree(tree_m, jnp.zeros((3,)), tree_p)
    np.testing.assert_allclose(np.asarray(out["w"]), 5.0)


# ------------------------------------------------------------------ quantize
@pytest.mark.parametrize("rows,cols,br", [(256, 256, 256), (512, 128, 256),
                                          (64, 512, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_kernel_matches_ref(rows, cols, br, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(rows + cols), (rows, cols))
         * 3.0).astype(dtype)
    q, s = quantize(x, block_rows=br, interpret=True)
    qr, sr = quantize_ref(x)
    if dtype == jnp.float32:
        assert bool(jnp.all(q == qr))
    else:
        # bf16 inputs land on exact .5 boundaries: tolerate 1-LSB flips from
        # op-ordering ULP differences between the kernel and oracle paths
        diff = jnp.abs(q.astype(jnp.int32) - qr.astype(jnp.int32))
        assert int(diff.max()) <= 1
        assert float((diff > 0).mean()) < 0.01
    np.testing.assert_allclose(np.asarray(s[:, 0]), np.asarray(sr[:, 0]),
                               rtol=1e-5)
    # dequant math checked against the SAME q (kernel q may differ from ref
    # q by the tolerated 1 LSB above)
    xd = dequantize(q, s, block_rows=br, interpret=True)
    xr = dequantize_ref(q, s)
    np.testing.assert_allclose(np.asarray(xd), np.asarray(xr), rtol=1e-5)
    # relative reconstruction error bound for int8 symmetric quantization
    rel = float(jnp.max(jnp.abs(xd - x.astype(jnp.float32)))
                / jnp.max(jnp.abs(x.astype(jnp.float32))))
    assert rel < 0.01


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 32)])
@pytest.mark.parametrize("S,H,KH,Dh", [(128, 4, 2, 64), (128, 2, 2, 80),
                                       (256, 4, 1, 32)])
def test_pallas_flash_matches_ref(causal, window, S, H, KH, Dh):
    key = jax.random.PRNGKey(S + H + Dh)
    B = 2
    q = jax.random.normal(key, (B, S, H, Dh), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KH, Dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KH, Dh))
    o = pallas_flash(q, k, v, causal=causal, window=window,
                     block_q=64, block_kv=64)
    ke = jnp.repeat(k, H // KH, axis=2)
    ve = jnp.repeat(v, H // KH, axis=2)
    r = attention_ref(q, ke, ve, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_pallas_flash_bf16(dtype):
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (1, 128, 2, 64)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 128, 2, 64)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 128, 2, 64)).astype(dtype)
    o = pallas_flash(q, k, v, causal=True, block_q=64, block_kv=64)
    r = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), rtol=3e-2, atol=3e-2)
