"""Sharding rules engine + HLO cost walker unit tests.

(The hypothesis-based Dirichlet-partition property test lives in
tests/test_partition_props.py so this module collects on minimal installs.)
"""
import pytest
from jax.sharding import PartitionSpec as P

from repro import sharding as sh
from repro.data.partition import dirichlet_class_probs
from repro.launch import hlo_cost


class FakeMesh:
    def __init__(self, shape):  # dict axis -> size
        self.shape = shape
        self.axis_names = tuple(shape)


def test_divisibility_drops_assignment():
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = sh.make_rules()
    # kv_heads=8 cannot shard over model=16 -> replicated
    spec = sh.logical_to_spec(("batch", None, "kv_heads", "head_dim"), mesh,
                              rules, (128, 32, 8, 128))
    assert spec == P("data")
    # heads=32 shards fine
    spec = sh.logical_to_spec(("batch", None, "heads", "head_dim"), mesh,
                              rules, (128, 32, 32, 128))
    assert spec == P("data", None, "model")


def test_axis_used_once_per_tensor():
    mesh = FakeMesh({"data": 4, "model": 4})
    rules = sh.make_rules(fsdp=True)
    # both embed (fsdp->data) and batch want data; batch (first dim) wins
    spec = sh.logical_to_spec(("batch", "embed"), mesh, rules, (64, 64))
    assert spec == P("data")


def test_multi_axis_batch_sharding():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    rules = sh.make_rules()
    spec = sh.logical_to_spec(("batch", "seq"), mesh, rules, (256, 4096))
    assert spec == P(("pod", "data"))


def test_decode_kv_seq_fallback_order():
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = sh.make_rules()
    rules[sh.KV_SEQ] = (("data",), ("model",))
    # batch=1 can't take data -> kv_seq gets data
    spec = sh.logical_to_spec(("batch", "kv_seq", "kv_heads", "head_dim"),
                              mesh, rules, (1, 524288, 8, 128))
    assert spec == P(None, "data")
    # batch=128 takes data -> kv_seq falls to model
    spec = sh.logical_to_spec(("batch", "kv_seq", "kv_heads", "head_dim"),
                              mesh, rules, (128, 32768, 8, 128))
    assert spec == P("data", "model")


# ------------------------------------------------------------ hlo cost walker
def test_shape_parse():
    assert hlo_cost.shape_elems_bytes("bf16[4,8]{1,0}") == (32, 64)
    assert hlo_cost.shape_elems_bytes("(f32[2,2], s32[3])") == (7, 28)
    assert hlo_cost.shape_elems_bytes("pred[]")[1] == 1


def test_instr_parse_tuple_result_with_index_comment():
    line = ('  %while.1 = (s32[], f32[2,2]{1,0}, /*index=2*/f32[4]{0}) '
            'while(%tuple.1), condition=%cond.1, body=%body.1, '
            'backend_config={"known_trip_count":{"n":"7"}}')
    ins = hlo_cost.parse_instr(line)
    assert ins.opcode == "while"
    assert hlo_cost._TRIPCOUNT_RE.search(ins.line).group(1) == "7"


def test_dot_flops_counted_with_trip_count():
    txt = """
%body.1 (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %gte.1 = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %c.1 = f32[16,16]{1,0} constant({...})
  %dot.1 = f32[8,16]{1,0} dot(%gte.1, %c.1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %gte.0 = s32[] get-tuple-element(%p), index=0
  %one = s32[] constant(1)
  %add.1 = s32[] add(%gte.0, %one)
  ROOT %tuple.2 = (s32[], f32[8,16]{1,0}) tuple(%add.1, %dot.1)
}

%cond.1 (p.1: (s32[], f32[8,16])) -> pred[] {
  %gte.2 = s32[] get-tuple-element(%p.1), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%gte.2, %n), direction=LT
}

ENTRY %main (x: f32[8,16]) -> f32[8,16] {
  %zero = s32[] constant(0)
  %tuple.1 = (s32[], f32[8,16]{1,0}) tuple(%zero, %x)
  %while.1 = (s32[], f32[8,16]{1,0}) while(%tuple.1), condition=%cond.1, body=%body.1
  ROOT %gte.3 = f32[8,16]{1,0} get-tuple-element(%while.1), index=1
}
"""
    res = hlo_cost.analyze(txt)
    assert res.while_trips == [5]
    assert res.flops == pytest.approx(2 * 8 * 16 * 16 * 5)


# --------------------------------------------------------- dirichlet partition
def test_smaller_alpha_more_imbalanced():
    even = dirichlet_class_probs(5, 10, 100.0, 0)
    skew = dirichlet_class_probs(5, 10, 0.1, 0)
    assert skew.max() > even.max()


# ------------------------------------------- partial-auto shard_map fail-fast
def test_partial_auto_shard_map_check_fails_fast_on_old_jax():
    """dryrun --dfl on the 16x16 production mesh used to abort deep inside
    old jaxlib's SPMD partitioner; repro.compat now detects the partial-auto
    case up front and raises an actionable error instead."""
    from types import SimpleNamespace

    from repro import compat

    prod = SimpleNamespace(axis_names=("data", "model"),
                           shape={"data": 16, "model": 16})
    fed = SimpleNamespace(axis_names=("fed", "data", "model"),
                          shape={"fed": 4, "data": 1, "model": 1})
    # federation meshes (trivial auto axes) pass on every jax version
    compat.check_partial_auto_shard_map(fed, {"fed"})
    # fully-manual is always fine too
    compat.check_partial_auto_shard_map(prod, {"data", "model"})
    if compat.supports_partial_auto_shard_map():
        compat.check_partial_auto_shard_map(prod, {"data"})
    else:
        with pytest.raises(RuntimeError, match="jax >= 0.6"):
            compat.check_partial_auto_shard_map(prod, {"data"})
