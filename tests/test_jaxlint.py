"""``tools/jaxlint.py`` — the trace-hygiene linter: rule firing, module
scoping, suppression comments, exit codes, and the two acceptance
contracts CI enforces (the self-test proves every rule fires; the repo
pass over ``src/`` is clean)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))
import jaxlint  # noqa: E402


def _findings(src, module, rule=None):
    out = [f for f in jaxlint.lint_source(src, f"<{module}>", module)
           if not f.suppressed]
    if rule:
        out = [f for f in out if f.rule == rule]
    return out


def test_self_test_every_rule_fires():
    assert jaxlint.self_test() == 0


def test_repo_pass_is_clean():
    """The acceptance criterion: zero unsuppressed findings over src/."""
    assert jaxlint.main([os.path.join(REPO, "src")]) == 0


def test_rules_scope_to_jitted_modules():
    """The same unguarded ``jnp.nonzero`` is a finding inside a known-jitted
    module and silent in host-side code — the rule set encodes the repo's
    jit boundary, not a blanket style ban."""
    src = """
import jax
import jax.numpy as jnp

def body(state, t):
    idx = jnp.nonzero(state > 0)
    return state, idx

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
"""
    assert _findings(src, "repro.chain.simlax", "nonzero-size")
    assert not _findings(src, "benchmarks.bench_gossip")


def test_host_coercion_in_scan_body():
    src = """
import jax
import jax.numpy as jnp

def body(state, t):
    x = float(state.sum())
    return state + x, None

def run(state):
    return jax.lax.scan(body, state, jnp.arange(3))
"""
    hits = _findings(src, "repro.chain.simlax", "host-coercion")
    assert hits and "float(" in hits[0].message


def test_traced_control_flow_taint_stops_at_static_attrs():
    """``if`` over a value computed from a traced param is a finding;
    ``if`` over its .shape/.ndim (static at trace time) is not."""
    bad = """
import jax
import jax.numpy as jnp

def body(state, t):
    m = jnp.sum(state)
    if m > 0:
        state = state + 1
    return state, None

def run(state):
    return jax.lax.scan(body, state, jnp.arange(3))
"""
    good = bad.replace("m = jnp.sum(state)", "m = state.ndim")
    assert _findings(bad, "repro.chain.simlax", "traced-control-flow")
    assert not _findings(good, "repro.chain.simlax", "traced-control-flow")


def test_suppression_comment_and_exit_codes(tmp_path, capsys):
    bad = """\
import jax
import jax.numpy as jnp

def body(state, t):
    idx = jnp.nonzero(state > 0)
    return state, idx

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
"""
    hits = jaxlint.lint_source(bad, "<t>", "repro.chain.simlax")
    assert any(f.rule == "nonzero-size" and not f.suppressed for f in hits)
    sup = bad.replace("state > 0)", "state > 0)  # jaxlint: ignore[nonzero-size]")
    hits = jaxlint.lint_source(sup, "<t>", "repro.chain.simlax")
    assert hits and all(f.suppressed for f in hits)
    # the wrong rule name in the comment must NOT suppress
    wrong = bad.replace("state > 0)", "state > 0)  # jaxlint: ignore[fp16-wire]")
    hits = jaxlint.lint_source(wrong, "<t>", "repro.chain.simlax")
    assert any(not f.suppressed for f in hits)


def test_main_json_output_and_failure_exit(tmp_path, capsys):
    bad_file = tmp_path / "snippet.py"
    # tmp files resolve to no known module: use a wire-module rule that
    # fires on path-independent compression code? No — fp16-wire scopes by
    # module too, so assert the clean-exit path on an out-of-scope file
    bad_file.write_text("import numpy as np\nx = np.float16(1.0)\n")
    out_json = tmp_path / "findings.json"
    assert jaxlint.main([str(bad_file), "--json", str(out_json)]) == 0
    assert json.loads(out_json.read_text()) == []
    summary = capsys.readouterr().out
    assert "jaxlint,summary,findings=0" in summary


def test_repo_pass_full_surface_is_clean_and_fast():
    """CI now lints src + benchmarks + tools in one pass (per-tree rule
    profiles keep host-side benchmark idiom legal), and the acceptance
    budget for the whole-repo cross-module analysis is < 10 s."""
    import time
    t0 = time.monotonic()
    rc = jaxlint.main([os.path.join(REPO, p)
                       for p in ("src", "benchmarks", "tools")])
    elapsed = time.monotonic() - t0
    assert rc == 0
    assert elapsed < 10.0, f"repo pass took {elapsed:.1f}s (budget 10s)"


def test_bare_ignore_is_itself_a_finding():
    """A suppression without a rule list silences everything on the line —
    reject it, and don't let the finding suppress itself."""
    src = """
import jax.numpy as jnp

def f(x):
    return jnp.sum(x)  # jaxlint: ignore
"""
    hits = _findings(src, "benchmarks.bench_gossip", "bare-ignore")
    assert hits and "name the rules" in hits[0].message
    # spelling out the rule is the fix
    ok = src.replace("# jaxlint: ignore", "# jaxlint: ignore[nonzero-size]")
    assert not _findings(ok, "benchmarks.bench_gossip", "bare-ignore")


def test_prng_reuse_rule():
    """The same key consumed by two jax.random primitives without an
    intervening split/fold_in breaks the fold_in(tick) stream contract."""
    bad = """
import jax
import jax.numpy as jnp

def body(state, key):
    a = jax.random.normal(key, (4,))
    b = jax.random.uniform(key, (4,))
    return state + a + b, None

def run(state, keys):
    return jax.lax.scan(body, state, keys)
"""
    good = bad.replace(
        "    a = jax.random.normal(key, (4,))\n"
        "    b = jax.random.uniform(key, (4,))",
        "    k1, k2 = jax.random.split(key)\n"
        "    a = jax.random.normal(k1, (4,))\n"
        "    b = jax.random.uniform(k2, (4,))")
    hits = _findings(bad, "repro.chain.simlax", "prng-reuse")
    assert hits and "key" in hits[0].message
    assert not _findings(good, "repro.chain.simlax", "prng-reuse")


def test_f64_root_rule():
    bad = """
import jax
import jax.numpy as jnp

def body(state, t):
    acc = jnp.zeros((4,), dtype="float64")
    return state + acc, None

def run(state):
    return jax.lax.scan(body, state, jnp.arange(3))
"""
    good = bad.replace('"float64"', '"float32"')
    assert _findings(bad, "repro.chain.simlax", "f64-root")
    assert not _findings(good, "repro.chain.simlax", "f64-root")


def test_cached_closure_capture_rule():
    """Functions stored in simlax._SCAN_CACHE outlive their builder: a
    captured dataset silently pins the first federation's data."""
    bad = """
import jax

_SCAN_CACHE = {}

def build(train_data):
    def dispatch(params, key):
        return params, train_data
    _SCAN_CACHE["k"] = jax.jit(dispatch)
"""
    good = bad.replace("def dispatch(params, key):",
                       "def dispatch(params, key, train_data):")
    hits = _findings(bad, "repro.chain.simlax", "cached-closure-capture")
    assert hits and "train_data" in hits[0].message
    assert not _findings(good, "repro.chain.simlax",
                         "cached-closure-capture")


def test_explain_cli_resolves_cross_module_chain(capsys):
    """--explain on a compression codec function shows the derived chain
    rooted at a simlax tracing entry — evidence the jit boundary is
    derived, not just asserted, and that it crosses module boundaries."""
    assert jaxlint.main(["--explain", "roundtrip_tree"]) == 0
    out = capsys.readouterr().out
    assert "repro.core.compression.roundtrip_tree: TRACED" in out
    assert "repro.chain.simlax" in out
    # unknown functions exit nonzero with a NO-MATCH marker
    assert jaxlint.main(["--explain", "no_such_function_xyz"]) == 1
    assert "NO-MATCH" in capsys.readouterr().out


def test_check_model_cli_agrees_on_repo(capsys):
    """The checked-in override tables must agree with the derived model —
    the CI static-analysis job fails on any drift."""
    assert jaxlint.main(["--check-model"]) == 0
    assert "check-model,OK" in capsys.readouterr().out


def test_check_model_flags_stale_tables():
    from jaxlintlib.project import Project

    src_files = []
    for dirpath, _, files in os.walk(os.path.join(REPO, "src")):
        src_files.extend(os.path.join(dirpath, f) for f in files
                         if f.endswith(".py"))
    project = Project.from_paths(src_files, REPO)
    model = jaxlint.Model(
        project,
        jitted_modules={"repro.chain.simlax", "repro.chain.vanished"},
        traced_seeds={"repro.core.compression": {"no_such_func_*"}},
        host_side={"repro.chain.simlax": {"LaxSimulator.gone": "stale"}},
        wire_modules={"repro.core.compression"})
    problems = model.check()
    assert any("repro.chain.vanished" in p for p in problems)
    assert any("no_such_func_*" in p for p in problems)
    assert any("LaxSimulator.gone" in p for p in problems)


def test_parse_error_is_a_finding_not_a_crash():
    hits = jaxlint.lint_source("def broken(:\n", "<t>", "repro.chain.simlax")
    assert hits and hits[0].rule == "parse-error"


def test_no_jax_import_discipline():
    """jaxlint must be importable (and must lint) without jax present —
    same discipline as tools/docs_check.py, so the CI job stays fast and
    dependency-free."""
    code = (
        "import sys; sys.path.insert(0, 'tools')\n"
        "import jaxlint\n"
        "jaxlint.lint_source('x = 1', '<t>', 'repro.chain.simlax')\n"
        "assert 'jax' not in sys.modules, 'jaxlint imported jax'\n"
        "print('clean')\n"
    )
    res = subprocess.run([sys.executable, "-c", code], cwd=REPO,
                         capture_output=True, text=True, timeout=120)
    assert res.returncode == 0, res.stderr
    assert "clean" in res.stdout
