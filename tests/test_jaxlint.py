"""``tools/jaxlint.py`` — the trace-hygiene linter: rule firing, module
scoping, suppression comments, exit codes, and the two acceptance
contracts CI enforces (the self-test proves every rule fires; the repo
pass over ``src/`` is clean)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))
import jaxlint  # noqa: E402


def _findings(src, module, rule=None):
    out = [f for f in jaxlint.lint_source(src, f"<{module}>", module)
           if not f.suppressed]
    if rule:
        out = [f for f in out if f.rule == rule]
    return out


def test_self_test_every_rule_fires():
    assert jaxlint.self_test() == 0


def test_repo_pass_is_clean():
    """The acceptance criterion: zero unsuppressed findings over src/."""
    assert jaxlint.main([os.path.join(REPO, "src")]) == 0


def test_rules_scope_to_jitted_modules():
    """The same unguarded ``jnp.nonzero`` is a finding inside a known-jitted
    module and silent in host-side code — the rule set encodes the repo's
    jit boundary, not a blanket style ban."""
    src = """
import jax
import jax.numpy as jnp

def body(state, t):
    idx = jnp.nonzero(state > 0)
    return state, idx

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
"""
    assert _findings(src, "repro.chain.simlax", "nonzero-size")
    assert not _findings(src, "benchmarks.bench_gossip")


def test_host_coercion_in_scan_body():
    src = """
import jax
import jax.numpy as jnp

def body(state, t):
    x = float(state.sum())
    return state + x, None

def run(state):
    return jax.lax.scan(body, state, jnp.arange(3))
"""
    hits = _findings(src, "repro.chain.simlax", "host-coercion")
    assert hits and "float(" in hits[0].message


def test_traced_control_flow_taint_stops_at_static_attrs():
    """``if`` over a value computed from a traced param is a finding;
    ``if`` over its .shape/.ndim (static at trace time) is not."""
    bad = """
import jax
import jax.numpy as jnp

def body(state, t):
    m = jnp.sum(state)
    if m > 0:
        state = state + 1
    return state, None

def run(state):
    return jax.lax.scan(body, state, jnp.arange(3))
"""
    good = bad.replace("m = jnp.sum(state)", "m = state.ndim")
    assert _findings(bad, "repro.chain.simlax", "traced-control-flow")
    assert not _findings(good, "repro.chain.simlax", "traced-control-flow")


def test_suppression_comment_and_exit_codes(tmp_path, capsys):
    bad = """\
import jax
import jax.numpy as jnp

def body(state, t):
    idx = jnp.nonzero(state > 0)
    return state, idx

def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
"""
    hits = jaxlint.lint_source(bad, "<t>", "repro.chain.simlax")
    assert any(f.rule == "nonzero-size" and not f.suppressed for f in hits)
    sup = bad.replace("state > 0)", "state > 0)  # jaxlint: ignore[nonzero-size]")
    hits = jaxlint.lint_source(sup, "<t>", "repro.chain.simlax")
    assert hits and all(f.suppressed for f in hits)
    # the wrong rule name in the comment must NOT suppress
    wrong = bad.replace("state > 0)", "state > 0)  # jaxlint: ignore[fp16-wire]")
    hits = jaxlint.lint_source(wrong, "<t>", "repro.chain.simlax")
    assert any(not f.suppressed for f in hits)


def test_main_json_output_and_failure_exit(tmp_path, capsys):
    bad_file = tmp_path / "snippet.py"
    # tmp files resolve to no known module: use a wire-module rule that
    # fires on path-independent compression code? No — fp16-wire scopes by
    # module too, so assert the clean-exit path on an out-of-scope file
    bad_file.write_text("import numpy as np\nx = np.float16(1.0)\n")
    out_json = tmp_path / "findings.json"
    assert jaxlint.main([str(bad_file), "--json", str(out_json)]) == 0
    assert json.loads(out_json.read_text()) == []
    summary = capsys.readouterr().out
    assert "jaxlint,summary,findings=0" in summary


def test_parse_error_is_a_finding_not_a_crash():
    hits = jaxlint.lint_source("def broken(:\n", "<t>", "repro.chain.simlax")
    assert hits and hits[0].rule == "parse-error"


def test_no_jax_import_discipline():
    """jaxlint must be importable (and must lint) without jax present —
    same discipline as tools/docs_check.py, so the CI job stays fast and
    dependency-free."""
    code = (
        "import sys; sys.path.insert(0, 'tools')\n"
        "import jaxlint\n"
        "jaxlint.lint_source('x = 1', '<t>', 'repro.chain.simlax')\n"
        "assert 'jax' not in sys.modules, 'jaxlint imported jax'\n"
        "print('clean')\n"
    )
    res = subprocess.run([sys.executable, "-c", code], cwd=REPO,
                         capture_output=True, text=True, timeout=120)
    assert res.returncode == 0, res.stderr
    assert "clean" in res.stdout
