import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_python(code: str, host_devices: int = 0, timeout: int = 560):
    """Run a snippet in a fresh interpreter (multi-device tests must set
    XLA_FLAGS before jax first init; the pytest process sees 1 device)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if host_devices:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={host_devices}"
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.fixture
def subprocess_runner():
    return run_python
