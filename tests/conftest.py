import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_python(code: str, host_devices: int = 0, timeout: int = 560):
    """Run a snippet in a fresh interpreter (multi-device tests must set
    XLA_FLAGS before jax first init; the pytest process sees 1 device)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if host_devices:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={host_devices}"
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.fixture
def subprocess_runner():
    return run_python


def pytest_addoption(parser):
    parser.addoption(
        "--jax-debug-nans", action="store_true", default=False,
        help="run with jax_debug_nans: a NaN produced inside a jitted "
        "computation raises at the producing op instead of propagating "
        "into a downstream assertion (slower — opt-in debugging aid, "
        "not part of tier-1)")


@pytest.fixture(scope="session", autouse=True)
def _jax_debug_nans_flag(request):
    if not request.config.getoption("--jax-debug-nans"):
        yield
        return
    import jax
    jax.config.update("jax_debug_nans", True)
    try:
        yield
    finally:
        jax.config.update("jax_debug_nans", False)


@pytest.fixture
def check_tracer_leaks():
    """Wrap a test body in jax.checking_leaks(): a tracer escaping its
    trace (e.g. a scan carry captured into a closure or module global —
    the bug class tools/jaxlint.py lints for statically) fails the test
    at the leak site instead of surfacing later as an opaque
    UnexpectedTracerError. Applied to the engine-parity suite, which
    exercises every delivery engine's full trace path."""
    import jax
    with jax.checking_leaks():
        yield
