"""Reputation impls (paper §IV-D1): decrease-only, floor 0, ties punished."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.reputation import IMPL1, IMPL2, ReputationImpl, get, register


def test_registry():
    assert get("impl1").penalty == pytest.approx(0.01)
    assert get("impl1").buffer_size == 5
    assert get("impl2").penalty == pytest.approx(0.05)
    assert get("impl2").buffer_size == 10
    with pytest.raises(KeyError):
        get("nope")


def test_lowest_accuracy_sender_punished():
    row = jnp.ones((5,))
    senders = jnp.asarray([1, 2, 3])
    accs = jnp.asarray([0.9, 0.2, 0.8])
    new = IMPL1.update_row(row, senders, accs)
    np.testing.assert_allclose(new, [1.0, 1.0, 0.99, 1.0, 1.0], atol=1e-6)


def test_ties_all_punished():
    row = jnp.ones((4,))
    new = IMPL2.update_row(row, jnp.asarray([0, 1, 2]),
                           jnp.asarray([0.3, 0.3, 0.9]))
    np.testing.assert_allclose(new, [0.95, 0.95, 1.0, 1.0], atol=1e-6)


def test_reputation_never_increases_and_floors_at_zero():
    impl = ReputationImpl("fast", penalty=0.3, buffer_size=2)
    row = jnp.ones((2,))
    for _ in range(10):
        prev = row
        row = impl.update_row(row, jnp.asarray([0]), jnp.asarray([0.1]))
        assert bool(jnp.all(row <= prev + 1e-9))
    assert float(row[0]) == pytest.approx(0.0)
    assert float(row[1]) == pytest.approx(1.0)


def test_custom_impl_pluggable():
    mine = register(ReputationImpl("custom-x", penalty=0.2, buffer_size=3))
    assert get("custom-x") is mine
