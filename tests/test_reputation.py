"""Reputation impls (paper §IV-D1): decrease-only, floor 0, ties punished."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.reputation import IMPL1, IMPL2, ReputationImpl, get, register


def test_registry():
    assert get("impl1").penalty == pytest.approx(0.01)
    assert get("impl1").buffer_size == 5
    assert get("impl2").penalty == pytest.approx(0.05)
    assert get("impl2").buffer_size == 10
    with pytest.raises(KeyError):
        get("nope")


def test_lowest_accuracy_sender_punished():
    row = jnp.ones((5,))
    senders = jnp.asarray([1, 2, 3])
    accs = jnp.asarray([0.9, 0.2, 0.8])
    new = IMPL1.update_row(row, senders, accs)
    np.testing.assert_allclose(new, [1.0, 1.0, 0.99, 1.0, 1.0], atol=1e-6)


def test_ties_all_punished():
    row = jnp.ones((4,))
    new = IMPL2.update_row(row, jnp.asarray([0, 1, 2]),
                           jnp.asarray([0.3, 0.3, 0.9]))
    np.testing.assert_allclose(new, [0.95, 0.95, 1.0, 1.0], atol=1e-6)


def test_reputation_never_increases_and_floors_at_zero():
    impl = ReputationImpl("fast", penalty=0.3, buffer_size=2)
    row = jnp.ones((2,))
    for _ in range(10):
        prev = row
        row = impl.update_row(row, jnp.asarray([0]), jnp.asarray([0.1]))
        assert bool(jnp.all(row <= prev + 1e-9))
    assert float(row[0]) == pytest.approx(0.0)
    assert float(row[1]) == pytest.approx(1.0)


def test_custom_impl_pluggable():
    mine = register(ReputationImpl("custom-x", penalty=0.2, buffer_size=3))
    assert get("custom-x") is mine


# ------------------------------- direct update_row coverage (edge semantics)
def test_empty_buffer_round_is_noop():
    """A FedAvg round that delivered nothing (K = 0) must punish nobody —
    the row passes through unchanged (and jnp-typed)."""
    row = jnp.asarray([1.0, 0.4, 0.0])
    out = IMPL2.update_row(row, jnp.zeros((0,), jnp.int32),
                           jnp.zeros((0,), jnp.float32))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(row))
    # also under jit (static empty shape branches at trace time)
    import jax
    out_j = jax.jit(IMPL2.update_row)(row, jnp.zeros((0,), jnp.int32),
                                      jnp.zeros((0,), jnp.float32))
    np.testing.assert_array_equal(np.asarray(out_j), np.asarray(row))


def test_all_tied_worst_senders_punished_and_floor_clamped():
    """Three senders tied at the worst accuracy all lose penalty; a row
    already at the floor clamps there instead of going negative."""
    impl = ReputationImpl("clampy", penalty=0.4, buffer_size=3)
    row = jnp.asarray([0.5, 0.3, 0.9, 1.0])
    out = impl.update_row(row, jnp.asarray([0, 1, 2]),
                          jnp.asarray([0.2, 0.2, 0.2]))
    # all tied at worst: 0.5-0.4, 0.3-0.4 floored at 0, 0.9-0.4
    np.testing.assert_allclose(np.asarray(out), [0.1, 0.0, 0.5, 1.0],
                               atol=1e-6)
    # a second identical round floors the first two at exactly 0
    out2 = impl.update_row(out, jnp.asarray([0, 1, 2]),
                           jnp.asarray([0.2, 0.2, 0.2]))
    np.testing.assert_allclose(np.asarray(out2), [0.0, 0.0, 0.1, 1.0],
                               atol=1e-6)


def test_update_row_is_jit_traceable_inside_scan():
    """The in-graph form the lax engine relies on: update_row under jit with
    traced sender ids/accuracies."""
    import jax

    def body(row, _):
        return IMPL1.update_row(row, jnp.asarray([1, 2]),
                                jnp.asarray([0.1, 0.9])), None

    row, _ = jax.lax.scan(body, jnp.ones((4,)), None, length=5)
    np.testing.assert_allclose(np.asarray(row), [1.0, 0.95, 1.0, 1.0],
                               atol=1e-6)
