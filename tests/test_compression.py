"""core.compression correctness + bitwise parity against the Pallas
kernels/quantize pair.

Three families, matching the latent bugs they pin:
* scale underflow — tiny-magnitude leaves must round-trip (the old fp16
  wire scales flushed anything under ~6e-8 to zero, dequantizing nonzero
  q to zeros; scales now ship as bf16);
* edge-case shapes — zero-size, 0-d, and odd non-multiple-of-block last
  dims have DEFINED behavior (empty -> empty, scalar -> 1-block);
* reference <-> kernel parity — q AND scales bitwise across sizes and
  dtypes, including the ops.py block-rows fallback path. compression
  stores bf16 scales, the kernel fp32; the contract is that the kernel's
  fp32 value IS the bf16 grid point, so the comparison is exact.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression
from repro.kernels.quantize import ops
from repro.kernels.quantize.quantize import quantize
from repro.kernels.quantize.ref import dequantize_ref, quantize_ref


# --------------------------------------------------------- scale underflow
@pytest.mark.parametrize("mag", [1e-5, 1e-6, 1e-7, 6e-8, 1e-10, 1e-12])
def test_tiny_leaf_roundtrip_not_zeroed(mag):
    x = jnp.asarray([mag, -mag, mag / 2, 0.0, mag], jnp.float32)
    q, s = compression.quantize_last_axis(x)
    dq = compression.dequantize_last_axis(q, s, x.shape, x.dtype)
    # the old bug: q nonzero but scale underflows to fp16 zero -> dq == 0
    assert float(s.astype(jnp.float32).min()) > 0.0
    assert float(jnp.max(jnp.abs(dq))) > 0.0
    # bf16 scales keep tiny leaves at ordinary quantization accuracy: one
    # scale step of error, plus bf16 rounding slack on the scale itself
    bound = 1.1 * float(s.astype(jnp.float32).max())
    np.testing.assert_allclose(np.asarray(dq), np.asarray(x), atol=bound)
    if mag / 127.0 > compression.SCALE_EPS:  # above the clamp floor the
        assert bound < 0.02 * mag            # bound is tight: ~1% relative


def test_zero_block_dequantizes_to_exact_zero():
    x = jnp.zeros((compression.BLOCK,), jnp.float32)
    q, s = compression.quantize_tensor(x)
    assert float(s.astype(jnp.float32)[0]) > 0.0  # clamp survives bf16 cast
    assert int(jnp.max(jnp.abs(q))) == 0
    dq = compression.dequantize_tensor(q, s, x.shape, x.dtype)
    assert bool(jnp.all(dq == 0.0))


def test_quantize_grid_consistency():
    """q is computed against the SAME bf16-rounded scale the receiver
    multiplies by, so round-trip error stays under one scale step (half a
    step of rounding + at most a quarter step of clip from the bf16
    round-to-nearest undershoot) at every magnitude."""
    key = jax.random.PRNGKey(7)
    for mag in (1.0, 1e-3, 1e-5, 3e-6, 1e-8):
        x = jax.random.normal(key, (512,)) * mag
        q, s = compression.quantize_last_axis(x)
        dq = compression.dequantize_last_axis(q, s, x.shape, x.dtype)
        step = float(s.astype(jnp.float32).max())
        assert float(jnp.max(jnp.abs(dq - x))) <= 0.76 * step


# --------------------------------------------------------- edge-case shapes
def test_zero_size_leaves_roundtrip_empty():
    for shape in [(0,), (3, 0), (0, 5), (2, 0, 4)]:
        x = jnp.zeros(shape, jnp.float32)
        q, s = compression.quantize_last_axis(x)
        assert q.size == 0 and s.size == 0
        dq = compression.dequantize_last_axis(q, s, shape, x.dtype)
        assert dq.shape == shape and dq.dtype == x.dtype


def test_scalar_leaf_is_one_block():
    x = jnp.float32(3.5)
    q, s = compression.quantize_last_axis(x)
    assert q.shape == (1, 1) and s.shape == (1,)
    dq = compression.dequantize_last_axis(q, s, x.shape, x.dtype)
    assert dq.shape == ()
    np.testing.assert_allclose(float(dq), 3.5, rtol=1e-2)


@pytest.mark.parametrize("last", [1, 7, 255, 257, 300, 1000])
def test_odd_last_dims_roundtrip(last):
    x = jax.random.normal(jax.random.PRNGKey(last), (3, last))
    q, s = compression.quantize_last_axis(x)
    nblocks = -(-last // min(compression.BLOCK, last))
    assert s.shape == (3, nblocks)
    dq = compression.dequantize_last_axis(q, s, x.shape, x.dtype)
    rel = float(jnp.max(jnp.abs(dq - x)) / jnp.max(jnp.abs(x)))
    assert rel < 0.01


def test_quantize_tree_mixed_edge_leaves():
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 300)),
            "scalar": jnp.float32(2.0),
            "empty": jnp.zeros((0, 8), jnp.float32),
            "tiny": jnp.full((9,), 1e-6, jnp.float32)}
    rt = compression.roundtrip_tree(tree)
    assert jax.tree.structure(rt) == jax.tree.structure(tree)
    for k in tree:
        assert rt[k].shape == tree[k].shape and rt[k].dtype == tree[k].dtype
    assert float(jnp.max(jnp.abs(rt["tiny"] - tree["tiny"]))) < 1e-7


def test_stacked_equals_per_node_bitwise():
    """The heap<->lax parity mechanism: quantizing a stacked (N, ...) pytree
    equals quantizing each node's slice independently, bit for bit, because
    blocks never cross the last axis."""
    key = jax.random.PRNGKey(3)
    stacked = {"w": jax.random.normal(key, (6, 5, 37)),
               "b": jax.random.normal(jax.random.fold_in(key, 1), (6, 13))}
    rt = compression.roundtrip_tree(stacked)
    for i in range(6):
        per = compression.roundtrip_tree(
            jax.tree.map(lambda a, _i=i: a[_i], stacked))
        for k in stacked:
            assert bool(jnp.all(rt[k][i] == per[k]))


# --------------------------------------------- reference <-> kernel parity
@pytest.mark.parametrize("size", [256, 2048, 65536, 300, 4096 + 17])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_compression_matches_kernel_bitwise(size, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(size), (size,)) * 2.0)
    # exercise tiny magnitudes in half the payload to cover the clamp path
    x = x * jnp.where(jnp.arange(size) % 2 == 0, 1.0, 1e-6)
    x = x.astype(dtype)
    qc, sc = compression.quantize_tensor(x)
    qk, sk, n = ops.quantize_flat(x)
    assert n == size
    assert qc.shape == qk.shape
    assert bool(jnp.all(qc == qk))
    # kernel fp32 scales must BE the bf16 grid points compression ships
    assert bool(jnp.all(sc.astype(jnp.float32) == sk[:, 0]))
    assert bool(jnp.all(sc == sk[:, 0].astype(jnp.bfloat16)))
    dc = compression.dequantize_tensor(qc, sc, x.shape, jnp.float32)
    dk = ops.dequantize_flat(qk, sk, n)
    assert bool(jnp.all(dc == dk))


@pytest.mark.parametrize("rows", [1, 2, 3, 96, 768])
def test_ops_block_rows_fallback_matches_ref(rows):
    """rows not divisible by 256 exercises the halving fallback in ops.py
    (and rows=3 the final br=1 path)."""
    size = rows * ops.BLOCK_COLS - (17 if rows > 1 else 0)
    x = jax.random.normal(jax.random.PRNGKey(rows), (size,))
    qk, sk, n = ops.quantize_flat(x)
    qc, sc = compression.quantize_tensor(x)
    assert bool(jnp.all(qc == qk))
    assert bool(jnp.all(sc.astype(jnp.float32) == sk[:, 0]))


@pytest.mark.parametrize("mag", [1.0, 1e-6])
def test_kernel_matches_ref_oracle_tiny(mag):
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 128)) * mag
    q, s = quantize(x, block_rows=64, interpret=True)
    qr, sr = quantize_ref(x)
    assert bool(jnp.all(q == qr))
    assert bool(jnp.all(s == sr))
    assert bool(jnp.all(dequantize_ref(q, s) == dequantize_ref(qr, sr)))


# ------------------------------------------------------------- wire bytes
def test_payload_bytes_model():
    tree = {"w": jnp.zeros((4, 512), jnp.float32),
            "b": jnp.zeros((10,), jnp.float32)}
    fp32 = compression.payload_bytes(tree, None)
    assert fp32 == (4 * 512 + 10) * 4
    int8 = compression.payload_bytes(tree, "int8")
    # w: 4 rows x 2 blocks x (256 q bytes + 2 scale bytes); b: 1 block of 10
    assert int8 == 4 * 2 * (256 + 2) + 1 * (10 + 2)
    assert int8 < 0.3 * fp32
    # spec leaves (shape/dtype carriers) work the same as arrays
    spec = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
    assert compression.payload_bytes(spec, "int8") == int8
    with pytest.raises(ValueError):
        compression.payload_bytes(tree, "fp8")


def test_payload_bytes_edge_leaves():
    assert compression.leaf_wire_bytes((), jnp.float32, "int8") == 1 + 2
    assert compression.leaf_wire_bytes((3, 0), jnp.float32, "int8") == 0
    assert compression.leaf_wire_bytes((0,), jnp.float32, None) == 0
