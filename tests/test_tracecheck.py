"""``core/tracecheck.py`` unit contract + the simlax retrace-regression pin.

The trace counter's promise: wrap BEFORE jit, and the wrapper's call count
is the trace count — same-shape calls reuse the compiled executable, a
shape change costs exactly one more trace. The simlax half pins the
``_SCAN_CACHE`` behavior the counter guards in production: two simulators
built over the SAME scenario/topology/spec objects with equal config share
one compiled scan (one trace total across both runs), a batch-size change
on a shared cache entry retraces exactly once more, and a config change is
a separate cache entry rather than a silent retrace.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.chain import scenarios, simlax
from repro.chain.attacks import BatchedFederationSpec, FederationSpec
from repro.core import topology as T
from repro.core import tracecheck
from repro.core.reputation import IMPL2


# ------------------------------------------------------------------ unit layer
def test_counts_traces_not_calls():
    counted = tracecheck.count_traces(lambda x: x * 2, name="t.calls")
    f = jax.jit(counted)
    for _ in range(3):
        f(jnp.ones(4))
    assert counted.counter.count == 1
    f(jnp.ones(8))  # shape change: one more trace, then cached again
    f(jnp.ones(8))
    assert counted.counter.count == 2


def test_assert_max_traces_raises_at_the_retrace():
    guarded = jax.jit(tracecheck.assert_max_traces(
        lambda x: x + 1, n=1, name="t.guard"))
    guarded(jnp.ones(3))
    guarded(jnp.ones(3))  # cache hit: no second trace
    with pytest.raises(RuntimeError, match="t.guard.*traced 2"):
        guarded(jnp.ones(5))


def test_bare_decorator_form():
    @tracecheck.assert_max_traces
    def f(x):
        return x - 1

    g = jax.jit(f)
    g(jnp.ones(2))
    with pytest.raises(RuntimeError, match="traced 2"):
        g(jnp.ones(3))


def test_registry_lookup_and_reset():
    counted = tracecheck.count_traces(lambda x: x, name="t.registry")
    assert tracecheck.get_counter("t.registry") is counted.counter
    jax.jit(counted)(jnp.ones(2))
    assert counted.counter.count == 1
    counted.counter.reset()
    assert tracecheck.get_counter("t.registry").count == 0
    # last registration under a name wins — audits never read a dead counter
    counted2 = tracecheck.count_traces(lambda x: x, name="t.registry")
    assert tracecheck.get_counter("t.registry") is counted2.counter


# ---------------------------------------------------- simlax retrace regression
def _cfg(ticks=8, **kw):
    kw.setdefault("seed", 0)
    kw.setdefault("train_interval", (4, 4))
    kw.setdefault("latency", 1)
    kw.setdefault("ttl", 2)
    kw.setdefault("delivery", "compact")
    return simlax.SimLaxConfig(ticks=ticks, **kw)


def _shared_fixture(n=8):
    topo = T.kregular(n, 2)
    sc = scenarios.toy_scenario(n, dim=8)
    spec = FederationSpec.build(
        n, initial_countdown=[1 + (3 * i) % 4 for i in range(n)])
    return sc, topo, spec


def test_same_config_simulators_share_one_trace():
    """The satellite contract: constructing LaxSimulator twice with
    identical static config (same scenario/topology/spec OBJECTS — the
    cache binds train/eval fns by identity) compiles the scan once; both
    runs execute the same executable."""
    simlax.clear_scan_cache()
    sc, topo, spec = _shared_fixture()
    sim_a = simlax.LaxSimulator(sc, topo, spec, IMPL2, _cfg())
    sim_b = simlax.LaxSimulator(sc, topo, spec, IMPL2, _cfg())
    assert sim_b.trace_counter is sim_a.trace_counter
    res_a = sim_a.run()
    res_b = sim_b.run()
    assert sim_a.trace_counter.count == 1
    # sharing a compiled scan must not perturb results: bitwise equal runs
    np.testing.assert_array_equal(res_a.acc_history, res_b.acc_history)


def test_batch_size_change_retraces_exactly_once():
    """Honest batched specs of different batch size share one cache entry
    (the static key ignores batch size — it is a shape, not a config), so
    a B=3 run after a B=2 run is the canonical shape-changing call: jit
    must retrace exactly once more, not once per member."""
    simlax.clear_scan_cache()
    sc, topo, spec = _shared_fixture()
    sim2 = simlax.LaxSimulator(
        sc, topo, BatchedFederationSpec.build([spec, spec], [0, 1]),
        IMPL2, _cfg())
    sim2.run()
    assert sim2.trace_counter.count == 1
    sim3 = simlax.LaxSimulator(
        sc, topo, BatchedFederationSpec.build([spec, spec, spec], [0, 1, 2]),
        IMPL2, _cfg())
    assert sim3.trace_counter is sim2.trace_counter
    sim3.run()
    assert sim3.trace_counter.count == 2
    sim3.run()  # same shapes again: cache hit, no third trace
    assert sim3.trace_counter.count == 2


def test_config_change_is_a_new_cache_entry_not_a_retrace():
    simlax.clear_scan_cache()
    sc, topo, spec = _shared_fixture()
    sim_a = simlax.LaxSimulator(sc, topo, spec, IMPL2, _cfg(ticks=8))
    sim_c = simlax.LaxSimulator(sc, topo, spec, IMPL2, _cfg(ticks=10))
    assert sim_c.trace_counter is not sim_a.trace_counter
    sim_a.run()
    sim_c.run()
    assert sim_a.trace_counter.count == 1
    assert sim_c.trace_counter.count == 1


def test_fresh_scenario_object_is_a_deliberate_cache_miss():
    """A re-built scenario carries new bound train/eval fns: identity-keyed
    caching treats it as a different federation (its data really could
    differ), so the second simulator gets its own counter rather than
    silently reusing a compile against foreign closures."""
    simlax.clear_scan_cache()
    n = 8
    topo = T.kregular(n, 2)
    spec = FederationSpec.build(
        n, initial_countdown=[1 + (3 * i) % 4 for i in range(n)])
    sim_a = simlax.LaxSimulator(
        scenarios.toy_scenario(n, dim=8), topo, spec, IMPL2, _cfg())
    sim_b = simlax.LaxSimulator(
        scenarios.toy_scenario(n, dim=8), topo, spec, IMPL2, _cfg())
    assert sim_b.trace_counter is not sim_a.trace_counter
