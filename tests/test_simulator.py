"""Network simulator behaviour: ttl-bounded partial consensus, expiry,
malicious reputation dynamics, stragglers, node failure (paper §III-B, §VI)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.chain.network import (SimConfig, Simulator, fully_connected,
                                 mean_reputation, ring)
from repro.chain.node import DFLNode
from repro.core.reputation import IMPL1, IMPL2

D = 8  # toy model dim


def _mk_node(name, seed, acc=0.8, malicious=False, rep=IMPL1, ttl=2,
             expire=50.0):
    params = {"w": jnp.full((D,), float(seed))}

    def train_fn(p, _k):
        return jax.tree.map(lambda x: x + 0.1, p), {}

    def eval_fn(p):
        return acc

    return DFLNode(name=name, model_structure="toy", params=params,
                   train_fn=train_fn, eval_fn=eval_fn, rep_impl=rep, ttl=ttl,
                   malicious=malicious, expire_after=expire,
                   rng=jax.random.PRNGKey(seed))


def test_ttl_bounds_partial_consensus_range():
    """On a 6-ring with ttl=1, a node's transaction reaches only direct
    neighbors — the defining property of partial consensus (§III-B)."""
    names = [f"n{i}" for i in range(6)]
    nodes = [_mk_node(n, i, ttl=1) for i, n in enumerate(names)]
    sim = Simulator(nodes, ring(names), lambda p: 0.5,
                    SimConfig(ticks=80, seed=0, record_every=100))
    sim.run()
    # n0's transactions were seen by n1 and n5 (its buffer senders),
    # never by n3 (distance 3)
    addr0 = nodes[0].info.address
    assert addr0 in sim.nodes["n1"].reputation or any(
        b.sender == addr0 for b in sim.nodes["n1"].buffer)
    seen_by_n3 = addr0 in sim.nodes["n3"].reputation or any(
        b.sender == addr0 for b in sim.nodes["n3"].buffer)
    assert not seen_by_n3


def test_ttl2_reaches_distance_two():
    names = [f"n{i}" for i in range(6)]
    nodes = [_mk_node(n, i, ttl=2) for i, n in enumerate(names)]
    sim = Simulator(nodes, ring(names), lambda p: 0.5,
                    SimConfig(ticks=80, seed=0, record_every=100))
    sim.run()
    addr0 = nodes[0].info.address
    n2_saw = addr0 in sim.nodes["n2"].reputation or any(
        b.sender == addr0 for b in sim.nodes["n2"].buffer)
    assert n2_saw


def test_expired_transactions_dropped():
    names = ["a", "b"]
    nodes = [_mk_node(n, i, expire=0.0) for i, n in enumerate(names)]
    sim = Simulator(nodes, fully_connected(names), lambda p: 0.5,
                    SimConfig(ticks=60, seed=0, latency=(2, 4),
                              record_every=100))
    sim.run()
    assert sim.stats["tx_delivered"] == 0
    assert sim.stats["tx_dropped_expired"] > 0


def test_malicious_node_reputation_drops():
    """1-of-5 malicious (random model) loses reputation fastest (Fig 15)."""
    names = [f"n{i}" for i in range(5)]
    nodes = []
    for i, n in enumerate(names):
        params = {"w": jnp.full((D,), 1.0)}

        def train_fn(p, _k):
            return p, {}

        # receivers score received models by closeness to their own weights:
        # random (malicious) models land far away -> low accuracy
        def mk_eval(own=params):
            def eval_fn(recv):
                d = float(jnp.mean(jnp.abs(recv["w"] - own["w"])))
                return max(0.0, 1.0 - d)
            return eval_fn

        node = DFLNode(name=n, model_structure="toy", params=params,
                       train_fn=train_fn, eval_fn=lambda p: 0.9,
                       rep_impl=IMPL2, ttl=2, malicious=(i == 0),
                       rng=jax.random.PRNGKey(i))
        node.eval_fn = mk_eval()
        nodes.append(node)
    sim = Simulator(nodes, fully_connected(names), lambda p: 0.5,
                    SimConfig(ticks=400, seed=3, record_every=100))
    sim.run()
    rep_bad = mean_reputation(nodes[1:], nodes[0].info.address)
    rep_good = np.mean([
        mean_reputation([m for m in nodes if m is not n], n.info.address)
        for n in nodes[1:]])
    assert rep_bad < rep_good, (rep_bad, rep_good)


def test_node_failure_is_survivable():
    names = [f"n{i}" for i in range(4)]
    nodes = [_mk_node(n, i) for i, n in enumerate(names)]
    sim = Simulator(nodes, fully_connected(names), lambda p: 0.5,
                    SimConfig(ticks=120, seed=1, record_every=100))
    sim.kill_node("n3")
    sim.run()
    assert sim.stats["tx_delivered"] > 0
    assert all(len(sim.nodes[n].accuracy_history) > 0 for n in names[:3])
    assert len(sim.nodes["n3"].accuracy_history) == 0


def test_straggler_sends_fewer_transactions():
    names = [f"n{i}" for i in range(3)]
    nodes = [_mk_node(n, i) for i, n in enumerate(names)]
    sim = Simulator(nodes, fully_connected(names), lambda p: 0.5,
                    SimConfig(ticks=200, seed=2, record_every=100))
    sim.set_straggler("n0", 6)
    sim.run()
    sent = {n: sim.nodes[n].ledger.contribution_count() +
            len(sim.nodes[n].pending_tx) for n in names}
    assert sent["n0"] < sent["n1"] and sent["n0"] < sent["n2"]


def test_fedavg_triggers_at_buffer_size():
    names = [f"n{i}" for i in range(4)]
    nodes = [_mk_node(n, i, rep=IMPL1) for i, n in enumerate(names)]  # buffer 5
    sim = Simulator(nodes, fully_connected(names), lambda p: 0.5,
                    SimConfig(ticks=150, seed=0, record_every=100))
    sim.run()
    assert sim.stats["fedavg_rounds"] > 0
    for n in nodes:
        assert len(n.buffer) < IMPL1.buffer_size
