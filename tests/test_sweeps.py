"""Sweep orchestrator (`repro.chain.sweeps`): grid expansion, shape-
compatible batch planning, end-to-end outcomes + frontier tables, and the
docs-check reference linter that guards docs/ against code drift."""
import importlib.util
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.chain import simlax, sweeps

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_expand_grid_is_full_product():
    cells = sweeps.expand_grid(sizes=[8, 16], attacks=[None, "gaussian"],
                               topology_seeds=[0, 1], seeds=[0, 1, 2])
    assert len(cells) == 2 * 2 * 2 * 3
    assert len(set(cells)) == len(cells)       # frozen dataclass, no dups
    honest = [c for c in cells if c.attack is None]
    assert all(c.num_malicious() == 0 for c in honest)
    attacked = [c for c in cells if c.attack == "gaussian"]
    # malicious_frac floors at one attacker
    assert all(c.num_malicious() == max(1, int(0.125 * c.size))
               for c in attacked)


def test_plan_batches_groups_by_static_shape():
    cells = sweeps.expand_grid(sizes=[8, 16], attacks=[None, "gaussian"],
                               topology_seeds=[0, 1], seeds=[0, 1])
    batches = sweeps.plan_batches(cells)
    # one batch per (size, topology_seed): 2 sizes x 2 topo seeds
    assert len(batches) == 4
    for batch in batches:
        keys = {c.batch_key() for c in batch}
        assert len(keys) == 1                  # shape-compatible members
        assert len(batch) == 4                 # attacks x seeds ride along
    # cells are preserved exactly once across batches
    flat = [c for b in batches for c in b]
    assert sorted(map(hash, flat)) == sorted(map(hash, cells))


def test_plan_batches_max_batch_splits():
    cells = sweeps.expand_grid(sizes=[8], attacks=[None, "gaussian"],
                               seeds=[0, 1, 2])
    batches = sweeps.plan_batches(cells, max_batch=4)
    assert [len(b) for b in batches] == [4, 2]
    assert sweeps.plan_batches(cells, max_batch=0) == \
        sweeps.plan_batches(cells)


def test_run_sweep_end_to_end_and_frontier_tables():
    cells = sweeps.expand_grid(sizes=[12], attacks=[None, "gaussian"],
                               seeds=[0, 1])
    cfg = simlax.SimLaxConfig(ticks=30, train_interval=(6, 8), ttl=2,
                              record_every=6)
    outcomes = sweeps.run_sweep(cells, cfg=cfg, target_acc=0.4)
    assert len(outcomes) == len(cells)
    for o in outcomes:
        row = o.row()
        assert 0.0 <= row["final_honest_acc"] <= 1.0
        assert row["time_to_acc"] is None or row["time_to_acc"] < 30
        if o.cell.attack is None:
            assert np.isnan(o.attacker_reputation)
            assert row["attack"] == "none"
    tables = sweeps.frontier_tables(outcomes, target_acc=0.4)
    assert {r["attack"] for r in tables["time_to_accuracy"]} == \
        {"none", "gaussian"}
    for r in tables["time_to_accuracy"]:
        assert r["replicates"] == 2
        assert 0.0 <= r["reached_frac"] <= 1.0
        if r["reached_frac"] == 0:
            assert r["median_ticks_to_acc"] is None
    for r in tables["accuracy_under_attack"]:
        assert 0.0 <= r["mean_final_honest_acc"] <= 1.0
        if r["attack"] == "none":
            assert r["mean_attacker_reputation"] is None


def test_run_sweep_outcomes_match_single_runs():
    """The orchestrator adds no simulation semantics: a swept cell's
    metrics equal those of a hand-built single run of the same cell."""
    from repro.chain.attacks import BatchedFederationSpec  # noqa: F401
    from repro.core import topology as T
    from repro.core.reputation import IMPL2
    from repro.chain import scenarios

    cells = sweeps.expand_grid(sizes=[10], attacks=["signflip"],
                               seeds=[7])
    cfg = simlax.SimLaxConfig(ticks=24, train_interval=(6, 8), ttl=2,
                              record_every=6)
    (outcome,) = sweeps.run_sweep(cells, cfg=cfg, target_acc=0.4)
    cell = cells[0]
    sc = scenarios.toy_scenario(10)
    topo = T.kregular(10, 2)
    res = simlax.LaxSimulator(
        sc, topo, cell.spec(), IMPL2,
        simlax.SimLaxConfig(ticks=24, train_interval=(6, 8), ttl=2,
                            record_every=6, seed=7)).run()
    mal = range(cell.num_malicious())
    honest = [i for i in range(10) if i not in mal]
    assert outcome.final_honest_acc == pytest.approx(
        float(res.acc_history[-1][honest].mean()))
    assert outcome.attacker_reputation == pytest.approx(
        float(np.mean([res.mean_reputation(i) for i in mal])))


# ------------------------------------------------------------- docs-check

def _load_docs_check():
    spec = importlib.util.spec_from_file_location(
        "docs_check", os.path.join(REPO, "tools", "docs_check.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docs_check_resolves_good_and_flags_bad():
    dc = _load_docs_check()
    assert dc.check_dotted("repro.chain.simlax.LaxSimulator") is None
    assert dc.check_dotted("repro.chain.attacks.BatchedFederationSpec") \
        is None
    assert dc.check_dotted("repro.core.topology.batch_budgets") is None
    assert dc.check_dotted("benchmarks.bench_sweep") is None
    assert dc.check_dotted("repro.chain.simlax.NoSuchThing") is not None
    assert dc.check_dotted("repro.no_such_module.x") is not None
    assert dc.check_path("benchmarks/check_regress.py") is None
    assert dc.check_path("repro/compat.py") is None          # under src/
    assert dc.check_path("docs/no_such_page.md") is not None


def test_docs_check_flags_broken_page(tmp_path):
    dc = _load_docs_check()
    page = tmp_path / "bad.md"
    page.write_text("see `repro.chain.simlax.Gone` and "
                    "[link](missing_page.md)\n")
    fails = dc.check_file(str(page))
    assert {ref for ref, _ in fails} == {"repro.chain.simlax.Gone",
                                         "missing_page.md"}


def test_docs_check_passes_on_repo_docs():
    """The committed docs/README must be reference-clean (same invocation
    as the CI docs-check job)."""
    proc = subprocess.run([sys.executable,
                           os.path.join(REPO, "tools", "docs_check.py")],
                          capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stdout + proc.stderr
