"""Dynamic membership (MembershipSchedule): join/leave/rejoin semantics on
BOTH simulator engines, and the churn parity contract (docs/SCALING.md) —
identical event streams heap<->lax, bitwise-equal integer state across lax
delivery engines, budgets safe under any mid-run mask because they are the
all-alive worst case."""
import numpy as np
import pytest

from repro.chain import scenarios, simlax
from repro.chain.attacks import (FederationSpec, MembershipEvent,
                                 MembershipSchedule)
from repro.chain.network import mean_reputation
from repro.core import topology as T
from repro.core.reputation import IMPL2

INTERVAL = 6


def _countdown(n):
    return [3 + (7 * i) % INTERVAL for i in range(n)]


def _cfg(ticks, *, delivery="compact", ttl=2):
    return simlax.SimLaxConfig(
        ticks=ticks, train_interval=(INTERVAL, INTERVAL), latency=1, ttl=ttl,
        record_every=8, seed=0, delivery=delivery)


def _churn_schedule():
    return MembershipSchedule.build(
        [(10, (), (3,)),        # node 3 leaves
         (15, (9,), ()),        # initially-offline node 9 first-joins
         (25, (3,), ()),        # node 3 rejoins -> its reputation decays
         (30, (), (7,)),        # node 7 leaves for good
         (40, (), (3,)),
         (52, (3,), ())],       # node 3 churns a second time
        rejoin_decay=0.5, initial_offline=(9,))


# ===================================================== schedule validation
def test_membership_schedule_validation():
    with pytest.raises(ValueError, match="both join and leave"):
        MembershipEvent(tick=1, joins=(2,), leaves=(2,))
    with pytest.raises(ValueError, match="one MembershipEvent per tick"):
        MembershipSchedule(events=(MembershipEvent(3, joins=(1,)),
                                   MembershipEvent(3, leaves=(2,))))
    with pytest.raises(ValueError, match="rejoin_decay"):
        MembershipSchedule(rejoin_decay=1.5)
    ms = MembershipSchedule.build([(2, (), (1,))])
    with pytest.raises(ValueError, match=r"outside \[0, "):
        ms.validate(1)
    with pytest.raises(ValueError, match="dead; it cannot churn"):
        ms.validate(4, dead=(1,))
    # replay errors: double-leave / join-while-online
    with pytest.raises(ValueError, match="already offline"):
        MembershipSchedule.build([(2, (), (1,)), (4, (), (1,))]).validate(4)
    with pytest.raises(ValueError, match="already online"):
        MembershipSchedule.build([(2, (1,), ())]).validate(4)


def test_membership_timeline():
    ms = MembershipSchedule.build([(1, (), (0,)), (3, (0,), ())],
                                  initial_offline=(2,))
    alive, rejoin = ms.timeline(3, 5)
    np.testing.assert_array_equal(alive[:, 0], [1, 0, 0, 1, 1])
    np.testing.assert_array_equal(alive[:, 1], [1, 1, 1, 1, 1])
    np.testing.assert_array_equal(alive[:, 2], [0, 0, 0, 0, 0])
    # node 0 was online before -> its tick-3 join is a REJOIN
    np.testing.assert_array_equal(rejoin[:, 0], [0, 0, 0, 1, 0])
    assert not rejoin[:, 1].any() and not rejoin[:, 2].any()


def test_first_join_is_not_a_rejoin():
    ms = MembershipSchedule.build([(2, (1,), ())], initial_offline=(1,))
    _, rejoin = ms.timeline(3, 4)
    assert not rejoin.any()     # never online before -> no decay


# ================================================== lax engine churn parity
def test_lax_engines_churn_parity():
    """compact == sparse == dense under an identical churn event stream
    (the repo's cross-engine contract: integer state bitwise, float state
    equal up to summation order)."""
    n = 10
    sc = scenarios.toy_scenario(n, dim=8, malicious=(0,))
    topo = T.full(n)
    spec = FederationSpec.build(n, malicious=(0,),
                                initial_countdown=_countdown(n),
                                membership=_churn_schedule())
    out = {}
    for eng in ("compact", "sparse", "dense"):
        out[eng] = simlax.LaxSimulator(
            sc, topo, spec, IMPL2, _cfg(60, delivery=eng)).run()
    for s, d in (("compact", "sparse"), ("sparse", "dense")):
        s, d = out[s], out[d]
        for k in ("broadcasts", "deliveries", "fedavg_rounds",
                  "max_tick_deliveries"):
            assert s.stats[k] == d.stats[k], (k, s.stats[k], d.stats[k])
        np.testing.assert_array_equal(s.stats["broadcasts_per_node"],
                                      d.stats["broadcasts_per_node"])
        for k in ("arrive", "min_sender", "buf_cnt", "next_train"):
            np.testing.assert_array_equal(s.final_state[k],
                                          d.final_state[k], err_msg=k)
        np.testing.assert_allclose(s.reputation, d.reputation, atol=1e-6)
        np.testing.assert_allclose(s.acc_history, d.acc_history, atol=1e-5)
    assert out["compact"].stats["deliveries"] > 0


def test_churn_loses_deliveries_vs_static_membership():
    """Offline windows lose in-flight models for good: a churned run
    delivers strictly less than its all-alive twin, while budgets (the
    all-alive worst case) keep the compact scatter safe."""
    n = 10
    sc = scenarios.toy_scenario(n, dim=8)
    topo = T.full(n)
    churn = simlax.LaxSimulator(
        sc, topo,
        FederationSpec.build(n, initial_countdown=_countdown(n),
                             membership=_churn_schedule()),
        IMPL2, _cfg(60)).run()
    still = simlax.LaxSimulator(
        sc, topo, FederationSpec.build(n, initial_countdown=_countdown(n)),
        IMPL2, _cfg(60)).run()
    assert churn.stats["deliveries"] < still.stats["deliveries"]
    # budget safety under mid-run mask changes: churn can RAISE the per-tick
    # peak (frozen countdowns re-align broadcast phases on rejoin — this
    # scenario peaks at 2x the staggered no-churn run), which is exactly why
    # the work buffer keeps the all-alive worst-case width instead of
    # shrinking to the live subset; the bound itself is mask-independent
    assert churn.stats["max_tick_deliveries"] <= churn.stats["compact_budget"]
    assert churn.stats["compact_budget"] == still.stats["compact_budget"]


# ===================================================== heap <-> lax parity
def test_heap_lax_churn_parity():
    """The acceptance pin: ONE churn event stream through both engines —
    broadcast/delivery counts agree exactly, attacker payload bitwise,
    decayed-reputation views within the heap<->lax tolerance."""
    n = 10
    sc = scenarios.toy_scenario(n, dim=8, malicious=(0,))
    topo = T.full(n)
    spec = FederationSpec.build(n, malicious=(0,),
                                initial_countdown=_countdown(n),
                                membership=_churn_schedule())
    cfg = _cfg(72)
    heap = scenarios.make_heap_simulator(sc, topo, spec, IMPL2, cfg)
    heap.run()
    res = simlax.LaxSimulator(sc, topo, spec, IMPL2, cfg).run()

    assert res.stats["broadcasts"] == heap.stats["tx_sent"]
    assert res.stats["deliveries"] == heap.stats["tx_delivered"]
    assert res.stats["deliveries"] > 0
    nodes = list(heap.nodes.values())
    np.testing.assert_array_equal(
        np.asarray(nodes[0].last_broadcast["w"]), res.sent["w"][0])
    # churned node 3's column decays on both engines and the engines agree
    others = [nd for i, nd in enumerate(nodes) if i != 3]
    h3 = mean_reputation(others, nodes[3].info.address)
    l3 = res.mean_reputation(3)
    assert abs(h3 - l3) < 0.1, (h3, l3)
    assert h3 < 0.6 and l3 < 0.6        # two rejoins at decay 0.5 bite
    # node 7 left for good (no rejoin) -> no decay on its column
    h7 = mean_reputation(others, nodes[7].info.address)
    assert abs(h7 - res.mean_reputation(7)) < 0.1
    assert res.mean_reputation(7) > 0.9


# =========================================================== heap semantics
def _quiet_heap(n, ms, *, ticks=12, topo=None):
    """A heap sim where nobody ever trains — isolates membership effects."""
    sc = scenarios.toy_scenario(n, dim=4)
    spec = FederationSpec.build(n, initial_countdown=[10_000] * n,
                                membership=ms)
    cfg = _cfg(ticks)
    sim = scenarios.make_heap_simulator(sc, topo or T.full(n), spec, IMPL2,
                                        cfg)
    return sim


def test_heap_rejoin_decay_exact():
    """With no traffic (hence no punishments) the rejoin decay is the ONLY
    reputation update: every peer's view of the rejoiner lands exactly on
    clip(decay * initial, floor, initial)."""
    n = 5
    ms = MembershipSchedule.build([(2, (), (1,)), (5, (1,), ())],
                                  rejoin_decay=0.5)
    sim = _quiet_heap(n, ms)
    sim.run()
    nodes = list(sim.nodes.values())
    addr = nodes[1].info.address
    want = min(IMPL2.initial, max(IMPL2.floor, 0.5 * IMPL2.initial))
    for i, nd in enumerate(nodes):
        if i != 1:
            assert nd.reputation[addr] == pytest.approx(want)
    # first join of an initially-offline node decays nothing
    ms2 = MembershipSchedule.build([(2, (4,), ())], initial_offline=(4,),
                                   rejoin_decay=0.5)
    sim2 = _quiet_heap(n, ms2)
    sim2.run()
    addr4 = list(sim2.nodes.values())[4].info.address
    for nd in sim2.nodes.values():
        assert addr4 not in nd.reputation


def test_heap_offline_node_relays_the_flood():
    """Routing is static: a flood crosses an offline node unchanged (ttl
    decremented via an unsigned relay receipt) — nodes BEHIND it still
    receive, while the offline node itself buffers nothing and the copy it
    relayed is lost to it for good (no late delivery after rejoin)."""
    n = 5
    sc = scenarios.toy_scenario(n, dim=4)
    # a line: 0-1-2-3-4; only node 0 ever trains; node 1 offline throughout
    adj = np.zeros((n, n), bool)
    for i in range(n - 1):
        adj[i, i + 1] = adj[i + 1, i] = True
    topo = T.Topology("line", adj)
    ms = MembershipSchedule.build([], initial_offline=(1,))
    countdown = [2] + [10_000] * (n - 1)
    spec = FederationSpec.build(n, initial_countdown=countdown,
                                membership=ms)
    cfg = simlax.SimLaxConfig(ticks=12, train_interval=(10_000, 10_000),
                              latency=1, ttl=3, record_every=4, seed=0)
    sim = scenarios.make_heap_simulator(sc, topo, spec, IMPL2, cfg)
    sim.run()
    nodes = list(sim.nodes.values())
    # the flood reached nodes 2 and 3 THROUGH offline node 1 (ttl 3: hop 3
    # is node 3's receipt at ttl 0, which is not forwarded on to node 4)
    assert sim.stats["tx_sent"] == 1
    assert sim.stats["tx_delivered"] == 2
    assert len(nodes[2].buffer) == 1 and len(nodes[3].buffer) == 1
    assert len(nodes[4].buffer) == 0
    # the offline relay saw the tx but never processed it
    assert len(nodes[1].buffer) == 0 and len(nodes[1].seen_tx) == 1


def test_heap_rejoin_resumes_from_committed_params():
    """Offline nodes freeze: params stay at the committed value for the
    whole offline window, then training resumes after the rejoin."""
    n, interval = 6, 4
    sc = scenarios.toy_scenario(n, dim=4)
    ms = MembershipSchedule.build([(6, (), (2,)), (18, (2,), ())])
    spec = FederationSpec.build(n, initial_countdown=[2 + i for i in range(n)],
                                membership=ms)
    cfg = simlax.SimLaxConfig(ticks=28, train_interval=(interval, interval),
                              latency=1, ttl=1, record_every=1, seed=0)
    sim = scenarios.make_heap_simulator(sc, T.full(n), spec, IMPL2, cfg)
    snaps = {}
    node2 = list(sim.nodes.values())[2]
    sim.run(progress=lambda tick, s: snaps.update(
        {tick: np.asarray(node2.params["w"]).copy()}))
    frozen = snaps[6]
    for t in range(6, 18):
        np.testing.assert_array_equal(snaps[t], frozen, err_msg=str(t))
    assert not np.array_equal(snaps[27], frozen)   # training resumed


def test_spec_membership_validates_against_dead():
    with pytest.raises(ValueError, match="dead; it cannot churn"):
        FederationSpec.build(
            4, dead=(1,),
            membership=MembershipSchedule.build([(2, (), (1,))]))
