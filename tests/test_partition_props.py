"""Property tests (hypothesis) for the Dirichlet data partition."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # minimal installs still collect the suite
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.data.partition import dirichlet_class_probs  # noqa: E402

settings.register_profile("ci2", max_examples=20, deadline=None)
settings.load_profile("ci2")


@given(nodes=st.integers(2, 8), classes=st.integers(2, 10),
       alpha=st.sampled_from([0.1, 1.0, 10.0]), seed=st.integers(0, 99))
def test_dirichlet_rows_are_distributions(nodes, classes, alpha, seed):
    m = dirichlet_class_probs(nodes, classes, alpha, seed)
    assert m.shape == (nodes, classes)
    np.testing.assert_allclose(m.sum(axis=1), 1.0, rtol=1e-6)
    assert (m >= 0).all()
