"""Cross-module taint propagation in jaxlint over REAL multi-file trees
(tmpdir projects, not in-memory fixtures): a traced caller in module A
must light up the offending helper in module B, diamond import graphs
must not duplicate findings, import cycles must not hang the worklist,
and per-line suppressions must stay file-local."""
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))
import jaxlint  # noqa: E402

SIM = """\
import jax
import jax.numpy as jnp

from pkg.helpers import smooth


def body(state, t):
    s = smooth(state)
    return s, None


def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
"""

HELPER_BAD = """\
import numpy as np


def smooth(x):
    return np.cumsum(x)
"""

HELPER_GOOD = HELPER_BAD.replace("import numpy as np",
                                 "import jax.numpy as np")


def _lint_tree(tmp_path, files):
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(src)
    return jaxlint.lint_paths([str(tmp_path)], root=str(tmp_path))


def _active(findings, rule=None):
    out = [f for f in findings if not f.suppressed]
    if rule:
        out = [f for f in out if f.rule == rule]
    return out


def test_traced_caller_in_a_flags_helper_in_b(tmp_path):
    """scan body in pkg/sim.py taints smooth()'s param across the module
    boundary; the np-in-traced finding lands in pkg/helpers.py at the
    offending call, and names the taint origin."""
    hits = _active(_lint_tree(tmp_path, {
        "src/pkg/sim.py": SIM,
        "src/pkg/helpers.py": HELPER_BAD,
    }), "np-in-traced")
    assert len(hits) == 1, [f.as_dict() for f in hits]
    f = hits[0]
    assert f.path.endswith("helpers.py")
    assert "smooth" in f.message
    assert "pkg.sim.body" in f.message  # foreign-taint origin
    # the jnp spelling of the same helper is clean
    assert not _active(_lint_tree(tmp_path, {
        "src/pkg/sim.py": SIM,
        "src/pkg/helpers.py": HELPER_GOOD,
    }))


def test_host_coercion_crosses_module_boundary(tmp_path):
    sim = SIM.replace("smooth", "step_size")
    helper = """\
def step_size(x):
    return float(x[0])
"""
    hits = _active(_lint_tree(tmp_path, {
        "src/pkg/sim.py": sim,
        "src/pkg/helpers.py": helper,
    }), "host-coercion")
    assert len(hits) == 1 and hits[0].path.endswith("helpers.py")


def test_diamond_imports_fire_once(tmp_path):
    """A's scan body calls B.via_b and C.via_c, both of which call
    D.helper with the traced value — one finding at D's offending line,
    not one per path."""
    a = """\
import jax
import jax.numpy as jnp

from pkg.b import via_b
from pkg.c import via_c


def body(state, t):
    return via_b(state) + via_c(state), None


def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
"""
    b = "from pkg.d import helper\n\n\ndef via_b(x):\n    return helper(x)\n"
    c = "from pkg.d import helper\n\n\ndef via_c(x):\n    return helper(x)\n"
    d = """\
import numpy as np


def helper(x):
    return np.cumsum(x)
"""
    hits = _active(_lint_tree(tmp_path, {
        "src/pkg/a.py": a, "src/pkg/b.py": b,
        "src/pkg/c.py": c, "src/pkg/d.py": d,
    }), "np-in-traced")
    assert len(hits) == 1, [f.as_dict() for f in hits]
    assert hits[0].path.endswith("d.py")


def test_import_cycle_converges(tmp_path):
    """a <-> b import cycle: the propagation worklist must converge, and
    taint still flows a.body -> b.relay -> a.leaf."""
    a = """\
import jax
import jax.numpy as jnp
import numpy as np

from pkg.b import relay


def leaf(x):
    return np.cumsum(x)


def body(state, t):
    return relay(state), None


def run(state):
    return jax.lax.scan(body, state, jnp.arange(4))
"""
    b = "from pkg.a import leaf\n\n\ndef relay(x):\n    return leaf(x)\n"
    hits = _active(_lint_tree(tmp_path, {
        "src/pkg/a.py": a, "src/pkg/b.py": b,
    }), "np-in-traced")
    assert any(f.path.endswith("a.py") and "leaf" in f.message
               for f in hits)


def test_suppressions_stay_file_local(tmp_path):
    """An ignore comment on the CALL line in sim.py must not silence the
    finding reported in helpers.py; the ignore belongs on the offending
    line in the file that owns it."""
    sim_suppressed = SIM.replace(
        "    s = smooth(state)",
        "    s = smooth(state)  # jaxlint: ignore[np-in-traced]")
    hits = _active(_lint_tree(tmp_path, {
        "src/pkg/sim.py": sim_suppressed,
        "src/pkg/helpers.py": HELPER_BAD,
    }), "np-in-traced")
    assert len(hits) == 1 and hits[0].path.endswith("helpers.py")

    helper_suppressed = HELPER_BAD.replace(
        "    return np.cumsum(x)",
        "    return np.cumsum(x)  # jaxlint: ignore[np-in-traced]")
    findings = _lint_tree(tmp_path, {
        "src/pkg/sim.py": SIM,
        "src/pkg/helpers.py": helper_suppressed,
    })
    assert not _active(findings, "np-in-traced")
    assert any(f.rule == "np-in-traced" and f.suppressed for f in findings)


def test_explain_names_the_cross_module_chain(tmp_path):
    from jaxlintlib.project import Project

    for rel, src in {"src/pkg/sim.py": SIM,
                     "src/pkg/helpers.py": HELPER_BAD}.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(src)
    project = Project.from_paths([str(tmp_path)], str(tmp_path))
    model = jaxlint.Model(project, jitted_modules=set(), traced_seeds={},
                          host_side={}, wire_modules=set())
    out = "\n".join(model.explain("smooth"))
    assert "pkg.helpers.smooth: TRACED" in out
    assert "called from pkg.sim.body" in out
    assert "passed to scan" in out
    assert "foreign taint via pkg.sim.body" in out
