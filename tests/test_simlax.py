"""Vectorized tick simulator vs the heap behavioral reference, plus
scale/straggler/failure behaviour (paper §VI-D at large N)."""
import numpy as np
import pytest

from repro.chain import scenarios, simlax
from repro.chain.network import SimConfig, Simulator, mean_reputation
from repro.core import topology as T
from repro.core.reputation import IMPL2


def _staggered(n, interval):
    # de-synchronized first broadcasts: both engines support an explicit
    # initial countdown, which keeps FedAvg window sizes comparable
    return [3 + (7 * i) % interval for i in range(n)]


def test_matches_heap_simulator_on_shared_scenario():
    """The acceptance scenario: same topology, schedule, and toy model on
    both engines -> event counts identical, final mean accuracy/reputation
    within tolerance."""
    n, ticks, interval = 12, 160, 12
    sc = scenarios.toy_scenario(n, malicious=(0,))
    topo = T.full(n)
    names = [f"n{i}" for i in range(n)]
    stagger = _staggered(n, interval)

    nodes = sc.make_heap_nodes(rep_impl=IMPL2, ttl=2)
    heap = Simulator(nodes, topo.as_name_dict(names), sc.heap_test_fn(),
                     SimConfig(ticks=ticks, seed=0,
                               train_interval=(interval, interval),
                               latency=(1, 1), record_every=10))
    heap.next_train = {names[i]: stagger[i] for i in range(n)}
    heap.run()
    honest = nodes[1:]
    heap_acc = np.mean([nd.accuracy_history[-1][1] for nd in honest])
    heap_mal = mean_reputation(honest, nodes[0].info.address)
    heap_hon = np.mean([mean_reputation([m for m in honest if m is not nd],
                                        nd.info.address) for nd in honest])

    cfg = simlax.SimLaxConfig(ticks=ticks, train_interval=(interval, interval),
                              latency=1, ttl=2, record_every=10, seed=0)
    sim = simlax.LaxSimulator(
        topology=topo, train_fn=sc.train_fn, eval_fn=sc.eval_fn,
        test_fn=sc.test_fn, eval_data=sc.eval_data(), rep_impl=IMPL2,
        cfg=cfg, malicious=(0,), initial_countdown=stagger)
    res = sim.run(sc.init_params_stacked())
    lax_acc = res.acc_history[-1][1:].mean()
    lax_mal = res.mean_reputation(0)
    lax_hon = np.mean([res.mean_reputation(i) for i in range(1, n)])

    # deterministic schedule: the event streams must agree exactly
    assert res.stats["broadcasts"] == heap.stats["tx_sent"]
    assert res.stats["deliveries"] == heap.stats["tx_delivered"]
    # headline metrics within tolerance (buffer-window semantics differ
    # slightly: consume-all-at-end-of-tick vs consume-exactly-B mid-tick)
    assert abs(heap_acc - lax_acc) < 0.02, (heap_acc, lax_acc)
    assert abs(heap_mal - lax_mal) < 0.1, (heap_mal, lax_mal)
    assert abs(heap_hon - lax_hon) < 0.05, (heap_hon, lax_hon)
    # both must have identified the attacker (well below the honest mean)
    assert lax_mal < lax_hon - 0.3, (lax_mal, lax_hon)
    assert heap_mal < heap_hon - 0.3, (heap_mal, heap_hon)


def test_thousand_node_simulation_runs():
    """Acceptance: 1000 nodes x 200 ticks through the jitted engine."""
    n = 1000
    sc = scenarios.toy_scenario(n, dim=4, malicious=(0, 1, 2))
    cfg = simlax.SimLaxConfig(ticks=200, train_interval=(8, 16), latency=2,
                              ttl=2, record_every=20, seed=0)
    sim = simlax.LaxSimulator(
        topology=T.kregular(n, 3), train_fn=sc.train_fn, eval_fn=sc.eval_fn,
        test_fn=sc.test_fn, eval_data=sc.eval_data(), rep_impl=IMPL2,
        cfg=cfg, malicious=(0, 1, 2))
    res = sim.run(sc.init_params_stacked())
    assert res.acc_history.shape == (10, n)
    assert res.stats["broadcasts"] > n  # everyone broadcast repeatedly
    assert res.stats["deliveries"] > res.stats["broadcasts"]
    # training converged toward the target across the federation
    assert res.acc_history[-1].mean() > res.acc_history[0].mean() + 0.1


@pytest.mark.parametrize("kind", ["ring", "kregular", "erdos", "smallworld"])
def test_non_full_topologies_execute(kind):
    n = 24
    sc = scenarios.toy_scenario(n)
    topo = T.make(kind, n, degree=2, p=0.25, seed=1)
    cfg = simlax.SimLaxConfig(ticks=80, train_interval=(6, 6), latency=1,
                              ttl=1, record_every=20, seed=0)
    sim = simlax.LaxSimulator(
        topology=topo, train_fn=sc.train_fn, eval_fn=sc.eval_fn,
        test_fn=sc.test_fn, eval_data=sc.eval_data(), rep_impl=IMPL2, cfg=cfg)
    res = sim.run(sc.init_params_stacked())
    # ttl=1 deterministic delivery: every broadcast reaches exactly deg(dst)
    per_node = res.stats["broadcasts_per_node"]
    expected = int(np.sum(topo.degrees() * per_node))
    # broadcasts in the final `latency` ticks are still in flight
    assert 0 <= expected - res.stats["deliveries"] <= int(topo.degrees().max()) * n
    assert res.acc_history[-1].mean() > res.acc_history[0].mean()


def test_straggler_broadcasts_less():
    n = 8
    sc = scenarios.toy_scenario(n)
    cfg = simlax.SimLaxConfig(ticks=150, train_interval=(8, 8), latency=1,
                              ttl=1, record_every=50, seed=0)
    sim = simlax.LaxSimulator(
        topology=T.full(n), train_fn=sc.train_fn, eval_fn=sc.eval_fn,
        test_fn=sc.test_fn, eval_data=sc.eval_data(), rep_impl=IMPL2,
        cfg=cfg, stragglers={0: 5})
    res = sim.run(sc.init_params_stacked())
    per_node = res.stats["broadcasts_per_node"]
    assert per_node[0] < per_node[1:].min()


def test_dead_node_is_silent_and_survivable():
    n = 8
    sc = scenarios.toy_scenario(n)
    cfg = simlax.SimLaxConfig(ticks=120, train_interval=(8, 8), latency=1,
                              ttl=2, record_every=40, seed=0)
    sim = simlax.LaxSimulator(
        topology=T.full(n), train_fn=sc.train_fn, eval_fn=sc.eval_fn,
        test_fn=sc.test_fn, eval_data=sc.eval_data(), rep_impl=IMPL2,
        cfg=cfg, dead=(3,))
    res = sim.run(sc.init_params_stacked())
    per_node = res.stats["broadcasts_per_node"]
    assert per_node[3] == 0
    assert per_node[[i for i in range(n) if i != 3]].min() > 0
    # dead node's params never move; the rest still converge
    np.testing.assert_allclose(res.params["w"][3],
                               sc.init_params_stacked()["w"][3])
    live = [i for i in range(n) if i != 3]
    assert res.acc_history[-1][live].mean() > res.acc_history[0][live].mean()


def test_reputation_crushes_malicious_only():
    n = 10
    sc = scenarios.toy_scenario(n, malicious=(4,))
    cfg = simlax.SimLaxConfig(ticks=300, train_interval=(10, 10), latency=1,
                              ttl=1, record_every=50, seed=0)
    sim = simlax.LaxSimulator(
        topology=T.full(n), train_fn=sc.train_fn, eval_fn=sc.eval_fn,
        test_fn=sc.test_fn, eval_data=sc.eval_data(), rep_impl=IMPL2,
        cfg=cfg, malicious=(4,),
        initial_countdown=_staggered(n, 10))
    res = sim.run(sc.init_params_stacked())
    mal = res.mean_reputation(4)
    hon = np.mean([res.mean_reputation(i) for i in range(n) if i != 4])
    assert mal < 0.2 < hon, (mal, hon)
