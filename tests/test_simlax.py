"""Vectorized tick simulator vs the heap behavioral reference, the sparse
(budgeted slot) receipt engine vs the dense N^2 oracle, plus
scale/straggler/failure behaviour (paper §VI-D at large N)."""
import numpy as np
import pytest

from repro.chain import scenarios, simlax
from repro.chain.network import SimConfig, Simulator, mean_reputation
from repro.core import topology as T
from repro.core.reputation import IMPL2


def _staggered(n, interval):
    # de-synchronized first broadcasts: both engines support an explicit
    # initial countdown, which keeps FedAvg window sizes comparable
    return [3 + (7 * i) % interval for i in range(n)]


def test_matches_heap_simulator_on_shared_scenario():
    """The acceptance scenario: same topology, schedule, and toy model on
    both engines -> event counts identical, final mean accuracy/reputation
    within tolerance."""
    n, ticks, interval = 12, 160, 12
    sc = scenarios.toy_scenario(n, malicious=(0,))
    topo = T.full(n)
    names = [f"n{i}" for i in range(n)]
    stagger = _staggered(n, interval)

    nodes = sc.make_heap_nodes(rep_impl=IMPL2, ttl=2)
    heap = Simulator(nodes, topo.as_name_dict(names), sc.heap_test_fn(),
                     SimConfig(ticks=ticks, seed=0,
                               train_interval=(interval, interval),
                               latency=(1, 1), record_every=10))
    heap.next_train = {names[i]: stagger[i] for i in range(n)}
    heap.run()
    honest = nodes[1:]
    heap_acc = np.mean([nd.accuracy_history[-1][1] for nd in honest])
    heap_mal = mean_reputation(honest, nodes[0].info.address)
    heap_hon = np.mean([mean_reputation([m for m in honest if m is not nd],
                                        nd.info.address) for nd in honest])

    cfg = simlax.SimLaxConfig(ticks=ticks, train_interval=(interval, interval),
                              latency=1, ttl=2, record_every=10, seed=0)
    sim = simlax.LaxSimulator(
        topology=topo, train_fn=sc.train_fn, eval_fn=sc.eval_fn,
        test_fn=sc.test_fn, eval_data=sc.eval_data(), rep_impl=IMPL2,
        cfg=cfg, malicious=(0,), initial_countdown=stagger)
    res = sim.run(sc.init_params_stacked())
    lax_acc = res.acc_history[-1][1:].mean()
    lax_mal = res.mean_reputation(0)
    lax_hon = np.mean([res.mean_reputation(i) for i in range(1, n)])

    # deterministic schedule: the event streams must agree exactly
    assert res.stats["broadcasts"] == heap.stats["tx_sent"]
    assert res.stats["deliveries"] == heap.stats["tx_delivered"]
    # headline metrics within tolerance (buffer-window semantics differ
    # slightly: consume-all-at-end-of-tick vs consume-exactly-B mid-tick)
    assert abs(heap_acc - lax_acc) < 0.02, (heap_acc, lax_acc)
    assert abs(heap_mal - lax_mal) < 0.1, (heap_mal, lax_mal)
    assert abs(heap_hon - lax_hon) < 0.05, (heap_hon, lax_hon)
    # both must have identified the attacker (well below the honest mean)
    assert lax_mal < lax_hon - 0.3, (lax_mal, lax_hon)
    assert heap_mal < heap_hon - 0.3, (heap_mal, heap_hon)


def test_thousand_node_simulation_runs():
    """Acceptance: 1000 nodes x 200 ticks through the jitted engine."""
    n = 1000
    sc = scenarios.toy_scenario(n, dim=4, malicious=(0, 1, 2))
    cfg = simlax.SimLaxConfig(ticks=200, train_interval=(8, 16), latency=2,
                              ttl=2, record_every=20, seed=0)
    sim = simlax.LaxSimulator(
        topology=T.kregular(n, 3), train_fn=sc.train_fn, eval_fn=sc.eval_fn,
        test_fn=sc.test_fn, eval_data=sc.eval_data(), rep_impl=IMPL2,
        cfg=cfg, malicious=(0, 1, 2))
    res = sim.run(sc.init_params_stacked())
    assert res.acc_history.shape == (10, n)
    assert res.stats["broadcasts"] > n  # everyone broadcast repeatedly
    assert res.stats["deliveries"] > res.stats["broadcasts"]
    # training converged toward the target across the federation
    assert res.acc_history[-1].mean() > res.acc_history[0].mean() + 0.1


@pytest.mark.parametrize("kind", ["ring", "kregular", "erdos", "smallworld"])
def test_non_full_topologies_execute(kind):
    n = 24
    sc = scenarios.toy_scenario(n)
    topo = T.make(kind, n, degree=2, p=0.25, seed=1)
    cfg = simlax.SimLaxConfig(ticks=80, train_interval=(6, 6), latency=1,
                              ttl=1, record_every=20, seed=0)
    sim = simlax.LaxSimulator(
        topology=topo, train_fn=sc.train_fn, eval_fn=sc.eval_fn,
        test_fn=sc.test_fn, eval_data=sc.eval_data(), rep_impl=IMPL2, cfg=cfg)
    res = sim.run(sc.init_params_stacked())
    # ttl=1 deterministic delivery: every broadcast reaches exactly deg(dst)
    per_node = res.stats["broadcasts_per_node"]
    expected = int(np.sum(topo.degrees() * per_node))
    # broadcasts in the final `latency` ticks are still in flight
    assert 0 <= expected - res.stats["deliveries"] <= int(topo.degrees().max()) * n
    assert res.acc_history[-1].mean() > res.acc_history[0].mean()


def test_straggler_broadcasts_less():
    n = 8
    sc = scenarios.toy_scenario(n)
    cfg = simlax.SimLaxConfig(ticks=150, train_interval=(8, 8), latency=1,
                              ttl=1, record_every=50, seed=0)
    sim = simlax.LaxSimulator(
        topology=T.full(n), train_fn=sc.train_fn, eval_fn=sc.eval_fn,
        test_fn=sc.test_fn, eval_data=sc.eval_data(), rep_impl=IMPL2,
        cfg=cfg, stragglers={0: 5})
    res = sim.run(sc.init_params_stacked())
    per_node = res.stats["broadcasts_per_node"]
    assert per_node[0] < per_node[1:].min()


def test_dead_node_is_silent_and_survivable():
    n = 8
    sc = scenarios.toy_scenario(n)
    cfg = simlax.SimLaxConfig(ticks=120, train_interval=(8, 8), latency=1,
                              ttl=2, record_every=40, seed=0)
    sim = simlax.LaxSimulator(
        topology=T.full(n), train_fn=sc.train_fn, eval_fn=sc.eval_fn,
        test_fn=sc.test_fn, eval_data=sc.eval_data(), rep_impl=IMPL2,
        cfg=cfg, dead=(3,))
    res = sim.run(sc.init_params_stacked())
    per_node = res.stats["broadcasts_per_node"]
    assert per_node[3] == 0
    assert per_node[[i for i in range(n) if i != 3]].min() > 0
    # dead node's params never move; the rest still converge
    np.testing.assert_allclose(res.params["w"][3],
                               sc.init_params_stacked()["w"][3])
    live = [i for i in range(n) if i != 3]
    assert res.acc_history[-1][live].mean() > res.acc_history[0][live].mean()


def test_reputation_crushes_malicious_only():
    n = 10
    sc = scenarios.toy_scenario(n, malicious=(4,))
    cfg = simlax.SimLaxConfig(ticks=300, train_interval=(10, 10), latency=1,
                              ttl=1, record_every=50, seed=0)
    sim = simlax.LaxSimulator(
        topology=T.full(n), train_fn=sc.train_fn, eval_fn=sc.eval_fn,
        test_fn=sc.test_fn, eval_data=sc.eval_data(), rep_impl=IMPL2,
        cfg=cfg, malicious=(4,),
        initial_countdown=_staggered(n, 10))
    res = sim.run(sc.init_params_stacked())
    mal = res.mean_reputation(4)
    hon = np.mean([res.mean_reputation(i) for i in range(n) if i != 4])
    assert mal < 0.2 < hon, (mal, hon)


# ===================================================== sparse vs dense engines
def _run_both_engines(sc, topo, *, ticks, interval, latency=1, ttl=2,
                      seed=0, malicious=(), dead=(), stragglers=None,
                      countdown=None, train_data=None):
    out = {}
    for eng in ("sparse", "dense"):
        cfg = simlax.SimLaxConfig(
            ticks=ticks, train_interval=interval, latency=latency, ttl=ttl,
            record_every=max(1, ticks // 5), seed=seed, delivery=eng)
        sim = simlax.LaxSimulator(
            topology=topo, train_fn=sc.train_fn, eval_fn=sc.eval_fn,
            test_fn=sc.test_fn, eval_data=sc.eval_data(), rep_impl=IMPL2,
            cfg=cfg, malicious=malicious, dead=dead, stragglers=stragglers,
            initial_countdown=countdown, train_data=train_data)
        out[eng] = sim.run(sc.init_params_stacked())
    return out["sparse"], out["dense"]


def _assert_engine_parity(s, d):
    """The two delivery engines must replay the SAME event stream: integer
    state identical, float state identical up to summation order."""
    for k in ("broadcasts", "deliveries", "fedavg_rounds"):
        assert s.stats[k] == d.stats[k], (k, s.stats[k], d.stats[k])
    np.testing.assert_array_equal(s.stats["broadcasts_per_node"],
                                  d.stats["broadcasts_per_node"])
    for k in ("arrive", "min_sender", "buf_cnt", "next_train"):
        np.testing.assert_array_equal(s.final_state[k], d.final_state[k],
                                      err_msg=k)
    for k in ("w_sum", "min_acc"):
        np.testing.assert_allclose(s.final_state[k], d.final_state[k],
                                   rtol=1e-6, atol=1e-6, err_msg=k)
    np.testing.assert_allclose(s.reputation, d.reputation, atol=1e-6)
    np.testing.assert_allclose(s.acc_history, d.acc_history, atol=1e-5)
    import jax
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32),
        rtol=1e-5, atol=1e-6), s.params, d.params)


@pytest.mark.parametrize("kind,kw,ttl,latency,dead,stragglers,malicious", [
    ("full", {}, 2, 1, (), None, (0,)),
    ("ring", {}, 3, 2, (), None, ()),
    ("kregular", {"degree": 3}, 2, 1, (5,), {1: 4}, (2,)),
    ("erdos", {"p": 0.3}, 2, 2, (3,), None, (0, 1)),
    ("smallworld", {"degree": 2, "beta": 0.3}, 1, 1, (), {0: 3}, (4,)),
])
def test_sparse_matches_dense_engine(kind, kw, ttl, latency, dead,
                                     stragglers, malicious):
    n = 14
    sc = scenarios.toy_scenario(n, dim=8, malicious=malicious)
    topo = T.make(kind, n, seed=2, **kw)
    lo = ttl * latency + 1  # stay out of the re-broadcast-overwrite regime
    s, d = _run_both_engines(
        sc, topo, ticks=90, interval=(lo, lo + 4), latency=latency, ttl=ttl,
        malicious=malicious, dead=dead, stragglers=stragglers,
        countdown=[1 + (3 * i) % lo for i in range(n)])
    assert s.stats["deliveries"] > 0
    _assert_engine_parity(s, d)


def test_engine_parity_property():
    """Hypothesis sweep: random topology/ttl/latency/dead/straggler/seed
    combinations never separate the engines."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=8, deadline=None,
                  suppress_health_check=list(hyp.HealthCheck))
    @hyp.given(data=st.data())
    def run(data):
        n = data.draw(st.integers(6, 12), label="n")
        kind = data.draw(st.sampled_from(
            ["full", "ring", "kregular", "erdos", "smallworld"]),
            label="kind")
        ttl = data.draw(st.integers(1, 3), label="ttl")
        latency = data.draw(st.integers(1, 2), label="latency")
        seed = data.draw(st.integers(0, 5), label="seed")
        dead = data.draw(st.sets(st.integers(0, n - 1), max_size=2),
                         label="dead")
        malicious = data.draw(st.sets(st.integers(0, n - 1), max_size=2),
                              label="malicious")
        strag = data.draw(st.dictionaries(
            st.integers(0, n - 1), st.integers(2, 4), max_size=2),
            label="stragglers")
        topo = T.make(kind, n, degree=2, p=0.4, seed=seed)
        sc = scenarios.toy_scenario(n, dim=4, malicious=tuple(malicious),
                                    seed=seed)
        lo = ttl * latency + 1
        s, d = _run_both_engines(
            sc, topo, ticks=50, interval=(lo, lo + 3), latency=latency,
            ttl=ttl, seed=seed, malicious=tuple(malicious),
            dead=tuple(dead), stragglers=strag,
            countdown=[1 + (3 * i) % (lo + 2) for i in range(n)])
        _assert_engine_parity(s, d)

    run()


def test_lenet_sparse_matches_dense_engine():
    """The real-model scenario through both engines at toy size: identical
    event stream, matching reputations/accuracy (receipt evals are actual
    LeNet forward passes, so any slot-buffer indexing slip shows up here)."""
    n = 6
    mal = (0,)
    sc = scenarios.lenet_scenario(n, alpha=1.0, malicious=mal, seed=0,
                                  pool=16, eval_size=8, test_size=16,
                                  train_steps=1, batch=4, lr=0.1)
    topo = T.kregular(n, 2)
    s, d = _run_both_engines(
        sc, topo, ticks=16, interval=(4, 4), latency=1, ttl=1,
        malicious=mal, train_data=sc.train_data(),
        countdown=[1 + (3 * i) % 4 for i in range(n)])
    assert s.stats["deliveries"] > 0
    _assert_engine_parity(s, d)


def test_delivery_budget_bounds_due_pairs():
    """The static slot budget is the exact ttl-ball bound: never exceeded
    by (and on some tick equal to the max of) actual per-receiver
    deliveries."""
    n = 16
    topo = T.make("erdos", n, p=0.3, seed=3)
    budget = T.delivery_budget(topo.adj, 2)
    balls = T.ttl_ball_sizes(topo.adj, 2)
    assert budget == balls.max()
    assert (balls >= topo.degrees()).all()   # ball contains the neighbors
    assert T.delivery_budget(topo.adj, 1) == topo.degrees().max()
    full = T.full(n)
    assert T.delivery_budget(full.adj, 1) == n - 1
    assert T.delivery_budget(full.adj, 3) == n - 1   # ball saturates


# ============================================== re-broadcast overwrite caveat
def test_rebroadcast_overwrite_warns_and_pins_heap_divergence():
    """When min train interval < ttl * latency a node re-broadcasts while
    its previous model is still in flight; the single in-flight snapshot
    per (dst, src) pair overwrites the pending delivery. The constructor
    must warn, and the documented effect — fewer deliveries than the heap
    reference, which keeps every snapshot — is pinned here (ring, hop-2
    delay 4 > interval 3, so every hop-2 delivery is overwritten).
    Equality is the safe boundary (deliveries are processed before the
    same-tick re-broadcast): no warning, exact heap parity."""
    n, interval, latency, ttl, ticks = 8, 3, 2, 2, 60
    sc = scenarios.toy_scenario(n)
    topo = T.ring(n)
    cfg = simlax.SimLaxConfig(ticks=ticks, train_interval=(interval, interval),
                              latency=latency, ttl=ttl, record_every=20,
                              seed=0)
    with pytest.warns(UserWarning, match="re-broadcast"):
        sim = simlax.LaxSimulator(
            topology=topo, train_fn=sc.train_fn, eval_fn=sc.eval_fn,
            test_fn=sc.test_fn, eval_data=sc.eval_data(), rep_impl=IMPL2,
            cfg=cfg, initial_countdown=[interval] * n)
    res = sim.run(sc.init_params_stacked())

    names = [f"n{i}" for i in range(n)]
    nodes = sc.make_heap_nodes(rep_impl=IMPL2, ttl=ttl)
    heap = Simulator(nodes, topo.as_name_dict(names), sc.heap_test_fn(),
                     SimConfig(ticks=ticks, seed=0,
                               train_interval=(interval, interval),
                               latency=(latency, latency), record_every=20))
    heap.next_train = {nm: interval for nm in names}
    heap.run()

    assert res.stats["broadcasts"] == heap.stats["tx_sent"]
    lost = heap.stats["tx_delivered"] - res.stats["deliveries"]
    # every broadcast's 2 hop-2 deliveries are overwritten by the next
    # broadcast (modulo the in-flight tail) -> a strict, large deficit
    assert lost > res.stats["broadcasts"], (lost, res.stats)
    # the boundary (interval == ttl*latency) is safe: same-tick deliveries
    # are processed before the re-broadcast -> no warning, exact heap parity
    safe_interval = ttl * latency
    cfg2 = simlax.SimLaxConfig(
        ticks=ticks, train_interval=(safe_interval, safe_interval),
        latency=latency, ttl=ttl, record_every=20, seed=0)
    import warnings as _warnings
    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        sim2 = simlax.LaxSimulator(
            topology=topo, train_fn=sc.train_fn, eval_fn=sc.eval_fn,
            test_fn=sc.test_fn, eval_data=sc.eval_data(), rep_impl=IMPL2,
            cfg=cfg2, initial_countdown=[safe_interval] * n)
    res2 = sim2.run(sc.init_params_stacked())
    nodes2 = sc.make_heap_nodes(rep_impl=IMPL2, ttl=ttl)
    heap2 = Simulator(nodes2, topo.as_name_dict(names), sc.heap_test_fn(),
                      SimConfig(ticks=ticks, seed=0,
                                train_interval=(safe_interval, safe_interval),
                                latency=(latency, latency), record_every=20))
    heap2.next_train = {nm: safe_interval for nm in names}
    heap2.run()
    assert res2.stats["deliveries"] == heap2.stats["tx_delivered"]


# ======================================================== result-object cover
def test_mean_reputation_excludes_self_view():
    rep = np.full((4, 4), 1.0, np.float32)
    rep[:, 2] = 0.25          # everyone scores node 2 low ...
    rep[2, 2] = 1.0           # ... except node 2's (ignored) self-view
    res = simlax.SimLaxResult(
        params={}, reputation=rep, acc_history=np.zeros((1, 4)),
        record_ticks=np.zeros((1,)), stats={})
    assert res.mean_reputation(2) == pytest.approx(0.25)
    assert res.mean_reputation(0) == pytest.approx(1.0)


# ================================================== real-model (LeNet) slow
@pytest.mark.slow
def test_lenet_smoke():
    """CI smoke: 8 nodes x 30 ticks of the real-model scenario through the
    sparse engine — exercises Dirichlet shards, vmapped LeNet train/eval,
    poison, FedAvg, reputation end-to-end."""
    n = 8
    mal = (0,)
    sc = scenarios.lenet_scenario(n, alpha=0.5, malicious=mal, seed=0,
                                  pool=96, eval_size=16, test_size=128,
                                  train_steps=2, batch=16, lr=0.12)
    topo = T.kregular(n, 2)
    cfg = simlax.SimLaxConfig(ticks=30, train_interval=(6, 6), latency=1,
                              ttl=2, record_every=10, seed=0,
                              delivery="sparse")
    sim = simlax.LaxSimulator(
        topology=topo, train_fn=sc.train_fn, eval_fn=sc.eval_fn,
        test_fn=sc.test_fn, eval_data=sc.eval_data(), rep_impl=IMPL2,
        cfg=cfg, malicious=mal, train_data=sc.train_data(),
        initial_countdown=[1 + (5 * i) % 6 for i in range(n)])
    res = sim.run(sc.init_params_stacked())
    assert res.stats["delivery_budget"] == 7   # kregular(8,2) ttl=2 ball
    assert res.stats["deliveries"] > 0
    assert res.stats["broadcasts"] >= n
    assert np.isfinite(res.acc_history).all()
    assert (res.acc_history >= 0).all() and (res.acc_history <= 1).all()
    # training moved the federation off its random-init accuracy
    assert res.acc_history[-1].mean() > res.acc_history[0].mean()


@pytest.mark.slow
def test_lenet_poisoned_federation_reaches_paper_accuracy():
    """§VI-D acceptance: 20% poisoned senders, non-I.I.D. Dirichlet(1)
    shards — the reputation-weighted federation still reaches >=90% mean
    test accuracy AND drives the poisoners' reputation below the honest
    nodes' (~7 min on 2 CPU cores; the sparse engine is what makes the
    receipt-eval bill payable at all)."""
    n = 10
    sc, mal, topo, cfg, countdown = scenarios.lenet_paper_setup(n)
    assert mal == (0, 1)    # 20% poisoned senders
    sim = simlax.LaxSimulator(
        topology=topo, train_fn=sc.train_fn, eval_fn=sc.eval_fn,
        test_fn=sc.test_fn, eval_data=sc.eval_data(), rep_impl=IMPL2,
        cfg=cfg, malicious=mal, train_data=sc.train_data(),
        initial_countdown=countdown)
    res = sim.run(sc.init_params_stacked())
    honest = [i for i in range(n) if i not in mal]
    final_acc = res.acc_history[-1][honest].mean()
    rep_mal = np.mean([res.mean_reputation(i) for i in mal])
    rep_hon = np.mean([res.mean_reputation(i) for i in honest])
    assert final_acc >= 0.90, (final_acc, res.acc_history[:, honest].mean(1))
    assert rep_mal < rep_hon - 0.1, (rep_mal, rep_hon)
