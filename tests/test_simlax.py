"""Vectorized tick simulator vs the heap behavioral reference, the
receipt-delivery engine chain (compact segment-compacted == sparse
budgeted-slot == dense N^2 oracle) incl. compaction edge cases, plus
scale/straggler/failure behaviour (paper §VI-D at large N).

Both engines are constructed from ONE ``FederationSpec`` role sheet
(``LaxSimulator(sc, topo, spec, rep, cfg)`` vs
``scenarios.make_heap_simulator(sc, topo, spec, rep, cfg)``), so the parity
tests compare a single source of truth."""
import numpy as np
import pytest

from repro.chain import attacks, scenarios, simlax
from repro.chain.attacks import FederationSpec
from repro.chain.network import mean_reputation
from repro.core import topology as T
from repro.core.reputation import IMPL2


def _staggered(n, interval):
    # de-synchronized first broadcasts: both engines support an explicit
    # initial countdown, which keeps FedAvg window sizes comparable
    return [3 + (7 * i) % interval for i in range(n)]


def test_matches_heap_simulator_on_shared_scenario():
    """The acceptance scenario: same topology, schedule, and toy model on
    both engines, built from ONE FederationSpec -> event counts identical,
    final mean accuracy/reputation within tolerance."""
    n, ticks, interval = 12, 160, 12
    sc = scenarios.toy_scenario(n, malicious=(0,))
    topo = T.full(n)
    spec = FederationSpec.build(n, malicious=(0,),
                                initial_countdown=_staggered(n, interval))
    cfg = simlax.SimLaxConfig(ticks=ticks, train_interval=(interval, interval),
                              latency=1, ttl=2, record_every=10, seed=0)

    heap = scenarios.make_heap_simulator(sc, topo, spec, IMPL2, cfg)
    heap.run()
    nodes = list(heap.nodes.values())
    honest = nodes[1:]
    heap_acc = np.mean([nd.accuracy_history[-1][1] for nd in honest])
    heap_mal = mean_reputation(honest, nodes[0].info.address)
    heap_hon = np.mean([mean_reputation([m for m in honest if m is not nd],
                                        nd.info.address) for nd in honest])

    sim = simlax.LaxSimulator(sc, topo, spec, IMPL2, cfg)
    res = sim.run()
    lax_acc = res.acc_history[-1][1:].mean()
    lax_mal = res.mean_reputation(0)
    lax_hon = np.mean([res.mean_reputation(i) for i in range(1, n)])

    # deterministic schedule: the event streams must agree exactly
    assert res.stats["broadcasts"] == heap.stats["tx_sent"]
    assert res.stats["deliveries"] == heap.stats["tx_delivered"]
    # headline metrics within tolerance (buffer-window semantics differ
    # slightly: consume-all-at-end-of-tick vs consume-exactly-B mid-tick)
    assert abs(heap_acc - lax_acc) < 0.02, (heap_acc, lax_acc)
    assert abs(heap_mal - lax_mal) < 0.1, (heap_mal, lax_mal)
    assert abs(heap_hon - lax_hon) < 0.05, (heap_hon, lax_hon)
    # both must have identified the attacker (well below the honest mean)
    assert lax_mal < lax_hon - 0.3, (lax_mal, lax_hon)
    assert heap_mal < heap_hon - 0.3, (heap_mal, heap_hon)


@pytest.mark.parametrize("attack",
                         ["gaussian", "signflip", "freerider", "intermittent"])
def test_attack_parity_heap_vs_lax(attack):
    """Every attack is ONE definition driving both engines: identical event
    streams (attacks corrupt payloads, never schedules), matching aggregate
    dynamics from the same FederationSpec, and — since the heap node draws
    attack keys from the lax scan's fold_in(tick) stream — the attacker's
    broadcast payloads agree across engines: BITWISE for the randomized
    gaussian poison (it depends only on the shared key stream), and to
    float epsilon for trained/committed-dependent attacks (the committed
    params drift at epsilon scale through the engines' differing FedAvg
    buffer-window order)."""
    n, ticks, interval = 10, 120, 12
    sc = scenarios.toy_scenario(n)
    topo = T.full(n)
    spec = FederationSpec.build(n, malicious=(0,), attack=attack,
                                initial_countdown=_staggered(n, interval))
    cfg = simlax.SimLaxConfig(ticks=ticks, train_interval=(interval, interval),
                              latency=1, ttl=2, record_every=10, seed=0)

    heap = scenarios.make_heap_simulator(sc, topo, spec, IMPL2, cfg)
    heap.run()
    nodes = list(heap.nodes.values())
    honest = nodes[1:]
    heap_acc = np.mean([nd.accuracy_history[-1][1] for nd in honest])
    heap_mal = mean_reputation(honest, nodes[0].info.address)

    sim = simlax.LaxSimulator(sc, topo, spec, IMPL2, cfg)
    res = sim.run()
    lax_acc = res.acc_history[-1][1:].mean()
    lax_mal = res.mean_reputation(0)

    # identical event streams across engines
    assert res.stats["broadcasts"] == heap.stats["tx_sent"]
    assert res.stats["deliveries"] == heap.stats["tx_delivered"]
    # the attacker's final broadcast payload across engines
    heap_payload = np.asarray(nodes[0].last_broadcast["w"])
    lax_payload = res.sent["w"][0]
    if attack == "gaussian":
        np.testing.assert_array_equal(heap_payload, lax_payload)
    else:
        np.testing.assert_allclose(heap_payload, lax_payload, atol=5e-3)
    assert abs(heap_acc - lax_acc) < 0.03, (attack, heap_acc, lax_acc)
    assert abs(heap_mal - lax_mal) < 0.15, (attack, heap_mal, lax_mal)
    if attack == "signflip":
        # a constant garbage-model attacker must be crushed on both engines
        assert lax_mal < 0.7 and heap_mal < 0.7, (lax_mal, heap_mal)


@pytest.mark.parametrize("attack", sorted(attacks.names()))
def test_attack_stream_bitwise_parity(attack):
    """The PRNG-stream pin behind the parity upgrade: with FedAvg disabled
    (so committed params cannot drift between the engines' buffer-window
    semantics) every attacker broadcast is reproduced across engines from
    the SHARED fold_in(tick) key stream — bit-for-bit, except `scaled`,
    where XLA fuses ``cm + factor * (tr - cm)`` differently under
    vmap-in-scan vs a single jit (float-epsilon, keys still identical)."""
    import dataclasses
    rep = dataclasses.replace(IMPL2, buffer_size=10 ** 6)  # FedAvg never fires
    n, ticks, interval = 8, 60, 8
    mal = (0, 3)
    sc = scenarios.toy_scenario(n)
    topo = T.full(n)
    spec = FederationSpec.build(
        n, malicious=mal, attack=attack,
        initial_countdown=[1 + (3 * i) % interval for i in range(n)])
    cfg = simlax.SimLaxConfig(ticks=ticks, train_interval=(interval, interval),
                              latency=1, ttl=2, record_every=10, seed=0)
    heap = scenarios.make_heap_simulator(sc, topo, spec, rep, cfg)
    heap.run()
    res = simlax.LaxSimulator(sc, topo, spec, rep, cfg).run()
    nodes = list(heap.nodes.values())
    for i in mal:
        heap_payload = np.asarray(nodes[i].last_broadcast["w"])
        lax_payload = res.sent["w"][i]
        if attack == "scaled":
            np.testing.assert_allclose(heap_payload, lax_payload, atol=1e-5)
        else:
            np.testing.assert_array_equal(heap_payload, lax_payload)


@pytest.mark.parametrize("kind,kw,ttl", [
    ("erdos", {"p": 0.3}, 2),
    ("erdos", {"p": 0.25}, 3),
    ("smallworld", {"degree": 2, "beta": 0.3}, 2),
    ("smallworld", {"degree": 2, "beta": 0.4}, 3),
])
def test_heap_lax_parity_irregular_graphs(kind, kw, ttl):
    """Heap <-> lax event-stream parity on IRREGULAR graphs at ttl >= 2 —
    the regime where the production gossip schedule used to under-cover the
    ttl-ball. Both tick engines flood the exact BFS ball, and the frontier
    schedule now delivers that same set of pairs, at the same hops, in the
    jitted round (test_topology.py::test_audit_schedule_frontier_clean_*)."""
    n, interval = 12, 8
    lo = ttl * 1 + 1
    sc = scenarios.toy_scenario(n, malicious=(0,))
    topo = T.make(kind, n, seed=3, **kw)
    spec = FederationSpec.build(
        n, malicious=(0,),
        initial_countdown=[1 + (3 * i) % interval for i in range(n)])
    cfg = simlax.SimLaxConfig(ticks=96, train_interval=(interval, interval),
                              latency=1, ttl=ttl, record_every=12, seed=0)
    assert interval >= lo  # stay out of the re-broadcast-overwrite regime
    heap = scenarios.make_heap_simulator(sc, topo, spec, IMPL2, cfg)
    heap.run()
    res = simlax.LaxSimulator(sc, topo, spec, IMPL2, cfg).run()
    assert res.stats["broadcasts"] == heap.stats["tx_sent"]
    assert res.stats["deliveries"] == heap.stats["tx_delivered"]
    assert res.stats["deliveries"] > 0
    # the delivered-pairs-per-broadcast rate is the ttl-ball, not the
    # chain-walk subset: mean deliveries == sum over nodes of ball size
    # weighted by per-node broadcasts, minus the in-flight tail
    dist = topo.hop_distance()
    ball = ((dist >= 1) & (dist <= ttl)).sum(axis=1)
    per_node = res.stats["broadcasts_per_node"]
    expected = int((ball * per_node).sum())
    tail = int(ball.max()) * n
    assert 0 <= expected - res.stats["deliveries"] <= tail


def test_legacy_constructor_shim_equals_spec_path():
    """The pre-spec keyword constructor is a thin shim over the new API:
    same scenario + roles -> bit-identical run (the legacy ``malicious=``
    ids map to the default gaussian attack)."""
    n = 10
    sc = scenarios.toy_scenario(n, dim=6, malicious=(1, 3))
    topo = T.kregular(n, 2)
    cfg = simlax.SimLaxConfig(ticks=80, train_interval=(6, 6), latency=1,
                              ttl=2, record_every=20, seed=0)
    cd = [1 + i % 6 for i in range(n)]
    with pytest.warns(DeprecationWarning, match="deprecated"):
        old = simlax.LaxSimulator(
            topology=topo, train_fn=sc.train_fn, eval_fn=sc.eval_fn,
            test_fn=sc.test_fn, eval_data=sc.eval_data(), rep_impl=IMPL2,
            cfg=cfg, malicious=(1, 3), stragglers={2: 3}, dead=(5,),
            initial_countdown=cd)
    r_old = old.run(sc.init_params_stacked())

    spec = FederationSpec.build(n, malicious=(1, 3), dead=(5,),
                                stragglers={2: 3}, initial_countdown=cd)
    new = simlax.LaxSimulator(sc, topo, spec, IMPL2, cfg)
    r_new = new.run()

    for k in ("broadcasts", "deliveries", "fedavg_rounds"):
        assert r_old.stats[k] == r_new.stats[k], k
    for k, v in r_old.final_state.items():
        np.testing.assert_array_equal(v, r_new.final_state[k], err_msg=k)
    np.testing.assert_array_equal(r_old.reputation, r_new.reputation)
    np.testing.assert_array_equal(r_old.acc_history, r_new.acc_history)
    np.testing.assert_array_equal(r_old.params["w"], r_new.params["w"])


def test_mixing_spec_and_legacy_role_kwargs_rejected():
    n = 6
    sc = scenarios.toy_scenario(n)
    topo = T.full(n)
    cfg = simlax.SimLaxConfig(ticks=10, record_every=5)
    spec = FederationSpec.build(n, malicious=(0,))
    with pytest.raises(TypeError, match="not both"):
        simlax.LaxSimulator(sc, topo, spec, IMPL2, cfg, malicious=(0,))
    with pytest.raises(ValueError, match="nodes"):
        simlax.LaxSimulator(sc, topo, FederationSpec.honest(n + 1), IMPL2, cfg)


def test_two_arg_train_fn_with_train_data_rejected():
    """A legacy (params, key) train_fn cannot consume per-node train_data;
    silently dropping the data would corrupt results, so construction must
    fail loudly."""
    n = 4
    sc = scenarios.toy_scenario(n)
    with pytest.raises(TypeError, match="train_data"), \
            pytest.warns(DeprecationWarning):
        simlax.LaxSimulator(
            topology=T.full(n), train_fn=lambda p, k: p,
            eval_fn=sc.eval_fn, test_fn=sc.test_fn, eval_data=sc.eval_data(),
            rep_impl=IMPL2, cfg=simlax.SimLaxConfig(ticks=10, record_every=5),
            train_data={"x": np.zeros((n, 2))})


def test_heterogeneous_attackers_run_with_disjoint_streams():
    """Multiple distinct attacks in one spec: each group runs over its own
    node ids inside the scan (smoke for the per-group gather/scatter and
    the disjoint PRNG fold constants)."""
    n = 8
    sc = scenarios.toy_scenario(n)
    spec = FederationSpec.build(
        n, malicious={0: "signflip", 2: "gaussian", 5: "freerider"},
        initial_countdown=[1 + i % 5 for i in range(n)])
    cfg = simlax.SimLaxConfig(ticks=60, train_interval=(5, 9), latency=1,
                              ttl=1, record_every=20, seed=0)
    res = simlax.LaxSimulator(sc, T.full(n), spec, IMPL2, cfg).run()
    assert res.stats["deliveries"] > 0
    honest = [1, 3, 4, 6, 7]
    assert res.acc_history[-1][honest].mean() > res.acc_history[0][honest].mean()


def test_thousand_node_simulation_runs():
    """Acceptance: 1000 nodes x 200 ticks through the jitted engine."""
    n = 1000
    sc = scenarios.toy_scenario(n, dim=4, malicious=(0, 1, 2))
    cfg = simlax.SimLaxConfig(ticks=200, train_interval=(8, 16), latency=2,
                              ttl=2, record_every=20, seed=0)
    sim = simlax.LaxSimulator(sc, T.kregular(n, 3),
                              FederationSpec.build(n, malicious=(0, 1, 2)),
                              IMPL2, cfg)
    res = sim.run()
    assert res.acc_history.shape == (10, n)
    assert res.stats["broadcasts"] > n  # everyone broadcast repeatedly
    assert res.stats["deliveries"] > res.stats["broadcasts"]
    # training converged toward the target across the federation
    assert res.acc_history[-1].mean() > res.acc_history[0].mean() + 0.1


@pytest.mark.parametrize("kind", ["ring", "kregular", "erdos", "smallworld"])
def test_non_full_topologies_execute(kind):
    n = 24
    sc = scenarios.toy_scenario(n)
    topo = T.make(kind, n, degree=2, p=0.25, seed=1)
    cfg = simlax.SimLaxConfig(ticks=80, train_interval=(6, 6), latency=1,
                              ttl=1, record_every=20, seed=0)
    sim = simlax.LaxSimulator(sc, topo, FederationSpec.honest(n), IMPL2, cfg)
    res = sim.run()
    # ttl=1 deterministic delivery: every broadcast reaches exactly deg(dst)
    per_node = res.stats["broadcasts_per_node"]
    expected = int(np.sum(topo.degrees() * per_node))
    # broadcasts in the final `latency` ticks are still in flight
    assert 0 <= expected - res.stats["deliveries"] <= int(topo.degrees().max()) * n
    assert res.acc_history[-1].mean() > res.acc_history[0].mean()


def test_straggler_broadcasts_less():
    n = 8
    sc = scenarios.toy_scenario(n)
    cfg = simlax.SimLaxConfig(ticks=150, train_interval=(8, 8), latency=1,
                              ttl=1, record_every=50, seed=0)
    sim = simlax.LaxSimulator(sc, T.full(n),
                              FederationSpec.build(n, stragglers={0: 5}),
                              IMPL2, cfg)
    res = sim.run()
    per_node = res.stats["broadcasts_per_node"]
    assert per_node[0] < per_node[1:].min()


def test_dead_node_is_silent_and_survivable():
    n = 8
    sc = scenarios.toy_scenario(n)
    cfg = simlax.SimLaxConfig(ticks=120, train_interval=(8, 8), latency=1,
                              ttl=2, record_every=40, seed=0)
    sim = simlax.LaxSimulator(sc, T.full(n),
                              FederationSpec.build(n, dead=(3,)), IMPL2, cfg)
    res = sim.run()
    per_node = res.stats["broadcasts_per_node"]
    assert per_node[3] == 0
    assert per_node[[i for i in range(n) if i != 3]].min() > 0
    # dead node's params never move; the rest still converge
    np.testing.assert_allclose(res.params["w"][3],
                               sc.init_params_stacked()["w"][3])
    live = [i for i in range(n) if i != 3]
    assert res.acc_history[-1][live].mean() > res.acc_history[0][live].mean()


def test_reputation_crushes_malicious_only():
    n = 10
    sc = scenarios.toy_scenario(n, malicious=(4,))
    cfg = simlax.SimLaxConfig(ticks=300, train_interval=(10, 10), latency=1,
                              ttl=1, record_every=50, seed=0)
    spec = FederationSpec.build(n, malicious=(4,),
                                initial_countdown=_staggered(n, 10))
    sim = simlax.LaxSimulator(sc, T.full(n), spec, IMPL2, cfg)
    res = sim.run()
    mal = res.mean_reputation(4)
    hon = np.mean([res.mean_reputation(i) for i in range(n) if i != 4])
    assert mal < 0.2 < hon, (mal, hon)


# ============================================ compact vs sparse vs dense
def _run_engines(sc, topo, spec, *, ticks, interval, latency=1, ttl=2,
                 seed=0, engines=("compact", "sparse", "dense"),
                 compact_budget=None, compress=None):
    # default engines = the single-device trio; delivery="sharded" has its
    # own parity suite (tests/test_sharded.py, forced multi-device mesh)
    out = {}
    for eng in engines:
        cfg = simlax.SimLaxConfig(
            ticks=ticks, train_interval=interval, latency=latency, ttl=ttl,
            record_every=max(1, ticks // 5), seed=seed, delivery=eng,
            compact_budget=compact_budget if eng == "compact" else None,
            compress=compress)
        sim = simlax.LaxSimulator(sc, topo, spec, IMPL2, cfg)
        out[eng] = sim.run()
    return out


def _assert_engine_parity(s, d):
    """Two delivery engines must replay the SAME event stream: integer
    state identical, float state identical up to summation order."""
    for k in ("broadcasts", "deliveries", "fedavg_rounds",
              "max_tick_deliveries"):
        assert s.stats[k] == d.stats[k], (k, s.stats[k], d.stats[k])
    np.testing.assert_array_equal(s.stats["broadcasts_per_node"],
                                  d.stats["broadcasts_per_node"])
    for k in ("arrive", "min_sender", "buf_cnt", "next_train"):
        np.testing.assert_array_equal(s.final_state[k], d.final_state[k],
                                      err_msg=k)
    for k in ("w_sum", "min_acc"):
        np.testing.assert_allclose(s.final_state[k], d.final_state[k],
                                   rtol=1e-6, atol=1e-6, err_msg=k)
    np.testing.assert_allclose(s.reputation, d.reputation, atol=1e-6)
    np.testing.assert_allclose(s.acc_history, d.acc_history, atol=1e-5)
    import jax
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32),
        rtol=1e-5, atol=1e-6), s.params, d.params)


@pytest.mark.parametrize(
    "kind,kw,ttl,latency,dead,stragglers,malicious,attack", [
        ("full", {}, 2, 1, (), None, (0,), "gaussian"),
        ("ring", {}, 3, 2, (), None, (), "gaussian"),
        ("kregular", {"degree": 3}, 2, 1, (5,), {1: 4}, (2,), "signflip"),
        ("erdos", {"p": 0.3}, 2, 2, (3,), None, (0, 1), "intermittent"),
        ("smallworld", {"degree": 2, "beta": 0.3}, 1, 1, (), {0: 3}, (4,),
         "freerider"),
    ])
@pytest.mark.usefixtures("check_tracer_leaks")
def test_delivery_engines_parity(kind, kw, ttl, latency, dead,
                                 stragglers, malicious, attack):
    """compact == sparse == dense on the same (scenario, topology, spec):
    the compact engine's slot-state layout and work-buffer compaction must
    replay the oracles' event stream bit-for-bit. Runs under
    jax.checking_leaks (conftest fixture): tracing any of the three
    engines must not leak a tracer out of its trace."""
    n = 14
    sc = scenarios.toy_scenario(n, dim=8, malicious=malicious)
    topo = T.make(kind, n, seed=2, **kw)
    lo = ttl * latency + 1  # stay out of the re-broadcast-overwrite regime
    spec = FederationSpec.build(
        n, malicious=malicious, attack=attack, dead=dead,
        stragglers=stragglers,
        initial_countdown=[1 + (3 * i) % lo for i in range(n)])
    out = _run_engines(sc, topo, spec, ticks=90, interval=(lo, lo + 4),
                       latency=latency, ttl=ttl)
    assert out["compact"].stats["deliveries"] > 0
    _assert_engine_parity(out["compact"], out["sparse"])
    _assert_engine_parity(out["sparse"], out["dense"])


def test_engine_parity_property():
    """Hypothesis sweep: random topology/ttl/latency/dead/straggler/attack
    combinations never separate compact, sparse and dense."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=8, deadline=None,
                  suppress_health_check=list(hyp.HealthCheck))
    @hyp.given(data=st.data())
    def run(data):
        n = data.draw(st.integers(6, 12), label="n")
        kind = data.draw(st.sampled_from(
            ["full", "ring", "kregular", "erdos", "smallworld"]),
            label="kind")
        ttl = data.draw(st.integers(1, 3), label="ttl")
        latency = data.draw(st.integers(1, 2), label="latency")
        seed = data.draw(st.integers(0, 5), label="seed")
        dead = data.draw(st.sets(st.integers(0, n - 1), max_size=2),
                         label="dead")
        malicious = data.draw(st.sets(st.integers(0, n - 1), max_size=2),
                              label="malicious")
        attack = data.draw(st.sampled_from(sorted(attacks.names())),
                           label="attack")
        strag = data.draw(st.dictionaries(
            st.integers(0, n - 1), st.integers(2, 4), max_size=2),
            label="stragglers")
        topo = T.make(kind, n, degree=2, p=0.4, seed=seed)
        sc = scenarios.toy_scenario(n, dim=4, malicious=tuple(malicious),
                                    seed=seed)
        lo = ttl * latency + 1
        spec = FederationSpec.build(
            n, malicious=tuple(malicious), attack=attack, dead=tuple(dead),
            stragglers=strag,
            initial_countdown=[1 + (3 * i) % (lo + 2) for i in range(n)])
        out = _run_engines(sc, topo, spec, ticks=50,
                           interval=(lo, lo + 3), latency=latency,
                           ttl=ttl, seed=seed)
        _assert_engine_parity(out["compact"], out["sparse"])
        _assert_engine_parity(out["sparse"], out["dense"])

    run()


def test_lenet_delivery_engines_parity():
    """The real-model scenario through all three engines at toy size:
    identical event stream, matching reputations/accuracy (receipt evals
    are actual LeNet forward passes, so any slot-buffer or work-buffer
    indexing slip shows up here)."""
    n = 6
    mal = (0,)
    sc = scenarios.lenet_scenario(n, alpha=1.0, malicious=mal, seed=0,
                                  pool=16, eval_size=8, test_size=16,
                                  train_steps=1, batch=4, lr=0.1)
    topo = T.kregular(n, 2)
    spec = FederationSpec.build(
        n, malicious=mal, initial_countdown=[1 + (3 * i) % 4 for i in range(n)])
    out = _run_engines(sc, topo, spec, ticks=16, interval=(4, 4),
                       latency=1, ttl=1)
    assert out["compact"].stats["deliveries"] > 0
    _assert_engine_parity(out["compact"], out["sparse"])
    _assert_engine_parity(out["sparse"], out["dense"])


def test_delivery_budget_bounds_due_pairs():
    """The static slot budget is the exact ttl-ball bound: never exceeded
    by (and on some tick equal to the max of) actual per-receiver
    deliveries."""
    n = 16
    topo = T.make("erdos", n, p=0.3, seed=3)
    budget = T.delivery_budget(topo.adj, 2)
    balls = T.ttl_ball_sizes(topo.adj, 2)
    assert budget == balls.max()
    assert (balls >= topo.degrees()).all()   # ball contains the neighbors
    assert T.delivery_budget(topo.adj, 1) == topo.degrees().max()
    full = T.full(n)
    assert T.delivery_budget(full.adj, 1) == n - 1
    assert T.delivery_budget(full.adj, 3) == n - 1   # ball saturates


@pytest.mark.parametrize("kind,kw", [
    ("ring", {}), ("kregular", {"degree": 2}), ("erdos", {"p": 0.35}),
    ("smallworld", {"degree": 2, "beta": 0.3}), ("full", {}),
])
@pytest.mark.parametrize("ttl", [1, 2, 3])
def test_delivery_budget_consistent_with_frontier_schedule(kind, kw, ttl):
    """The sparse engine's static budget vs the production schedule: the
    frontier lowering delivers each receiver exactly its ttl-ball, so the
    per-receiver schedule delivery counts must equal ``ttl_ball_sizes`` and
    never exceed ``delivery_budget`` — including on a dead-node-masked
    adjacency (the budget the lax engine actually allocates), where the
    masked ball can only shrink."""
    n = 12
    topo = T.make(kind, n, seed=4, **kw)
    sched = T.gossip_schedule(topo, ttl)
    per_receiver = sched.delivery_counts().sum(axis=1)
    balls = T.ttl_ball_sizes(topo.adj, ttl)
    np.testing.assert_array_equal(per_receiver, balls)
    assert per_receiver.max() <= T.delivery_budget(topo.adj, ttl)

    # dead-masked adjacency: flooding routes only through alive nodes —
    # exactly what LaxSimulator passes to delivery_budget
    dead = (1, 7)
    alive = np.ones((n,), bool)
    alive[list(dead)] = False
    masked = topo.adj & alive[None, :] & alive[:, None]
    masked_balls = T.ttl_ball_sizes(masked, ttl)
    assert (masked_balls <= balls).all()
    assert (masked_balls[list(dead)] == 0).all()
    assert T.delivery_budget(masked, ttl) <= T.delivery_budget(topo.adj, ttl)
    # the schedule over the alive-induced subgraph stays within the masked
    # budget (when that subgraph is still a valid connected gossip graph)
    sub = masked[np.ix_(alive, alive)]
    try:
        sub_topo = T.Topology("masked", sub)
    except ValueError:
        return  # masking isolated a node; nothing further to check
    if not sub_topo.is_connected():
        return
    sub_sched = T.gossip_schedule(sub_topo, ttl)
    sub_max = int(sub_sched.delivery_counts().sum(axis=1).max())
    assert sub_max <= T.delivery_budget(masked, ttl)


# ================================================ compaction edge cases
def test_compact_zero_delivery_ticks():
    """A run whose every tick is delivery-free (latency beyond the
    horizon): the compact work buffer never fills, no NaNs leak out of the
    dropped-item paths, and training still progresses."""
    n = 8
    sc = scenarios.toy_scenario(n)
    spec = FederationSpec.build(n, initial_countdown=[2] * n)
    cfg = simlax.SimLaxConfig(ticks=4, train_interval=(12, 12), latency=10,
                              ttl=1, record_every=2, seed=0,
                              delivery="compact")
    res = simlax.LaxSimulator(sc, T.full(n), spec, IMPL2, cfg).run()
    assert res.stats["deliveries"] == 0
    assert res.stats["max_tick_deliveries"] == 0
    assert res.stats["broadcasts"] == n          # everyone trained at t=2
    assert np.isfinite(res.acc_history).all()
    assert (res.final_state["w_sum"] == 0).all()
    assert (res.final_state["buf_cnt"] == 0).all()


def test_compact_all_receivers_dead():
    """Every node dead: no broadcasts, no deliveries, a degenerate (empty)
    masked adjacency — the compact budget floors at 1 and the run is a
    clean no-op."""
    n = 6
    sc = scenarios.toy_scenario(n)
    spec = FederationSpec.build(n, dead=tuple(range(n)))
    cfg = simlax.SimLaxConfig(ticks=30, train_interval=(4, 4), latency=1,
                              ttl=2, record_every=10, seed=0,
                              delivery="compact")
    sim = simlax.LaxSimulator(sc, T.full(n), spec, IMPL2, cfg)
    assert sim.compact_budget == 1
    res = sim.run()
    assert res.stats["broadcasts"] == 0
    assert res.stats["deliveries"] == 0
    np.testing.assert_allclose(res.params["w"], sc.init_params_stacked()["w"])


def test_compact_buffer_exactly_full():
    """Synchronized countdowns on a full graph land every (dst, src) pair
    on one tick: the due count hits the exact compaction_budget bound
    (n*(n-1)) and the run still matches the oracles — the boundary where
    off-by-one slot arithmetic would silently drop receipts."""
    n = 8
    sc = scenarios.toy_scenario(n)
    spec = FederationSpec.build(n, initial_countdown=[3] * n)
    out = _run_engines(sc, T.full(n), spec, ticks=40, interval=(5, 5),
                       latency=1, ttl=1)
    res = out["compact"]
    assert res.stats["compact_budget"] == n * (n - 1)
    assert res.stats["max_tick_deliveries"] == n * (n - 1)  # exactly full
    _assert_engine_parity(res, out["sparse"])
    _assert_engine_parity(out["sparse"], out["dense"])


def test_compact_overflow_fails_fast():
    """A cfg.compact_budget override below the tick's actual due count must
    raise from run() — never silently drop receipts."""
    n = 8
    sc = scenarios.toy_scenario(n)
    spec = FederationSpec.build(n, initial_countdown=[3] * n)
    cfg = simlax.SimLaxConfig(ticks=20, train_interval=(5, 5), latency=1,
                              ttl=1, record_every=5, seed=0,
                              delivery="compact", compact_budget=5)
    sim = simlax.LaxSimulator(sc, T.full(n), spec, IMPL2, cfg)
    assert sim.compact_budget == 5               # override honored
    with pytest.raises(RuntimeError, match="compact delivery overflow"):
        sim.run()
    with pytest.raises(ValueError, match="compact_budget"):
        simlax.LaxSimulator(sc, T.full(n), spec, IMPL2,
                            simlax.SimLaxConfig(delivery="compact",
                                                compact_budget=0))


def test_compact_budget_override_with_headroom_matches_oracles():
    """A tight-but-sufficient override (staggered phases) is the bench's
    operating point: parity must hold and the recorded max tick activity
    must stay under the override."""
    n, interval = 16, 8
    sc = scenarios.toy_scenario(n)
    topo = T.kregular(n, 2)
    spec = FederationSpec.build(
        n, initial_countdown=[1 + (3 * i) % interval for i in range(n)])
    default_w = simlax.LaxSimulator(
        sc, topo, spec, IMPL2,
        simlax.SimLaxConfig(ticks=1, train_interval=(interval, interval),
                            latency=1, ttl=2, delivery="compact")
    ).compact_budget
    out = _run_engines(sc, topo, spec, ticks=64,
                       interval=(interval, interval), latency=1, ttl=2,
                       compact_budget=default_w // 2)
    res = out["compact"]
    assert res.stats["compact_budget"] == default_w // 2
    assert res.stats["max_tick_deliveries"] <= default_w // 2
    _assert_engine_parity(res, out["sparse"])


# ============================================== re-broadcast overwrite caveat
def test_rebroadcast_overwrite_warns_and_pins_heap_divergence():
    """When min train interval < ttl * latency a node re-broadcasts while
    its previous model is still in flight; the single in-flight snapshot
    per (dst, src) pair overwrites the pending delivery. The constructor
    must warn, and the documented effect — fewer deliveries than the heap
    reference, which keeps every snapshot — is pinned here (ring, hop-2
    delay 4 > interval 3, so every hop-2 delivery is overwritten).
    Equality is the safe boundary (deliveries are processed before the
    same-tick re-broadcast): no warning, exact heap parity."""
    n, interval, latency, ttl, ticks = 8, 3, 2, 2, 60
    sc = scenarios.toy_scenario(n)
    topo = T.ring(n)
    spec = FederationSpec.build(n, initial_countdown=[interval] * n)
    cfg = simlax.SimLaxConfig(ticks=ticks, train_interval=(interval, interval),
                              latency=latency, ttl=ttl, record_every=20,
                              seed=0)
    with pytest.warns(UserWarning, match="re-broadcast"):
        sim = simlax.LaxSimulator(sc, topo, spec, IMPL2, cfg)
    res = sim.run()

    heap = scenarios.make_heap_simulator(sc, topo, spec, IMPL2, cfg)
    heap.run()

    assert res.stats["broadcasts"] == heap.stats["tx_sent"]
    lost = heap.stats["tx_delivered"] - res.stats["deliveries"]
    # every broadcast's 2 hop-2 deliveries are overwritten by the next
    # broadcast (modulo the in-flight tail) -> a strict, large deficit
    assert lost > res.stats["broadcasts"], (lost, res.stats)
    # the boundary (interval == ttl*latency) is safe: same-tick deliveries
    # are processed before the re-broadcast -> no warning, exact heap parity
    safe_interval = ttl * latency
    spec2 = FederationSpec.build(n, initial_countdown=[safe_interval] * n)
    cfg2 = simlax.SimLaxConfig(
        ticks=ticks, train_interval=(safe_interval, safe_interval),
        latency=latency, ttl=ttl, record_every=20, seed=0)
    import warnings as _warnings
    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        sim2 = simlax.LaxSimulator(sc, topo, spec2, IMPL2, cfg2)
    res2 = sim2.run()
    heap2 = scenarios.make_heap_simulator(sc, topo, spec2, IMPL2, cfg2)
    heap2.run()
    assert res2.stats["deliveries"] == heap2.stats["tx_delivered"]


# ======================================================== result-object cover
def test_mean_reputation_excludes_self_view():
    rep = np.full((4, 4), 1.0, np.float32)
    rep[:, 2] = 0.25          # everyone scores node 2 low ...
    rep[2, 2] = 1.0           # ... except node 2's (ignored) self-view
    res = simlax.SimLaxResult(
        params={}, reputation=rep, acc_history=np.zeros((1, 4)),
        record_ticks=np.zeros((1,)), stats={})
    assert res.mean_reputation(2) == pytest.approx(0.25)
    assert res.mean_reputation(0) == pytest.approx(1.0)


# ================================================== real-model (LeNet) slow
@pytest.mark.slow
def test_lenet_smoke():
    """CI smoke: 8 nodes x 30 ticks of the real-model scenario through the
    sparse engine — exercises Dirichlet shards, vmapped LeNet train/eval,
    poison, FedAvg, reputation end-to-end."""
    n = 8
    mal = (0,)
    sc = scenarios.lenet_scenario(n, alpha=0.5, malicious=mal, seed=0,
                                  pool=96, eval_size=16, test_size=128,
                                  train_steps=2, batch=16, lr=0.12)
    topo = T.kregular(n, 2)
    cfg = simlax.SimLaxConfig(ticks=30, train_interval=(6, 6), latency=1,
                              ttl=2, record_every=10, seed=0,
                              delivery="sparse")
    spec = FederationSpec.build(
        n, malicious=mal, initial_countdown=[1 + (5 * i) % 6 for i in range(n)])
    sim = simlax.LaxSimulator(sc, topo, spec, IMPL2, cfg)
    res = sim.run()
    assert res.stats["delivery_budget"] == 7   # kregular(8,2) ttl=2 ball
    assert res.stats["deliveries"] > 0
    assert res.stats["broadcasts"] >= n
    assert np.isfinite(res.acc_history).all()
    assert (res.acc_history >= 0).all() and (res.acc_history <= 1).all()
    # training moved the federation off its random-init accuracy
    assert res.acc_history[-1].mean() > res.acc_history[0].mean()


@pytest.mark.slow
def test_lenet_poisoned_federation_reaches_paper_accuracy():
    """§VI-D acceptance: 20% poisoned senders, non-I.I.D. Dirichlet(1)
    shards — the reputation-weighted federation still reaches >=90% mean
    test accuracy AND drives the poisoners' reputation below the honest
    nodes' (~7 min on 2 CPU cores; the sparse engine is what makes the
    receipt-eval bill payable at all)."""
    n = 10
    sc, spec, topo, cfg = scenarios.lenet_paper_setup(n)
    mal = spec.malicious
    assert mal == (0, 1)    # 20% poisoned senders
    sim = simlax.LaxSimulator(sc, topo, spec, IMPL2, cfg)
    res = sim.run()
    honest = [i for i in range(n) if i not in mal]
    final_acc = res.acc_history[-1][honest].mean()
    rep_mal = np.mean([res.mean_reputation(i) for i in mal])
    rep_hon = np.mean([res.mean_reputation(i) for i in honest])
    assert final_acc >= 0.90, (final_acc, res.acc_history[:, honest].mean(1))
    assert rep_mal < rep_hon - 0.1, (rep_mal, rep_hon)


# ------------------------------------------------- quantized wire payloads
def test_compress_rejects_unknown_mode():
    with pytest.raises(ValueError, match="compress"):
        simlax.SimLaxConfig(compress="fp8")
        sc = scenarios.toy_scenario(4)
        simlax.LaxSimulator(sc, T.full(4), FederationSpec.build(4), IMPL2,
                            simlax.SimLaxConfig(compress="fp8"))


def test_compress_int8_changes_the_wire_payload():
    """Guard against the compression path silently becoming a no-op: the
    int8 run's broadcast payloads must differ from the fp32 run's (same
    seed/schedule), land exactly on the quantization grid, and stay close."""
    from repro.core import compression
    n = 6
    sc = scenarios.toy_scenario(n)
    topo = T.full(n)
    spec = FederationSpec.build(n, initial_countdown=[2 + i for i in range(n)])
    out = {}
    for compress in (None, "int8"):
        cfg = simlax.SimLaxConfig(ticks=30, train_interval=(8, 8), latency=1,
                                  ttl=1, record_every=10, seed=0,
                                  compress=compress)
        out[compress] = simlax.LaxSimulator(sc, topo, spec, IMPL2, cfg).run()
    raw, q8 = out[None].sent["w"], out["int8"].sent["w"]
    assert not np.array_equal(raw, q8)
    np.testing.assert_allclose(raw, q8, rtol=0.05, atol=1e-6)
    # the int8 payload must be its own quantization fixed point
    refix = compression.roundtrip_tree({"w": np.asarray(q8)})["w"]
    np.testing.assert_array_equal(np.asarray(refix), q8)
    # and the dtype-derived wire model must reflect the compression
    assert out["int8"].stats["compress"] == "int8"
    assert out[None].stats["compress"] is None
    assert (out["int8"].stats["broadcast_bytes"]
            < 0.3 * out[None].stats["broadcast_bytes"])


@pytest.mark.parametrize("attack", ["gaussian", "signflip"])
@pytest.mark.usefixtures("check_tracer_leaks")
def test_delivery_engines_parity_int8(attack):
    """The engine-parity pin under wire quantization: the sender-side
    round-trip happens once in do_train (every engine reads the same
    ``sent`` state), so compact == sparse == dense must hold bit-for-bit
    with compress="int8" exactly as without."""
    n = 12
    mal = (0, 4)
    sc = scenarios.toy_scenario(n, dim=8, malicious=mal)
    topo = T.make("kregular", n, degree=3, seed=2)
    spec = FederationSpec.build(
        n, malicious=mal, attack=attack, dead=(7,), stragglers={1: 3},
        initial_countdown=[1 + (3 * i) % 4 for i in range(n)])
    out = _run_engines(sc, topo, spec, ticks=80, interval=(4, 7),
                       latency=1, ttl=2, compress="int8")
    assert out["compact"].stats["deliveries"] > 0
    _assert_engine_parity(out["compact"], out["sparse"])
    _assert_engine_parity(out["sparse"], out["dense"])
    for eng in ("sparse", "dense"):
        np.testing.assert_array_equal(out["compact"].sent["w"],
                                      out[eng].sent["w"])


@pytest.mark.parametrize("attack", sorted(attacks.names()))
def test_attack_stream_bitwise_parity_int8(attack):
    """Heap <-> lax bitwise attack-payload parity survives quantization:
    both engines round-trip the post-attack payload through the SAME
    repro.core.compression calls (stacked vs per-node application is
    bitwise identical because blocks never cross the last axis), so the
    quantized wire payloads agree bit-for-bit — including `scaled`, whose
    pre-quantization float-epsilon drift is absorbed by the int8 grid."""
    import dataclasses
    rep = dataclasses.replace(IMPL2, buffer_size=10 ** 6)  # FedAvg never fires
    n, ticks, interval = 8, 60, 8
    mal = (0, 3)
    sc = scenarios.toy_scenario(n)
    topo = T.full(n)
    spec = FederationSpec.build(
        n, malicious=mal, attack=attack,
        initial_countdown=[1 + (3 * i) % interval for i in range(n)])
    cfg = simlax.SimLaxConfig(ticks=ticks, train_interval=(interval, interval),
                              latency=1, ttl=2, record_every=10, seed=0,
                              compress="int8")
    heap = scenarios.make_heap_simulator(sc, topo, spec, rep, cfg)
    heap.run()
    res = simlax.LaxSimulator(sc, topo, spec, rep, cfg).run()
    assert res.stats["broadcasts"] == heap.stats["tx_sent"]
    assert res.stats["deliveries"] == heap.stats["tx_delivered"]
    nodes = list(heap.nodes.values())
    for i in range(n):   # attackers AND honest nodes ship quantized payloads
        heap_payload = np.asarray(nodes[i].last_broadcast["w"])
        lax_payload = res.sent["w"][i]
        if attack == "scaled" and i in mal:
            # the engines' pre-quantization payloads differ by float
            # epsilon (vmap-in-scan vs single-jit fusion); quantization
            # almost always rounds both to the same grid point, but an
            # input sitting on a .5 boundary can flip one int8 step
            scale = np.abs(heap_payload).max() / 127
            np.testing.assert_allclose(heap_payload, lax_payload,
                                       atol=1.01 * scale)
        else:
            np.testing.assert_array_equal(heap_payload, lax_payload)


def test_heap_lax_aggregate_parity_int8():
    """The full acceptance comparison (FedAvg enabled) under int8: event
    streams identical, aggregate accuracy/reputation within the same
    tolerances as the uncompressed parity test, attacker still isolated."""
    n, ticks, interval = 12, 160, 12
    sc = scenarios.toy_scenario(n, malicious=(0,))
    topo = T.full(n)
    spec = FederationSpec.build(n, malicious=(0,),
                                initial_countdown=_staggered(n, interval))
    cfg = simlax.SimLaxConfig(ticks=ticks, train_interval=(interval, interval),
                              latency=1, ttl=2, record_every=10, seed=0,
                              compress="int8")
    heap = scenarios.make_heap_simulator(sc, topo, spec, IMPL2, cfg)
    heap.run()
    nodes = list(heap.nodes.values())
    honest = nodes[1:]
    heap_acc = np.mean([nd.accuracy_history[-1][1] for nd in honest])
    heap_mal = mean_reputation(honest, nodes[0].info.address)
    res = simlax.LaxSimulator(sc, topo, spec, IMPL2, cfg).run()
    lax_acc = res.acc_history[-1][1:].mean()
    lax_mal = res.mean_reputation(0)
    assert res.stats["broadcasts"] == heap.stats["tx_sent"]
    assert res.stats["deliveries"] == heap.stats["tx_delivered"]
    assert abs(heap_acc - lax_acc) < 0.02, (heap_acc, lax_acc)
    assert abs(heap_mal - lax_mal) < 0.1, (heap_mal, lax_mal)
    assert lax_mal < 0.9 and heap_mal < 0.9


@pytest.mark.slow
def test_lenet_poisoned_federation_reaches_paper_accuracy_int8():
    """§VI-D acceptance with quantized wire payloads: shipping int8
    broadcasts (4x fewer link bytes) must not cost the headline result —
    honest nodes still clear 90% mean test accuracy under 20% poisoning
    and the reputation system still separates the poisoners."""
    n = 10
    sc, spec, topo, cfg = scenarios.lenet_paper_setup(n, compress="int8")
    mal = spec.malicious
    sim = simlax.LaxSimulator(sc, topo, spec, IMPL2, cfg)
    res = sim.run()
    honest = [i for i in range(n) if i not in mal]
    final_acc = res.acc_history[-1][honest].mean()
    rep_mal = np.mean([res.mean_reputation(i) for i in mal])
    rep_hon = np.mean([res.mean_reputation(i) for i in honest])
    assert final_acc >= 0.90, (final_acc, res.acc_history[:, honest].mean(1))
    assert rep_mal < rep_hon - 0.1, (rep_mal, rep_hon)
