"""Per-arch smoke tests: reduced same-family config, one train step on CPU,
output shapes + finite loss; decode/prefill consistency for cache-bearing
archs (the assigned-architecture deliverable's smoke requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, cell_status, get_config, smoke_config
from repro.models import transformer
from repro.train import step as step_lib

B, S = 2, 64


def _batch(cfg):
    if cfg.frontend == "audio":
        return {
            "frame_embeds": jnp.ones((B, S, cfg.d_model), jnp.bfloat16),
            "labels": jnp.ones((B, S), jnp.int32),
            "loss_mask": jnp.ones((B, S), jnp.float32),
        }
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(0), (B, S), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                     cfg.vocab_size),
    }
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.ones(
            (B, cfg.num_patch_tokens, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = smoke_config(arch)
    state, _ = step_lib.init_train_state(cfg, jax.random.PRNGKey(0))
    ts = jax.jit(step_lib.make_train_step(cfg))
    state2, metrics = ts(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    assert int(state2["step"]) == 1
    # some params changed (hubert's embed table gets no grads — frame-embed
    # inputs — so check across all leaves)
    changed = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(state2["params"])))
    assert changed


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_config(a).supports_decode])
def test_prefill_then_decode_consistency(arch):
    """Prefill S tokens then decode token S must match a full forward of
    S+1 tokens (cache correctness across every layer kind).

    xLSTM runs this check in fp32 with a per-layer-amplification-aware
    tolerance: its chunkwise prefill and single-step decode recurrence are
    algebraically identical but float-diverge ~0.5% relative PER LAYER
    (signed cancellation in the stabilized q·n denominator), and that
    deviation compounds through the recurrent residual stream — measured
    here: ~0.23 max / ~0.04 mean logit gap over 8 layers in fp32 (bf16 is
    the same magnitude, so the gap is formulation, not precision). A real
    cache bug produces O(1)+ gaps and argmax disagreement, both still
    well outside these bounds; the single-layer gap that anchors the
    per-layer constant is pinned by test_xlstm_single_layer_decode_gap."""
    cfg = smoke_config(arch)
    recurrent_chunkwise = arch == "xlstm-125m"
    dtype = jnp.float32 if recurrent_chunkwise else jnp.bfloat16
    params, _ = transformer.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks[:, :S]}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.ones((B, cfg.num_patch_tokens, cfg.d_model),
                                         jnp.bfloat16) * 0.01
    cache, _ = transformer.cache_init(cfg, B, S + 8)
    logits_p, cache = jax.jit(
        lambda p, b, c: transformer.prefill(p, cfg, b, c, dtype=dtype))(
        params, batch, cache)
    logits_d, _ = jax.jit(
        lambda p, c, t, pos: transformer.decode_step(p, cfg, t, c, pos,
                                                     dtype=dtype))(
        params, cache, toks[:, S:S + 1], jnp.asarray(S, jnp.int32))

    full_batch = dict(batch, tokens=toks)
    cache2, _ = transformer.cache_init(cfg, B, S + 8)
    logits_full, _ = jax.jit(
        lambda p, b, c: transformer.prefill(p, cfg, b, c, dtype=dtype))(
        params, full_batch, cache2)
    d = np.asarray(logits_d, np.float32)
    f = np.asarray(logits_full, np.float32)
    if recurrent_chunkwise:
        per_layer = 0.06   # 2x the measured worst per-layer amplification
        np.testing.assert_allclose(d, f, rtol=0.1,
                                   atol=per_layer * cfg.num_layers)
        assert np.abs(d - f).mean() < 0.015 * cfg.num_layers
        assert (d.argmax(-1) == f.argmax(-1)).mean() > 0.95
    else:
        np.testing.assert_allclose(d, f, rtol=0.08, atol=0.08)


def test_xlstm_single_layer_decode_gap():
    """Anchors the per-layer tolerance used above: ONE fp32 mLSTM layer's
    chunkwise-prefill vs decode-step outputs at the same position differ
    by well under the 0.06/layer budget, and the prefix (both chunkwise)
    is exact."""
    from repro.models import xlstm as xlstm_lib
    cfg = smoke_config("xlstm-125m")
    Bx, Sx = 2, 64
    p, _ = xlstm_lib.mlstm_init(jax.random.PRNGKey(0), cfg)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1),
                                (Bx, Sx + 1, cfg.d_model), jnp.float32)
    y_full, _ = xlstm_lib.mlstm_apply(
        p, cfg, x, cache=xlstm_lib.mlstm_state_init(cfg, Bx))
    y_pre, cache = xlstm_lib.mlstm_apply(
        p, cfg, x[:, :Sx], cache=xlstm_lib.mlstm_state_init(cfg, Bx))
    y_last, _ = xlstm_lib.mlstm_decode(p, cfg, x[:, Sx:], cache)
    np.testing.assert_array_equal(np.asarray(y_full[:, :Sx]),
                                  np.asarray(y_pre))
    gap = np.abs(np.asarray(y_full[:, -1]) - np.asarray(y_last[:, 0])).max()
    assert gap < 0.03, gap


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_abstract_init(arch):
    """Full (unreduced) configs build abstract params with sane counts."""
    cfg = get_config(arch)
    params, axes = step_lib.abstract_params(cfg)
    n = sum(x.size for x in jax.tree.leaves(params))
    expected_scale = {
        "recurrentgemma-2b": 2e9, "chatglm3-6b": 6e9, "command-r-35b": 35e9,
        "gemma3-12b": 12e9, "llama3-8b": 8e9, "llava-next-mistral-7b": 7e9,
        "hubert-xlarge": 1e9, "llama4-maverick-400b-a17b": 400e9,
        "dbrx-132b": 132e9, "xlstm-125m": 125e6,
    }[arch]
    assert 0.4 * expected_scale < n < 2.6 * expected_scale, (arch, n)


def test_cell_status_skip_rules():
    assert cell_status(get_config("hubert-xlarge"), SHAPES["decode_32k"])[0] is False
    assert cell_status(get_config("llama3-8b"), SHAPES["long_500k"])[0] is False
    assert cell_status(get_config("recurrentgemma-2b"), SHAPES["long_500k"])[0]
    assert cell_status(get_config("gemma3-12b"), SHAPES["long_500k"])[0]
    assert cell_status(get_config("xlstm-125m"), SHAPES["long_500k"])[0]
