"""The CI perf-regression gate (benchmarks/check_regress.py): extraction,
pass/fail verdicts, the baseline-refresh (--update) workflow, and the
seeded-slowdown self-test CI runs before trusting the gate."""
import copy
import json
import os
import sys

import pytest

# benchmarks/ is a top-level namespace package next to tests/
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks import check_regress as cr  # noqa: E402


def _bench_json():
    """A minimal but structurally faithful bench_gossip.json."""
    return {
        "frontier_vs_chain": [
            {"kind": "erdos", "nodes": 12, "ttl": 2, "schedule": "frontier",
             "coverage": 1.0, "missing_pairs": 0, "num_collectives": 21,
             "collectives_per_delivered_pair": 0.3},
            {"kind": "erdos", "nodes": 12, "ttl": 2, "schedule": "chain",
             "coverage": 0.45, "missing_pairs": 38, "num_collectives": 16,
             "collectives_per_delivered_pair": 0.5},
        ],
        "simulator": {"nodes": 256, "heap_ticks": 4, "lax_ticks": 50,
                      "speedup": 20.0, "lax_s_per_tick": 0.002},
        "sparse_vs_dense": {"nodes": 256, "ticks_pair": [12, 96],
                            "speedup": 4.0,
                            "sparse_s_per_tick": 0.001,
                            "dense_s_per_tick": 0.004},
        "compact_vs_sparse": {"nodes": 2048, "ticks_pair": [24, 240],
                              "speedup": 2.5,
                              "compact_s_per_tick": 0.01,
                              "sparse_s_per_tick": 0.025},
    }


def _write(tmp_path, name, data):
    p = tmp_path / name
    p.write_text(json.dumps(data))
    return str(p)


# keep main() hermetic in tests: never pick up a real
# experiments/bench_sweep.json or hlo_audit.json from the working directory
NOHLO = ["--current-hlo", "/nonexistent/hlo_audit.json"]
NOSWEEP = ["--current-sweep", "/nonexistent/bench_sweep.json", *NOHLO]


def test_extract_trims_to_gated_metrics():
    out = cr.extract(_bench_json())
    assert out["schedule"]["erdos,n=12,ttl=2,frontier"] == {
        "num_collectives": 21, "coverage": 1.0, "missing_pairs": 0}
    assert out["speedups"] == {"simulator": 20.0, "sparse_vs_dense": 4.0,
                               "compact_vs_sparse": 2.5}
    assert out["times"]["compact_vs_sparse.compact_s_per_tick"] == 0.01
    assert out["scale"]["compact_vs_sparse"] == [2048, [24, 240]]


def test_gate_passes_identical_run_and_update_bootstraps(tmp_path):
    cur = _write(tmp_path, "current.json", _bench_json())
    base = str(tmp_path / "baselines" / "bench_gossip.json")
    # no baseline yet -> setup failure telling the operator to --update
    assert cr.main(["--current", cur, "--baseline", base, *NOSWEEP]) == 2
    assert cr.main(["--current", cur, "--baseline", base, "--update",
                    *NOSWEEP]) == 0
    assert cr.main(["--current", cur, "--baseline", base, *NOSWEEP]) == 0


@pytest.mark.parametrize("doctor,category", [
    (lambda d: d["frontier_vs_chain"][0].update(num_collectives=22),
     "schedule"),
    (lambda d: d["frontier_vs_chain"][0].update(coverage=0.9,
                                                missing_pairs=3),
     "schedule"),
    (lambda d: d["compact_vs_sparse"].update(speedup=1.0), "speedup"),
    (lambda d: d.pop("compact_vs_sparse"), "speedup"),  # vanished line
    (lambda d: d["compact_vs_sparse"].update(compact_s_per_tick=0.05),
     "per_tick"),
])
def test_gate_fails_on_seeded_slowdown(tmp_path, doctor, category, capsys):
    base_data = _bench_json()
    seeded = copy.deepcopy(base_data)
    doctor(seeded)
    cur = _write(tmp_path, "current.json", seeded)
    base = _write(tmp_path, "baseline.json", cr.extract(base_data))
    assert cr.main(["--current", cur, "--baseline", base, *NOSWEEP]) == 1
    out = capsys.readouterr().out
    assert f"regress,{category}" in out and "FAIL" in out


def test_gate_tolerates_within_threshold_drift(tmp_path):
    base_data = _bench_json()
    drifted = copy.deepcopy(base_data)
    # 20% slower: inside the default 30% tolerance; the speedup drop stays
    # above the compact acceptance floor (2.0), which caps the band
    drifted["compact_vs_sparse"]["compact_s_per_tick"] *= 1.2
    drifted["compact_vs_sparse"]["speedup"] = 2.1
    cur = _write(tmp_path, "current.json", drifted)
    base = _write(tmp_path, "baseline.json", cr.extract(base_data))
    assert cr.main(["--current", cur, "--baseline", base, *NOSWEEP]) == 0
    # a tighter --tolerance turns the same wall drift into a failure
    assert cr.main(["--current", cur, "--baseline", base,
                    "--tolerance", "0.1", *NOSWEEP]) == 1


def test_speedup_band_capped_by_acceptance_floor(tmp_path):
    """Wall-ratio noise above the documented contract must not flake the
    gate: a lucky 4.0x compact baseline would put the 30% band at 2.8x,
    above the >=2x acceptance contract — the cap (min(band, floor)) lets a
    noisy-but-conforming 2.2x pass, while below-contract still fails."""
    base_data = _bench_json()
    base_data["compact_vs_sparse"]["speedup"] = 4.0    # lucky run
    base = _write(tmp_path, "baseline.json", cr.extract(base_data))
    noisy = copy.deepcopy(base_data)
    noisy["compact_vs_sparse"]["speedup"] = 2.2   # < band 2.8, > floor 2.0
    cur = _write(tmp_path, "current.json", noisy)
    assert cr.main(["--current", cur, "--baseline", base, *NOSWEEP]) == 0
    below = copy.deepcopy(base_data)
    below["compact_vs_sparse"]["speedup"] = 1.9   # < band AND < floor
    cur2 = _write(tmp_path, "current2.json", below)
    assert cr.main(["--current", cur2, "--baseline", base, *NOSWEEP]) == 1


def test_gate_skips_mode_mismatched_rows(tmp_path, capsys):
    """quick vs full runs use different N / tick windows for some lines:
    those rows must be skipped (with a visible line), not mis-compared."""
    base_data = _bench_json()
    other_mode = copy.deepcopy(base_data)
    other_mode["sparse_vs_dense"].update(nodes=512, speedup=1.0)
    other_mode["compact_vs_sparse"].update(ticks_pair=[48, 480],
                                           compact_s_per_tick=9.9)
    cur = _write(tmp_path, "current.json", other_mode)
    base = _write(tmp_path, "baseline.json", cr.extract(base_data))
    assert cr.main(["--current", cur, "--baseline", base, *NOSWEEP]) == 0
    out = capsys.readouterr().out
    assert "regress,speedup(sparse_vs_dense),skip" in out
    assert "regress,per_tick(compact_vs_sparse.compact_s_per_tick),skip" \
        in out


def _sweep_json(speedup=6.0):
    return {"sweep_batched_vs_loop": {
        "nodes": 256, "batch": 32, "ticks": 120, "speedup": speedup,
        "batched_s_per_fed": 0.2, "loop_s_per_fed": 0.2 * speedup,
        "bitwise_equal": True}}


def test_sweep_rows_merge_and_gate(tmp_path, capsys):
    """bench_sweep.json merges into the same gate: the batched_vs_loop
    speedup band is capped by the 5x acceptance contract (a lucky 10x
    baseline must not flake a conforming 6x run), below-contract fails,
    and a missing sweep JSON is a vanished gated row, not a silent skip."""
    base_data = _bench_json()
    cur = _write(tmp_path, "current.json", _bench_json())
    merged = dict(base_data, **_sweep_json(10.0))   # lucky baseline run
    base = _write(tmp_path, "baseline.json", cr.extract(merged))
    # 6.0 < the 7.0 relative band but >= the 5x contract -> pass
    sw = _write(tmp_path, "sweep.json", _sweep_json(6.0))
    assert cr.main(["--current", cur, "--current-sweep", sw,
                    "--baseline", base, *NOHLO]) == 0
    # below the 5x contract -> FAIL
    sw_bad = _write(tmp_path, "sweep_bad.json", _sweep_json(4.4))
    assert cr.main(["--current", cur, "--current-sweep", sw_bad,
                    "--baseline", base, *NOHLO]) == 1
    assert "speedup(sweep_batched_vs_loop)" in capsys.readouterr().out
    # sweep bench silently dropped from CI -> vanished-row FAIL
    assert cr.main(["--current", cur, "--baseline", base, *NOSWEEP]) == 1
    # a different batch geometry is a scale mismatch -> skip, not compare
    other = _sweep_json(1.0)
    other["sweep_batched_vs_loop"]["batch"] = 8
    sw_other = _write(tmp_path, "sweep_other.json", other)
    assert cr.main(["--current", cur, "--current-sweep", sw_other,
                    "--baseline", base, *NOHLO]) == 0
    assert "regress,speedup(sweep_batched_vs_loop),skip" in \
        capsys.readouterr().out


def _hlo_json(ok=True, collectives=8):
    return {"hlo_audit": {
        "round/ring/ttl1/int8": {
            "ok": ok, "collectives": collectives,
            "schedule_collectives": 2, "buffers_per_step": 4,
            "permute_dtypes": ["f32", "s8"], "permute_bytes": 4608,
            "problems": [] if ok else ["int8 wire is not s8-dominated"]},
        "retrace/single": {"ok": True, "collectives": 0, "traces": 1,
                           "problems": []},
    }}


def test_hlo_rows_merge_and_gate(tmp_path, capsys):
    """hlo_audit.json merges like the sweep JSON, and its rows gate with
    no tolerance band: ok=false fails with the audit's problem text,
    collective growth fails, a vanished audit cell fails, and an identical
    re-run passes."""
    cur = _write(tmp_path, "current.json", _bench_json())
    merged = dict(_bench_json(), **_hlo_json())
    base = _write(tmp_path, "baseline.json", cr.extract(merged))
    hlo = _write(tmp_path, "hlo.json", _hlo_json())
    ok_args = ["--current", cur, "--current-hlo", hlo, "--baseline", base,
               "--current-sweep", "/nonexistent/bench_sweep.json"]
    assert cr.main(ok_args) == 0
    assert "regress,hlo(round/ring/ttl1/int8),ok" in capsys.readouterr().out
    # an audit cell flipping to failed carries its problem text into CI
    hlo_bad = _write(tmp_path, "hlo_bad.json", _hlo_json(ok=False))
    assert cr.main(ok_args[:3] + [hlo_bad] + ok_args[4:]) == 1
    assert "not s8-dominated" in capsys.readouterr().out
    # collective-permute growth on an ok cell is a lowering regression
    hlo_grow = _write(tmp_path, "hlo_grow.json", _hlo_json(collectives=12))
    assert cr.main(ok_args[:3] + [hlo_grow] + ok_args[4:]) == 1
    assert "8->12" in capsys.readouterr().out
    # the audit silently dropped from CI -> vanished-row FAIL
    assert cr.main(ok_args[:3] + ["/nonexistent/hlo.json"]
                   + ok_args[4:]) == 1


def test_extract_trims_hlo_rows_to_structural_facts():
    out = cr.extract(_hlo_json())
    row = out["hlo"]["round/ring/ttl1/int8"]
    assert row == {"ok": True, "collectives": 8, "problems": []}


def test_self_test_detects_all_categories():
    assert cr.self_test(0.30) == 0


def test_missing_current_is_actionable(tmp_path, capsys):
    assert cr.main(["--current", str(tmp_path / "nope.json")]) == 2
    assert "bench_gossip" in capsys.readouterr().out
