"""delivery="sharded": the shard_map multi-device engine must be BITWISE
identical to delivery="compact" — same scatter-add structure, same key
streams, the node axis merely partitioned over the mesh (docs/SCALING.md).

The multi-device cases run in a fresh interpreter with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (conftest
``subprocess_runner``); the in-process cases exercise the S=1 single-device
fallback, which lowers through the same shard_map path."""
import numpy as np
import pytest

from repro.chain import scenarios, simlax
from repro.chain.attacks import (BatchedFederationSpec, FederationSpec,
                                 MembershipSchedule)
from repro.core import topology as T
from repro.core.reputation import IMPL2


def _assert_bitwise(a, b):
    """Full-result bitwise equality — stricter than the cross-engine
    allclose contract in tests/test_simlax.py, per the sharded pin."""
    import jax
    for k in ("broadcasts", "deliveries", "fedavg_rounds",
              "max_tick_deliveries"):
        assert a.stats[k] == b.stats[k], (k, a.stats[k], b.stats[k])
    np.testing.assert_array_equal(a.stats["broadcasts_per_node"],
                                  b.stats["broadcasts_per_node"])
    for k in a.final_state:
        if k in b.final_state:
            np.testing.assert_array_equal(np.asarray(a.final_state[k]),
                                          np.asarray(b.final_state[k]),
                                          err_msg=k)
    np.testing.assert_array_equal(a.reputation, b.reputation)
    np.testing.assert_array_equal(a.acc_history, b.acc_history)
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a.params, b.params)
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a.sent, b.sent)


def _pair(sc, topo, spec, *, ticks, interval, ttl=2, compress=None,
          shards=None):
    out = []
    for eng in ("compact", "sharded"):
        cfg = simlax.SimLaxConfig(
            ticks=ticks, train_interval=(interval, interval), latency=1,
            ttl=ttl, record_every=8, seed=0, delivery=eng,
            shards=shards if eng == "sharded" else None, compress=compress)
        out.append(simlax.LaxSimulator(sc, topo, spec, IMPL2, cfg).run())
    return out


# ============================================== single-device (S=1) fallback
@pytest.mark.parametrize("compress", [None, "int8"])
def test_sharded_single_device_matches_compact_bitwise(compress):
    n, interval = 8, 6
    sc = scenarios.toy_scenario(n, dim=8, malicious=(0,))
    spec = FederationSpec.build(
        n, malicious=(0,),
        initial_countdown=[3 + (7 * i) % interval for i in range(n)])
    a, b = _pair(sc, T.full(n), spec, ticks=48, interval=interval,
                 compress=compress)
    assert a.stats["deliveries"] > 0
    assert b.stats["shards"] == 1
    _assert_bitwise(a, b)


def test_sharded_single_device_churn_matches_compact_bitwise():
    """Membership events thread through the shard_map scan identically:
    the replicated alive/rejoin rows gate each shard's local slice."""
    n, interval = 8, 6
    sc = scenarios.toy_scenario(n, dim=8, malicious=(0,))
    ms = MembershipSchedule.build(
        [(8, (), (3,)), (20, (3,), ()), (30, (), (5,))],
        rejoin_decay=0.5)
    spec = FederationSpec.build(
        n, malicious=(0,), membership=ms,
        initial_countdown=[3 + (7 * i) % interval for i in range(n)])
    a, b = _pair(sc, T.full(n), spec, ticks=48, interval=interval)
    assert a.stats["deliveries"] > 0
    _assert_bitwise(a, b)


# ==================================================== config-space contract
def test_sharded_config_validation():
    n, interval = 8, 6
    sc = scenarios.toy_scenario(n, dim=4)
    spec = FederationSpec.build(n)
    def cfg(**kw):
        return simlax.SimLaxConfig(ticks=8, train_interval=(interval, interval),
                                   latency=1, ttl=1, record_every=4, **kw)
    # shards= only means something on the sharded engine
    with pytest.raises(ValueError, match="shards"):
        simlax.LaxSimulator(sc, T.full(n), spec, IMPL2,
                            cfg(delivery="compact", shards=2))
    # N must split evenly over the mesh
    with pytest.raises(ValueError, match="divisible"):
        simlax.LaxSimulator(sc, T.full(n), spec, IMPL2,
                            cfg(delivery="sharded", shards=3))
    # cannot ask for more shards than visible devices
    import jax
    too_many = jax.device_count() + 1
    while n % too_many:
        too_many += 1
    with pytest.raises(ValueError, match="device"):
        simlax.LaxSimulator(sc, T.full(n), spec, IMPL2,
                            cfg(delivery="sharded", shards=too_many))


def test_sharded_does_not_compose_with_batching():
    """BatchedFederationSpec x sharding is explicitly rejected (the fed
    mesh axis is taken by the node partition — docs/SCALING.md)."""
    n = 8
    sc = scenarios.toy_scenario(n, dim=4)
    batch = BatchedFederationSpec.build(
        [FederationSpec.build(n), FederationSpec.build(n, malicious=(0,))])
    cfg = simlax.SimLaxConfig(ticks=8, train_interval=(6, 6), latency=1,
                              ttl=1, record_every=4, delivery="sharded")
    with pytest.raises(ValueError, match="[Bb]atched"):
        simlax.LaxSimulator(sc, T.full(n), batch, IMPL2, cfg)


# ================================================= forced 8-host-device mesh
_SUBPROC_COMMON = r"""
import numpy as np, jax
assert jax.device_count() == 8, jax.device_count()
from repro.chain import scenarios, simlax
from repro.chain.attacks import FederationSpec, MembershipSchedule
from repro.core import topology as T
from repro.core.reputation import IMPL2

def pair(sc, topo, spec, *, ticks, interval, ttl, compress=None):
    out = []
    for eng in ("compact", "sharded"):
        cfg = simlax.SimLaxConfig(
            ticks=ticks, train_interval=(interval, interval), latency=1,
            ttl=ttl, record_every=8, seed=0, delivery=eng, compress=compress)
        out.append(simlax.LaxSimulator(sc, topo, spec, IMPL2, cfg).run())
    return out

def check(a, b):
    for k in ("broadcasts", "deliveries", "fedavg_rounds",
              "max_tick_deliveries"):
        assert a.stats[k] == b.stats[k], (k, a.stats[k], b.stats[k])
    np.testing.assert_array_equal(a.stats["broadcasts_per_node"],
                                  b.stats["broadcasts_per_node"])
    for k in a.final_state:
        if k in b.final_state:
            np.testing.assert_array_equal(np.asarray(a.final_state[k]),
                                          np.asarray(b.final_state[k]),
                                          err_msg=k)
    np.testing.assert_array_equal(a.reputation, b.reputation)
    np.testing.assert_array_equal(a.acc_history, b.acc_history)
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a.params, b.params)
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a.sent, b.sent)
    assert a.stats["deliveries"] > 0
    assert b.stats["shards"] == 8
"""


def test_sharded_eight_devices_toy_bitwise(subprocess_runner):
    """The acceptance pin: sharded == compact bit for bit on a REAL
    8-device mesh — with attackers, int8 wire compression, and churn."""
    code = _SUBPROC_COMMON + r"""
n, interval = 16, 6
sc = scenarios.toy_scenario(n, dim=8, malicious=(0, 5))
topo = T.kregular(n, 3)
cd = [3 + (7 * i) % interval for i in range(n)]
for compress in (None, "int8"):
    spec = FederationSpec.build(n, malicious=(0, 5), initial_countdown=cd)
    a, b = pair(sc, topo, spec, ticks=48, interval=interval, ttl=2,
                compress=compress)
    check(a, b)
ms = MembershipSchedule.build(
    [(7, (), (3, 11)), (19, (3,), ()), (29, (11,), ()), (37, (), (6,))],
    rejoin_decay=0.5, initial_offline=(9,))
spec = FederationSpec.build(n, malicious=(0, 5), initial_countdown=cd,
                            membership=ms)
a, b = pair(sc, topo, spec, ticks=48, interval=interval, ttl=2)
check(a, b)
print("TOY-8DEV-OK")
"""
    r = subprocess_runner(code, host_devices=8)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "TOY-8DEV-OK" in r.stdout


def test_sharded_eight_devices_lenet_bitwise(subprocess_runner):
    """Same pin on the paper's real workload: LeNet-5, non-IID shards,
    gaussian poisoning, one node per device (N=8, S=8)."""
    code = _SUBPROC_COMMON + r"""
n, interval = 8, 6
sc = scenarios.lenet_scenario(n, malicious=(0,), pool=32, eval_size=8,
                              test_size=32, train_steps=1, batch=8)
spec = FederationSpec.build(
    n, malicious=(0,),
    initial_countdown=[3 + (7 * i) % interval for i in range(n)])
a, b = pair(sc, T.kregular(n, 2), spec, ticks=24, interval=interval, ttl=2)
check(a, b)
print("LENET-8DEV-OK")
"""
    r = subprocess_runner(code, host_devices=8)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "LENET-8DEV-OK" in r.stdout
