"""Partial-consensus gossip: multi-device semantics via subprocess (device
count must be set before jax init; the main pytest process keeps 1 device)."""
import json

import pytest

GOSSIP_EQUIV = r"""
import jax, jax.numpy as jnp, numpy as np, json
from repro.core import gossip as gossip_lib, fedavg
from repro.core.reputation import IMPL2
from repro.launch.mesh import make_fed_mesh

F, D = 4, 8
mesh = make_fed_mesh(F, 1, 1)
models = jnp.arange(F * D, dtype=jnp.float32).reshape(F, D)
rep = jnp.ones((F, F))
# eval returns a deterministic per-node accuracy from the model itself
def eval_fn(params, vb):
    return jnp.clip(jnp.mean(params) / 40.0, 0.0, 1.0)
round_fn = gossip_lib.make_gossip_round(
    eval_fn, fed_axis="fed", fed_size=F, ttl=1, rep_impl=IMPL2, mesh=mesh)
vb = jnp.zeros((F, 1))
with mesh:
    new, new_rep, m = jax.jit(round_fn)(models, rep, vb)

# host-side oracle: each node averages its ring neighbors weighted by
# rep * acc (receiver-measured), Eq. 3 with its own model as prev
def acc_of(i): return float(np.clip(np.mean(np.arange(i*D,(i+1)*D))/40.0, 0, 1))
expect = np.zeros((F, D))
for i in range(F):
    nb = [(i - 1) % F, (i + 1) % F]
    w = np.array([1.0 * acc_of(j) for j in nb])
    stack = np.stack([np.arange(j*D,(j+1)*D, dtype=np.float32) for j in nb])
    avg = (w / w.sum()) @ stack
    expect[i] = 0.5 * (avg + np.arange(i*D,(i+1)*D))
np.testing.assert_allclose(np.asarray(new), expect, rtol=1e-5)

# reputation: each node punished its lowest-accuracy neighbor by 0.05
rep_np = np.asarray(new_rep)
for i in range(F):
    worst = min([(i-1)%F, (i+1)%F], key=acc_of)
    assert abs(rep_np[i, worst] - 0.95) < 1e-6, (i, rep_np[i])
print(json.dumps({"ok": True}))
"""

LOCAL_ISOLATION = r"""
import jax, jax.numpy as jnp, numpy as np, json
from repro.core import gossip as gossip_lib
from repro.launch.mesh import make_fed_mesh

F = 4
mesh = make_fed_mesh(F, 1, 1)
def train_step(state, batch):
    # 'training' = add my batch mean; leaks across nodes would show up
    return {"w": state["w"] + jnp.mean(batch)}, {"loss": jnp.mean(batch)}
local = gossip_lib.make_local_steps(train_step, fed_axis="fed", mesh=mesh)
state = {"w": jnp.zeros((F, 2))}
batches = jnp.arange(F * 3 * 2, dtype=jnp.float32).reshape(F, 3, 2)
with mesh:
    out, metrics = jax.jit(local)(state, batches)
expect = np.asarray([batches[i].reshape(3, -1).mean(1).sum() for i in range(F)])
np.testing.assert_allclose(np.asarray(out["w"])[:, 0], expect, rtol=1e-6)
print(json.dumps({"ok": True}))
"""

INT8_GOSSIP = r"""
import jax, jax.numpy as jnp, numpy as np, json
from repro.core import gossip as gossip_lib
from repro.core.reputation import IMPL1
from repro.launch.mesh import make_fed_mesh

F, D = 4, 512
mesh = make_fed_mesh(F, 1, 1)
key = jax.random.PRNGKey(0)
models = jax.random.normal(key, (F, D))
rep = jnp.ones((F, F))
eval_fn = lambda p, vb: jnp.asarray(0.5)
mk = lambda comp: gossip_lib.make_gossip_round(
    eval_fn, fed_axis="fed", fed_size=F, ttl=1, rep_impl=IMPL1,
    compress=comp, mesh=mesh)
vb = jnp.zeros((F, 1))
with mesh:
    exact, _, _ = jax.jit(mk(None))(models, rep, vb)
    quant, _, _ = jax.jit(mk("int8"))(models, rep, vb)
rel = float(jnp.max(jnp.abs(exact - quant)) / jnp.max(jnp.abs(exact)))
assert rel < 0.02, rel
print(json.dumps({"ok": True, "rel": rel}))
"""


TOPOLOGY_GOSSIP = r"""
import jax, jax.numpy as jnp, numpy as np, json
from repro.core import gossip as gossip_lib, topology as T
from repro.core.reputation import IMPL2
from repro.launch.mesh import make_fed_mesh
from repro.launch import hlo_cost

F, D = 8, 16
mesh = make_fed_mesh(F, 1, 1)
models = jnp.arange(F * D, dtype=jnp.float32).reshape(F, D) / (F * D)
rep = jnp.ones((F, F))
def eval_fn(params, vb):
    return jnp.clip(jnp.mean(params) + 0.5, 0.0, 1.0)
vb = jnp.zeros((F, 1))

def permute_count(fn):
    with mesh:
        txt = jax.jit(fn).lower(models, rep, vb).compile().as_text()
    return hlo_cost.analyze(txt).collective_count.get("collective-permute", 0)

# 1) ring topology reproduces the seed lowering: exactly 2*ttl permutes
for ttl in (1, 2):
    fn = gossip_lib.make_gossip_round(
        eval_fn, fed_axis="fed", fed_size=F, ttl=ttl, rep_impl=IMPL2,
        mesh=mesh, topology=T.ring(F))
    assert permute_count(fn) == 2 * ttl, ttl

# 2) three non-ring topologies lower, execute, and match a host oracle (ttl=1)
mn = np.asarray(models)
def acc_of(j): return float(np.clip(mn[j].mean() + 0.5, 0, 1))
for topo in (T.kregular(F, 2), T.erdos_renyi(F, 0.4, 1),
             T.small_world(F, 2, 0.3, 0), T.full(F)):
    fn = gossip_lib.make_gossip_round(
        eval_fn, fed_axis="fed", fed_size=F, ttl=1, rep_impl=IMPL2,
        mesh=mesh, topology=topo)
    sched = T.gossip_schedule(topo, 1)
    assert permute_count(fn) == sched.num_collectives, topo.kind
    with mesh:
        new, new_rep, m = jax.jit(fn)(models, rep, vb)
    expect = np.zeros((F, D))
    for i in range(F):
        nb = topo.neighbors(i)
        w = np.array([acc_of(j) for j in nb])
        expect[i] = 0.5 * ((w / w.sum()) @ mn[nb] + mn[i])
    np.testing.assert_allclose(np.asarray(new), expect, rtol=1e-5)
    np.testing.assert_array_equal(
        np.asarray(m["models_received"]), topo.degrees().astype(np.float32))

# 3) kregular ttl=2: the whole ttl-ball, each sender weighted exactly once
topo, ttl = T.kregular(F, 2), 2
fn = gossip_lib.make_gossip_round(
    eval_fn, fed_axis="fed", fed_size=F, ttl=ttl, rep_impl=IMPL2,
    mesh=mesh, topology=topo)
with mesh:
    new, _, m = jax.jit(fn)(models, rep, vb)
dist = topo.hop_distance()
expect = np.zeros((F, D))
for i in range(F):
    ball = [j for j in range(F) if 1 <= dist[i, j] <= ttl]
    w = np.array([acc_of(j) for j in ball])
    expect[i] = 0.5 * ((w / w.sum()) @ mn[ball] + mn[i])
np.testing.assert_allclose(np.asarray(new), expect, rtol=1e-5)
np.testing.assert_array_equal(
    np.asarray(m["models_received"]),
    ((dist >= 1) & (dist <= ttl)).sum(1).astype(np.float32))

# 4) IRREGULAR graphs at ttl=2: the frontier schedule floods the EXACT
# BFS ball through the jitted round (the chain lowering used to miss a
# subset of it) — every in-ball sender weighted exactly once, matching the
# host oracle, with the permute count the schedule promised
for topo in (T.erdos_renyi(F, 0.4, 1), T.small_world(F, 2, 0.3, 0)):
    ttl = 2
    fn = gossip_lib.make_gossip_round(
        eval_fn, fed_axis="fed", fed_size=F, ttl=ttl, rep_impl=IMPL2,
        mesh=mesh, topology=topo)
    sched = T.gossip_schedule(topo, ttl)
    assert T.audit_schedule(topo, ttl, sched).ok, topo.kind
    assert permute_count(fn) == sched.num_collectives, topo.kind
    with mesh:
        new, _, m = jax.jit(fn)(models, rep, vb)
    dist = topo.hop_distance()
    expect = np.zeros((F, D))
    for i in range(F):
        ball = [j for j in range(F) if 1 <= dist[i, j] <= ttl]
        w = np.array([acc_of(j) for j in ball])
        expect[i] = 0.5 * ((w / w.sum()) @ mn[ball] + mn[i])
    np.testing.assert_allclose(np.asarray(new), expect, rtol=1e-5)
    np.testing.assert_array_equal(
        np.asarray(m["models_received"]),
        ((dist >= 1) & (dist <= ttl)).sum(1).astype(np.float32))
    # the chain oracle still lowers but under-covers the same ball
    chain_fn = gossip_lib.make_gossip_round(
        eval_fn, fed_axis="fed", fed_size=F, ttl=ttl, rep_impl=IMPL2,
        mesh=mesh, topology=topo, schedule="chain")
    with mesh:
        _, _, mc = jax.jit(chain_fn)(models, rep, vb)
    assert (np.asarray(mc["models_received"]).sum()
            < np.asarray(m["models_received"]).sum()), topo.kind

# 5) degree-1 node never punishes its only neighbor (reputation freeze guard)
adj = np.zeros((F, F), bool)
for a, b in [(0, 1), (1, 2), (2, 0), (2, 3)] + [(i, (i + 1) % 4) for i in range(4, F - 1)]:
    adj[a, b] = adj[b, a] = True
adj[3, 4] = adj[4, 3] = True          # keep the graph connected
adj[F - 1, 0] = adj[0, F - 1] = True
deg1 = int(np.flatnonzero(adj.sum(1) == 1)[0]) if (adj.sum(1) == 1).any() else None
if deg1 is None:
    adj[5, 6] = adj[6, 5] = False     # force node 6 to degree 1 via 5 only
topo = T.Topology("custom", adj)
fn = gossip_lib.make_gossip_round(
    eval_fn, fed_axis="fed", fed_size=F, ttl=1, rep_impl=IMPL2,
    mesh=mesh, topology=topo)
with mesh:
    _, new_rep, _ = jax.jit(fn)(models, rep, vb)
rep_np = np.asarray(new_rep)
for i in range(F):
    if topo.degrees()[i] == 1:
        np.testing.assert_array_equal(rep_np[i], np.ones(F))  # no punishment
    else:
        assert rep_np[i].min() == 0.95, (i, rep_np[i])        # worst punished
assert (topo.degrees() == 1).any()    # the scenario really has a deg-1 node
print(json.dumps({"ok": True}))
"""


@pytest.mark.parametrize("name,code", [
    ("gossip_matches_oracle", GOSSIP_EQUIV),
    ("local_steps_isolated_per_node", LOCAL_ISOLATION),
    ("int8_compressed_gossip_close_to_exact", INT8_GOSSIP),
    ("arbitrary_topologies_lower_and_match_oracle", TOPOLOGY_GOSSIP),
])
def test_multidevice(subprocess_runner, name, code):
    res = subprocess_runner(code, host_devices=8 if "topolog" in name else 4)
    assert res.returncode == 0, res.stderr[-3000:]
    assert json.loads(res.stdout.strip().splitlines()[-1])["ok"]
